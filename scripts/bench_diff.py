#!/usr/bin/env python3
"""Diff BENCH_*.json files between two directories and flag regressions.

The bench harness (rust/benches/bench_main.rs) writes one
BENCH_<name>.json per benchmark with median/min/max wall-clock ns and
peak allocated bytes. This script compares the current run against a
baseline directory (typically the previous PR's committed numbers in
bench_baseline/) and flags any benchmark whose median time or peak
bytes regressed by more than --threshold percent.

Usage:
    scripts/bench_diff.py --current rust --baseline bench_baseline
    scripts/bench_diff.py --current out --baseline base --threshold 5
    scripts/bench_diff.py ... --warn-only     # time regressions never fail
    scripts/bench_diff.py ... --fail-on-regression  # peak-bytes regressions
                                              # fail even under --warn-only
    scripts/bench_diff.py ... --seed-if-empty # copy current → empty baseline

Besides the per-benchmark diff, the report includes scaling sections
for the `stream/parallel_r{N}*` reduce-stage ingest benches and the
`knn/forest_s{N}*` kd-forest shard benches: the speedup of every
rN/sN entry over its r1/s1 sibling in the *current* run, flagging any
sharded configuration that runs slower than its single-shard baseline.
A kernel-scaling section pairs the `kernel/<op>_scalar_d{D}` benches
with their `kernel/<op>_simd_d{D}` siblings (present only in builds
where the AVX2/FMA dispatcher resolved) and the `kmeans/bounds_off_*`
benches with `kmeans/bounds_on_*`, including the recorded
`bound_hit_pct` pruning rate. A dist-scaling section pairs the
`dist/loopback_w{N}*` leased-ingest benches against their `w0`
in-process sibling — output is byte-identical across worker counts
(rust/tests/dist_parity.rs pins that), so the ratio is the protocol's
overhead-vs-offload balance. All of these are ordinary BENCH_*.json
entries, so the regression gate (`--fail-on-regression`) covers them
like every other bench.

`--seed-if-empty` starts the perf trajectory on the first machine with a
toolchain: when the baseline directory is missing or holds no
BENCH_*.json, the current run's files are copied into it (commit them to
seed the baseline — see bench_baseline/README.md).

Exit status: 0 when no regressions, 1 when at least one metric regressed
past the threshold, 2 on usage errors. `--warn-only` downgrades *time*
regressions to warnings (CI runner timing noise exceeds any sane
threshold); `--fail-on-regression` keeps *peak-bytes* regressions fatal
regardless — allocation counts are deterministic, so a peak regression
on a noisy runner is a real one.
"""

import argparse
import json
import re
import shutil
import sys
from pathlib import Path

METRICS = [("median_ns", "time"), ("peak_bytes", "peak")]


def load_dir(path: Path):
    benches = {}
    for f in sorted(path.glob("BENCH_*.json")):
        try:
            doc = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping unreadable {f}: {e}", file=sys.stderr)
            continue
        name = doc.get("name", f.stem)
        benches[name] = doc
    return benches


def fmt_ns(ns):
    if ns >= 1e9:
        return f"{ns / 1e9:.3f}s"
    if ns >= 1e6:
        return f"{ns / 1e6:.2f}ms"
    return f"{ns / 1e3:.1f}us"


def fmt_bytes(b):
    return f"{b / 1e6:.2f}MB"


# Bench families with a numbered scaling axis: reduce stages
# (stream/parallel_r{N}_…) and kd-forest shards (knn/forest_s{N}_…).
# Each pattern captures the axis letter so the report can label rows
# r1/r2/… or s1/s2/… and compare against the axis-1 baseline.
# (The retired `stream/parallel_rN` static-split names are matched only
# against the *baseline* by shared_vs_static_report below — the current
# run never emits them anymore.)
SCALING_RES = [
    ("shared-pool reduce-stage",
     re.compile(r"^(?P<family>.*?/shared_pool)_(?P<axis>r)(?P<x>\d+)(?P<rest>.*)$")),
    ("kd-forest shard", re.compile(r"^(?P<family>.*?/forest)_(?P<axis>s)(?P<x>\d+)(?P<rest>.*)$")),
]


def scaling_report(current):
    """Speedup of rN/sN over the r1/s1 sibling for every scaled family.

    Returns the number of scaled configurations slower than their
    single-shard/stage sibling (a scaling regression within the current
    run — no baseline needed).
    """
    slower = 0
    for label, pattern in SCALING_RES:
        families = {}
        axis = "?"
        for name, doc in current.items():
            m = pattern.match(name)
            if not m or not doc.get("median_ns"):
                continue
            axis = m.group("axis")
            key = m.group("family") + m.group("rest")
            families.setdefault(key, {})[int(m.group("x"))] = doc["median_ns"]
        printed_header = False
        for key, by_x in sorted(families.items()):
            if by_x.get(1) is None or len(by_x) < 2:
                continue
            if not printed_header:
                print(f"\n{label} scaling (current run, speedup vs {axis}1):")
                printed_header = True
            base = by_x[1]
            for x in sorted(by_x):
                if x == 1:
                    print(f"  {key:<44} {axis}1  {fmt_ns(base):>10}  1.00x")
                    continue
                speedup = base / by_x[x]
                marker = ""
                if speedup < 1.0:
                    marker = f"  << SLOWER THAN {axis}1"
                    slower += 1
                print(f"  {key:<44} {axis}{x:<2} {fmt_ns(by_x[x]):>10}  {speedup:.2f}x{marker}")
    return slower


def shared_vs_static_report(current, baseline):
    '''Speedup of the shared-executor reduce benches over the retired
    static-split ones.

    The `stream/shared_pool_rN_*` benches replaced `stream/parallel_rN_*`
    when the reduce stages moved from statically divided per-stage pools
    onto one work-stealing executor. While a baseline directory still
    holds the old names, print the per-rN speedup of shared over static
    next to the r1-to-rN scaling section, matched by rN and name suffix.
    '''
    pat_new = re.compile(r"^stream/shared_pool_r(\d+)(.*)$")
    pat_old = re.compile(r"^stream/parallel_r(\d+)(.*)$")
    old = {}
    for name, doc in baseline.items():
        m = pat_old.match(name)
        if m and doc.get("median_ns"):
            old[(m.group(1), m.group(2))] = doc["median_ns"]
    printed = False
    for name, doc in sorted(current.items()):
        m = pat_new.match(name)
        if not m or not doc.get("median_ns"):
            continue
        key = (m.group(1), m.group(2))
        if key not in old:
            continue
        if not printed:
            print("\nshared vs static reduce "
                  "(current shared_pool_rN vs baseline parallel_rN):")
            printed = True
        speedup = old[key] / doc["median_ns"]
        label = "r" + key[0] + key[1]
        print(f"  {label:<46} static {fmt_ns(old[key]):>10}  shared "
              f"{fmt_ns(doc['median_ns']):>10}  {speedup:.2f}x")

def kernel_report(current):
    """Scalar-vs-SIMD kernel pairing and bounded-k-means pruning report.

    The `kernel/<op>_simd_d{D}` benches only exist when the AVX2/FMA
    dispatcher actually resolved (feature built, CPU capable, no
    IHTC_FORCE_SCALAR), so a missing simd sibling means a scalar build —
    reported as such rather than treated as an error. Both sections read
    the *current* run only: the cross-build comparison is within one
    run's files, the cross-PR trajectory is the ordinary diff above.
    """
    pat = re.compile(r"^kernel/(?P<op>\w+?)_(?P<kind>scalar|simd)_d(?P<d>\d+)$")
    pairs = {}
    for name, doc in current.items():
        m = pat.match(name)
        if m and doc.get("median_ns"):
            pairs.setdefault((m.group("op"), int(m.group("d"))),
                             {})[m.group("kind")] = doc["median_ns"]
    if pairs:
        print("\nkernel scaling (current run, scalar vs dispatched SIMD):")
        simd_seen = False
        for (op, d), by_kind in sorted(pairs.items()):
            scalar = by_kind.get("scalar")
            simd = by_kind.get("simd")
            if scalar is None:
                continue
            if simd is None:
                print(f"  {op} d={d:<4} scalar {fmt_ns(scalar):>10}  (no simd lane in this build)")
                continue
            simd_seen = True
            print(f"  {op} d={d:<4} scalar {fmt_ns(scalar):>10}  simd "
                  f"{fmt_ns(simd):>10}  {scalar / simd:.2f}x")
        if not simd_seen:
            print("  (scalar build — rerun with --features simd on an AVX2 machine "
                  "for the simd lanes)")

    pat_b = re.compile(r"^kmeans/bounds_(?P<kind>on|off)(?P<rest>.*)$")
    bounds = {}
    hit_pct = {}
    for name, doc in current.items():
        m = pat_b.match(name)
        if m and doc.get("median_ns"):
            bounds.setdefault(m.group("rest"), {})[m.group("kind")] = doc["median_ns"]
            if m.group("kind") == "on" and doc.get("bound_hit_pct") is not None:
                hit_pct[m.group("rest")] = doc["bound_hit_pct"]
    printed = False
    for rest, by_kind in sorted(bounds.items()):
        off, on = by_kind.get("off"), by_kind.get("on")
        if off is None or on is None:
            continue
        if not printed:
            print("\nbounded k-means (current run, Elkan/Hamerly pruning — "
                  "results are byte-identical by contract):")
            printed = True
        hits = f"  hit rate {hit_pct[rest]:.1f}%" if rest in hit_pct else ""
        print(f"  kmeans{rest:<38} off {fmt_ns(off):>10}  on "
              f"{fmt_ns(on):>10}  {off / on:.2f}x{hits}")


def dist_report(current):
    """Distributed-lease loopback scaling: wN workers vs the w0 in-process run.

    The `dist/loopback_w{N}_…` benches run the same fused ingest with
    level-0 reduce batches leased to N loopback worker processes; w0 is
    the plain in-process baseline. Output bytes are identical across N
    (the dist_parity suite pins that), so the ratio isolates wire
    framing + serialization overhead against the offloaded compute.
    Reads the *current* run only, like the other scaling sections.
    """
    pat = re.compile(r"^dist/loopback_w(?P<w>\d+)(?P<rest>.*)$")
    families = {}
    for name, doc in current.items():
        m = pat.match(name)
        if m and doc.get("median_ns"):
            families.setdefault(m.group("rest"), {})[int(m.group("w"))] = doc["median_ns"]
    printed = False
    for rest, by_w in sorted(families.items()):
        if by_w.get(0) is None or len(by_w) < 2:
            continue
        if not printed:
            print("\ndist loopback scaling (current run, leased wN vs in-process w0):")
            printed = True
        base = by_w[0]
        for w in sorted(by_w):
            if w == 0:
                print(f"  dist/loopback{rest:<32} w0  {fmt_ns(base):>10}  1.00x (in-process)")
                continue
            speedup = base / by_w[w]
            marker = "" if speedup >= 1.0 else "  (overhead exceeds offload win)"
            print(f"  dist/loopback{rest:<32} w{w:<2} {fmt_ns(by_w[w]):>10}  {speedup:.2f}x{marker}")


def seed_baseline(cur_dir, base_dir):
    base_dir.mkdir(parents=True, exist_ok=True)
    copied = 0
    for f in sorted(cur_dir.glob("BENCH_*.json")):
        shutil.copy2(f, base_dir / f.name)
        copied += 1
    print(f"seeded baseline {base_dir} with {copied} BENCH_*.json file(s) — "
          f"commit them to start the perf trajectory")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True, help="directory with this run's BENCH_*.json")
    ap.add_argument("--baseline", required=True, help="directory with the previous BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=10.0,
                    help="regression threshold in percent (default: 10)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report time regressions but do not fail on them "
                         "(noisy CI runners)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 on peak-bytes regressions even under "
                         "--warn-only (allocation counts are deterministic)")
    ap.add_argument("--seed-if-empty", action="store_true",
                    help="when the baseline directory is missing/empty, copy the "
                         "current BENCH_*.json there to start the trajectory")
    args = ap.parse_args()

    cur_dir, base_dir = Path(args.current), Path(args.baseline)
    if not cur_dir.is_dir():
        print(f"error: current directory {cur_dir} does not exist", file=sys.stderr)
        return 2
    current = load_dir(cur_dir)
    if not current:
        print(f"error: no BENCH_*.json in {cur_dir}", file=sys.stderr)
        return 2
    baseline = load_dir(base_dir) if base_dir.is_dir() else {}
    if not baseline:
        if args.seed_if_empty:
            seed_baseline(cur_dir, base_dir)
        else:
            print(f"no baseline in {base_dir} — nothing to diff (seed it with "
                  f"--seed-if-empty, or copy {cur_dir}/BENCH_*.json there)")
        scaling_report(current)
        kernel_report(current)
        dist_report(current)
        return 0

    regressions = []
    improvements = 0
    print(f"{'benchmark':<46} {'metric':<6} {'baseline':>10} {'current':>10} {'delta':>8}")
    for name in sorted(current):
        if name not in baseline:
            print(f"{name:<46} (new — no baseline)")
            continue
        for key, label in METRICS:
            old, new = baseline[name].get(key), current[name].get(key)
            if not old or new is None:
                continue  # metric absent or zero in baseline: nothing comparable
            delta = 100.0 * (new - old) / old
            fmt = fmt_ns if key == "median_ns" else fmt_bytes
            marker = ""
            if delta > args.threshold:
                marker = "  << REGRESSION"
                regressions.append((name, label, delta))
            elif delta < -args.threshold:
                marker = "  (improved)"
                improvements += 1
            print(f"{name:<46} {label:<6} {fmt(old):>10} {fmt(new):>10} {delta:>+7.1f}%{marker}")
    missing = sorted(set(baseline) - set(current))
    for name in missing:
        print(f"{name:<46} (missing from current run)")

    slower = scaling_report(current)
    shared_vs_static_report(current, baseline)
    kernel_report(current)
    dist_report(current)

    print(f"\n{len(regressions)} regression(s) past {args.threshold:.0f}%, "
          f"{improvements} improvement(s), {len(missing)} missing, "
          f"{slower} scaled config(s) slower than their r1/s1 baseline")
    peak_regressions = [r for r in regressions if r[1] == "peak"]
    if args.fail_on_regression and peak_regressions:
        print(f"failing: {len(peak_regressions)} peak-bytes regression(s) "
              f"(deterministic metric — not runner noise)", file=sys.stderr)
        return 1
    if regressions and not args.warn_only:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
