#!/usr/bin/env bash
# Tier-1 verification + perf smoke for the ihtc crate.
#
#   scripts/verify.sh            # build + tests + bench smoke
#   IHTC_BENCH_DIR=out scripts/verify.sh   # redirect BENCH_*.json
#
# The bench smoke runs the tiny `smoke/` benches with IHTC_BENCH_FAST=1
# so it finishes in seconds; full perf numbers come from `cargo bench`
# (see README).
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== perf smoke: IHTC_BENCH_FAST=1 cargo bench -- smoke =="
IHTC_BENCH_FAST=1 cargo bench -- smoke

echo "verify.sh: OK"
