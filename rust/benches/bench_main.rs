//! Benchmark harness (`cargo bench`). Criterion is unavailable offline,
//! so this is a self-contained harness: warmup + repeated timing, median
//! and spread per benchmark, with one end-to-end bench per paper table
//! plus the microbenches the §Perf pass iterates on.
//!
//! Filter by substring: `cargo bench -- knn` runs only knn benches,
//! `cargo bench -- e2e` the end-to-end ones, `cargo bench -- smoke` the
//! tiny CI smoke run. `IHTC_BENCH_FAST=1` shrinks workloads.
//!
//! Every bench also writes a machine-readable `BENCH_<name>.json`
//! (median/min/max ns + peak bytes from `memtrack`) into
//! `$IHTC_BENCH_DIR` (default: the working directory) so the perf
//! trajectory is tracked across PRs.

use ihtc::checkpoint::FaultPlan;
use ihtc::cluster::hac::{hac, HacConfig, Linkage};
use ihtc::cluster::kmeans::{kmeans_with_backend, KMeansConfig, NativeAssign};
use ihtc::coordinator::parallel_knn;
use ihtc::dist::DistPool;
use ihtc::exec::Executor;
use ihtc::data::synth::{find_spec, gaussian_mixture_paper, realistic};
use ihtc::data::Preprocess;
use ihtc::hybrid::{FinalClusterer, Ihtc, IhtcWorkspace};
use ihtc::itis::{itis, ItisConfig, PrototypeKind};
use ihtc::knn::forest::KdForest;
use ihtc::knn::{
    kdtree::KdTree, knn_auto, knn_brute, knn_chunked, knn_chunked_pool, KnnLists, NativeChunks,
};
use ihtc::runtime::{Engine, PjrtAssign, PjrtChunks};
use ihtc::tc::{threshold_cluster, TcConfig};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[global_allocator]
static ALLOC: ihtc::memtrack::CountingAllocator = ihtc::memtrack::CountingAllocator;

struct Bench {
    filter: Vec<String>,
    fast: bool,
}

impl Bench {
    fn matches(&self, name: &str) -> bool {
        self.filter.is_empty() || self.filter.iter().any(|f| name.contains(f))
    }

    /// Time `f` (which returns a value to keep the optimizer honest).
    fn run<T>(&self, name: &str, iters: usize, mut f: impl FnMut() -> T) {
        if !self.matches(name) {
            return;
        }
        let iters = if self.fast { 1 } else { iters.max(1) };
        // Warmup.
        std::hint::black_box(f());
        let mut times = Vec::with_capacity(iters);
        ihtc::memtrack::reset_peak();
        let base = ihtc::memtrack::live_bytes();
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let peak = ihtc::memtrack::peak_bytes().saturating_sub(base);
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let min = times[0];
        let max = *times.last().unwrap();
        println!(
            "bench {name:<42} median {:>10.4}s  min {:>10.4}s  max {:>10.4}s  peak {:>9} MB  ({iters} iters)",
            median, min, max, ihtc::memtrack::fmt_mb(peak)
        );
        write_json(name, median, min, max, peak, iters);
    }
}

/// Where bench `name`'s JSON lives: `$IHTC_BENCH_DIR` (default: working
/// directory) with the name sanitized. Shared by the writer and
/// [`read_peak`] so the two can never drift apart.
fn bench_json_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::var("IHTC_BENCH_DIR").unwrap_or_else(|_| ".".into());
    let file = format!("BENCH_{}.json", name.replace(['/', ' ', '(', ')', '+'], "_"));
    std::path::Path::new(&dir).join(file)
}

/// Machine-readable result sink: one `BENCH_<name>.json` per bench in
/// `$IHTC_BENCH_DIR` (default: working directory).
fn write_json(name: &str, median: f64, min: f64, max: f64, peak: usize, iters: usize) {
    let path = bench_json_path(name);
    let to_ns = |s: f64| (s * 1e9).round() as u64;
    let body = format!(
        "{{\"name\":\"{name}\",\"median_ns\":{},\"min_ns\":{},\"max_ns\":{},\"peak_bytes\":{peak},\"iters\":{iters}}}\n",
        to_ns(median),
        to_ns(min),
        to_ns(max)
    );
    if let Err(e) = std::fs::write(&path, body) {
        eprintln!("warning: cannot write {}: {e}", path.display());
    }
}

/// Read back the `peak_bytes` field of a just-written bench JSON (used
/// by the streaming comparison to print the fused-vs-materialized ratio).
fn read_peak(name: &str) -> Option<usize> {
    let text = std::fs::read_to_string(bench_json_path(name)).ok()?;
    let tail = text.split("\"peak_bytes\":").nth(1)?;
    let digits: String = tail.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

/// Read back the `median_ns` field of a just-written bench JSON (used
/// by the checkpoint-overhead printout).
fn read_median(name: &str) -> Option<u64> {
    let text = std::fs::read_to_string(bench_json_path(name)).ok()?;
    let tail = text.split("\"median_ns\":").nth(1)?;
    let digits: String = tail.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

/// Splice one extra numeric field into a just-written bench JSON (the
/// bounds bench records its measured `bound_hit_pct` alongside the
/// timings so `scripts/bench_diff.py` can report pruning power, not
/// just wall-clock).
fn append_json_field(name: &str, key: &str, value: &str) {
    let path = bench_json_path(name);
    let Ok(text) = std::fs::read_to_string(&path) else { return };
    let head = text.trim_end().trim_end_matches('}');
    if let Err(e) = std::fs::write(&path, format!("{head},\"{key}\":{value}}}\n")) {
        eprintln!("warning: cannot rewrite {}: {e}", path.display());
    }
}

/// Deterministic pseudo-random row-major matrix for the kernel benches
/// (an LCG; no rand dependency, same bytes every run).
fn kernel_rows(n: usize, d: usize, salt: u32) -> Vec<f32> {
    let mut state = 0x9e37_79b9u32 ^ salt;
    (0..n * d)
        .map(|_| {
            state = state.wrapping_mul(1_664_525).wrapping_add(1_013_904_223);
            (state >> 8) as f32 / (1u32 << 24) as f32 - 0.5
        })
        .collect()
}

fn main() {
    // `cargo bench` passes `--bench`; everything else is a filter.
    let filter: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with("--"))
        .collect();
    let fast = std::env::var("IHTC_BENCH_FAST").is_ok();
    let b = Bench { filter, fast };
    let engine = Engine::load(Engine::default_dir()).ok();
    if engine.is_none() {
        eprintln!("note: PJRT artifacts not found; pjrt benches skipped");
    }
    let small = if b.fast { 2_000 } else { 20_000 };
    let big = if b.fast { 5_000 } else { 100_000 };

    // ---------- microbenches (the §Perf iteration targets) ----------
    let ds_small = gaussian_mixture_paper(small, 1);
    let ds_big = gaussian_mixture_paper(big, 1);

    b.run("micro/knn_brute_n2e4_k3", 3, || knn_brute(&ds_small.points, 3).unwrap());
    b.run("micro/knn_kdtree_n2e4_k3", 5, || {
        KdTree::build(&ds_small.points).knn_all(&ds_small.points, 3).unwrap()
    });
    b.run("micro/knn_kdtree_n1e5_k3", 3, || {
        KdTree::build(&ds_big.points).knn_all(&ds_big.points, 3).unwrap()
    });
    let pool = Executor::new(0);
    b.run(
        &format!("micro/knn_parallel_n1e5_k3_w{}", pool.workers()),
        3,
        || parallel_knn(&ds_big.points, 3, &pool).unwrap(),
    );
    // Serial vs pooled construction and the default (pooled) auto path —
    // the acceptance comparison for the §Perf parallelization pass.
    b.run("knn/build_kdtree_serial_n1e5", 5, || KdTree::build(&ds_big.points));
    b.run(
        &format!("knn/build_kdtree_parallel_n1e5_w{}", pool.workers()),
        5,
        || KdTree::build_parallel(&ds_big.points, &pool),
    );
    b.run(
        &format!("knn/auto_pooled_n1e5_k3_w{}", pool.workers()),
        3,
        || knn_auto(&ds_big.points, 3).unwrap(),
    );
    // Sharded kd-forest: per-shard parallel construction + merged
    // queries. s=1 is the serial single-tree baseline; bench_diff.py
    // reports the s1→sN scaling alongside the stream/shared_pool_r{N}
    // reduce-stage section. Output is byte-identical across s (and to
    // knn_brute), so only wall-clock and peak bytes move.
    for s in [1usize, 2, 4] {
        b.run(&format!("knn/forest_s{s}_build_n1e5"), 5, || {
            let mut forest = KdForest::new();
            forest.rebuild(&ds_big.points, s, &pool);
            forest
        });
        // The query bench's index build lives outside b.run (only the
        // queries are timed), so gate it on the filter too — a filtered
        // `cargo bench -- stream` must not pay three 1e5-point builds.
        let query_name = format!("knn/forest_s{s}_query_n1e5_k3");
        if b.matches(&query_name) {
            let mut forest = KdForest::new();
            forest.rebuild(&ds_big.points, s, &pool);
            let mut forest_out = KnnLists::default();
            b.run(&query_name, 3, || {
                forest.knn_all_pool_into(&ds_big.points, 3, &pool, &mut forest_out).unwrap()
            });
        }
    }
    b.run("micro/knn_chunked_native_n2e4_k15", 3, || {
        knn_chunked(&ds_small.points, 15, 256, 1024, &NativeChunks::default()).unwrap()
    });
    b.run(
        &format!("knn/chunked_pooled_n2e4_k15_w{}", pool.workers()),
        3,
        || {
            knn_chunked_pool(&ds_small.points, 15, 256, 1024, &NativeChunks::default(), &pool)
                .unwrap()
        },
    );
    if let Some(engine) = &engine {
        b.run("micro/knn_chunked_pjrt_n2e4_k15", 3, || {
            knn_chunked(&ds_small.points, 15, engine.tile.knn_q, engine.tile.knn_r, &PjrtChunks {
                engine,
            })
            .unwrap()
        });
    }
    b.run("micro/tc_t2_n1e5(graph+seeds+grow)", 3, || {
        threshold_cluster(&ds_big.points, &TcConfig::new(2)).unwrap()
    });
    b.run("micro/itis_m3_t2_n1e5", 3, || {
        itis(&ds_big.points, &ItisConfig::iterations(2, 3)).unwrap()
    });
    b.run("micro/kmeans_native_n1e5_k3", 3, || {
        kmeans_with_backend(&ds_big.points, None, &KMeansConfig::new(3), &NativeAssign).unwrap()
    });
    if let Some(engine) = &engine {
        b.run("micro/kmeans_pjrt_n1e5_k3", 3, || {
            kmeans_with_backend(&ds_big.points, None, &KMeansConfig::new(3), &PjrtAssign {
                engine,
            })
            .unwrap()
        });
    }
    let ds_hac = gaussian_mixture_paper(if b.fast { 500 } else { 4_000 }, 2);
    b.run("micro/hac_ward_n4e3", 3, || {
        hac(&ds_hac.points, &HacConfig::default()).unwrap()
    });

    // ---------- distance kernels: scalar vs dispatched SIMD ----------
    // The `_scalar` benches always run (direct calls, any build); the
    // `_simd` benches only exist when the dispatcher actually resolved
    // the AVX2/FMA kernels (feature on + CPU support + no
    // IHTC_FORCE_SCALAR), so bench_diff.py's kernel-scaling section can
    // pair them without guessing the build. d=8 is the SIMD threshold
    // (one vector lane, worst case); d=64 is the amortized case.
    for d in [8usize, 64] {
        let rows = if b.fast { 2_000 } else { 50_000 };
        let a = kernel_rows(rows, d, 1);
        let c = kernel_rows(rows, d, 2);
        b.run(&format!("kernel/sq_dist_scalar_d{d}"), 5, || {
            let mut acc = 0.0f32;
            for i in 0..rows {
                acc += ihtc::linalg::sq_dist_scalar(&a[i * d..(i + 1) * d], &c[i * d..(i + 1) * d]);
            }
            acc
        });
        b.run(&format!("kernel/dot_scalar_d{d}"), 5, || {
            let mut acc = 0.0f32;
            for i in 0..rows {
                acc += ihtc::linalg::dot_scalar(&a[i * d..(i + 1) * d], &c[i * d..(i + 1) * d]);
            }
            acc
        });
        if ihtc::linalg::simd::active() {
            let sq = ihtc::linalg::simd::sq_dist_kernel();
            let dot = ihtc::linalg::simd::dot_kernel();
            b.run(&format!("kernel/sq_dist_simd_d{d}"), 5, || {
                let mut acc = 0.0f32;
                for i in 0..rows {
                    acc += sq(&a[i * d..(i + 1) * d], &c[i * d..(i + 1) * d]);
                }
                acc
            });
            b.run(&format!("kernel/dot_simd_d{d}"), 5, || {
                let mut acc = 0.0f32;
                for i in 0..rows {
                    acc += dot(&a[i * d..(i + 1) * d], &c[i * d..(i + 1) * d]);
                }
                acc
            });
        }
    }

    // ---------- bounded k-means: Elkan/Hamerly pruning ----------
    // Identical input and config except the `bounds` flag; the results
    // are byte-identical by contract (tests pin that), so the only
    // things that move are wall-clock and the recorded bound-hit rate.
    {
        let mut cfg = KMeansConfig::new(8);
        b.run("kmeans/bounds_off_n1e5_k8", 3, || {
            kmeans_with_backend(&ds_big.points, None, &cfg, &NativeAssign).unwrap()
        });
        cfg.bounds = true;
        let hit_pct = std::cell::Cell::new(None);
        b.run("kmeans/bounds_on_n1e5_k8", 3, || {
            let r = kmeans_with_backend(&ds_big.points, None, &cfg, &NativeAssign).unwrap();
            hit_pct.set(Some(100.0 * r.bound_hits as f64 / r.bound_checks.max(1) as f64));
            r
        });
        if let Some(pct) = hit_pct.get() {
            append_json_field("kmeans/bounds_on_n1e5_k8", "bound_hit_pct", &format!("{pct:.1}"));
            println!("kmeans: Elkan/Hamerly bound hit rate {pct:.1}% of checked points pruned");
        }
    }

    // ---------- one end-to-end bench per paper table ----------
    // Table 1 / Figs 3-4: IHTC+kmeans, m=0 vs m=1 vs m=2 (the headline).
    for m in [0usize, 1, 2] {
        b.run(&format!("table1/ihtc_kmeans_n1e5_m{m}"), 3, || {
            Ihtc::new(2, m, FinalClusterer::KMeans { k: 3, restarts: 4 })
                .run(&ds_big.points)
                .unwrap()
        });
    }
    // Table 2 / Figs 5-6: IHTC+HAC (m chosen so HAC is feasible).
    for m in [3usize, 5] {
        b.run(&format!("table2/ihtc_hac_n1e5_m{m}"), 2, || {
            Ihtc::new(2, m, FinalClusterer::Hac { k: 3, linkage: Linkage::Ward })
                .run(&ds_big.points)
                .unwrap()
        });
    }
    // Tables 3-6 / Figs 7-8: the dataset analogues.
    let cover = {
        let spec = find_spec("covertype").unwrap();
        let ds = realistic(spec, if b.fast { 400 } else { 20 }, 3);
        Preprocess { standardize: true, pca_variance: Some(0.99), max_components: None }
            .apply(&ds)
            .unwrap()
    };
    b.run("table4/covertype_kmeans_m0", 2, || {
        Ihtc::new(2, 0, FinalClusterer::KMeans { k: 7, restarts: 4 }).run(&cover.points).unwrap()
    });
    b.run("table4/covertype_kmeans_m2", 2, || {
        Ihtc::new(2, 2, FinalClusterer::KMeans { k: 7, restarts: 4 }).run(&cover.points).unwrap()
    });
    b.run("table6/covertype_hac_m4", 2, || {
        Ihtc::new(2, 4, FinalClusterer::Hac { k: 7, linkage: Linkage::Ward })
            .run(&cover.points)
            .unwrap()
    });
    // Table 7/8 (Appendix A): t* sweep at m=1.
    for t in [2usize, 8, 32] {
        b.run(&format!("table7/tstar{t}_kmeans_n2e4_m1"), 2, || {
            Ihtc::new(t, 1, FinalClusterer::KMeans { k: 3, restarts: 4 })
                .run(&ds_small.points)
                .unwrap()
        });
    }
    b.run("table8/tstar8_hac_n2e4_m1", 2, || {
        Ihtc::new(8, 1, FinalClusterer::Hac { k: 3, linkage: Linkage::Ward })
            .run(&ds_small.points)
            .unwrap()
    });
    // Table 9 (Appendix B): DBSCAN hybrid.
    let pm = {
        let spec = find_spec("pm 2.5").unwrap();
        let ds = realistic(spec, if b.fast { 30 } else { 2 }, 4);
        Preprocess { standardize: true, pca_variance: Some(0.99), max_components: None }
            .apply(&ds)
            .unwrap()
    };
    let params = ihtc::cluster::dbscan::estimate_params(&pm.points, 1000, 5).unwrap();
    for m in [0usize, 1] {
        b.run(&format!("table9/pm25_dbscan_m{m}"), 2, || {
            Ihtc::new(2, m, FinalClusterer::Dbscan { eps: params.eps, min_pts: params.min_pts })
                .run(&pm.points)
                .unwrap()
        });
    }

    // ---------- end-to-end IHTC: fresh vs reused workspace ----------
    // The peak column of the reuse bench versus the fresh bench is the
    // reduced-allocation acceptance signal for `IhtcWorkspace`.
    let ih = Ihtc::new(2, 2, FinalClusterer::KMeans { k: 3, restarts: 4 });
    b.run("e2e/ihtc_fresh_n1e5_m2", 3, || ih.run(&ds_big.points).unwrap());
    {
        let mut ws = IhtcWorkspace::new();
        b.run(
            &format!("e2e/ihtc_workspace_reuse_n1e5_m2_w{}", pool.workers()),
            3,
            || ih.run_with(&ds_big.points, &pool, &mut ws).unwrap(),
        );
    }

    // ---------- coordinator / pipeline overhead ----------
    b.run("pipeline/e2e_native_n1e5_m2", 2, || {
        let cfg = ihtc::config::PipelineConfig {
            source: ihtc::config::DataSource::PaperMixture { n: big },
            iterations: 2,
            workers: 0,
            ..Default::default()
        };
        ihtc::coordinator::driver::run(&cfg).unwrap()
    });

    // ---------- out-of-core streaming: fused vs materialized ----------
    // The acceptance comparison for the fused streaming ingest: the same
    // 1M-row synthetic source and identical clustering settings, with
    // only the execution model switched. The fused path must show ≥2×
    // lower peak bytes (its resident set is one shard + the prototype
    // stream instead of the full n × d matrix and its n × k neighbor
    // lists).
    {
        let nstream = if b.fast { 50_000 } else { 1_000_000 };
        let stream_cfg = |streaming: bool| ihtc::config::PipelineConfig {
            name: if streaming { "fused".into() } else { "materialized".into() },
            source: ihtc::config::DataSource::PaperMixture { n: nstream },
            threshold: 4,
            iterations: 2,
            prototype: PrototypeKind::WeightedCentroid,
            streaming,
            shard_size: 65_536,
            workers: 0,
            ..Default::default()
        };
        b.run("stream/materialized_n1e6_t4_m2", 1, || {
            ihtc::coordinator::driver::run(&stream_cfg(false)).unwrap()
        });
        b.run("stream/fused_n1e6_t4_m2", 1, || {
            ihtc::coordinator::driver::run(&stream_cfg(true)).unwrap()
        });
        if let (true, Some(mat), Some(fused)) = (
            b.matches("stream/"),
            read_peak("stream/materialized_n1e6_t4_m2"),
            read_peak("stream/fused_n1e6_t4_m2"),
        ) {
            let ratio = mat as f64 / fused.max(1) as f64;
            println!(
                "stream: materialized peak {} MB, fused peak {} MB → {ratio:.2}× lower{}",
                ihtc::memtrack::fmt_mb(mat),
                ihtc::memtrack::fmt_mb(fused),
                if ratio >= 2.0 { "  [OK ≥2×]" } else { "  [BELOW 2× TARGET]" }
            );
        }

        // Shared-executor reduce stages: pure ingest throughput (the
        // fused level-0 reduction is the bottleneck stage; N stage
        // threads submit into ONE work-stealing executor and the reorder
        // buffer restores stream order, so output is byte-identical
        // across r — only wall-clock moves). `scripts/bench_diff.py`
        // reports the r1→rN scaling of these, plus the shared-vs-static
        // section against any retired `stream/parallel_rN` baseline.
        for r in [1usize, 2, 4] {
            let mut cfg = stream_cfg(true);
            cfg.name = format!("shared_pool_r{r}");
            cfg.reduce_stages = r;
            b.run(&format!("stream/shared_pool_r{r}_ingest_n1e6_t4"), 1, || {
                ihtc::coordinator::driver::ingest_streaming(&cfg).unwrap()
            });
        }

        // Durable checkpointing: the same fused r1 ingest with the
        // CRC-framed checkpoint sink armed at its worst-case durability
        // cadence (one fsync per shard). The delta against
        // stream/shared_pool_r1 is the whole crash-safety tax (target
        // ≤ 10%); the peak-bytes column of every stream/ ingest bench
        // meanwhile excludes the old O(n) resident level-0 map, which
        // now lives in this file (or an anonymous spill) instead of RAM.
        {
            let ckpt = std::env::temp_dir().join("ihtc_bench_checkpoint.ckpt");
            let mut cfg = stream_cfg(true);
            cfg.name = "checkpointed".into();
            cfg.checkpoint_path = Some(ckpt.to_string_lossy().into_owned());
            b.run("stream/checkpointed_ingest_n1e6", 1, || {
                ihtc::coordinator::driver::ingest_streaming(&cfg).unwrap()
            });
            let _ = std::fs::remove_file(&ckpt);
            let _ = std::fs::remove_file(ihtc::checkpoint::tmp_path(&ckpt));
            if let (true, Some(plain), Some(ckpted)) = (
                b.matches("stream/"),
                read_median("stream/shared_pool_r1_ingest_n1e6_t4"),
                read_median("stream/checkpointed_ingest_n1e6"),
            ) {
                let overhead = ckpted as f64 / plain.max(1) as f64 - 1.0;
                println!(
                    "stream: checkpointed ingest overhead {:+.1}% vs un-checkpointed{}",
                    overhead * 100.0,
                    if overhead <= 0.10 { "  [OK ≤10%]" } else { "  [ABOVE 10% TARGET]" }
                );
            }
        }

        // Distributed leases over loopback: the same fused ingest with
        // its level-0 reduce batches leased to N worker threads running
        // the real wire protocol (`ihtc::dist::serve`) on 127.0.0.1.
        // w0 is the in-process baseline (no pool at all). Output is
        // byte-identical across w — rust/tests/dist_parity.rs pins
        // that — so the wN-vs-w0 delta `scripts/bench_diff.py` reports
        // is purely framing/serialization overhead traded against the
        // leased remote compute.
        for w in [0usize, 1, 2] {
            let name = format!("dist/loopback_w{w}_ingest_n1e6");
            if !b.matches(&name) {
                continue;
            }
            let mut cfg = stream_cfg(true);
            cfg.name = format!("dist_w{w}");
            cfg.reduce_stages = 4; // keep ≥ w leases in flight
            if w == 0 {
                b.run(&name, 1, || {
                    ihtc::coordinator::driver::ingest_streaming(&cfg).unwrap()
                });
                continue;
            }
            let pool = DistPool::listen("127.0.0.1:0", Duration::from_secs(60)).unwrap();
            let workers: Vec<_> = (0..w)
                .map(|_| {
                    let addr = pool.addr().to_string();
                    std::thread::spawn(move || ihtc::dist::serve(&addr, 2))
                })
                .collect();
            assert!(pool.wait_for_workers(w, Duration::from_secs(10)), "workers didn't connect");
            b.run(&name, 1, || {
                ihtc::coordinator::driver::ingest_streaming_with_pool(
                    &cfg,
                    Some(Arc::clone(&pool)),
                    &FaultPlan::none(),
                )
                .unwrap()
            });
            pool.shutdown();
            for h in workers {
                h.join().unwrap().unwrap();
            }
        }
    }

    // ---------- CI smoke (scripts/verify.sh filters on "smoke") ----------
    let ds_smoke = gaussian_mixture_paper(2_000, 5);
    b.run("smoke/e2e_n2e3_m2", 1, || {
        Ihtc::new(2, 2, FinalClusterer::KMeans { k: 3, restarts: 2 })
            .run(&ds_smoke.points)
            .unwrap()
    });
}
