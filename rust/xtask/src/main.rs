//! `cargo xtask`-style determinism / unsafe-hygiene lint.
//!
//! Usage: `cargo run --manifest-path rust/xtask/Cargo.toml -- [SRC_DIR]`
//! (default `rust/src`). Exit code 0 = clean, 1 = findings, 2 = usage /
//! I/O error. CI runs this as the `determinism-lint` job.
//!
//! The byte-parity contract ("same config ⇒ same output bytes, any
//! worker count") and the loom/Miri lanes only stay meaningful if new
//! code keeps their preconditions. Those preconditions are mechanical,
//! so this binary enforces them mechanically:
//!
//! 1. **safety-comment** — every `unsafe` keyword must have a
//!    `// SAFETY:` (or `# Safety` doc section) within the 10 lines
//!    above or 2 below it.
//! 2. **hash-iter** — no `HashMap`/`HashSet` in non-test code: hash
//!    iteration order is nondeterministic across processes (SipHash
//!    keys are random), so any iterated map silently breaks byte
//!    parity. Keyed-lookup-only uses are allowlisted in place with a
//!    `det-lint: allow(hash-iter)` comment stating *why* order cannot
//!    leak.
//! 3. **wallclock** — `Instant`/`SystemTime` only in the timing-owning
//!    modules (driver, pipeline stage metrics, executor batch timing,
//!    bench runners, main): time must never steer an algorithm.
//! 4. **raw-spawn** — no `thread::spawn` outside the `sync` facade:
//!    ad-hoc threads bypass the executor (and loom cannot see them).
//! 5. **raw-atomic** — no `std::sync::atomic` imports outside the
//!    `sync` facade: raw atomics dodge loom's model checking.
//!    Const-init statics that genuinely cannot go through the facade
//!    carry `det-lint: allow(raw-atomic)` markers in place.
//! 6. **stage-spawn** — no `thread::spawn_named` outside `sync` and
//!    `exec`: with the executor-native pipeline, parallel work is
//!    submitted to the shared team as prioritized batches, so a new
//!    dedicated stage thread is a structural regression. The surviving
//!    source/sink/reorder threads in `coordinator/pipeline.rs` carry
//!    `det-lint: allow(stage-spawn)` markers stating why each is
//!    legitimately not executor work.
//! 7. **std-mpsc** — no `std::sync::mpsc` outside the `sync` facade:
//!    loom has no mpsc double, so channel endpoints are invisible to
//!    the model checker. The pipeline's one deliberate import carries
//!    `det-lint: allow(std-mpsc)` with the argument (the pipeline is
//!    compiled but never *executed* under `--cfg loom`).
//! 8. **arch-gate** — `core::arch` / `std::arch` /
//!    `is_x86_feature_detected!` only inside `linalg/` and `knn/`,
//!    where the kernel dispatcher and its hoisted-pointer callers
//!    live. Intrinsics sprinkled anywhere else would fork the
//!    FP-ordering contract per call site; everything reaches SIMD
//!    through `linalg::simd::kernels()` instead.
//! 9. **target-feature** — every `#[target_feature]` fn must have a
//!    SAFETY / `# Safety` comment nearby (same window as rule 1):
//!    calling one is a CPU-capability proof obligation even when the
//!    fn itself is safe, and the comment must say who discharges it.
//! 10. **net-gate** — `std::net` / `TcpListener` / `TcpStream` only
//!    inside `dist/`, the one module that owns the wire protocol. A
//!    socket anywhere else is an unframed, un-CRC'd, un-timeout'd side
//!    channel the lease/re-lease and determinism contracts cannot see;
//!    everything remote goes through `dist::DistPool` / `dist::serve`.
//!
//! `#[cfg(test)]` modules are skipped entirely (tests may hash, sleep,
//! and spawn freely); line comments, block comments, and string
//! literals are stripped before matching so prose and error messages
//! never trip a rule. Markers are read from the *raw* text, so they
//! live in ordinary comments.

use std::path::{Path, PathBuf};

/// How far above a flagged line a marker / SAFETY comment may sit.
const LOOKBACK: usize = 10;
/// How far below an `unsafe` keyword its SAFETY comment may sit (an
/// `unsafe fn` whose first body line is the comment).
const LOOKAHEAD: usize = 2;
/// Marker window for `det-lint: allow(...)` (same line or just above).
const MARKER_LOOKBACK: usize = 5;

#[derive(Debug, PartialEq)]
struct Finding {
    file: PathBuf,
    line: usize,
    rule: &'static str,
    message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file.display(), self.line, self.rule, self.message)
    }
}

fn main() {
    let root = std::env::args().nth(1).unwrap_or_else(|| "rust/src".to_string());
    let root = PathBuf::from(root);
    if !root.is_dir() {
        eprintln!("xtask: source dir {} not found (run from the repo root)", root.display());
        std::process::exit(2);
    }
    let mut files = Vec::new();
    if let Err(e) = collect_rs_files(&root, &mut files) {
        eprintln!("xtask: walking {}: {e}", root.display());
        std::process::exit(2);
    }
    files.sort();
    let mut findings = Vec::new();
    for file in &files {
        match std::fs::read_to_string(file) {
            Ok(text) => lint_file(file, &text, &mut findings),
            Err(e) => {
                eprintln!("xtask: reading {}: {e}", file.display());
                std::process::exit(2);
            }
        }
    }
    if findings.is_empty() {
        println!("determinism-lint: {} files clean", files.len());
        return;
    }
    for f in &findings {
        eprintln!("{f}");
    }
    eprintln!("determinism-lint: {} finding(s) in {} files", findings.len(), files.len());
    std::process::exit(1);
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// One source line, pre-processed.
struct Line {
    /// Code with comments and string-literal *contents* blanked out.
    code: String,
    /// The raw text (markers and SAFETY comments are read from here).
    raw: String,
    /// Inside a `#[cfg(test)] mod … { … }` block.
    in_test_mod: bool,
}

/// Lexer state carried across lines (strings and block comments span
/// physical lines).
#[derive(Default)]
struct LexState {
    in_block_comment: bool,
    in_string: bool,
    /// Raw string (`r"…"`): no escape processing until the closing quote.
    raw_string: bool,
}

/// Blank out comments and string contents, preserving byte positions
/// well enough for word matching. Quote characters themselves are kept
/// so `"…"` still reads as a string token boundary.
fn strip_line(raw: &str, st: &mut LexState) -> String {
    let bytes = raw.as_bytes();
    let mut out = vec![b' '; bytes.len()];
    let mut i = 0;
    while i < bytes.len() {
        if st.in_block_comment {
            if bytes[i] == b'*' && i + 1 < bytes.len() && bytes[i + 1] == b'/' {
                st.in_block_comment = false;
                i += 2;
            } else {
                i += 1;
            }
            continue;
        }
        if st.in_string {
            match bytes[i] {
                b'\\' if !st.raw_string => i += 2, // skip the escaped char
                b'"' => {
                    st.in_string = false;
                    st.raw_string = false;
                    out[i] = b'"';
                    i += 1;
                }
                _ => i += 1,
            }
            continue;
        }
        match bytes[i] {
            b'/' if i + 1 < bytes.len() && bytes[i + 1] == b'/' => break, // line comment
            b'/' if i + 1 < bytes.len() && bytes[i + 1] == b'*' => {
                st.in_block_comment = true;
                i += 2;
            }
            b'"' => {
                st.in_string = true;
                st.raw_string = i > 0 && bytes[i - 1] == b'r';
                out[i] = b'"';
                i += 1;
            }
            b'\'' => {
                // Char literal or lifetime. `'x'` / `'\n'` forms are
                // consumed; a lifetime (no closing quote nearby) passes.
                let close = if i + 2 < bytes.len() && bytes[i + 1] == b'\\' {
                    (i + 3 < bytes.len() && bytes[i + 3] == b'\'').then_some(i + 3)
                } else {
                    (i + 2 < bytes.len() && bytes[i + 2] == b'\'').then_some(i + 2)
                };
                match close {
                    Some(c) => i = c + 1,
                    None => {
                        out[i] = b'\'';
                        i += 1;
                    }
                }
            }
            c => {
                out[i] = c;
                i += 1;
            }
        }
    }
    String::from_utf8(out).expect("blanked ASCII stays valid UTF-8")
}

/// Pre-process a file: strip every line and mark `#[cfg(test)]` module
/// bodies (attribute, then the next `mod` item, then its brace extent).
fn preprocess(text: &str) -> Vec<Line> {
    let mut st = LexState::default();
    let mut lines: Vec<Line> = text
        .lines()
        .map(|raw| Line { code: strip_line(raw, &mut st), raw: raw.to_string(), in_test_mod: false })
        .collect();
    let mut armed = false; // saw #[cfg(test)], waiting for the mod item
    let mut depth = 0i64; // brace depth inside the test mod (0 = outside)
    for line in lines.iter_mut() {
        let code = line.code.as_str();
        if depth > 0 {
            line.in_test_mod = true;
            depth += brace_delta(code);
            if depth <= 0 {
                depth = 0;
            }
            continue;
        }
        if code.contains("#[cfg(test)]") || code.contains("#[cfg(all(test") {
            armed = true;
            continue;
        }
        if armed && code.trim_start().starts_with("mod ") {
            armed = false;
            line.in_test_mod = true;
            depth = brace_delta(code);
            if depth <= 0 {
                // `#[cfg(test)] mod tests;` — a file-level test module;
                // nothing more to skip here.
                depth = 0;
            }
            continue;
        }
        if armed && !code.trim().is_empty() && !code.trim_start().starts_with("#[") {
            // The attribute applied to a non-mod item (e.g. a cfg'd fn);
            // stop waiting rather than skip the rest of the file.
            armed = false;
        }
    }
    lines
}

fn brace_delta(code: &str) -> i64 {
    code.bytes().fold(0i64, |acc, b| match b {
        b'{' => acc + 1,
        b'}' => acc - 1,
        _ => acc,
    })
}

/// Does `code` contain `word` with non-word characters (or edges) on
/// both sides? Keeps `unsafe_op_in_unsafe_fn` from matching `unsafe`.
fn has_word(code: &str, word: &str) -> bool {
    let is_word = |b: u8| b == b'_' || b.is_ascii_alphanumeric();
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let ok_before = start == 0 || !is_word(bytes[start - 1]);
        let ok_after = end >= bytes.len() || !is_word(bytes[end]);
        if ok_before && ok_after {
            return true;
        }
        from = end;
    }
    false
}

/// Is a `det-lint: allow(<rule>)` marker on this line or just above?
fn has_marker(lines: &[Line], idx: usize, rule: &str) -> bool {
    let needle = format!("det-lint: allow({rule})");
    let lo = idx.saturating_sub(MARKER_LOOKBACK);
    lines[lo..=idx].iter().any(|l| l.raw.contains(&needle))
}

/// Is a SAFETY / `# Safety` comment near line `idx`?
fn has_safety_comment(lines: &[Line], idx: usize) -> bool {
    let lo = idx.saturating_sub(LOOKBACK);
    let hi = (idx + LOOKAHEAD).min(lines.len() - 1);
    lines[lo..=hi].iter().any(|l| {
        let raw = l.raw.to_ascii_lowercase();
        raw.contains("safety:") || raw.contains("# safety")
    })
}

fn path_matches(file: &Path, suffixes: &[&str]) -> bool {
    let p = file.to_string_lossy().replace('\\', "/");
    suffixes.iter().any(|s| p.ends_with(s))
}

fn lint_file(file: &Path, text: &str, findings: &mut Vec<Finding>) {
    let lines = preprocess(text);
    // Per-file rule exemptions (the facade and the timing owners).
    let is_sync_facade = path_matches(file, &["sync/mod.rs"]);
    // `exec` owns spawning the worker team; everyone else submits
    // batches instead of spawning (see the stage-spawn rule).
    let owns_spawn_named =
        is_sync_facade || file.to_string_lossy().replace('\\', "/").contains("/exec/");
    let owns_wallclock = path_matches(
        file,
        &[
            "coordinator/driver.rs",
            "coordinator/pipeline.rs",
            "exec/mod.rs",
            "sim/runners.rs",
            "src/main.rs",
        ],
    );
    // The kernel dispatcher and its hoisted-pointer callers (arch-gate).
    let owns_arch = {
        let p = file.to_string_lossy().replace('\\', "/");
        p.contains("/linalg/") || p.contains("/knn/")
    };
    // The distributed wire protocol owns every socket (net-gate).
    let owns_net = file.to_string_lossy().replace('\\', "/").contains("/dist/");
    for (idx, line) in lines.iter().enumerate() {
        if line.in_test_mod {
            continue;
        }
        let code = line.code.as_str();
        let lineno = idx + 1;
        let mut push = |rule: &'static str, message: String| {
            findings.push(Finding { file: file.to_path_buf(), line: lineno, rule, message });
        };
        if has_word(code, "unsafe") && !has_safety_comment(&lines, idx) {
            push(
                "safety-comment",
                "`unsafe` without a nearby `// SAFETY:` comment stating the proof obligation"
                    .to_string(),
            );
        }
        if (has_word(code, "HashMap") || has_word(code, "HashSet"))
            && !has_marker(&lines, idx, "hash-iter")
        {
            push(
                "hash-iter",
                "hash collections iterate in nondeterministic order; use BTreeMap/Vec, or mark \
                 a keyed-lookup-only use with `det-lint: allow(hash-iter)` and say why order \
                 cannot leak"
                    .to_string(),
            );
        }
        if !owns_wallclock && (has_word(code, "Instant") || has_word(code, "SystemTime")) {
            push(
                "wallclock",
                "wall-clock reads belong to the driver/pipeline/bench timing modules; \
                 algorithms must not read time"
                    .to_string(),
            );
        }
        if !is_sync_facade {
            // `thread::spawn(` but not `thread::spawn_named` — the word
            // check handles the suffix.
            if code.contains("thread::spawn") && !code.contains("thread::spawn_named") {
                push(
                    "raw-spawn",
                    "spawn threads through `crate::sync::thread::spawn_named` (the facade loom \
                     models), not `thread::spawn`"
                        .to_string(),
                );
            }
            if code.contains("std::sync::atomic") && !has_marker(&lines, idx, "raw-atomic") {
                push(
                    "raw-atomic",
                    "import atomics from `crate::sync::atomic` so loom can model them, or mark \
                     a const-init static with `det-lint: allow(raw-atomic)`"
                        .to_string(),
                );
            }
            if code.contains("std::sync::mpsc") && !has_marker(&lines, idx, "std-mpsc") {
                push(
                    "std-mpsc",
                    "std channels have no loom double; route new concurrency through the \
                     executor, or mark a never-run-under-loom endpoint with \
                     `det-lint: allow(std-mpsc)` and say why"
                        .to_string(),
                );
            }
        }
        if !owns_arch
            && (code.contains("core::arch")
                || code.contains("std::arch")
                || has_word(code, "is_x86_feature_detected"))
        {
            push(
                "arch-gate",
                "arch intrinsics and feature detection live in `linalg/` (dispatcher) and \
                 `knn/` (hoisted callers); reach SIMD through `linalg::simd::kernels()`"
                    .to_string(),
            );
        }
        if !owns_net
            && (code.contains("std::net")
                || has_word(code, "TcpListener")
                || has_word(code, "TcpStream"))
        {
            push(
                "net-gate",
                "sockets live in `dist/` only (framed, CRC-checked, lease-timed); route remote \
                 work through `dist::DistPool` / `dist::serve` instead of opening a raw socket"
                    .to_string(),
            );
        }
        if code.contains("#[target_feature") && !has_safety_comment(&lines, idx) {
            push(
                "target-feature",
                "`#[target_feature]` fn without a nearby SAFETY / `# Safety` comment saying \
                 who proves the CPU capability (normally the dispatcher's runtime detection)"
                    .to_string(),
            );
        }
        if !owns_spawn_named
            && code.contains("thread::spawn_named")
            && !has_marker(&lines, idx, "stage-spawn")
        {
            push(
                "stage-spawn",
                "dedicated stage threads bypass the shared executor; submit a prioritized \
                 batch via `Executor::submit`, or mark a surviving source/sink thread with \
                 `det-lint: allow(stage-spawn)` and say why it is not executor work"
                    .to_string(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(file: &str, text: &str) -> Vec<&'static str> {
        let mut findings = Vec::new();
        lint_file(Path::new(file), text, &mut findings);
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        assert_eq!(run("src/a.rs", "unsafe { foo() };"), vec!["safety-comment"]);
        assert!(run("src/a.rs", "// SAFETY: checked above\nunsafe { foo() };").is_empty());
        // Doc-style `# Safety` sections count too.
        assert!(run("src/a.rs", "/// # Safety\n/// caller checks p\nunsafe fn f() {}").is_empty());
        // The comment may sit just below an `unsafe fn` signature.
        assert!(run("src/a.rs", "unsafe fn f() {\n    // SAFETY: forwarded\n}").is_empty());
    }

    #[test]
    fn safety_word_boundaries() {
        // The lint attribute must not read as the `unsafe` keyword.
        assert!(run("src/lib.rs", "#![deny(unsafe_op_in_unsafe_fn)]").is_empty());
    }

    #[test]
    fn hash_collections_flagged_unless_marked() {
        assert_eq!(run("src/a.rs", "let m = std::collections::HashMap::new();"), vec!["hash-iter"]);
        assert_eq!(run("src/a.rs", "use std::collections::HashSet;"), vec!["hash-iter"]);
        assert!(run(
            "src/a.rs",
            "// keyed lookups only\n// det-lint: allow(hash-iter)\nlet m = std::collections::HashMap::new();"
        )
        .is_empty());
    }

    #[test]
    fn comments_and_strings_do_not_trip_rules() {
        assert!(run("src/a.rs", "// a HashMap would be wrong here").is_empty());
        assert!(run("src/a.rs", "/* unsafe HashSet Instant */ let x = 1;").is_empty());
        assert!(run("src/a.rs", "let m = \"an unsafe HashMap of Instant\";").is_empty());
        // Multi-line string continuation.
        assert!(run("src/a.rs", "let m = \"first half \\\n  second HashMap half\";").is_empty());
        // …and code after a closed block comment is still scanned.
        assert_eq!(run("src/a.rs", "/* ok */ let m = std::collections::HashMap::new();"), vec![
            "hash-iter"
        ]);
    }

    #[test]
    fn test_modules_are_skipped() {
        let text = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn t() { unsafe { x() } }\n}\n";
        assert!(run("src/a.rs", text).is_empty());
        // …but code after the test mod is scanned again.
        let text2 = "#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nuse std::collections::HashMap;\n";
        assert_eq!(run("src/a.rs", text2), vec!["hash-iter"]);
    }

    #[test]
    fn wallclock_only_in_timing_owners() {
        assert_eq!(run("src/tc/mod.rs", "let t = Instant::now();"), vec!["wallclock"]);
        assert!(run("src/coordinator/driver.rs", "let t = Instant::now();").is_empty());
        assert!(run("src/coordinator/pipeline.rs", "let t = Instant::now();").is_empty());
        assert!(run("src/exec/mod.rs", "let t = Instant::now();").is_empty());
        assert!(run("src/sim/runners.rs", "let t = Instant::now();").is_empty());
        assert!(run("src/main.rs", "let t = std::time::Instant::now();").is_empty());
    }

    #[test]
    fn spawn_and_atomics_confined_to_facade() {
        assert_eq!(run("src/knn/mod.rs", "std::thread::spawn(|| {});"), vec!["raw-spawn"]);
        assert!(run("src/sync/mod.rs", "std::thread::spawn(f)").is_empty());
        assert_eq!(
            run("src/knn/mod.rs", "use std::sync::atomic::AtomicUsize;"),
            vec!["raw-atomic"]
        );
        assert!(run("src/sync/mod.rs", "pub use std::sync::atomic::Ordering;").is_empty());
        assert!(run(
            "src/memtrack.rs",
            "// const-init static\n// det-lint: allow(raw-atomic)\nuse std::sync::atomic::AtomicUsize;"
        )
        .is_empty());
    }

    #[test]
    fn stage_spawn_confined_to_exec_unless_marked() {
        // A dedicated stage thread in algorithm code is a regression…
        assert_eq!(run("src/knn/mod.rs", "thread::spawn_named(name, f);"), vec!["stage-spawn"]);
        // …but the facade and the executor's own worker team are the owners…
        assert!(run("src/sync/mod.rs", "thread::spawn_named(name, f);").is_empty());
        assert!(run("src/exec/mod.rs", "thread::spawn_named(name, f);").is_empty());
        // …and a marked source/sink thread passes with its justification.
        assert!(run(
            "src/coordinator/pipeline.rs",
            "// I/O-bound producer, not executor work\n// det-lint: allow(stage-spawn)\nthread::spawn_named(name, f);"
        )
        .is_empty());
        // `spawn_named` through the facade path must not also trip raw-spawn.
        assert_eq!(
            run("src/knn/mod.rs", "crate::sync::thread::spawn_named(name, f);"),
            vec!["stage-spawn"]
        );
    }

    #[test]
    fn std_mpsc_confined_to_facade_unless_marked() {
        assert_eq!(
            run("src/knn/mod.rs", "use std::sync::mpsc::sync_channel;"),
            vec!["std-mpsc"]
        );
        assert!(run("src/sync/mod.rs", "use std::sync::mpsc::sync_channel;").is_empty());
        assert!(run(
            "src/coordinator/pipeline.rs",
            "// never executed under loom\n// det-lint: allow(std-mpsc)\nuse std::sync::mpsc::{sync_channel, Receiver};"
        )
        .is_empty());
        // Prose mentioning mpsc must not trip the rule.
        assert!(run("src/knn/mod.rs", "// std::sync::mpsc would be wrong here").is_empty());
    }

    #[test]
    fn arch_intrinsics_confined_to_kernel_modules() {
        assert_eq!(
            run("src/tc/mod.rs", "use core::arch::x86_64::_mm256_loadu_ps;"),
            vec!["arch-gate"]
        );
        assert_eq!(
            run("src/cluster/kmeans.rs", "if std::is_x86_feature_detected!(\"avx2\") {}"),
            vec!["arch-gate"]
        );
        // The dispatcher and its hoisted-pointer callers are the owners.
        assert!(run("src/linalg/simd.rs", "use core::arch::x86_64::_mm256_loadu_ps;").is_empty());
        assert!(run("src/knn/mod.rs", "if std::is_x86_feature_detected!(\"avx2\") {}").is_empty());
        // Prose and strings must not trip the gate.
        assert!(run("src/tc/mod.rs", "// core::arch intrinsics live in linalg").is_empty());
        assert!(run("src/tc/mod.rs", "let m = \"std::arch is gated\";").is_empty());
    }

    #[test]
    fn sockets_confined_to_dist_module() {
        assert_eq!(run("src/coordinator/driver.rs", "use std::net::TcpStream;"), vec!["net-gate"]);
        assert_eq!(run("src/knn/mod.rs", "let l = TcpListener::bind(addr)?;"), vec!["net-gate"]);
        // The wire-protocol module is the owner.
        assert!(run("src/dist/mod.rs", "use std::net::{TcpListener, TcpStream};").is_empty());
        // Prose and strings must not trip the gate…
        assert!(run("src/exec/mod.rs", "// a TcpStream would be wrong here").is_empty());
        assert!(run("src/exec/mod.rs", "let m = \"std::net is gated\";").is_empty());
        // …and neither must identifiers that merely contain the words.
        assert!(run("src/exec/mod.rs", "fn not_a_TcpStreamLike() {}").is_empty());
    }

    #[test]
    fn target_feature_needs_safety_comment() {
        assert_eq!(
            run("src/linalg/simd.rs", "#[target_feature(enable = \"avx2\")]\nfn f() {}"),
            vec!["target-feature"]
        );
        assert!(run(
            "src/linalg/simd.rs",
            "/// # Safety\n/// dispatcher detects avx2\n#[target_feature(enable = \"avx2\")]\nfn f() {}"
        )
        .is_empty());
        assert!(run(
            "src/linalg/simd.rs",
            "// SAFETY: only installed after detection\n#[target_feature(enable = \"avx2\")]\nfn f() {}"
        )
        .is_empty());
    }

    #[test]
    fn file_level_test_mod_declaration_does_not_swallow_the_file() {
        // `#[cfg(test)] mod foo;` (semicolon form) must not mark the
        // rest of the file as test code.
        let text = "#[cfg(all(loom, test))]\nmod loom_tests;\nuse std::collections::HashMap;\n";
        assert_eq!(run("src/a.rs", text), vec!["hash-iter"]);
    }

    #[test]
    fn char_literals_and_lifetimes_survive_the_lexer() {
        assert!(run("src/a.rs", "let c = '\"'; let s: &'static str = \"HashMap\";").is_empty());
        assert_eq!(
            run("src/a.rs", "let c = 'x'; let m = std::collections::HashMap::new();"),
            vec!["hash-iter"]
        );
    }
}
