//! PJRT runtime: load and execute the AOT artifacts from the Rust hot path.
//!
//! `make artifacts` runs `python/compile/aot.py` once at build time; this
//! module is everything the request path needs afterwards:
//!
//! * [`Engine::load`] — PJRT CPU client + `HloModuleProto::from_text_file`
//!   + compile, validated against `manifest.json`.
//! * [`Engine::knn_block`] / [`Engine::kmeans_block`] — execute one tile.
//! * [`PjrtChunks`] / [`PjrtAssign`] — adapters implementing the
//!   [`crate::knn::ChunkEvaluator`] and
//!   [`crate::cluster::kmeans::AssignBackend`] traits, with all the
//!   padding/masking to map arbitrary workloads onto the fixed tile
//!   geometry the artifacts were compiled for.
//!
//! Python never runs here; the binary is self-contained once
//! `artifacts/` exists.
//!
//! ## The `pjrt` and `pjrt-runtime` features
//!
//! The real implementation (in `pjrt.rs`) needs the `xla` bindings
//! crate, which is not available in the offline build environment. It is
//! gated behind the `pjrt-runtime` cargo feature, which requires
//! manually adding `xla` to `[dependencies]` (see `Cargo.toml`).
//!
//! The `pjrt` feature (implied by `pjrt-runtime`) gates only the PJRT
//! *surface*: the integration tests in `rust/tests/pjrt_integration.rs`
//! and any future pjrt-conditional call sites. Building with
//! `--features pjrt` alone compiles that surface against the
//! API-compatible stub — CI's feature-matrix job does exactly this so
//! the stub and its callers cannot rot silently — while [`Engine::load`]
//! still returns [`crate::Error::Runtime`], so the driver, benches, and
//! `ihtc check-artifacts` degrade gracefully to the native pooled path.

#[cfg(feature = "pjrt-runtime")]
mod pjrt;
#[cfg(feature = "pjrt-runtime")]
pub use pjrt::{Engine, PjrtAssign, PjrtChunks};

#[cfg(not(feature = "pjrt-runtime"))]
mod stub;
#[cfg(not(feature = "pjrt-runtime"))]
pub use stub::{Engine, PjrtAssign, PjrtChunks};

/// Tile geometry the artifacts were compiled for (mirrors `aot.py`).
#[derive(Clone, Copy, Debug)]
pub struct TileGeometry {
    /// Query rows per knn_chunk call.
    pub knn_q: usize,
    /// Reference rows per knn_chunk call.
    pub knn_r: usize,
    /// Neighbor slots per query.
    pub knn_k: usize,
    /// Point rows per kmeans_assign call.
    pub km_n: usize,
    /// Center slots.
    pub km_k: usize,
    /// Feature dimension (datasets padded up to this).
    pub dim: usize,
}

/// Sentinel distance emitted by the artifacts for masked candidates
/// (mirrors `model.MASK_BIG`).
pub const MASK_BIG: f32 = 1e30;

#[cfg(test)]
mod tests {
    // The PJRT engine needs built artifacts; integration tests live in
    // rust/tests/pjrt_integration.rs and skip gracefully when
    // artifacts/manifest.json is absent. Unit tests here cover the load
    // failure path, which both the real and the stub implementation hit.
    use super::*;

    #[test]
    fn load_missing_dir_errors_helpfully() {
        let err = match Engine::load("/nonexistent/ihtc-artifacts") {
            Err(e) => e,
            Ok(_) => panic!("load should fail on a missing directory"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
