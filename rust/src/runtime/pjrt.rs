//! Real PJRT runtime implementation (requires the `xla` bindings crate;
//! compiled only with `--features pjrt-runtime` after adding `xla` to
//! `[dependencies]`). See the parent module docs.

use super::{TileGeometry, MASK_BIG};
use crate::cluster::kmeans::AssignBackend;
use crate::config::json::Json;
use crate::knn::{ChunkEvaluator, TopK};
use crate::linalg::Matrix;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// A loaded PJRT engine holding the compiled executables.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    /// knn_chunk variants `(neighbor_slots, executable)`, ascending by
    /// slot count — the top-k rounds cost a full pass over the distance
    /// block each, so small-`t*` workloads use a small variant.
    knn_exes: Vec<(usize, xla::PjRtLoadedExecutable)>,
    km_exe: xla::PjRtLoadedExecutable,
    /// Tile geometry from the manifest (`knn_k` = the largest variant).
    pub tile: TileGeometry,
    /// Where the artifacts came from.
    pub dir: PathBuf,
}

impl Engine {
    /// Default artifact directory: `$IHTC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("IHTC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Load + compile all artifacts listed in `manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = Json::parse(&text)?;
        let tile_j = manifest
            .get("tile")
            .ok_or_else(|| Error::Runtime("manifest missing 'tile'".into()))?;
        let tile = TileGeometry {
            knn_q: tile_j.req_usize("knn_q")?,
            knn_r: tile_j.req_usize("knn_r")?,
            knn_k: tile_j.req_usize("knn_k")?,
            km_n: tile_j.req_usize("km_n")?,
            km_k: tile_j.req_usize("km_k")?,
            dim: tile_j.req_usize("dim")?,
        };
        let client = xla::PjRtClient::cpu()?;
        let mut knn_exes = Vec::new();
        let mut km_exe = None;
        for art in manifest
            .get("artifacts")
            .and_then(Json::as_array)
            .ok_or_else(|| Error::Runtime("manifest missing 'artifacts'".into()))?
        {
            let name = art.req_str("name")?;
            let file = dir.join(art.req_str("file")?);
            let proto = xla::HloModuleProto::from_text_file(
                file.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            if name.starts_with("knn_chunk") {
                // Neighbor-slot count from the first output's shape [Q, k].
                let slots = art
                    .get("outputs")
                    .and_then(Json::as_array)
                    .and_then(|o| o.first())
                    .and_then(|o| o.get("shape"))
                    .and_then(Json::as_array)
                    .and_then(|s| s.get(1))
                    .and_then(Json::as_usize)
                    .ok_or_else(|| Error::Runtime(format!("artifact '{name}' lacks output shape")))?;
                knn_exes.push((slots, exe));
            } else if name.starts_with("kmeans_assign") {
                km_exe = Some(exe);
            } else {
                eprintln!("warning: unknown artifact '{name}' ignored");
            }
        }
        knn_exes.sort_by_key(|&(k, _)| k);
        if knn_exes.is_empty() {
            return Err(Error::Runtime("manifest lacks knn_chunk".into()));
        }
        Ok(Engine {
            client,
            knn_exes,
            km_exe: km_exe
                .ok_or_else(|| Error::Runtime("manifest lacks kmeans_assign".into()))?,
            tile,
            dir,
        })
    }

    /// Smallest knn variant with ≥ `k` neighbor slots (or the largest).
    fn knn_variant(&self, k: usize) -> (usize, &xla::PjRtLoadedExecutable) {
        for (slots, exe) in &self.knn_exes {
            if *slots >= k {
                return (*slots, exe);
            }
        }
        let (slots, exe) = self.knn_exes.last().expect("nonempty");
        (*slots, exe)
    }

    /// Execute one knn tile using the smallest artifact variant with at
    /// least `k` neighbor slots. Buffer lengths must match the tile
    /// geometry exactly (`knn_q × dim`, `knn_r × dim`, `knn_q`, `knn_r`).
    ///
    /// Returns `(slots, dists, ids)` where `dists`/`ids` have shape
    /// `knn_q × slots` (row-major); `ids[i] == -1` marks an invalid slot
    /// (masked / padding).
    pub fn knn_block(
        &self,
        k: usize,
        q: &[f32],
        r: &[f32],
        q_ids: &[i32],
        r_ids: &[i32],
    ) -> Result<(usize, Vec<f32>, Vec<i32>)> {
        let t = &self.tile;
        if q.len() != t.knn_q * t.dim
            || r.len() != t.knn_r * t.dim
            || q_ids.len() != t.knn_q
            || r_ids.len() != t.knn_r
        {
            return Err(Error::Shape("knn_block buffer sizes vs tile geometry".into()));
        }
        let (slots, exe) = self.knn_variant(k);
        let ql = xla::Literal::vec1(q).reshape(&[t.knn_q as i64, t.dim as i64])?;
        let rl = xla::Literal::vec1(r).reshape(&[t.knn_r as i64, t.dim as i64])?;
        let qi = xla::Literal::vec1(q_ids);
        let ri = xla::Literal::vec1(r_ids);
        let result = exe.execute::<xla::Literal>(&[ql, rl, qi, ri])?[0][0].to_literal_sync()?;
        let (dists, ids) = result.to_tuple2()?;
        Ok((slots, dists.to_vec::<f32>()?, ids.to_vec::<i32>()?))
    }

    /// Execute one kmeans_assign tile. Returns
    /// `(assign[km_n], sums[km_k×dim], counts[km_k], wcss)`.
    pub fn kmeans_block(
        &self,
        x: &[f32],
        centers: &[f32],
        center_mask: &[f32],
        point_mask: &[f32],
    ) -> Result<(Vec<i32>, Vec<f32>, Vec<f32>, f32)> {
        let t = &self.tile;
        if x.len() != t.km_n * t.dim
            || centers.len() != t.km_k * t.dim
            || center_mask.len() != t.km_k
            || point_mask.len() != t.km_n
        {
            return Err(Error::Shape("kmeans_block buffer sizes vs tile geometry".into()));
        }
        let xl = xla::Literal::vec1(x).reshape(&[t.km_n as i64, t.dim as i64])?;
        let cl = xla::Literal::vec1(centers).reshape(&[t.km_k as i64, t.dim as i64])?;
        let cm = xla::Literal::vec1(center_mask);
        let pm = xla::Literal::vec1(point_mask);
        let result = self.km_exe.execute::<xla::Literal>(&[xl, cl, cm, pm])?[0][0]
            .to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        if parts.len() != 4 {
            return Err(Error::Runtime(format!(
                "kmeans artifact returned {}-tuple, expected 4",
                parts.len()
            )));
        }
        let wcss = parts.pop().unwrap().get_first_element::<f32>()?;
        let counts = parts.pop().unwrap().to_vec::<f32>()?;
        let sums = parts.pop().unwrap().to_vec::<f32>()?;
        let assign = parts.pop().unwrap().to_vec::<i32>()?;
        Ok((assign, sums, counts, wcss))
    }

    /// Pad a row block `[start, start+n)` of `points` into a
    /// `rows × tile.dim` buffer (zero-padded in both directions).
    fn pack_rows(&self, points: &Matrix, start: usize, n: usize, rows: usize) -> Vec<f32> {
        let d = points.cols().min(self.tile.dim);
        let mut out = vec![0.0f32; rows * self.tile.dim];
        for i in 0..n {
            let src = points.row(start + i);
            out[i * self.tile.dim..i * self.tile.dim + d].copy_from_slice(&src[..d]);
        }
        out
    }
}

/// [`ChunkEvaluator`] that routes pairwise/top-k blocks through the AOT
/// knn artifact. Use with [`crate::knn::knn_chunked`] and block sizes
/// equal to the tile geometry.
pub struct PjrtChunks<'a> {
    /// The loaded engine.
    pub engine: &'a Engine,
}

impl ChunkEvaluator for PjrtChunks<'_> {
    fn eval_block(
        &self,
        points: &Matrix,
        q0: usize,
        nq: usize,
        r0: usize,
        nr: usize,
        tops: &mut [TopK],
    ) -> Result<()> {
        let t = &self.engine.tile;
        if points.cols() > t.dim {
            return Err(Error::Shape(format!(
                "dataset dim {} exceeds artifact dim {} (re-run aot.py with a larger DIM)",
                points.cols(),
                t.dim
            )));
        }
        if nq > t.knn_q || nr > t.knn_r {
            return Err(Error::Shape("block larger than tile geometry".into()));
        }
        let q = self.engine.pack_rows(points, q0, nq, t.knn_q);
        let r = self.engine.pack_rows(points, r0, nr, t.knn_r);
        let mut q_ids = vec![-1i32; t.knn_q];
        for (i, slot) in q_ids.iter_mut().take(nq).enumerate() {
            *slot = (q0 + i) as i32;
        }
        let mut r_ids = vec![-1i32; t.knn_r];
        for (j, slot) in r_ids.iter_mut().take(nr).enumerate() {
            *slot = (r0 + j) as i32;
        }
        let k_needed = tops.first().map(|t| t.capacity()).unwrap_or(1);
        let (slots, dists, ids) = self.engine.knn_block(k_needed, &q, &r, &q_ids, &r_ids)?;
        for (qi, top) in tops.iter_mut().enumerate().take(nq) {
            let row_d = &dists[qi * slots..(qi + 1) * slots];
            let row_i = &ids[qi * slots..(qi + 1) * slots];
            for (&d, &id) in row_d.iter().zip(row_i) {
                if id >= 0 && d < MASK_BIG / 2.0 {
                    top.push(d, id as u32);
                }
            }
        }
        Ok(())
    }
}

/// [`AssignBackend`] that routes Lloyd assignment blocks through the AOT
/// kmeans artifact.
pub struct PjrtAssign<'a> {
    /// The loaded engine.
    pub engine: &'a Engine,
}

impl AssignBackend for PjrtAssign<'_> {
    fn assign_block(
        &self,
        points: &Matrix,
        weights: Option<&[f32]>,
        p0: usize,
        np: usize,
        centers: &Matrix,
        assign_out: &mut [u32],
        sums: &mut [f64],
        counts: &mut [f64],
    ) -> Result<f64> {
        let t = &self.engine.tile;
        let d = points.cols();
        if d > t.dim {
            return Err(Error::Shape(format!("dim {d} exceeds artifact dim {}", t.dim)));
        }
        let k = centers.rows();
        if k > t.km_k {
            return Err(Error::Shape(format!("k={k} exceeds artifact centers {}", t.km_k)));
        }
        if weights.is_some() {
            // The artifact computes unweighted sums; the weighted path
            // (prototype masses) stays native. The paper's IHTC runs
            // unweighted k-means, so this is not on the repro path.
            return Err(Error::Runtime(
                "PJRT kmeans artifact does not support per-point weights; use NativeAssign"
                    .into(),
            ));
        }
        let mut wcss_total = 0.0f64;
        let centers_buf = self.engine.pack_rows(centers, 0, k, t.km_k);
        let mut cmask = vec![0.0f32; t.km_k];
        for slot in cmask.iter_mut().take(k) {
            *slot = 1.0;
        }
        let mut off = 0usize;
        while off < np {
            let n = (np - off).min(t.km_n);
            let x = self.engine.pack_rows(points, p0 + off, n, t.km_n);
            let mut pmask = vec![0.0f32; t.km_n];
            for slot in pmask.iter_mut().take(n) {
                *slot = 1.0;
            }
            let (assign, bsums, bcounts, wcss) =
                self.engine.kmeans_block(&x, &centers_buf, &cmask, &pmask)?;
            for i in 0..n {
                assign_out[off + i] = assign[i] as u32;
            }
            for c in 0..k {
                counts[c] += bcounts[c] as f64;
                for j in 0..d {
                    sums[c * d + j] += bsums[c * t.dim + j] as f64;
                }
            }
            wcss_total += wcss as f64;
            off += n;
        }
        Ok(wcss_total)
    }
}
