//! API-compatible stand-in for the PJRT runtime, compiled whenever the
//! `pjrt-runtime` feature is off (the default — the `xla` bindings crate
//! is not available in the offline build environment). The plain `pjrt`
//! feature compiles the PJRT-gated surface against this stub, which is
//! what CI's feature-matrix job builds.
//!
//! Every entry point exists with the real signature so callers compile
//! unchanged; [`Engine::load`] fails with [`crate::Error::Runtime`] and
//! the adapters refuse to evaluate, which routes the driver, benches, and
//! `ihtc check-artifacts` onto the native pooled path.

use super::TileGeometry;
use crate::cluster::kmeans::AssignBackend;
use crate::knn::{ChunkEvaluator, TopK};
use crate::linalg::Matrix;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

fn unavailable() -> Error {
    Error::Runtime(
        "PJRT support is compiled out (run `make artifacts`, add the `xla` \
         dependency, and rebuild with `--features pjrt`)"
            .into(),
    )
}

/// Stub engine: holds the tile geometry shape but can never be loaded.
pub struct Engine {
    /// Tile geometry (never populated in the stub).
    pub tile: TileGeometry,
    /// Where the artifacts would have come from.
    pub dir: PathBuf,
}

impl Engine {
    /// Default artifact directory: `$IHTC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("IHTC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Always fails: the `pjrt` feature is off.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let _ = dir.as_ref();
        Err(unavailable())
    }

    /// Always fails: the `pjrt` feature is off.
    pub fn knn_block(
        &self,
        _k: usize,
        _q: &[f32],
        _r: &[f32],
        _q_ids: &[i32],
        _r_ids: &[i32],
    ) -> Result<(usize, Vec<f32>, Vec<i32>)> {
        Err(unavailable())
    }

    /// Always fails: the `pjrt` feature is off.
    pub fn kmeans_block(
        &self,
        _x: &[f32],
        _centers: &[f32],
        _center_mask: &[f32],
        _point_mask: &[f32],
    ) -> Result<(Vec<i32>, Vec<f32>, Vec<f32>, f32)> {
        Err(unavailable())
    }
}

/// Stub [`ChunkEvaluator`]; always errors.
pub struct PjrtChunks<'a> {
    /// The (never-loadable) engine.
    pub engine: &'a Engine,
}

impl ChunkEvaluator for PjrtChunks<'_> {
    fn eval_block(
        &self,
        _points: &Matrix,
        _q0: usize,
        _nq: usize,
        _r0: usize,
        _nr: usize,
        _tops: &mut [TopK],
    ) -> Result<()> {
        Err(unavailable())
    }
}

/// Stub [`AssignBackend`]; always errors.
pub struct PjrtAssign<'a> {
    /// The (never-loadable) engine.
    pub engine: &'a Engine,
}

impl AssignBackend for PjrtAssign<'_> {
    fn assign_block(
        &self,
        _points: &Matrix,
        _weights: Option<&[f32]>,
        _p0: usize,
        _np: usize,
        _centers: &Matrix,
        _assign_out: &mut [u32],
        _sums: &mut [f64],
        _counts: &mut [f64],
    ) -> Result<f64> {
        Err(unavailable())
    }
}
