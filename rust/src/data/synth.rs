//! Synthetic workload generators.
//!
//! Two families:
//!
//! 1. [`gaussian_mixture_paper`] — the *exact* simulation model of §4:
//!    a three-component bivariate Gaussian mixture with weights
//!    (0.5, 0.3, 0.2), means (1,2), (7,8), (3,5) and diagonal covariances
//!    diag(1, 0.5), diag(2, 1), diag(3, 4).
//! 2. [`realistic`] — deterministic analogues of the paper's six real
//!    datasets (Table 3). The originals are Kaggle/UCI downloads we cannot
//!    fetch offline; the analogues match n, post-PCA dimensionality, and
//!    class count, and mix anisotropic/correlated clusters with heavy-tail
//!    noise so the BSS/TSS and runtime/memory *shapes* of Tables 4–6 and 9
//!    are exercised by the same code paths. The substitution is documented
//!    in DESIGN.md §4.

use super::Dataset;
use crate::linalg::Matrix;
use crate::rng::Xoshiro256;

/// One Gaussian mixture component with a diagonal-plus-correlation
/// covariance, optional log-normal skew per axis.
#[derive(Clone, Debug)]
pub struct Component {
    /// Mixture weight (normalized internally).
    pub weight: f64,
    /// Mean vector.
    pub mean: Vec<f64>,
    /// Per-axis standard deviation.
    pub std: Vec<f64>,
    /// Pairwise correlation applied between consecutive axes (0 = none).
    pub corr: f64,
    /// When true, exponentiate axis 0 (log-normal-style skew).
    pub skew: bool,
}

/// A full mixture specification.
#[derive(Clone, Debug)]
pub struct MixtureSpec {
    /// Dataset name used in reports.
    pub name: String,
    /// Mixture components; one class label per component.
    pub components: Vec<Component>,
    /// Fraction of points replaced by uniform background noise
    /// (labelled by their nearest component).
    pub noise_frac: f64,
}

impl MixtureSpec {
    /// Sample `n` points deterministically from `seed`.
    pub fn sample(&self, n: usize, seed: u64) -> Dataset {
        let mut sampler = MixtureSampler::new(self, seed);
        let (points, labels) = sampler.next_shard(n);
        Dataset::new(&self.name, points, Some(labels), self.components.len())
            .expect("synthetic dataset")
    }
}

/// Incremental sampler over a [`MixtureSpec`]: successive
/// [`MixtureSampler::next_shard`] calls draw from one RNG stream, so the
/// concatenation of any shard sequence is byte-identical to a single
/// [`MixtureSpec::sample`] call of the same total size. This is what
/// lets the streaming ingest generate synthetic sources shard-by-shard
/// without changing the data the materialized path sees.
pub struct MixtureSampler {
    components: Vec<Component>,
    noise_frac: f64,
    cuts: Vec<f64>,
    lo: Vec<f64>,
    hi: Vec<f64>,
    d: usize,
    rng: Xoshiro256,
}

impl MixtureSampler {
    /// Prepare a sampler for `spec`, seeding the point RNG with `seed`.
    pub fn new(spec: &MixtureSpec, seed: u64) -> Self {
        let d = spec.components[0].mean.len();
        for c in &spec.components {
            assert_eq!(c.mean.len(), d, "component dims must agree");
            assert_eq!(c.std.len(), d, "component dims must agree");
        }
        let total_w: f64 = spec.components.iter().map(|c| c.weight).sum();
        let mut cum = 0.0;
        let cuts: Vec<f64> = spec
            .components
            .iter()
            .map(|c| {
                cum += c.weight / total_w;
                cum
            })
            .collect();
        // Bounding box for background noise: mean ± 4σ across components.
        let mut lo = vec![f64::INFINITY; d];
        let mut hi = vec![f64::NEG_INFINITY; d];
        for c in &spec.components {
            for j in 0..d {
                lo[j] = lo[j].min(c.mean[j] - 4.0 * c.std[j]);
                hi[j] = hi[j].max(c.mean[j] + 4.0 * c.std[j]);
            }
        }
        Self {
            components: spec.components.clone(),
            noise_frac: spec.noise_frac,
            cuts,
            lo,
            hi,
            d,
            rng: Xoshiro256::seed_from_u64(seed),
        }
    }

    /// Dimensionality of the sampled points.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Advance the sampler past `rows` points without keeping them — the
    /// checkpoint-resume fast path for synthetic sources. Implemented by
    /// drawing and discarding in bounded chunks: per-row RNG consumption
    /// is data-dependent (the noise branch draws uniforms, the Gaussian
    /// branch draws normals, and `next_gaussian` itself rejects
    /// internally), so replaying the exact draw sequence is the only way
    /// to land on the same stream state as an uninterrupted run —
    /// anything cheaper would silently fork the RNG stream and break the
    /// resumed-run byte-parity guarantee.
    pub fn seek(&mut self, rows: usize) {
        let mut left = rows;
        while left > 0 {
            let take = left.min(4096);
            let _ = self.next_shard(take);
            left -= take;
        }
    }

    /// Draw the next `rows` points; labels are parallel to the rows.
    pub fn next_shard(&mut self, rows: usize) -> (Matrix, Vec<u32>) {
        let d = self.d;
        let mut data = Vec::with_capacity(rows * d);
        let mut labels = Vec::with_capacity(rows);
        for _ in 0..rows {
            let u = self.rng.next_f64();
            let comp_idx =
                self.cuts.iter().position(|&c| u <= c).unwrap_or(self.components.len() - 1);
            let comp = &self.components[comp_idx];
            labels.push(comp_idx as u32);
            if self.noise_frac > 0.0 && self.rng.next_f64() < self.noise_frac {
                for j in 0..d {
                    data.push((self.lo[j] + (self.hi[j] - self.lo[j]) * self.rng.next_f64()) as f32);
                }
                continue;
            }
            let mut prev = 0.0f64;
            for j in 0..d {
                let mut g = self.rng.next_gaussian();
                if comp.corr != 0.0 && j > 0 {
                    g = comp.corr * prev + (1.0 - comp.corr * comp.corr).sqrt() * g;
                }
                prev = g;
                let mut v = comp.mean[j] + comp.std[j] * g;
                if comp.skew && j == 0 {
                    // Log-normal-ish positive skew around the mean.
                    v = comp.mean[j] + comp.std[j] * (g.exp() - 1.0);
                }
                data.push(v as f32);
            }
        }
        (Matrix::from_vec(data, rows, d).expect("sample buffer"), labels)
    }
}

/// The §4 simulation model, verbatim:
/// `f(x) = 0.5·N(μ₁,Σ₁) + 0.3·N(μ₂,Σ₂) + 0.2·N(μ₃,Σ₃)` with
/// μ₁=(1,2), μ₂=(7,8), μ₃=(3,5); Σ₁=diag(1,.5), Σ₂=diag(2,1), Σ₃=diag(3,4).
pub fn paper_mixture_spec() -> MixtureSpec {
    MixtureSpec {
        name: "gmm3-paper".into(),
        components: vec![
            Component {
                weight: 0.5,
                mean: vec![1.0, 2.0],
                std: vec![1.0, 0.5f64.sqrt()],
                corr: 0.0,
                skew: false,
            },
            Component {
                weight: 0.3,
                mean: vec![7.0, 8.0],
                std: vec![2.0f64.sqrt(), 1.0],
                corr: 0.0,
                skew: false,
            },
            Component {
                weight: 0.2,
                mean: vec![3.0, 5.0],
                std: vec![3.0f64.sqrt(), 2.0],
                corr: 0.0,
                skew: false,
            },
        ],
        noise_frac: 0.0,
    }
}

/// Sample `n` points from the paper's simulation mixture (§4).
pub fn gaussian_mixture_paper(n: usize, seed: u64) -> Dataset {
    paper_mixture_spec().sample(n, seed)
}

/// Descriptor of a real dataset from Table 3 with its synthetic analogue.
#[derive(Clone, Debug)]
pub struct RealDatasetSpec {
    /// Paper's dataset name.
    pub name: &'static str,
    /// Paper's instance count.
    pub instances: usize,
    /// Paper's attribute count.
    pub attributes: usize,
    /// Paper's class count (elbow-selected `k`).
    pub classes: usize,
}

/// Table 3 of the paper.
pub const TABLE3: &[RealDatasetSpec] = &[
    RealDatasetSpec { name: "PM 2.5", instances: 41_757, attributes: 5, classes: 4 },
    RealDatasetSpec { name: "Credit Score", instances: 120_269, attributes: 6, classes: 5 },
    RealDatasetSpec { name: "Black Friday", instances: 166_986, attributes: 7, classes: 4 },
    RealDatasetSpec { name: "Covertype", instances: 581_012, attributes: 6, classes: 7 },
    RealDatasetSpec { name: "House Price", instances: 2_885_485, attributes: 5, classes: 5 },
    RealDatasetSpec { name: "Stock", instances: 7_026_593, attributes: 5, classes: 7 },
];

/// Build the synthetic analogue of Table 3 dataset `spec`, scaled to
/// `n = instances / scale_div` points (scale_div=1 reproduces the paper's
/// size; larger divisors keep experiments within this testbed's budget).
pub fn realistic(spec: &RealDatasetSpec, scale_div: usize, seed: u64) -> Dataset {
    let (spec_m, n) = realistic_spec(spec, scale_div, seed);
    spec_m.sample(n, seed)
}

/// The deterministic analogue mixture behind [`realistic`], plus its row
/// count — split out so the streaming ingest can drive a
/// [`MixtureSampler`] over it shard-by-shard instead of materializing
/// the dataset. `realistic(spec, s, seed)` ≡ sampling the returned spec
/// for the returned `n` rows with the same seed.
pub fn realistic_spec(spec: &RealDatasetSpec, scale_div: usize, seed: u64) -> (MixtureSpec, usize) {
    let n = (spec.instances / scale_div.max(1)).max(spec.classes * 50);
    let d = spec.attributes;
    let k = spec.classes;
    // Deterministic per-dataset geometry: place k anisotropic components
    // on a low-discrepancy lattice in d dimensions, with skew/correlation
    // patterns cycling so datasets are structurally diverse.
    let mut geom = Xoshiro256::seed_from_u64(seed ^ 0xD1CE_5EED);
    let mut components = Vec::with_capacity(k);
    for c in 0..k {
        let mut mean = Vec::with_capacity(d);
        let mut std = Vec::with_capacity(d);
        for j in 0..d {
            // Golden-ratio lattice keeps components separated but not grid-like.
            let phi = 0.618_033_988_75_f64;
            let pos = ((c as f64 + 1.0) * phi * (j as f64 + 1.3)).fract();
            mean.push(pos * 10.0 * (1.0 + 0.15 * geom.next_gaussian()));
            std.push(0.4 + 1.4 * geom.next_f64());
        }
        components.push(Component {
            weight: 1.0 + geom.next_f64() * 2.0, // imbalanced classes
            mean,
            std,
            corr: if c % 3 == 1 { 0.6 } else { 0.0 },
            skew: c % 4 == 2,
        });
    }
    let spec_m = MixtureSpec {
        name: format!("{}-analogue", spec.name),
        components,
        noise_frac: 0.02,
    };
    (spec_m, n)
}

/// Look up a Table 3 spec by (case-insensitive, prefix) name.
pub fn find_spec(name: &str) -> Option<&'static RealDatasetSpec> {
    let lname = name.to_lowercase().replace([' ', '_', '-'], "");
    TABLE3.iter().find(|s| {
        s.name.to_lowercase().replace([' ', '_', '-'], "").starts_with(&lname)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_mixture_shapes_and_weights() {
        let ds = gaussian_mixture_paper(30_000, 1);
        assert_eq!(ds.len(), 30_000);
        assert_eq!(ds.dim(), 2);
        let labels = ds.labels.as_ref().unwrap();
        let mut counts = [0usize; 3];
        for &l in labels {
            counts[l as usize] += 1;
        }
        let f0 = counts[0] as f64 / 30_000.0;
        let f1 = counts[1] as f64 / 30_000.0;
        let f2 = counts[2] as f64 / 30_000.0;
        assert!((f0 - 0.5).abs() < 0.02, "{f0}");
        assert!((f1 - 0.3).abs() < 0.02, "{f1}");
        assert!((f2 - 0.2).abs() < 0.02, "{f2}");
    }

    #[test]
    fn paper_mixture_component_moments() {
        let ds = gaussian_mixture_paper(60_000, 2);
        let labels = ds.labels.as_ref().unwrap();
        // Component 1 (weight .3): mean (7,8), var (2,1).
        let idx: Vec<usize> =
            (0..ds.len()).filter(|&i| labels[i] == 1).collect();
        let sub = ds.points.select_rows(&idx);
        let means = sub.col_means();
        assert!((means[0] - 7.0).abs() < 0.05, "{means:?}");
        assert!((means[1] - 8.0).abs() < 0.05, "{means:?}");
        let stds = sub.col_stds();
        assert!((stds[0] - 2.0f64.sqrt()).abs() < 0.05, "{stds:?}");
        assert!((stds[1] - 1.0).abs() < 0.05, "{stds:?}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = gaussian_mixture_paper(100, 7);
        let b = gaussian_mixture_paper(100, 7);
        let c = gaussian_mixture_paper(100, 8);
        assert_eq!(a.points.data(), b.points.data());
        assert_ne!(a.points.data(), c.points.data());
    }

    #[test]
    fn realistic_analogues_match_table3_shape() {
        for spec in TABLE3 {
            let ds = realistic(spec, 100, 5);
            assert_eq!(ds.dim(), spec.attributes, "{}", spec.name);
            assert_eq!(ds.k_hint, spec.classes, "{}", spec.name);
            assert!(ds.len() >= spec.classes * 50);
            let labels = ds.labels.as_ref().unwrap();
            let distinct: std::collections::HashSet<_> = labels.iter().collect();
            assert_eq!(distinct.len(), spec.classes, "{}", spec.name);
        }
    }

    #[test]
    fn sampler_shards_match_one_shot() {
        // Concatenated shards from one sampler must be byte-identical to
        // a single sample() of the total size — including across the
        // noise branch (realistic analogues) and skew/correlation paths.
        let (analogue, _) = realistic_spec(&TABLE3[0], 100, 11);
        for spec in [paper_mixture_spec(), analogue] {
            let whole = spec.sample(1000, 42);
            let mut sampler = MixtureSampler::new(&spec, 42);
            let mut data: Vec<f32> = Vec::new();
            let mut labels: Vec<u32> = Vec::new();
            for rows in [1usize, 127, 128, 500, 244] {
                let (m, l) = sampler.next_shard(rows);
                assert_eq!(m.rows(), rows);
                data.extend_from_slice(m.data());
                labels.extend(l);
            }
            assert_eq!(&data, whole.points.data(), "{}", spec.name);
            assert_eq!(Some(labels), whole.labels);
        }
    }

    #[test]
    fn seek_matches_full_stream_tail() {
        // seek(k) + next_shard(n−k) must be byte-identical to the tail
        // of a single n-row draw — for the paper mixture and for a noisy
        // analogue (whose per-row RNG consumption is data-dependent),
        // at boundary and mid-shard seek points including one past the
        // internal 4096-row discard chunk.
        let (analogue, _) = realistic_spec(&TABLE3[1], 100, 13);
        for spec in [paper_mixture_spec(), analogue] {
            let whole = spec.sample(6000, 21);
            for start in [0usize, 500, 4097, 5999] {
                let mut sampler = MixtureSampler::new(&spec, 21);
                sampler.seek(start);
                let (m, l) = sampler.next_shard(6000 - start);
                assert_eq!(m.data(), &whole.points.data()[start * spec.components[0].mean.len()..],
                    "{} start={start}", spec.name);
                assert_eq!(&l, &whole.labels.as_ref().unwrap()[start..], "{} start={start}",
                    spec.name);
            }
        }
    }

    #[test]
    fn realistic_spec_matches_realistic() {
        let spec = find_spec("covertype").unwrap();
        let whole = realistic(spec, 200, 9);
        let (mix, n) = realistic_spec(spec, 200, 9);
        assert_eq!(n, whole.len());
        let again = mix.sample(n, 9);
        assert_eq!(again.points.data(), whole.points.data());
        assert_eq!(again.labels, whole.labels);
    }

    #[test]
    fn find_spec_matches() {
        assert_eq!(find_spec("covertype").unwrap().instances, 581_012);
        assert!(find_spec("pm2.5").is_some());
        assert!(find_spec("pm 2.5").is_some());
        assert!(find_spec("stock").is_some());
        assert!(find_spec("nope").is_none());
    }
}
