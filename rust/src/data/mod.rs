//! Dataset containers, CSV I/O, and preprocessing.
//!
//! The paper's pipeline (§5): load → PCA feature selection → standardized
//! Euclidean dissimilarity → cluster. This module owns the first two steps
//! plus the synthetic workload generators used by the simulation study.

pub mod csv;
pub mod synth;

use crate::linalg::{pca::Pca, standardize, Matrix};
use crate::{Error, Result};

/// A dataset: `n × d` covariates plus optional ground-truth labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human-readable name (used in reports).
    pub name: String,
    /// Covariate matrix, one row per unit.
    pub points: Matrix,
    /// Ground-truth class labels, when known (simulations; labeled data).
    pub labels: Option<Vec<u32>>,
    /// Suggested number of clusters `k` (paper's Table 3 "Classes").
    pub k_hint: usize,
}

impl Dataset {
    /// Build a dataset from parts.
    pub fn new(name: impl Into<String>, points: Matrix, labels: Option<Vec<u32>>, k_hint: usize) -> Result<Self> {
        if let Some(l) = &labels {
            if l.len() != points.rows() {
                return Err(Error::Data(format!(
                    "{} labels for {} rows",
                    l.len(),
                    points.rows()
                )));
            }
        }
        Ok(Self { name: name.into(), points, labels, k_hint })
    }

    /// Number of units.
    pub fn len(&self) -> usize {
        self.points.rows()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.points.rows() == 0
    }

    /// Dimensionality.
    pub fn dim(&self) -> usize {
        self.points.cols()
    }
}

/// Preprocessing options applied before clustering (paper §5 defaults:
/// PCA feature selection + Euclidean distance on standardized columns).
#[derive(Clone, Debug)]
pub struct Preprocess {
    /// Standardize columns to zero mean / unit variance.
    pub standardize: bool,
    /// Keep the smallest number of principal components explaining at
    /// least this fraction of variance (`None` = no PCA).
    pub pca_variance: Option<f64>,
    /// Hard cap on the number of components kept.
    pub max_components: Option<usize>,
}

impl Default for Preprocess {
    fn default() -> Self {
        Self { standardize: true, pca_variance: None, max_components: None }
    }
}

impl Preprocess {
    /// Apply to a dataset, returning the transformed copy.
    pub fn apply(&self, ds: &Dataset) -> Result<Dataset> {
        let mut points = ds.points.clone();
        if self.standardize {
            standardize(&mut points);
        }
        if let Some(frac) = self.pca_variance {
            let pca = Pca::fit(&points)?;
            let mut k = pca.components_for_variance(frac);
            if let Some(cap) = self.max_components {
                k = k.min(cap);
            }
            points = pca.transform(&points, k)?;
        }
        Dataset::new(ds.name.clone(), points, ds.labels.clone(), ds.k_hint)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_length_checked() {
        let m = Matrix::zeros(4, 2);
        assert!(Dataset::new("x", m.clone(), Some(vec![0, 1]), 2).is_err());
        assert!(Dataset::new("x", m, Some(vec![0, 1, 0, 1]), 2).is_ok());
    }

    #[test]
    fn preprocess_standardizes() {
        let m = Matrix::from_vec(vec![0.0, 100.0, 1.0, 200.0, 2.0, 300.0, 3.0, 400.0], 4, 2).unwrap();
        let ds = Dataset::new("t", m, None, 2).unwrap();
        let out = Preprocess::default().apply(&ds).unwrap();
        let stds = out.points.col_stds();
        assert!((stds[0] - 1.0).abs() < 1e-5);
        assert!((stds[1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn preprocess_pca_reduces_dim() {
        let ds = synth::gaussian_mixture_paper(500, 3);
        // Add a redundant third column = copy of the first.
        let mut data = Vec::with_capacity(500 * 3);
        for i in 0..500 {
            let r = ds.points.row(i);
            data.extend_from_slice(&[r[0], r[1], r[0]]);
        }
        let wide = Dataset::new("wide", Matrix::from_vec(data, 500, 3).unwrap(), None, 3).unwrap();
        let pp = Preprocess { standardize: true, pca_variance: Some(0.999), max_components: None };
        let out = pp.apply(&wide).unwrap();
        assert!(out.dim() <= 2, "redundant column should be dropped, dim={}", out.dim());
    }
}
