//! Minimal, dependency-free CSV reader/writer for numeric datasets.
//!
//! Supports the subset of CSV the pipeline needs: numeric feature columns,
//! optional header row, optional integer label column. Malformed rows are
//! reported with line numbers.

use super::Dataset;
use crate::linalg::Matrix;
use crate::{Error, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Options for [`read_csv`].
#[derive(Clone, Debug)]
pub struct CsvOptions {
    /// Field delimiter.
    pub delimiter: char,
    /// Skip the first line as a header.
    pub has_header: bool,
    /// Column index holding an integer class label (excluded from features).
    pub label_column: Option<usize>,
    /// Suggested `k` recorded on the resulting dataset.
    pub k_hint: usize,
}

impl Default for CsvOptions {
    fn default() -> Self {
        Self { delimiter: ',', has_header: true, label_column: None, k_hint: 0 }
    }
}

/// Read a numeric CSV file into a [`Dataset`].
pub fn read_csv(path: impl AsRef<Path>, opts: &CsvOptions) -> Result<Dataset> {
    let path = path.as_ref();
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "csv".into());
    parse_csv(reader, &name, opts)
}

/// Parse one CSV line into `data`/`labels`, establishing or checking the
/// feature-column count. Returns `Ok(true)` when the line held a data
/// row, `Ok(false)` for blank lines. Shared by the one-shot
/// [`parse_csv`] and the incremental [`CsvChunks`] reader so both report
/// identical errors.
fn parse_line(
    line: &str,
    lineno: usize,
    name: &str,
    opts: &CsvOptions,
    cols: &mut Option<usize>,
    data: &mut Vec<f32>,
    labels: &mut Vec<u32>,
) -> Result<bool> {
    let trimmed = line.trim();
    if trimmed.is_empty() {
        return Ok(false);
    }
    let fields: Vec<&str> = trimmed.split(opts.delimiter).collect();
    let nfeat = fields.len() - opts.label_column.map(|_| 1).unwrap_or(0);
    match cols {
        None => *cols = Some(nfeat),
        Some(c) if *c != nfeat => {
            return Err(Error::Data(format!(
                "{name}:{}: expected {c} feature fields, found {nfeat}",
                lineno + 1
            )))
        }
        _ => {}
    }
    for (i, field) in fields.iter().enumerate() {
        if Some(i) == opts.label_column {
            let v: i64 = field.trim().parse().map_err(|_| {
                Error::Data(format!("{name}:{}: bad label '{field}'", lineno + 1))
            })?;
            labels.push(v as u32);
        } else {
            let v: f32 = field.trim().parse().map_err(|_| {
                Error::Data(format!("{name}:{}: bad number '{field}'", lineno + 1))
            })?;
            data.push(v);
        }
    }
    Ok(true)
}

/// Parse CSV from any reader (exposed for tests and in-memory sources).
pub fn parse_csv(reader: impl BufRead, name: &str, opts: &CsvOptions) -> Result<Dataset> {
    let mut data: Vec<f32> = Vec::new();
    let mut labels: Vec<u32> = Vec::new();
    let mut cols: Option<usize> = None;
    let mut rows = 0usize;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if lineno == 0 && opts.has_header {
            continue;
        }
        if parse_line(&line, lineno, name, opts, &mut cols, &mut data, &mut labels)? {
            rows += 1;
        }
    }
    let cols = cols.unwrap_or(0);
    let points = Matrix::from_vec(data, rows, cols)?;
    let labels = if opts.label_column.is_some() { Some(labels) } else { None };
    Dataset::new(name, points, labels, opts.k_hint)
}

/// Incremental CSV reader: yields fixed-size row shards so the streaming
/// ingest never materializes the full matrix. Each item is
/// `(points, labels)` for up to `shard_rows` rows; concatenating all
/// shards is equivalent to one [`parse_csv`] call on the same input.
/// The iterator fuses on the first error.
pub struct CsvChunks<R: BufRead> {
    lines: std::io::Lines<R>,
    name: String,
    opts: CsvOptions,
    shard_rows: usize,
    cols: Option<usize>,
    lineno: usize,
    done: bool,
}

impl<R: BufRead> CsvChunks<R> {
    /// Number of feature columns, known after the first emitted shard.
    pub fn cols(&self) -> Option<usize> {
        self.cols
    }
}

impl<R: BufRead> Iterator for CsvChunks<R> {
    type Item = Result<(Matrix, Option<Vec<u32>>)>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        let mut data: Vec<f32> = Vec::new();
        let mut labels: Vec<u32> = Vec::new();
        let mut rows = 0usize;
        while rows < self.shard_rows {
            let Some(line) = self.lines.next() else { break };
            let line = match line {
                Ok(l) => l,
                Err(e) => {
                    self.done = true;
                    return Some(Err(e.into()));
                }
            };
            let lineno = self.lineno;
            self.lineno += 1;
            if lineno == 0 && self.opts.has_header {
                continue;
            }
            match parse_line(
                &line,
                lineno,
                &self.name,
                &self.opts,
                &mut self.cols,
                &mut data,
                &mut labels,
            ) {
                Ok(true) => rows += 1,
                Ok(false) => {}
                Err(e) => {
                    self.done = true;
                    return Some(Err(e));
                }
            }
        }
        if rows == 0 {
            self.done = true;
            return None;
        }
        let cols = self.cols.unwrap_or(0);
        let points = match Matrix::from_vec(data, rows, cols) {
            Ok(m) => m,
            Err(e) => {
                self.done = true;
                return Some(Err(e));
            }
        };
        let labels = if self.opts.label_column.is_some() { Some(labels) } else { None };
        Some(Ok((points, labels)))
    }
}

/// Chunked CSV parsing from any reader (see [`CsvChunks`]).
pub fn csv_chunks<R: BufRead>(
    reader: R,
    name: &str,
    opts: &CsvOptions,
    shard_rows: usize,
) -> CsvChunks<R> {
    CsvChunks {
        lines: reader.lines(),
        name: name.to_string(),
        opts: opts.clone(),
        shard_rows: shard_rows.max(1),
        cols: None,
        lineno: 0,
        done: false,
    }
}

/// Open a CSV file for chunked, out-of-core reading: at most
/// `shard_rows` rows are resident per emitted shard.
pub fn read_csv_chunks(
    path: impl AsRef<Path>,
    opts: &CsvOptions,
    shard_rows: usize,
) -> Result<CsvChunks<std::io::BufReader<std::fs::File>>> {
    let path = path.as_ref();
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "csv".into());
    Ok(csv_chunks(reader, &name, opts, shard_rows))
}

/// Write a dataset to CSV (features then optional `label` column).
pub fn write_csv(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    let d = ds.dim();
    // Header.
    let mut header: Vec<String> = (0..d).map(|j| format!("x{j}")).collect();
    if ds.labels.is_some() {
        header.push("label".into());
    }
    writeln!(w, "{}", header.join(","))?;
    for i in 0..ds.len() {
        let row = ds.points.row(i);
        let mut fields: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        if let Some(labels) = &ds.labels {
            fields.push(labels[i].to_string());
        }
        writeln!(w, "{}", fields.join(","))?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic() {
        let src = "a,b\n1.0,2.0\n3.5,-4\n";
        let ds = parse_csv(Cursor::new(src), "t", &CsvOptions::default()).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.points.row(1), &[3.5, -4.0]);
        assert!(ds.labels.is_none());
    }

    #[test]
    fn parse_with_labels() {
        let src = "x,y,c\n1,2,0\n3,4,1\n5,6,1\n";
        let opts = CsvOptions { label_column: Some(2), k_hint: 2, ..Default::default() };
        let ds = parse_csv(Cursor::new(src), "t", &opts).unwrap();
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.labels, Some(vec![0, 1, 1]));
        assert_eq!(ds.k_hint, 2);
    }

    #[test]
    fn ragged_rows_rejected() {
        let src = "1,2\n3,4,5\n";
        let opts = CsvOptions { has_header: false, ..Default::default() };
        let err = parse_csv(Cursor::new(src), "t", &opts).unwrap_err();
        assert!(err.to_string().contains("expected 2"));
    }

    #[test]
    fn bad_number_reported_with_line() {
        let src = "h1,h2\n1,oops\n";
        let err = parse_csv(Cursor::new(src), "t", &CsvOptions::default()).unwrap_err();
        assert!(err.to_string().contains(":2:"), "{err}");
    }

    #[test]
    fn roundtrip_through_file() {
        let ds = crate::data::synth::gaussian_mixture_paper(64, 9);
        let dir = std::env::temp_dir().join("ihtc_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("round.csv");
        write_csv(&ds, &path).unwrap();
        let opts = CsvOptions { label_column: Some(2), k_hint: 3, ..Default::default() };
        let back = read_csv(&path, &opts).unwrap();
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.dim(), ds.dim());
        assert_eq!(back.labels, ds.labels);
        for i in 0..ds.len() {
            for j in 0..ds.dim() {
                assert!((back.points.get(i, j) - ds.points.get(i, j)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn empty_lines_skipped() {
        let src = "h\n1\n\n2\n";
        let opts = CsvOptions { ..Default::default() };
        let ds = parse_csv(Cursor::new(src), "t", &opts).unwrap();
        assert_eq!(ds.len(), 2);
    }

    #[test]
    fn chunked_concat_equals_one_shot() {
        // Chunked reads of any shard size must concatenate to exactly
        // what parse_csv produces — the streaming ingest's contract.
        let ds = crate::data::synth::gaussian_mixture_paper(257, 10);
        let dir = std::env::temp_dir().join("ihtc_csv_chunks_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("chunks.csv");
        write_csv(&ds, &path).unwrap();
        let opts = CsvOptions { label_column: Some(2), k_hint: 3, ..Default::default() };
        let whole = read_csv(&path, &opts).unwrap();
        for shard_rows in [1usize, 64, 100, 257, 1000] {
            let mut data: Vec<f32> = Vec::new();
            let mut labels: Vec<u32> = Vec::new();
            let mut shards = 0usize;
            for item in read_csv_chunks(&path, &opts, shard_rows).unwrap() {
                let (m, l) = item.unwrap();
                assert!(m.rows() <= shard_rows);
                data.extend_from_slice(m.data());
                labels.extend(l.unwrap());
                shards += 1;
            }
            assert_eq!(shards, 257usize.div_ceil(shard_rows));
            assert_eq!(&data, whole.points.data());
            assert_eq!(Some(labels), whole.labels);
        }
    }

    #[test]
    fn chunked_errors_carry_line_numbers_and_fuse() {
        let src = "h1,h2\n1,2\n3,4\n5,oops\n7,8\n";
        let mut it = csv_chunks(Cursor::new(src), "t", &CsvOptions::default(), 2);
        let first = it.next().unwrap().unwrap();
        assert_eq!(first.0.rows(), 2);
        let err = it.next().unwrap().unwrap_err();
        assert!(err.to_string().contains(":4:"), "{err}");
        // Fused: no items after the error.
        assert!(it.next().is_none());
    }

    #[test]
    fn chunked_empty_input_yields_nothing() {
        let mut it = csv_chunks(Cursor::new("h1,h2\n"), "t", &CsvOptions::default(), 8);
        assert!(it.next().is_none());
    }
}
