//! Minimal, dependency-free CSV reader/writer for numeric datasets.
//!
//! Supports the subset of CSV the pipeline needs: numeric feature columns,
//! optional header row, optional integer label column. Malformed rows are
//! reported with line numbers *and* byte offsets, so a torn or truncated
//! stream can be triaged (and resumed) without re-reading the file.

use super::Dataset;
use crate::linalg::Matrix;
use crate::{Error, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Options for [`read_csv`].
#[derive(Clone, Debug)]
pub struct CsvOptions {
    /// Field delimiter.
    pub delimiter: char,
    /// Skip the first line as a header.
    pub has_header: bool,
    /// Column index holding an integer class label (excluded from features).
    pub label_column: Option<usize>,
    /// Suggested `k` recorded on the resulting dataset.
    pub k_hint: usize,
}

impl Default for CsvOptions {
    fn default() -> Self {
        Self { delimiter: ',', has_header: true, label_column: None, k_hint: 0 }
    }
}

/// Read a numeric CSV file into a [`Dataset`].
pub fn read_csv(path: impl AsRef<Path>, opts: &CsvOptions) -> Result<Dataset> {
    let path = path.as_ref();
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "csv".into());
    parse_csv(reader, &name, opts)
}

/// Parse one CSV line into `data`/`labels`, establishing or checking the
/// feature-column count. Returns `Ok(true)` when the line held a data
/// row, `Ok(false)` for blank lines. Shared by the one-shot
/// [`parse_csv`] and the incremental [`CsvChunks`] reader so both report
/// identical errors: `name:line:` plus the byte offset of the line's
/// first character, so a malformed or truncated row mid-stream can be
/// located (and the file repaired or re-fetched) without a re-scan.
fn parse_line(
    line: &str,
    lineno: usize,
    byte: u64,
    name: &str,
    opts: &CsvOptions,
    cols: &mut Option<usize>,
    data: &mut Vec<f32>,
    labels: &mut Vec<u32>,
) -> Result<bool> {
    let trimmed = line.trim();
    if trimmed.is_empty() {
        return Ok(false);
    }
    let fields: Vec<&str> = trimmed.split(opts.delimiter).collect();
    let nfeat = fields.len() - opts.label_column.map(|_| 1).unwrap_or(0);
    match cols {
        None => *cols = Some(nfeat),
        Some(c) if *c != nfeat => {
            return Err(Error::Data(format!(
                "{name}:{}: expected {c} feature fields, found {nfeat} (byte {byte}; a short \
                 final row usually means the file was truncated mid-write)",
                lineno + 1
            )))
        }
        _ => {}
    }
    for (i, field) in fields.iter().enumerate() {
        if Some(i) == opts.label_column {
            let v: i64 = field.trim().parse().map_err(|_| {
                Error::Data(format!("{name}:{}: bad label '{field}' (byte {byte})", lineno + 1))
            })?;
            labels.push(v as u32);
        } else {
            let v: f32 = field.trim().parse().map_err(|_| {
                Error::Data(format!("{name}:{}: bad number '{field}' (byte {byte})", lineno + 1))
            })?;
            data.push(v);
        }
    }
    Ok(true)
}

/// Parse CSV from any reader (exposed for tests and in-memory sources).
pub fn parse_csv(mut reader: impl BufRead, name: &str, opts: &CsvOptions) -> Result<Dataset> {
    let mut data: Vec<f32> = Vec::new();
    let mut labels: Vec<u32> = Vec::new();
    let mut cols: Option<usize> = None;
    let mut rows = 0usize;
    let mut line = String::new();
    let mut lineno = 0usize;
    let mut byte = 0u64;

    loop {
        line.clear();
        let n = reader.read_line(&mut line)?;
        if n == 0 {
            break;
        }
        let line_start = byte;
        byte += n as u64;
        let this = lineno;
        lineno += 1;
        if this == 0 && opts.has_header {
            continue;
        }
        if parse_line(&line, this, line_start, name, opts, &mut cols, &mut data, &mut labels)? {
            rows += 1;
        }
    }
    let cols = cols.unwrap_or(0);
    let points = Matrix::from_vec(data, rows, cols)?;
    let labels = if opts.label_column.is_some() { Some(labels) } else { None };
    Dataset::new(name, points, labels, opts.k_hint)
}

/// Incremental CSV reader: yields fixed-size row shards so the streaming
/// ingest never materializes the full matrix. Each item is
/// `(points, labels)` for up to `shard_rows` rows; concatenating all
/// shards is equivalent to one [`parse_csv`] call on the same input.
/// The iterator fuses on the first error.
pub struct CsvChunks<R: BufRead> {
    reader: R,
    /// Reused line buffer (read_line appends; cleared per line).
    line: String,
    name: String,
    opts: CsvOptions,
    shard_rows: usize,
    cols: Option<usize>,
    lineno: usize,
    /// Byte offset of the next unread line's first character.
    byte: u64,
    done: bool,
}

impl<R: BufRead> CsvChunks<R> {
    /// Number of feature columns, known after the first emitted shard.
    pub fn cols(&self) -> Option<usize> {
        self.cols
    }

    /// Byte offset the reader has consumed through (start of the next
    /// unread line).
    pub fn byte_offset(&self) -> u64 {
        self.byte
    }

    /// Skip `rows` data rows (plus the header and any blank lines, which
    /// are skipped exactly as the parser skips them) without parsing —
    /// the checkpoint-resume fast path: a resumed run trusts the rows it
    /// already reduced and repositions the reader at the first missing
    /// one. Line and byte counters keep advancing, so errors after the
    /// seek still report true file positions. Errors when the file ends
    /// before `rows` data rows were seen (the checkpoint covers more
    /// rows than the file holds — wrong file, or a shrunken one).
    pub fn seek_to_row(&mut self, rows: usize) -> Result<()> {
        let mut remaining = rows;
        while remaining > 0 {
            self.line.clear();
            let n = self.reader.read_line(&mut self.line)?;
            if n == 0 {
                return Err(Error::Data(format!(
                    "{}: stream ended at line {} (byte {}) while seeking to data row {rows} — \
                     the checkpoint covers more rows than the file holds",
                    self.name, self.lineno, self.byte
                )));
            }
            self.byte += n as u64;
            let lineno = self.lineno;
            self.lineno += 1;
            if lineno == 0 && self.opts.has_header {
                continue;
            }
            if !self.line.trim().is_empty() {
                remaining -= 1;
            }
        }
        Ok(())
    }
}

impl<R: BufRead> Iterator for CsvChunks<R> {
    type Item = Result<(Matrix, Option<Vec<u32>>)>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        let mut data: Vec<f32> = Vec::new();
        let mut labels: Vec<u32> = Vec::new();
        let mut rows = 0usize;
        while rows < self.shard_rows {
            self.line.clear();
            let n = match self.reader.read_line(&mut self.line) {
                Ok(n) => n,
                Err(e) => {
                    self.done = true;
                    return Some(Err(e.into()));
                }
            };
            if n == 0 {
                break;
            }
            let line_start = self.byte;
            self.byte += n as u64;
            let lineno = self.lineno;
            self.lineno += 1;
            if lineno == 0 && self.opts.has_header {
                continue;
            }
            match parse_line(
                &self.line,
                lineno,
                line_start,
                &self.name,
                &self.opts,
                &mut self.cols,
                &mut data,
                &mut labels,
            ) {
                Ok(true) => rows += 1,
                Ok(false) => {}
                Err(e) => {
                    self.done = true;
                    return Some(Err(e));
                }
            }
        }
        if rows == 0 {
            self.done = true;
            return None;
        }
        let cols = self.cols.unwrap_or(0);
        let points = match Matrix::from_vec(data, rows, cols) {
            Ok(m) => m,
            Err(e) => {
                self.done = true;
                return Some(Err(e));
            }
        };
        let labels = if self.opts.label_column.is_some() { Some(labels) } else { None };
        Some(Ok((points, labels)))
    }
}

/// Chunked CSV parsing from any reader (see [`CsvChunks`]).
pub fn csv_chunks<R: BufRead>(
    reader: R,
    name: &str,
    opts: &CsvOptions,
    shard_rows: usize,
) -> CsvChunks<R> {
    CsvChunks {
        reader,
        line: String::new(),
        name: name.to_string(),
        opts: opts.clone(),
        shard_rows: shard_rows.max(1),
        cols: None,
        lineno: 0,
        byte: 0,
        done: false,
    }
}

/// Open a CSV file for chunked, out-of-core reading: at most
/// `shard_rows` rows are resident per emitted shard.
pub fn read_csv_chunks(
    path: impl AsRef<Path>,
    opts: &CsvOptions,
    shard_rows: usize,
) -> Result<CsvChunks<std::io::BufReader<std::fs::File>>> {
    let path = path.as_ref();
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "csv".into());
    Ok(csv_chunks(reader, &name, opts, shard_rows))
}

/// [`read_csv_chunks`] positioned at data row `start_row` (0-based,
/// header excluded) — what a checkpoint-resumed streaming run uses to
/// continue from the first row its replayed frames do not cover.
pub fn read_csv_chunks_from(
    path: impl AsRef<Path>,
    opts: &CsvOptions,
    shard_rows: usize,
    start_row: usize,
) -> Result<CsvChunks<std::io::BufReader<std::fs::File>>> {
    let mut chunks = read_csv_chunks(path, opts, shard_rows)?;
    chunks.seek_to_row(start_row)?;
    Ok(chunks)
}

/// Write a dataset to CSV (features then optional `label` column).
pub fn write_csv(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    let d = ds.dim();
    // Header.
    let mut header: Vec<String> = (0..d).map(|j| format!("x{j}")).collect();
    if ds.labels.is_some() {
        header.push("label".into());
    }
    writeln!(w, "{}", header.join(","))?;
    for i in 0..ds.len() {
        let row = ds.points.row(i);
        let mut fields: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        if let Some(labels) = &ds.labels {
            fields.push(labels[i].to_string());
        }
        writeln!(w, "{}", fields.join(","))?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic() {
        let src = "a,b\n1.0,2.0\n3.5,-4\n";
        let ds = parse_csv(Cursor::new(src), "t", &CsvOptions::default()).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.points.row(1), &[3.5, -4.0]);
        assert!(ds.labels.is_none());
    }

    #[test]
    fn parse_with_labels() {
        let src = "x,y,c\n1,2,0\n3,4,1\n5,6,1\n";
        let opts = CsvOptions { label_column: Some(2), k_hint: 2, ..Default::default() };
        let ds = parse_csv(Cursor::new(src), "t", &opts).unwrap();
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.labels, Some(vec![0, 1, 1]));
        assert_eq!(ds.k_hint, 2);
    }

    #[test]
    fn ragged_rows_rejected() {
        let src = "1,2\n3,4,5\n";
        let opts = CsvOptions { has_header: false, ..Default::default() };
        let err = parse_csv(Cursor::new(src), "t", &opts).unwrap_err();
        assert!(err.to_string().contains("expected 2"));
    }

    #[test]
    fn bad_number_reported_with_line() {
        let src = "h1,h2\n1,oops\n";
        let err = parse_csv(Cursor::new(src), "t", &CsvOptions::default()).unwrap_err();
        assert!(err.to_string().contains(":2:"), "{err}");
    }

    #[test]
    fn roundtrip_through_file() {
        let ds = crate::data::synth::gaussian_mixture_paper(64, 9);
        let dir = std::env::temp_dir().join("ihtc_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("round.csv");
        write_csv(&ds, &path).unwrap();
        let opts = CsvOptions { label_column: Some(2), k_hint: 3, ..Default::default() };
        let back = read_csv(&path, &opts).unwrap();
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.dim(), ds.dim());
        assert_eq!(back.labels, ds.labels);
        for i in 0..ds.len() {
            for j in 0..ds.dim() {
                assert!((back.points.get(i, j) - ds.points.get(i, j)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn empty_lines_skipped() {
        let src = "h\n1\n\n2\n";
        let opts = CsvOptions { ..Default::default() };
        let ds = parse_csv(Cursor::new(src), "t", &opts).unwrap();
        assert_eq!(ds.len(), 2);
    }

    #[test]
    fn chunked_concat_equals_one_shot() {
        // Chunked reads of any shard size must concatenate to exactly
        // what parse_csv produces — the streaming ingest's contract.
        let ds = crate::data::synth::gaussian_mixture_paper(257, 10);
        let dir = std::env::temp_dir().join("ihtc_csv_chunks_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("chunks.csv");
        write_csv(&ds, &path).unwrap();
        let opts = CsvOptions { label_column: Some(2), k_hint: 3, ..Default::default() };
        let whole = read_csv(&path, &opts).unwrap();
        for shard_rows in [1usize, 64, 100, 257, 1000] {
            let mut data: Vec<f32> = Vec::new();
            let mut labels: Vec<u32> = Vec::new();
            let mut shards = 0usize;
            for item in read_csv_chunks(&path, &opts, shard_rows).unwrap() {
                let (m, l) = item.unwrap();
                assert!(m.rows() <= shard_rows);
                data.extend_from_slice(m.data());
                labels.extend(l.unwrap());
                shards += 1;
            }
            assert_eq!(shards, 257usize.div_ceil(shard_rows));
            assert_eq!(&data, whole.points.data());
            assert_eq!(Some(labels), whole.labels);
        }
    }

    #[test]
    fn chunked_errors_carry_line_numbers_and_fuse() {
        let src = "h1,h2\n1,2\n3,4\n5,oops\n7,8\n";
        let mut it = csv_chunks(Cursor::new(src), "t", &CsvOptions::default(), 2);
        let first = it.next().unwrap().unwrap();
        assert_eq!(first.0.rows(), 2);
        let err = it.next().unwrap().unwrap_err();
        assert!(err.to_string().contains(":4:"), "{err}");
        // Fused: no items after the error.
        assert!(it.next().is_none());
    }

    #[test]
    fn chunked_empty_input_yields_nothing() {
        let mut it = csv_chunks(Cursor::new("h1,h2\n"), "t", &CsvOptions::default(), 8);
        assert!(it.next().is_none());
    }

    #[test]
    fn truncated_final_line_reports_row_and_byte_offset() {
        // A file torn mid-write: the last row is cut after the
        // delimiter. The error must carry the 1-based line number AND
        // the byte offset of the malformed line, so triage can jump
        // straight to the tear. Line 4 starts at byte 14
        // ("h1,h2\n" = 6, "1,2\n" = 4, "3,4\n" = 4).
        let src = "h1,h2\n1,2\n3,4\n5,";
        let mut it = csv_chunks(Cursor::new(src), "t", &CsvOptions::default(), 100);
        let err = it.next().unwrap().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(":4:"), "{msg}");
        assert!(msg.contains("byte 14"), "{msg}");
        assert!(it.next().is_none(), "iterator must fuse after the error");

        // A row cut *before* the delimiter loses a field instead —
        // reported as a field-count mismatch at the same position.
        let src = "h1,h2\n1,2\n3,4\n5";
        let err = parse_csv(Cursor::new(src), "t", &CsvOptions::default()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(":4:") && msg.contains("byte 14") && msg.contains("truncated"),
            "{msg}");
    }

    #[test]
    fn seek_to_row_matches_full_read_tail() {
        // seek_to_row(k) + chunked read ≡ the tail of the one-shot read,
        // for boundary and mid-shard seek points — the resume contract.
        let ds = crate::data::synth::gaussian_mixture_paper(300, 11);
        let dir = std::env::temp_dir().join("ihtc_csv_seek_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seek.csv");
        write_csv(&ds, &path).unwrap();
        let opts = CsvOptions { label_column: Some(2), k_hint: 3, ..Default::default() };
        let whole = read_csv(&path, &opts).unwrap();
        for start in [0usize, 64, 100, 299, 300] {
            let mut data: Vec<f32> = Vec::new();
            let mut labels: Vec<u32> = Vec::new();
            for item in read_csv_chunks_from(&path, &opts, 64, start).unwrap() {
                let (m, l) = item.unwrap();
                data.extend_from_slice(m.data());
                labels.extend(l.unwrap());
            }
            assert_eq!(&data, &whole.points.data()[start * 2..], "start={start}");
            assert_eq!(&labels, &whole.labels.as_ref().unwrap()[start..], "start={start}");
        }
        // Seeking past the end is the explicit wrong-file error.
        let err = read_csv_chunks_from(&path, &opts, 64, 301).unwrap_err();
        assert!(err.to_string().contains("more rows than the file holds"), "{err}");
    }
}
