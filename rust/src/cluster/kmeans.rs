//! Lloyd's k-means (§2.1) with k-means++ or random initialization,
//! multiple restarts, optional per-point weights, and a pluggable
//! assignment backend so the hot loop (distance-to-centers + argmin +
//! per-cluster sums) can run through the AOT PJRT executable.
//!
//! Complexity `O(n·k·L·d)` time, `O((n+k)·d)` space — the quantities the
//! paper's Table 1 measures with and without ITIS pre-processing.

use crate::linalg::{sq_dist, Matrix};
use crate::rng::Xoshiro256;
use crate::{Error, Result};

/// Initialization strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KMeansInit {
    /// Sample k distinct points uniformly (R's `kmeans` default).
    Random,
    /// k-means++ (Arthur & Vassilvitskii 2007).
    PlusPlus,
}

/// k-means configuration.
#[derive(Clone, Debug)]
pub struct KMeansConfig {
    /// Number of clusters.
    pub k: usize,
    /// Maximum Lloyd iterations per restart.
    pub max_iters: usize,
    /// Restarts (`nstart`); best WCSS wins.
    pub restarts: usize,
    /// Initialization.
    pub init: KMeansInit,
    /// RNG seed.
    pub seed: u64,
    /// Relative WCSS improvement below which a restart stops early.
    pub tol: f64,
}

impl KMeansConfig {
    /// Defaults mirroring the paper's R usage (`kmeans(x, k)`).
    pub fn new(k: usize) -> Self {
        Self { k, max_iters: 100, restarts: 1, init: KMeansInit::PlusPlus, seed: 0x5EED, tol: 1e-6 }
    }
}

/// k-means output.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    /// Cluster per point.
    pub assignments: Vec<u32>,
    /// Final centers (`k × d`).
    pub centers: Matrix,
    /// Within-cluster sum of squares (weighted).
    pub wcss: f64,
    /// Lloyd iterations used by the winning restart.
    pub iterations: usize,
}

/// The assignment + accumulation step for one block of points: given
/// centers, produce per-point argmin assignments and per-cluster weighted
/// sums/counts. The native implementation below mirrors the L2 JAX model
/// (`kmeans_assign` in `python/compile/model.py`); the PJRT runtime
/// provides a drop-in that executes the AOT artifact.
pub trait AssignBackend {
    /// For points `[p0, p0+np)`: write assignments and accumulate
    /// `sums[c*d..][j] += w_i * x_ij`, `counts[c] += w_i`.
    /// Returns the block's weighted WCSS contribution.
    fn assign_block(
        &self,
        points: &Matrix,
        weights: Option<&[f32]>,
        p0: usize,
        np: usize,
        centers: &Matrix,
        assign_out: &mut [u32],
        sums: &mut [f64],
        counts: &mut [f64],
    ) -> Result<f64>;
}

/// Pure-Rust assignment backend.
pub struct NativeAssign;

impl AssignBackend for NativeAssign {
    fn assign_block(
        &self,
        points: &Matrix,
        weights: Option<&[f32]>,
        p0: usize,
        np: usize,
        centers: &Matrix,
        assign_out: &mut [u32],
        sums: &mut [f64],
        counts: &mut [f64],
    ) -> Result<f64> {
        let k = centers.rows();
        let d = points.cols();
        let mut wcss = 0.0f64;
        for i in 0..np {
            let x = points.row(p0 + i);
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..k {
                let dist = sq_dist(x, centers.row(c));
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            assign_out[i] = best as u32;
            let w = weights.map(|w| w[p0 + i] as f64).unwrap_or(1.0);
            wcss += w * best_d as f64;
            counts[best] += w;
            let acc = &mut sums[best * d..(best + 1) * d];
            for (a, &v) in acc.iter_mut().zip(x) {
                *a += w * v as f64;
            }
        }
        Ok(wcss)
    }
}

/// Run k-means with the native backend.
pub fn kmeans(points: &Matrix, config: &KMeansConfig) -> Result<KMeansResult> {
    kmeans_with_backend(points, None, config, &NativeAssign)
}

/// Run weighted k-means (used when clustering ITIS prototypes with their
/// represented-unit masses — an extension over the paper's unweighted use).
pub fn kmeans_weighted(
    points: &Matrix,
    weights: &[f32],
    config: &KMeansConfig,
) -> Result<KMeansResult> {
    if weights.len() != points.rows() {
        return Err(Error::Shape("weights vs points".into()));
    }
    kmeans_with_backend(points, Some(weights), config, &NativeAssign)
}

/// Full-control entry point with an explicit assignment backend.
pub fn kmeans_with_backend(
    points: &Matrix,
    weights: Option<&[f32]>,
    config: &KMeansConfig,
    backend: &dyn AssignBackend,
) -> Result<KMeansResult> {
    let n = points.rows();
    let k = config.k;
    if k == 0 || k > n {
        return Err(Error::InvalidArgument(format!("need 0 < k ≤ n (k={k}, n={n})")));
    }
    let mut best: Option<KMeansResult> = None;
    for restart in 0..config.restarts.max(1) {
        let mut rng = Xoshiro256::stream(config.seed, restart as u64);
        let centers = match config.init {
            KMeansInit::Random => init_random(points, k, &mut rng),
            KMeansInit::PlusPlus => init_plus_plus(points, k, &mut rng),
        };
        let run = lloyd(points, weights, centers, config, backend)?;
        if best.as_ref().map(|b| run.wcss < b.wcss).unwrap_or(true) {
            best = Some(run);
        }
    }
    Ok(best.expect("at least one restart"))
}

fn init_random(points: &Matrix, k: usize, rng: &mut Xoshiro256) -> Matrix {
    let idx = rng.sample_indices(points.rows(), k);
    points.select_rows(&idx)
}

fn init_plus_plus(points: &Matrix, k: usize, rng: &mut Xoshiro256) -> Matrix {
    let n = points.rows();
    let mut chosen = Vec::with_capacity(k);
    chosen.push(rng.next_below(n as u64) as usize);
    // dist²(x, nearest chosen center); updated incrementally.
    let mut d2: Vec<f32> =
        (0..n).map(|i| sq_dist(points.row(i), points.row(chosen[0]))).collect();
    while chosen.len() < k {
        let total: f64 = d2.iter().map(|&v| v as f64).sum();
        let next = if total <= 0.0 {
            // All remaining mass at distance 0 (duplicates): pick uniformly.
            rng.next_below(n as u64) as usize
        } else {
            let mut target = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &v) in d2.iter().enumerate() {
                target -= v as f64;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        chosen.push(next);
        for i in 0..n {
            let d = sq_dist(points.row(i), points.row(next));
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    points.select_rows(&chosen)
}

fn lloyd(
    points: &Matrix,
    weights: Option<&[f32]>,
    mut centers: Matrix,
    config: &KMeansConfig,
    backend: &dyn AssignBackend,
) -> Result<KMeansResult> {
    let n = points.rows();
    let d = points.cols();
    let k = config.k;
    let mut assignments = vec![0u32; n];
    let mut prev_wcss = f64::INFINITY;
    let mut iterations = 0;
    const BLOCK: usize = 4096;

    for iter in 0..config.max_iters.max(1) {
        iterations = iter + 1;
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0.0f64; k];
        let mut wcss = 0.0f64;
        let mut p0 = 0;
        while p0 < n {
            let np = BLOCK.min(n - p0);
            wcss += backend.assign_block(
                points,
                weights,
                p0,
                np,
                &centers,
                &mut assignments[p0..p0 + np],
                &mut sums,
                &mut counts,
            )?;
            p0 += np;
        }
        // Update step; empty clusters are re-seeded to the point farthest
        // from its center (a common Lloyd fix; R restarts instead).
        let mut empty: Vec<usize> = Vec::new();
        for c in 0..k {
            if counts[c] > 0.0 {
                let row = centers.row_mut(c);
                for (j, slot) in row.iter_mut().enumerate() {
                    *slot = (sums[c * d + j] / counts[c]) as f32;
                }
            } else {
                empty.push(c);
            }
        }
        for c in empty {
            // Farthest point from its assigned center.
            let mut far = (0usize, -1.0f32);
            for i in 0..n {
                let dd = sq_dist(points.row(i), centers.row(assignments[i] as usize));
                if dd > far.1 {
                    far = (i, dd);
                }
            }
            let src = points.row(far.0).to_vec();
            centers.row_mut(c).copy_from_slice(&src);
        }
        // Convergence: relative WCSS improvement.
        if prev_wcss.is_finite() {
            let denom = prev_wcss.abs().max(1e-30);
            if (prev_wcss - wcss) / denom < config.tol {
                prev_wcss = wcss;
                break;
            }
        }
        prev_wcss = wcss;
    }
    Ok(KMeansResult { assignments, centers, wcss: prev_wcss, iterations })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::metrics;

    #[test]
    fn recovers_well_separated_clusters() {
        let ds = gaussian_mixture_paper(3000, 81);
        let cfg = KMeansConfig { restarts: 4, ..KMeansConfig::new(3) };
        let r = kmeans(&ds.points, &cfg).unwrap();
        let acc =
            metrics::prediction_accuracy(ds.labels.as_ref().unwrap(), &r.assignments).unwrap();
        // Paper's simulation accuracy is ~0.92 at this geometry.
        assert!(acc > 0.85, "accuracy={acc}");
    }

    #[test]
    fn wcss_decreases_with_k() {
        let ds = gaussian_mixture_paper(1000, 82);
        let w2 = kmeans(&ds.points, &KMeansConfig { restarts: 3, ..KMeansConfig::new(2) })
            .unwrap()
            .wcss;
        let w6 = kmeans(&ds.points, &KMeansConfig { restarts: 3, ..KMeansConfig::new(6) })
            .unwrap()
            .wcss;
        assert!(w6 < w2, "{w6} !< {w2}");
    }

    #[test]
    fn k_equals_n_zero_wcss() {
        let ds = gaussian_mixture_paper(12, 83);
        let r = kmeans(&ds.points, &KMeansConfig::new(12)).unwrap();
        assert!(r.wcss < 1e-6, "{}", r.wcss);
    }

    #[test]
    fn invalid_k_rejected() {
        let ds = gaussian_mixture_paper(10, 84);
        assert!(kmeans(&ds.points, &KMeansConfig::new(0)).is_err());
        assert!(kmeans(&ds.points, &KMeansConfig::new(11)).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = gaussian_mixture_paper(500, 85);
        let cfg = KMeansConfig::new(3);
        let a = kmeans(&ds.points, &cfg).unwrap();
        let b = kmeans(&ds.points, &cfg).unwrap();
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.wcss, b.wcss);
    }

    #[test]
    fn restarts_never_hurt() {
        let ds = gaussian_mixture_paper(800, 86);
        let one = kmeans(
            &ds.points,
            &KMeansConfig { restarts: 1, init: KMeansInit::Random, ..KMeansConfig::new(5) },
        )
        .unwrap();
        let many = kmeans(
            &ds.points,
            &KMeansConfig { restarts: 8, init: KMeansInit::Random, ..KMeansConfig::new(5) },
        )
        .unwrap();
        assert!(many.wcss <= one.wcss + 1e-9);
    }

    #[test]
    fn weighted_equals_replicated() {
        // Weighted k-means on (x, w) should match unweighted on the
        // replicated dataset.
        let base = gaussian_mixture_paper(40, 87);
        let weights: Vec<f32> = (0..40).map(|i| (1 + (i % 3)) as f32).collect();
        let mut rep_rows = Vec::new();
        for i in 0..40 {
            for _ in 0..weights[i] as usize {
                rep_rows.push(i);
            }
        }
        let replicated = base.points.select_rows(&rep_rows);
        let cfg = KMeansConfig { restarts: 6, ..KMeansConfig::new(3) };
        let w = kmeans_weighted(&base.points, &weights, &cfg).unwrap();
        let r = kmeans(&replicated, &cfg).unwrap();
        // Same objective value (centers may be permuted).
        assert!(
            (w.wcss - r.wcss).abs() < 1e-2 * (1.0 + r.wcss),
            "weighted {} vs replicated {}",
            w.wcss,
            r.wcss
        );
    }

    #[test]
    fn all_points_assigned_valid_ids() {
        let ds = gaussian_mixture_paper(700, 88);
        let r = kmeans(&ds.points, &KMeansConfig::new(4)).unwrap();
        assert_eq!(r.assignments.len(), 700);
        assert!(r.assignments.iter().all(|&a| a < 4));
    }

    #[test]
    fn duplicate_heavy_data_handles_plus_plus() {
        // 95 duplicates + 5 distinct points; k-means++ must not spin.
        let mut data = vec![0.0f32; 190];
        for i in 0..5 {
            data.push(10.0 + i as f32);
            data.push(10.0 - i as f32);
        }
        let m = Matrix::from_vec(data, 100, 2).unwrap();
        let r = kmeans(&m, &KMeansConfig::new(3)).unwrap();
        assert_eq!(r.assignments.len(), 100);
    }
}
