//! Lloyd's k-means (§2.1) with k-means++ or random initialization,
//! multiple restarts, optional per-point weights, and a pluggable
//! assignment backend so the hot loop (distance-to-centers + argmin +
//! per-cluster sums) can run through the AOT PJRT executable.
//!
//! Complexity `O(n·k·L·d)` time, `O((n+k)·d)` space — the quantities the
//! paper's Table 1 measures with and without ITIS pre-processing.

use crate::exec::Executor;
use crate::linalg::{simd, sq_dist, Matrix};
use crate::rng::Xoshiro256;
use crate::{Error, Result};

/// Fixed row count per parallel assignment part. Partial sums merge in
/// part order, so pooled results do not depend on the worker count.
const PART: usize = 8192;

/// Row count per serial assignment block (both the plain and the
/// bounded serial Lloyd loops chunk by this, so their f64 WCSS
/// accumulation order — per-point within a block, blocks summed in
/// order — is structurally identical).
const BLOCK: usize = 4096;

/// Relative slack on the Elkan/Hamerly prune test. A prune needs
/// `u·(1+BOUND_SLACK) < max(lower, half_sep)` — all f64, with `u` the
/// freshly computed distance to the current center. The bounds
/// themselves carry only ~1e-7 relative error (one f32 kernel plus an
/// f64 sqrt; the decayed lower bound adds ≤ max_iters·[`DELTA_INFLATE`]),
/// so a test that passes with 1e-4 slack implies a *true* gap of
/// ~1e-4·distance between the assigned center and every other — far
/// above the ~1e-6 relative error of the f32 distance kernel. The full
/// scan could therefore neither find a strictly closer center nor an
/// exact tie at a smaller index, which is what makes skipping it
/// byte-exact (see `assign_block_bounded`).
const BOUND_SLACK: f64 = 1e-4;

/// Relative inflation applied to per-iteration center-movement deltas
/// before they decay the lower bounds, so a kernel that *under*-computes
/// a movement by a few ULP can never make a stale lower bound unsafe,
/// even accumulated across `max_iters` iterations.
const DELTA_INFLATE: f64 = 1e-5;

/// Initialization strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KMeansInit {
    /// Sample k distinct points uniformly (R's `kmeans` default).
    Random,
    /// k-means++ (Arthur & Vassilvitskii 2007).
    PlusPlus,
}

/// k-means configuration.
#[derive(Clone, Debug)]
pub struct KMeansConfig {
    /// Number of clusters.
    pub k: usize,
    /// Maximum Lloyd iterations per restart.
    pub max_iters: usize,
    /// Restarts (`nstart`); best WCSS wins.
    pub restarts: usize,
    /// Initialization.
    pub init: KMeansInit,
    /// RNG seed.
    pub seed: u64,
    /// Relative WCSS improvement below which a restart stops early.
    pub tol: f64,
    /// Elkan/Hamerly triangle-inequality pruning of the assignment scan.
    /// Exact: labels, centers, WCSS, and iteration count are
    /// byte-identical to the unpruned path (the pruned evaluations are
    /// provably non-winners; every *computed* value is unchanged).
    /// Requires a backend with [`AssignBackend::supports_bounds`].
    pub bounds: bool,
}

impl KMeansConfig {
    /// Defaults mirroring the paper's R usage (`kmeans(x, k)`).
    pub fn new(k: usize) -> Self {
        Self {
            k,
            max_iters: 100,
            restarts: 1,
            init: KMeansInit::PlusPlus,
            seed: 0x5EED,
            tol: 1e-6,
            bounds: false,
        }
    }
}

/// k-means output.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    /// Cluster per point.
    pub assignments: Vec<u32>,
    /// Final centers (`k × d`).
    pub centers: Matrix,
    /// Within-cluster sum of squares (weighted).
    pub wcss: f64,
    /// Lloyd iterations used by the winning restart.
    pub iterations: usize,
    /// Bound tests attempted by the winning restart (one per point per
    /// post-initial iteration when `bounds` is on; 0 otherwise).
    pub bound_checks: u64,
    /// Bound tests that pruned the full k-center scan. The hit rate
    /// `bound_hits / bound_checks` is the bench-reported pruning power.
    pub bound_hits: u64,
}

/// The assignment + accumulation step for one block of points: given
/// centers, produce per-point argmin assignments and per-cluster weighted
/// sums/counts. The native implementation below mirrors the L2 JAX model
/// (`kmeans_assign` in `python/compile/model.py`); the PJRT runtime
/// provides a drop-in that executes the AOT artifact.
pub trait AssignBackend {
    /// For points `[p0, p0+np)`: write assignments and accumulate
    /// `sums[c*d..][j] += w_i * x_ij`, `counts[c] += w_i`.
    /// Returns the block's weighted WCSS contribution.
    fn assign_block(
        &self,
        points: &Matrix,
        weights: Option<&[f32]>,
        p0: usize,
        np: usize,
        centers: &Matrix,
        assign_out: &mut [u32],
        sums: &mut [f64],
        counts: &mut [f64],
    ) -> Result<f64>;

    /// Whether `KMeansConfig::bounds` may be combined with this backend.
    /// Bounded Lloyd replays the *native* scan when a bound fails, so it
    /// is only byte-exact against backends whose `assign_block` computes
    /// exactly that scan — [`NativeAssign`] opts in; remote/AOT backends
    /// (PJRT) keep the default `false` and are rejected up front.
    fn supports_bounds(&self) -> bool {
        false
    }
}

/// Pure-Rust assignment backend.
pub struct NativeAssign;

impl AssignBackend for NativeAssign {
    fn assign_block(
        &self,
        points: &Matrix,
        weights: Option<&[f32]>,
        p0: usize,
        np: usize,
        centers: &Matrix,
        assign_out: &mut [u32],
        sums: &mut [f64],
        counts: &mut [f64],
    ) -> Result<f64> {
        let k = centers.rows();
        let d = points.cols();
        // One kernel dispatch per block; the bounded path's replay scan
        // (`scan_best_second`) hoists the same pointer, so both scans
        // call the identical kernel in the identical order.
        let sq = simd::sq_dist_kernel();
        let mut wcss = 0.0f64;
        for i in 0..np {
            let x = points.row(p0 + i);
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..k {
                let dist = sq(x, centers.row(c));
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            assign_out[i] = best as u32;
            let w = weights.map(|w| w[p0 + i] as f64).unwrap_or(1.0);
            wcss += w * best_d as f64;
            counts[best] += w;
            let acc = &mut sums[best * d..(best + 1) * d];
            for (a, &v) in acc.iter_mut().zip(x) {
                *a += w * v as f64;
            }
        }
        Ok(wcss)
    }

    fn supports_bounds(&self) -> bool {
        true
    }
}

/// Reusable buffers for [`kmeans_pool`]: per-part partial accumulators,
/// sized on demand and kept across Lloyd iterations, restarts, and whole
/// runs (see [`crate::hybrid::IhtcWorkspace`]).
#[derive(Debug, Default)]
pub struct KMeansWorkspace {
    part_sums: Vec<Vec<f64>>,
    part_counts: Vec<Vec<f64>>,
    // ── Elkan/Hamerly bound state (`KMeansConfig::bounds`) ──
    /// Per-point f64 lower bound on the distance to the second-closest
    /// center, refreshed on every full scan and decayed by the maximum
    /// center movement otherwise.
    lower: Vec<f64>,
    /// Per-center half distance to its nearest other center (Elkan's
    /// half-center-distance test), recomputed every iteration.
    half_sep: Vec<f64>,
    /// Centers snapshot from before `update_centers`, for movement deltas.
    old_centers: Vec<f32>,
}

impl KMeansWorkspace {
    /// Empty workspace; buffers grow on first use and are then reused.
    pub fn new() -> Self {
        Self::default()
    }
}

/// Run k-means with the native backend.
pub fn kmeans(points: &Matrix, config: &KMeansConfig) -> Result<KMeansResult> {
    kmeans_with_backend(points, None, config, &NativeAssign)
}

/// Executor-parallel k-means: the assignment + accumulation phase of
/// every Lloyd iteration is sharded across the shared executor in fixed
/// 8192-row parts whose partial sums merge in part order, so results are
/// identical for any worker count (they may differ from the serial path
/// in the last float bit — f64 accumulation is re-associated at part
/// boundaries). Small inputs and single-worker executors fall through to
/// the serial path.
pub fn kmeans_pool<B: AssignBackend + Sync>(
    points: &Matrix,
    weights: Option<&[f32]>,
    config: &KMeansConfig,
    backend: &B,
    exec: &Executor,
    ws: &mut KMeansWorkspace,
) -> Result<KMeansResult> {
    let n = points.rows();
    let k = config.k;
    if k == 0 || k > n {
        return Err(Error::InvalidArgument(format!("need 0 < k ≤ n (k={k}, n={n})")));
    }
    if let Some(w) = weights {
        if w.len() != n {
            return Err(Error::Shape("weights vs points".into()));
        }
    }
    if config.bounds && !backend.supports_bounds() {
        return Err(Error::InvalidArgument(
            "kmeans bounds require a backend that supports them (native assignment)".into(),
        ));
    }
    if exec.workers() <= 1 || n < 2 * PART {
        if config.bounds {
            // Serial fallback, but keep the caller's workspace so the
            // bound buffers are reused across restarts and runs.
            return run_restarts(points, config, |centers| {
                lloyd_bounded(points, weights, centers, config, ws)
            });
        }
        return kmeans_with_backend(points, weights, config, backend);
    }
    if config.bounds {
        return run_restarts(points, config, |centers| {
            lloyd_bounded_pool(points, weights, centers, config, exec, ws)
        });
    }
    run_restarts(points, config, |centers| {
        lloyd_pool(points, weights, centers, config, backend, exec, ws)
    })
}

/// Shared restart driver: seed per-restart RNG streams, initialize
/// centers, run one Lloyd pass via `lloyd_fn`, keep the best WCSS. Both
/// the serial and the pooled entry points go through this so restart /
/// init semantics cannot drift between them.
fn run_restarts(
    points: &Matrix,
    config: &KMeansConfig,
    mut lloyd_fn: impl FnMut(Matrix) -> Result<KMeansResult>,
) -> Result<KMeansResult> {
    let mut best: Option<KMeansResult> = None;
    for restart in 0..config.restarts.max(1) {
        let mut rng = Xoshiro256::stream(config.seed, restart as u64);
        let centers = match config.init {
            KMeansInit::Random => init_random(points, config.k, &mut rng),
            KMeansInit::PlusPlus => init_plus_plus(points, config.k, &mut rng),
        };
        let run = lloyd_fn(centers)?;
        if best.as_ref().map(|b| run.wcss < b.wcss).unwrap_or(true) {
            best = Some(run);
        }
    }
    Ok(best.expect("at least one restart"))
}

/// Run weighted k-means (used when clustering ITIS prototypes with their
/// represented-unit masses — an extension over the paper's unweighted use).
pub fn kmeans_weighted(
    points: &Matrix,
    weights: &[f32],
    config: &KMeansConfig,
) -> Result<KMeansResult> {
    if weights.len() != points.rows() {
        return Err(Error::Shape("weights vs points".into()));
    }
    kmeans_with_backend(points, Some(weights), config, &NativeAssign)
}

/// Full-control entry point with an explicit assignment backend.
pub fn kmeans_with_backend(
    points: &Matrix,
    weights: Option<&[f32]>,
    config: &KMeansConfig,
    backend: &dyn AssignBackend,
) -> Result<KMeansResult> {
    let n = points.rows();
    let k = config.k;
    if k == 0 || k > n {
        return Err(Error::InvalidArgument(format!("need 0 < k ≤ n (k={k}, n={n})")));
    }
    if let Some(w) = weights {
        if w.len() != n {
            return Err(Error::Shape("weights vs points".into()));
        }
    }
    if config.bounds {
        if !backend.supports_bounds() {
            return Err(Error::InvalidArgument(
                "kmeans bounds require a backend that supports them (native assignment)".into(),
            ));
        }
        // No caller-provided workspace on this entry point; the bound
        // buffers still live in a KMeansWorkspace (shared across the
        // restarts of this call) so the two bounded loops have one home.
        let mut ws = KMeansWorkspace::new();
        return run_restarts(points, config, |centers| {
            lloyd_bounded(points, weights, centers, config, &mut ws)
        });
    }
    run_restarts(points, config, |centers| lloyd(points, weights, centers, config, backend))
}

fn init_random(points: &Matrix, k: usize, rng: &mut Xoshiro256) -> Matrix {
    let idx = rng.sample_indices(points.rows(), k);
    points.select_rows(&idx)
}

fn init_plus_plus(points: &Matrix, k: usize, rng: &mut Xoshiro256) -> Matrix {
    let n = points.rows();
    let mut chosen = Vec::with_capacity(k);
    chosen.push(rng.next_below(n as u64) as usize);
    // dist²(x, nearest chosen center); updated incrementally.
    let mut d2: Vec<f32> =
        (0..n).map(|i| sq_dist(points.row(i), points.row(chosen[0]))).collect();
    while chosen.len() < k {
        let total: f64 = d2.iter().map(|&v| v as f64).sum();
        let next = if total <= 0.0 {
            // All remaining mass at distance 0 (duplicates): pick uniformly.
            rng.next_below(n as u64) as usize
        } else {
            let mut target = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &v) in d2.iter().enumerate() {
                target -= v as f64;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        chosen.push(next);
        for i in 0..n {
            let d = sq_dist(points.row(i), points.row(next));
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    points.select_rows(&chosen)
}

/// Lloyd update step: move centers to their accumulated weighted means;
/// empty clusters are re-seeded to the point farthest from its assigned
/// center (a common Lloyd fix; R restarts instead).
fn update_centers(
    points: &Matrix,
    assignments: &[u32],
    centers: &mut Matrix,
    sums: &[f64],
    counts: &[f64],
) {
    let n = points.rows();
    let d = points.cols();
    let k = centers.rows();
    let mut empty: Vec<usize> = Vec::new();
    for c in 0..k {
        if counts[c] > 0.0 {
            let row = centers.row_mut(c);
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = (sums[c * d + j] / counts[c]) as f32;
            }
        } else {
            empty.push(c);
        }
    }
    for c in empty {
        // Farthest point from its assigned center.
        let mut far = (0usize, -1.0f32);
        for i in 0..n {
            let dd = sq_dist(points.row(i), centers.row(assignments[i] as usize));
            if dd > far.1 {
                far = (i, dd);
            }
        }
        let src = points.row(far.0).to_vec();
        centers.row_mut(c).copy_from_slice(&src);
    }
}

fn lloyd(
    points: &Matrix,
    weights: Option<&[f32]>,
    mut centers: Matrix,
    config: &KMeansConfig,
    backend: &dyn AssignBackend,
) -> Result<KMeansResult> {
    let n = points.rows();
    let d = points.cols();
    let k = config.k;
    let mut assignments = vec![0u32; n];
    let mut prev_wcss = f64::INFINITY;
    let mut iterations = 0;
    // Accumulators hoisted out of the iteration loop (§Perf: the seed
    // allocated fresh k×d buffers every Lloyd iteration).
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0.0f64; k];

    for iter in 0..config.max_iters.max(1) {
        iterations = iter + 1;
        sums.iter_mut().for_each(|v| *v = 0.0);
        counts.iter_mut().for_each(|v| *v = 0.0);
        let mut wcss = 0.0f64;
        let mut p0 = 0;
        while p0 < n {
            let np = BLOCK.min(n - p0);
            wcss += backend.assign_block(
                points,
                weights,
                p0,
                np,
                &centers,
                &mut assignments[p0..p0 + np],
                &mut sums,
                &mut counts,
            )?;
            p0 += np;
        }
        update_centers(points, &assignments, &mut centers, &sums, &counts);
        // Convergence: relative WCSS improvement.
        if prev_wcss.is_finite() {
            let denom = prev_wcss.abs().max(1e-30);
            if (prev_wcss - wcss) / denom < config.tol {
                prev_wcss = wcss;
                break;
            }
        }
        prev_wcss = wcss;
    }
    Ok(KMeansResult {
        assignments,
        centers,
        wcss: prev_wcss,
        iterations,
        bound_checks: 0,
        bound_hits: 0,
    })
}

/// One Lloyd run with the assignment phase sharded over the executor.
/// Parts
/// are a fixed [`PART`] rows; each part owns its own accumulators from
/// the workspace and partial results merge in part order, making the
/// outcome independent of worker count and scheduling.
fn lloyd_pool<B: AssignBackend + Sync>(
    points: &Matrix,
    weights: Option<&[f32]>,
    mut centers: Matrix,
    config: &KMeansConfig,
    backend: &B,
    exec: &Executor,
    ws: &mut KMeansWorkspace,
) -> Result<KMeansResult> {
    let n = points.rows();
    let d = points.cols();
    let k = config.k;
    let mut assignments = vec![0u32; n];
    let mut prev_wcss = f64::INFINITY;
    let mut iterations = 0;
    let nparts = n.div_ceil(PART);
    if ws.part_sums.len() < nparts {
        ws.part_sums.resize_with(nparts, Vec::new);
        ws.part_counts.resize_with(nparts, Vec::new);
    }
    let mut merged_sums = vec![0.0f64; k * d];
    let mut merged_counts = vec![0.0f64; k];

    for iter in 0..config.max_iters.max(1) {
        iterations = iter + 1;
        for p in 0..nparts {
            ws.part_sums[p].clear();
            ws.part_sums[p].resize(k * d, 0.0);
            ws.part_counts[p].clear();
            ws.part_counts[p].resize(k, 0.0);
        }
        let centers_ref = &centers;
        let mut tasks: Vec<(usize, &mut [u32], &mut [f64], &mut [f64])> =
            Vec::with_capacity(nparts);
        for (((p, a_chunk), s), c) in assignments
            .chunks_mut(PART)
            .enumerate()
            .zip(ws.part_sums.iter_mut().take(nparts))
            .zip(ws.part_counts.iter_mut().take(nparts))
        {
            tasks.push((p * PART, a_chunk, s.as_mut_slice(), c.as_mut_slice()));
        }
        let wcss_parts = exec.run_tasks(tasks, |(p0, a_chunk, s, c)| {
            let np = a_chunk.len();
            backend.assign_block(points, weights, p0, np, centers_ref, a_chunk, s, c)
        })?;
        let wcss: f64 = wcss_parts.iter().sum();
        merged_sums.iter_mut().for_each(|v| *v = 0.0);
        merged_counts.iter_mut().for_each(|v| *v = 0.0);
        for p in 0..nparts {
            for (g, v) in merged_sums.iter_mut().zip(&ws.part_sums[p]) {
                *g += v;
            }
            for (g, v) in merged_counts.iter_mut().zip(&ws.part_counts[p]) {
                *g += v;
            }
        }
        update_centers(points, &assignments, &mut centers, &merged_sums, &merged_counts);
        if prev_wcss.is_finite() {
            let denom = prev_wcss.abs().max(1e-30);
            if (prev_wcss - wcss) / denom < config.tol {
                prev_wcss = wcss;
                break;
            }
        }
        prev_wcss = wcss;
    }
    Ok(KMeansResult {
        assignments,
        centers,
        wcss: prev_wcss,
        iterations,
        bound_checks: 0,
        bound_hits: 0,
    })
}

// ── Elkan/Hamerly bounded Lloyd ─────────────────────────────────────────
//
// Exactness argument (the byte-parity contract rests on this):
//
// The unbounded scan assigns each point to the lowest-indexed center
// attaining the minimum *computed* f32 distance (strict `<` over
// ascending center index). The bounded path always computes the exact
// distance `d_a` to the point's current center — one kernel call, the
// same call the full scan would make — and skips the remaining k−1
// calls only when the triangle inequality proves, with [`BOUND_SLACK`]
// margin over every FP error in the bound arithmetic, that each other
// center is strictly farther by ≳1e-4 relative. That gap dwarfs the
// ~1e-6 relative error of the f32 kernel, so the skipped scan could
// neither have found a strictly smaller computed distance nor an exact
// tie at a smaller index. Assignment, its distance (and hence the f64
// WCSS term), the per-cluster accumulations, and the convergence test
// are therefore bit-for-bit those of the unbounded path; pruning only
// removes evaluations whose results provably would not have been used.
// The serial/pooled bounded loops replicate the BLOCK/PART f64
// accumulation structure of their unbounded twins for the same reason.

/// Per-run pruning counters.
#[derive(Clone, Copy, Debug, Default)]
struct BoundStats {
    checks: u64,
    hits: u64,
}

/// `half_sep[c] = ½·min_{c'≠c} dist(c, c')` — Elkan's half-center-
/// distance: a point within `half_sep[c]` of center `c` cannot be
/// closer to any other center. O(k²) per iteration, negligible next to
/// the O(n·k) scans it prunes.
fn half_separation(centers: &Matrix, half_sep: &mut Vec<f64>) {
    let k = centers.rows();
    let sq = simd::sq_dist_kernel();
    half_sep.clear();
    half_sep.resize(k, f64::INFINITY);
    for a in 0..k {
        for b in a + 1..k {
            let d = (sq(centers.row(a), centers.row(b)) as f64).sqrt();
            if d < 2.0 * half_sep[a] {
                half_sep[a] = 0.5 * d;
            }
            if d < 2.0 * half_sep[b] {
                half_sep[b] = 0.5 * d;
            }
        }
    }
}

/// Maximum center movement since `old` (inflated by [`DELTA_INFLATE`]
/// so it stays an upper bound under kernel FP error); decays the
/// per-point lower bounds.
fn max_center_delta(old: &[f32], centers: &Matrix) -> f64 {
    let d = centers.cols();
    let sq = simd::sq_dist_kernel();
    let mut dmax = 0.0f64;
    for c in 0..centers.rows() {
        let delta = (sq(&old[c * d..(c + 1) * d], centers.row(c)) as f64).sqrt();
        if delta > dmax {
            dmax = delta;
        }
    }
    dmax * (1.0 + DELTA_INFLATE)
}

/// The unbounded assignment scan, verbatim (same kernel pointer, same
/// visit order, same strict `<`), additionally tracking the second-best
/// distance to refresh the Hamerly lower bound.
#[inline]
fn scan_best_second(
    sq: simd::KernelFn,
    x: &[f32],
    centers: &Matrix,
) -> (usize, f32, f32) {
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    let mut second = f32::INFINITY;
    for c in 0..centers.rows() {
        let dist = sq(x, centers.row(c));
        if dist < best_d {
            second = best_d;
            best_d = dist;
            best = c;
        } else if dist < second {
            second = dist;
        }
    }
    (best, best_d, second)
}

/// Bounded counterpart of [`NativeAssign::assign_block`]: identical
/// per-point outputs and accumulation order, with the k-center scan
/// skipped whenever the bound test proves it redundant. `assign_out`
/// carries the previous iteration's assignments in (`first_iter` marks
/// them — and the lower bounds — uninitialized).
#[allow(clippy::too_many_arguments)]
fn assign_block_bounded(
    points: &Matrix,
    weights: Option<&[f32]>,
    p0: usize,
    np: usize,
    centers: &Matrix,
    half_sep: &[f64],
    first_iter: bool,
    assign_out: &mut [u32],
    lower: &mut [f64],
    sums: &mut [f64],
    counts: &mut [f64],
    stats: &mut BoundStats,
) -> f64 {
    let d = points.cols();
    let sq = simd::sq_dist_kernel();
    let mut wcss = 0.0f64;
    for i in 0..np {
        let x = points.row(p0 + i);
        let mut pruned = false;
        let (mut best, mut best_d) = (0usize, f32::INFINITY);
        if !first_iter {
            let a = assign_out[i] as usize;
            let d_a = sq(x, centers.row(a));
            let u = (d_a as f64).sqrt();
            stats.checks += 1;
            if u * (1.0 + BOUND_SLACK) < lower[i].max(half_sep[a]) {
                stats.hits += 1;
                pruned = true;
                best = a;
                best_d = d_a;
            }
        }
        if !pruned {
            let (b, bd, second) = scan_best_second(sq, x, centers);
            best = b;
            best_d = bd;
            lower[i] = (second as f64).sqrt();
        }
        assign_out[i] = best as u32;
        let w = weights.map(|w| w[p0 + i] as f64).unwrap_or(1.0);
        wcss += w * best_d as f64;
        counts[best] += w;
        let acc = &mut sums[best * d..(best + 1) * d];
        for (a, &v) in acc.iter_mut().zip(x) {
            *a += w * v as f64;
        }
    }
    wcss
}

/// Serial bounded Lloyd — byte-identical outputs to [`lloyd`] over
/// [`NativeAssign`] (see the exactness argument above), with most
/// post-warmup distance evaluations pruned on well-separated data.
fn lloyd_bounded(
    points: &Matrix,
    weights: Option<&[f32]>,
    mut centers: Matrix,
    config: &KMeansConfig,
    ws: &mut KMeansWorkspace,
) -> Result<KMeansResult> {
    let n = points.rows();
    let d = points.cols();
    let k = config.k;
    let mut assignments = vec![0u32; n];
    let mut prev_wcss = f64::INFINITY;
    let mut iterations = 0;
    let mut stats = BoundStats::default();
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0.0f64; k];
    ws.lower.clear();
    ws.lower.resize(n, 0.0);

    for iter in 0..config.max_iters.max(1) {
        iterations = iter + 1;
        sums.iter_mut().for_each(|v| *v = 0.0);
        counts.iter_mut().for_each(|v| *v = 0.0);
        half_separation(&centers, &mut ws.half_sep);
        let mut wcss = 0.0f64;
        let mut p0 = 0;
        while p0 < n {
            let np = BLOCK.min(n - p0);
            wcss += assign_block_bounded(
                points,
                weights,
                p0,
                np,
                &centers,
                &ws.half_sep,
                iter == 0,
                &mut assignments[p0..p0 + np],
                &mut ws.lower[p0..p0 + np],
                &mut sums,
                &mut counts,
                &mut stats,
            );
            p0 += np;
        }
        ws.old_centers.clear();
        ws.old_centers.extend_from_slice(centers.data());
        update_centers(points, &assignments, &mut centers, &sums, &counts);
        let dmax = max_center_delta(&ws.old_centers, &centers);
        for l in &mut ws.lower {
            *l = (*l - dmax).max(0.0);
        }
        if prev_wcss.is_finite() {
            let denom = prev_wcss.abs().max(1e-30);
            if (prev_wcss - wcss) / denom < config.tol {
                prev_wcss = wcss;
                break;
            }
        }
        prev_wcss = wcss;
    }
    Ok(KMeansResult {
        assignments,
        centers,
        wcss: prev_wcss,
        iterations,
        bound_checks: stats.checks,
        bound_hits: stats.hits,
    })
}

/// Pooled bounded Lloyd — byte-identical outputs to [`lloyd_pool`] over
/// [`NativeAssign`] for any worker count: the same fixed [`PART`]
/// decomposition, per-part accumulators merged in part order, with each
/// part additionally owning its slice of the lower-bound array (bound
/// state is per-point, so parts never share it).
fn lloyd_bounded_pool(
    points: &Matrix,
    weights: Option<&[f32]>,
    mut centers: Matrix,
    config: &KMeansConfig,
    exec: &Executor,
    ws: &mut KMeansWorkspace,
) -> Result<KMeansResult> {
    let n = points.rows();
    let d = points.cols();
    let k = config.k;
    let mut assignments = vec![0u32; n];
    let mut prev_wcss = f64::INFINITY;
    let mut iterations = 0;
    let mut stats = BoundStats::default();
    let nparts = n.div_ceil(PART);
    if ws.part_sums.len() < nparts {
        ws.part_sums.resize_with(nparts, Vec::new);
        ws.part_counts.resize_with(nparts, Vec::new);
    }
    ws.lower.clear();
    ws.lower.resize(n, 0.0);
    let mut merged_sums = vec![0.0f64; k * d];
    let mut merged_counts = vec![0.0f64; k];

    for iter in 0..config.max_iters.max(1) {
        iterations = iter + 1;
        for p in 0..nparts {
            ws.part_sums[p].clear();
            ws.part_sums[p].resize(k * d, 0.0);
            ws.part_counts[p].clear();
            ws.part_counts[p].resize(k, 0.0);
        }
        half_separation(&centers, &mut ws.half_sep);
        let centers_ref = &centers;
        let half_sep: &[f64] = &ws.half_sep;
        let first_iter = iter == 0;
        let mut tasks: Vec<(usize, &mut [u32], &mut [f64], &mut [f64], &mut [f64])> =
            Vec::with_capacity(nparts);
        for ((((p, a_chunk), l_chunk), s), c) in assignments
            .chunks_mut(PART)
            .enumerate()
            .zip(ws.lower.chunks_mut(PART))
            .zip(ws.part_sums.iter_mut().take(nparts))
            .zip(ws.part_counts.iter_mut().take(nparts))
        {
            tasks.push((p * PART, a_chunk, l_chunk, s.as_mut_slice(), c.as_mut_slice()));
        }
        let part_results = exec.run_tasks(tasks, |(p0, a_chunk, l_chunk, s, c)| {
            let np = a_chunk.len();
            let mut part_stats = BoundStats::default();
            let wcss = assign_block_bounded(
                points,
                weights,
                p0,
                np,
                centers_ref,
                half_sep,
                first_iter,
                a_chunk,
                l_chunk,
                s,
                c,
                &mut part_stats,
            );
            Ok((wcss, part_stats))
        })?;
        // Part order, exactly as lloyd_pool sums its per-part WCSS.
        let wcss: f64 = part_results.iter().map(|(w, _)| w).sum();
        for (_, ps) in &part_results {
            stats.checks += ps.checks;
            stats.hits += ps.hits;
        }
        merged_sums.iter_mut().for_each(|v| *v = 0.0);
        merged_counts.iter_mut().for_each(|v| *v = 0.0);
        for p in 0..nparts {
            for (g, v) in merged_sums.iter_mut().zip(&ws.part_sums[p]) {
                *g += v;
            }
            for (g, v) in merged_counts.iter_mut().zip(&ws.part_counts[p]) {
                *g += v;
            }
        }
        ws.old_centers.clear();
        ws.old_centers.extend_from_slice(centers.data());
        update_centers(points, &assignments, &mut centers, &merged_sums, &merged_counts);
        let dmax = max_center_delta(&ws.old_centers, &centers);
        for l in &mut ws.lower {
            *l = (*l - dmax).max(0.0);
        }
        if prev_wcss.is_finite() {
            let denom = prev_wcss.abs().max(1e-30);
            if (prev_wcss - wcss) / denom < config.tol {
                prev_wcss = wcss;
                break;
            }
        }
        prev_wcss = wcss;
    }
    Ok(KMeansResult {
        assignments,
        centers,
        wcss: prev_wcss,
        iterations,
        bound_checks: stats.checks,
        bound_hits: stats.hits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::metrics;

    #[test]
    fn recovers_well_separated_clusters() {
        let ds = gaussian_mixture_paper(3000, 81);
        let cfg = KMeansConfig { restarts: 4, ..KMeansConfig::new(3) };
        let r = kmeans(&ds.points, &cfg).unwrap();
        let acc =
            metrics::prediction_accuracy(ds.labels.as_ref().unwrap(), &r.assignments).unwrap();
        // Paper's simulation accuracy is ~0.92 at this geometry.
        assert!(acc > 0.85, "accuracy={acc}");
    }

    #[test]
    fn wcss_decreases_with_k() {
        let ds = gaussian_mixture_paper(1000, 82);
        let w2 = kmeans(&ds.points, &KMeansConfig { restarts: 3, ..KMeansConfig::new(2) })
            .unwrap()
            .wcss;
        let w6 = kmeans(&ds.points, &KMeansConfig { restarts: 3, ..KMeansConfig::new(6) })
            .unwrap()
            .wcss;
        assert!(w6 < w2, "{w6} !< {w2}");
    }

    #[test]
    fn k_equals_n_zero_wcss() {
        let ds = gaussian_mixture_paper(12, 83);
        let r = kmeans(&ds.points, &KMeansConfig::new(12)).unwrap();
        assert!(r.wcss < 1e-6, "{}", r.wcss);
    }

    #[test]
    fn invalid_k_rejected() {
        let ds = gaussian_mixture_paper(10, 84);
        assert!(kmeans(&ds.points, &KMeansConfig::new(0)).is_err());
        assert!(kmeans(&ds.points, &KMeansConfig::new(11)).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = gaussian_mixture_paper(500, 85);
        let cfg = KMeansConfig::new(3);
        let a = kmeans(&ds.points, &cfg).unwrap();
        let b = kmeans(&ds.points, &cfg).unwrap();
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.wcss, b.wcss);
    }

    #[test]
    fn restarts_never_hurt() {
        let ds = gaussian_mixture_paper(800, 86);
        let one = kmeans(
            &ds.points,
            &KMeansConfig { restarts: 1, init: KMeansInit::Random, ..KMeansConfig::new(5) },
        )
        .unwrap();
        let many = kmeans(
            &ds.points,
            &KMeansConfig { restarts: 8, init: KMeansInit::Random, ..KMeansConfig::new(5) },
        )
        .unwrap();
        assert!(many.wcss <= one.wcss + 1e-9);
    }

    #[test]
    fn weighted_equals_replicated() {
        // Weighted k-means on (x, w) should match unweighted on the
        // replicated dataset.
        let base = gaussian_mixture_paper(40, 87);
        let weights: Vec<f32> = (0..40).map(|i| (1 + (i % 3)) as f32).collect();
        let mut rep_rows = Vec::new();
        for i in 0..40 {
            for _ in 0..weights[i] as usize {
                rep_rows.push(i);
            }
        }
        let replicated = base.points.select_rows(&rep_rows);
        let cfg = KMeansConfig { restarts: 6, ..KMeansConfig::new(3) };
        let w = kmeans_weighted(&base.points, &weights, &cfg).unwrap();
        let r = kmeans(&replicated, &cfg).unwrap();
        // Same objective value (centers may be permuted).
        assert!(
            (w.wcss - r.wcss).abs() < 1e-2 * (1.0 + r.wcss),
            "weighted {} vs replicated {}",
            w.wcss,
            r.wcss
        );
    }

    #[test]
    fn all_points_assigned_valid_ids() {
        let ds = gaussian_mixture_paper(700, 88);
        let r = kmeans(&ds.points, &KMeansConfig::new(4)).unwrap();
        assert_eq!(r.assignments.len(), 700);
        assert!(r.assignments.iter().all(|&a| a < 4));
    }

    #[test]
    fn pooled_matches_serial_and_is_worker_count_invariant() {
        let ds = gaussian_mixture_paper(17_000, 89);
        let cfg = KMeansConfig { restarts: 2, ..KMeansConfig::new(3) };
        let serial = kmeans(&ds.points, &cfg).unwrap();
        let mut results = Vec::new();
        for workers in [2usize, 4] {
            let exec = Executor::new(workers);
            let mut ws = KMeansWorkspace::new();
            let r = kmeans_pool(&ds.points, None, &cfg, &NativeAssign, &exec, &mut ws).unwrap();
            // Same objective up to part-boundary f64 reassociation.
            assert!(
                (r.wcss - serial.wcss).abs() < 1e-6 * (1.0 + serial.wcss),
                "workers={workers}: {} vs {}",
                r.wcss,
                serial.wcss
            );
            results.push(r);
        }
        // Fixed-part merging makes pooled results worker-count exact.
        assert_eq!(results[0].assignments, results[1].assignments);
        assert_eq!(results[0].wcss.to_bits(), results[1].wcss.to_bits());
    }

    #[test]
    fn bounded_serial_byte_identical_to_unbounded() {
        let ds = gaussian_mixture_paper(3000, 90);
        let base = KMeansConfig { restarts: 2, ..KMeansConfig::new(3) };
        let plain = kmeans(&ds.points, &base).unwrap();
        let bounded = kmeans(&ds.points, &KMeansConfig { bounds: true, ..base.clone() }).unwrap();
        assert_eq!(plain.assignments, bounded.assignments);
        assert_eq!(plain.wcss.to_bits(), bounded.wcss.to_bits());
        assert_eq!(plain.iterations, bounded.iterations);
        let pc: Vec<u32> = plain.centers.data().iter().map(|v| v.to_bits()).collect();
        let bc: Vec<u32> = bounded.centers.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(pc, bc);
        // The unbounded path never tests bounds; the bounded one must
        // actually prune on well-separated blobs.
        assert_eq!(plain.bound_checks, 0);
        assert!(bounded.bound_hits > 0, "no prunes on separated blobs");
        assert!(bounded.bound_hits <= bounded.bound_checks);
        // Weighted runs take the same bounded path.
        let weights: Vec<f32> = (0..3000).map(|i| 1.0 + (i % 5) as f32).collect();
        let pw = kmeans_weighted(&ds.points, &weights, &base).unwrap();
        let bw = kmeans_weighted(
            &ds.points,
            &weights,
            &KMeansConfig { bounds: true, ..base },
        )
        .unwrap();
        assert_eq!(pw.assignments, bw.assignments);
        assert_eq!(pw.wcss.to_bits(), bw.wcss.to_bits());
    }

    #[test]
    fn bounded_pool_byte_identical_to_unbounded_pool() {
        let ds = gaussian_mixture_paper(17_000, 91);
        let base = KMeansConfig { restarts: 2, ..KMeansConfig::new(3) };
        let exec = Executor::new(4);
        let mut ws = KMeansWorkspace::new();
        let plain = kmeans_pool(&ds.points, None, &base, &NativeAssign, &exec, &mut ws).unwrap();
        let mut ws_b = KMeansWorkspace::new();
        let bounded = kmeans_pool(
            &ds.points,
            None,
            &KMeansConfig { bounds: true, ..base },
            &NativeAssign,
            &exec,
            &mut ws_b,
        )
        .unwrap();
        assert_eq!(plain.assignments, bounded.assignments);
        assert_eq!(plain.wcss.to_bits(), bounded.wcss.to_bits());
        assert_eq!(plain.iterations, bounded.iterations);
        assert!(bounded.bound_hits > 0);
    }

    #[test]
    fn bounds_rejected_without_backend_support() {
        // A backend that keeps the default `supports_bounds() == false`
        // must be rejected up front, not silently run unbounded.
        struct NoBounds;
        impl AssignBackend for NoBounds {
            fn assign_block(
                &self,
                _points: &Matrix,
                _weights: Option<&[f32]>,
                _p0: usize,
                _np: usize,
                _centers: &Matrix,
                _assign_out: &mut [u32],
                _sums: &mut [f64],
                _counts: &mut [f64],
            ) -> Result<f64> {
                Ok(0.0)
            }
        }
        let ds = gaussian_mixture_paper(100, 92);
        let cfg = KMeansConfig { bounds: true, ..KMeansConfig::new(3) };
        assert!(kmeans_with_backend(&ds.points, None, &cfg, &NoBounds).is_err());
        let exec = Executor::new(2);
        let mut ws = KMeansWorkspace::new();
        assert!(kmeans_pool(&ds.points, None, &cfg, &NoBounds, &exec, &mut ws).is_err());
    }

    #[test]
    fn bounded_matches_unbounded_on_all_duplicates() {
        // Degenerate geometry: every distance is 0, every half-
        // separation is 0, so no bound can ever fire — the bounded path
        // must degrade to the exact full scan, not misbehave.
        let m = Matrix::from_vec(vec![1.25f32; 200], 100, 2).unwrap();
        let plain = kmeans(&m, &KMeansConfig::new(3)).unwrap();
        let bounded = kmeans(&m, &KMeansConfig { bounds: true, ..KMeansConfig::new(3) }).unwrap();
        assert_eq!(plain.assignments, bounded.assignments);
        assert_eq!(plain.wcss.to_bits(), bounded.wcss.to_bits());
    }

    #[test]
    fn duplicate_heavy_data_handles_plus_plus() {
        // 95 duplicates + 5 distinct points; k-means++ must not spin.
        let mut data = vec![0.0f32; 190];
        for i in 0..5 {
            data.push(10.0 + i as f32);
            data.push(10.0 - i as f32);
        }
        let m = Matrix::from_vec(data, 100, 2).unwrap();
        let r = kmeans(&m, &KMeansConfig::new(3)).unwrap();
        assert_eq!(r.assignments.len(), 100);
    }
}
