//! Conventional clustering algorithms (the "sophisticated" methods IHTC
//! hybridizes): k-means, hierarchical agglomerative clustering, DBSCAN.
//!
//! Each returns a plain `Vec<u32>` assignment so [`crate::hybrid`] can
//! back labels out through the ITIS prototype maps uniformly.

pub mod dbscan;
pub mod elbow;
pub mod gmm;
pub mod hac;
pub mod kmeans;

pub use dbscan::{dbscan, DbscanConfig, NOISE};
pub use elbow::{select_k, ElbowResult};
pub use gmm::{gmm, GmmConfig, GmmResult};
pub use hac::{hac, Dendrogram, HacConfig, Linkage};
pub use kmeans::{kmeans, KMeansConfig, KMeansInit, KMeansResult};
