//! DBSCAN (Ester et al. 1996) — the third clustering method the paper
//! hybridizes (Appendix B).
//!
//! Region queries run through the exact k-d tree, so the complexity is
//! `O(n log n)` for well-behaved ε. Noise points receive the sentinel
//! label [`NOISE`]; the IHTC back-out propagates noise from prototypes to
//! every unit they represent, mirroring the paper's treatment.

use crate::knn::kdtree::KdTree;
use crate::linalg::Matrix;
use crate::{Error, Result};

/// Label for points not reachable from any core point.
pub const NOISE: u32 = u32::MAX;

/// DBSCAN parameters (ε and MinPts in the paper's notation).
#[derive(Clone, Debug)]
pub struct DbscanConfig {
    /// Neighborhood radius ε (Euclidean, not squared).
    pub eps: f64,
    /// Minimum neighborhood size (including the point itself) for a core
    /// point.
    pub min_pts: usize,
}

/// Run DBSCAN; returns per-point labels (`0..k` or [`NOISE`]).
pub fn dbscan(points: &Matrix, config: &DbscanConfig) -> Result<Vec<u32>> {
    if config.eps <= 0.0 {
        return Err(Error::InvalidArgument(format!("eps must be > 0, got {}", config.eps)));
    }
    if config.min_pts == 0 {
        return Err(Error::InvalidArgument("min_pts must be ≥ 1".into()));
    }
    let n = points.rows();
    let tree = KdTree::build(points);
    let r2 = (config.eps * config.eps) as f32;
    const UNVISITED: u32 = u32::MAX - 1;
    let mut labels = vec![UNVISITED; n];
    let mut cluster = 0u32;
    let mut queue: Vec<u32> = Vec::new();

    for i in 0..n {
        if labels[i] != UNVISITED {
            continue;
        }
        let nbrs = tree.radius_query(points, points.row(i), r2, i as u32);
        if nbrs.len() + 1 < config.min_pts {
            labels[i] = NOISE;
            continue;
        }
        // New cluster seeded at core point i; BFS expansion.
        labels[i] = cluster;
        queue.clear();
        queue.extend_from_slice(&nbrs);
        let mut head = 0;
        while head < queue.len() {
            let j = queue[head] as usize;
            head += 1;
            if labels[j] == NOISE {
                labels[j] = cluster; // border point adopted
                continue;
            }
            if labels[j] != UNVISITED {
                continue;
            }
            labels[j] = cluster;
            let jn = tree.radius_query(points, points.row(j), r2, j as u32);
            if jn.len() + 1 >= config.min_pts {
                queue.extend_from_slice(&jn);
            }
        }
        cluster += 1;
    }
    Ok(labels)
}

/// Choose (ε, MinPts) on a subsample the way the paper's Appendix B does:
/// ε from the knee of the sorted `MinPts`-NN distance curve (here: the
/// 90th percentile, a robust stand-in for the visual elbow), MinPts from
/// the rule of thumb `2·d`.
pub fn estimate_params(points: &Matrix, sample: usize, seed: u64) -> Result<DbscanConfig> {
    let n = points.rows();
    if n < 8 {
        return Err(Error::InvalidArgument("too few points to estimate DBSCAN params".into()));
    }
    let min_pts = (2 * points.cols()).max(4);
    let take = sample.min(n);
    let mut rng = crate::rng::Xoshiro256::seed_from_u64(seed);
    let idx = rng.sample_indices(n, take);
    let sub = points.select_rows(&idx);
    let k = (min_pts - 1).min(sub.rows() - 1).max(1);
    let knn = crate::knn::knn_auto(&sub, k)?;
    let mut kth: Vec<f32> = (0..sub.rows()).map(|i| knn.distances(i)[k - 1].sqrt()).collect();
    kth.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Knee of the sorted k-distance curve: the point farthest below the
    // chord from the first to the last value (a discrete "kneedle").
    // This is where the curve turns from cluster-interior distances to
    // outlier distances — the elbow the paper picks visually.
    let n_s = kth.len();
    let (x0, y0) = (0.0f64, kth[0] as f64);
    let (x1, y1) = ((n_s - 1) as f64, kth[n_s - 1] as f64);
    let mut best = (0usize, f64::NEG_INFINITY);
    for (i, &v) in kth.iter().enumerate() {
        let chord = y0 + (y1 - y0) * (i as f64 - x0) / (x1 - x0).max(1.0);
        let below = chord - v as f64;
        if below > best.1 {
            best = (i, below);
        }
    }
    // The raw knee consistently over-estimates ε on overlapping mixtures
    // (everything merges into one component); the paper's cross-validated
    // ε sits well below it. Halving the knee lands in the regime where
    // the dense cores separate (validated on the Table 3 analogues —
    // see EXPERIMENTS.md Table 9 notes).
    let eps = kth[best.0] as f64 * 0.5;
    Ok(DbscanConfig { eps: eps.max(1e-9), min_pts })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;
    use crate::rng::Xoshiro256;

    fn two_moons_ish(seed: u64, per: usize) -> (Matrix, Vec<u32>) {
        // Two dense blobs plus sparse uniform noise.
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut data = Vec::new();
        let mut labels = Vec::new();
        for (ci, &(cx, cy)) in [(0.0f32, 0.0f32), (10.0, 0.0)].iter().enumerate() {
            for _ in 0..per {
                data.push(cx + 0.5 * rng.next_gaussian() as f32);
                data.push(cy + 0.5 * rng.next_gaussian() as f32);
                labels.push(ci as u32);
            }
        }
        (Matrix::from_vec(data, 2 * per, 2).unwrap(), labels)
    }

    #[test]
    fn recovers_dense_blobs() {
        let (m, truth) = two_moons_ish(101, 100);
        let labels = dbscan(&m, &DbscanConfig { eps: 0.8, min_pts: 5 }).unwrap();
        // Noise-free here; two clusters matching the blobs.
        let k = labels.iter().filter(|&&l| l != NOISE).map(|&l| l + 1).max().unwrap();
        assert_eq!(k, 2);
        let acc = metrics::prediction_accuracy(&truth, &labels).unwrap();
        assert!(acc > 0.98, "{acc}");
    }

    #[test]
    fn isolated_points_are_noise() {
        let mut data = vec![];
        // Tight blob of 20 + 1 far point.
        let mut rng = Xoshiro256::seed_from_u64(102);
        for _ in 0..20 {
            data.push(0.1 * rng.next_gaussian() as f32);
            data.push(0.1 * rng.next_gaussian() as f32);
        }
        data.push(100.0);
        data.push(100.0);
        let m = Matrix::from_vec(data, 21, 2).unwrap();
        let labels = dbscan(&m, &DbscanConfig { eps: 1.0, min_pts: 4 }).unwrap();
        assert_eq!(labels[20], NOISE);
        assert!(labels[..20].iter().all(|&l| l == 0));
    }

    #[test]
    fn min_pts_one_no_noise() {
        let (m, _) = two_moons_ish(103, 30);
        let labels = dbscan(&m, &DbscanConfig { eps: 0.5, min_pts: 1 }).unwrap();
        assert!(labels.iter().all(|&l| l != NOISE));
    }

    #[test]
    fn invalid_params_rejected() {
        let m = Matrix::zeros(10, 2);
        assert!(dbscan(&m, &DbscanConfig { eps: 0.0, min_pts: 4 }).is_err());
        assert!(dbscan(&m, &DbscanConfig { eps: 1.0, min_pts: 0 }).is_err());
    }

    #[test]
    fn border_points_adopted_not_noise() {
        // A line of points at spacing 1 with eps=1.1, min_pts=3: ends are
        // border points (2 neighbors incl. self) but reachable → clustered.
        let data: Vec<f32> = (0..10).flat_map(|i| [i as f32, 0.0]).collect();
        let m = Matrix::from_vec(data, 10, 2).unwrap();
        let labels = dbscan(&m, &DbscanConfig { eps: 1.1, min_pts: 3 }).unwrap();
        assert!(labels.iter().all(|&l| l == 0), "{labels:?}");
    }

    #[test]
    fn estimate_params_reasonable() {
        let (m, _) = two_moons_ish(104, 200);
        let cfg = estimate_params(&m, 200, 1).unwrap();
        assert_eq!(cfg.min_pts, 4);
        assert!(cfg.eps > 0.05 && cfg.eps < 3.0, "eps={}", cfg.eps);
        // The estimated params must separate the blobs (≥ 2 clusters,
        // never one merged component) without drowning in noise.
        let labels = dbscan(&m, &cfg).unwrap();
        let k = labels
            .iter()
            .filter(|&&l| l != NOISE)
            .collect::<std::collections::HashSet<_>>()
            .len();
        let noise = labels.iter().filter(|&&l| l == NOISE).count();
        assert!(k >= 2, "k={k}");
        assert!(noise < labels.len() / 3, "noise={noise}");
        // Points from different blobs never share a cluster.
        for i in 0..200 {
            for j in 200..400 {
                if labels[i] != NOISE {
                    assert_ne!(labels[i], labels[j], "blobs merged");
                }
            }
        }
    }

    #[test]
    fn deterministic() {
        let (m, _) = two_moons_ish(105, 50);
        let a = dbscan(&m, &DbscanConfig { eps: 0.7, min_pts: 4 }).unwrap();
        let b = dbscan(&m, &DbscanConfig { eps: 0.7, min_pts: 4 }).unwrap();
        assert_eq!(a, b);
    }
}
