//! Hierarchical agglomerative clustering (§2.2).
//!
//! Implemented with the nearest-neighbor-chain algorithm over a condensed
//! distance matrix and Lance–Williams updates, giving `O(n²)` time and
//! `O(n²)` memory for the four classic reducible linkages (Ward, average,
//! complete, single). Ward is the paper's choice (Ward Jr. 1963 is the
//! §2.2 citation) and the default.
//!
//! Like R's `hclust` — which the paper notes "will throw an error" past
//! 65 536 points — construction refuses inputs above a configurable cap.
//! That cap is exactly the pain IHTC exists to remove: ITIS first reduces
//! `n` below the cap, then HAC runs on the prototypes.

use crate::linalg::{sq_dist, Matrix};
use crate::{Error, Result};

/// Linkage criterion (Lance–Williams family, all reducible).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Linkage {
    /// Ward's minimum-variance method (paper default; R's `ward.D2`).
    Ward,
    /// Unweighted average (UPGMA).
    Average,
    /// Complete linkage (farthest neighbor).
    Complete,
    /// Single linkage (nearest neighbor).
    Single,
}

/// HAC configuration.
#[derive(Clone, Debug)]
pub struct HacConfig {
    /// Linkage criterion.
    pub linkage: Linkage,
    /// Refuse inputs larger than this (R's `hclust` practical limit).
    pub max_n: usize,
}

impl Default for HacConfig {
    fn default() -> Self {
        Self { linkage: Linkage::Ward, max_n: 65_536 }
    }
}

/// One merge step: clusters `a` and `b` (scipy node convention: leaves are
/// `0..n`, the merge at step `s` creates node `n + s`) joined at `height`.
#[derive(Clone, Copy, Debug)]
pub struct Merge {
    /// First merged node id.
    pub a: u32,
    /// Second merged node id.
    pub b: u32,
    /// Merge dissimilarity (Euclidean scale for every linkage).
    pub height: f32,
    /// Size of the new cluster.
    pub size: u32,
}

/// The full merge tree.
#[derive(Clone, Debug)]
pub struct Dendrogram {
    /// Number of leaves.
    pub n: usize,
    /// `n − 1` merges in the order the algorithm performed them.
    pub merges: Vec<Merge>,
}

impl Dendrogram {
    /// Cut the tree into `k` clusters; returns per-leaf labels `0..k`.
    ///
    /// Merges are replayed in ascending height order (valid for reducible
    /// linkages) through a union-find until `k` components remain.
    pub fn cut(&self, k: usize) -> Result<Vec<u32>> {
        let n = self.n;
        if k == 0 || k > n {
            return Err(Error::InvalidArgument(format!("cut k={k} of n={n}")));
        }
        let mut order: Vec<usize> = (0..self.merges.len()).collect();
        order.sort_by(|&x, &y| {
            self.merges[x]
                .height
                .partial_cmp(&self.merges[y].height)
                .unwrap()
                .then(x.cmp(&y))
        });
        // Union-find over merge-tree node ids (2n − 1 of them).
        let mut parent: Vec<u32> = (0..(2 * n - 1) as u32).collect();
        fn find(parent: &mut [u32], mut x: u32) -> u32 {
            while parent[x as usize] != x {
                parent[x as usize] = parent[parent[x as usize] as usize];
                x = parent[x as usize];
            }
            x
        }
        let mut components = n;
        for &mi in &order {
            if components == k {
                break;
            }
            let m = &self.merges[mi];
            let node = (n + mi) as u32;
            let ra = find(&mut parent, m.a);
            let rb = find(&mut parent, m.b);
            parent[ra as usize] = node;
            parent[rb as usize] = node;
            components -= 1;
        }
        // Relabel roots to compact 0..k, first-seen order (leaf-index
        // order, so labels are deterministic). Roots are merge-tree node
        // ids < 2n − 1 — a flat table beats hashing.
        let mut labels = vec![0u32; n];
        let mut remap = vec![u32::MAX; 2 * n - 1];
        let mut next = 0u32;
        for i in 0..n {
            let root = find(&mut parent, i as u32) as usize;
            if remap[root] == u32::MAX {
                remap[root] = next;
                next += 1;
            }
            labels[i] = remap[root];
        }
        Ok(labels)
    }
}

#[inline]
fn cidx(n: usize, i: usize, j: usize) -> usize {
    debug_assert!(i < j && j < n);
    i * n - i * (i + 1) / 2 + (j - i - 1)
}

/// Run HAC; returns the dendrogram. Use [`Dendrogram::cut`] for labels or
/// [`hac_cut`] for the one-call version.
pub fn hac(points: &Matrix, config: &HacConfig) -> Result<Dendrogram> {
    let n = points.rows();
    if n > config.max_n {
        return Err(Error::InvalidArgument(format!(
            "HAC refuses n={n} > max_n={} (this is the bottleneck ITIS pre-processing removes; \
             reduce first or raise max_n)",
            config.max_n
        )));
    }
    if n == 0 {
        return Ok(Dendrogram { n: 0, merges: vec![] });
    }
    // Working dissimilarity: squared Euclidean for Ward, Euclidean otherwise.
    let ward = config.linkage == Linkage::Ward;
    let mut dmat = vec![0.0f32; n * (n - 1) / 2];
    for i in 0..n {
        let ri = points.row(i);
        for j in (i + 1)..n {
            let d2 = sq_dist(ri, points.row(j));
            dmat[cidx(n, i, j)] = if ward { d2 } else { d2.sqrt() };
        }
    }
    hac_from_dissimilarity(n, &mut dmat, config.linkage)
}

/// NN-chain over a prefilled condensed dissimilarity matrix (consumed).
/// For `Linkage::Ward` the matrix must contain *squared* distances.
pub fn hac_from_dissimilarity(
    n: usize,
    dmat: &mut [f32],
    linkage: Linkage,
) -> Result<Dendrogram> {
    if n == 0 {
        return Ok(Dendrogram { n: 0, merges: vec![] });
    }
    // A wrong-length matrix is caller data, not an invariant — erroring
    // (instead of the old assert) keeps a bad condensed buffer from
    // aborting a long pipeline run.
    let want = n * (n - 1) / 2;
    if dmat.len() != want {
        return Err(Error::Data(format!(
            "hac: condensed dissimilarity has {} entries but n = {n} needs {want}",
            dmat.len()
        )));
    }
    let ward = linkage == Linkage::Ward;
    let mut active: Vec<bool> = vec![true; n];
    let mut size: Vec<u32> = vec![1; n];
    // Map active row → current merge-tree node id.
    let mut node_id: Vec<u32> = (0..n as u32).collect();
    let mut merges: Vec<Merge> = Vec::with_capacity(n.saturating_sub(1));
    let mut chain: Vec<usize> = Vec::with_capacity(n);
    let mut remaining = n;
    let get = |dmat: &[f32], a: usize, b: usize| -> f32 {
        if a < b { dmat[cidx(n, a, b)] } else { dmat[cidx(n, b, a)] }
    };

    while remaining > 1 {
        if chain.is_empty() {
            let start = active.iter().position(|&a| a).expect("an active cluster");
            chain.push(start);
        }
        loop {
            let a = *chain.last().unwrap();
            // Nearest active neighbor of a (smallest dissimilarity,
            // ties to the smaller index for determinism).
            let mut best = usize::MAX;
            let mut best_d = f32::INFINITY;
            for b in 0..n {
                if b == a || !active[b] {
                    continue;
                }
                let d = get(dmat, a, b);
                if d < best_d {
                    best_d = d;
                    best = b;
                }
            }
            let b = best;
            if chain.len() >= 2 && chain[chain.len() - 2] == b {
                // Reciprocal nearest neighbors: merge a and b.
                chain.pop();
                chain.pop();
                let dab = best_d;
                let (sa, sb) = (size[a] as f32, size[b] as f32);
                // Lance–Williams update of every other active cluster's
                // dissimilarity to the merged cluster (stored at slot a).
                for k in 0..n {
                    if k == a || k == b || !active[k] {
                        continue;
                    }
                    let dak = get(dmat, a, k);
                    let dbk = get(dmat, b, k);
                    let sk = size[k] as f32;
                    let newd = match linkage {
                        Linkage::Ward => {
                            ((sa + sk) * dak + (sb + sk) * dbk - sk * dab) / (sa + sb + sk)
                        }
                        Linkage::Average => (sa * dak + sb * dbk) / (sa + sb),
                        Linkage::Complete => dak.max(dbk),
                        Linkage::Single => dak.min(dbk),
                    };
                    let idx = if a < k { cidx(n, a, k) } else { cidx(n, k, a) };
                    dmat[idx] = newd;
                }
                active[b] = false;
                size[a] += size[b];
                let height = if ward { dab.max(0.0).sqrt() } else { dab };
                let new_node = (n + merges.len()) as u32;
                merges.push(Merge {
                    a: node_id[a],
                    b: node_id[b],
                    height,
                    size: size[a],
                });
                node_id[a] = new_node;
                remaining -= 1;
                break;
            }
            chain.push(b);
        }
    }
    Ok(Dendrogram { n, merges })
}

/// One-call HAC + cut.
pub fn hac_cut(points: &Matrix, k: usize, config: &HacConfig) -> Result<Vec<u32>> {
    hac(points, config)?.cut(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::metrics;
    use crate::rng::Xoshiro256;

    fn blobs(seed: u64, per: usize, centers: &[(f32, f32)], spread: f32) -> (Matrix, Vec<u32>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut data = Vec::new();
        let mut labels = Vec::new();
        for (ci, &(cx, cy)) in centers.iter().enumerate() {
            for _ in 0..per {
                data.push(cx + spread * rng.next_gaussian() as f32);
                data.push(cy + spread * rng.next_gaussian() as f32);
                labels.push(ci as u32);
            }
        }
        (Matrix::from_vec(data, per * centers.len(), 2).unwrap(), labels)
    }

    #[test]
    fn separated_blobs_recovered_every_linkage() {
        let (m, truth) = blobs(91, 30, &[(0.0, 0.0), (50.0, 0.0), (0.0, 50.0)], 1.0);
        for linkage in [Linkage::Ward, Linkage::Average, Linkage::Complete, Linkage::Single] {
            let cfg = HacConfig { linkage, ..Default::default() };
            let labels = hac_cut(&m, 3, &cfg).unwrap();
            let acc = metrics::prediction_accuracy(&truth, &labels).unwrap();
            assert_eq!(acc, 1.0, "{linkage:?}");
        }
    }

    #[test]
    fn merge_count_and_sizes() {
        let (m, _) = blobs(92, 10, &[(0.0, 0.0), (10.0, 10.0)], 0.5);
        let dend = hac(&m, &HacConfig::default()).unwrap();
        assert_eq!(dend.merges.len(), 19);
        assert_eq!(dend.merges.last().unwrap().size, 20);
    }

    #[test]
    fn cut_extremes() {
        let (m, _) = blobs(93, 5, &[(0.0, 0.0), (10.0, 10.0)], 0.5);
        let dend = hac(&m, &HacConfig::default()).unwrap();
        let all = dend.cut(1).unwrap();
        assert!(all.iter().all(|&l| l == 0));
        let singles = dend.cut(10).unwrap();
        let distinct: std::collections::HashSet<_> = singles.iter().collect();
        assert_eq!(distinct.len(), 10);
        assert!(dend.cut(0).is_err());
        assert!(dend.cut(11).is_err());
    }

    #[test]
    fn wrong_length_dissimilarity_is_an_error_not_a_panic() {
        // 4 points need 6 condensed entries; 5 must error cleanly.
        let mut short = vec![0.0f32; 5];
        let err = hac_from_dissimilarity(4, &mut short, Linkage::Average).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("needs 6"), "{err}");
        let mut long = vec![0.0f32; 7];
        assert!(hac_from_dissimilarity(4, &mut long, Linkage::Ward).is_err());
        // n = 0 with an empty buffer stays the documented no-op.
        assert_eq!(hac_from_dissimilarity(0, &mut [], Linkage::Single).unwrap().merges.len(), 0);
    }

    #[test]
    fn max_n_guard_replicates_hclust_limit() {
        let m = Matrix::zeros(11, 2);
        let cfg = HacConfig { max_n: 10, ..Default::default() };
        let err = hac(&m, &cfg).unwrap_err();
        assert!(err.to_string().contains("max_n"), "{err}");
    }

    #[test]
    fn single_linkage_chains() {
        // A chain of equidistant points plus one far point: single linkage
        // with k=2 isolates the far point.
        let mut data = Vec::new();
        for i in 0..8 {
            data.push(i as f32);
            data.push(0.0);
        }
        data.push(100.0);
        data.push(0.0);
        let m = Matrix::from_vec(data, 9, 1 + 1).unwrap();
        let labels = hac_cut(&m, 2, &HacConfig { linkage: Linkage::Single, ..Default::default() }).unwrap();
        assert_eq!(labels[8] == labels[0], false);
        for i in 1..8 {
            assert_eq!(labels[i], labels[0]);
        }
    }

    #[test]
    fn ward_heights_monotone() {
        // For reducible linkages, sorted replay = valid hierarchy; Ward
        // heights from NN-chain should be non-decreasing after sorting and
        // the final merge the largest.
        let ds = gaussian_mixture_paper(120, 94);
        let dend = hac(&ds.points, &HacConfig::default()).unwrap();
        let mut heights: Vec<f32> = dend.merges.iter().map(|m| m.height).collect();
        let max = heights.iter().cloned().fold(0.0f32, f32::max);
        heights.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(*heights.last().unwrap(), max);
        assert!(heights.iter().all(|&h| h >= 0.0));
    }

    #[test]
    fn average_linkage_two_pairs() {
        // Known tiny instance: points at 0, 1, 10, 11 on a line.
        let m = Matrix::from_vec(vec![0.0, 1.0, 10.0, 11.0], 4, 1).unwrap();
        let dend = hac(&m, &HacConfig { linkage: Linkage::Average, ..Default::default() }).unwrap();
        // First two merges at height 1 (the pairs), final at average
        // distance between pairs = (9+10+10+11)/4 = 10.
        let mut hs: Vec<f32> = dend.merges.iter().map(|m| m.height).collect();
        hs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((hs[0] - 1.0).abs() < 1e-5);
        assert!((hs[1] - 1.0).abs() < 1e-5);
        assert!((hs[2] - 10.0).abs() < 1e-4, "{hs:?}");
    }

    #[test]
    fn matches_bruteforce_agglomeration_complete() {
        // Cross-check NN-chain against a naive O(n³) agglomerative
        // implementation on a small random instance (complete linkage).
        let ds = gaussian_mixture_paper(40, 95);
        let n = 40;
        let fast = hac(&ds.points, &HacConfig { linkage: Linkage::Complete, ..Default::default() })
            .unwrap();
        // Naive: repeatedly merge the globally closest pair.
        let mut clusters: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
        let mut naive_heights = Vec::new();
        while clusters.len() > 1 {
            let mut best = (0, 1, f32::INFINITY);
            for a in 0..clusters.len() {
                for b in (a + 1)..clusters.len() {
                    let mut dmax = 0.0f32;
                    for &i in &clusters[a] {
                        for &j in &clusters[b] {
                            dmax = dmax.max(sq_dist(ds.points.row(i), ds.points.row(j)).sqrt());
                        }
                    }
                    if dmax < best.2 {
                        best = (a, b, dmax);
                    }
                }
            }
            naive_heights.push(best.2);
            let merged = clusters.remove(best.1);
            clusters[best.0].extend(merged);
        }
        let mut fast_heights: Vec<f32> = fast.merges.iter().map(|m| m.height).collect();
        fast_heights.sort_by(|a, b| a.partial_cmp(b).unwrap());
        naive_heights.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (f, e) in fast_heights.iter().zip(&naive_heights) {
            assert!((f - e).abs() < 1e-4, "{fast_heights:?} vs {naive_heights:?}");
        }
    }
}
