//! Gaussian-mixture clustering by EM (diagonal covariances).
//!
//! §3.2 closes with "IHTC may be applied to most other clustering
//! algorithms — not just k-means or HAC". This is that extension point
//! exercised for real: a diagonal-covariance GMM fit by
//! expectation-maximization, usable as an IHTC final clusterer (and the
//! natural model family for the paper's §4 simulation, which *is* a
//! Gaussian mixture). Supports per-point weights so prototypes can carry
//! their represented-unit masses — the statistically faithful way to fit
//! a model on reduced data.

use crate::linalg::Matrix;
use crate::rng::Xoshiro256;
use crate::{Error, Result};

/// GMM configuration.
#[derive(Clone, Debug)]
pub struct GmmConfig {
    /// Number of components.
    pub k: usize,
    /// Maximum EM iterations.
    pub max_iters: usize,
    /// Log-likelihood relative-improvement stopping tolerance.
    pub tol: f64,
    /// Variance floor (keeps components from collapsing onto points).
    pub var_floor: f64,
    /// RNG seed (k-means++-style initialization).
    pub seed: u64,
}

impl GmmConfig {
    /// Defaults for `k` components.
    pub fn new(k: usize) -> Self {
        Self { k, max_iters: 200, tol: 1e-7, var_floor: 1e-6, seed: 0x96_6D }
    }
}

/// Fitted mixture.
#[derive(Clone, Debug)]
pub struct GmmResult {
    /// Hard assignment (argmax responsibility) per point.
    pub assignments: Vec<u32>,
    /// Mixture weights (length k).
    pub weights: Vec<f64>,
    /// Component means (k × d).
    pub means: Matrix,
    /// Component per-axis variances (k × d).
    pub variances: Matrix,
    /// Final mean log-likelihood.
    pub log_likelihood: f64,
    /// EM iterations used.
    pub iterations: usize,
}

/// Fit a diagonal GMM with EM; `point_weights` (optional) scales each
/// point's contribution (prototype masses).
pub fn gmm(points: &Matrix, point_weights: Option<&[f32]>, config: &GmmConfig) -> Result<GmmResult> {
    let (n, d) = (points.rows(), points.cols());
    let k = config.k;
    if k == 0 || k > n {
        return Err(Error::InvalidArgument(format!("need 0 < k ≤ n (k={k}, n={n})")));
    }
    if let Some(w) = point_weights {
        if w.len() != n {
            return Err(Error::Shape("point_weights vs points".into()));
        }
        if w.iter().any(|&x| x < 0.0) {
            return Err(Error::InvalidArgument("negative point weight".into()));
        }
    }
    let wsum: f64 = match point_weights {
        Some(w) => w.iter().map(|&x| x as f64).sum(),
        None => n as f64,
    };
    if wsum <= 0.0 {
        return Err(Error::InvalidArgument("total point weight is zero".into()));
    }

    // ---- init: distance-weighted center seeding + global variance. ----
    let mut rng = Xoshiro256::seed_from_u64(config.seed);
    let mut means = init_means(points, k, &mut rng);
    let global_var: Vec<f64> = points
        .col_stds()
        .iter()
        .map(|s| (s * s).max(config.var_floor))
        .collect();
    let mut variances = Matrix::zeros(k, d);
    for c in 0..k {
        for j in 0..d {
            variances.set(c, j, global_var[j] as f32);
        }
    }
    let mut mix = vec![1.0 / k as f64; k];

    let mut resp = vec![0.0f64; n * k];
    let mut prev_ll = f64::NEG_INFINITY;
    let mut iterations = 0;
    for iter in 0..config.max_iters.max(1) {
        iterations = iter + 1;
        // ---- E step: responsibilities via log-sum-exp. ----
        let mut ll = 0.0f64;
        for i in 0..n {
            let x = points.row(i);
            let mut logp = vec![0.0f64; k];
            for c in 0..k {
                let mut acc = mix[c].max(1e-300).ln();
                for j in 0..d {
                    let var = variances.get(c, j) as f64;
                    let diff = x[j] as f64 - means.get(c, j) as f64;
                    acc += -0.5 * ((2.0 * std::f64::consts::PI * var).ln() + diff * diff / var);
                }
                logp[c] = acc;
            }
            let m = logp.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let sum: f64 = logp.iter().map(|&l| (l - m).exp()).sum();
            let w_i = point_weights.map(|w| w[i] as f64).unwrap_or(1.0);
            ll += w_i * (m + sum.ln());
            for c in 0..k {
                resp[i * k + c] = (logp[c] - m).exp() / sum;
            }
        }
        ll /= wsum;
        // ---- M step. ----
        let mut nk = vec![0.0f64; k];
        let mut mu = vec![0.0f64; k * d];
        for i in 0..n {
            let w_i = point_weights.map(|w| w[i] as f64).unwrap_or(1.0);
            let x = points.row(i);
            for c in 0..k {
                let r = w_i * resp[i * k + c];
                nk[c] += r;
                for j in 0..d {
                    mu[c * d + j] += r * x[j] as f64;
                }
            }
        }
        for c in 0..k {
            let denom = nk[c].max(1e-12);
            for j in 0..d {
                means.set(c, j, (mu[c * d + j] / denom) as f32);
            }
            mix[c] = nk[c] / wsum;
        }
        let mut var = vec![0.0f64; k * d];
        for i in 0..n {
            let w_i = point_weights.map(|w| w[i] as f64).unwrap_or(1.0);
            let x = points.row(i);
            for c in 0..k {
                let r = w_i * resp[i * k + c];
                for j in 0..d {
                    let diff = x[j] as f64 - means.get(c, j) as f64;
                    var[c * d + j] += r * diff * diff;
                }
            }
        }
        for c in 0..k {
            let denom = nk[c].max(1e-12);
            for j in 0..d {
                variances.set(c, j, (var[c * d + j] / denom).max(config.var_floor) as f32);
            }
        }
        if (ll - prev_ll).abs() < config.tol * ll.abs().max(1.0) {
            prev_ll = ll;
            break;
        }
        prev_ll = ll;
    }

    let assignments: Vec<u32> = (0..n)
        .map(|i| {
            (0..k)
                .max_by(|&a, &b| resp[i * k + a].partial_cmp(&resp[i * k + b]).unwrap())
                .unwrap() as u32
        })
        .collect();
    Ok(GmmResult {
        assignments,
        weights: mix,
        means,
        variances,
        log_likelihood: prev_ll,
        iterations,
    })
}

/// k-means++-style seeding reused for the EM means.
fn init_means(points: &Matrix, k: usize, rng: &mut Xoshiro256) -> Matrix {
    let n = points.rows();
    let mut chosen = vec![rng.next_below(n as u64) as usize];
    let mut d2: Vec<f32> = (0..n)
        .map(|i| crate::linalg::sq_dist(points.row(i), points.row(chosen[0])))
        .collect();
    while chosen.len() < k {
        let total: f64 = d2.iter().map(|&v| v as f64).sum();
        let next = if total <= 0.0 {
            rng.next_below(n as u64) as usize
        } else {
            let mut target = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &v) in d2.iter().enumerate() {
                target -= v as f64;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        chosen.push(next);
        for i in 0..n {
            let d = crate::linalg::sq_dist(points.row(i), points.row(next));
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    points.select_rows(&chosen)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::metrics;

    #[test]
    fn recovers_paper_mixture_parameters() {
        let ds = gaussian_mixture_paper(20_000, 121);
        let fit = gmm(&ds.points, None, &GmmConfig::new(3)).unwrap();
        // Mixture weights ≈ (0.5, 0.3, 0.2) in some order.
        let mut w = fit.weights.clone();
        w.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!((w[0] - 0.5).abs() < 0.05, "{w:?}");
        assert!((w[1] - 0.3).abs() < 0.05, "{w:?}");
        assert!((w[2] - 0.2).abs() < 0.05, "{w:?}");
        // Some component mean ≈ (7, 8) (the well-separated one).
        let found = (0..3).any(|c| {
            (fit.means.get(c, 0) - 7.0).abs() < 0.3 && (fit.means.get(c, 1) - 8.0).abs() < 0.3
        });
        assert!(found, "{:?}", fit.means);
    }

    #[test]
    fn accuracy_at_least_kmeans_level() {
        let ds = gaussian_mixture_paper(8_000, 122);
        let fit = gmm(&ds.points, None, &GmmConfig::new(3)).unwrap();
        let acc =
            metrics::prediction_accuracy(ds.labels.as_ref().unwrap(), &fit.assignments).unwrap();
        // GMM is the true model family → should beat the ~0.92 k-means band.
        assert!(acc > 0.90, "{acc}");
    }

    #[test]
    fn log_likelihood_monotone_enough() {
        // EM's ll must not decrease between a 5-iter and 50-iter run.
        let ds = gaussian_mixture_paper(2_000, 123);
        let short = gmm(&ds.points, None, &GmmConfig { max_iters: 5, ..GmmConfig::new(3) }).unwrap();
        let long = gmm(&ds.points, None, &GmmConfig { max_iters: 50, ..GmmConfig::new(3) }).unwrap();
        assert!(long.log_likelihood >= short.log_likelihood - 1e-9);
    }

    #[test]
    fn weighted_fit_matches_replication() {
        let ds = gaussian_mixture_paper(120, 124);
        let weights: Vec<f32> = (0..120).map(|i| 1.0 + (i % 3) as f32).collect();
        let mut rep_rows = Vec::new();
        for (i, &w) in weights.iter().enumerate() {
            for _ in 0..w as usize {
                rep_rows.push(i);
            }
        }
        let replicated = ds.points.select_rows(&rep_rows);
        let a = gmm(&ds.points, Some(&weights), &GmmConfig::new(2)).unwrap();
        let b = gmm(&replicated, None, &GmmConfig::new(2)).unwrap();
        assert!(
            (a.log_likelihood - b.log_likelihood).abs() < 0.05 * b.log_likelihood.abs().max(1.0),
            "{} vs {}",
            a.log_likelihood,
            b.log_likelihood
        );
    }

    #[test]
    fn degenerate_inputs() {
        let m = Matrix::from_vec(vec![1.0, 1.0, 1.0, 1.0], 2, 2).unwrap();
        // Identical points: variance floor must keep EM finite.
        let fit = gmm(&m, None, &GmmConfig::new(1)).unwrap();
        assert!(fit.log_likelihood.is_finite());
        assert!(gmm(&m, None, &GmmConfig::new(0)).is_err());
        assert!(gmm(&m, None, &GmmConfig::new(3)).is_err());
        assert!(gmm(&m, Some(&[1.0]), &GmmConfig::new(1)).is_err());
        assert!(gmm(&m, Some(&[-1.0, 1.0]), &GmmConfig::new(1)).is_err());
    }
}
