//! Elbow selection of the cluster count `k`.
//!
//! §5 of the paper: "The number of classes (k) is chosen by the elbow of
//! plot of within-cluster sum of squared distances for different k."
//! This module automates the visual rule: sweep `k`, fit k-means on each
//! (on a subsample for speed), and pick the point of maximum distance
//! below the chord of the WCSS curve (the discrete "kneedle" criterion —
//! the same rule `dbscan::estimate_params` uses for ε).

use super::kmeans::{kmeans, KMeansConfig};
use crate::linalg::Matrix;
use crate::rng::Xoshiro256;
use crate::{Error, Result};

/// One point of the sweep.
#[derive(Clone, Debug)]
pub struct ElbowPoint {
    /// Number of clusters.
    pub k: usize,
    /// Within-cluster sum of squares at that k.
    pub wcss: f64,
}

/// Result of an elbow sweep.
#[derive(Clone, Debug)]
pub struct ElbowResult {
    /// The selected k.
    pub k: usize,
    /// The full curve (for plotting / the paper's figure).
    pub curve: Vec<ElbowPoint>,
}

/// Sweep `k ∈ [k_min, k_max]` and select the elbow.
///
/// `sample` caps the number of points k-means sees per fit (the curve's
/// shape, not its absolute level, determines the elbow).
pub fn select_k(
    points: &Matrix,
    k_min: usize,
    k_max: usize,
    sample: usize,
    seed: u64,
) -> Result<ElbowResult> {
    if k_min < 1 || k_max < k_min {
        return Err(Error::InvalidArgument(format!("bad k range [{k_min}, {k_max}]")));
    }
    let n = points.rows();
    if n < k_max {
        return Err(Error::InvalidArgument(format!("n={n} < k_max={k_max}")));
    }
    let sub = if n > sample {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let idx = rng.sample_indices(n, sample);
        points.select_rows(&idx)
    } else {
        points.clone()
    };
    let mut curve = Vec::with_capacity(k_max - k_min + 1);
    for k in k_min..=k_max {
        let cfg = KMeansConfig { restarts: 3, seed, ..KMeansConfig::new(k) };
        let fit = kmeans(&sub, &cfg)?;
        curve.push(ElbowPoint { k, wcss: fit.wcss });
    }
    // Discrete kneedle on the (k, log wcss) curve. The log matters: raw
    // WCSS curves are steeply convex and the raw chord test fires one or
    // two steps early; in log space the drop at the true k dominates.
    let lw: Vec<f64> = curve.iter().map(|p| p.wcss.max(1e-12).ln()).collect();
    let first_k = curve[0].k as f64;
    let span_k = (curve[curve.len() - 1].k - curve[0].k).max(1) as f64;
    let span_w = lw[0] - lw[lw.len() - 1];
    let mut best = (curve[0].k, f64::NEG_INFINITY);
    for (p, &w) in curve.iter().zip(&lw) {
        let chord = lw[0] - span_w * (p.k as f64 - first_k) / span_k;
        let below = chord - w;
        if below > best.1 {
            best = (p.k, below);
        }
    }
    Ok(ElbowResult { k: best.0, curve })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn blobs(k: usize, per: usize, sep: f32, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut data = Vec::with_capacity(k * per * 2);
        for c in 0..k {
            let cx = (c as f32) * sep;
            let cy = ((c * 7919) % 13) as f32 * sep * 0.3;
            for _ in 0..per {
                data.push(cx + rng.next_gaussian() as f32 * 0.5);
                data.push(cy + rng.next_gaussian() as f32 * 0.5);
            }
        }
        Matrix::from_vec(data, k * per, 2).unwrap()
    }

    #[test]
    fn finds_true_k_on_separated_blobs() {
        for true_k in [3usize, 5] {
            let m = blobs(true_k, 150, 20.0, 42);
            let r = select_k(&m, 1, 9, 2_000, 1).unwrap();
            assert_eq!(r.k, true_k, "curve: {:?}", r.curve);
        }
    }

    #[test]
    fn curve_is_monotone_decreasing_roughly() {
        let m = blobs(4, 100, 15.0, 43);
        let r = select_k(&m, 1, 8, 2_000, 2).unwrap();
        // WCSS never increases by more than noise between consecutive k.
        for w in r.curve.windows(2) {
            assert!(w[1].wcss <= w[0].wcss * 1.05, "{:?}", r.curve);
        }
    }

    #[test]
    fn invalid_ranges_rejected() {
        let m = blobs(2, 20, 10.0, 44);
        assert!(select_k(&m, 0, 5, 100, 1).is_err());
        assert!(select_k(&m, 5, 2, 100, 1).is_err());
        assert!(select_k(&m, 1, 1000, 100, 1).is_err());
    }

    #[test]
    fn subsampling_does_not_change_selection() {
        let m = blobs(3, 400, 25.0, 45);
        let full = select_k(&m, 1, 7, usize::MAX, 3).unwrap();
        let sub = select_k(&m, 1, 7, 300, 3).unwrap();
        assert_eq!(full.k, sub.k);
    }
}
