//! The concurrency facade: every synchronization primitive the crate's
//! parallel layers use, re-exported from one place.
//!
//! Default builds (`cfg(not(loom))`) re-export `std` types verbatim, so
//! this module costs nothing — no wrappers, no indirection, the same
//! codegen as importing `std::sync` directly. Under `--cfg loom` the
//! same names resolve to [loom](https://docs.rs/loom)'s model-checked
//! doubles, which lets the loom scenarios in
//! `rust/src/exec/loom_tests.rs` exhaustively explore the executor's
//! interleavings (claim/execute races, `abort_rest` vs. racing
//! decrements, the wait/notify protocol, lazy spawn, shutdown) instead
//! of relying on whatever schedules the test host happens to produce.
//!
//! Four rules keep the facade meaningful, all enforced by the in-tree
//! determinism lint (`rust/xtask`):
//!
//! * **No raw `std::sync::atomic` imports outside this module.**
//!   Atomics that bypass the facade are invisible to loom and therefore
//!   unverified. The two deliberate exceptions — `memtrack`'s global
//!   allocator counters and `checkpoint`'s spill-name counter — need
//!   const-initialized `static`s (loom's atomics are not const-
//!   constructible, and loom cannot model a global allocator at all);
//!   each carries an inline `det-lint: allow(raw-atomic)` marker with
//!   that argument.
//! * **No `thread::spawn` outside this module.** `exec` and the
//!   pipeline spawn through [`thread::spawn_named`]; threads spawned
//!   anywhere else are scheduling surface the determinism suites never
//!   exercise.
//! * **No `spawn_named` outside this module and `exec`.** With the
//!   executor-native pipeline, parallel work belongs on the shared
//!   team as prioritized batches; a new dedicated stage thread is a
//!   structural regression. The surviving source/sink/reorder spawn
//!   sites in `coordinator/pipeline.rs` each carry a
//!   `det-lint: allow(stage-spawn)` marker stating why the thread is
//!   legitimately not executor work (I/O-bound producer, inherently
//!   sequential sink).
//! * **No `std::sync::mpsc` outside this module's facade story.** The
//!   pipeline's channel endpoints deliberately stay on std — loom has
//!   no mpsc double, and the pipeline is only *compiled*, never
//!   executed, under `--cfg loom` (the loom scenarios model the
//!   executor the stages submit into). The one import site carries a
//!   `det-lint: allow(std-mpsc)` marker with that argument; new mpsc
//!   uses elsewhere must justify themselves the same way.
//!
//! The loom dependency itself is cfg-gated in `rust/Cargo.toml` and
//! points at the in-tree `rust/loom-shim` package (std-backed, same
//! API subset) so offline builds resolve without crates.io; the CI
//! loom job swaps the real model checker in. See README §Verification
//! lanes.

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Atomic types and orderings (std or loom, by `cfg(loom)`).
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
}

/// Thread spawning (std or loom, by `cfg(loom)`).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::JoinHandle;

    #[cfg(loom)]
    pub use loom::thread::JoinHandle;

    /// Spawn a named thread. Under loom the name is dropped (model
    /// threads are anonymous); under std a failed spawn is a panic —
    /// the executor treats thread exhaustion as unrecoverable, exactly
    /// as the retired per-call pools did.
    pub fn spawn_named<F, T>(name: String, f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        #[cfg(not(loom))]
        {
            std::thread::Builder::new()
                .name(name)
                .spawn(f)
                .expect("spawn thread")
        }
        #[cfg(loom)]
        {
            let _ = name;
            loom::thread::spawn(f)
        }
    }

    /// The machine's available parallelism (≥ 1). Loom models run with
    /// a fixed budget of 2 — the model explores interleavings, not
    /// machine sizes.
    pub fn available_parallelism() -> usize {
        #[cfg(not(loom))]
        {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        }
        #[cfg(loom)]
        {
            2
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    #[test]
    fn spawn_named_names_the_thread() {
        let h = super::thread::spawn_named("ihtc-facade-test".to_string(), || {
            std::thread::current().name().map(str::to_string)
        });
        assert_eq!(h.join().unwrap().as_deref(), Some("ihtc-facade-test"));
    }

    #[test]
    fn available_parallelism_at_least_one() {
        assert!(super::thread::available_parallelism() >= 1);
    }
}
