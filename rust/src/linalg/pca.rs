//! Principal component analysis.
//!
//! §5 of the paper uses PCA for feature selection on every real dataset
//! before clustering. Covariate dimensionality there is 5–7, so a dense
//! cyclic Jacobi eigensolver on the covariance matrix is exact, fast, and
//! dependency-free.

use super::Matrix;
use crate::{Error, Result};

/// A fitted PCA transform.
#[derive(Clone, Debug)]
pub struct Pca {
    /// Column means of the fitted data (length `d`).
    pub means: Vec<f64>,
    /// Eigenvalues (variances) sorted descending (length `d`).
    pub eigenvalues: Vec<f64>,
    /// Principal axes, row `c` is the `c`-th component (shape `d × d`,
    /// row-major, sorted to match `eigenvalues`).
    pub components: Vec<Vec<f64>>,
}

impl Pca {
    /// Fit PCA on `data` (covariance of centered columns, Jacobi
    /// eigendecomposition).
    pub fn fit(data: &Matrix) -> Result<Pca> {
        let (n, d) = (data.rows(), data.cols());
        if n < 2 {
            return Err(Error::InvalidArgument("PCA needs at least 2 rows".into()));
        }
        let means = data.col_means();
        // Covariance matrix (d × d), f64 accumulation.
        let mut cov = vec![vec![0.0f64; d]; d];
        for i in 0..n {
            let row = data.row(i);
            for a in 0..d {
                let da = row[a] as f64 - means[a];
                for b in a..d {
                    cov[a][b] += da * (row[b] as f64 - means[b]);
                }
            }
        }
        let denom = (n - 1) as f64;
        for a in 0..d {
            for b in a..d {
                cov[a][b] /= denom;
                cov[b][a] = cov[a][b];
            }
        }
        Ok(Self::from_eigen(means, cov))
    }

    /// Fit from a precomputed `d × d` covariance matrix (row-major) and
    /// the matching column means. The streaming driver derives both
    /// *exactly* from the single-pass cross-moments folded during
    /// ingest, so the resulting basis is the full-data PCA — no second
    /// pass over the source, and no prototype-stream approximation.
    pub fn from_covariance(means: Vec<f64>, cov: &[f64]) -> Result<Pca> {
        let d = means.len();
        if cov.len() != d * d {
            return Err(Error::Shape(format!(
                "covariance has {} entries for d={d} (need d²)",
                cov.len()
            )));
        }
        if d == 0 {
            return Err(Error::InvalidArgument("PCA needs at least 1 column".into()));
        }
        let grid: Vec<Vec<f64>> = (0..d).map(|a| cov[a * d..(a + 1) * d].to_vec()).collect();
        Ok(Self::from_eigen(means, grid))
    }

    /// Shared eigendecompose-and-sort tail of [`Self::fit`] and
    /// [`Self::from_covariance`].
    fn from_eigen(means: Vec<f64>, mut cov: Vec<Vec<f64>>) -> Pca {
        let d = cov.len();
        let (mut eigvals, mut eigvecs) = jacobi_eigen(&mut cov, 100, 1e-12);
        // Sort descending by eigenvalue.
        let mut order: Vec<usize> = (0..d).collect();
        order.sort_by(|&a, &b| eigvals[b].partial_cmp(&eigvals[a]).unwrap());
        eigvals = order.iter().map(|&i| eigvals[i]).collect();
        eigvecs = order.iter().map(|&i| eigvecs[i].clone()).collect();
        Pca { means, eigenvalues: eigvals, components: eigvecs }
    }

    /// Project `data` onto the top `k` components.
    pub fn transform(&self, data: &Matrix, k: usize) -> Result<Matrix> {
        let d = self.means.len();
        if data.cols() != d {
            return Err(Error::Shape(format!(
                "PCA fitted on d={d}, got d={}",
                data.cols()
            )));
        }
        let k = k.min(d);
        let mut out = Matrix::zeros(data.rows(), k);
        for i in 0..data.rows() {
            let row = data.row(i);
            for c in 0..k {
                let mut acc = 0.0f64;
                for j in 0..d {
                    acc += (row[j] as f64 - self.means[j]) * self.components[c][j];
                }
                out.set(i, c, acc as f32);
            }
        }
        Ok(out)
    }

    /// Smallest `k` whose cumulative explained-variance ratio ≥ `frac`.
    pub fn components_for_variance(&self, frac: f64) -> usize {
        let total: f64 = self.eigenvalues.iter().map(|v| v.max(0.0)).sum();
        if total <= 0.0 {
            return self.eigenvalues.len();
        }
        let mut cum = 0.0;
        for (i, v) in self.eigenvalues.iter().enumerate() {
            cum += v.max(0.0);
            if cum / total >= frac {
                return i + 1;
            }
        }
        self.eigenvalues.len()
    }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix (in place).
/// Returns `(eigenvalues, eigenvectors)` where eigenvector `i` is a row.
fn jacobi_eigen(a: &mut [Vec<f64>], max_sweeps: usize, tol: f64) -> (Vec<f64>, Vec<Vec<f64>>) {
    let d = a.len();
    // v starts as identity; columns accumulate the rotations.
    let mut v = vec![vec![0.0f64; d]; d];
    for (i, row) in v.iter_mut().enumerate() {
        row[i] = 1.0;
    }
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0;
        for p in 0..d {
            for q in (p + 1)..d {
                off += a[p][q] * a[p][q];
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                if a[p][q].abs() <= 1e-300 {
                    continue;
                }
                let theta = (a[q][q] - a[p][p]) / (2.0 * a[p][q]);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for k in 0..d {
                    let akp = a[k][p];
                    let akq = a[k][q];
                    a[k][p] = c * akp - s * akq;
                    a[k][q] = s * akp + c * akq;
                }
                for k in 0..d {
                    let apk = a[p][k];
                    let aqk = a[q][k];
                    a[p][k] = c * apk - s * aqk;
                    a[q][k] = s * apk + c * aqk;
                }
                for row in v.iter_mut() {
                    let vp = row[p];
                    let vq = row[q];
                    row[p] = c * vp - s * vq;
                    row[q] = s * vp + c * vq;
                }
            }
        }
    }
    let eigvals: Vec<f64> = (0..d).map(|i| a[i][i]).collect();
    // Transpose v: eigenvector i (for eigenvalue i) as a row.
    let eigvecs: Vec<Vec<f64>> = (0..d).map(|i| (0..d).map(|j| v[j][i]).collect()).collect();
    (eigvals, eigvecs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn identity_covariance() {
        // Isotropic data → eigenvalues all ≈ 1 after standardization.
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 20_000;
        let data: Vec<f32> = (0..n * 3).map(|_| r.next_gaussian() as f32).collect();
        let m = Matrix::from_vec(data, n, 3).unwrap();
        let pca = Pca::fit(&m).unwrap();
        for &v in &pca.eigenvalues {
            assert!((v - 1.0).abs() < 0.05, "eig={v}");
        }
    }

    #[test]
    fn recovers_dominant_direction() {
        // Data along (1, 1)/√2 with small noise: first component ≈ that axis.
        let mut r = Xoshiro256::seed_from_u64(12);
        let n = 5_000;
        let mut data = Vec::with_capacity(n * 2);
        for _ in 0..n {
            let t = r.next_gaussian() * 5.0;
            let e1 = r.next_gaussian() * 0.1;
            let e2 = r.next_gaussian() * 0.1;
            data.push((t + e1) as f32);
            data.push((t + e2) as f32);
        }
        let m = Matrix::from_vec(data, n, 2).unwrap();
        let pca = Pca::fit(&m).unwrap();
        assert!(pca.eigenvalues[0] > 20.0 * pca.eigenvalues[1]);
        let c = &pca.components[0];
        let inv_sqrt2 = std::f64::consts::FRAC_1_SQRT_2;
        assert!((c[0].abs() - inv_sqrt2).abs() < 0.02, "{c:?}");
        assert!((c[1].abs() - inv_sqrt2).abs() < 0.02, "{c:?}");
    }

    #[test]
    fn transform_decorrelates() {
        let mut r = Xoshiro256::seed_from_u64(13);
        let n = 4_000;
        let mut data = Vec::with_capacity(n * 2);
        for _ in 0..n {
            let x = r.next_gaussian();
            let y = 0.8 * x + 0.2 * r.next_gaussian();
            data.push(x as f32);
            data.push(y as f32);
        }
        let m = Matrix::from_vec(data, n, 2).unwrap();
        let pca = Pca::fit(&m).unwrap();
        let t = pca.transform(&m, 2).unwrap();
        // Empirical covariance of the projected data should be ~diagonal.
        let mut cov01 = 0.0f64;
        for i in 0..n {
            cov01 += t.get(i, 0) as f64 * t.get(i, 1) as f64;
        }
        cov01 /= (n - 1) as f64;
        assert!(cov01.abs() < 0.02, "cov01={cov01}");
    }

    #[test]
    fn explained_variance_selection() {
        let mut r = Xoshiro256::seed_from_u64(14);
        let n = 3_000;
        let mut data = Vec::with_capacity(n * 3);
        for _ in 0..n {
            data.push((r.next_gaussian() * 10.0) as f32);
            data.push(r.next_gaussian() as f32);
            data.push((r.next_gaussian() * 0.01) as f32);
        }
        let m = Matrix::from_vec(data, n, 3).unwrap();
        let pca = Pca::fit(&m).unwrap();
        assert_eq!(pca.components_for_variance(0.95), 1);
        assert_eq!(pca.components_for_variance(0.9999), 2);
    }

    #[test]
    fn from_covariance_matches_fit() {
        // Build the sample covariance by hand from the raw cross-moments
        // (the streaming driver's formula) and check the basis equals a
        // direct fit on the data, up to eigenvector sign.
        let mut r = Xoshiro256::seed_from_u64(15);
        let n = 4_000usize;
        let d = 3usize;
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            let x = r.next_gaussian() * 4.0;
            let y = 0.6 * x + r.next_gaussian();
            let z = r.next_gaussian() * 0.3;
            data.extend_from_slice(&[x as f32, y as f32, z as f32]);
        }
        let m = Matrix::from_vec(data, n, d).unwrap();
        let direct = Pca::fit(&m).unwrap();
        // Cross-moments Σxᵢxⱼ and sums, f64 (what Moments folds).
        let mut sum = vec![0.0f64; d];
        let mut cross = vec![0.0f64; d * d];
        for i in 0..n {
            let row = m.row(i);
            for a in 0..d {
                sum[a] += row[a] as f64;
                for b in 0..d {
                    cross[a * d + b] += row[a] as f64 * row[b] as f64;
                }
            }
        }
        let means: Vec<f64> = sum.iter().map(|s| s / n as f64).collect();
        let mut cov = vec![0.0f64; d * d];
        for a in 0..d {
            for b in 0..d {
                cov[a * d + b] =
                    (cross[a * d + b] - n as f64 * means[a] * means[b]) / (n as f64 - 1.0);
            }
        }
        let streamed = Pca::from_covariance(means, &cov).unwrap();
        for (ev_a, ev_b) in direct.eigenvalues.iter().zip(&streamed.eigenvalues) {
            assert!((ev_a - ev_b).abs() < 1e-6 * (1.0 + ev_a.abs()), "{ev_a} vs {ev_b}");
        }
        for (ca, cb) in direct.components.iter().zip(&streamed.components) {
            let dot: f64 = ca.iter().zip(cb).map(|(x, y)| x * y).sum();
            assert!((dot.abs() - 1.0).abs() < 1e-6, "components differ: |dot|={}", dot.abs());
        }
    }

    #[test]
    fn from_covariance_rejects_bad_shapes() {
        assert!(Pca::from_covariance(vec![0.0; 2], &[0.0; 3]).is_err());
        assert!(Pca::from_covariance(Vec::new(), &[]).is_err());
    }

    #[test]
    fn transform_shape_error() {
        let m = Matrix::from_vec(vec![0.0; 8], 4, 2).unwrap();
        let pca = Pca::fit(&m).unwrap();
        let bad = Matrix::from_vec(vec![0.0; 9], 3, 3).unwrap();
        assert!(pca.transform(&bad, 2).is_err());
    }
}
