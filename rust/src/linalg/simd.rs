//! Pinned SIMD distance kernels with one-time runtime dispatch.
//!
//! The scalar kernels in [`super`] are unrolled for auto-vectorization
//! but not pinned to a target feature set — whether they actually emit
//! vector code depends on the default target. This module pins them:
//! behind the `simd` cargo feature it provides explicit `std::arch`
//! x86_64 AVX2/FMA implementations of the squared-distance and dot
//! kernels, selected **once per process** by [`kernels`] so the hot
//! loops carry a plain function-pointer call and no per-call detection
//! branch (hot loops hoist the pointer via [`sq_dist_kernel`] /
//! [`dot_kernel`] and pay nothing per element).
//!
//! Dispatch rules, in order:
//!
//! 1. Feature `simd` off → this module only re-exports the scalar
//!    kernels; no detection code is compiled and every output byte
//!    matches the unfeatured build by construction.
//! 2. `IHTC_FORCE_SCALAR=1` (any value but `0`) → scalar fallback even
//!    when the feature and the CPU support AVX2. This is the lane CI's
//!    `kernels` job uses to cover the detection branch itself.
//! 3. `is_x86_feature_detected!("avx2")` + `fma` on x86_64 → the AVX2
//!    kernels; anything else → scalar fallback.
//!
//! ## FP-ordering contract
//!
//! The AVX2 kernels reassociate the reduction (8 partial sums + FMA
//! instead of the scalar kernel's 4 partial sums and separate
//! multiply/add), so with the SIMD kernels active, distances may differ
//! from scalar by a few ULP. Everything downstream is built on total
//! orders over the *computed* values (`(distance, index)` in k-NN,
//! strict argmin in k-means), so each kernel choice is individually
//! deterministic: same build + same `IHTC_FORCE_SCALAR` setting ⇒ same
//! output bytes for any worker count. Byte parity *across* kernel
//! choices is deliberately not promised — `rust/tests/kernel_parity.rs`
//! pins the bounded-ULP tolerance contract instead. Dimensions below
//! [`super::SIMD_MIN_DIM`] never enter the vector loop, so the paper's
//! post-PCA small-dimension fast paths stay byte-equal to scalar even
//! with SIMD active.

use super::{dot_scalar, sq_dist_scalar};

/// A distance-kernel entry point: two equal-length rows in, one f32 out.
pub type KernelFn = fn(&[f32], &[f32]) -> f32;

/// The resolved kernel set for this process.
#[derive(Clone, Copy, Debug)]
pub struct Kernels {
    /// Squared Euclidean distance (the [`super::sq_dist`] hot path).
    pub sq_dist: KernelFn,
    /// Dot product (the norm-trick kernel in `knn::NativeChunks`).
    pub dot: KernelFn,
    /// True when the AVX2/FMA implementations are installed.
    pub simd: bool,
}

/// The always-available scalar kernel set (bit-for-bit the unfeatured
/// build's arithmetic).
pub static SCALAR: Kernels = Kernels { sq_dist: sq_dist_scalar, dot: dot_scalar, simd: false };

/// The process-wide kernel set. Without the `simd` feature this is a
/// zero-cost reference to [`SCALAR`].
#[cfg(not(feature = "simd"))]
#[inline]
pub fn kernels() -> &'static Kernels {
    &SCALAR
}

/// The process-wide kernel set, resolved once on first use (runtime
/// CPU detection + the `IHTC_FORCE_SCALAR` override) and then a plain
/// pointer load. Hot loops should hoist the function pointers via
/// [`sq_dist_kernel`] / [`dot_kernel`] so not even this load sits in
/// the inner loop.
#[cfg(feature = "simd")]
pub fn kernels() -> &'static Kernels {
    static KERNELS: std::sync::OnceLock<Kernels> = std::sync::OnceLock::new();
    KERNELS.get_or_init(resolve)
}

/// One-time dispatch decision (see the module docs for the rules). The
/// env read happens once per process, before any kernel runs — it is a
/// build-configuration input like the cargo feature itself, not a
/// mid-run nondeterminism source.
#[cfg(feature = "simd")]
fn resolve() -> Kernels {
    if std::env::var_os("IHTC_FORCE_SCALAR").is_some_and(|v| v != "0") {
        return SCALAR;
    }
    #[cfg(target_arch = "x86_64")]
    if std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma") {
        return Kernels { sq_dist: x86::sq_dist_avx2, dot: x86::dot_avx2, simd: true };
    }
    SCALAR
}

/// The resolved squared-distance kernel as a bare function pointer —
/// hoist this out of hot loops so each call is a direct indirect call
/// with no dispatch logic at all.
#[inline]
pub fn sq_dist_kernel() -> KernelFn {
    kernels().sq_dist
}

/// The resolved dot-product kernel as a bare function pointer (the
/// norm-trick inner loop in `knn::NativeChunks` hoists this per block).
#[inline]
pub fn dot_kernel() -> KernelFn {
    kernels().dot
}

/// Whether the AVX2/FMA kernels are active in this process. False when
/// the feature is off, the CPU lacks AVX2/FMA, or `IHTC_FORCE_SCALAR`
/// is set — the parity tests branch their tolerance contract on this.
#[inline]
pub fn active() -> bool {
    kernels().simd
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use super::super::{dot_scalar, sq_dist_scalar, SIMD_MIN_DIM};
    use core::arch::x86_64::{
        __m256, _mm256_castps256_ps128, _mm256_extractf128_ps, _mm256_fmadd_ps,
        _mm256_loadu_ps, _mm256_setzero_ps, _mm256_sub_ps, _mm_add_ps, _mm_add_ss,
        _mm_cvtss_f32, _mm_movehl_ps, _mm_shuffle_ps,
    };

    /// Horizontal sum of an 8-lane register: lanes are reduced pairwise
    /// (hi half + lo half, then within the 128-bit half), one fixed
    /// association per call — deterministic, like every kernel here.
    ///
    /// # Safety
    /// AVX2 must be available; callers are themselves
    /// `#[target_feature(enable = "avx2")]` fns reached only through
    /// the dispatcher's runtime detection.
    #[target_feature(enable = "avx2")]
    fn hsum256(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps(v, 1);
        let lo = _mm256_castps256_ps128(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 0b01));
        _mm_cvtss_f32(s)
    }

    /// AVX2/FMA squared Euclidean distance. Dimensions below
    /// [`SIMD_MIN_DIM`] delegate to the scalar kernel so the small-dim
    /// fast paths stay byte-equal to the scalar build; the vector body
    /// accumulates 8 lanes with FMA and handles the tail scalar-wise.
    ///
    /// # Safety
    /// AVX2 + FMA must be available. This fn is reached only through
    /// [`sq_dist_avx2`], whose pointer the dispatcher installs after
    /// `is_x86_feature_detected!` confirms both features.
    #[target_feature(enable = "avx2", enable = "fma")]
    fn sq_dist_avx2_inner(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        if n < SIMD_MIN_DIM {
            return sq_dist_scalar(a, b);
        }
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            // SAFETY: i + 8 ≤ n bounds both unaligned 8-float loads
            // inside their slices.
            let (va, vb) = unsafe {
                (_mm256_loadu_ps(a.as_ptr().add(i)), _mm256_loadu_ps(b.as_ptr().add(i)))
            };
            let d = _mm256_sub_ps(va, vb);
            acc = _mm256_fmadd_ps(d, d, acc);
            i += 8;
        }
        let mut s = hsum256(acc);
        while i < n {
            let d = a[i] - b[i];
            s += d * d;
            i += 1;
        }
        s
    }

    /// AVX2/FMA dot product (norm-trick inner loop); same structure and
    /// dispatch contract as [`sq_dist_avx2_inner`].
    ///
    /// # Safety
    /// AVX2 + FMA must be available — see [`sq_dist_avx2_inner`].
    #[target_feature(enable = "avx2", enable = "fma")]
    fn dot_avx2_inner(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        if n < SIMD_MIN_DIM {
            return dot_scalar(a, b);
        }
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            // SAFETY: i + 8 ≤ n bounds both unaligned 8-float loads
            // inside their slices.
            let (va, vb) = unsafe {
                (_mm256_loadu_ps(a.as_ptr().add(i)), _mm256_loadu_ps(b.as_ptr().add(i)))
            };
            acc = _mm256_fmadd_ps(va, vb, acc);
            i += 8;
        }
        let mut s = hsum256(acc);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// Plain-`fn` wrapper in `KernelFn` shape over the target-feature fn.
    pub(super) fn sq_dist_avx2(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: this symbol is only reachable through the Kernels
        // pointer the dispatcher installs after runtime detection of
        // AVX2 + FMA, so the required target features are present.
        unsafe { sq_dist_avx2_inner(a, b) }
    }

    /// Plain-`fn` wrapper in `KernelFn` shape over the target-feature fn.
    pub(super) fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: as for `sq_dist_avx2` — the dispatcher's runtime
        // detection is the precondition proof.
        unsafe { dot_avx2_inner(a, b) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_set_is_always_available() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let b = [9.0f32, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!((SCALAR.sq_dist)(&a, &b), sq_dist_scalar(&a, &b));
        assert_eq!((SCALAR.dot)(&a, &b), dot_scalar(&a, &b));
        assert!(!SCALAR.simd);
    }

    #[test]
    fn dispatched_kernels_match_scalar_within_tolerance() {
        // Under the scalar lanes this is byte equality; with AVX2 active
        // it is the bounded-ULP contract (see kernel_parity.rs for the
        // exhaustive dim sweep).
        let a: Vec<f32> = (0..33).map(|i| (i as f32) * 0.37 - 3.0).collect();
        let b: Vec<f32> = (0..33).map(|i| (33 - i) as f32 * 0.21).collect();
        let (ks, kd) = ((kernels().sq_dist)(&a, &b), sq_dist_scalar(&a, &b));
        if active() {
            assert!((ks - kd).abs() <= 1e-5 * (1.0 + kd.abs()), "{ks} vs {kd}");
        } else {
            assert_eq!(ks.to_bits(), kd.to_bits());
        }
    }

    #[test]
    fn small_dims_byte_equal_under_every_kernel() {
        // d < SIMD_MIN_DIM never enters the vector body.
        for d in 1..super::super::SIMD_MIN_DIM {
            let a: Vec<f32> = (0..d).map(|i| i as f32 * 0.5 + 0.25).collect();
            let b: Vec<f32> = (0..d).map(|i| (d - i) as f32 * 0.125).collect();
            assert_eq!(
                (kernels().sq_dist)(&a, &b).to_bits(),
                sq_dist_scalar(&a, &b).to_bits(),
                "d={d}"
            );
            assert_eq!((kernels().dot)(&a, &b).to_bits(), dot_scalar(&a, &b).to_bits(), "d={d}");
        }
    }
}
