//! Dense linear-algebra substrate: row-major `f32` matrices, distance
//! kernels, and summary statistics.
//!
//! Every clustering algorithm in this crate operates on a [`Matrix`] of
//! `n` rows (units) × `d` columns (covariates). Distances are squared
//! Euclidean unless stated otherwise, matching the paper (§2: "We use
//! Euclidean distance to measure dissimilarity").

pub mod pca;
pub mod simd;

use crate::{Error, Result};

// ── Dimensionality-regime constants ─────────────────────────────────────
//
// One shared home for every "which kernel/backend at which d" threshold,
// so the scalar fast paths below, the SIMD dispatcher (`simd`), the k-NN
// backend chooser (`knn::kdtree_regime` / the norm-trick predicate), and
// the doc comments can never disagree about the regime boundaries.

/// Largest dimensionality served by the hand-written small-`d` fast
/// paths in [`sq_dist_scalar`] (the paper's post-PCA regime, §5:
/// d ∈ 2..7, bottoms out at 2–3 after PCA on the evaluated datasets).
pub const SMALL_DIM_MAX: usize = 3;

/// Minimum dimensionality at which the blocked norm-trick
/// (`‖q‖² + ‖r‖² − 2 q·r`) kernel beats plain per-pair [`sq_dist`] in
/// the chunked k-NN evaluator.
pub const NORM_TRICK_MIN_DIM: usize = 4;

/// Largest dimensionality at which kd-tree pruning still wins over
/// brute force (curse of dimensionality; see `knn::kdtree_regime`).
pub const KDTREE_MAX_DIM: usize = 12;

/// Minimum row count for the kd-tree/forest backend to be worth its
/// build cost (below this, brute force wins; see `knn::kdtree_regime`).
pub const KDTREE_MIN_ROWS: usize = 256;

/// Minimum dimensionality at which the AVX2 kernels ([`simd`]) use the
/// 8-lane vector body. Below this they delegate to the scalar kernels,
/// so the small-`d` fast paths stay byte-equal under every dispatch.
pub const SIMD_MIN_DIM: usize = 8;

/// A dense, row-major matrix of `f32` values.
///
/// Row `i` is the covariate vector of unit `i`. The layout is chosen so a
/// row is a contiguous `&[f32]`, which is what the distance kernels, the
/// PJRT tile packers, and the CSV writer all want.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// Create a matrix from a flat row-major buffer.
    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "buffer has {} elements, expected {rows}x{cols}={}",
                data.len(),
                rows * cols
            )));
        }
        Ok(Self { data, rows, cols })
    }

    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Number of rows (units).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns (features).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a contiguous slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row access.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Flat row-major data.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Gather a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }

    /// Vertical slice `[start, end)` of rows (copied).
    pub fn slice_rows(&self, start: usize, end: usize) -> Matrix {
        assert!(start <= end && end <= self.rows);
        Matrix {
            data: self.data[start * self.cols..end * self.cols].to_vec(),
            rows: end - start,
            cols: self.cols,
        }
    }

    /// Per-column mean.
    pub fn col_means(&self) -> Vec<f64> {
        let mut means = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for (m, &v) in means.iter_mut().zip(self.row(i)) {
                *m += v as f64;
            }
        }
        let n = self.rows.max(1) as f64;
        for m in &mut means {
            *m /= n;
        }
        means
    }

    /// Per-column (population) standard deviation.
    pub fn col_stds(&self) -> Vec<f64> {
        let means = self.col_means();
        let mut vars = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for ((v, &m), &x) in vars.iter_mut().zip(&means).zip(self.row(i)) {
                let d = x as f64 - m;
                *v += d * d;
            }
        }
        let n = self.rows.max(1) as f64;
        vars.iter().map(|v| (v / n).sqrt()).collect()
    }

    /// The grand centroid (mean row).
    pub fn centroid(&self) -> Vec<f32> {
        self.col_means().iter().map(|&m| m as f32).collect()
    }
}

/// Squared Euclidean distance between two feature vectors — the
/// innermost loop of the whole system (k-NN graph construction, k-means
/// assignment, HAC linkage).
///
/// Without the `simd` feature this *is* [`sq_dist_scalar`]; with it,
/// each call goes through the process-wide kernel set resolved once by
/// [`simd::kernels`] (hot loops should hoist [`simd::sq_dist_kernel`]
/// instead so not even that load repeats per pair).
#[cfg(not(feature = "simd"))]
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    sq_dist_scalar(a, b)
}

/// Squared Euclidean distance, dispatched through the resolved kernel
/// set (see the `cfg(not(feature = "simd"))` twin for the contract).
#[cfg(feature = "simd")]
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    (simd::kernels().sq_dist)(a, b)
}

/// Scalar squared Euclidean distance kernel.
///
/// Unrolled-by-4 accumulation, kept branch-free and auto-vectorizable.
/// This is the reference implementation every other kernel is measured
/// against: the `simd` dispatcher falls back to it, and sub-
/// [`SIMD_MIN_DIM`] inputs use it verbatim even with AVX2 active.
#[inline]
pub fn sq_dist_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    // Fast paths for dimensionalities up to SMALL_DIM_MAX — the paper's
    // post-PCA regime (§5 reduces to a handful of components; the
    // evaluated datasets bottom out at d = 2..3). The generic unrolled
    // loop below costs a division and two loop setups that dominate at
    // d = 2. The norm-trick/kd-tree boundaries for larger d live beside
    // SMALL_DIM_MAX at the top of this module.
    if n == 2 {
        let d0 = a[0] - b[0];
        let d1 = a[1] - b[1];
        return d0 * d0 + d1 * d1;
    }
    if n == 3 {
        let d0 = a[0] - b[0];
        let d1 = a[1] - b[1];
        let d2 = a[2] - b[2];
        return d0 * d0 + d1 * d1 + d2 * d2;
    }
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        let d0 = a[i] - b[i];
        let d1 = a[i + 1] - b[i + 1];
        let d2 = a[i + 2] - b[i + 2];
        let d3 = a[i + 3] - b[i + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// Scalar dot-product kernel — the norm-trick inner loop.
///
/// Plain sequential accumulation, bit-identical to the historical
/// inline `for (x, y) in q.iter().zip(r) { dot += x * y }` loops it
/// replaces (in [`pairwise_sq_dists`] and `knn::NativeChunks`), so
/// featureless builds stay byte-for-byte unchanged.
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut dot = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        dot += x * y;
    }
    dot
}

/// Euclidean distance.
#[inline]
pub fn dist(a: &[f32], b: &[f32]) -> f32 {
    sq_dist(a, b).sqrt()
}

/// Squared L2 norm.
#[inline]
pub fn sq_norm(a: &[f32]) -> f32 {
    a.iter().map(|&x| x * x).sum()
}

/// `out[i][j] = ||q_i - r_j||²` for a block of queries × references —
/// the pure-Rust mirror of the L1 Pallas kernel (`pairwise.py`), used as
/// the native fallback path and as the oracle in cross-validation tests
/// against the PJRT artifacts.
///
/// Uses the same `‖q‖² + ‖r‖² − 2 q·r` decomposition as the kernel so the
/// two paths agree bit-for-bit up to standard float reassociation.
pub fn pairwise_sq_dists(queries: &Matrix, refs: &Matrix, out: &mut [f32]) {
    assert_eq!(queries.cols(), refs.cols());
    assert_eq!(out.len(), queries.rows() * refs.rows());
    let (nq, nr) = (queries.rows(), refs.rows());
    // One dispatch for the whole block — no per-pair kernel lookup.
    let dot = simd::dot_kernel();
    let rnorms: Vec<f32> = (0..nr).map(|j| sq_norm(refs.row(j))).collect();
    for i in 0..nq {
        let q = queries.row(i);
        let qn = sq_norm(q);
        let row = &mut out[i * nr..(i + 1) * nr];
        for (j, slot) in row.iter_mut().enumerate() {
            // Clamp: catastrophic cancellation can produce tiny negatives.
            *slot = (qn + rnorms[j] - 2.0 * dot(q, refs.row(j))).max(0.0);
        }
    }
}

/// Standardize columns to zero mean / unit variance in place.
/// Columns with zero variance are left centered only.
pub fn standardize(m: &mut Matrix) {
    let means = m.col_means();
    let stds = m.col_stds();
    let cols = m.cols();
    for i in 0..m.rows() {
        let row = m.row_mut(i);
        for j in 0..cols {
            let s = stds[j];
            let centered = row[j] as f64 - means[j];
            row[j] = if s > 1e-12 { (centered / s) as f32 } else { centered as f32 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f32, b: f32, eps: f32) -> bool {
        (a - b).abs() <= eps * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn matrix_roundtrip() {
        let m = Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.get(0, 2), 3.0);
    }

    #[test]
    fn matrix_shape_error() {
        assert!(Matrix::from_vec(vec![1.0; 5], 2, 3).is_err());
    }

    #[test]
    fn sq_dist_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.7).collect();
        let b: Vec<f32> = (0..13).map(|i| (13 - i) as f32 * 0.3).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(approx(sq_dist(&a, &b), naive, 1e-6));
    }

    #[test]
    fn sq_dist_zero_on_self() {
        let a = [1.5f32, -2.0, 3.25];
        assert_eq!(sq_dist(&a, &a), 0.0);
    }

    #[test]
    fn dot_scalar_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.7).collect();
        let b: Vec<f32> = (0..13).map(|i| (13 - i) as f32 * 0.3).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot_scalar(&a, &b).to_bits(), naive.to_bits());
    }

    #[test]
    fn regime_constants_are_ordered() {
        // The regimes must tile without overlap: small-dim fast paths,
        // then norm-trick, then the SIMD vector body; kd-tree sits on
        // top of the norm-trick range.
        assert_eq!(SMALL_DIM_MAX + 1, NORM_TRICK_MIN_DIM);
        assert!(NORM_TRICK_MIN_DIM <= SIMD_MIN_DIM);
        assert!(KDTREE_MAX_DIM >= NORM_TRICK_MIN_DIM);
        assert!(KDTREE_MIN_ROWS > 0);
    }

    #[test]
    fn pairwise_matches_pointwise() {
        let q = Matrix::from_vec(vec![0.0, 0.0, 1.0, 1.0, -1.0, 2.0], 3, 2).unwrap();
        let r = Matrix::from_vec(vec![1.0, 0.0, 0.0, 3.0], 2, 2).unwrap();
        let mut out = vec![0.0f32; 6];
        pairwise_sq_dists(&q, &r, &mut out);
        for i in 0..3 {
            for j in 0..2 {
                let expect = sq_dist(q.row(i), r.row(j));
                assert!(approx(out[i * 2 + j], expect, 1e-5), "{i},{j}");
            }
        }
    }

    #[test]
    fn pairwise_never_negative() {
        // Points far from origin trigger cancellation in ‖q‖²+‖r‖²−2qr.
        let q = Matrix::from_vec(vec![1e4, 1e4], 1, 2).unwrap();
        let r = Matrix::from_vec(vec![1e4, 1e4], 1, 2).unwrap();
        let mut out = vec![0.0f32; 1];
        pairwise_sq_dists(&q, &r, &mut out);
        assert!(out[0] >= 0.0);
    }

    #[test]
    fn col_stats() {
        let m = Matrix::from_vec(vec![1.0, 10.0, 3.0, 20.0], 2, 2).unwrap();
        let means = m.col_means();
        assert_eq!(means, vec![2.0, 15.0]);
        let stds = m.col_stds();
        assert!((stds[0] - 1.0).abs() < 1e-9);
        assert!((stds[1] - 5.0).abs() < 1e-9);
    }

    #[test]
    fn standardize_gives_unit_stats() {
        let mut m = Matrix::from_vec(
            (0..40).map(|i| (i as f32) * 1.7 + 3.0).collect(),
            20,
            2,
        )
        .unwrap();
        standardize(&mut m);
        let means = m.col_means();
        let stds = m.col_stds();
        for j in 0..2 {
            assert!(means[j].abs() < 1e-6);
            assert!((stds[j] - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn standardize_constant_column() {
        let mut m = Matrix::from_vec(vec![5.0, 1.0, 5.0, 2.0, 5.0, 3.0], 3, 2).unwrap();
        standardize(&mut m);
        for i in 0..3 {
            assert_eq!(m.get(i, 0), 0.0); // centered, not divided
        }
    }

    #[test]
    fn select_and_slice_rows() {
        let m = Matrix::from_vec((0..12).map(|x| x as f32).collect(), 4, 3).unwrap();
        let s = m.select_rows(&[3, 0]);
        assert_eq!(s.row(0), &[9.0, 10.0, 11.0]);
        assert_eq!(s.row(1), &[0.0, 1.0, 2.0]);
        let sl = m.slice_rows(1, 3);
        assert_eq!(sl.rows(), 2);
        assert_eq!(sl.row(0), &[3.0, 4.0, 5.0]);
    }
}
