//! Threshold clustering (TC) — the paper's core primitive (§2.3).
//!
//! TC partitions `n` units into clusters of **at least** `t*` units while
//! 4-approximating the bottleneck threshold partitioning problem (BTPP,
//! eq. 2): the maximum within-cluster dissimilarity is at most `4λ` where
//! `λ` is the optimum (Higgins, Sävje & Sekhon 2016). The algorithm:
//!
//! 1. build the `(t*−1)`-nearest-neighbor subgraph `NG` (Definition 6);
//! 2. greedily choose **seeds**: a maximal set with no walk of length ≤ 2
//!    between any two seeds (a maximal independent set of `NG²`);
//! 3. grow a cluster around each seed from its adjacent vertices;
//! 4. attach every remaining vertex (all are within two walks of a seed)
//!    to the candidate seed with the smallest dissimilarity `d_{ℓj}`.
//!
//! Outside of k-NN construction this runs in `O(t*·n)` time and space.
//!
//! The module is deliberately graph-first: [`threshold_cluster_graph`]
//! takes a prebuilt [`NeighborGraph`] so the coordinator can construct
//! the graph with sharded/PJRT k-NN and reuse it, while
//! [`threshold_cluster`] is the one-call convenience path.

pub mod refine;

use crate::knn::graph::NeighborGraph;
use crate::knn::{knn_auto, KnnLists};
use crate::linalg::{sq_dist, Matrix};
use crate::{Error, Result};

/// Order in which vertices are considered for seed selection (step 2).
/// Higgins et al. note seed selection is a quality lever; the ablation
/// bench compares these.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeedOrder {
    /// Input order — fastest, fully deterministic.
    Natural,
    /// Lowest-degree vertices first (tends to produce more seeds, i.e.
    /// more and smaller clusters).
    DegreeAscending,
    /// Highest-degree first (fewer, larger clusters).
    DegreeDescending,
}

/// Configuration for one TC invocation.
#[derive(Clone, Debug)]
pub struct TcConfig {
    /// Minimum cluster size `t*` (≥ 2; `1` returns singletons).
    pub threshold: usize,
    /// Seed-selection order.
    pub seed_order: SeedOrder,
}

impl TcConfig {
    /// Default configuration for a given threshold.
    pub fn new(threshold: usize) -> Self {
        Self { threshold, seed_order: SeedOrder::Natural }
    }
}

/// Result of a TC run.
#[derive(Clone, Debug)]
pub struct TcResult {
    /// Cluster id per unit, `0..num_clusters`.
    pub assignments: Vec<u32>,
    /// Number of clusters formed.
    pub num_clusters: usize,
    /// The seed unit of each cluster (index parallel to cluster id).
    pub seeds: Vec<u32>,
}

/// One-call TC: builds the `(t*−1)`-NN graph with the best exact backend
/// and clusters.
pub fn threshold_cluster(points: &Matrix, config: &TcConfig) -> Result<TcResult> {
    let n = points.rows();
    let t = config.threshold;
    if t <= 1 {
        // Degenerate: every unit its own cluster.
        return Ok(TcResult {
            assignments: (0..n as u32).collect(),
            num_clusters: n,
            seeds: (0..n as u32).collect(),
        });
    }
    if n <= t {
        // Cannot form two clusters: everything in one.
        return Ok(TcResult { assignments: vec![0; n], num_clusters: usize::from(n > 0), seeds: if n > 0 { vec![0] } else { vec![] } });
    }
    let knn = knn_auto(points, t - 1)?;
    let graph = NeighborGraph::from_knn(&knn);
    Ok(threshold_cluster_graph(&graph, points, config))
}

/// TC over a prebuilt `(t*−1)`-NN graph. `points` is only used to break
/// ties in step 4 by true dissimilarity `d_{ℓj}`.
pub fn threshold_cluster_graph(
    graph: &NeighborGraph,
    points: &Matrix,
    config: &TcConfig,
) -> TcResult {
    let n = graph.len();
    const UNASSIGNED: u32 = u32::MAX;
    let mut assign = vec![UNASSIGNED; n];
    let mut seeds: Vec<u32> = Vec::new();

    // ---- Step 2: greedy maximal independent set of NG². ----
    // `blocked[v]` = v is within a walk of length ≤ 2 of an existing seed.
    let order: Vec<u32> = match config.seed_order {
        SeedOrder::Natural => (0..n as u32).collect(),
        SeedOrder::DegreeAscending | SeedOrder::DegreeDescending => {
            let mut idx: Vec<u32> = (0..n as u32).collect();
            idx.sort_by_key(|&v| {
                let d = graph.degree(v as usize) as i64;
                if config.seed_order == SeedOrder::DegreeAscending { d } else { -d }
            });
            idx
        }
    };
    let mut blocked = vec![false; n];
    for &v in &order {
        let v = v as usize;
        if blocked[v] {
            continue;
        }
        let cluster_id = seeds.len() as u32;
        seeds.push(v as u32);
        blocked[v] = true;
        // ---- Step 3 (fused): grow the cluster from the seed's neighbors,
        // and block everything within two walks so future seeds satisfy
        // the independence condition.
        assign[v] = cluster_id;
        for &u in graph.neighbors(v) {
            let u = u as usize;
            blocked[u] = true;
            // A vertex adjacent to a seed belongs to that seed's cluster;
            // adjacency to two seeds is impossible (their seeds would be
            // two walks apart).
            assign[u] = cluster_id;
            for &w in graph.neighbors(u) {
                blocked[w as usize] = true;
            }
        }
    }

    // ---- Step 4: attach the remaining vertices. Every unassigned vertex
    // has an assigned *grow-phase* vertex among its neighbors (it is two
    // walks from some seed); pick the candidate seed minimizing the true
    // dissimilarity d_{ℓj}.
    // Snapshot of grow-phase assignment: assignments made above.
    let grow_assign = assign.clone();
    for j in 0..n {
        if assign[j] != UNASSIGNED {
            continue;
        }
        let mut best_cluster = UNASSIGNED;
        let mut best_d = f32::INFINITY;
        for &u in graph.neighbors(j) {
            let c = grow_assign[u as usize];
            if c == UNASSIGNED {
                continue;
            }
            let seed = seeds[c as usize] as usize;
            let d = sq_dist(points.row(j), points.row(seed));
            if d < best_d || (d == best_d && c < best_cluster) {
                best_d = d;
                best_cluster = c;
            }
        }
        debug_assert_ne!(best_cluster, UNASSIGNED, "vertex {j} not within 2 walks of any seed");
        assign[j] = best_cluster;
    }

    TcResult { assignments: assign, num_clusters: seeds.len(), seeds }
}

/// Verify the TC invariants on a result; used by tests and by the
/// pipeline's (optional) self-check mode. Returns `Ok(())` when the
/// spanning, minimum-cluster-size, and seed-independence invariants all
/// hold, and a descriptive error naming the first violated invariant
/// otherwise.
pub fn validate(
    result: &TcResult,
    graph: &NeighborGraph,
    threshold: usize,
) -> Result<()> {
    let n = graph.len();
    if result.assignments.len() != n {
        return Err(Error::Shape("assignment length".into()));
    }
    // Spanning + cluster size ≥ t*.
    let mut sizes = vec![0usize; result.num_clusters];
    for &a in &result.assignments {
        if a as usize >= result.num_clusters {
            return Err(Error::InvalidArgument(format!("cluster id {a} out of range")));
        }
        sizes[a as usize] += 1;
    }
    if let Some(&min) = sizes.iter().min() {
        if result.num_clusters > 1 && min < threshold {
            return Err(Error::InvalidArgument(format!(
                "cluster of size {min} < t*={threshold}"
            )));
        }
    }
    // Seed independence in NG²: no two seeds within two walks. The
    // membership set is a flat bool table over unit ids (deterministic
    // by construction, no hashing) — which also forces the range check
    // a validator owes its caller before seeds index anything.
    let mut is_seed = vec![false; n];
    for &s in &result.seeds {
        if s as usize >= n {
            return Err(Error::InvalidArgument(format!("seed {s} out of range (n={n})")));
        }
        is_seed[s as usize] = true;
    }
    for &s in &result.seeds {
        let mut bad = false;
        graph.for_two_walk(s as usize, |v, _| {
            if is_seed[v as usize] {
                bad = true;
            }
        });
        if bad {
            return Err(Error::InvalidArgument(format!("seed {s} within 2 walks of another seed")));
        }
    }
    Ok(())
}

/// Convenience: TC from precomputed k-NN lists.
pub fn threshold_cluster_knn(
    knn: &KnnLists,
    points: &Matrix,
    config: &TcConfig,
) -> TcResult {
    let graph = NeighborGraph::from_knn(knn);
    threshold_cluster_graph(&graph, points, config)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::knn::knn_brute;
    use crate::metrics;
    use crate::rng::Xoshiro256;

    fn run_tc(points: &Matrix, t: usize) -> (TcResult, NeighborGraph) {
        let knn = knn_brute(points, t - 1).unwrap();
        let g = NeighborGraph::from_knn(&knn);
        let r = threshold_cluster_graph(&g, points, &TcConfig::new(t));
        (r, g)
    }

    #[test]
    fn all_points_assigned_and_sizes_hold() {
        let ds = gaussian_mixture_paper(1000, 51);
        for t in [2usize, 3, 5, 8] {
            let (r, g) = run_tc(&ds.points, t);
            validate(&r, &g, t).unwrap();
            assert_eq!(metrics::cluster_sizes(&r.assignments).len(), r.num_clusters);
            assert!(metrics::min_cluster_size(&r.assignments) >= t, "t={t}");
        }
    }

    #[test]
    fn reduction_factor_at_least_threshold() {
        // n* ≤ n / t*: each cluster has ≥ t* units.
        let ds = gaussian_mixture_paper(2000, 52);
        for t in [2usize, 4] {
            let (r, _) = run_tc(&ds.points, t);
            assert!(r.num_clusters <= 2000 / t, "t={t}, n*={}", r.num_clusters);
            assert!(r.num_clusters >= 1);
        }
    }

    #[test]
    fn four_approximation_bound() {
        // Within-cluster max distance ≤ 4 × (max edge weight of NG), and the
        // max edge weight is itself a lower bound for λ — so this checks the
        // paper's 4λ guarantee end-to-end.
        let ds = gaussian_mixture_paper(600, 53);
        for t in [2usize, 3, 6] {
            let (r, g) = run_tc(&ds.points, t);
            let bound = 4.0 * (g.max_weight() as f64).sqrt();
            let got = metrics::bottleneck(&ds.points, &r.assignments, usize::MAX).unwrap();
            assert!(got <= bound + 1e-5, "t={t}: {got} > {bound}");
        }
    }

    #[test]
    fn threshold_one_gives_singletons() {
        let ds = gaussian_mixture_paper(20, 54);
        let r = threshold_cluster(&ds.points, &TcConfig::new(1)).unwrap();
        assert_eq!(r.num_clusters, 20);
    }

    #[test]
    fn tiny_inputs_one_cluster() {
        let ds = gaussian_mixture_paper(3, 55);
        let r = threshold_cluster(&ds.points, &TcConfig::new(5)).unwrap();
        assert_eq!(r.num_clusters, 1);
        assert_eq!(r.assignments, vec![0, 0, 0]);
    }

    #[test]
    fn seeds_in_own_cluster() {
        let ds = gaussian_mixture_paper(400, 56);
        let (r, _) = run_tc(&ds.points, 3);
        for (c, &s) in r.seeds.iter().enumerate() {
            assert_eq!(r.assignments[s as usize], c as u32);
        }
    }

    #[test]
    fn well_separated_blobs_not_merged() {
        // Two far-apart blobs of 10 points each; t*=2 must never produce a
        // cluster spanning both blobs.
        let mut rng = Xoshiro256::seed_from_u64(57);
        let mut data = Vec::new();
        for b in 0..2 {
            for _ in 0..10 {
                data.push((b as f32) * 1000.0 + rng.next_gaussian() as f32);
                data.push(rng.next_gaussian() as f32);
            }
        }
        let m = Matrix::from_vec(data, 20, 2).unwrap();
        let (r, _) = run_tc(&m, 2);
        for c in 0..r.num_clusters as u32 {
            let members: Vec<usize> =
                (0..20).filter(|&i| r.assignments[i] == c).collect();
            let blob0 = members.iter().any(|&i| i < 10);
            let blob1 = members.iter().any(|&i| i >= 10);
            assert!(!(blob0 && blob1), "cluster {c} spans blobs: {members:?}");
        }
    }

    #[test]
    fn seed_orders_all_valid() {
        let ds = gaussian_mixture_paper(500, 58);
        let knn = knn_brute(&ds.points, 2).unwrap();
        let g = NeighborGraph::from_knn(&knn);
        for order in [SeedOrder::Natural, SeedOrder::DegreeAscending, SeedOrder::DegreeDescending] {
            let cfg = TcConfig { threshold: 3, seed_order: order };
            let r = threshold_cluster_graph(&g, &ds.points, &cfg);
            validate(&r, &g, 3).unwrap();
        }
    }

    #[test]
    fn property_random_workloads() {
        // Hand-rolled property test: random n, t*, seeds — invariants hold.
        let mut rng = Xoshiro256::seed_from_u64(59);
        for case in 0..25 {
            let n = 30 + (rng.next_below(400) as usize);
            let t = 2 + (rng.next_below(5) as usize);
            let ds = gaussian_mixture_paper(n, 1000 + case);
            if n <= t {
                continue;
            }
            let (r, g) = run_tc(&ds.points, t);
            validate(&r, &g, t).expect("invariants");
            // Spanning: every point in exactly one cluster (assignment total).
            assert_eq!(r.assignments.len(), n);
        }
    }
}
