//! Polynomial-time refinements of a TC clustering.
//!
//! §2.3 of the paper: "polynomial-time improvements to this algorithm —
//! for example, in selecting cluster seeds or splitting large clusters —
//! may improve the performance of TC without substantially increasing
//! its runtime." Seed-order selection lives in [`super::SeedOrder`];
//! this module implements the other two:
//!
//! * [`reassign_boundary`] — one local-improvement sweep: move each
//!   non-seed unit to the cluster with the nearest *seed* if that is
//!   strictly closer than its current seed and the move does not break
//!   the donor's `|V| ≥ t*` guarantee.
//! * [`split_large_clusters`] — any cluster with `≥ 2·t*` units is split
//!   greedily into valid-size sub-clusters seeded at its two mutually
//!   farthest members (reduces within-cluster spread; never violates the
//!   size threshold).
//!
//! Both preserve every TC invariant (validated in tests) and both are
//! `O(t*·n)`-ish passes, honoring the "without substantially increasing
//! its runtime" constraint.

use super::TcResult;
use crate::knn::graph::NeighborGraph;
use crate::linalg::{sq_dist, Matrix};

/// One boundary-reassignment sweep. Returns the number of moves.
pub fn reassign_boundary(
    result: &mut TcResult,
    graph: &NeighborGraph,
    points: &Matrix,
    threshold: usize,
) -> usize {
    let n = result.assignments.len();
    let mut sizes = vec![0usize; result.num_clusters];
    for &a in &result.assignments {
        sizes[a as usize] += 1;
    }
    let seed_of = |c: u32| result.seeds[c as usize] as usize;
    // Membership-only over unit ids < n: a flat bool table instead of a
    // hash set — deterministic by construction and cheaper to probe.
    let mut is_seed = vec![false; n];
    for &s in &result.seeds {
        is_seed[s as usize] = true;
    }
    let mut moves = 0usize;
    for i in 0..n {
        if is_seed[i] {
            continue; // seeds anchor their clusters
        }
        let cur = result.assignments[i];
        if sizes[cur as usize] <= threshold {
            continue; // donor would fall under t*
        }
        let d_cur = sq_dist(points.row(i), points.row(seed_of(cur)));
        // Candidate clusters: those owning a neighbor of i (stays within
        // the walk-≤2 structure TC's approximation bound relies on).
        let mut best = (cur, d_cur);
        for &u in graph.neighbors(i) {
            let c = result.assignments[u as usize];
            if c == cur {
                continue;
            }
            let d = sq_dist(points.row(i), points.row(seed_of(c)));
            if d < best.1 {
                best = (c, d);
            }
        }
        if best.0 != cur {
            sizes[cur as usize] -= 1;
            sizes[best.0 as usize] += 1;
            result.assignments[i] = best.0;
            moves += 1;
        }
    }
    moves
}

/// Split every cluster of size ≥ `2·t*` into two valid halves around its
/// two mutually farthest members (exact on the cluster, which TC keeps
/// small). Returns the number of splits performed.
pub fn split_large_clusters(
    result: &mut TcResult,
    points: &Matrix,
    threshold: usize,
) -> usize {
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); result.num_clusters];
    for (i, &a) in result.assignments.iter().enumerate() {
        members[a as usize].push(i as u32);
    }
    let mut splits = 0usize;
    let mut queue: std::collections::VecDeque<u32> =
        (0..result.num_clusters as u32).collect();
    while let Some(c) = queue.pop_front() {
        let m = std::mem::take(&mut members[c as usize]);
        if m.len() < 2 * threshold {
            members[c as usize] = m;
            continue;
        }
        // Farthest pair (clusters are small — |V| is O(t*) in TC output,
        // so the quadratic scan is bounded).
        let mut far = (0usize, 1usize, -1.0f32);
        for a in 0..m.len() {
            for b in (a + 1)..m.len() {
                let d = sq_dist(points.row(m[a] as usize), points.row(m[b] as usize));
                if d > far.2 {
                    far = (a, b, d);
                }
            }
        }
        let (pa, pb) = (m[far.0] as usize, m[far.1] as usize);
        // Partition by nearer pole, then rebalance to keep both ≥ t*.
        let mut part: Vec<(f32, u32, bool)> = m
            .iter()
            .map(|&i| {
                let da = sq_dist(points.row(i as usize), points.row(pa));
                let db = sq_dist(points.row(i as usize), points.row(pb));
                (da - db, i, da <= db)
            })
            .collect();
        // Sort by affinity so rebalancing moves the least-committed units.
        part.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
        let mut a_side: Vec<u32> = part.iter().filter(|p| p.2).map(|p| p.1).collect();
        let mut b_side: Vec<u32> = part.iter().filter(|p| !p.2).map(|p| p.1).collect();
        while a_side.len() < threshold {
            a_side.push(b_side.remove(0));
        }
        while b_side.len() < threshold {
            b_side.push(a_side.pop().unwrap());
        }
        // New cluster id for the b side; a side keeps c.
        let new_id = result.num_clusters as u32;
        result.num_clusters += 1;
        result.seeds.push(nearest_to_centroid(points, &b_side));
        result.seeds[c as usize] = nearest_to_centroid(points, &a_side);
        for &i in &b_side {
            result.assignments[i as usize] = new_id;
        }
        splits += 1;
        // Either half may still be ≥ 2t*.
        if a_side.len() >= 2 * threshold {
            queue.push_back(c);
        }
        if b_side.len() >= 2 * threshold {
            queue.push_back(new_id);
        }
        members[c as usize] = a_side;
        members.push(b_side);
    }
    splits
}

fn nearest_to_centroid(points: &Matrix, members: &[u32]) -> u32 {
    let d = points.cols();
    let mut mean = vec![0.0f64; d];
    for &i in members {
        for (m, &x) in mean.iter_mut().zip(points.row(i as usize)) {
            *m += x as f64;
        }
    }
    for m in &mut mean {
        *m /= members.len() as f64;
    }
    let meanf: Vec<f32> = mean.iter().map(|&x| x as f32).collect();
    *members
        .iter()
        .min_by(|&&a, &&b| {
            sq_dist(points.row(a as usize), &meanf)
                .partial_cmp(&sq_dist(points.row(b as usize), &meanf))
                .unwrap()
        })
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::knn::knn_brute;
    use crate::metrics;
    use crate::tc::{threshold_cluster_graph, TcConfig};

    fn setup(n: usize, t: usize, seed: u64) -> (Matrix, NeighborGraph, TcResult) {
        let ds = gaussian_mixture_paper(n, seed);
        let knn = knn_brute(&ds.points, t - 1).unwrap();
        let g = NeighborGraph::from_knn(&knn);
        let r = threshold_cluster_graph(&g, &ds.points, &TcConfig::new(t));
        (ds.points, g, r)
    }

    #[test]
    fn reassign_never_breaks_threshold() {
        let (points, g, mut r) = setup(800, 3, 131);
        let moves = reassign_boundary(&mut r, &g, &points, 3);
        assert!(metrics::min_cluster_size(&r.assignments) >= 3, "moves={moves}");
        assert_eq!(r.assignments.len(), 800);
    }

    #[test]
    fn reassign_does_not_worsen_mean_seed_distance() {
        let (points, g, mut r) = setup(600, 2, 132);
        let seed_dist = |r: &TcResult| -> f64 {
            (0..600)
                .map(|i| {
                    sq_dist(
                        points.row(i),
                        points.row(r.seeds[r.assignments[i] as usize] as usize),
                    ) as f64
                })
                .sum::<f64>()
        };
        let before = seed_dist(&r);
        reassign_boundary(&mut r, &g, &points, 2);
        let after = seed_dist(&r);
        assert!(after <= before + 1e-6, "{before} -> {after}");
    }

    #[test]
    fn split_eliminates_oversized_clusters() {
        let (points, _, mut r) = setup(1000, 2, 133);
        let t = 2;
        split_large_clusters(&mut r, &points, t);
        let sizes = metrics::cluster_sizes(&r.assignments);
        assert!(sizes.iter().all(|&s| s >= t), "{sizes:?}");
        assert!(sizes.iter().all(|&s| s < 2 * t + t), "oversized remain: {sizes:?}");
        // Seeds stay members of their clusters.
        for (c, &s) in r.seeds.iter().enumerate() {
            assert_eq!(r.assignments[s as usize], c as u32);
        }
    }

    #[test]
    fn split_reduces_bottleneck() {
        let (points, _, mut r) = setup(500, 4, 134);
        let before = metrics::bottleneck(&points, &r.assignments, usize::MAX).unwrap();
        split_large_clusters(&mut r, &points, 4);
        let after = metrics::bottleneck(&points, &r.assignments, usize::MAX).unwrap();
        assert!(after <= before + 1e-9, "{before} -> {after}");
        assert!(metrics::min_cluster_size(&r.assignments) >= 4);
    }

    #[test]
    fn refinements_preserve_spanning() {
        let (points, g, mut r) = setup(700, 3, 135);
        reassign_boundary(&mut r, &g, &points, 3);
        split_large_clusters(&mut r, &points, 3);
        // Every unit assigned to a valid cluster id.
        assert!(r.assignments.iter().all(|&a| (a as usize) < r.num_clusters));
        let sizes = metrics::cluster_sizes(&r.assignments);
        assert_eq!(sizes.iter().sum::<usize>(), 700);
    }
}
