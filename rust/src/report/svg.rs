//! Minimal SVG line-chart renderer for the paper's figures.
//!
//! Figures 3–6 and 9–11 are log-x line plots of the exact series the
//! repro tables produce; this renderer turns those series into
//! standalone `.svg` files (no plotting library exists offline). Output
//! is deliberately simple: axes, ticks, one polyline + markers per
//! series, a legend.

use std::fmt::Write as _;

/// One named series of (x, y) points.
#[derive(Clone, Debug)]
pub struct Series {
    /// Legend label.
    pub label: String,
    /// Data points (x ascending not required but typical).
    pub points: Vec<(f64, f64)>,
}

/// Axis scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AxisScale {
    /// Linear axis.
    Linear,
    /// Log10 axis (non-positive values are dropped from the plot).
    Log10,
}

/// Chart configuration.
#[derive(Clone, Debug)]
pub struct Chart {
    /// Chart title.
    pub title: String,
    /// X-axis label.
    pub x_label: String,
    /// Y-axis label.
    pub y_label: String,
    /// X-axis scale.
    pub x_scale: AxisScale,
    /// Y-axis scale.
    pub y_scale: AxisScale,
    /// The series to draw.
    pub series: Vec<Series>,
}

const W: f64 = 640.0;
const H: f64 = 420.0;
const ML: f64 = 64.0; // margins
const MR: f64 = 16.0;
const MT: f64 = 36.0;
const MB: f64 = 48.0;
const PALETTE: &[&str] = &["#1f77b4", "#d62728", "#2ca02c", "#ff7f0e", "#9467bd", "#8c564b"];

fn tx(scale: AxisScale, v: f64) -> Option<f64> {
    match scale {
        AxisScale::Linear => Some(v),
        AxisScale::Log10 => {
            if v > 0.0 {
                Some(v.log10())
            } else {
                None
            }
        }
    }
}

impl Chart {
    /// Render to an SVG document string.
    pub fn render(&self) -> String {
        // Transformed bounds.
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for s in &self.series {
            for &(x, y) in &s.points {
                if let (Some(a), Some(b)) = (tx(self.x_scale, x), tx(self.y_scale, y)) {
                    xs.push(a);
                    ys.push(b);
                }
            }
        }
        let (x0, x1) = bounds(&xs);
        let (y0, y1) = bounds(&ys);
        let px = |v: f64| ML + (v - x0) / (x1 - x0).max(1e-12) * (W - ML - MR);
        let py = |v: f64| H - MB - (v - y0) / (y1 - y0).max(1e-12) * (H - MT - MB);

        let mut out = String::new();
        let _ = write!(
            out,
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{W}" height="{H}" viewBox="0 0 {W} {H}"><rect width="{W}" height="{H}" fill="white"/>"#
        );
        let _ = write!(
            out,
            r#"<text x="{}" y="20" font-family="sans-serif" font-size="14" text-anchor="middle">{}</text>"#,
            W / 2.0,
            esc(&self.title)
        );
        // Axes.
        let _ = write!(
            out,
            r#"<line x1="{ML}" y1="{}" x2="{}" y2="{}" stroke="black"/><line x1="{ML}" y1="{MT}" x2="{ML}" y2="{}" stroke="black"/>"#,
            H - MB,
            W - MR,
            H - MB,
            H - MB
        );
        // Ticks: 5 per axis at transformed-space intervals.
        for i in 0..=4 {
            let fx = x0 + (x1 - x0) * i as f64 / 4.0;
            let fy = y0 + (y1 - y0) * i as f64 / 4.0;
            let lx = match self.x_scale {
                AxisScale::Linear => fmt_tick(fx),
                AxisScale::Log10 => format!("1e{fx:.1}"),
            };
            let ly = match self.y_scale {
                AxisScale::Linear => fmt_tick(fy),
                AxisScale::Log10 => format!("1e{fy:.1}"),
            };
            let _ = write!(
                out,
                r#"<text x="{}" y="{}" font-family="sans-serif" font-size="10" text-anchor="middle">{lx}</text>"#,
                px(fx),
                H - MB + 16.0
            );
            let _ = write!(
                out,
                r#"<text x="{}" y="{}" font-family="sans-serif" font-size="10" text-anchor="end">{ly}</text>"#,
                ML - 6.0,
                py(fy) + 3.0
            );
            let _ = write!(
                out,
                r##"<line x1="{}" y1="{MT}" x2="{}" y2="{}" stroke="#eeeeee"/>"##,
                px(fx),
                px(fx),
                H - MB
            );
        }
        // Axis labels.
        let _ = write!(
            out,
            r#"<text x="{}" y="{}" font-family="sans-serif" font-size="12" text-anchor="middle">{}</text>"#,
            W / 2.0,
            H - 10.0,
            esc(&self.x_label)
        );
        let _ = write!(
            out,
            r#"<text x="14" y="{}" font-family="sans-serif" font-size="12" text-anchor="middle" transform="rotate(-90 14 {})">{}</text>"#,
            H / 2.0,
            H / 2.0,
            esc(&self.y_label)
        );
        // Series.
        for (si, s) in self.series.iter().enumerate() {
            let color = PALETTE[si % PALETTE.len()];
            let mut path = String::new();
            for &(x, y) in &s.points {
                if let (Some(a), Some(b)) = (tx(self.x_scale, x), tx(self.y_scale, y)) {
                    if path.is_empty() {
                        let _ = write!(path, "M{:.1},{:.1}", px(a), py(b));
                    } else {
                        let _ = write!(path, " L{:.1},{:.1}", px(a), py(b));
                    }
                    let _ = write!(
                        out,
                        r#"<circle cx="{:.1}" cy="{:.1}" r="2.5" fill="{color}"/>"#,
                        px(a),
                        py(b)
                    );
                }
            }
            let _ = write!(out, r#"<path d="{path}" fill="none" stroke="{color}" stroke-width="1.5"/>"#);
            // Legend entry.
            let ly = MT + 14.0 * si as f64;
            let _ = write!(
                out,
                r#"<line x1="{}" y1="{ly}" x2="{}" y2="{ly}" stroke="{color}" stroke-width="2"/><text x="{}" y="{}" font-family="sans-serif" font-size="11">{}</text>"#,
                W - MR - 120.0,
                W - MR - 100.0,
                W - MR - 94.0,
                ly + 3.0,
                esc(&s.label)
            );
        }
        out.push_str("</svg>");
        out
    }

    /// Write the rendering to `path`.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.render())?;
        Ok(())
    }
}

fn bounds(v: &[f64]) -> (f64, f64) {
    if v.is_empty() {
        return (0.0, 1.0);
    }
    let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if (hi - lo).abs() < 1e-12 {
        (lo - 0.5, hi + 0.5)
    } else {
        (lo, hi)
    }
}

fn fmt_tick(v: f64) -> String {
    if v.abs() >= 1000.0 || (v != 0.0 && v.abs() < 0.01) {
        format!("{v:.1e}")
    } else {
        format!("{v:.2}")
    }
}

fn esc(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

/// Build a figure from a long-format table (columns: group, x, y):
/// one series per distinct `group` value.
pub fn chart_from_long(
    title: &str,
    table: &super::Table,
    group_col: usize,
    x_col: usize,
    y_col: usize,
    x_label: &str,
    y_label: &str,
    y_scale: AxisScale,
) -> Chart {
    let mut series: Vec<Series> = Vec::new();
    for row in &table.rows {
        let group = &row[group_col];
        let x: f64 = row[x_col].parse().unwrap_or(f64::NAN);
        let y: f64 = row[y_col].parse().unwrap_or(f64::NAN);
        if !x.is_finite() || !y.is_finite() {
            continue;
        }
        match series.iter_mut().find(|s| s.label == *group) {
            Some(s) => s.points.push((x, y)),
            None => series.push(Series { label: group.clone(), points: vec![(x, y)] }),
        }
    }
    Chart {
        title: title.into(),
        x_label: x_label.into(),
        y_label: y_label.into(),
        x_scale: AxisScale::Linear,
        y_scale,
        series,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_chart() -> Chart {
        Chart {
            title: "t".into(),
            x_label: "x".into(),
            y_label: "y".into(),
            x_scale: AxisScale::Linear,
            y_scale: AxisScale::Log10,
            series: vec![
                Series { label: "a".into(), points: vec![(0.0, 1.0), (1.0, 10.0), (2.0, 100.0)] },
                Series { label: "b".into(), points: vec![(0.0, 5.0), (2.0, 0.5)] },
            ],
        }
    }

    #[test]
    fn renders_valid_svg_with_all_series() {
        let svg = sample_chart().render();
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>"));
        assert_eq!(svg.matches("<path").count(), 2);
        assert!(svg.contains(">a</text>"));
        assert!(svg.contains(">b</text>"));
        // 5 data points drawn as markers.
        assert_eq!(svg.matches("<circle").count(), 5);
    }

    #[test]
    fn log_scale_drops_nonpositive() {
        let mut c = sample_chart();
        c.series[0].points.push((3.0, 0.0)); // dropped on log axis
        let svg = c.render();
        assert_eq!(svg.matches("<circle").count(), 5);
    }

    #[test]
    fn escapes_markup() {
        let mut c = sample_chart();
        c.title = "a<b & c>".into();
        let svg = c.render();
        assert!(svg.contains("a&lt;b &amp; c&gt;"));
    }

    #[test]
    fn chart_from_long_groups_rows() {
        let mut t = crate::report::Table::new("", &["n", "m", "seconds"]);
        t.push_row(vec!["10000".into(), "0".into(), "1.5".into()]);
        t.push_row(vec!["10000".into(), "1".into(), "0.7".into()]);
        t.push_row(vec!["100000".into(), "0".into(), "15.0".into()]);
        t.push_row(vec!["100000".into(), "bad".into(), "x".into()]); // skipped
        let c = chart_from_long("f", &t, 0, 1, 2, "m", "s", AxisScale::Linear);
        assert_eq!(c.series.len(), 2);
        assert_eq!(c.series[0].points.len(), 2);
        assert_eq!(c.series[1].points.len(), 1);
    }

    #[test]
    fn save_writes_file() {
        let dir = std::env::temp_dir().join("ihtc_svg_test");
        let path = dir.join("fig.svg");
        sample_chart().save(&path).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("</svg>"));
    }
}
