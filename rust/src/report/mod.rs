//! Paper-style table rendering.
//!
//! The repro harness emits the same rows the paper's tables report; this
//! module owns the formatting: fixed-width text tables for the terminal
//! and CSV for plotting (the paper's figures are line plots over the same
//! series).

pub mod svg;

use std::fmt::Write as _;

/// A simple column-oriented table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Title printed above the table.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Rows of cells (already formatted).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    /// Append a row (must match header count).
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |out: &mut String, cells: &[String]| {
            let mut first = true;
            for (i, cell) in cells.iter().enumerate().take(ncols) {
                if !first {
                    out.push_str("  ");
                }
                first = false;
                let pad = widths[i] - cell.len();
                for _ in 0..pad {
                    out.push(' ');
                }
                out.push_str(cell);
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Render as CSV (headers + rows).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write both text and CSV renderings under `dir/<stem>.{txt,csv}`.
    pub fn save(&self, dir: &std::path::Path, stem: &str) -> crate::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.txt")), self.render())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// Format seconds the way the paper's tables do.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else {
        format!("{s:.3}")
    }
}

/// Format a ratio/accuracy with 4 decimals (paper convention).
pub fn fmt4(x: f64) -> String {
    format!("{x:.4}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("T", &["m", "time", "acc"]);
        t.push_row(vec!["0".into(), "1.23".into(), "0.9239".into()]);
        t.push_row(vec!["10".into(), "123".into(), "0.9".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        let lines: Vec<&str> = r.lines().collect();
        // All data lines equal width.
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.push_row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("", &["a"]);
        t.push_row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(0.1234), "0.123");
        assert_eq!(fmt_secs(12.346), "12.35");
        assert_eq!(fmt_secs(1234.6), "1235");
        assert_eq!(fmt4(0.92388), "0.9239");
    }

    #[test]
    fn save_writes_both_files() {
        let mut t = Table::new("x", &["a"]);
        t.push_row(vec!["1".into()]);
        let dir = std::env::temp_dir().join("ihtc_report_test");
        t.save(&dir, "t1").unwrap();
        assert!(dir.join("t1.txt").exists());
        assert!(dir.join("t1.csv").exists());
    }
}
