//! Peak-memory accounting.
//!
//! The paper reports "Memory (Mb)" for every experiment. R measures this
//! with `gc()`/`object.size`; our equivalent is a counting global
//! allocator: a thin wrapper over the system allocator that tracks live
//! bytes and the high-water mark. Binaries that want the numbers opt in
//! with:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: ihtc::memtrack::CountingAllocator = ihtc::memtrack::CountingAllocator;
//! ```
//!
//! The counters are process-wide atomics, so the repro harness brackets
//! each phase with [`reset_peak`] / [`peak_bytes`].

use std::alloc::{GlobalAlloc, Layout, System};
// Raw std atomics, not the `crate::sync` facade: a `#[global_allocator]`
// static needs const construction and runs before (and underneath)
// everything else, so it can never be a loom double — loom cannot model
// the allocator its own runtime allocates through.
// det-lint: allow(raw-atomic)
use std::sync::atomic::{AtomicUsize, Ordering};

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

/// Counting wrapper around the system allocator.
pub struct CountingAllocator;

// SAFETY: every method delegates the actual allocation verbatim to
// `System` (which upholds the `GlobalAlloc` contract) and only adds
// counter arithmetic on the side — layouts, pointers, and sizes pass
// through untouched, so the wrapper inherits `System`'s guarantees.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // SAFETY: `layout` is forwarded unchanged from our own caller,
        // who promises it is non-zero-sized per the trait contract.
        let p = unsafe { System.alloc(layout) };
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: `ptr`/`layout` are forwarded unchanged; the caller
        // promises `ptr` came from this allocator with this layout, and
        // our `alloc`/`realloc` return `System`'s pointers untouched.
        unsafe { System.dealloc(ptr, layout) };
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // SAFETY: forwarded unchanged under the caller's contract
        // (`ptr` from this allocator, `layout` its current layout,
        // `new_size` non-zero).
        let p = unsafe { System.realloc(ptr, layout, new_size) };
        if !p.is_null() {
            if new_size >= layout.size() {
                let live =
                    LIVE.fetch_add(new_size - layout.size(), Ordering::Relaxed) + new_size
                        - layout.size();
                PEAK.fetch_max(live, Ordering::Relaxed);
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

/// Bytes currently live (only meaningful when `CountingAllocator` is the
/// global allocator; otherwise always 0).
pub fn live_bytes() -> usize {
    LIVE.load(Ordering::Relaxed)
}

/// High-water mark since the last [`reset_peak`].
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// Reset the high-water mark to the current live size; returns the old
/// peak. Call at the start of a measured phase.
pub fn reset_peak() -> usize {
    PEAK.swap(LIVE.load(Ordering::Relaxed), Ordering::Relaxed)
}

/// Peak bytes *above* the live baseline over a closure: the working set
/// the phase forced. Returns `(result, peak_delta_bytes)`.
pub fn measure<T>(f: impl FnOnce() -> T) -> (T, usize) {
    let baseline = live_bytes();
    reset_peak();
    let out = f();
    let peak = peak_bytes();
    (out, peak.saturating_sub(baseline))
}

/// Format a byte count the way the paper's tables do (decimal MB).
pub fn fmt_mb(bytes: usize) -> String {
    format!("{:.2}", bytes as f64 / 1_000_000.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the unit-test binary does not install the counting allocator
    // (only benches/examples do), so these tests exercise the arithmetic
    // via direct counter manipulation rather than real allocations.

    #[test]
    fn fmt_mb_formats() {
        assert_eq!(fmt_mb(2_500_000), "2.50");
        assert_eq!(fmt_mb(0), "0.00");
    }

    #[test]
    fn measure_returns_closure_result() {
        let (v, _peak) = measure(|| 42);
        assert_eq!(v, 42);
    }

    #[test]
    fn reset_peak_monotonic() {
        reset_peak();
        assert!(peak_bytes() >= 0usize.min(live_bytes()));
    }
}
