//! `ihtc` — launcher for the IHTC data-pipeline framework.
//!
//! Subcommands:
//!
//! * `run --config cfg.json` — execute a full pipeline from a config.
//! * `repro --exp table1 [--scale default] [--out-dir results]` —
//!   regenerate a paper table/figure (or `--all`).
//! * `ablation` — seed-order × prototype-kind ablation (DESIGN.md §Perf).
//! * `generate --dataset gmm --n 10000 --out data.csv` — emit datasets.
//! * `serve --connect host:port [--workers N]` — run a distributed
//!   worker process that leases batches from a coordinator (README
//!   §Distributed mode).
//! * `check-artifacts` — load the PJRT artifacts and run a smoke block.
//! * `list` — list reproducible experiments.

use ihtc::config::PipelineConfig;
use ihtc::coordinator::driver;
use ihtc::data::{csv, synth};
use ihtc::report::Table;
use ihtc::sim::{self, Scale};
use ihtc::Result;
use std::path::PathBuf;

// Peak-memory accounting for the paper's "Memory (Mb)" columns.
#[global_allocator]
static ALLOC: ihtc::memtrack::CountingAllocator = ihtc::memtrack::CountingAllocator;

/// Minimal flag parser: `--key value` pairs plus positional words.
struct Args {
    #[allow(dead_code)]
    positional: Vec<String>,
    // Keyed `get` lookups only, never iterated — hash order can't leak.
    // det-lint: allow(hash-iter)
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: impl Iterator<Item = String>) -> Args {
        let mut positional = Vec::new();
        // det-lint: allow(hash-iter) — same map as the field above.
        let mut flags = std::collections::HashMap::new();
        let mut argv = argv.peekable();
        while let Some(arg) = argv.next() {
            if let Some(key) = arg.strip_prefix("--") {
                let value = match argv.peek() {
                    Some(v) if !v.starts_with("--") => argv.next().unwrap(),
                    _ => "true".to_string(),
                };
                flags.insert(key.to_string(), value);
            } else {
                positional.push(arg);
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                ihtc::Error::InvalidArgument(format!("--{key} expects an integer, got '{v}'"))
            }),
        }
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                ihtc::Error::InvalidArgument(format!("--{key} expects an integer, got '{v}'"))
            }),
        }
    }
}

const USAGE: &str = "\
ihtc — Iterative Hybridized Threshold Clustering (Luo et al. 2019 reproduction)

USAGE:
  ihtc run --config cfg.json            run a pipeline from a JSON config
  ihtc run [--n 100000] [--t 2] [--m 2] [--k 3] [--backend native|pjrt]
           [--workers N] [--clusterer kmeans|hac|dbscan] [--seed S]
                                        run an inline-configured pipeline
  ihtc repro --exp table1 [--scale smoke|default|full] [--seed S]
             [--out-dir results]        regenerate one paper table/figure
  ihtc repro --all [...]                regenerate every table
  ihtc ablation [--seed S]              seed-order × prototype ablation
  ihtc itis-profile [--n 100000] [--t 2]  ITIS reduction profile
  ihtc generate --dataset gmm|<table3-name> --n N --out file.csv
  ihtc serve --connect host:port [--workers N]
                                        lease work from a coordinator
  ihtc check-artifacts [--dir artifacts]  smoke-test the PJRT artifacts
  ihtc list                             list experiments
";

fn main() {
    let mut argv = std::env::args().skip(1);
    let cmd = argv.next().unwrap_or_default();
    let args = Args::parse(argv);
    let code = match cmd.as_str() {
        "run" => run_cmd(&args),
        "repro" => repro_cmd(&args),
        "ablation" => ablation_cmd(&args),
        "itis-profile" => itis_profile_cmd(&args),
        "generate" => generate_cmd(&args),
        "serve" => serve_cmd(&args),
        "check-artifacts" => check_artifacts_cmd(&args),
        "list" => {
            for e in sim::EXPERIMENTS {
                println!("{:<8} {}", e.id, e.description);
            }
            Ok(())
        }
        "" | "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(ihtc::Error::InvalidArgument(format!(
            "unknown command '{other}'\n{USAGE}"
        ))),
    };
    if let Err(e) = code {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run_cmd(args: &Args) -> Result<()> {
    let cfg = match args.get("config") {
        Some(path) => PipelineConfig::from_file(path)?,
        None => {
            let mut cfg = PipelineConfig {
                source: ihtc::config::DataSource::PaperMixture {
                    n: args.get_usize("n", 100_000)?,
                },
                ..Default::default()
            };
            if let Some(name) = args.get("dataset") {
                if name != "gmm" {
                    cfg.source = ihtc::config::DataSource::Analogue {
                        name: name.to_string(),
                        scale_div: args.get_usize("scale-div", 1)?,
                    };
                    cfg.standardize = true;
                }
            }
            cfg.threshold = args.get_usize("t", 2)?;
            cfg.iterations = args.get_usize("m", 2)?;
            cfg.seed = args.get_u64("seed", 42)?;
            cfg.workers = args.get_usize("workers", 0)?;
            let k = args.get_usize("k", 3)?;
            cfg.clusterer = match args.get("clusterer").unwrap_or("kmeans") {
                "kmeans" => ihtc::hybrid::FinalClusterer::KMeans { k, restarts: 4 },
                "hac" => ihtc::hybrid::FinalClusterer::Hac {
                    k,
                    linkage: ihtc::cluster::hac::Linkage::Ward,
                },
                "dbscan" => ihtc::hybrid::FinalClusterer::Dbscan {
                    eps: args
                        .get("eps")
                        .map(|v| v.parse().unwrap_or(0.5))
                        .unwrap_or(0.5),
                    min_pts: args.get_usize("min-pts", 4)?,
                },
                other => {
                    return Err(ihtc::Error::InvalidArgument(format!(
                        "unknown clusterer '{other}'"
                    )))
                }
            };
            cfg.backend = match args.get("backend").unwrap_or("native") {
                "native" => ihtc::config::Backend::Native,
                "pjrt" => ihtc::config::Backend::Pjrt,
                other => {
                    return Err(ihtc::Error::InvalidArgument(format!(
                        "unknown backend '{other}'"
                    )))
                }
            };
            if let Some(out) = args.get("output") {
                cfg.output = Some(out.to_string());
            }
            cfg
        }
    };
    let (_, report) = driver::run(&cfg)?;
    print!("{}", report.render());
    Ok(())
}

fn save_or_print(tables: &[Table], out_dir: Option<&str>, stem: &str) -> Result<()> {
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.render());
        if let Some(dir) = out_dir {
            let dir = PathBuf::from(dir);
            t.save(&dir, &format!("{stem}_{i}"))?;
        }
    }
    Ok(())
}

fn repro_cmd(args: &Args) -> Result<()> {
    let scale = Scale::parse(args.get("scale").unwrap_or("default"))?;
    let seed = args.get_u64("seed", 42)?;
    let out_dir = args.get("out-dir");
    let ids: Vec<&str> = if args.get("all").is_some() {
        sim::EXPERIMENTS.iter().map(|e| e.id).collect()
    } else {
        vec![args.get("exp").ok_or_else(|| {
            ihtc::Error::InvalidArgument("repro needs --exp <id> or --all".into())
        })?]
    };
    for id in ids {
        eprintln!("[repro] running {id} at {scale:?} scale…");
        let t0 = std::time::Instant::now();
        let tables = sim::run_experiment(id, scale, seed)?;
        save_or_print(&tables, out_dir, id)?;
        if let Some(dir) = out_dir {
            // Emit the paper's figures (SVG) from the sweep series.
            for (stem, chart) in sim::figures(id, &tables) {
                let path = PathBuf::from(dir).join(format!("{stem}.svg"));
                chart.save(&path)?;
                eprintln!("[repro] wrote {}", path.display());
            }
        }
        eprintln!("[repro] {id} done in {:.1}s", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn ablation_cmd(args: &Args) -> Result<()> {
    let seed = args.get_u64("seed", 42)?;
    let tables = sim::ablation(seed)?;
    save_or_print(&tables, args.get("out-dir"), "ablation")
}

fn itis_profile_cmd(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 100_000)?;
    let t = args.get_usize("t", 2)?;
    let seed = args.get_u64("seed", 42)?;
    let table = sim::itis_profile(n, t, seed)?;
    println!("{}", table.render());
    Ok(())
}

fn generate_cmd(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 10_000)?;
    let seed = args.get_u64("seed", 42)?;
    let out = args
        .get("out")
        .ok_or_else(|| ihtc::Error::InvalidArgument("generate needs --out".into()))?;
    let name = args.get("dataset").unwrap_or("gmm");
    let ds = if name == "gmm" {
        synth::gaussian_mixture_paper(n, seed)
    } else {
        let spec = synth::find_spec(name).ok_or_else(|| {
            ihtc::Error::InvalidArgument(format!("unknown dataset '{name}'"))
        })?;
        let div = (spec.instances / n.max(1)).max(1);
        synth::realistic(spec, div, seed)
    };
    csv::write_csv(&ds, out)?;
    println!("wrote {} rows × {} cols to {out}", ds.len(), ds.dim());
    Ok(())
}

/// Worker mode: connect to a coordinator and lease work units until it
/// closes the connection (clean EOF → exit 0). `--workers 0` sizes the
/// local executor to the machine's available parallelism.
fn serve_cmd(args: &Args) -> Result<()> {
    let addr = args.get("connect").ok_or_else(|| {
        ihtc::Error::InvalidArgument("serve needs --connect host:port".into())
    })?;
    let workers = args.get_usize("workers", 0)?;
    eprintln!("[serve] leasing from {addr} ({workers} local workers; 0 = auto)…");
    ihtc::dist::serve(addr, workers)?;
    eprintln!("[serve] coordinator closed the connection; done");
    Ok(())
}

fn check_artifacts_cmd(args: &Args) -> Result<()> {
    let dir = args
        .get("dir")
        .map(PathBuf::from)
        .unwrap_or_else(ihtc::runtime::Engine::default_dir);
    let engine = ihtc::runtime::Engine::load(&dir)?;
    println!(
        "loaded artifacts from {} (tile: q{} r{} k{} | n{} k{} | d{})",
        dir.display(),
        engine.tile.knn_q,
        engine.tile.knn_r,
        engine.tile.knn_k,
        engine.tile.km_n,
        engine.tile.km_k,
        engine.tile.dim
    );
    // Smoke: cross-check one knn pass against the native path.
    let ds = synth::gaussian_mixture_paper(2_000, 1);
    let native = ihtc::knn::knn_auto(&ds.points, 3)?;
    let pjrt = ihtc::knn::knn_chunked(
        &ds.points,
        3,
        engine.tile.knn_q,
        engine.tile.knn_r,
        &ihtc::runtime::PjrtChunks { engine: &engine },
    )?;
    let mut max_err = 0f32;
    for i in 0..ds.len() {
        for (a, b) in native.distances(i).iter().zip(pjrt.distances(i)) {
            max_err = max_err.max((a - b).abs());
        }
    }
    println!("knn cross-check vs native: max |Δd²| = {max_err:.3e}");
    if max_err > 1e-2 {
        return Err(ihtc::Error::Runtime("PJRT/native mismatch".into()));
    }
    println!("check-artifacts OK");
    Ok(())
}
