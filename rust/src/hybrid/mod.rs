//! IHTC — Iterative Hybridized Threshold Clustering (§3.2).
//!
//! The paper's headline method: run [`crate::itis`] for `m` iterations to
//! form prototypes, cluster the prototypes with a conventional algorithm,
//! then "back out" the labels onto all `n` units. Guarantees every final
//! cluster contains at least `(t*)^m` units and reduces the downstream
//! algorithm's input size by the same factor.

use crate::cluster::{dbscan, gmm, hac, kmeans};
use crate::coordinator::PoolKnnProvider;
use crate::exec::Executor;
use crate::itis::{itis_with_workspace, ItisConfig, ItisResult, ItisWorkspace, PrototypeKind};
use crate::linalg::Matrix;
use crate::tc::SeedOrder;
use crate::Result;

/// Reusable scratch arena for repeated IHTC runs: the ITIS neighbor-list
/// and prototype buffers plus the k-means assignment accumulators. A
/// service clustering many batches (or the repro harness sweeping `m`)
/// holds one workspace and passes it to [`Ihtc::run_with`] so the hot
/// path stops reallocating its large buffers per run.
#[derive(Debug, Default)]
pub struct IhtcWorkspace {
    /// ITIS-level buffers (neighbor lists, prototype accumulators).
    pub itis: ItisWorkspace,
    /// k-means assignment-phase accumulators.
    pub kmeans: kmeans::KMeansWorkspace,
}

impl IhtcWorkspace {
    /// Empty workspace; buffers grow on first use and are then reused.
    pub fn new() -> Self {
        Self::default()
    }
}

/// The conventional ("sophisticated") algorithm applied to the prototypes.
#[derive(Clone, Debug)]
pub enum FinalClusterer {
    /// k-means with `restarts` random restarts.
    KMeans {
        /// Number of clusters.
        k: usize,
        /// Restarts (`nstart`).
        restarts: usize,
    },
    /// Hierarchical agglomerative clustering cut at `k`.
    Hac {
        /// Number of clusters after cutting the dendrogram.
        k: usize,
        /// Linkage criterion.
        linkage: hac::Linkage,
    },
    /// DBSCAN with explicit parameters.
    Dbscan {
        /// Neighborhood radius ε.
        eps: f64,
        /// Core-point neighborhood size.
        min_pts: usize,
    },
    /// Diagonal-covariance Gaussian mixture fit by EM (extension; §3.2
    /// notes IHTC applies to "most other clustering algorithms"). When
    /// `weighted`, prototypes carry their represented-unit masses into
    /// the fit.
    Gmm {
        /// Number of components.
        k: usize,
        /// Weight prototypes by represented-unit counts.
        weighted: bool,
    },
}

impl FinalClusterer {
    /// Minimum number of prototypes this clusterer needs ITIS to leave
    /// behind — the `min_prototypes` floor the reduction enforces
    /// ([`crate::itis::ItisConfig::min_prototypes`]): `k` for the
    /// k-seeking algorithms, 2 for DBSCAN.
    pub fn min_prototypes(&self) -> usize {
        match self {
            FinalClusterer::KMeans { k, .. }
            | FinalClusterer::Hac { k, .. }
            | FinalClusterer::Gmm { k, .. } => *k,
            FinalClusterer::Dbscan { .. } => 2,
        }
    }
}

/// IHTC configuration: `m` ITIS iterations at threshold `t*`, then a
/// final clusterer.
#[derive(Clone, Debug)]
pub struct Ihtc {
    /// TC size threshold `t*` (≥ 2).
    pub threshold: usize,
    /// ITIS iterations `m` (0 = run the final clusterer directly, the
    /// paper's "Null"/m=0 rows).
    pub iterations: usize,
    /// Final clustering algorithm.
    pub clusterer: FinalClusterer,
    /// Prototype kind (paper: centroid).
    pub prototype: PrototypeKind,
    /// TC seed-selection order.
    pub seed_order: SeedOrder,
    /// Base RNG seed for the final clusterer.
    pub seed: u64,
    /// kd-forest shard count for the k-NN index (1 = single tree).
    /// Results are byte-identical for every value; > 1 parallelizes
    /// index construction across shard trees.
    pub knn_shards: usize,
    /// Elkan/Hamerly bound pruning for a k-means final clusterer
    /// (`KMeansConfig::bounds`). Exact — labels and centroids stay
    /// byte-identical — and ignored by non-k-means clusterers (the
    /// config layer rejects that combination up front).
    pub kmeans_bounds: bool,
}

/// Full IHTC output.
#[derive(Clone, Debug)]
pub struct IhtcResult {
    /// Cluster label per original unit ([`crate::cluster::NOISE`] marks
    /// DBSCAN noise).
    pub assignments: Vec<u32>,
    /// Labels assigned to the prototypes by the final clusterer.
    pub prototype_labels: Vec<u32>,
    /// The ITIS reduction that produced the prototypes.
    pub itis: ItisResult,
}

impl IhtcResult {
    /// Number of prototypes the final clusterer saw.
    pub fn num_prototypes(&self) -> usize {
        self.itis.prototypes.rows()
    }
}

impl Ihtc {
    /// Paper-default construction.
    pub fn new(threshold: usize, iterations: usize, clusterer: FinalClusterer) -> Self {
        Self {
            threshold,
            iterations,
            clusterer,
            prototype: PrototypeKind::Centroid,
            seed_order: SeedOrder::Natural,
            seed: 0x1117C,
            knn_shards: 1,
            kmeans_bounds: false,
        }
    }

    /// Run IHTC on `points` with a machine-default executor and a
    /// throwaway workspace. Use [`Self::run_with`] to reuse allocations
    /// across runs or control the team size.
    pub fn run(&self, points: &Matrix) -> Result<IhtcResult> {
        self.run_with(points, &Executor::default(), &mut IhtcWorkspace::new())
    }

    /// Run IHTC on `points` over an explicit shared executor, reusing
    /// the given workspace's buffers. The whole pipeline — k-NN graph
    /// construction, prototype reduction, and (for k-means) the
    /// assignment phase — executes on that one thread team.
    pub fn run_with(
        &self,
        points: &Matrix,
        exec: &Executor,
        ws: &mut IhtcWorkspace,
    ) -> Result<IhtcResult> {
        let itis_cfg = ItisConfig {
            threshold: self.threshold,
            stop: crate::itis::StopRule::Iterations(self.iterations),
            prototype: self.prototype,
            seed_order: self.seed_order,
            min_prototypes: self.clusterer.min_prototypes(),
        };
        let reduction = if self.iterations == 0 {
            // m = 0: no pre-processing; identity ITIS result.
            ItisResult {
                levels: vec![],
                prototypes: points.clone(),
                weights: vec![1; points.rows()],
                n_original: points.rows(),
            }
        } else {
            let provider = PoolKnnProvider { exec, shards: self.knn_shards };
            itis_with_workspace(points, &itis_cfg, &provider, exec, &mut ws.itis)?
        };
        let protos = &reduction.prototypes;
        let prototype_labels: Vec<u32> = match &self.clusterer {
            FinalClusterer::KMeans { k, restarts } => {
                let cfg = kmeans::KMeansConfig {
                    restarts: (*restarts).max(1),
                    seed: self.seed,
                    bounds: self.kmeans_bounds,
                    ..kmeans::KMeansConfig::new((*k).min(protos.rows()))
                };
                kmeans::kmeans_pool(
                    protos,
                    None,
                    &cfg,
                    &kmeans::NativeAssign,
                    exec,
                    &mut ws.kmeans,
                )?
                .assignments
            }
            FinalClusterer::Hac { k, linkage } => {
                let cfg = hac::HacConfig { linkage: *linkage, ..Default::default() };
                hac::hac_cut(protos, (*k).min(protos.rows()), &cfg)?
            }
            FinalClusterer::Dbscan { eps, min_pts } => {
                dbscan::dbscan(protos, &dbscan::DbscanConfig { eps: *eps, min_pts: *min_pts })?
            }
            FinalClusterer::Gmm { k, weighted } => {
                let cfg = gmm::GmmConfig { seed: self.seed, ..gmm::GmmConfig::new((*k).min(protos.rows())) };
                let masses: Vec<f32>;
                let w = if *weighted {
                    masses = reduction.weights.iter().map(|&x| x as f32).collect();
                    Some(masses.as_slice())
                } else {
                    None
                };
                gmm::gmm(protos, w, &cfg)?.assignments
            }
        };
        let assignments = reduction.back_out(&prototype_labels)?;
        Ok(IhtcResult { assignments, prototype_labels, itis: reduction })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::hac::Linkage;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::metrics;

    #[test]
    fn m0_equals_plain_kmeans_accuracy() {
        let ds = gaussian_mixture_paper(2000, 111);
        let ih = Ihtc::new(2, 0, FinalClusterer::KMeans { k: 3, restarts: 4 });
        let r = ih.run(&ds.points).unwrap();
        let acc =
            metrics::prediction_accuracy(ds.labels.as_ref().unwrap(), &r.assignments).unwrap();
        assert!(acc > 0.85, "{acc}");
        assert_eq!(r.num_prototypes(), 2000);
    }

    #[test]
    fn accuracy_preserved_across_iterations() {
        // The paper's central claim (Table 1): accuracy stays ≈ constant
        // for the first few iterations.
        let ds = gaussian_mixture_paper(4000, 112);
        let truth = ds.labels.as_ref().unwrap();
        let base = Ihtc::new(2, 0, FinalClusterer::KMeans { k: 3, restarts: 4 })
            .run(&ds.points)
            .unwrap();
        let base_acc = metrics::prediction_accuracy(truth, &base.assignments).unwrap();
        for m in 1..=3 {
            let r = Ihtc::new(2, m, FinalClusterer::KMeans { k: 3, restarts: 4 })
                .run(&ds.points)
                .unwrap();
            let acc = metrics::prediction_accuracy(truth, &r.assignments).unwrap();
            assert!(
                acc > base_acc - 0.05,
                "m={m}: accuracy dropped {base_acc} → {acc}"
            );
        }
    }

    #[test]
    fn min_cluster_size_guarantee() {
        // IHTC ensures each final cluster has ≥ (t*)^m units (§3.2).
        let ds = gaussian_mixture_paper(3000, 113);
        for (t, m) in [(2usize, 3usize), (3, 2)] {
            let r = Ihtc::new(t, m, FinalClusterer::KMeans { k: 3, restarts: 2 })
                .run(&ds.points)
                .unwrap();
            let guarantee = t.pow(m as u32);
            let min = metrics::min_cluster_size(&r.assignments);
            assert!(
                min >= guarantee,
                "t*={t}, m={m}: min cluster {min} < {guarantee}"
            );
        }
    }

    #[test]
    fn prototype_count_shrinks_geometrically() {
        let ds = gaussian_mixture_paper(4096, 114);
        let mut last = usize::MAX;
        for m in 1..=4 {
            let r = Ihtc::new(2, m, FinalClusterer::KMeans { k: 3, restarts: 1 })
                .run(&ds.points)
                .unwrap();
            let np = r.num_prototypes();
            assert!(np <= 4096 / (1 << m));
            assert!(np < last);
            last = np;
        }
    }

    #[test]
    fn hac_hybrid_works_past_its_cap() {
        // HAC alone refuses big inputs; IHTC makes it feasible — the core
        // §4.2 story, scaled down: cap HAC at 200, cluster 2000 points.
        let ds = gaussian_mixture_paper(2000, 115);
        let direct = crate::cluster::hac::hac(
            &ds.points,
            &crate::cluster::hac::HacConfig { max_n: 200, ..Default::default() },
        );
        assert!(direct.is_err());
        let r = Ihtc::new(2, 4, FinalClusterer::Hac { k: 3, linkage: Linkage::Ward })
            .run(&ds.points)
            .unwrap();
        assert!(r.num_prototypes() <= 200, "prototypes={}", r.num_prototypes());
        let acc = metrics::prediction_accuracy(ds.labels.as_ref().unwrap(), &r.assignments)
            .unwrap();
        assert!(acc > 0.80, "{acc}");
    }

    #[test]
    fn dbscan_hybrid_propagates_noise() {
        let ds = gaussian_mixture_paper(1000, 116);
        let r = Ihtc::new(2, 1, FinalClusterer::Dbscan { eps: 0.6, min_pts: 4 })
            .run(&ds.points)
            .unwrap();
        assert_eq!(r.assignments.len(), 1000);
        // Any unit mapped to a noise prototype must itself be noise.
        let map = r.itis.unit_to_prototype();
        for i in 0..1000 {
            assert_eq!(r.assignments[i], r.prototype_labels[map[i] as usize]);
        }
    }

    #[test]
    fn gmm_hybrid_weighted_and_unweighted() {
        let ds = gaussian_mixture_paper(3000, 118);
        let truth = ds.labels.as_ref().unwrap();
        for weighted in [false, true] {
            let r = Ihtc::new(2, 2, FinalClusterer::Gmm { k: 3, weighted })
                .run(&ds.points)
                .unwrap();
            let acc = metrics::prediction_accuracy(truth, &r.assignments).unwrap();
            assert!(acc > 0.85, "weighted={weighted}: {acc}");
        }
    }

    #[test]
    fn run_with_reused_workspace_matches_run() {
        // Workspace reuse and team size must not change the clustering.
        let ds = gaussian_mixture_paper(3000, 119);
        let ih = Ihtc::new(2, 2, FinalClusterer::KMeans { k: 3, restarts: 2 });
        let fresh = ih.run(&ds.points).unwrap();
        let exec = Executor::new(3);
        let mut ws = IhtcWorkspace::new();
        let a = ih.run_with(&ds.points, &exec, &mut ws).unwrap();
        let b = ih.run_with(&ds.points, &exec, &mut ws).unwrap();
        assert_eq!(a.assignments, b.assignments, "reuse changed the result");
        assert_eq!(fresh.assignments, a.assignments, "team size changed the result");
        assert_eq!(fresh.num_prototypes(), a.num_prototypes());
    }

    #[test]
    fn labels_cover_all_units() {
        let ds = gaussian_mixture_paper(1500, 117);
        let r = Ihtc::new(2, 2, FinalClusterer::KMeans { k: 3, restarts: 2 })
            .run(&ds.points)
            .unwrap();
        assert_eq!(r.assignments.len(), 1500);
        let k = metrics::num_clusters(&r.assignments);
        assert!(k <= 3);
    }
}
