//! Crash-safe checkpointing for the fused streaming ingest.
//!
//! Long out-of-core runs die for mundane reasons — OOM kills, node
//! preemption, torn disks — and restarting a million-row ingest from
//! row zero forfeits everything the run already paid for. This module
//! makes the per-shard level-0 reduction durable: every
//! [`ReducedShard`] released by the pipeline's reorder stage is
//! appended to the checkpoint file as one length-prefixed,
//! CRC32-checked frame keyed by its stream offset — prototype rows,
//! weights, the shard's local assignment segment, optional ground-truth
//! labels, and the shard's standardization moments. Offsets must tile
//! the stream (the reorder contract), so the longest valid frame prefix
//! identifies an exact resume point: replay the frames, seek the source
//! to the first missing row, continue. Because each shard's reduction
//! is worker/stage invariant and moments merge in stream order, an
//! interrupted-then-resumed run is byte-identical to an uninterrupted
//! one.
//!
//! The same file doubles as the **disk-spilled level-0 map**: the
//! per-row `row → level-0 prototype` assignments are only ever read
//! once, sequentially, during back-out — so they live in the frames
//! instead of RAM ([`Level0Map`]), removing the last O(n) resident
//! buffer from streaming ingest. Runs without a configured
//! `checkpoint_path` spill to an anonymous temp file that is deleted
//! when the map drops.
//!
//! Durability protocol: frames append to `<path>.tmp`, fsynced at the
//! configured row cadence; a completed run fsyncs and atomically
//! renames the tmp onto `<path>`. On open, the reader CRC-verifies
//! every frame and truncates the file to the last valid one — a torn or
//! corrupted tail is recomputed from the source, never silently
//! consumed. [`FaultPlan`] threads deterministic failures (source
//! death, stage kill, sink write failure) through the driver so the
//! whole crash/recovery cycle is exercised in-tree, not hoped for.

use crate::coordinator::driver::Moments;
use crate::coordinator::pipeline::ReducedShard;
use crate::{Error, Result};
use std::fs::{self, File, OpenOptions};
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
// Raw std atomic, not the `crate::sync` facade: a file-scope static
// needs const construction, which loom's doubles do not offer — and the
// spill-sequence counter is process-global bookkeeping, not part of the
// modeled executor protocol.
// det-lint: allow(raw-atomic)
use std::sync::atomic::{AtomicU64, Ordering};

/// File magic: "IHTC checkpoint, format 1".
const MAGIC: [u8; 8] = *b"IHTCCKP1";
/// Header bytes: magic + u32 column count.
const HEADER_LEN: u64 = 12;
/// Sanity ceiling for one frame's payload: a corrupted length field
/// must read as a torn tail, not trigger a multi-gigabyte allocation.
const MAX_FRAME_BYTES: u64 = 1 << 32;

// ---------------------------------------------------------------------
// CRC32 (IEEE 802.3 reflected polynomial, the zlib/PNG variant) —
// hand-rolled because the crate has no external dependencies.

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC32 of `bytes` (IEEE, reflected — matches zlib's `crc32`).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------
// Shared framing helpers
//
// One frame = `payload_len: u64 LE` + payload + `crc32(payload): u32
// LE`. The checkpoint file and the distributed-lease wire protocol
// (`crate::dist`) both speak this layout; the *reader policies* differ
// by medium. A checkpoint tail may legitimately be torn (the process
// died mid-write), so `scan` below tolerates truncation by design. A
// socket, by contrast, has no legitimate torn state — a short or
// CRC-bad frame means a dead or corrupting peer, so [`read_frame_from`]
// turns it into a hard error.

/// Write one length-prefixed, CRC32-trailed frame to `w`.
pub fn write_frame_to(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(payload)?;
    w.write_all(&crc32(payload).to_le_bytes())
}

/// Read one frame from `r`, strictly: `Ok(None)` on clean EOF at a
/// frame boundary; a torn frame, an absurd length, or a CRC mismatch is
/// a hard [`Error::Data`] — never a silent truncation. This is the wire
/// discipline (`crate::dist`); the checkpoint file reader keeps its own
/// tolerant loop in `scan` because a torn *file* tail is recoverable.
pub fn read_frame_from(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 8];
    let got = read_up_to(r, &mut len_buf)?;
    if got == 0 {
        return Ok(None);
    }
    if got < len_buf.len() {
        return Err(Error::Data("frame: torn length field".into()));
    }
    let len = u64::from_le_bytes(len_buf);
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(Error::Data(format!("frame: corrupted length {len}")));
    }
    let mut payload = vec![0u8; len as usize];
    if read_up_to(r, &mut payload)? < payload.len() {
        return Err(Error::Data("frame: torn payload".into()));
    }
    let mut crc_buf = [0u8; 4];
    if read_up_to(r, &mut crc_buf)? < crc_buf.len() {
        return Err(Error::Data("frame: torn checksum".into()));
    }
    if crc32(&payload) != u32::from_le_bytes(crc_buf) {
        return Err(Error::Data("frame: checksum mismatch".into()));
    }
    Ok(Some(payload))
}

// ---------------------------------------------------------------------
// Fault injection

/// Deterministic fault injection for the streaming driver. Each field
/// names one crash site; `Default` injects nothing. Threaded through
/// [`crate::coordinator::driver::ingest_streaming_with_faults`] so the
/// crash/recovery cycle is pinned by tests rather than hoped for.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Fail the source with [`Error::Data`] before emitting the shard
    /// containing this row (shards entirely below it stream normally,
    /// so boundary and mid-shard crash points both reduce to "rows
    /// before the failing shard are durable").
    pub fail_source_at_row: Option<usize>,
    /// Panic the reduce stage handling the shard at this stream offset
    /// — a killed stage thread rather than a clean error, exercising
    /// the pipeline's panic-to-root-cause path.
    pub kill_reduce_at_offset: Option<usize>,
    /// Fail the checkpoint sink with [`Error::Coordinator`] instead of
    /// writing this frame index.
    pub fail_sink_at_frame: Option<usize>,
}

impl FaultPlan {
    /// A plan that injects nothing (the normal production path).
    pub fn none() -> Self {
        Self::default()
    }
}

// ---------------------------------------------------------------------
// Frame encoding

/// One decoded checkpoint frame: a released [`ReducedShard`] plus the
/// shard's standardization moments.
#[derive(Debug)]
pub struct Frame {
    /// Stream row offset of the shard (frames must tile the stream).
    pub offset: usize,
    /// Level-0 prototype rows (`proto_rows × d`, row-major).
    pub prototypes: Vec<f32>,
    /// Original units represented by each prototype.
    pub weights: Vec<u32>,
    /// Shard row → *local* prototype index (length = shard rows).
    pub assignments: Vec<u32>,
    /// Ground-truth labels for the shard's rows, when known.
    pub labels: Option<Vec<u32>>,
    /// The shard's first/second moments.
    pub moments: Moments,
}

fn encode_frame(shard: &ReducedShard, moments: &Moments) -> Vec<u8> {
    let d = shard.prototypes.cols();
    let proto_rows = shard.prototypes.rows();
    let rows = shard.assignments.len();
    debug_assert_eq!(moments.sum.len(), d);
    let labels_bytes = if shard.labels.is_some() { 4 * rows } else { 0 };
    let mut buf = Vec::with_capacity(
        25 + 8 * d + 8 * d * d + 4 * proto_rows * d + 4 * proto_rows + 4 * rows + labels_bytes,
    );
    buf.extend_from_slice(&(shard.offset as u64).to_le_bytes());
    buf.extend_from_slice(&(rows as u32).to_le_bytes());
    buf.extend_from_slice(&(proto_rows as u32).to_le_bytes());
    buf.push(u8::from(shard.labels.is_some()));
    buf.extend_from_slice(&(moments.count as u64).to_le_bytes());
    for v in &moments.sum {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    for v in &moments.cross {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    for v in shard.prototypes.data() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    for v in &shard.weights {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    for v in &shard.assignments {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    if let Some(labels) = &shard.labels {
        for v in labels {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    buf
}

/// Little-endian field reader over one frame payload. Shared with the
/// wire codec in `crate::dist`, whose payloads follow the same
/// pre-validate-total-length discipline.
pub(crate) struct Cursor<'a> {
    pub(crate) buf: &'a [u8],
    pub(crate) pos: usize,
}

impl<'a> Cursor<'a> {
    pub(crate) fn take(&mut self, n: usize) -> &'a [u8] {
        // decode_frame pre-validates the total payload length, so a
        // short take here is unreachable; slice indexing keeps it loud.
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        s
    }

    pub(crate) fn u8(&mut self) -> u8 {
        self.take(1)[0]
    }

    pub(crate) fn u32(&mut self) -> u32 {
        u32::from_le_bytes(self.take(4).try_into().unwrap())
    }

    pub(crate) fn u64(&mut self) -> u64 {
        u64::from_le_bytes(self.take(8).try_into().unwrap())
    }

    pub(crate) fn f32(&mut self) -> f32 {
        f32::from_le_bytes(self.take(4).try_into().unwrap())
    }

    pub(crate) fn f64(&mut self) -> f64 {
        f64::from_le_bytes(self.take(8).try_into().unwrap())
    }
}

/// Decode one CRC-verified payload. A structural mismatch after a CRC
/// pass means version skew or a writer bug, so it is a hard error — not
/// a torn tail to truncate.
fn decode_frame(payload: &[u8], d: usize) -> Result<Frame> {
    const FIXED: usize = 8 + 4 + 4 + 1 + 8; // offset, rows, proto_rows, flag, count
    if payload.len() < FIXED {
        return Err(Error::Data(
            "checkpoint frame: payload shorter than its fixed fields".into(),
        ));
    }
    let mut c = Cursor { buf: payload, pos: 0 };
    let offset = c.u64() as usize;
    let rows = c.u32() as usize;
    let proto_rows = c.u32() as usize;
    let has_labels = c.u8() != 0;
    let count = c.u64() as usize;
    let expect = FIXED
        + 8 * d
        + 8 * d * d
        + 4 * proto_rows * d
        + 4 * proto_rows
        + 4 * rows
        + if has_labels { 4 * rows } else { 0 };
    if payload.len() != expect {
        return Err(Error::Data(format!(
            "checkpoint frame at offset {offset}: payload is {} bytes but its declared shape \
             ({rows} rows, {proto_rows} prototypes, d={d}) needs {expect}",
            payload.len()
        )));
    }
    let mut moments = Moments::new(d);
    moments.count = count;
    for slot in moments.sum.iter_mut() {
        *slot = c.f64();
    }
    for slot in moments.cross.iter_mut() {
        *slot = c.f64();
    }
    let prototypes: Vec<f32> = (0..proto_rows * d).map(|_| c.f32()).collect();
    let weights: Vec<u32> = (0..proto_rows).map(|_| c.u32()).collect();
    let assignments: Vec<u32> = (0..rows).map(|_| c.u32()).collect();
    let labels = has_labels.then(|| (0..rows).map(|_| c.u32()).collect::<Vec<u32>>());
    Ok(Frame { offset, prototypes, weights, assignments, labels, moments })
}

// ---------------------------------------------------------------------
// Reader

/// Fill `buf` from `r`, tolerating EOF: returns the number of bytes
/// actually read (0 = clean EOF at a frame boundary).
fn read_up_to(r: &mut impl Read, buf: &mut [u8]) -> Result<usize> {
    let mut got = 0;
    while got < buf.len() {
        let n = r.read(&mut buf[got..])?;
        if n == 0 {
            break;
        }
        got += n;
    }
    Ok(got)
}

/// Iterate the valid frame prefix of `path`, calling `on_frame` for
/// each CRC-verified frame in file order. Returns `(d, valid_bytes,
/// clean)`: `valid_bytes` covers the header plus every valid frame, and
/// `clean` is false when a torn or corrupted tail was detected after
/// it. A missing/short header or wrong magic is a hard error (the
/// caller decides whether that means "fresh file" or "wrong file").
fn scan(path: &Path, mut on_frame: impl FnMut(Frame) -> Result<()>) -> Result<(usize, u64, bool)> {
    let mut r = BufReader::new(File::open(path)?);
    let mut header = [0u8; HEADER_LEN as usize];
    if read_up_to(&mut r, &mut header)? < header.len() {
        return Err(Error::Data(format!(
            "checkpoint {}: file too short for a header",
            path.display()
        )));
    }
    if header[..8] != MAGIC {
        return Err(Error::Data(format!(
            "checkpoint {}: bad magic — not an ihtc checkpoint file",
            path.display()
        )));
    }
    let d = u32::from_le_bytes(header[8..12].try_into().unwrap()) as usize;
    let mut valid = HEADER_LEN;
    loop {
        let mut len_buf = [0u8; 8];
        let got = read_up_to(&mut r, &mut len_buf)?;
        if got == 0 {
            return Ok((d, valid, true)); // clean EOF on a frame boundary
        }
        if got < len_buf.len() {
            return Ok((d, valid, false)); // torn length field
        }
        let len = u64::from_le_bytes(len_buf);
        if len == 0 || len > MAX_FRAME_BYTES {
            return Ok((d, valid, false)); // corrupted length
        }
        let mut payload = vec![0u8; len as usize];
        if read_up_to(&mut r, &mut payload)? < payload.len() {
            return Ok((d, valid, false)); // torn payload
        }
        let mut crc_buf = [0u8; 4];
        if read_up_to(&mut r, &mut crc_buf)? < crc_buf.len() {
            return Ok((d, valid, false)); // torn checksum
        }
        if crc32(&payload) != u32::from_le_bytes(crc_buf) {
            return Ok((d, valid, false)); // corrupted frame
        }
        on_frame(decode_frame(&payload, d)?)?;
        valid += 8 + len + 4;
    }
}

/// Everything a resumed run reconstructs from the valid frame prefix —
/// exactly the state the streaming collector would hold after folding
/// the same shards live (concatenation order, label flag semantics, and
/// the left-to-right f64 moment merge all mirror the collector, so the
/// replayed state is bit-identical).
#[derive(Debug)]
pub struct Replay {
    /// Column count (from the file header).
    pub d: usize,
    /// Stream rows covered by the valid prefix (= the first row the
    /// source must re-produce).
    pub rows: usize,
    /// Valid frames replayed.
    pub frames: usize,
    /// Concatenated prototype rows (`Σ proto_rows × d`).
    pub prototypes: Vec<f32>,
    /// Concatenated prototype weights.
    pub weights: Vec<u32>,
    /// Concatenated ground-truth labels (meaningful iff `have_labels`).
    pub labels: Vec<u32>,
    /// False as soon as any frame lacked labels.
    pub have_labels: bool,
    /// Moments merged in stream order (None when no frames replayed).
    pub moments: Option<Moments>,
    /// File length covered by the header + valid frames.
    valid_bytes: u64,
}

/// Replay the valid frame prefix of `path` into collector state,
/// verifying that frame offsets tile the stream from row 0.
pub fn replay(path: &Path) -> Result<Replay> {
    let mut rows = 0usize;
    let mut frames = 0usize;
    let mut prototypes: Vec<f32> = Vec::new();
    let mut weights: Vec<u32> = Vec::new();
    let mut labels: Vec<u32> = Vec::new();
    let mut have_labels = true;
    let mut moments: Option<Moments> = None;
    let (d, valid_bytes, _clean) = scan(path, |f| {
        if f.offset != rows {
            return Err(Error::Data(format!(
                "checkpoint {}: frame at offset {} does not tile the stream (expected {})",
                path.display(),
                f.offset,
                rows
            )));
        }
        rows += f.assignments.len();
        frames += 1;
        prototypes.extend_from_slice(&f.prototypes);
        weights.extend_from_slice(&f.weights);
        match f.labels {
            Some(l) => labels.extend(l),
            None => have_labels = false,
        }
        match &mut moments {
            Some(total) => total.merge(&f.moments),
            None => moments = Some(f.moments),
        }
        Ok(())
    })?;
    Ok(Replay { d, rows, frames, prototypes, weights, labels, have_labels, moments, valid_bytes })
}

/// Resolve the on-disk state of `dest` for a resuming run: prefer the
/// in-progress `<dest>.tmp` (a crashed run), fall back to a completed
/// `<dest>` (renamed back to tmp so the run can extend and re-finish
/// it), and report `None` when neither exists (fresh start). The
/// returned replay covers the longest valid frame prefix, and the tmp
/// file is physically truncated to it — a torn or corrupted tail is
/// recomputed from the source, never silently consumed.
pub fn prepare_resume(dest: &Path) -> Result<Option<Replay>> {
    let tmp = tmp_path(dest);
    if !tmp.exists() {
        if dest.exists() {
            fs::rename(dest, &tmp)?;
        } else {
            return Ok(None);
        }
    }
    if fs::metadata(&tmp)?.len() < HEADER_LEN {
        // Crashed before the header landed: nothing to replay. (A wrong
        // magic, by contrast, stays a hard error — never truncate a
        // file that was not ours.)
        fs::remove_file(&tmp)?;
        return Ok(None);
    }
    let rep = replay(&tmp)?;
    let f = OpenOptions::new().write(true).open(&tmp)?;
    f.set_len(rep.valid_bytes)?;
    f.sync_all()?;
    Ok(Some(rep))
}

/// The in-progress twin of a checkpoint destination (`<path>.tmp`).
pub fn tmp_path(dest: &Path) -> PathBuf {
    let mut os = dest.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// A fresh anonymous spill path in the system temp directory — used
/// when no `checkpoint_path` is configured, so the level-0 map still
/// leaves RAM. The file is deleted when its [`Level0Map`] drops.
pub fn spill_path() -> PathBuf {
    let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("ihtc_spill_{}_{seq}.ckpt", std::process::id()))
}

// ---------------------------------------------------------------------
// Writer

/// Append-only checkpoint writer. Durable writers (`create`/`resume`)
/// target `<dest>.tmp`, fsync at the configured row cadence, and
/// atomically rename onto `dest` at [`finish`](Self::finish); spill
/// writers (`create_spill`) skip every durability step — their only job
/// is evicting the level-0 map from RAM.
pub struct CheckpointWriter {
    file: BufWriter<File>,
    /// Where bytes are currently going (the tmp file for durable runs).
    path: PathBuf,
    /// Durable rename target; `None` marks an anonymous spill.
    dest: Option<PathBuf>,
    d: usize,
    rows: usize,
    frames: usize,
    sync_every_rows: usize,
    rows_since_sync: usize,
}

impl CheckpointWriter {
    /// Durable writer for a fresh run: truncates any stale
    /// `<dest>.tmp`, writes the header, fsyncs every `sync_every_rows`
    /// appended rows (0 = after every frame).
    pub fn create(dest: &Path, d: usize, sync_every_rows: usize) -> Result<Self> {
        Self::open_new(tmp_path(dest), Some(dest.to_path_buf()), d, sync_every_rows)
    }

    /// Non-durable spill writer: frames go straight to `path` with no
    /// fsync and no rename.
    pub fn create_spill(path: &Path, d: usize) -> Result<Self> {
        Self::open_new(path.to_path_buf(), None, d, usize::MAX)
    }

    fn open_new(
        path: PathBuf,
        dest: Option<PathBuf>,
        d: usize,
        sync_every_rows: usize,
    ) -> Result<Self> {
        let mut file = BufWriter::new(File::create(&path)?);
        file.write_all(&MAGIC)?;
        file.write_all(&(d as u32).to_le_bytes())?;
        Ok(Self { file, path, dest, d, rows: 0, frames: 0, sync_every_rows, rows_since_sync: 0 })
    }

    /// Reopen the tmp file [`prepare_resume`] truncated and append
    /// after its last valid frame.
    pub fn resume(dest: &Path, rep: &Replay, sync_every_rows: usize) -> Result<Self> {
        let tmp = tmp_path(dest);
        let mut f = OpenOptions::new().write(true).open(&tmp)?;
        f.seek(SeekFrom::End(0))?;
        Ok(Self {
            file: BufWriter::new(f),
            path: tmp,
            dest: Some(dest.to_path_buf()),
            d: rep.d,
            rows: rep.rows,
            frames: rep.frames,
            sync_every_rows,
            rows_since_sync: 0,
        })
    }

    /// Stream rows covered by the frames written (and replayed) so far.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Frames written (and replayed) so far.
    pub fn frames(&self) -> usize {
        self.frames
    }

    /// Append one released shard (plus its moments) as a frame. Frames
    /// must tile the stream: `shard.offset` must equal the rows already
    /// covered, mirroring the reorder stage's release contract.
    pub fn append(&mut self, shard: &ReducedShard, moments: &Moments) -> Result<()> {
        if shard.offset != self.rows {
            return Err(Error::Coordinator(format!(
                "checkpoint frames must tile the stream: shard at offset {} arrived after only \
                 {} checkpointed rows",
                shard.offset, self.rows
            )));
        }
        if shard.prototypes.cols() != self.d {
            return Err(Error::Coordinator(format!(
                "checkpoint dimensionality changed mid-stream: shard has d={} but the file \
                 header says d={}",
                shard.prototypes.cols(),
                self.d
            )));
        }
        let payload = encode_frame(shard, moments);
        write_frame_to(&mut self.file, &payload)?;
        self.rows += shard.assignments.len();
        self.frames += 1;
        if self.dest.is_some() {
            self.rows_since_sync += shard.assignments.len();
            if self.sync_every_rows == 0 || self.rows_since_sync >= self.sync_every_rows {
                self.file.flush()?;
                self.file.get_ref().sync_data()?;
                self.rows_since_sync = 0;
            }
        }
        Ok(())
    }

    /// Seal the checkpoint and hand the file over as the run's spilled
    /// level-0 map. Durable writers fsync and atomically rename the tmp
    /// onto the destination (plus a best-effort directory fsync); spill
    /// writers just flush and mark the file for deletion on drop.
    pub fn finish(mut self) -> Result<Level0Map> {
        self.file.flush()?;
        let rows = self.rows;
        match self.dest {
            Some(dest) => {
                self.file.get_ref().sync_all()?;
                drop(self.file);
                fs::rename(&self.path, &dest)?;
                sync_parent_dir(&dest);
                Ok(Level0Map { path: dest, rows, owned: false })
            }
            None => {
                drop(self.file);
                Ok(Level0Map { path: self.path, rows, owned: true })
            }
        }
    }

    /// Salvage on a failed run: flush + fsync whatever was appended so
    /// a later `resume: true` can replay it; anonymous spills are
    /// deleted instead. Errors are swallowed — this runs on a path that
    /// is already failing.
    pub fn abort(mut self) {
        let durable = self.dest.is_some();
        let _ = self.file.flush();
        if durable {
            let _ = self.file.get_ref().sync_all();
        }
        drop(self.file);
        if !durable {
            let _ = fs::remove_file(&self.path);
        }
    }
}

/// fsync the directory containing `path` so a completed rename survives
/// power loss. Best effort — not every platform lets a directory be
/// opened as a file.
fn sync_parent_dir(path: &Path) {
    if let Some(parent) = path.parent() {
        if let Ok(dir) = File::open(parent) {
            let _ = dir.sync_all();
        }
    }
}

// ---------------------------------------------------------------------
// Spilled level-0 map

/// Handle to the disk-spilled level-0 assignment map: the checkpoint
/// file itself, read once, sequentially, during back-out — the O(n)
/// vector the streaming collector used to hold in RAM. Anonymous spills
/// own their file and delete it on drop; user-configured checkpoints
/// are left on disk.
#[derive(Debug)]
pub struct Level0Map {
    path: PathBuf,
    rows: usize,
    owned: bool,
}

impl Level0Map {
    /// Open an existing finished checkpoint as a level-0 map (full
    /// CRC-verifying scan to count rows). The file is not deleted on
    /// drop.
    pub fn open(path: &Path) -> Result<Self> {
        let rep = replay(path)?;
        Ok(Self { path: path.to_path_buf(), rows: rep.rows, owned: false })
    }

    /// Stream rows covered by the map.
    pub fn len(&self) -> usize {
        self.rows
    }

    /// True when the map covers no rows.
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// The backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Materialize every row's *global* level-0 prototype id (each
    /// frame's local ids rebased by the prototypes before it) — the
    /// vector the collector used to hold resident. Intended for tests
    /// and small runs; back-out streams the file instead.
    pub fn read_assignments(&self) -> Result<Vec<u32>> {
        self.fold(None)
    }

    /// IHTC back-out over the spilled map: `lookup` maps global level-0
    /// prototype id → final cluster label; returns one label per
    /// original row, in stream order, from a single sequential read.
    pub fn back_out(&self, lookup: &[u32]) -> Result<Vec<u32>> {
        self.fold(Some(lookup))
    }

    fn fold(&self, lookup: Option<&[u32]>) -> Result<Vec<u32>> {
        let mut out: Vec<u32> = Vec::with_capacity(self.rows);
        let mut base = 0u64;
        let mut rows = 0usize;
        let (_d, _valid, clean) = scan(&self.path, |f| {
            if f.offset != rows {
                return Err(Error::Data(format!(
                    "level-0 map {}: frame at offset {} does not tile the stream (expected {})",
                    self.path.display(),
                    f.offset,
                    rows
                )));
            }
            rows += f.assignments.len();
            for &a in &f.assignments {
                let g = base + a as u64;
                match lookup {
                    Some(l) => {
                        let label = l.get(g as usize).ok_or_else(|| {
                            Error::Shape(format!(
                                "level-0 map {}: prototype id {g} out of range for {} labels",
                                self.path.display(),
                                l.len()
                            ))
                        })?;
                        out.push(*label);
                    }
                    None => out.push(g as u32),
                }
            }
            base += f.weights.len() as u64;
            Ok(())
        })?;
        if !clean || rows != self.rows {
            return Err(Error::Data(format!(
                "level-0 map {}: expected {} rows but only {} replay cleanly — the spill file \
                 changed under the run",
                self.path.display(),
                self.rows,
                rows
            )));
        }
        Ok(out)
    }
}

impl Drop for Level0Map {
    fn drop(&mut self) {
        if self.owned {
            let _ = fs::remove_file(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn test_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("ihtc_ckpt_unit").join(name);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Two tiny deterministic shards (d = 2) tiling rows [0, 5).
    fn fixture_shards() -> Vec<(ReducedShard, Moments)> {
        let mut out = Vec::new();
        let specs: [(usize, usize, usize); 2] = [(0, 3, 2), (3, 2, 1)];
        for (offset, rows, protos) in specs {
            let data: Vec<f32> = (0..protos * 2).map(|i| (offset + i) as f32 * 0.5).collect();
            let prototypes = Matrix::from_vec(data, protos, 2).unwrap();
            let shard = ReducedShard {
                offset,
                prototypes,
                weights: (0..protos as u32).map(|w| w + 1).collect(),
                assignments: (0..rows as u32).map(|r| r % protos as u32).collect(),
                labels: Some((0..rows as u32).map(|r| r % 3).collect()),
            };
            let mut moments = Moments::new(2);
            moments.count = rows;
            moments.sum = vec![offset as f64, rows as f64];
            moments.cross = vec![1.0, 2.0, 3.0, 4.0];
            out.push((shard, moments));
        }
        out
    }

    fn write_fixture(dest: &Path) -> CheckpointWriter {
        let mut w = CheckpointWriter::create(dest, 2, 0).unwrap();
        for (shard, mo) in fixture_shards() {
            w.append(&shard, &mo).unwrap();
        }
        w
    }

    #[test]
    fn crc32_matches_the_standard_vector() {
        // The classic IEEE-802.3 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn miri_strict_frame_roundtrip_and_rejections() {
        // write_frame_to / read_frame_from are the wire discipline:
        // round-trip is exact, and *every* truncation or corruption is a
        // hard error (a socket has no legitimate torn state).
        let mut buf = Vec::new();
        write_frame_to(&mut buf, b"hello").unwrap();
        write_frame_to(&mut buf, &[0u8; 3]).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame_from(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame_from(&mut r).unwrap().unwrap(), vec![0u8; 3]);
        assert!(read_frame_from(&mut r).unwrap().is_none()); // clean EOF

        // Truncation anywhere strictly inside a frame is a hard error.
        let mut one = Vec::new();
        write_frame_to(&mut one, b"payload").unwrap();
        for cut in 1..one.len() {
            let mut r = &one[..cut];
            assert!(read_frame_from(&mut r).is_err(), "cut at {cut} must be torn");
        }
        // A flipped payload byte fails the checksum.
        let mut bad = one.clone();
        bad[10] ^= 0x01;
        assert!(read_frame_from(&mut &bad[..]).is_err());
        // Zero-length and absurd-length frames are rejected.
        let zero = 0u64.to_le_bytes();
        assert!(read_frame_from(&mut &zero[..]).is_err());
        let huge = (MAX_FRAME_BYTES + 1).to_le_bytes();
        assert!(read_frame_from(&mut &huge[..]).is_err());
    }

    // The `miri_frame_codec_*` tests below are pure in-memory (no
    // filesystem, no threads): they are the checkpoint slice of the CI
    // Miri lane, where the codec's slice indexing and byte-level
    // reinterpretation run under the interpreter's UB checks.

    #[test]
    fn miri_frame_codec_roundtrip_is_exact() {
        for (shard, mo) in fixture_shards() {
            let payload = encode_frame(&shard, &mo);
            let frame = decode_frame(&payload, 2).unwrap();
            assert_eq!(frame.offset, shard.offset);
            assert_eq!(frame.prototypes, shard.prototypes.data());
            assert_eq!(frame.weights, shard.weights);
            assert_eq!(frame.assignments, shard.assignments);
            assert_eq!(frame.labels, shard.labels);
            assert_eq!(frame.moments.count, mo.count);
            assert_eq!(frame.moments.sum, mo.sum);
            assert_eq!(frame.moments.cross, mo.cross);
        }
        // Label-less shards take the shorter layout and round-trip too.
        let (mut shard, mo) = fixture_shards().remove(0);
        shard.labels = None;
        let frame = decode_frame(&encode_frame(&shard, &mo), 2).unwrap();
        assert!(frame.labels.is_none());
        assert_eq!(frame.assignments, shard.assignments);
    }

    #[test]
    fn miri_frame_codec_rejects_every_truncation() {
        // decode_frame pre-validates the total length, so `Cursor::take`
        // can never slice out of bounds: chopping the payload at *any*
        // byte must yield Err, never a panic or an out-of-bounds read
        // (under Miri the latter would be caught as UB, not just a test
        // failure).
        let (shard, mo) = fixture_shards().remove(0);
        let payload = encode_frame(&shard, &mo);
        for cut in 0..payload.len() {
            assert!(
                decode_frame(&payload[..cut], 2).is_err(),
                "truncation to {cut}/{} bytes must be rejected",
                payload.len()
            );
        }
        // Extra trailing bytes are a shape mismatch, not extra frames.
        let mut padded = payload.clone();
        padded.push(0);
        assert!(decode_frame(&padded, 2).is_err());
    }

    #[test]
    fn miri_frame_codec_rejects_shape_lies() {
        // A CRC-valid payload whose declared shape disagrees with its
        // length is version skew / writer bug — hard error either way
        // the disagreement points.
        let (shard, mo) = fixture_shards().remove(0);
        let payload = encode_frame(&shard, &mo);
        // Inflate the declared row count (bytes 8..12, little-endian).
        let mut lied = payload.clone();
        lied[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_frame(&lied, 2).is_err());
        // Decode under the wrong dimensionality.
        assert!(decode_frame(&payload, 3).is_err());
        assert!(decode_frame(&payload, 0).is_err());
    }

    #[test]
    fn roundtrip_replay_reconstructs_collector_state() {
        let dest = test_dir("roundtrip").join("run.ckpt");
        let map = write_fixture(&dest).finish().unwrap();
        assert_eq!(map.len(), 5);
        assert_eq!(map.path(), dest.as_path());

        let rep = replay(&dest).unwrap();
        assert_eq!(rep.d, 2);
        assert_eq!(rep.rows, 5);
        assert_eq!(rep.frames, 2);
        let shards = fixture_shards();
        let want_protos: Vec<f32> = shards
            .iter()
            .flat_map(|(s, _)| s.prototypes.data().to_vec())
            .collect();
        assert_eq!(rep.prototypes, want_protos);
        assert_eq!(rep.weights, vec![1, 2, 1]);
        assert!(rep.have_labels);
        assert_eq!(rep.labels, vec![0, 1, 2, 0, 1]);
        let mo = rep.moments.unwrap();
        assert_eq!(mo.count, 5);
        assert_eq!(mo.sum, vec![3.0, 5.0]);
        assert_eq!(mo.cross, vec![2.0, 4.0, 6.0, 8.0]);

        // Global rebasing: frame 2's local ids shift by frame 1's 2
        // prototypes.
        assert_eq!(map.read_assignments().unwrap(), vec![0, 1, 0, 2, 2]);
        // Back-out maps global prototype ids through the lookup.
        assert_eq!(map.back_out(&[7, 8, 9]).unwrap(), vec![7, 8, 7, 9, 9]);
        assert!(map.back_out(&[7]).is_err());
    }

    #[test]
    fn frames_must_tile_the_stream() {
        let dest = test_dir("tiling").join("run.ckpt");
        let mut w = CheckpointWriter::create(&dest, 2, 0).unwrap();
        let (mut shard, mo) = fixture_shards().remove(1);
        shard.offset = 7; // first frame must start at row 0
        let err = w.append(&shard, &mo).unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)), "{err}");
        assert!(err.to_string().contains("tile"), "{err}");
        w.abort();
    }

    #[test]
    fn torn_tail_is_truncated_to_last_valid_frame() {
        let dest = test_dir("torn").join("run.ckpt");
        write_fixture(&dest).abort(); // durable abort keeps the tmp
        let tmp = tmp_path(&dest);
        let whole = fs::metadata(&tmp).unwrap().len();

        // Garbage appended after the last frame: both frames survive.
        let mut f = OpenOptions::new().append(true).open(&tmp).unwrap();
        f.write_all(&[0xAB; 11]).unwrap();
        drop(f);
        let rep = prepare_resume(&dest).unwrap().unwrap();
        assert_eq!((rep.rows, rep.frames), (5, 2));
        assert_eq!(fs::metadata(&tmp).unwrap().len(), whole);

        // Tear the last frame's checksum off: frame 2 is dropped.
        let f = OpenOptions::new().write(true).open(&tmp).unwrap();
        f.set_len(whole - 2).unwrap();
        drop(f);
        let rep = prepare_resume(&dest).unwrap().unwrap();
        assert_eq!((rep.rows, rep.frames), (3, 1));
        assert!(fs::metadata(&tmp).unwrap().len() < whole - 2);
    }

    #[test]
    fn corrupted_tail_is_detected_never_silently_consumed() {
        let dest = test_dir("corrupt").join("run.ckpt");
        write_fixture(&dest).abort();
        let tmp = tmp_path(&dest);
        // Flip one byte inside the last frame's payload.
        let mut bytes = fs::read(&tmp).unwrap();
        let n = bytes.len();
        bytes[n - 10] ^= 0xFF;
        fs::write(&tmp, &bytes).unwrap();
        let rep = prepare_resume(&dest).unwrap().unwrap();
        assert_eq!((rep.rows, rep.frames), (3, 1));
        // And the resumed writer appends cleanly after the good frame.
        let mut w = CheckpointWriter::resume(&dest, &rep, 0).unwrap();
        let (mut shard, mo) = fixture_shards().remove(1);
        shard.offset = 3;
        w.append(&shard, &mo).unwrap();
        let map = w.finish().unwrap();
        assert_eq!(map.len(), 5);
        assert_eq!(map.read_assignments().unwrap(), vec![0, 1, 0, 2, 2]);
    }

    #[test]
    fn wrong_magic_is_a_hard_error() {
        let dir = test_dir("magic");
        let path = dir.join("not_a_checkpoint.ckpt");
        fs::write(&path, b"definitely,not,a,checkpoint,file").unwrap();
        let err = replay(&path).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        // prepare_resume must refuse to truncate a foreign file too.
        fs::write(tmp_path(&path), b"also definitely not a checkpoint").unwrap();
        assert!(prepare_resume(&path).is_err());
    }

    #[test]
    fn header_only_crash_restarts_fresh() {
        let dest = test_dir("headercrash").join("run.ckpt");
        let tmp = tmp_path(&dest);
        fs::write(&tmp, &MAGIC[..4]).unwrap(); // died mid-header
        assert!(prepare_resume(&dest).unwrap().is_none());
        assert!(!tmp.exists());
        assert!(prepare_resume(&dest).unwrap().is_none()); // nothing at all
    }

    #[test]
    fn finished_checkpoint_resumes_via_rename() {
        let dest = test_dir("finished").join("run.ckpt");
        write_fixture(&dest).finish().unwrap();
        assert!(dest.exists());
        let rep = prepare_resume(&dest).unwrap().unwrap();
        assert_eq!((rep.rows, rep.frames), (5, 2));
        assert!(!dest.exists());
        assert!(tmp_path(&dest).exists());
        // Re-finishing restores the durable file.
        let map = CheckpointWriter::resume(&dest, &rep, 0).unwrap().finish().unwrap();
        assert!(dest.exists());
        assert_eq!(map.len(), 5);
    }

    #[test]
    fn anonymous_spill_is_deleted_on_drop() {
        let path = spill_path();
        let mut w = CheckpointWriter::create_spill(&path, 2).unwrap();
        for (shard, mo) in fixture_shards() {
            w.append(&shard, &mo).unwrap();
        }
        let map = w.finish().unwrap();
        assert!(path.exists());
        assert_eq!(map.read_assignments().unwrap(), vec![0, 1, 0, 2, 2]);
        drop(map);
        assert!(!path.exists());
    }

    #[test]
    fn labelless_frames_clear_the_label_flag() {
        let dest = test_dir("labels").join("run.ckpt");
        let mut w = CheckpointWriter::create(&dest, 2, 0).unwrap();
        let mut shards = fixture_shards();
        let (mut shard1, mo1) = shards.pop().unwrap();
        let (shard0, mo0) = shards.pop().unwrap();
        w.append(&shard0, &mo0).unwrap();
        shard1.labels = None;
        w.append(&shard1, &mo1).unwrap();
        w.finish().unwrap();
        let rep = replay(&dest).unwrap();
        assert!(!rep.have_labels);
        assert_eq!(rep.labels, vec![0, 1, 2]); // frame 1's labels only
    }
}
