//! ITIS — iterated threshold instance selection (§3.1).
//!
//! Given a size threshold `t*`, each iteration (i) threshold-clusters the
//! current point set, (ii) collapses every cluster to a **prototype**
//! (its centroid or medoid), and (iii) repeats on the prototypes until
//! the requested reduction is reached. `m` iterations reduce `n` by at
//! least a factor `(t*)^m` and cost `O(t*·m·n·log n)`.
//!
//! The full chain of per-level assignments is retained so a clustering of
//! the final prototypes can be "backed out" onto the original units
//! (IHTC step 3) by composing the maps.

use crate::exec::Executor;
use crate::knn::forest::KdForest;
use crate::knn::graph::{GraphScratch, NeighborGraph};
use crate::knn::KnnLists;
use crate::linalg::Matrix;
use crate::tc::{threshold_cluster, threshold_cluster_graph, TcConfig, TcResult};
use crate::{Error, Result};

/// Pluggable k-NN backend for ITIS's inner loop: the coordinator injects
/// its sharded/PJRT implementation here while the default goes through
/// [`crate::knn::knn_auto`] (pool-sharded itself since the §Perf pass).
pub trait KnnProvider {
    /// Exact k-NN lists for all rows of `points`.
    fn knn(&self, points: &Matrix, k: usize) -> Result<KnnLists>;

    /// Fill `out` in place, reusing its buffers across calls — the ITIS
    /// loop's per-iteration allocation-reuse hook. Defaults to
    /// [`Self::knn`] (which allocates); pooled providers override it.
    fn knn_into(&self, points: &Matrix, k: usize, out: &mut KnnLists) -> Result<()> {
        *out = self.knn(points, k)?;
        Ok(())
    }

    /// Workspace-aware variant for providers with a sharded kd-forest
    /// backend: `forest` is the caller's reusable per-shard index (the
    /// ITIS loop passes [`ItisWorkspace::forest`], so shard trees are
    /// rebuilt in place level after level). The default ignores the
    /// forest and delegates to [`Self::knn_into`]; only
    /// [`crate::coordinator::PoolKnnProvider`] with `knn_shards > 1`
    /// actually uses it.
    fn knn_forest_into(
        &self,
        points: &Matrix,
        k: usize,
        forest: &mut KdForest,
        out: &mut KnnLists,
    ) -> Result<()> {
        let _ = forest;
        self.knn_into(points, k, out)
    }
}

/// Default provider: best exact backend on a default executor.
pub struct DefaultKnn;

impl KnnProvider for DefaultKnn {
    fn knn(&self, points: &Matrix, k: usize) -> Result<KnnLists> {
        crate::knn::knn_auto(points, k)
    }

    fn knn_into(&self, points: &Matrix, k: usize, out: &mut KnnLists) -> Result<()> {
        crate::knn::knn_auto_into(points, k, &Executor::default(), out)
    }
}

/// Reusable scratch arena for the ITIS reduction loop: the step-1
/// neighbor lists (the dominant `n×k` allocation), the sharded kd-forest
/// index, the symmetrized neighbor graph (edge list + CSR), and the
/// prototype accumulation buffers are all reused across TC rounds — and
/// across whole `itis` runs when the caller holds onto the workspace
/// (see [`crate::hybrid::IhtcWorkspace`]). Level sizes shrink
/// geometrically, so after the first iteration the loop allocates only
/// the prototype matrices it returns.
#[derive(Debug, Default)]
pub struct ItisWorkspace {
    /// Step-1 neighbor lists (`n × (t*−1)`).
    pub knn: KnnLists,
    /// Sharded kd-forest index (per-shard trees and their arenas),
    /// rebuilt in place each level; only touched when the provider runs
    /// with `knn_shards > 1`.
    pub forest: KdForest,
    /// Symmetrized `NG_k`, rebuilt in place each level.
    pub graph: NeighborGraph,
    /// Edge-list/cursor scratch for the graph rebuild.
    graph_scratch: GraphScratch,
    /// Per-cluster weighted coordinate sums (`k × d`).
    sums: Vec<f64>,
    /// Per-cluster accumulation weights.
    wsum: Vec<u64>,
}

impl ItisWorkspace {
    /// Empty workspace; buffers grow on first use and are then reused.
    pub fn new() -> Self {
        Self::default()
    }
}

/// How prototypes summarize their cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrototypeKind {
    /// Cluster centroid (mean) — the paper's default.
    Centroid,
    /// Cluster centroid weighted by the number of *original* units each
    /// point represents (extension; exact mean of the represented units).
    WeightedCentroid,
    /// Cluster medoid: the member minimizing total dissimilarity to the
    /// other members (stays on a real data point).
    Medoid,
}

/// Stopping rule for the iteration (§3.1 step 3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StopRule {
    /// Run exactly `m` iterations.
    Iterations(usize),
    /// Stop once `n / n*` ≥ `alpha`.
    ReductionFactor(f64),
    /// Stop once the prototype count is ≤ this target.
    TargetSize(usize),
}

/// ITIS configuration.
#[derive(Clone, Debug)]
pub struct ItisConfig {
    /// TC size threshold `t*`.
    pub threshold: usize,
    /// Stopping rule.
    pub stop: StopRule,
    /// Prototype kind.
    pub prototype: PrototypeKind,
    /// TC seed order (passed through).
    pub seed_order: crate::tc::SeedOrder,
    /// Never reduce below this many prototypes (guards the final
    /// clustering step, e.g. k-means needs ≥ k points).
    pub min_prototypes: usize,
}

impl ItisConfig {
    /// Paper defaults: centroid prototypes, `m` iterations at threshold `t*`.
    pub fn iterations(threshold: usize, m: usize) -> Self {
        Self {
            threshold,
            stop: StopRule::Iterations(m),
            prototype: PrototypeKind::Centroid,
            seed_order: crate::tc::SeedOrder::Natural,
            min_prototypes: 1,
        }
    }

    /// Reduce until `n/n* ≥ alpha`.
    pub fn reduction(threshold: usize, alpha: f64) -> Self {
        Self {
            threshold,
            stop: StopRule::ReductionFactor(alpha),
            prototype: PrototypeKind::Centroid,
            seed_order: crate::tc::SeedOrder::Natural,
            min_prototypes: 1,
        }
    }

    /// The streaming level-0 shard reduction: exactly one TC pass with
    /// weight-exact prototypes. Shared by the in-process ingest stage
    /// and the distributed worker (`crate::dist`) so a leased shard is
    /// reduced under byte-identical configuration on either side of the
    /// socket.
    pub fn level0(threshold: usize, seed_order: crate::tc::SeedOrder) -> Self {
        Self {
            threshold,
            stop: StopRule::Iterations(1),
            prototype: PrototypeKind::WeightedCentroid,
            seed_order,
            min_prototypes: 1,
        }
    }
}

/// One ITIS level: the TC assignment of level-`i` points to level-`i+1`
/// prototypes, and the prototypes themselves.
#[derive(Clone, Debug)]
pub struct ItisLevel {
    /// `points_at_level[i] → prototype index` (length = level size).
    pub assignments: Vec<u32>,
    /// Number of prototypes formed (next level's size).
    pub num_prototypes: usize,
}

/// Full ITIS output.
#[derive(Clone, Debug)]
pub struct ItisResult {
    /// Per-iteration assignment maps, first applies to the original data.
    pub levels: Vec<ItisLevel>,
    /// Final prototype matrix (`n* × d`).
    pub prototypes: Matrix,
    /// Number of original units each final prototype represents.
    pub weights: Vec<u32>,
    /// Original `n`.
    pub n_original: usize,
}

impl ItisResult {
    /// Achieved reduction factor `n / n*`.
    pub fn reduction_factor(&self) -> f64 {
        self.n_original as f64 / self.prototypes.rows().max(1) as f64
    }

    /// Number of iterations actually performed.
    pub fn iterations(&self) -> usize {
        self.levels.len()
    }

    /// Map every original unit to its final prototype by composing the
    /// per-level assignment maps.
    pub fn unit_to_prototype(&self) -> Vec<u32> {
        let mut map: Vec<u32> = (0..self.n_original as u32).collect();
        for level in &self.levels {
            for slot in map.iter_mut() {
                *slot = level.assignments[*slot as usize];
            }
        }
        map
    }

    /// IHTC step 3 ("back out"): given a clustering of the final
    /// prototypes, produce the clustering of all original units.
    pub fn back_out(&self, prototype_labels: &[u32]) -> Result<Vec<u32>> {
        if prototype_labels.len() != self.prototypes.rows() {
            return Err(Error::Shape(format!(
                "{} prototype labels for {} prototypes",
                prototype_labels.len(),
                self.prototypes.rows()
            )));
        }
        // Guard the composition: the first level must map every original
        // unit. A result from `itis_resume` whose caller forgot to
        // prepend its level-0 map would otherwise panic on indexing.
        if let Some(first) = self.levels.first() {
            if first.assignments.len() != self.n_original {
                return Err(Error::Shape(format!(
                    "first level maps {} units but n_original is {} \
                     (itis_resume callers must prepend their level-0 map)",
                    first.assignments.len(),
                    self.n_original
                )));
            }
        }
        Ok(self
            .unit_to_prototype()
            .into_iter()
            .map(|p| prototype_labels[p as usize])
            .collect())
    }
}

/// Accumulate prototype sums for the clusters in `[c0, c0+len)` only.
/// The parallel reduction partitions *cluster ids* (not points) across
/// workers: every worker scans the whole assignment vector but owns a
/// disjoint slice of the accumulators, so there are no write conflicts,
/// no per-worker `k×d` copies, and — because each cluster's members are
/// visited in point order regardless of the partitioning — the result is
/// byte-identical to the serial reduction for any worker count.
#[allow(clippy::too_many_arguments)]
fn accumulate_range(
    points: &Matrix,
    weights: &[u32],
    assignments: &[u32],
    kind: PrototypeKind,
    c0: usize,
    len: usize,
    sums: &mut [f64],
    wsum: &mut [u64],
    new_weights: &mut [u32],
) {
    let d = points.cols();
    for (i, &a) in assignments.iter().enumerate() {
        let a = a as usize;
        if a < c0 || a >= c0 + len {
            continue;
        }
        let c = a - c0;
        let w = match kind {
            PrototypeKind::WeightedCentroid => weights[i] as u64,
            _ => 1,
        };
        wsum[c] += w;
        new_weights[c] += weights[i];
        let row = points.row(i);
        let acc = &mut sums[c * d..(c + 1) * d];
        for (slot, &x) in acc.iter_mut().zip(row) {
            *slot += x as f64 * w as f64;
        }
    }
}

/// Compute prototypes for one TC level, accumulating in parallel over
/// the executor (for large levels) into the workspace's reused buffers.
fn make_prototypes(
    points: &Matrix,
    weights: &[u32],
    tc: &TcResult,
    kind: PrototypeKind,
    exec: &Executor,
    ws: &mut ItisWorkspace,
) -> Result<(Matrix, Vec<u32>)> {
    let d = points.cols();
    let k = tc.num_clusters;
    ws.sums.clear();
    ws.sums.resize(k * d, 0.0);
    ws.wsum.clear();
    ws.wsum.resize(k, 0);
    let mut new_weights = vec![0u32; k];
    let nparts = if exec.workers() > 1 && k >= 64 && points.rows() >= 8192 {
        exec.workers().min(k)
    } else {
        1
    };
    if nparts <= 1 {
        accumulate_range(
            points,
            weights,
            &tc.assignments,
            kind,
            0,
            k,
            &mut ws.sums,
            &mut ws.wsum,
            &mut new_weights,
        );
    } else {
        // Partition cluster ids into contiguous ranges; each task owns
        // the matching accumulator windows.
        let base = k / nparts;
        let rem = k % nparts;
        let mut tasks: Vec<(usize, usize, &mut [f64], &mut [u64], &mut [u32])> =
            Vec::with_capacity(nparts);
        let mut sums_rest: &mut [f64] = &mut ws.sums;
        let mut wsum_rest: &mut [u64] = &mut ws.wsum;
        let mut nw_rest: &mut [u32] = &mut new_weights;
        let mut c0 = 0usize;
        for p in 0..nparts {
            let len = base + usize::from(p < rem);
            let (s, s_tail) = std::mem::take(&mut sums_rest).split_at_mut(len * d);
            sums_rest = s_tail;
            let (w, w_tail) = std::mem::take(&mut wsum_rest).split_at_mut(len);
            wsum_rest = w_tail;
            let (nw, nw_tail) = std::mem::take(&mut nw_rest).split_at_mut(len);
            nw_rest = nw_tail;
            tasks.push((c0, len, s, w, nw));
            c0 += len;
        }
        exec.run_tasks(tasks, |(c0, len, s, w, nw)| {
            accumulate_range(points, weights, &tc.assignments, kind, c0, len, s, w, nw);
            Ok(())
        })?;
    }
    let mut protos = Matrix::zeros(k, d);
    for c in 0..k {
        let denom = ws.wsum[c].max(1) as f64;
        let row = protos.row_mut(c);
        for (j, slot) in row.iter_mut().enumerate() {
            *slot = (ws.sums[c * d + j] / denom) as f32;
        }
    }
    if kind == PrototypeKind::Medoid {
        // Snap each centroid to the nearest member of its cluster.
        let mut best = vec![(f32::INFINITY, 0u32); k];
        for (i, &c) in tc.assignments.iter().enumerate() {
            let c = c as usize;
            let d2 = crate::linalg::sq_dist(points.row(i), protos.row(c));
            if d2 < best[c].0 {
                best[c] = (d2, i as u32);
            }
        }
        for c in 0..k {
            let src = points.row(best[c].1 as usize).to_vec();
            protos.row_mut(c).copy_from_slice(&src);
        }
    }
    Ok((protos, new_weights))
}

/// Run ITIS on `points` with the default pooled k-NN backend.
pub fn itis(points: &Matrix, config: &ItisConfig) -> Result<ItisResult> {
    itis_with(points, config, &DefaultKnn)
}

/// Run ITIS with an injected k-NN backend (the coordinator passes its
/// work-stealing parallel or PJRT implementation), on a default
/// executor with a throwaway workspace.
pub fn itis_with(
    points: &Matrix,
    config: &ItisConfig,
    knn: &dyn KnnProvider,
) -> Result<ItisResult> {
    let exec = Executor::default();
    let mut ws = ItisWorkspace::new();
    itis_with_workspace(points, config, knn, &exec, &mut ws)
}

/// Full-control ITIS: explicit k-NN backend, executor, and reusable
/// workspace. Repeated calls on the same workspace (e.g. the repro
/// harness sweeping `m`, or a service clustering many batches) reuse the
/// `n×k` neighbor buffers and prototype accumulators across runs.
///
/// `exec` governs the *prototype reduction*; the k-NN phase's threading
/// belongs to the `knn` provider. To run both phases on the one shared
/// team — the intended shape — pass
/// [`crate::coordinator::PoolKnnProvider`]`{ exec, .. }` as the provider
/// (what [`crate::hybrid::Ihtc::run_with`] does). [`DefaultKnn`] always
/// spins a machine-default executor, whatever `exec` is.
pub fn itis_with_workspace(
    points: &Matrix,
    config: &ItisConfig,
    knn: &dyn KnnProvider,
    exec: &Executor,
    ws: &mut ItisWorkspace,
) -> Result<ItisResult> {
    check_threshold(config)?;
    let n0 = points.rows();
    itis_core(points.clone(), vec![1; n0], n0, config, knn, exec, ws)
}

/// Resume ITIS from an already-reduced level: each row of `initial`
/// stands for `initial_weights[row]` original units (e.g. the fused
/// streaming ingest's concatenated shard prototypes). Stop rules and
/// [`ItisResult::n_original`] are relative to `n_original`, so
/// [`StopRule::ReductionFactor`] measures the reduction of the original
/// stream, not of `initial`. The returned levels cover only the resumed
/// iterations — the caller prepends its own level-0 map before backing
/// labels out.
pub fn itis_resume(
    initial: Matrix,
    initial_weights: Vec<u32>,
    n_original: usize,
    config: &ItisConfig,
    knn: &dyn KnnProvider,
    exec: &Executor,
    ws: &mut ItisWorkspace,
) -> Result<ItisResult> {
    check_threshold(config)?;
    if initial_weights.len() != initial.rows() {
        return Err(Error::Shape(format!(
            "{} weights for {} initial prototypes",
            initial_weights.len(),
            initial.rows()
        )));
    }
    itis_core(initial, initial_weights, n_original, config, knn, exec, ws)
}

fn check_threshold(config: &ItisConfig) -> Result<()> {
    if config.threshold < 2 {
        return Err(Error::InvalidArgument(format!(
            "ITIS needs t* ≥ 2, got {}",
            config.threshold
        )));
    }
    Ok(())
}

/// The shared reduction loop behind [`itis_with_workspace`] (weights all
/// one) and [`itis_resume`] (weights from a previous reduction).
fn itis_core(
    mut current: Matrix,
    mut weights: Vec<u32>,
    n0: usize,
    config: &ItisConfig,
    knn: &dyn KnnProvider,
    exec: &Executor,
    ws: &mut ItisWorkspace,
) -> Result<ItisResult> {
    let mut levels = Vec::new();
    let floor = config.min_prototypes.max(1);

    let max_iters = match config.stop {
        StopRule::Iterations(m) => m,
        _ => 64, // safety bound; reduction by ≥ t* per level hits any target long before
    };

    for _ in 0..max_iters {
        let done = match config.stop {
            StopRule::Iterations(_) => false,
            StopRule::ReductionFactor(alpha) => {
                (n0 as f64 / current.rows() as f64) >= alpha
            }
            StopRule::TargetSize(target) => current.rows() <= target,
        };
        if done {
            break;
        }
        // Too small to keep reducing? TC guarantees every cluster holds
        // ≥ t* units, so `num_clusters ≤ rows / t*` — a level that
        // cannot possibly reach the floor is knowable before clustering.
        if current.rows() <= config.threshold || current.rows() / config.threshold < floor {
            break;
        }
        let tc_cfg = TcConfig { threshold: config.threshold, seed_order: config.seed_order };
        knn.knn_forest_into(&current, config.threshold - 1, &mut ws.forest, &mut ws.knn)?;
        ws.graph.rebuild_from_knn(&ws.knn, &mut ws.graph_scratch);
        let tc = threshold_cluster_graph(&ws.graph, &current, &tc_cfg);
        if tc.num_clusters >= current.rows() {
            break; // no reduction possible
        }
        // TC clusters can hold up to 2t*−1 units, so the realized count
        // can undershoot the rows/t* prediction: enforce the floor on
        // the *actual* count and discard the level when it violates it,
        // otherwise the final clusterer is handed k > n* points.
        if tc.num_clusters < floor {
            break;
        }
        let (protos, new_weights) =
            make_prototypes(&current, &weights, &tc, config.prototype, exec, ws)?;
        levels.push(ItisLevel { assignments: tc.assignments, num_prototypes: tc.num_clusters });
        current = protos;
        weights = new_weights;
    }

    Ok(ItisResult { levels, prototypes: current, weights, n_original: n0 })
}

/// One shard's fused level-0 reduction (see [`reduce_shard`]).
#[derive(Clone, Debug)]
pub struct ShardReduction {
    /// Weighted-centroid prototypes, one per TC cluster of the shard.
    pub prototypes: Matrix,
    /// Original units represented by each prototype.
    pub weights: Vec<u32>,
    /// Shard row → local prototype index (length = shard rows).
    pub assignments: Vec<u32>,
}

/// Threshold-cluster one data shard into weighted prototypes — the
/// streaming ingest's per-shard reduction step. Regardless of the
/// configured [`ItisConfig::prototype`], the accumulation is always
/// [`PrototypeKind::WeightedCentroid`]: that keeps every prototype the
/// exact mean of the original units it represents, so concatenating
/// shard reductions commutes with the weighted means the later pooled
/// iterations compute. Shards of ≤ t* rows collapse to a single
/// prototype (TC's tiny-input behavior); `weights` carries the units
/// each incoming row already represents (all ones for raw data).
pub fn reduce_shard(
    points: &Matrix,
    weights: &[u32],
    config: &ItisConfig,
    knn: &dyn KnnProvider,
    exec: &Executor,
    ws: &mut ItisWorkspace,
) -> Result<ShardReduction> {
    check_threshold(config)?;
    if weights.len() != points.rows() {
        return Err(Error::Shape(format!(
            "{} weights for {} shard rows",
            weights.len(),
            points.rows()
        )));
    }
    if points.rows() == 0 {
        return Ok(ShardReduction {
            prototypes: points.clone(),
            weights: Vec::new(),
            assignments: Vec::new(),
        });
    }
    let tc_cfg = TcConfig { threshold: config.threshold, seed_order: config.seed_order };
    let tc = if points.rows() <= config.threshold {
        threshold_cluster(points, &tc_cfg)?
    } else {
        knn.knn_forest_into(points, config.threshold - 1, &mut ws.forest, &mut ws.knn)?;
        ws.graph.rebuild_from_knn(&ws.knn, &mut ws.graph_scratch);
        threshold_cluster_graph(&ws.graph, points, &tc_cfg)
    };
    let (prototypes, new_weights) =
        make_prototypes(points, weights, &tc, PrototypeKind::WeightedCentroid, exec, ws)?;
    Ok(ShardReduction { prototypes, weights: new_weights, assignments: tc.assignments })
}

/// Everything one in-flight streaming reduce batch owns: a handle to
/// the run's **shared executor**, its reusable [`ItisWorkspace`], and
/// the unit-weight scratch buffer. The fused ingest
/// (`PipelineBuilder::source_exec_ordered`) pools at most
/// `reduce_stages` of these and hands one to each per-shard batch it
/// submits, recycling it when the batch completes — so a reducer may
/// run on a different worker thread for every shard (the type is
/// `Send`; nothing in it is thread-affine), but only one batch ever
/// holds it at a time. The thread team is one: each batch submits its
/// nested k-NN and prototype sub-batches into the same executor it is
/// running on, which is deadlock-free because `run_tasks` submitters
/// drain their own batch instead of parking on a worker slot.
pub struct ShardReducer {
    exec: std::sync::Arc<Executor>,
    ws: ItisWorkspace,
    ones: Vec<u32>,
    config: ItisConfig,
    knn_shards: usize,
}

impl ShardReducer {
    /// Batch-local state around the run's shared `exec`: fresh buffers,
    /// reduced with `config`; the per-shard k-NN step uses a
    /// `knn_shards`-tree kd-forest (1 = single tree), rebuilt in this
    /// reducer's workspace for every data shard.
    pub fn new(exec: std::sync::Arc<Executor>, knn_shards: usize, config: ItisConfig) -> Self {
        Self {
            exec,
            ws: ItisWorkspace::new(),
            ones: Vec::new(),
            config,
            knn_shards: knn_shards.max(1),
        }
    }

    /// Reduce one raw shard (every row one original unit) into weighted
    /// prototypes via [`reduce_shard`], reusing this stage's buffers.
    pub fn reduce(&mut self, points: &Matrix) -> Result<ShardReduction> {
        self.ones.clear();
        self.ones.resize(points.rows(), 1);
        let provider =
            crate::coordinator::PoolKnnProvider { exec: &self.exec, shards: self.knn_shards };
        reduce_shard(points, &self.ones, &self.config, &provider, &self.exec, &mut self.ws)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;

    #[test]
    fn reduction_guarantee_per_iteration() {
        let ds = gaussian_mixture_paper(3000, 61);
        for m in 1..=4 {
            let r = itis(&ds.points, &ItisConfig::iterations(2, m)).unwrap();
            assert_eq!(r.iterations(), m);
            // Each iteration reduces by ≥ t* = 2.
            assert!(
                r.prototypes.rows() <= 3000 / (1 << m),
                "m={m}: n*={}",
                r.prototypes.rows()
            );
            assert!(r.reduction_factor() >= (1 << m) as f64);
        }
    }

    #[test]
    fn weights_conserve_units() {
        let ds = gaussian_mixture_paper(1111, 62);
        let r = itis(&ds.points, &ItisConfig::iterations(2, 3)).unwrap();
        let total: u64 = r.weights.iter().map(|&w| w as u64).sum();
        assert_eq!(total, 1111);
    }

    #[test]
    fn unit_to_prototype_composes() {
        let ds = gaussian_mixture_paper(500, 63);
        let r = itis(&ds.points, &ItisConfig::iterations(2, 2)).unwrap();
        let map = r.unit_to_prototype();
        assert_eq!(map.len(), 500);
        let np = r.prototypes.rows() as u32;
        assert!(map.iter().all(|&p| p < np));
        // Prototype weights match the composed map's fiber sizes.
        let mut fibers = vec![0u32; np as usize];
        for &p in &map {
            fibers[p as usize] += 1;
        }
        assert_eq!(fibers, r.weights);
    }

    #[test]
    fn back_out_respects_composition() {
        let ds = gaussian_mixture_paper(400, 64);
        let r = itis(&ds.points, &ItisConfig::iterations(2, 2)).unwrap();
        // Label prototypes by parity.
        let labels: Vec<u32> = (0..r.prototypes.rows() as u32).map(|i| i % 2).collect();
        let full = r.back_out(&labels).unwrap();
        let map = r.unit_to_prototype();
        for i in 0..400 {
            assert_eq!(full[i], labels[map[i] as usize]);
        }
    }

    #[test]
    fn back_out_length_checked() {
        let ds = gaussian_mixture_paper(100, 65);
        let r = itis(&ds.points, &ItisConfig::iterations(2, 1)).unwrap();
        assert!(r.back_out(&[0]).is_err());
    }

    #[test]
    fn back_out_requires_level0_coverage() {
        // An itis_resume result whose caller forgot to prepend the
        // level-0 map must error on back-out, not panic on indexing.
        let ds = gaussian_mixture_paper(400, 79);
        let exec = Executor::new(1);
        let mut ws = ItisWorkspace::new();
        let cfg = ItisConfig {
            prototype: PrototypeKind::WeightedCentroid,
            ..ItisConfig::iterations(2, 1)
        };
        // Pretend `initial` is a level-0 reduction of 800 original rows.
        let r = itis_resume(ds.points.clone(), vec![2; 400], 800, &cfg, &DefaultKnn, &exec, &mut ws)
            .unwrap();
        let labels = vec![0u32; r.prototypes.rows()];
        let err = r.back_out(&labels).unwrap_err();
        assert!(err.to_string().contains("level"), "{err}");
    }

    #[test]
    fn reduction_factor_stop_rule() {
        let ds = gaussian_mixture_paper(4000, 66);
        let r = itis(&ds.points, &ItisConfig::reduction(2, 10.0)).unwrap();
        assert!(r.reduction_factor() >= 10.0, "{}", r.reduction_factor());
        // Should not overshoot by more than one extra iteration (each
        // iteration multiplies the reduction by roughly t*..2t*).
        assert!(r.reduction_factor() < 10.0 * 8.0);
    }

    #[test]
    fn target_size_stop_rule() {
        let ds = gaussian_mixture_paper(2000, 67);
        let cfg = ItisConfig {
            stop: StopRule::TargetSize(100),
            ..ItisConfig::iterations(2, 0)
        };
        let r = itis(&ds.points, &cfg).unwrap();
        assert!(r.prototypes.rows() <= 100);
    }

    #[test]
    fn centroid_prototypes_are_cluster_means() {
        let ds = gaussian_mixture_paper(300, 68);
        let r = itis(&ds.points, &ItisConfig::iterations(3, 1)).unwrap();
        let level = &r.levels[0];
        // Recompute one centroid by hand.
        let c0: Vec<usize> =
            (0..300).filter(|&i| level.assignments[i] == 0).collect();
        let sub = ds.points.select_rows(&c0);
        let mean = sub.centroid();
        for j in 0..2 {
            assert!((mean[j] - r.prototypes.get(0, j)).abs() < 1e-4);
        }
    }

    #[test]
    fn medoid_prototypes_are_data_points() {
        let ds = gaussian_mixture_paper(300, 69);
        let cfg = ItisConfig {
            prototype: PrototypeKind::Medoid,
            ..ItisConfig::iterations(2, 1)
        };
        let r = itis(&ds.points, &cfg).unwrap();
        // Every prototype must coincide with an original point.
        for p in 0..r.prototypes.rows() {
            let proto = r.prototypes.row(p);
            let found = (0..300).any(|i| {
                crate::linalg::sq_dist(proto, ds.points.row(i)) < 1e-12
            });
            assert!(found, "prototype {p} is not a data point");
        }
    }

    #[test]
    fn weighted_centroid_tracks_mass() {
        // After two iterations, WeightedCentroid prototypes equal the mean
        // of all original units they represent.
        let ds = gaussian_mixture_paper(256, 70);
        let cfg = ItisConfig {
            prototype: PrototypeKind::WeightedCentroid,
            ..ItisConfig::iterations(2, 2)
        };
        let r = itis(&ds.points, &cfg).unwrap();
        let map = r.unit_to_prototype();
        for p in 0..r.prototypes.rows().min(5) {
            let members: Vec<usize> =
                (0..256).filter(|&i| map[i] == p as u32).collect();
            let mean = ds.points.select_rows(&members).centroid();
            for j in 0..2 {
                assert!(
                    (mean[j] - r.prototypes.get(p, j)).abs() < 1e-3,
                    "proto {p} dim {j}: {} vs {}",
                    mean[j],
                    r.prototypes.get(p, j)
                );
            }
        }
    }

    #[test]
    fn rejects_threshold_one() {
        let ds = gaussian_mixture_paper(50, 71);
        assert!(itis(&ds.points, &ItisConfig::iterations(1, 1)).is_err());
    }

    #[test]
    fn workspace_reuse_matches_fresh() {
        // Two runs on one workspace must equal a fresh run bit-for-bit
        // (stale buffer contents must never leak into the next run).
        let ds = gaussian_mixture_paper(2500, 72);
        let cfg = ItisConfig::iterations(2, 3);
        let fresh = itis(&ds.points, &cfg).unwrap();
        let exec = Executor::new(2);
        let mut ws = ItisWorkspace::new();
        let first =
            itis_with_workspace(&ds.points, &cfg, &DefaultKnn, &exec, &mut ws).unwrap();
        let second =
            itis_with_workspace(&ds.points, &cfg, &DefaultKnn, &exec, &mut ws).unwrap();
        for r in [&first, &second] {
            assert_eq!(r.prototypes.data(), fresh.prototypes.data());
            assert_eq!(r.weights, fresh.weights);
            assert_eq!(r.levels.len(), fresh.levels.len());
        }
    }

    /// `blobs` far-apart tight blobs of `per_blob` points each: with
    /// `t* ≤ per_blob ≤ 2t*−1`, TC forms exactly one cluster per blob.
    fn blob_matrix(blobs: usize, per_blob: usize) -> Matrix {
        let mut data = Vec::with_capacity(blobs * per_blob * 2);
        for b in 0..blobs {
            for i in 0..per_blob {
                data.push(1000.0 * b as f32 + 0.01 * i as f32);
                data.push(0.01 * (i as f32).sin());
            }
        }
        Matrix::from_vec(data, blobs * per_blob, 2).unwrap()
    }

    #[test]
    fn realized_undershoot_discards_level() {
        // 5 blobs of 7 points, t* = 4: the prediction rows/t* = 35/4 = 8
        // passes a floor of 6, but TC clusters can hold up to 2t*−1 = 7
        // units, so the realized count is 5 < 6. The level must be
        // discarded — otherwise a k-means with k = 6 would be handed
        // only 5 prototypes.
        let points = blob_matrix(5, 7);
        let cfg = ItisConfig {
            min_prototypes: 6,
            ..ItisConfig::iterations(4, 1)
        };
        let r = itis(&points, &cfg).unwrap();
        assert!(
            r.prototypes.rows() >= cfg.min_prototypes,
            "floor violated: {} < {}",
            r.prototypes.rows(),
            cfg.min_prototypes
        );
        // The undershooting level was discarded entirely.
        assert!(r.levels.is_empty());
        assert_eq!(r.prototypes.rows(), 35);
        // Sanity: without the floor the same data does reduce to 5.
        let free = itis(&points, &ItisConfig::iterations(4, 1)).unwrap();
        assert_eq!(free.prototypes.rows(), 5);
    }

    #[test]
    fn reduce_shard_matches_single_itis_level() {
        // One shard covering the whole dataset must reproduce the first
        // WeightedCentroid ITIS level bit-for-bit.
        let ds = gaussian_mixture_paper(1200, 74);
        let cfg = ItisConfig {
            prototype: PrototypeKind::WeightedCentroid,
            ..ItisConfig::iterations(2, 1)
        };
        let level = itis(&ds.points, &cfg).unwrap();
        let exec = Executor::new(2);
        let mut ws = ItisWorkspace::new();
        let red = reduce_shard(&ds.points, &vec![1; 1200], &cfg, &DefaultKnn, &exec, &mut ws)
            .unwrap();
        assert_eq!(red.prototypes.data(), level.prototypes.data());
        assert_eq!(red.weights, level.weights);
        assert_eq!(red.assignments, level.levels[0].assignments);
    }

    #[test]
    fn reduce_shard_conserves_mass_and_handles_tiny_shards() {
        let ds = gaussian_mixture_paper(37, 75);
        let cfg = ItisConfig::iterations(2, 1);
        let exec = Executor::new(1);
        let mut ws = ItisWorkspace::new();
        // Incoming rows already weighted (as on a resumed level).
        let weights: Vec<u32> = (0..37).map(|i| 1 + (i % 3) as u32).collect();
        let total: u64 = weights.iter().map(|&w| w as u64).sum();
        let red = reduce_shard(&ds.points, &weights, &cfg, &DefaultKnn, &exec, &mut ws).unwrap();
        let got: u64 = red.weights.iter().map(|&w| w as u64).sum();
        assert_eq!(got, total);
        assert_eq!(red.assignments.len(), 37);
        // A shard of ≤ t* rows collapses to one prototype.
        let tiny = ds.points.slice_rows(0, 2);
        let red = reduce_shard(&tiny, &[1, 1], &cfg, &DefaultKnn, &exec, &mut ws).unwrap();
        assert_eq!(red.prototypes.rows(), 1);
        assert_eq!(red.weights, vec![2]);
        // Mismatched weights are rejected; empty shards are a no-op.
        assert!(reduce_shard(&tiny, &[1], &cfg, &DefaultKnn, &exec, &mut ws).is_err());
        let empty = ds.points.slice_rows(0, 0);
        let red = reduce_shard(&empty, &[], &cfg, &DefaultKnn, &exec, &mut ws).unwrap();
        assert_eq!(red.prototypes.rows(), 0);
    }

    #[test]
    fn itis_resume_composes_with_reduce_shard() {
        // reduce_shard over shards + itis_resume must agree with a
        // single itis run on stop-rule semantics: n_original governs
        // the reduction factor, and weights stay conserved.
        let ds = gaussian_mixture_paper(2048, 76);
        let cfg = ItisConfig {
            prototype: PrototypeKind::WeightedCentroid,
            ..ItisConfig::iterations(2, 2)
        };
        let exec = Executor::new(2);
        let mut ws = ItisWorkspace::new();
        let mut data = Vec::new();
        let mut weights = Vec::new();
        for start in (0..2048).step_by(512) {
            let shard = ds.points.slice_rows(start, start + 512);
            let red =
                reduce_shard(&shard, &vec![1; 512], &cfg, &DefaultKnn, &exec, &mut ws).unwrap();
            data.extend_from_slice(red.prototypes.data());
            weights.extend_from_slice(&red.weights);
        }
        let n_level0 = weights.len();
        let initial = Matrix::from_vec(data, n_level0, 2).unwrap();
        let resume_cfg = ItisConfig {
            prototype: PrototypeKind::WeightedCentroid,
            ..ItisConfig::iterations(2, 1)
        };
        let r = itis_resume(initial, weights, 2048, &resume_cfg, &DefaultKnn, &exec, &mut ws)
            .unwrap();
        assert_eq!(r.n_original, 2048);
        let total: u64 = r.weights.iter().map(|&w| w as u64).sum();
        assert_eq!(total, 2048);
        assert!(r.prototypes.rows() <= n_level0 / 2);
        assert!(r.reduction_factor() >= 4.0);
    }

    #[test]
    fn shard_reducer_matches_bare_reduce_shard() {
        // The stage-state wrapper must be a pure packaging change:
        // byte-identical to calling reduce_shard with unit weights, and
        // stable across reuse (stale buffers must never leak between
        // shards).
        let ds = gaussian_mixture_paper(900, 80);
        let cfg = ItisConfig {
            prototype: PrototypeKind::WeightedCentroid,
            ..ItisConfig::iterations(2, 1)
        };
        let shared = std::sync::Arc::new(Executor::new(2));
        let mut reducer = ShardReducer::new(shared, 1, cfg.clone());
        let exec = Executor::new(2);
        let mut ws = ItisWorkspace::new();
        for (start, end) in [(0usize, 300usize), (300, 600), (600, 900)] {
            let shard = ds.points.slice_rows(start, end);
            let got = reducer.reduce(&shard).unwrap();
            let want = reduce_shard(
                &shard,
                &vec![1; end - start],
                &cfg,
                &crate::coordinator::PoolKnnProvider { exec: &exec, shards: 1 },
                &exec,
                &mut ws,
            )
            .unwrap();
            assert_eq!(got.prototypes.data(), want.prototypes.data());
            assert_eq!(got.weights, want.weights);
            assert_eq!(got.assignments, want.assignments);
        }
    }

    #[test]
    fn knn_shards_invariant_through_itis() {
        // The sharded kd-forest provider must leave every ITIS output
        // byte unchanged for any shard count (the forest is
        // byte-identical to the single tree, so the whole reduction is).
        let ds = gaussian_mixture_paper(3000, 81);
        let cfg = ItisConfig::iterations(2, 2);
        let exec = Executor::new(2);
        let mut base: Option<ItisResult> = None;
        for shards in [1usize, 2, 4] {
            let provider = crate::coordinator::PoolKnnProvider { exec: &exec, shards };
            let mut ws = ItisWorkspace::new();
            let r = itis_with_workspace(&ds.points, &cfg, &provider, &exec, &mut ws).unwrap();
            match &base {
                None => base = Some(r),
                Some(b) => {
                    assert_eq!(b.prototypes.data(), r.prototypes.data(), "shards={shards}");
                    assert_eq!(b.weights, r.weights, "shards={shards}");
                    assert_eq!(b.levels.len(), r.levels.len(), "shards={shards}");
                    for (x, y) in b.levels.iter().zip(&r.levels) {
                        assert_eq!(x.assignments, y.assignments, "shards={shards}");
                    }
                }
            }
        }
    }

    #[test]
    fn prototype_reduction_worker_count_invariant() {
        // The cluster-range-partitioned reduction must be byte-identical
        // across worker counts (accumulation order per cluster is point
        // order regardless of the partitioning).
        let ds = gaussian_mixture_paper(9000, 73);
        let cfg = ItisConfig::iterations(2, 2);
        let mut results = Vec::new();
        for workers in [1usize, 2, 4] {
            let exec = Executor::new(workers);
            let mut ws = ItisWorkspace::new();
            let r =
                itis_with_workspace(&ds.points, &cfg, &DefaultKnn, &exec, &mut ws).unwrap();
            results.push(r);
        }
        let base: Vec<u32> = results[0].prototypes.data().iter().map(|v| v.to_bits()).collect();
        for r in &results[1..] {
            let got: Vec<u32> = r.prototypes.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(base, got);
            assert_eq!(results[0].weights, r.weights);
        }
    }
}
