//! Configuration system for the `ihtc` launcher.
//!
//! Pipeline runs are described by JSON config files (parsed with the
//! in-tree [`json`] parser — no external crates exist offline). A config
//! fully determines a run: dataset source, preprocessing, ITIS settings,
//! final clusterer, coordinator knobs, and output location. Every field
//! has a default so minimal configs stay small; `PipelineConfig::from_json`
//! validates cross-field constraints (e.g. `t* ≥ 2`, k-means needs `k`).

pub mod json;

use crate::cluster::hac::Linkage;
use crate::exec::{ExecutorConfig, Priority, StealPolicy};
use crate::hybrid::FinalClusterer;
use crate::itis::PrototypeKind;
use crate::tc::SeedOrder;
use crate::{Error, Result};
use json::Json;

/// Sanity ceiling for the `workers` knob: the shared executor spawns
/// `workers − 1` persistent threads, taken literally, so an absurd
/// budget (a typo'd `100000`) must be a config error rather than an
/// attempted hundred-thousand-thread spawn. Far above any real machine.
const MAX_WORKERS: usize = 4096;

/// Where the input data comes from.
#[derive(Clone, Debug, PartialEq)]
pub enum DataSource {
    /// Load a CSV file (`path`, optional label column).
    Csv {
        /// File path.
        path: String,
        /// Column index holding integer labels.
        label_column: Option<usize>,
    },
    /// The paper's §4 Gaussian mixture with `n` points.
    PaperMixture {
        /// Number of points.
        n: usize,
    },
    /// A Table 3 analogue by name (`"covertype"`, `"stock"`, ...).
    Analogue {
        /// Dataset name (prefix match against Table 3).
        name: String,
        /// Divide the paper's instance count by this.
        scale_div: usize,
    },
}

/// Which distance/assignment backend executes the hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust kd-tree / native loops.
    Native,
    /// AOT PJRT artifacts (requires `make artifacts`).
    Pjrt,
}

/// Full pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Run name (reports, output files).
    pub name: String,
    /// Input data.
    pub source: DataSource,
    /// RNG seed for everything downstream.
    pub seed: u64,
    /// Standardize columns before clustering.
    pub standardize: bool,
    /// PCA variance fraction to retain (None = skip PCA).
    pub pca_variance: Option<f64>,
    /// TC threshold `t*`.
    pub threshold: usize,
    /// ITIS iterations `m`.
    pub iterations: usize,
    /// Prototype kind.
    pub prototype: PrototypeKind,
    /// Seed-selection order for TC.
    pub seed_order: SeedOrder,
    /// Final clusterer.
    pub clusterer: FinalClusterer,
    /// Hot-path backend.
    pub backend: Backend,
    /// Assertion that this binary carries the `simd` distance kernels
    /// (the `simd` cargo feature). Kernel dispatch is resolved once per
    /// process from the compiled feature + runtime CPU detection — a
    /// config cannot flip it — so a knob that disagrees with the build
    /// would be silently inert and is rejected instead. Defaults to the
    /// build's own state, so omitting it always validates.
    pub simd: bool,
    /// Elkan/Hamerly bound pruning for the k-means final clusterer
    /// (exact — output bytes unchanged; see `KMeansConfig::bounds`).
    /// Requires a kmeans clusterer and the native backend.
    pub kmeans_bounds: bool,
    /// Coordinator worker threads (0 = available parallelism).
    pub workers: usize,
    /// kd-forest shard count for the k-NN index: partition each level's
    /// point set into this many contiguous row shards, build one kd-tree
    /// per shard in parallel, and merge candidates at query time through
    /// the deterministic `(distance, index)` order. Results are
    /// byte-identical for every value — 1 (the default) keeps the single
    /// tree; > 1 parallelizes index construction. Must be ≥ 1.
    pub knn_shards: usize,
    /// Rows per shard fed through the pipeline.
    pub shard_size: usize,
    /// Bounded-queue capacity between stages (backpressure depth).
    pub queue_capacity: usize,
    /// Out-of-core mode: fuse level-0 TC with ingest, so shards are
    /// threshold-clustered into weighted prototypes as they arrive and
    /// the full `n × d` matrix is never materialized. Requires
    /// `iterations ≥ 1` and `prototype = "weighted"` (weighted centroids
    /// keep the fused means exact).
    pub streaming: bool,
    /// Max per-shard reduce batches in flight on the shared executor at
    /// once during the fused streaming ingest. An in-flight cap, not a
    /// thread budget: batches run on the one worker team, so values
    /// above `workers` are fine (they queue), and each in-flight batch
    /// owns one pooled `ItisWorkspace`. Results are re-ordered by shard
    /// offset before concatenation, so every value produces
    /// byte-identical output; values > 1 only change throughput and
    /// peak workspace memory. Must be ≥ 1.
    pub reduce_stages: usize,
    /// Priority class the streaming reduce batches are submitted at
    /// (`"high"`, `"normal"` — the default — or `"bulk"`).
    /// Scheduling-only: output bytes are identical under every class;
    /// lower it to let latency-sensitive work overtake a bulk ingest on
    /// the same team.
    pub reduce_priority: Priority,
    /// Durable checkpoint file for streaming runs (optional). When set,
    /// every reduced shard is appended to this file as a CRC32-checked
    /// frame behind the reorder stage, so the file always holds an
    /// offset-tiled prefix of the stream and a crash can resume from
    /// the last fsynced frame. When unset, streaming runs still spill
    /// the level-0 assignment map to an anonymous temp file (deleted on
    /// drop) so the O(n) map is never resident — but nothing survives
    /// a crash.
    pub checkpoint_path: Option<String>,
    /// Fsync cadence for the durable checkpoint: flush + fsync after at
    /// least this many rows have been appended since the last sync.
    /// 0 (the default) syncs after every frame — maximum durability,
    /// one fsync per shard. Ignored without `checkpoint_path`.
    pub checkpoint_every_rows: usize,
    /// Resume an interrupted streaming run from `checkpoint_path`:
    /// replay the valid frames, seek the source to the first missing
    /// row, and continue. The resumed run is byte-identical to an
    /// uninterrupted one as long as the config is unchanged.
    pub resume: bool,
    /// Steal policy of the run's shared executor: which queued batch
    /// idle workers serve first (`"fifo"`, the default, or `"lifo"`).
    /// Scheduling-only — output bytes are identical under every policy.
    pub steal: StealPolicy,
    /// Reduce-stage fairness on the shared executor: cap how many tasks
    /// a worker takes from one stage's batch before re-selecting, so no
    /// stage starves its siblings (default true). Scheduling-only.
    pub fair_stages: bool,
    /// Distributed mode: the coordinator's listen address
    /// (`host:port`; port 0 picks a free port). Only meaningful with
    /// `dist_workers > 0`. Parsed from the nested `dist` block's
    /// `listen` key.
    pub dist_listen: Option<String>,
    /// Distributed mode: how many remote worker processes the run
    /// expects to connect (0, the default, disables distribution
    /// entirely). Workers that never show — or die mid-run — degrade
    /// the affected units to in-process execution, byte-identically.
    /// Parsed from the nested `dist` block's `workers` key.
    pub dist_workers: usize,
    /// Distributed mode: seconds of socket silence after which a leased
    /// worker is declared dead and its unit re-queued (`None` = the
    /// built-in default). Parsed from the nested `dist` block's
    /// `lease_timeout` key.
    pub dist_lease_timeout: Option<f64>,
    /// Write the final assignment CSV here (optional).
    pub output: Option<String>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            name: "ihtc-run".into(),
            source: DataSource::PaperMixture { n: 10_000 },
            seed: 42,
            standardize: false,
            pca_variance: None,
            threshold: 2,
            iterations: 2,
            prototype: PrototypeKind::Centroid,
            seed_order: SeedOrder::Natural,
            clusterer: FinalClusterer::KMeans { k: 3, restarts: 4 },
            backend: Backend::Native,
            simd: cfg!(feature = "simd"),
            kmeans_bounds: false,
            workers: 0,
            knn_shards: 1,
            shard_size: 8_192,
            queue_capacity: 4,
            streaming: false,
            reduce_stages: 1,
            reduce_priority: Priority::Normal,
            checkpoint_path: None,
            checkpoint_every_rows: 0,
            resume: false,
            steal: StealPolicy::Fifo,
            fair_stages: true,
            dist_listen: None,
            dist_workers: 0,
            dist_lease_timeout: None,
            output: None,
        }
    }
}

impl PipelineConfig {
    /// Parse and validate a JSON config document. Every scalar knob goes
    /// through a strict typed accessor: a field that is present with the
    /// wrong type (e.g. `"streaming": "true"` or `"workers": "four"`) is
    /// a config error, never a silently ignored value — dropping a
    /// typo'd knob would flip execution paths without telling the user.
    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let mut cfg = PipelineConfig::default();
        if let Some(name) = j.opt_str("name")? {
            cfg.name = name.to_string();
        }
        if let Some(seed) = j.opt_f64("seed")? {
            cfg.seed = seed as u64;
        }
        if let Some(source) = j.get("source") {
            cfg.source = parse_source(source)?;
        }
        if let Some(b) = j.opt_bool("standardize")? {
            cfg.standardize = b;
        }
        if let Some(v) = j.opt_f64("pca_variance")? {
            if !(0.0..=1.0).contains(&v) {
                return Err(Error::Config(format!("pca_variance must be in [0,1], got {v}")));
            }
            cfg.pca_variance = Some(v);
        }
        if let Some(t) = j.opt_usize("threshold")? {
            cfg.threshold = t;
        }
        if let Some(m) = j.opt_usize("iterations")? {
            cfg.iterations = m;
        }
        if let Some(p) = j.opt_str("prototype")? {
            cfg.prototype = match p {
                "centroid" => PrototypeKind::Centroid,
                "weighted" => PrototypeKind::WeightedCentroid,
                "medoid" => PrototypeKind::Medoid,
                other => return Err(Error::Config(format!("unknown prototype '{other}'"))),
            };
        }
        if let Some(o) = j.opt_str("seed_order")? {
            cfg.seed_order = match o {
                "natural" => SeedOrder::Natural,
                "degree_asc" => SeedOrder::DegreeAscending,
                "degree_desc" => SeedOrder::DegreeDescending,
                other => return Err(Error::Config(format!("unknown seed_order '{other}'"))),
            };
        }
        if let Some(c) = j.get("clusterer") {
            cfg.clusterer = parse_clusterer(c)?;
        }
        if let Some(b) = j.opt_str("backend")? {
            cfg.backend = match b {
                "native" => Backend::Native,
                "pjrt" => Backend::Pjrt,
                other => return Err(Error::Config(format!("unknown backend '{other}'"))),
            };
        }
        if let Some(b) = j.opt_bool("simd")? {
            cfg.simd = b;
        }
        if let Some(b) = j.opt_bool("kmeans_bounds")? {
            cfg.kmeans_bounds = b;
        }
        if let Some(w) = j.opt_usize("workers")? {
            cfg.workers = w;
        }
        if let Some(s) = j.opt_usize("knn_shards")? {
            cfg.knn_shards = s;
        }
        if let Some(s) = j.opt_usize("shard_size")? {
            cfg.shard_size = s;
        }
        if let Some(q) = j.opt_usize("queue_capacity")? {
            cfg.queue_capacity = q;
        }
        if let Some(s) = j.opt_bool("streaming")? {
            cfg.streaming = s;
        }
        if let Some(r) = j.opt_usize("reduce_stages")? {
            cfg.reduce_stages = r;
        }
        if let Some(p) = j.opt_str("reduce_priority")? {
            cfg.reduce_priority = Priority::parse(p).ok_or_else(|| {
                Error::Config(format!(
                    "unknown reduce_priority '{p}' (high | normal | bulk)"
                ))
            })?;
        }
        if let Some(p) = j.opt_str("checkpoint_path")? {
            cfg.checkpoint_path = Some(p.to_string());
        }
        if let Some(e) = j.opt_usize("checkpoint_every_rows")? {
            cfg.checkpoint_every_rows = e;
        }
        if let Some(r) = j.opt_bool("resume")? {
            cfg.resume = r;
        }
        if let Some(e) = j.get("executor") {
            // The executor block groups the thread-team knobs; its
            // `workers` is an alias for the top-level knob (the block
            // wins when both are present).
            if let Some(w) = e.opt_usize("workers")? {
                cfg.workers = w;
            }
            if let Some(policy) = e.opt_str("steal")? {
                cfg.steal = match policy {
                    "fifo" => StealPolicy::Fifo,
                    "lifo" => StealPolicy::Lifo,
                    other => {
                        return Err(Error::Config(format!(
                            "unknown executor steal policy '{other}' (fifo | lifo)"
                        )))
                    }
                };
            }
            if let Some(fair) = e.opt_bool("fair_stages")? {
                cfg.fair_stages = fair;
            }
        }
        if let Some(d) = j.get("dist") {
            // The dist block groups the coordinator/worker knobs; like
            // every scalar knob they parse strictly — a mistyped value
            // is an error, never a silently ignored field.
            if let Some(l) = d.opt_str("listen")? {
                cfg.dist_listen = Some(l.to_string());
            }
            if let Some(w) = d.opt_usize("workers")? {
                cfg.dist_workers = w;
            }
            if let Some(t) = d.opt_f64("lease_timeout")? {
                cfg.dist_lease_timeout = Some(t);
            }
        }
        if let Some(o) = j.opt_str("output")? {
            cfg.output = Some(o.to_string());
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read config {path}: {e}")))?;
        Self::from_json(&text)
    }

    /// The construction knobs for the run's shared executor — the one
    /// thread team every parallel layer of this run submits into.
    pub fn executor(&self) -> ExecutorConfig {
        ExecutorConfig { workers: self.workers, steal: self.steal, fair_stages: self.fair_stages }
    }

    /// Cross-field validation.
    pub fn validate(&self) -> Result<()> {
        if self.iterations > 0 && self.threshold < 2 {
            return Err(Error::Config(format!(
                "threshold t*={} must be ≥ 2 when iterations > 0",
                self.threshold
            )));
        }
        if self.shard_size == 0 {
            return Err(Error::Config("shard_size must be > 0".into()));
        }
        if self.workers > MAX_WORKERS {
            return Err(Error::Config(format!(
                "workers = {} exceeds the sanity ceiling of {MAX_WORKERS}: the executor spawns \
                 `workers − 1` persistent OS threads, so a typo'd budget would exhaust the \
                 process (use workers: 0 to size the team to the machine)",
                self.workers
            )));
        }
        if self.queue_capacity == 0 {
            return Err(Error::Config("queue_capacity must be > 0".into()));
        }
        if self.knn_shards == 0 {
            return Err(Error::Config(
                "knn_shards must be ≥ 1 (1 = single kd-tree, the default)".into(),
            ));
        }
        if self.reduce_stages == 0 {
            return Err(Error::Config(
                "reduce_stages must be ≥ 1 (1 = single-stage reduce, the default)".into(),
            ));
        }
        if self.reduce_stages > 1 && !self.streaming {
            return Err(Error::Config(format!(
                "reduce_stages = {} has no effect without streaming: true — the materialized \
                 path has no reduce fan-out (set streaming, or drop the knob)",
                self.reduce_stages
            )));
        }
        // The checkpoint knobs only govern the streaming ingest; on the
        // materialized path they would be silently inert, and a `resume`
        // or cadence knob without a file to act on is a contradiction —
        // reject all three instead of dropping them.
        if self.checkpoint_path.is_some() && !self.streaming {
            return Err(Error::Config(
                "checkpoint_path has no effect without streaming: true — only the fused \
                 streaming ingest writes offset-keyed frames (set streaming, or drop the knob)"
                    .into(),
            ));
        }
        if self.resume && self.checkpoint_path.is_none() {
            return Err(Error::Config(
                "resume: true needs a checkpoint_path to replay from".into(),
            ));
        }
        if self.checkpoint_every_rows > 0 && self.checkpoint_path.is_none() {
            return Err(Error::Config(format!(
                "checkpoint_every_rows = {} has no effect without checkpoint_path — the \
                 anonymous level-0 spill never fsyncs (set checkpoint_path, or drop the knob)",
                self.checkpoint_every_rows
            )));
        }
        // Note reduce_stages may exceed `workers`: it caps in-flight
        // executor *batches* (queued work and pooled workspaces), not
        // threads — the retired stage-thread scheme's budget check is
        // gone with the stage threads themselves.
        if self.reduce_priority != Priority::Normal && !self.streaming {
            return Err(Error::Config(
                "reduce_priority has no effect without streaming: true — only the fused \
                 streaming ingest submits prioritized reduce batches (set streaming, or drop \
                 the knob)"
                    .into(),
            ));
        }
        // The `simd` knob is a build assertion, not a runtime switch:
        // kernel dispatch resolves once per process from the compiled
        // feature + CPU detection, so a config disagreeing with the
        // build would be silently inert — reject with the fix named.
        if self.simd && !cfg!(feature = "simd") {
            return Err(Error::Config(
                "simd: true but this binary was built without the `simd` cargo feature — \
                 rebuild with `--features simd` (or drop the knob; it defaults to the \
                 build's own state)"
                    .into(),
            ));
        }
        if !self.simd && cfg!(feature = "simd") {
            return Err(Error::Config(
                "simd: false but this binary was built with the `simd` cargo feature — \
                 use a featureless build, or set IHTC_FORCE_SCALAR=1 to force the scalar \
                 kernels at runtime (or drop the knob)"
                    .into(),
            ));
        }
        if self.kmeans_bounds {
            if !matches!(self.clusterer, FinalClusterer::KMeans { .. }) {
                return Err(Error::Config(
                    "kmeans_bounds has no effect without a kmeans clusterer — the bound \
                     pruning lives in the k-means assignment scan (switch the clusterer, \
                     or drop the knob)"
                        .into(),
                ));
            }
            if self.backend == Backend::Pjrt {
                return Err(Error::Config(
                    "kmeans_bounds requires backend: \"native\" — the PJRT assignment \
                     backend evaluates whole distance tiles and cannot skip per-point \
                     scans (switch the backend, or drop the knob)"
                        .into(),
                ));
            }
        }
        // The dist knobs are one feature: a listen address or a lease
        // timeout without a worker count would be silently inert (the
        // pool is only built when workers > 0), and a worker count
        // without an address has nowhere to listen — reject the inert
        // combinations instead of dropping them.
        if self.dist_workers > 0 && self.dist_listen.is_none() {
            return Err(Error::Config(format!(
                "dist.workers = {} needs dist.listen (\"host:port\"; port 0 picks a free \
                 port) — the coordinator has no address to lease from",
                self.dist_workers
            )));
        }
        if self.dist_workers > MAX_WORKERS {
            return Err(Error::Config(format!(
                "dist.workers = {} exceeds the sanity ceiling of {MAX_WORKERS} (one I/O \
                 thread per connected worker)",
                self.dist_workers
            )));
        }
        if self.dist_listen.is_some() && self.dist_workers == 0 {
            return Err(Error::Config(
                "dist.listen has no effect without dist.workers ≥ 1 — no units are leased \
                 to a pool nobody is expected to join (set dist.workers, or drop the knob)"
                    .into(),
            ));
        }
        if self.dist_lease_timeout.is_some() && self.dist_workers == 0 {
            return Err(Error::Config(
                "dist.lease_timeout has no effect without dist.workers ≥ 1 — there are no \
                 leases to time out (set dist.workers, or drop the knob)"
                    .into(),
            ));
        }
        if let Some(t) = self.dist_lease_timeout {
            if !t.is_finite() || t <= 0.0 {
                return Err(Error::Config(format!(
                    "dist.lease_timeout must be a positive number of seconds, got {t}"
                )));
            }
        }
        if self.streaming {
            if self.iterations == 0 {
                return Err(Error::Config(
                    "streaming mode fuses level-0 TC with ingest and needs iterations ≥ 1"
                        .into(),
                ));
            }
            if self.prototype != PrototypeKind::WeightedCentroid {
                return Err(Error::Config(
                    "streaming mode requires prototype = \"weighted\": weighted centroids \
                     keep the fused shard-wise means exact"
                        .into(),
                ));
            }
        }
        match &self.clusterer {
            FinalClusterer::KMeans { k, .. } | FinalClusterer::Hac { k, .. } if *k == 0 => {
                Err(Error::Config("clusterer k must be ≥ 1".into()))
            }
            FinalClusterer::Dbscan { eps, min_pts } if *eps <= 0.0 || *min_pts == 0 => {
                Err(Error::Config("dbscan needs eps > 0 and min_pts ≥ 1".into()))
            }
            _ => Ok(()),
        }
    }
}

fn parse_source(j: &Json) -> Result<DataSource> {
    let kind = j.req_str("kind")?;
    Ok(match kind {
        "csv" => DataSource::Csv {
            path: j.req_str("path")?.to_string(),
            label_column: j.opt_usize("label_column")?,
        },
        "paper_mixture" => DataSource::PaperMixture { n: j.req_usize("n")? },
        "analogue" => DataSource::Analogue {
            name: j.req_str("dataset")?.to_string(),
            scale_div: j.opt_usize("scale_div")?.unwrap_or(1),
        },
        other => return Err(Error::Config(format!("unknown source kind '{other}'"))),
    })
}

fn parse_clusterer(j: &Json) -> Result<FinalClusterer> {
    let kind = j.req_str("kind")?;
    Ok(match kind {
        "kmeans" => FinalClusterer::KMeans {
            k: j.req_usize("k")?,
            restarts: j.opt_usize("restarts")?.unwrap_or(4),
        },
        "hac" => FinalClusterer::Hac {
            k: j.req_usize("k")?,
            linkage: match j.opt_str("linkage")?.unwrap_or("ward") {
                "ward" => Linkage::Ward,
                "average" => Linkage::Average,
                "complete" => Linkage::Complete,
                "single" => Linkage::Single,
                other => return Err(Error::Config(format!("unknown linkage '{other}'"))),
            },
        },
        "dbscan" => FinalClusterer::Dbscan {
            eps: j
                .opt_f64("eps")?
                .ok_or_else(|| Error::Config("dbscan needs 'eps'".into()))?,
            min_pts: j.req_usize("min_pts")?,
        },
        "gmm" => FinalClusterer::Gmm {
            k: j.req_usize("k")?,
            weighted: j.opt_bool("weighted")?.unwrap_or(false),
        },
        other => return Err(Error::Config(format!("unknown clusterer '{other}'"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_config_uses_defaults() {
        let cfg = PipelineConfig::from_json("{}").unwrap();
        assert_eq!(cfg.threshold, 2);
        assert_eq!(cfg.iterations, 2);
        assert!(matches!(cfg.source, DataSource::PaperMixture { n: 10_000 }));
    }

    #[test]
    fn full_config_roundtrip() {
        let doc = r#"{
          "name": "covertype-hac",
          "seed": 7,
          "source": {"kind": "analogue", "dataset": "covertype", "scale_div": 100},
          "standardize": true,
          "pca_variance": 0.95,
          "threshold": 3,
          "iterations": 4,
          "prototype": "medoid",
          "seed_order": "degree_asc",
          "clusterer": {"kind": "hac", "k": 7, "linkage": "average"},
          "backend": "pjrt",
          "workers": 4,
          "shard_size": 2048,
          "queue_capacity": 8,
          "output": "/tmp/out.csv"
        }"#;
        let cfg = PipelineConfig::from_json(doc).unwrap();
        assert_eq!(cfg.name, "covertype-hac");
        assert_eq!(cfg.threshold, 3);
        assert_eq!(cfg.prototype, PrototypeKind::Medoid);
        assert_eq!(cfg.seed_order, SeedOrder::DegreeAscending);
        assert_eq!(cfg.backend, Backend::Pjrt);
        assert!(matches!(cfg.clusterer, FinalClusterer::Hac { k: 7, .. }));
        assert!(matches!(cfg.source, DataSource::Analogue { ref name, scale_div: 100 } if name == "covertype"));
        assert_eq!(cfg.output.as_deref(), Some("/tmp/out.csv"));
    }

    #[test]
    fn dist_block_parses_and_rejects_inert_combinations() {
        let cfg = PipelineConfig::from_json(
            r#"{"dist": {"listen": "127.0.0.1:0", "workers": 2, "lease_timeout": 1.5}}"#,
        )
        .unwrap();
        assert_eq!(cfg.dist_listen.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(cfg.dist_workers, 2);
        assert_eq!(cfg.dist_lease_timeout, Some(1.5));
        // Defaults: disabled.
        let cfg = PipelineConfig::from_json("{}").unwrap();
        assert_eq!(cfg.dist_workers, 0);
        assert!(cfg.dist_listen.is_none());

        // Inert combinations are rejected, never dropped.
        let err = PipelineConfig::from_json(r#"{"dist": {"listen": "127.0.0.1:0"}}"#).unwrap_err();
        assert!(err.to_string().contains("no effect"), "{err}");
        let err = PipelineConfig::from_json(r#"{"dist": {"lease_timeout": 5.0}}"#).unwrap_err();
        assert!(err.to_string().contains("no effect"), "{err}");
        let err = PipelineConfig::from_json(r#"{"dist": {"workers": 2}}"#).unwrap_err();
        assert!(err.to_string().contains("dist.listen"), "{err}");
        // Mistyped knobs are config errors, not silently ignored.
        assert!(PipelineConfig::from_json(r#"{"dist": {"workers": "two"}}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"dist": {"listen": 9000, "workers": 1}}"#).is_err());
        // Degenerate timeouts are rejected.
        let err = PipelineConfig::from_json(
            r#"{"dist": {"listen": "127.0.0.1:0", "workers": 1, "lease_timeout": 0.0}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("positive"), "{err}");
        // And the worker ceiling holds for dist workers too.
        assert!(PipelineConfig::from_json(
            r#"{"dist": {"listen": "127.0.0.1:0", "workers": 5000}}"#
        )
        .is_err());
    }

    #[test]
    fn rejects_bad_threshold() {
        let err = PipelineConfig::from_json(r#"{"threshold": 1, "iterations": 2}"#).unwrap_err();
        assert!(err.to_string().contains("≥ 2"), "{err}");
    }

    #[test]
    fn m0_with_threshold_1_allowed() {
        // m = 0 means TC never runs; t* is irrelevant.
        assert!(PipelineConfig::from_json(r#"{"threshold": 1, "iterations": 0}"#).is_ok());
    }

    #[test]
    fn rejects_unknown_enum_values() {
        assert!(PipelineConfig::from_json(r#"{"prototype": "quantum"}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"backend": "gpu"}"#).is_err());
        assert!(
            PipelineConfig::from_json(r#"{"clusterer": {"kind": "hac", "k": 3, "linkage": "x"}}"#)
                .is_err()
        );
    }

    #[test]
    fn rejects_invalid_dbscan() {
        let err = PipelineConfig::from_json(
            r#"{"clusterer": {"kind": "dbscan", "eps": 0.0, "min_pts": 4}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("dbscan"), "{err}");
    }

    #[test]
    fn streaming_parse_and_validation() {
        let cfg = PipelineConfig::from_json(
            r#"{"streaming": true, "prototype": "weighted", "iterations": 2}"#,
        )
        .unwrap();
        assert!(cfg.streaming);
        assert!(!PipelineConfig::from_json("{}").unwrap().streaming);
        // Streaming needs at least the fused level-0 iteration…
        let err = PipelineConfig::from_json(
            r#"{"streaming": true, "prototype": "weighted", "iterations": 0}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("iterations"), "{err}");
        // …and weighted centroids so the fused means stay exact.
        let err = PipelineConfig::from_json(r#"{"streaming": true}"#).unwrap_err();
        assert!(err.to_string().contains("weighted"), "{err}");
    }

    #[test]
    fn reduce_stages_parse_and_validation() {
        assert_eq!(PipelineConfig::from_json("{}").unwrap().reduce_stages, 1);
        let cfg = PipelineConfig::from_json(
            r#"{"streaming": true, "prototype": "weighted", "reduce_stages": 4}"#,
        )
        .unwrap();
        assert_eq!(cfg.reduce_stages, 4);
        let err = PipelineConfig::from_json(r#"{"reduce_stages": 0}"#).unwrap_err();
        assert!(err.to_string().contains("reduce_stages"), "{err}");
        // A fan-out on the materialized path would be silently inert —
        // reject it instead.
        let err = PipelineConfig::from_json(r#"{"reduce_stages": 4}"#).unwrap_err();
        assert!(err.to_string().contains("streaming"), "{err}");
        // Wrong-typed knobs are config errors, not silently ignored
        // fields — a dropped "streaming" would flip the execution path.
        assert!(PipelineConfig::from_json(r#"{"reduce_stages": "four"}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"workers": "four"}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"shard_size": 2.5}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"streaming": "true"}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"iterations": "2"}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"prototype": 3}"#).is_err());
    }

    #[test]
    fn checkpoint_parse_and_validation() {
        let cfg = PipelineConfig::from_json(
            r#"{"streaming": true, "prototype": "weighted",
                "checkpoint_path": "/tmp/run.ckpt", "checkpoint_every_rows": 100000,
                "resume": true}"#,
        )
        .unwrap();
        assert_eq!(cfg.checkpoint_path.as_deref(), Some("/tmp/run.ckpt"));
        assert_eq!(cfg.checkpoint_every_rows, 100_000);
        assert!(cfg.resume);
        // Defaults: no checkpoint, fsync-every-frame cadence, no resume.
        let cfg = PipelineConfig::from_json("{}").unwrap();
        assert!(cfg.checkpoint_path.is_none());
        assert_eq!(cfg.checkpoint_every_rows, 0);
        assert!(!cfg.resume);
        // A checkpoint on the materialized path would be silently inert.
        let err =
            PipelineConfig::from_json(r#"{"checkpoint_path": "/tmp/run.ckpt"}"#).unwrap_err();
        assert!(err.to_string().contains("streaming"), "{err}");
        // Resume without a file to replay from is a contradiction…
        let err = PipelineConfig::from_json(
            r#"{"streaming": true, "prototype": "weighted", "resume": true}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("checkpoint_path"), "{err}");
        // …and so is a sync cadence with nothing durable to sync.
        let err = PipelineConfig::from_json(
            r#"{"streaming": true, "prototype": "weighted", "checkpoint_every_rows": 512}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("checkpoint_every_rows"), "{err}");
        // Mistyped knobs are config errors, never silently ignored.
        assert!(PipelineConfig::from_json(r#"{"checkpoint_path": 3}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"resume": "yes"}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"checkpoint_every_rows": "many"}"#).is_err());
    }

    #[test]
    fn knn_shards_parse_and_validation() {
        assert_eq!(PipelineConfig::from_json("{}").unwrap().knn_shards, 1);
        let cfg = PipelineConfig::from_json(r#"{"knn_shards": 4}"#).unwrap();
        assert_eq!(cfg.knn_shards, 4);
        let err = PipelineConfig::from_json(r#"{"knn_shards": 0}"#).unwrap_err();
        assert!(err.to_string().contains("knn_shards"), "{err}");
        // Mistyped knobs are config errors, never silently ignored.
        assert!(PipelineConfig::from_json(r#"{"knn_shards": "four"}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"knn_shards": 2.5}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"knn_shards": true}"#).is_err());
    }

    #[test]
    fn executor_block_parses_and_validates() {
        let cfg = PipelineConfig::from_json(
            r#"{"executor": {"workers": 6, "steal": "lifo", "fair_stages": false}}"#,
        )
        .unwrap();
        assert_eq!(cfg.workers, 6);
        assert_eq!(cfg.steal, StealPolicy::Lifo);
        assert!(!cfg.fair_stages);
        let ex = cfg.executor();
        assert_eq!(ex.workers, 6);
        assert_eq!(ex.steal, StealPolicy::Lifo);
        assert!(!ex.fair_stages);
        // Defaults.
        let cfg = PipelineConfig::from_json("{}").unwrap();
        assert_eq!(cfg.steal, StealPolicy::Fifo);
        assert!(cfg.fair_stages);
        // Unknown policy and mistyped knobs are config errors, and so
        // is an absurd thread budget (the executor takes it literally).
        assert!(PipelineConfig::from_json(r#"{"executor": {"workers": 100000}}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"workers": 4096}"#).is_ok());
        assert!(PipelineConfig::from_json(r#"{"executor": {"steal": "random"}}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"executor": {"fair_stages": "yes"}}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"executor": {"workers": "four"}}"#).is_err());
    }

    #[test]
    fn reduce_stages_may_exceed_worker_budget() {
        // reduce_stages caps in-flight executor batches, not threads:
        // a count above an explicit worker budget is valid (batches
        // queue on the team) — the retired stage-thread scheme's budget
        // error is gone with the stage threads themselves.
        assert!(PipelineConfig::from_json(
            r#"{"streaming": true, "prototype": "weighted", "reduce_stages": 4, "workers": 2}"#,
        )
        .is_ok());
        assert!(PipelineConfig::from_json(
            r#"{"streaming": true, "prototype": "weighted", "reduce_stages": 4, "workers": 1}"#,
        )
        .is_ok());
        assert!(PipelineConfig::from_json(
            r#"{"streaming": true, "prototype": "weighted", "reduce_stages": 8}"#,
        )
        .is_ok());
    }

    #[test]
    fn reduce_priority_parse_and_validation() {
        assert_eq!(PipelineConfig::from_json("{}").unwrap().reduce_priority, Priority::Normal);
        let cfg = PipelineConfig::from_json(
            r#"{"streaming": true, "prototype": "weighted", "reduce_priority": "bulk"}"#,
        )
        .unwrap();
        assert_eq!(cfg.reduce_priority, Priority::Bulk);
        let cfg = PipelineConfig::from_json(
            r#"{"streaming": true, "prototype": "weighted", "reduce_priority": "high"}"#,
        )
        .unwrap();
        assert_eq!(cfg.reduce_priority, Priority::High);
        // Unknown classes and mistyped knobs are config errors.
        let err = PipelineConfig::from_json(
            r#"{"streaming": true, "prototype": "weighted", "reduce_priority": "urgent"}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("reduce_priority"), "{err}");
        assert!(PipelineConfig::from_json(r#"{"reduce_priority": 3}"#).is_err());
        // A non-default class on the materialized path would be
        // silently inert — reject it instead.
        let err = PipelineConfig::from_json(r#"{"reduce_priority": "bulk"}"#).unwrap_err();
        assert!(err.to_string().contains("streaming"), "{err}");
        // The default class is accepted anywhere (it IS the default).
        assert!(PipelineConfig::from_json(r#"{"reduce_priority": "normal"}"#).is_ok());
    }

    #[test]
    fn simd_knob_is_a_build_assertion() {
        // Default mirrors the build, so "{}" always validates.
        assert_eq!(PipelineConfig::from_json("{}").unwrap().simd, cfg!(feature = "simd"));
        let matching = format!(r#"{{"simd": {}}}"#, cfg!(feature = "simd"));
        assert!(PipelineConfig::from_json(&matching).is_ok());
        // A knob that disagrees with the build would be silently inert
        // (dispatch is resolved from the build, not the config).
        let mismatched = format!(r#"{{"simd": {}}}"#, !cfg!(feature = "simd"));
        let err = PipelineConfig::from_json(&mismatched).unwrap_err();
        assert!(err.to_string().contains("simd"), "{err}");
        // Mistyped knobs are config errors, never silently ignored.
        assert!(PipelineConfig::from_json(r#"{"simd": "yes"}"#).is_err());
    }

    #[test]
    fn kmeans_bounds_parse_and_validation() {
        assert!(!PipelineConfig::from_json("{}").unwrap().kmeans_bounds);
        // Default clusterer is kmeans on the native backend → valid.
        assert!(PipelineConfig::from_json(r#"{"kmeans_bounds": true}"#).unwrap().kmeans_bounds);
        // Bound pruning lives in the k-means scan — inert elsewhere.
        let err = PipelineConfig::from_json(
            r#"{"kmeans_bounds": true, "clusterer": {"kind": "hac", "k": 3}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("kmeans"), "{err}");
        // The PJRT backend evaluates whole tiles; it cannot prune.
        let err = PipelineConfig::from_json(r#"{"kmeans_bounds": true, "backend": "pjrt"}"#)
            .unwrap_err();
        assert!(err.to_string().contains("native"), "{err}");
        assert!(PipelineConfig::from_json(r#"{"backend": "pjrt"}"#).is_ok());
        // Mistyped knobs are config errors, never silently ignored.
        assert!(PipelineConfig::from_json(r#"{"kmeans_bounds": "yes"}"#).is_err());
    }

    #[test]
    fn pca_variance_bounds() {
        assert!(PipelineConfig::from_json(r#"{"pca_variance": 1.5}"#).is_err());
        assert!(PipelineConfig::from_json(r#"{"pca_variance": 0.9}"#).is_ok());
    }
}
