//! Minimal JSON parser (no external crates are available offline).
//!
//! Supports the full JSON grammar minus exotic escapes (`\uXXXX` is
//! decoded for the BMP). Used for `artifacts/manifest.json` and for the
//! pipeline config files.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64, like JavaScript).
    Number(f64),
    /// String.
    String(String),
    /// Array.
    Array(Vec<Json>),
    /// Object (sorted keys for deterministic output).
    Object(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(map) => map.get(key),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (rejects negatives / fractions).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Number(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    /// Required typed accessors with error context for config parsing.
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Config(format!("missing or non-integer field '{key}'")))
    }

    /// Required string field.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Config(format!("missing or non-string field '{key}'")))
    }

    /// Optional non-negative integer field: `Ok(None)` when absent or
    /// explicitly `null` (the standard JSON spelling of "unset"), an
    /// error when present with the wrong type — silently coercing (or
    /// dropping) a typo'd config knob is worse than failing the parse.
    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(v) => v.as_usize().map(Some).ok_or_else(|| {
                Error::Config(format!("field '{key}' must be a non-negative integer"))
            }),
        }
    }

    /// Optional boolean field, strict like [`Self::opt_usize`].
    pub fn opt_bool(&self, key: &str) -> Result<Option<bool>> {
        match self.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(v) => v
                .as_bool()
                .map(Some)
                .ok_or_else(|| Error::Config(format!("field '{key}' must be a boolean"))),
        }
    }

    /// Optional number field, strict like [`Self::opt_usize`].
    pub fn opt_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(v) => v
                .as_f64()
                .map(Some)
                .ok_or_else(|| Error::Config(format!("field '{key}' must be a number"))),
        }
    }

    /// Optional string field, strict like [`Self::opt_usize`].
    pub fn opt_str(&self, key: &str) -> Result<Option<&str>> {
        match self.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(v) => v
                .as_str()
                .map(Some)
                .ok_or_else(|| Error::Config(format!("field '{key}' must be a string"))),
        }
    }
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        let line = self.src[..self.pos.min(self.src.len())]
            .iter()
            .filter(|&&c| c == b'\n')
            .count()
            + 1;
        Error::Config(format!("JSON parse error at line {line}: {msg}"))
    }

    fn skip_ws(&mut self) {
        while self.pos < self.src.len() && self.src[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Number)
            .map_err(|_| self.err(&format!("bad number '{text}'")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.src.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.src[self.pos..self.pos + 4]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(c) => {
                    // Copy a UTF-8 run verbatim.
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let end = (self.pos + len).min(self.src.len());
                    out.push_str(std::str::from_utf8(&self.src[self.pos..end]).map_err(|_| self.err("bad utf8"))?);
                    self.pos = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Number(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Number(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::String("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": "x"}], "c": {"d": false}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::String("A".into()));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(Json::parse("\"αβγ\"").unwrap(), Json::String("αβγ".into()));
    }

    #[test]
    fn req_accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req_usize("missing").is_err());
        assert!(v.req_usize("s").is_err());
    }

    #[test]
    fn opt_usize_distinguishes_absent_from_mistyped() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "f": 2.5, "neg": -1, "nil": null}"#).unwrap();
        assert_eq!(v.opt_usize("n").unwrap(), Some(3));
        assert_eq!(v.opt_usize("missing").unwrap(), None);
        assert!(v.opt_usize("s").is_err());
        assert!(v.opt_usize("f").is_err());
        assert!(v.opt_usize("neg").is_err());
        // Explicit null is the JSON idiom for "unset", not a type error.
        assert_eq!(v.opt_usize("nil").unwrap(), None);
        assert_eq!(v.opt_bool("nil").unwrap(), None);
        assert_eq!(v.opt_f64("nil").unwrap(), None);
        assert_eq!(v.opt_str("nil").unwrap(), None);
        assert!(v.opt_bool("n").is_err());
        assert_eq!(v.opt_f64("f").unwrap(), Some(2.5));
        assert_eq!(v.opt_str("s").unwrap(), Some("x"));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{
          "tile": {"knn_q": 256, "dim": 8},
          "artifacts": [{"name": "knn", "file": "knn.hlo.txt",
                         "inputs": [{"shape": [256, 8], "dtype": "float32"}]}]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("tile").unwrap().req_usize("knn_q").unwrap(), 256);
        let a = &v.get("artifacts").unwrap().as_array().unwrap()[0];
        assert_eq!(a.req_str("file").unwrap(), "knn.hlo.txt");
    }
}
