//! # ihtc — Iterative Hybridized Threshold Clustering for Massive Data
//!
//! A production-grade reproduction of *Hybridized Threshold Clustering for
//! Massive Data* (Luo, Annakula, Kannamareddy, Sekhon, Hsu, Higgins; stat.ML
//! 2019) as a three-layer Rust + JAX + Pallas data-pipeline framework.
//!
//! The paper's contributions, all implemented here:
//!
//! * [`tc`] — **threshold clustering** (TC), a 4-approximation to the
//!   bottleneck threshold partitioning problem: every cluster has at least
//!   `t*` units and the maximum within-cluster dissimilarity is within a
//!   factor 4 of optimal (Higgins et al. 2016).
//! * [`itis`] — **iterated threshold instance selection**: repeated TC +
//!   prototype (centroid) collapse, reducing `n` by a factor `(t*)^m`.
//! * [`hybrid`] — **IHTC**: ITIS as a pre-processing step for a
//!   conventional clustering algorithm ([`cluster::kmeans`],
//!   [`cluster::hac`], [`cluster::dbscan`]) followed by "backing out" the
//!   prototype labels onto all `n` original units.
//!
//! Everything on the request path is Rust. The numeric hot-spot (tiled
//! pairwise distances feeding k-NN construction and k-means assignment) is
//! authored in JAX + Pallas (`python/compile/`), AOT-lowered to HLO text,
//! and executed through the PJRT CPU client by [`runtime`]. The
//! [`coordinator`] module provides the streaming orchestrator (sharding,
//! bounded-channel backpressure, work-stealing workers) that drives the
//! whole pipeline over large datasets.
//!
//! ## Quick start
//!
//! ```no_run
//! use ihtc::data::synth::gaussian_mixture_paper;
//! use ihtc::hybrid::{Ihtc, FinalClusterer};
//!
//! let ds = gaussian_mixture_paper(10_000, 42);
//! let result = Ihtc::new(2, 3, FinalClusterer::KMeans { k: 3, restarts: 4 })
//!     .run(&ds.points)
//!     .unwrap();
//! assert!(result.assignments.len() == 10_000);
//! ```

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod hybrid;
pub mod itis;
pub mod knn;
pub mod linalg;
pub mod memtrack;
pub mod metrics;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod tc;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("invalid argument: {0}")]
    InvalidArgument(String),
    #[error("shape mismatch: {0}")]
    Shape(String),
    #[error("dataset error: {0}")]
    Data(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),
    #[error("coordinator error: {0}")]
    Coordinator(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Bail out with [`Error::InvalidArgument`].
#[macro_export]
macro_rules! invalid {
    ($($arg:tt)*) => {
        return Err($crate::Error::InvalidArgument(format!($($arg)*)))
    };
}
