//! # ihtc — Iterative Hybridized Threshold Clustering for Massive Data
//!
//! A production-grade reproduction of *Hybridized Threshold Clustering for
//! Massive Data* (Luo, Annakula, Kannamareddy, Sekhon, Hsu, Higgins; stat.ML
//! 2019) as a three-layer Rust + JAX + Pallas data-pipeline framework.
//!
//! The paper's contributions, all implemented here:
//!
//! * [`tc`] — **threshold clustering** (TC), a 4-approximation to the
//!   bottleneck threshold partitioning problem: every cluster has at least
//!   `t*` units and the maximum within-cluster dissimilarity is within a
//!   factor 4 of optimal (Higgins et al. 2016).
//! * [`itis`] — **iterated threshold instance selection**: repeated TC +
//!   prototype (centroid) collapse, reducing `n` by a factor `(t*)^m`.
//! * [`hybrid`] — **IHTC**: ITIS as a pre-processing step for a
//!   conventional clustering algorithm ([`cluster::kmeans`],
//!   [`cluster::hac`], [`cluster::dbscan`]) followed by "backing out" the
//!   prototype labels onto all `n` original units.
//!
//! Everything on the request path is Rust. The numeric hot-spot (tiled
//! pairwise distances feeding k-NN construction and k-means assignment) is
//! authored in JAX + Pallas (`python/compile/`), AOT-lowered to HLO text,
//! and executed through the PJRT CPU client by [`runtime`]. The
//! [`coordinator`] module provides the streaming orchestrator (sharding,
//! bounded-channel backpressure, work-stealing workers) that drives the
//! whole pipeline over large datasets.
//!
//! ## Quick start
//!
//! ```no_run
//! use ihtc::data::synth::gaussian_mixture_paper;
//! use ihtc::hybrid::{Ihtc, FinalClusterer};
//!
//! let ds = gaussian_mixture_paper(10_000, 42);
//! let result = Ihtc::new(2, 3, FinalClusterer::KMeans { k: 3, restarts: 4 })
//!     .run(&ds.points)
//!     .unwrap();
//! assert!(result.assignments.len() == 10_000);
//! ```
//!
//! ## Verification lanes
//!
//! The determinism contract ("same config ⇒ same bytes", any worker
//! count, any steal policy) rests on hand-written atomics in [`exec`].
//! Those are machine-checked, not just test-passed: [`sync`] is a
//! facade that swaps `std` primitives for loom's model-checked doubles
//! under `--cfg loom`, nightly CI runs Miri and ThreadSanitizer over
//! the unsafe core, and an in-tree lint (`rust/xtask`) rejects unsafe
//! blocks without SAFETY comments and nondeterministic collection
//! iteration in output-affecting modules. See README §Verification
//! lanes for how to run each lane locally.

// Every unsafe operation inside an `unsafe fn` must sit in its own
// `unsafe {}` block with its own SAFETY argument — the fn-level
// contract never silently licenses the body's dereferences.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod checkpoint;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod exec;
pub mod hybrid;
pub mod itis;
pub mod knn;
pub mod linalg;
pub mod memtrack;
pub mod metrics;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod sync;
pub mod tc;

/// Crate-wide error type.
///
/// Implemented by hand (no `thiserror`): the offline build has no
/// crates.io access, so the crate carries zero external dependencies.
#[derive(Debug)]
pub enum Error {
    /// A caller-supplied argument was out of range or inconsistent.
    InvalidArgument(String),
    /// Matrix/buffer shapes disagree.
    Shape(String),
    /// Dataset loading or validation failed.
    Data(String),
    /// Configuration parsing or validation failed.
    Config(String),
    /// The PJRT runtime failed (or is compiled out; see the `pjrt` feature).
    Runtime(String),
    /// The streaming coordinator failed.
    Coordinator(String),
    /// An underlying I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidArgument(m) => write!(f, "invalid argument: {m}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Data(m) => write!(f, "dataset error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Runtime(m) => write!(f, "runtime (PJRT) error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Bail out with [`Error::InvalidArgument`].
#[macro_export]
macro_rules! invalid {
    ($($arg:tt)*) => {
        return Err($crate::Error::InvalidArgument(format!($($arg)*)))
    };
}
