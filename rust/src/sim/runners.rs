//! Experiment runners for every table/figure of the paper.

use crate::cluster::dbscan;
use crate::cluster::hac::Linkage;
use crate::data::synth::{gaussian_mixture_paper, realistic, RealDatasetSpec, TABLE3};
use crate::data::{Dataset, Preprocess};
use crate::hybrid::{FinalClusterer, Ihtc};
use crate::itis::{itis, ItisConfig};
use crate::linalg::Matrix;
use crate::memtrack;
use crate::metrics;
use crate::report::{fmt4, fmt_secs, Table};
use crate::Result;
use std::time::Instant;

/// Workload scale. The paper sweeps n up to 10⁸ with 1000 replicates on a
/// 30 GB cluster node; these presets keep the same *shape* inside this
/// testbed's budget (see DESIGN.md §3 "Scale substitution").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Tiny — used by the integration tests.
    Smoke,
    /// Laptop-minutes (default): n ∈ {10⁴, 10⁵, 10⁶ (kmeans only)}.
    Default,
    /// Adds the next decade where feasible; several minutes per table.
    Full,
}

impl Scale {
    /// Parse from CLI text.
    pub fn parse(s: &str) -> Result<Scale> {
        match s {
            "smoke" => Ok(Scale::Smoke),
            "default" => Ok(Scale::Default),
            "full" => Ok(Scale::Full),
            other => Err(crate::Error::InvalidArgument(format!(
                "unknown scale '{other}' (smoke|default|full)"
            ))),
        }
    }

    fn kmeans_sizes(&self) -> Vec<usize> {
        match self {
            Scale::Smoke => vec![2_000],
            Scale::Default => vec![10_000, 100_000],
            Scale::Full => vec![10_000, 100_000, 1_000_000],
        }
    }

    fn hac_sizes(&self) -> Vec<usize> {
        match self {
            Scale::Smoke => vec![2_000],
            Scale::Default => vec![10_000, 100_000],
            Scale::Full => vec![10_000, 100_000, 1_000_000],
        }
    }

    /// Stand-in for R's 65 536-point `hclust` limit, scaled to this
    /// testbed (the paper's frontier shape is preserved: HAC is only
    /// feasible once ITIS brings the prototype count under the cap).
    fn hac_cap(&self) -> usize {
        match self {
            Scale::Smoke => 700,
            Scale::Default => 16_384,
            Scale::Full => 65_536,
        }
    }

    fn analogue_target(&self) -> usize {
        match self {
            Scale::Smoke => 1_500,
            Scale::Default => 30_000,
            Scale::Full => 150_000,
        }
    }

    fn tstar_list(&self) -> Vec<usize> {
        match self {
            Scale::Smoke => vec![2, 4, 8],
            Scale::Default => vec![2, 4, 8, 16, 32, 64, 128, 256],
            Scale::Full => vec![2, 4, 8, 16, 32, 64, 128, 256, 512, 1024],
        }
    }

    fn max_m(&self) -> usize {
        match self {
            Scale::Smoke => 4,
            _ => 12,
        }
    }
}

/// One measured IHTC run.
#[derive(Clone, Debug)]
pub struct Measured {
    /// Wall-clock seconds (whole IHTC, matching the paper's "whole
    /// procedure" accounting).
    pub seconds: f64,
    /// Peak allocation above baseline, bytes (0 without the counting
    /// allocator installed).
    pub peak_bytes: usize,
    /// Accuracy vs ground truth, when labels exist.
    pub accuracy: Option<f64>,
    /// BSS/TSS of the final clustering.
    pub bss_tss: f64,
    /// Prototypes the final clusterer saw.
    pub prototypes: usize,
}

/// Run one IHTC configuration with timing + peak-memory brackets.
/// Returns `None` when the final clusterer is infeasible at this size
/// (e.g. HAC above its cap) — the paper's "-" cells.
pub fn run_measured(
    points: &Matrix,
    truth: Option<&[u32]>,
    threshold: usize,
    m: usize,
    clusterer: FinalClusterer,
    hac_cap: usize,
    seed: u64,
) -> Result<Option<Measured>> {
    // Feasibility pre-check for HAC at m = 0 (avoid allocating n²/2).
    if let FinalClusterer::Hac { .. } = clusterer {
        let upper = points.rows() / 2usize.pow(m as u32).max(1);
        if m == 0 && points.rows() > hac_cap {
            return Ok(None);
        }
        // Heuristic skip: even optimistic reduction leaves it over cap.
        if upper / 2 > hac_cap {
            return Ok(None);
        }
    }
    let t0 = Instant::now();
    let (result, peak) = memtrack::measure(|| -> Result<_> {
        let mut ih = Ihtc::new(threshold, m, clusterer.clone());
        ih.seed = seed;
        let r = ih.run(points)?;
        // Enforce the HAC cap on what the final clusterer actually saw.
        if matches!(clusterer, FinalClusterer::Hac { .. }) && r.num_prototypes() > hac_cap {
            return Ok(None);
        }
        Ok(Some(r))
    });
    let seconds = t0.elapsed().as_secs_f64();
    let r = match result? {
        Some(r) => r,
        None => return Ok(None),
    };
    let accuracy = match truth {
        Some(t) => Some(metrics::prediction_accuracy(t, &r.assignments)?),
        None => None,
    };
    let bss = metrics::bss_tss(points, &r.assignments)?;
    Ok(Some(Measured {
        seconds,
        peak_bytes: peak,
        accuracy,
        bss_tss: bss,
        prototypes: r.num_prototypes(),
    }))
}

fn mb(bytes: usize) -> String {
    memtrack::fmt_mb(bytes)
}

fn dash() -> String {
    "-".into()
}

/// Sweep m for one clusterer over the §4 GMM; returns wide tables
/// (time / memory / accuracy: rows = m, one column per n) plus a long
/// CSV table for the figures.
fn gmm_iteration_sweep(
    title: &str,
    stem: &str,
    sizes: &[usize],
    max_m: usize,
    clusterer: impl Fn(usize) -> FinalClusterer,
    hac_cap: usize,
    seed: u64,
) -> Result<Vec<Table>> {
    let k = 3;
    let mut headers = vec!["m".to_string()];
    headers.extend(sizes.iter().map(|n| format!("n=1e{}", (*n as f64).log10().round() as u32)));
    let hdr: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t_time = Table::new(format!("{title} — run time (s)"), &hdr);
    let mut t_mem = Table::new(format!("{title} — peak memory (MB)"), &hdr);
    let mut t_acc = Table::new(format!("{title} — prediction accuracy"), &hdr);
    let mut long = Table::new(
        format!("{title} — long format (figure data)"),
        &["n", "m", "seconds", "mem_mb", "accuracy", "prototypes"],
    );

    // Generate each dataset once and reuse across m (the sweep axis).
    let datasets: Vec<Dataset> =
        sizes.iter().map(|&n| gaussian_mixture_paper(n, seed)).collect();

    for m in 0..=max_m {
        // Stop the sweep once every dataset would be reduced below a
        // meaningful prototype count (the paper's trailing "-" region).
        let any_possible = datasets
            .iter()
            .any(|ds| m == 0 || ds.len() / 2usize.pow(m as u32).max(1) >= 4 * k);
        if !any_possible {
            break;
        }
        let mut row_t = vec![m.to_string()];
        let mut row_m = vec![m.to_string()];
        let mut row_a = vec![m.to_string()];
        for ds in &datasets {
            // Too few prototypes for a meaningful k-cluster fit → "-".
            let est_protos = ds.len() / 2usize.pow(m as u32).max(1);
            let feasible = m == 0 || est_protos >= 4 * k;
            let cell = if feasible {
                run_measured(
                    &ds.points,
                    ds.labels.as_deref(),
                    2,
                    m,
                    clusterer(k),
                    hac_cap,
                    seed,
                )?
            } else {
                None
            };
            match cell {
                Some(meas) => {
                    row_t.push(fmt_secs(meas.seconds));
                    row_m.push(mb(meas.peak_bytes));
                    row_a.push(meas.accuracy.map(fmt4).unwrap_or_else(dash));
                    long.push_row(vec![
                        ds.len().to_string(),
                        m.to_string(),
                        format!("{:.6}", meas.seconds),
                        mb(meas.peak_bytes),
                        meas.accuracy.map(fmt4).unwrap_or_else(dash),
                        meas.prototypes.to_string(),
                    ]);
                }
                None => {
                    row_t.push(dash());
                    row_m.push(dash());
                    row_a.push(dash());
                }
            }
        }
        t_time.push_row(row_t);
        t_mem.push_row(row_m);
        t_acc.push_row(row_a);
    }
    let _ = stem;
    Ok(vec![t_time, t_mem, t_acc, long])
}

/// Table 1 / Figures 3–4: IHTC with k-means on the §4 mixture.
pub fn table1(scale: Scale, seed: u64) -> Result<Vec<Table>> {
    gmm_iteration_sweep(
        "Table 1: IHTC + k-means (k=3, t*=2)",
        "table1",
        &scale.kmeans_sizes(),
        scale.max_m(),
        |k| FinalClusterer::KMeans { k, restarts: 4 },
        usize::MAX,
        seed,
    )
}

/// Table 2 / Figures 5–6: IHTC with HAC on the §4 mixture.
pub fn table2(scale: Scale, seed: u64) -> Result<Vec<Table>> {
    gmm_iteration_sweep(
        "Table 2: IHTC + HAC (t*=2, Ward)",
        "table2",
        &scale.hac_sizes(),
        scale.max_m(),
        |k| FinalClusterer::Hac { k, linkage: Linkage::Ward },
        scale.hac_cap(),
        seed,
    )
}

/// Table 3: the dataset roster (paper sizes + analogue shapes), with the
/// elbow-selected k recomputed the way §5 chooses "Classes" — k from the
/// elbow of the WCSS curve on a subsample of each (analogue) dataset.
pub fn table3() -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 3: datasets (synthetic analogues; see DESIGN.md §4)",
        &["Name", "Instances (paper)", "Attributes", "Classes (paper)", "Elbow k (measured)"],
    );
    for spec in TABLE3 {
        let ds = realistic(spec, (spec.instances / 4_000).max(1), 42);
        let prep = Preprocess { standardize: true, pca_variance: Some(0.99), max_components: None }
            .apply(&ds)?;
        let elbow = crate::cluster::elbow::select_k(&prep.points, 1, 10, 2_000, 42)?;
        t.push_row(vec![
            spec.name.to_string(),
            spec.instances.to_string(),
            spec.attributes.to_string(),
            spec.classes.to_string(),
            elbow.k.to_string(),
        ]);
    }
    Ok(vec![t])
}

fn prepared_analogue(spec: &RealDatasetSpec, scale: Scale, seed: u64) -> Result<Dataset> {
    let target = scale.analogue_target().min(spec.instances);
    let div = (spec.instances / target).max(1);
    let ds = realistic(spec, div, seed);
    // Paper §5: PCA feature selection + standardized Euclidean distances.
    Preprocess { standardize: true, pca_variance: Some(0.99), max_components: None }.apply(&ds)
}

/// Table 4 / Figure 7: IHTC + k-means on the six dataset analogues.
pub fn table4(scale: Scale, seed: u64) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 4: IHTC + k-means on dataset analogues (t*=2)",
        &["Name", "m", "seconds", "mem_mb", "bss_tss", "prototypes", "n"],
    );
    let specs: &[&RealDatasetSpec] = &match scale {
        Scale::Smoke => TABLE3.iter().take(2).collect::<Vec<_>>(),
        _ => TABLE3.iter().collect::<Vec<_>>(),
    };
    for spec in specs {
        let ds = prepared_analogue(spec, scale, seed)?;
        for m in 0..=3 {
            let meas = run_measured(
                &ds.points,
                None,
                2,
                m,
                FinalClusterer::KMeans { k: spec.classes, restarts: 4 },
                usize::MAX,
                seed,
            )?
            .expect("kmeans always feasible");
            t.push_row(vec![
                spec.name.to_string(),
                m.to_string(),
                fmt_secs(meas.seconds),
                mb(meas.peak_bytes),
                fmt4(meas.bss_tss),
                meas.prototypes.to_string(),
                ds.len().to_string(),
            ]);
        }
    }
    Ok(vec![t])
}

fn hac_analogue_rows(
    t: &mut Table,
    spec: &RealDatasetSpec,
    m_values: &[usize],
    scale: Scale,
    seed: u64,
) -> Result<()> {
    let ds = prepared_analogue(spec, scale, seed)?;
    for &m in m_values {
        let meas = run_measured(
            &ds.points,
            None,
            2,
            m,
            FinalClusterer::Hac { k: spec.classes, linkage: Linkage::Ward },
            scale.hac_cap(),
            seed,
        )?;
        match meas {
            Some(meas) => t.push_row(vec![
                spec.name.to_string(),
                m.to_string(),
                fmt_secs(meas.seconds),
                mb(meas.peak_bytes),
                fmt4(meas.bss_tss),
                meas.prototypes.to_string(),
                ds.len().to_string(),
            ]),
            None => t.push_row(vec![
                spec.name.to_string(),
                m.to_string(),
                dash(),
                dash(),
                dash(),
                dash(),
                ds.len().to_string(),
            ]),
        }
    }
    Ok(())
}

/// Table 5 / Figure 8: IHTC + HAC on the three smaller analogues.
pub fn table5(scale: Scale, seed: u64) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 5: IHTC + HAC on smaller analogues (t*=2, Ward)",
        &["Name", "m", "seconds", "mem_mb", "bss_tss", "prototypes", "n"],
    );
    let plan: &[(&str, &[usize])] = &[
        ("PM 2.5", &[0, 1, 2, 3]),
        ("Credit Score", &[0, 2, 3, 4]),
        ("Black Friday", &[0, 1, 2, 3]),
    ];
    for (name, ms) in plan {
        let spec = TABLE3.iter().find(|s| s.name == *name).unwrap();
        hac_analogue_rows(&mut t, spec, ms, scale, seed)?;
        if scale == Scale::Smoke {
            break;
        }
    }
    Ok(vec![t])
}

/// Table 6 / Figure 8: IHTC + HAC on the three larger analogues.
pub fn table6(scale: Scale, seed: u64) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 6: IHTC + HAC on larger analogues (t*=2, Ward)",
        &["Name", "m", "seconds", "mem_mb", "bss_tss", "prototypes", "n"],
    );
    let plan: &[(&str, &[usize])] = &[
        ("Covertype", &[0, 4, 5, 6]),
        ("House Price", &[0, 6, 7, 8]),
        ("Stock", &[0, 7, 8, 9]),
    ];
    for (name, ms) in plan {
        let spec = TABLE3.iter().find(|s| s.name == *name).unwrap();
        hac_analogue_rows(&mut t, spec, ms, scale, seed)?;
        if scale == Scale::Smoke {
            break;
        }
    }
    Ok(vec![t])
}

/// t*-sweep core shared by Tables 7 and 8 (m = 1, Appendix A).
fn tstar_sweep(
    title: &str,
    sizes: &[usize],
    tstars: &[usize],
    clusterer: impl Fn(usize) -> FinalClusterer,
    hac_cap: usize,
    seed: u64,
) -> Result<Vec<Table>> {
    let k = 3;
    let mut headers = vec!["t*".to_string()];
    headers.extend(sizes.iter().map(|n| format!("n=1e{}", (*n as f64).log10().round() as u32)));
    let hdr: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t_time = Table::new(format!("{title} — run time (s)"), &hdr);
    let mut t_mem = Table::new(format!("{title} — peak memory (MB)"), &hdr);
    let mut t_acc = Table::new(format!("{title} — prediction accuracy"), &hdr);
    let mut long = Table::new(
        format!("{title} — long format (figure data)"),
        &["n", "tstar", "seconds", "mem_mb", "accuracy", "prototypes"],
    );
    let datasets: Vec<Dataset> =
        sizes.iter().map(|&n| gaussian_mixture_paper(n, seed)).collect();

    // "None" row = no pre-processing (m = 0).
    let mut rows: Vec<(String, Option<usize>)> = vec![("None".into(), None)];
    rows.extend(tstars.iter().map(|&t| (t.to_string(), Some(t))));

    for (label, tstar) in rows {
        let mut row_t = vec![label.clone()];
        let mut row_m = vec![label.clone()];
        let mut row_a = vec![label.clone()];
        for ds in &datasets {
            let feasible = match tstar {
                None => true,
                Some(t) => ds.len() / t >= 4 * k,
            };
            let cell = if feasible {
                run_measured(
                    &ds.points,
                    ds.labels.as_deref(),
                    tstar.unwrap_or(2),
                    usize::from(tstar.is_some()),
                    clusterer(k),
                    hac_cap,
                    seed,
                )?
            } else {
                None
            };
            match cell {
                Some(meas) => {
                    row_t.push(fmt_secs(meas.seconds));
                    row_m.push(mb(meas.peak_bytes));
                    row_a.push(meas.accuracy.map(fmt4).unwrap_or_else(dash));
                    long.push_row(vec![
                        ds.len().to_string(),
                        label.clone(),
                        format!("{:.6}", meas.seconds),
                        mb(meas.peak_bytes),
                        meas.accuracy.map(fmt4).unwrap_or_else(dash),
                        meas.prototypes.to_string(),
                    ]);
                }
                None => {
                    row_t.push(dash());
                    row_m.push(dash());
                    row_a.push(dash());
                }
            }
        }
        t_time.push_row(row_t);
        t_mem.push_row(row_m);
        t_acc.push_row(row_a);
    }
    Ok(vec![t_time, t_mem, t_acc, long])
}

/// Table 7 / Figures 9, 11: threshold sweep with k-means (m = 1).
pub fn table7(scale: Scale, seed: u64) -> Result<Vec<Table>> {
    tstar_sweep(
        "Table 7: t* sweep, IHTC + k-means (m=1, k=3)",
        &scale.kmeans_sizes(),
        &scale.tstar_list(),
        |k| FinalClusterer::KMeans { k, restarts: 4 },
        usize::MAX,
        seed,
    )
}

/// Table 8 / Figures 10, 11: threshold sweep with HAC (m = 1).
pub fn table8(scale: Scale, seed: u64) -> Result<Vec<Table>> {
    // HAC on n/t* prototypes is O((n/t*)²): restrict to the first size
    // tier at Default scale (the paper's own table is sparse here too).
    let sizes: Vec<usize> = match scale {
        Scale::Smoke => vec![2_000],
        Scale::Default => vec![10_000],
        Scale::Full => vec![10_000, 100_000],
    };
    tstar_sweep(
        "Table 8: t* sweep, IHTC + HAC (m=1, Ward)",
        &sizes,
        &scale.tstar_list(),
        |k| FinalClusterer::Hac { k, linkage: Linkage::Ward },
        scale.hac_cap(),
        seed,
    )
}

/// Table 9 (Appendix B): IHTC + DBSCAN on the four smallest analogues.
pub fn table9(scale: Scale, seed: u64) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 9: IHTC + DBSCAN on analogues (t*=2)",
        &["Name", "m", "seconds", "mem_mb", "bss_tss", "clusters", "noise_frac", "n"],
    );
    let names = ["PM 2.5", "Credit Score", "Black Friday", "Covertype"];
    let take = if scale == Scale::Smoke { 2 } else { 4 };
    for name in names.iter().take(take) {
        let spec = TABLE3.iter().find(|s| s.name == *name).unwrap();
        let ds = prepared_analogue(spec, scale, seed)?;
        // Parameter selection on a subsample, as in the paper's appendix.
        let params = dbscan::estimate_params(&ds.points, 1000, seed)?;
        for m in 0..=2 {
            let t0 = Instant::now();
            let (res, peak) = memtrack::measure(|| {
                let mut ih = Ihtc::new(
                    2,
                    m,
                    FinalClusterer::Dbscan { eps: params.eps, min_pts: params.min_pts },
                );
                ih.seed = seed;
                ih.run(&ds.points)
            });
            let secs = t0.elapsed().as_secs_f64();
            let r = res?;
            let noise =
                r.assignments.iter().filter(|&&a| a == dbscan::NOISE).count() as f64
                    / r.assignments.len() as f64;
            let clusters = r
                .assignments
                .iter()
                .filter(|&&a| a != dbscan::NOISE)
                .collect::<std::collections::HashSet<_>>()
                .len();
            let bss = metrics::bss_tss(&ds.points, &r.assignments)?;
            t.push_row(vec![
                spec.name.to_string(),
                m.to_string(),
                fmt_secs(secs),
                mb(peak),
                fmt4(bss),
                clusters.to_string(),
                fmt4(noise),
                ds.len().to_string(),
            ]);
        }
    }
    Ok(vec![t])
}

/// Ablation (DESIGN.md §Perf): seed-order and prototype-kind choices.
pub fn ablation(seed: u64) -> Result<Vec<Table>> {
    use crate::tc::SeedOrder;
    let ds = gaussian_mixture_paper(20_000, seed);
    let truth = ds.labels.as_deref();
    let mut t = Table::new(
        "Ablation: TC seed order × prototype kind (t*=2, m=2, k-means k=3)",
        &["seed_order", "prototype", "seconds", "accuracy", "prototypes"],
    );
    for (so_name, so) in [
        ("natural", SeedOrder::Natural),
        ("degree_asc", SeedOrder::DegreeAscending),
        ("degree_desc", SeedOrder::DegreeDescending),
    ] {
        for (pk_name, pk) in [
            ("centroid", crate::itis::PrototypeKind::Centroid),
            ("weighted", crate::itis::PrototypeKind::WeightedCentroid),
            ("medoid", crate::itis::PrototypeKind::Medoid),
        ] {
            let t0 = Instant::now();
            let mut ih = Ihtc::new(2, 2, FinalClusterer::KMeans { k: 3, restarts: 4 });
            ih.seed_order = so;
            ih.prototype = pk;
            ih.seed = seed;
            let r = ih.run(&ds.points)?;
            let secs = t0.elapsed().as_secs_f64();
            let acc = match truth {
                Some(tr) => metrics::prediction_accuracy(tr, &r.assignments)?,
                None => 0.0,
            };
            t.push_row(vec![
                so_name.into(),
                pk_name.into(),
                fmt_secs(secs),
                fmt4(acc),
                r.num_prototypes().to_string(),
            ]);
        }
    }
    Ok(vec![t])
}

/// ITIS-only reduction profile (Figure 1's quantitative counterpart):
/// prototype counts and reduction factor per iteration.
pub fn itis_profile(n: usize, threshold: usize, seed: u64) -> Result<Table> {
    let ds = gaussian_mixture_paper(n, seed);
    let mut t = Table::new(
        format!("ITIS reduction profile (n={n}, t*={threshold})"),
        &["m", "prototypes", "reduction", "seconds"],
    );
    for m in 1..=8 {
        let t0 = Instant::now();
        let r = itis(&ds.points, &ItisConfig::iterations(threshold, m))?;
        let secs = t0.elapsed().as_secs_f64();
        t.push_row(vec![
            m.to_string(),
            r.prototypes.rows().to_string(),
            format!("{:.1}", r.reduction_factor()),
            fmt_secs(secs),
        ]);
        if r.prototypes.rows() < threshold * 4 {
            break;
        }
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_smoke_has_expected_shape() {
        let tables = table1(Scale::Smoke, 3).unwrap();
        assert_eq!(tables.len(), 4);
        let time = &tables[0];
        assert_eq!(time.headers.len(), 2); // m + one size
        assert!(time.rows.len() >= 3); // m = 0, 1, 2 at least
        // Accuracy at m=1 should be close to m=0 (the paper's headline).
        let acc = &tables[2];
        let a0: f64 = acc.rows[0][1].parse().unwrap();
        let a1: f64 = acc.rows[1][1].parse().unwrap();
        assert!(a0 > 0.85 && (a0 - a1).abs() < 0.06, "a0={a0} a1={a1}");
    }

    #[test]
    fn table2_smoke_hac_frontier() {
        let tables = table2(Scale::Smoke, 4).unwrap();
        let time = &tables[0];
        // n=2000 > smoke cap 700 → m=0 infeasible ("-"), feasible later.
        assert_eq!(time.rows[0][1], "-");
        assert!(time.rows.iter().any(|r| r[1] != "-"), "{:?}", time.rows);
    }

    #[test]
    fn table3_static() {
        let tables = table3().unwrap();
        assert_eq!(tables[0].rows.len(), 6);
    }

    #[test]
    fn table9_smoke_runs() {
        let tables = table9(Scale::Smoke, 5).unwrap();
        assert!(tables[0].rows.len() >= 6); // 2 datasets × m=0..2
    }

    #[test]
    fn tstar_sweep_smoke() {
        let tables = table7(Scale::Smoke, 6).unwrap();
        let time = &tables[0];
        assert_eq!(time.rows[0][0], "None");
        assert!(time.rows.len() >= 3);
    }

    #[test]
    fn itis_profile_reduces_geometrically() {
        let t = itis_profile(4000, 2, 7).unwrap();
        let p1: usize = t.rows[0][1].parse().unwrap();
        let p2: usize = t.rows[1][1].parse().unwrap();
        assert!(p1 <= 2000 && p2 <= p1 / 2 + 1, "p1={p1} p2={p2}");
    }

    #[test]
    fn dispatch_known_ids() {
        for exp in crate::sim::EXPERIMENTS {
            if matches!(exp.id, "table1" | "table3") {
                assert!(crate::sim::run_experiment(exp.id, Scale::Smoke, 1).is_ok(), "{}", exp.id);
            }
        }
        assert!(crate::sim::run_experiment("nope", Scale::Smoke, 1).is_err());
    }
}
