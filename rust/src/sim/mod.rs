//! Repro harness: regenerate every table and figure of the paper.
//!
//! Each `table*` function sweeps the same axes the paper does and returns
//! [`crate::report::Table`]s whose rows mirror the published ones
//! (runtime seconds, memory MB, accuracy / BSS÷TSS, prototype counts).
//! The figures are line plots over these exact series, so the CSV output
//! of each table doubles as the figure data (see EXPERIMENTS.md).

use crate::report::Table;

/// Experiment registry entry.
pub struct Experiment {
    /// Identifier accepted by `ihtc repro --exp`.
    pub id: &'static str,
    /// What it reproduces.
    pub description: &'static str,
}

/// All reproducible experiments.
pub const EXPERIMENTS: &[Experiment] = &[
    Experiment { id: "table1", description: "IHTC + k-means on the §4 GMM: time/memory/accuracy vs m (Figs 3-4)" },
    Experiment { id: "table2", description: "IHTC + HAC on the §4 GMM: time/memory/accuracy vs m (Figs 5-6)" },
    Experiment { id: "table3", description: "dataset roster (analogue shapes)" },
    Experiment { id: "table4", description: "IHTC + k-means on the six dataset analogues (Fig 7)" },
    Experiment { id: "table5", description: "IHTC + HAC on the dataset analogues, small m (Fig 8)" },
    Experiment { id: "table6", description: "IHTC + HAC on the large analogues, large m (Fig 8)" },
    Experiment { id: "table7", description: "t* sweep with k-means, m=1 (Figs 9, 11)" },
    Experiment { id: "table8", description: "t* sweep with HAC, m=1 (Figs 10, 11)" },
    Experiment { id: "table9", description: "IHTC + DBSCAN on four analogues (Appendix B)" },
];

mod runners;
pub use runners::*;

use crate::report::svg::{chart_from_long, AxisScale, Chart};

/// Build the paper's figures from an experiment's long-format table
/// (the last table emitted by the sweep runners). Returns
/// `(file_stem, chart)` pairs; empty for experiments without figures.
pub fn figures(id: &str, tables: &[Table]) -> Vec<(String, Chart)> {
    // Sweep runners emit [time, memory, accuracy, long]; the long table
    // has columns [n, m|tstar, seconds, mem_mb, accuracy, prototypes].
    let long = match tables.last() {
        Some(t) if t.headers.len() == 6 => t,
        _ => return vec![],
    };
    let (xname, fig_time, fig_acc) = match id {
        "table1" => ("iterations m", "fig3", "fig4"),
        "table2" => ("iterations m", "fig5", "fig6"),
        "table7" => ("threshold t*", "fig9", "fig11_kmeans"),
        "table8" => ("threshold t*", "fig10", "fig11_hac"),
        _ => return vec![],
    };
    let mut out = Vec::new();
    let mk = |title: &str, y: usize, ylab: &str, scale: AxisScale| {
        chart_from_long(title, long, 0, 1, y, xname, ylab, scale)
    };
    out.push((
        format!("{fig_time}_time"),
        mk(&format!("{id}: run time"), 2, "seconds", AxisScale::Log10),
    ));
    out.push((
        format!("{fig_time}_memory"),
        mk(&format!("{id}: peak memory"), 3, "MB", AxisScale::Log10),
    ));
    out.push((
        format!("{fig_acc}_accuracy"),
        mk(&format!("{id}: prediction accuracy"), 4, "accuracy", AxisScale::Linear),
    ));
    out
}

/// Dispatch an experiment id to its runner.
pub fn run_experiment(id: &str, scale: Scale, seed: u64) -> crate::Result<Vec<Table>> {
    match id {
        "table1" => table1(scale, seed),
        "table2" => table2(scale, seed),
        "table3" => table3(),
        "table4" => table4(scale, seed),
        "table5" => table5(scale, seed),
        "table6" => table6(scale, seed),
        "table7" => table7(scale, seed),
        "table8" => table8(scale, seed),
        "table9" => table9(scale, seed),
        other => Err(crate::Error::InvalidArgument(format!(
            "unknown experiment '{other}'; known: {}",
            EXPERIMENTS.iter().map(|e| e.id).collect::<Vec<_>>().join(", ")
        ))),
    }
}
