//! Clustering evaluation metrics used by the paper.
//!
//! * [`prediction_accuracy`] — §4: fraction of units whose cluster, after
//!   the optimal cluster↔class matching (Hungarian algorithm on the
//!   contingency table), equals their true class.
//! * [`bss_tss`] — §5: between-cluster sum of squares over total sum of
//!   squares; larger is better.
//! * [`bottleneck`] — §2.3: maximum within-cluster dissimilarity, the
//!   objective TC 4-approximates.
//! * [`min_cluster_size`] — the `(t*)^m` guarantee of IHTC.

pub mod external;

pub use external::{adjusted_rand_index, normalized_mutual_info, silhouette};

use crate::linalg::{sq_dist, Matrix};
use crate::{Error, Result};

/// Compact arbitrary labels (including sentinels like
/// [`crate::cluster::NOISE`]) to dense `0..k` ids, preserving first-seen
/// order. Returns `(compact_labels, k)`.
pub fn compact_labels(assign: &[u32]) -> (Vec<u32>, usize) {
    // Keyed entry-lookup only, never iterated: ids are assigned in
    // first-seen input order, so the output cannot depend on hash order.
    // det-lint: allow(hash-iter)
    let mut remap = std::collections::HashMap::new();
    let mut out = Vec::with_capacity(assign.len());
    for &a in assign {
        let next = remap.len() as u32;
        let id = *remap.entry(a).or_insert(next);
        out.push(id);
    }
    (out, remap.len())
}

/// Count distinct clusters in an assignment vector.
pub fn num_clusters(assign: &[u32]) -> usize {
    compact_labels(assign).1
}

/// Sizes of each cluster (after label compaction; order = first seen).
pub fn cluster_sizes(assign: &[u32]) -> Vec<usize> {
    let (compact, k) = compact_labels(assign);
    let mut sizes = vec![0usize; k];
    for &a in &compact {
        sizes[a as usize] += 1;
    }
    sizes
}

/// Smallest cluster size (0 for empty assignment).
pub fn min_cluster_size(assign: &[u32]) -> usize {
    cluster_sizes(assign).into_iter().min().unwrap_or(0)
}

/// Prediction accuracy under the best one-to-one matching of predicted
/// clusters to true classes (Hungarian algorithm, maximizing agreement).
///
/// When the number of predicted clusters differs from the number of
/// classes the contingency table is padded with zeros, so surplus
/// clusters simply contribute no matched units.
pub fn prediction_accuracy(truth: &[u32], pred: &[u32]) -> Result<f64> {
    if truth.len() != pred.len() {
        return Err(Error::Shape(format!("{} truths vs {} preds", truth.len(), pred.len())));
    }
    if truth.is_empty() {
        return Ok(0.0);
    }
    let (truth, kt) = compact_labels(truth);
    let (pred, kp) = compact_labels(pred);
    let k = kt.max(kp);
    // Contingency counts[pred][truth].
    let mut counts = vec![vec![0i64; k]; k];
    for (&t, &p) in truth.iter().zip(&pred) {
        counts[p as usize][t as usize] += 1;
    }
    // Hungarian wants costs; maximize agreement = minimize (max - count).
    let maxc = counts.iter().flatten().copied().max().unwrap_or(0);
    let cost: Vec<Vec<i64>> = counts
        .iter()
        .map(|row| row.iter().map(|&c| maxc - c).collect())
        .collect();
    let matching = hungarian(&cost);
    let matched: i64 = matching.iter().enumerate().map(|(p, &t)| counts[p][t]).sum();
    Ok(matched as f64 / truth.len() as f64)
}

/// Hungarian (Kuhn–Munkres) algorithm for the square assignment problem,
/// O(k³), minimizing total cost. Returns `row → column`.
///
/// This is the classic potentials-based JV formulation; `k` here is the
/// number of clusters (≤ a few dozen), so cubic cost is negligible.
pub fn hungarian(cost: &[Vec<i64>]) -> Vec<usize> {
    let n = cost.len();
    if n == 0 {
        return vec![];
    }
    // Potentials u (rows), v (cols); way[j] = previous column on the
    // augmenting path; matches p[j] = row matched to column j.
    // 1-indexed internally per the standard e-maxx formulation.
    let inf = i64::MAX / 4;
    let mut u = vec![0i64; n + 1];
    let mut v = vec![0i64; n + 1];
    let mut p = vec![0usize; n + 1];
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![inf; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = inf;
            let mut j1 = 0usize;
            for j in 1..=n {
                if !used[j] {
                    let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut row_to_col = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            row_to_col[p[j] - 1] = j - 1;
        }
    }
    row_to_col
}

/// `BSS/TSS`: ratio of the between-cluster sum of squares to the total
/// sum of squares (both about the grand centroid). In `[0, 1]`; larger
/// means more compact clusters (paper §5).
pub fn bss_tss(points: &Matrix, assign: &[u32]) -> Result<f64> {
    if points.rows() != assign.len() {
        return Err(Error::Shape(format!(
            "{} points vs {} assignments",
            points.rows(),
            assign.len()
        )));
    }
    let (n, d) = (points.rows(), points.cols());
    if n == 0 {
        return Ok(0.0);
    }
    let (assign, k) = compact_labels(assign);
    let grand = points.col_means();
    let mut centroids = vec![vec![0.0f64; d]; k];
    let mut counts = vec![0usize; k];
    for i in 0..n {
        let c = assign[i] as usize;
        counts[c] += 1;
        for (acc, &x) in centroids[c].iter_mut().zip(points.row(i)) {
            *acc += x as f64;
        }
    }
    for (c, cnt) in centroids.iter_mut().zip(&counts) {
        if *cnt > 0 {
            for v in c.iter_mut() {
                *v /= *cnt as f64;
            }
        }
    }
    let mut tss = 0.0f64;
    for i in 0..n {
        for (j, &x) in points.row(i).iter().enumerate() {
            let dlt = x as f64 - grand[j];
            tss += dlt * dlt;
        }
    }
    let mut bss = 0.0f64;
    for (c, cnt) in centroids.iter().zip(&counts) {
        if *cnt == 0 {
            continue;
        }
        let mut s = 0.0;
        for (j, &g) in grand.iter().enumerate() {
            let dlt = c[j] - g;
            s += dlt * dlt;
        }
        bss += s * *cnt as f64;
    }
    if tss <= 0.0 {
        return Ok(0.0);
    }
    Ok(bss / tss)
}

/// Within-cluster sum of squares (the k-means objective).
pub fn wcss(points: &Matrix, assign: &[u32]) -> Result<f64> {
    let ratio = bss_tss(points, assign)?;
    // TSS = BSS + WCSS; recompute TSS once.
    let grand = points.col_means();
    let mut tss = 0.0f64;
    for i in 0..points.rows() {
        for (j, &x) in points.row(i).iter().enumerate() {
            let d = x as f64 - grand[j];
            tss += d * d;
        }
    }
    Ok(tss * (1.0 - ratio))
}

/// Maximum within-cluster (Euclidean) dissimilarity — the bottleneck
/// objective of BTPP (eq. 2). Exact `O(Σ|V|²)`; intended for validation
/// on small-to-medium clusterings, with `sample_cap` bounding the per-
/// cluster pair scan for big ones (pass `usize::MAX` for exact).
pub fn bottleneck(points: &Matrix, assign: &[u32], sample_cap: usize) -> Result<f64> {
    if points.rows() != assign.len() {
        return Err(Error::Shape("points vs assignments".into()));
    }
    let (assign, k) = compact_labels(assign);
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (i, &a) in assign.iter().enumerate() {
        members[a as usize].push(i as u32);
    }
    let mut worst = 0.0f64;
    for m in &members {
        let take = m.len().min(sample_cap);
        for a in 0..take {
            for b in (a + 1)..take {
                let d = sq_dist(points.row(m[a] as usize), points.row(m[b] as usize));
                worst = worst.max(d as f64);
            }
        }
    }
    Ok(worst.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hungarian_identity() {
        let cost = vec![vec![0, 9, 9], vec![9, 0, 9], vec![9, 9, 0]];
        assert_eq!(hungarian(&cost), vec![0, 1, 2]);
    }

    #[test]
    fn hungarian_permuted() {
        let cost = vec![vec![9, 0, 9], vec![9, 9, 0], vec![0, 9, 9]];
        assert_eq!(hungarian(&cost), vec![1, 2, 0]);
    }

    #[test]
    fn hungarian_nontrivial() {
        // Classic example: optimal total = 5 (r0→c1=1, r1→c0=2, r2→c2=2).
        let cost = vec![vec![4, 1, 3], vec![2, 0, 5], vec![3, 2, 2]];
        let m = hungarian(&cost);
        let total: i64 = m.iter().enumerate().map(|(r, &c)| cost[r][c]).sum();
        assert_eq!(total, 5);
    }

    #[test]
    fn accuracy_perfect_and_relabelled() {
        let truth = vec![0, 0, 1, 1, 2, 2];
        assert_eq!(prediction_accuracy(&truth, &truth).unwrap(), 1.0);
        // Same partition, different labels → still perfect.
        let relab = vec![2, 2, 0, 0, 1, 1];
        assert_eq!(prediction_accuracy(&truth, &relab).unwrap(), 1.0);
    }

    #[test]
    fn accuracy_partial() {
        let truth = vec![0, 0, 0, 1, 1, 1];
        let pred = vec![0, 0, 1, 1, 1, 1];
        // Best matching: 0→0, 1→1 gives 5/6 correct.
        assert!((prediction_accuracy(&truth, &pred).unwrap() - 5.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn accuracy_more_clusters_than_classes() {
        let truth = vec![0, 0, 0, 0];
        let pred = vec![0, 1, 2, 3];
        // Only one cluster can match class 0 → 1/4.
        assert!((prediction_accuracy(&truth, &pred).unwrap() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn accuracy_length_mismatch() {
        assert!(prediction_accuracy(&[0, 1], &[0]).is_err());
    }

    #[test]
    fn bss_tss_extremes() {
        // Two tight, far-apart clusters → ratio near 1.
        let m = Matrix::from_vec(
            vec![0.0, 0.0, 0.1, 0.0, 100.0, 0.0, 100.1, 0.0],
            4,
            2,
        )
        .unwrap();
        let good = bss_tss(&m, &[0, 0, 1, 1]).unwrap();
        assert!(good > 0.999, "{good}");
        // Clusters that cut across → much lower.
        let bad = bss_tss(&m, &[0, 1, 0, 1]).unwrap();
        assert!(bad < 0.001, "{bad}");
    }

    #[test]
    fn bss_plus_wcss_is_tss() {
        let m = Matrix::from_vec(vec![1.0, 2.0, 4.0, 0.0, -1.0, 3.0, 2.0, 2.0], 4, 2).unwrap();
        let assign = vec![0, 1, 0, 1];
        let ratio = bss_tss(&m, &assign).unwrap();
        let w = wcss(&m, &assign).unwrap();
        let grand = m.col_means();
        let mut tss = 0.0;
        for i in 0..4 {
            for j in 0..2 {
                let d = m.get(i, j) as f64 - grand[j];
                tss += d * d;
            }
        }
        assert!((ratio * tss + w - tss).abs() < 1e-9);
    }

    #[test]
    fn bottleneck_known() {
        let m = Matrix::from_vec(vec![0.0, 1.0, 3.0, 10.0], 4, 1).unwrap();
        // Clusters {0,1,3} and {10}: max within = 3.
        let b = bottleneck(&m, &[0, 0, 0, 1], usize::MAX).unwrap();
        assert!((b - 3.0).abs() < 1e-6);
    }

    #[test]
    fn sizes_and_min() {
        let assign = vec![0, 0, 1, 2, 2, 2];
        assert_eq!(cluster_sizes(&assign), vec![2, 1, 3]);
        assert_eq!(min_cluster_size(&assign), 1);
        assert_eq!(num_clusters(&assign), 3);
    }
}
