//! External and internal cluster-validity indices beyond the paper's
//! accuracy/BSS÷TSS pair: adjusted Rand index, normalized mutual
//! information, and (sampled) silhouette. Used by the extended
//! evaluation in `ihtc repro` CSVs and the property-test suite.

use super::compact_labels;
use crate::linalg::{dist, Matrix};
use crate::rng::Xoshiro256;
use crate::{Error, Result};

fn contingency(a: &[u32], b: &[u32]) -> (Vec<Vec<f64>>, Vec<f64>, Vec<f64>, f64) {
    let (a, ka) = compact_labels(a);
    let (b, kb) = compact_labels(b);
    let mut table = vec![vec![0.0f64; kb]; ka];
    for (&x, &y) in a.iter().zip(&b) {
        table[x as usize][y as usize] += 1.0;
    }
    let rows: Vec<f64> = table.iter().map(|r| r.iter().sum()).collect();
    let cols: Vec<f64> = (0..kb).map(|j| table.iter().map(|r| r[j]).sum()).collect();
    let n = a.len() as f64;
    (table, rows, cols, n)
}

fn choose2(x: f64) -> f64 {
    x * (x - 1.0) / 2.0
}

/// Adjusted Rand index between two labelings (1 = identical partitions,
/// ≈ 0 = chance agreement).
pub fn adjusted_rand_index(a: &[u32], b: &[u32]) -> Result<f64> {
    if a.len() != b.len() {
        return Err(Error::Shape("label vectors differ in length".into()));
    }
    if a.is_empty() {
        return Ok(0.0);
    }
    let (table, rows, cols, n) = contingency(a, b);
    let sum_cells: f64 = table.iter().flatten().map(|&c| choose2(c)).sum();
    let sum_rows: f64 = rows.iter().map(|&r| choose2(r)).sum();
    let sum_cols: f64 = cols.iter().map(|&c| choose2(c)).sum();
    let total = choose2(n);
    if total == 0.0 {
        return Ok(0.0);
    }
    let expected = sum_rows * sum_cols / total;
    let max = 0.5 * (sum_rows + sum_cols);
    if (max - expected).abs() < 1e-12 {
        return Ok(if (sum_cells - expected).abs() < 1e-12 { 1.0 } else { 0.0 });
    }
    Ok((sum_cells - expected) / (max - expected))
}

/// Normalized mutual information (arithmetic normalization), in [0, 1].
pub fn normalized_mutual_info(a: &[u32], b: &[u32]) -> Result<f64> {
    if a.len() != b.len() {
        return Err(Error::Shape("label vectors differ in length".into()));
    }
    if a.is_empty() {
        return Ok(0.0);
    }
    let (table, rows, cols, n) = contingency(a, b);
    let mut mi = 0.0f64;
    for (i, row) in table.iter().enumerate() {
        for (j, &c) in row.iter().enumerate() {
            if c > 0.0 {
                mi += (c / n) * ((c * n) / (rows[i] * cols[j])).ln();
            }
        }
    }
    let h = |margin: &[f64]| -> f64 {
        margin
            .iter()
            .filter(|&&m| m > 0.0)
            .map(|&m| -(m / n) * (m / n).ln())
            .sum()
    };
    let (ha, hb) = (h(&rows), h(&cols));
    if ha <= 0.0 && hb <= 0.0 {
        return Ok(1.0); // both partitions trivial and identical
    }
    let denom = 0.5 * (ha + hb);
    Ok(if denom > 0.0 { (mi / denom).clamp(0.0, 1.0) } else { 0.0 })
}

/// Mean silhouette coefficient, computed exactly when `n ≤ sample` and
/// on a seeded subsample otherwise (exact silhouette is O(n²)).
pub fn silhouette(points: &Matrix, labels: &[u32], sample: usize, seed: u64) -> Result<f64> {
    if points.rows() != labels.len() {
        return Err(Error::Shape("points vs labels".into()));
    }
    let (labels, k) = compact_labels(labels);
    if k < 2 {
        return Ok(0.0);
    }
    let n = points.rows();
    let idx: Vec<usize> = if n > sample {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        rng.sample_indices(n, sample)
    } else {
        (0..n).collect()
    };
    // Cluster membership restricted to the sample (distances are
    // computed within the sample — the standard subsampled estimator).
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
    for &i in &idx {
        members[labels[i] as usize].push(i);
    }
    let mut total = 0.0f64;
    let mut counted = 0usize;
    for &i in &idx {
        let own = labels[i] as usize;
        if members[own].len() < 2 {
            continue; // silhouette undefined for singletons
        }
        let mut a = 0.0f64;
        for &j in &members[own] {
            if j != i {
                a += dist(points.row(i), points.row(j)) as f64;
            }
        }
        a /= (members[own].len() - 1) as f64;
        let mut b = f64::INFINITY;
        for (c, group) in members.iter().enumerate() {
            if c == own || group.is_empty() {
                continue;
            }
            let mut m = 0.0f64;
            for &j in group {
                m += dist(points.row(i), points.row(j)) as f64;
            }
            b = b.min(m / group.len() as f64);
        }
        if b.is_finite() {
            total += (b - a) / a.max(b);
            counted += 1;
        }
    }
    Ok(if counted > 0 { total / counted as f64 } else { 0.0 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;

    #[test]
    fn ari_identical_and_permuted() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((adjusted_rand_index(&a, &a).unwrap() - 1.0).abs() < 1e-12);
        let p = vec![5, 5, 9, 9, 1, 1]; // same partition, odd labels
        assert!((adjusted_rand_index(&a, &p).unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ari_near_zero_for_random() {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(9);
        let a: Vec<u32> = (0..4000).map(|_| rng.next_below(4) as u32).collect();
        let b: Vec<u32> = (0..4000).map(|_| rng.next_below(4) as u32).collect();
        let ari = adjusted_rand_index(&a, &b).unwrap();
        assert!(ari.abs() < 0.02, "{ari}");
    }

    #[test]
    fn ari_disagreement_below_one() {
        let a = vec![0, 0, 0, 1, 1, 1];
        let b = vec![0, 0, 1, 1, 1, 1];
        let ari = adjusted_rand_index(&a, &b).unwrap();
        assert!(ari > 0.0 && ari < 1.0, "{ari}");
    }

    #[test]
    fn nmi_bounds_and_extremes() {
        let a = vec![0, 0, 1, 1];
        assert!((normalized_mutual_info(&a, &a).unwrap() - 1.0).abs() < 1e-12);
        let indep = vec![0, 1, 0, 1];
        let nmi = normalized_mutual_info(&a, &indep).unwrap();
        assert!(nmi < 0.01, "{nmi}");
    }

    #[test]
    fn nmi_invariant_to_relabeling() {
        let a = vec![0, 0, 1, 2, 2, 1];
        let b = vec![7, 7, 3, 0, 0, 3];
        assert!((normalized_mutual_info(&a, &b).unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn silhouette_separated_vs_mixed() {
        let ds = gaussian_mixture_paper(1_000, 10);
        let truth = ds.labels.clone().unwrap();
        let good = silhouette(&ds.points, &truth, 500, 1).unwrap();
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(2);
        let random: Vec<u32> = (0..1_000).map(|_| rng.next_below(3) as u32).collect();
        let bad = silhouette(&ds.points, &random, 500, 1).unwrap();
        assert!(good > bad + 0.2, "good={good} bad={bad}");
        assert!((-1.0..=1.0).contains(&good));
    }

    #[test]
    fn silhouette_single_cluster_zero() {
        let ds = gaussian_mixture_paper(100, 11);
        let labels = vec![0u32; 100];
        assert_eq!(silhouette(&ds.points, &labels, 100, 1).unwrap(), 0.0);
    }

    #[test]
    fn length_mismatches_rejected() {
        assert!(adjusted_rand_index(&[0], &[0, 1]).is_err());
        assert!(normalized_mutual_info(&[0], &[0, 1]).is_err());
        let m = Matrix::zeros(3, 2);
        assert!(silhouette(&m, &[0, 1], 10, 1).is_err());
    }
}
