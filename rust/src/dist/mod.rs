//! Distributed IHTC: lease executor batches to remote worker processes.
//!
//! The executor's contract — batches keyed by submission index, results
//! order-independent, output bytes scheduling-invariant (PRs 5/7/8) —
//! is exactly the unit a multi-process scheduler needs. This module
//! adds the scheduler: a coordinator-side [`DistPool`] listens on a
//! socket, worker processes ([`serve`]) connect and **lease** whole
//! self-contained work units, execute them on their own local
//! [`Executor`], and return results keyed by the coordinator's
//! submission index. Two unit kinds exist, both chosen because their
//! output is provably location-independent:
//!
//! * **ReduceShard** — one streaming level-0 reduction (rows in →
//!   prototypes + weights + assignments + [`Moments`] out). The shard
//!   reduction is worker-count invariant and the moments fold the same
//!   f32 rows in the same order, so the result bytes match the
//!   in-process stage exactly.
//! * **ForestKnn** — one kd-forest shard build + all-rows query block.
//!   The forest parity contract (byte-identical to `knn_brute` for any
//!   shards × workers combination) makes the answer independent of
//!   where it was computed.
//!
//! **Wire format.** The protocol reuses the checkpoint module's framing
//! discipline: a 12-byte handshake (`IHTCDST1` magic + u32 LE version)
//! sent by the worker and echoed by the coordinator, then a sequence of
//! frames, each `payload_len: u64 LE` + payload + `crc32(payload): u32
//! LE` ([`crate::checkpoint::write_frame_to`]). Unlike the checkpoint
//! *file* reader — where a torn tail is a recoverable crash artifact —
//! a torn or CRC-bad frame on a socket means a dead or corrupting peer
//! and is a **hard error** ([`crate::checkpoint::read_frame_from`]):
//! the connection is dropped and the lease is handled by the re-lease
//! protocol below. All integers and floats are little-endian; f32/f64
//! round-trip bit-exactly, which is what makes cross-process byte
//! parity possible at all.
//!
//! **Lease / re-lease semantics.** Each connected worker runs one lease
//! at a time: the coordinator sends a unit frame, the worker replies
//! with a result frame echoing the unit id. If the worker disconnects,
//! times out (`lease_timeout` of socket silence), or sends a torn or
//! mismatched frame, the coordinator declares it dead and **re-queues**
//! the unit for the remaining workers; when no workers remain, the unit
//! — and everything still pending — is *abandoned*, which tells the
//! submitting caller to run it in-process instead. A unit submitted
//! while no worker is connected is abandoned immediately. Every lease
//! therefore terminates in `Done` or `Abandoned`: a lost worker
//! degrades the run to local execution, it never hangs it.
//!
//! **Determinism contract.** Because every unit's result is
//! byte-identical whether computed locally or remotely, and the
//! coordinator merges results purely by submission index / stream
//! offset (the same keys the in-process paths use), the run's output
//! bytes are identical whether its batches ran in-process, on one
//! loopback worker, or on N remote workers — including runs where
//! workers died mid-lease and units fell back. `rust/tests/
//! dist_parity.rs` pins this grid.
//!
//! Concurrency notes: the pool's lease table lives under one
//! `std::sync::Mutex` with two condvars (`work_cv` wakes worker I/O
//! threads, `done_cv` wakes submitters). Like the pipeline's mpsc
//! endpoints, this layer is I/O plumbing that loom never executes — the
//! loom scenarios model the *executor* the units run on — so it uses
//! std primitives directly rather than the `crate::sync` facade (which
//! would be a lie of modeledness, not a verification). Timeouts are
//! expressed purely through socket read timeouts and bounded sleeps;
//! the protocol needs no clock reads.

use crate::checkpoint::{read_frame_from, write_frame_to, Cursor};
use crate::coordinator::driver::Moments;
use crate::coordinator::PoolKnnProvider;
use crate::exec::{Completion, Executor};
use crate::itis::{ItisConfig, KnnProvider, ShardReducer, ShardReduction};
use crate::knn::{forest::KdForest, KnnLists};
use crate::linalg::Matrix;
use crate::tc::SeedOrder;
use crate::{Error, Result};
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Handshake magic: "IHTC distributed protocol, format 1".
const DIST_MAGIC: [u8; 8] = *b"IHTCDST1";
/// Protocol version, echoed in the handshake; a mismatch drops the
/// connection before any lease is attempted.
const DIST_VERSION: u32 = 1;
/// Poll cadence for the nonblocking accept loop and worker waits.
const POLL_STEP: Duration = Duration::from_millis(5);
/// Default lease timeout when the config leaves it unset: seconds of
/// socket silence after which a leased worker is declared dead.
pub const DEFAULT_LEASE_TIMEOUT_SECS: f64 = 30.0;

const KIND_REDUCE: u8 = 0;
const KIND_FOREST: u8 = 1;
const STATUS_OK: u8 = 0;
const STATUS_ERR: u8 = 1;

fn seed_order_code(s: SeedOrder) -> u8 {
    match s {
        SeedOrder::Natural => 0,
        SeedOrder::DegreeAscending => 1,
        SeedOrder::DegreeDescending => 2,
    }
}

fn seed_order_from_code(c: u8) -> Result<SeedOrder> {
    match c {
        0 => Ok(SeedOrder::Natural),
        1 => Ok(SeedOrder::DegreeAscending),
        2 => Ok(SeedOrder::DegreeDescending),
        _ => Err(Error::Data(format!("dist: unknown seed-order code {c}"))),
    }
}

// ---------------------------------------------------------------------
// Wire codec

/// Checked little-endian reader over one wire payload: every read
/// verifies the remaining length first, so malformed bytes off a socket
/// become [`Error::Data`] instead of a panic. (The checkpoint codec's
/// [`Cursor`] may index unchecked because `decode_frame` pre-validates
/// the exact total length; wire payloads have variable structure, so
/// the check moves into each read.)
struct Wire<'a> {
    c: Cursor<'a>,
}

impl<'a> Wire<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { c: Cursor { buf, pos: 0 } }
    }

    fn remaining(&self) -> usize {
        self.c.buf.len() - self.c.pos
    }

    fn need(&self, n: usize) -> Result<()> {
        if self.remaining() < n {
            return Err(Error::Data(format!(
                "dist frame: payload truncated (need {n} more bytes, have {})",
                self.remaining()
            )));
        }
        Ok(())
    }

    fn u8(&mut self) -> Result<u8> {
        self.need(1)?;
        Ok(self.c.u8())
    }

    fn u32(&mut self) -> Result<u32> {
        self.need(4)?;
        Ok(self.c.u32())
    }

    fn u64(&mut self) -> Result<u64> {
        self.need(8)?;
        Ok(self.c.u64())
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        self.need(4 * n)?;
        Ok((0..n).map(|_| self.c.f32()).collect())
    }

    fn f64_vec(&mut self, n: usize) -> Result<Vec<f64>> {
        self.need(8 * n)?;
        Ok((0..n).map(|_| self.c.f64()).collect())
    }

    fn u32_vec(&mut self, n: usize) -> Result<Vec<u32>> {
        self.need(4 * n)?;
        Ok((0..n).map(|_| self.c.u32()).collect())
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        self.need(n)?;
        Ok(self.c.take(n))
    }

    fn finish(self, what: &str) -> Result<()> {
        if self.remaining() != 0 {
            return Err(Error::Data(format!(
                "dist frame: {} trailing bytes after {what}",
                self.remaining()
            )));
        }
        Ok(())
    }
}

/// A work unit to lease, borrowing the submitter's buffers (the encode
/// copies them onto the wire; nothing is cloned in RAM first).
pub enum WorkSpec<'a> {
    /// One streaming level-0 shard reduction (see
    /// [`crate::itis::reduce_shard`]).
    ReduceShard {
        /// Stream row offset (tracing only; the result is keyed by the
        /// lease's unit id, not by this).
        offset: u64,
        /// The shard rows.
        points: &'a Matrix,
        /// TC size threshold `t*`.
        threshold: usize,
        /// TC seed order.
        seed_order: SeedOrder,
        /// kd-forest shards for the per-shard k-NN step.
        knn_shards: usize,
    },
    /// One kd-forest shard build + all-rows k-NN query block.
    ForestKnn {
        /// The indexed/query rows.
        points: &'a Matrix,
        /// Neighbors per row.
        k: usize,
        /// Forest shard count.
        shards: usize,
    },
}

/// A decoded work unit, owned by the worker that leased it.
pub enum WorkUnit {
    /// See [`WorkSpec::ReduceShard`].
    ReduceShard {
        /// Stream row offset (tracing only).
        offset: u64,
        /// The shard rows.
        points: Matrix,
        /// TC size threshold `t*`.
        threshold: usize,
        /// TC seed order.
        seed_order: SeedOrder,
        /// kd-forest shards for the per-shard k-NN step.
        knn_shards: usize,
    },
    /// See [`WorkSpec::ForestKnn`].
    ForestKnn {
        /// The indexed/query rows.
        points: Matrix,
        /// Neighbors per row.
        k: usize,
        /// Forest shard count.
        shards: usize,
    },
}

/// A decoded unit result — byte-identical to what the same unit
/// produces in-process (the whole point of the protocol).
pub enum UnitResult {
    /// [`WorkSpec::ReduceShard`] output.
    ReduceShard {
        /// The shard's reduction (prototypes, weights, assignments).
        reduction: ShardReduction,
        /// The shard's standardization moments.
        moments: Moments,
    },
    /// [`WorkSpec::ForestKnn`] output.
    ForestKnn {
        /// The k-NN lists for every row.
        lists: KnnLists,
    },
}

fn encode_spec(id: u64, spec: &WorkSpec<'_>) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&id.to_le_bytes());
    match spec {
        WorkSpec::ReduceShard { offset, points, threshold, seed_order, knn_shards } => {
            buf.reserve(30 + 4 * points.data().len());
            buf.push(KIND_REDUCE);
            buf.extend_from_slice(&offset.to_le_bytes());
            buf.extend_from_slice(&(*threshold as u64).to_le_bytes());
            buf.push(seed_order_code(*seed_order));
            buf.extend_from_slice(&(*knn_shards as u32).to_le_bytes());
            push_matrix(&mut buf, points);
        }
        WorkSpec::ForestKnn { points, k, shards } => {
            buf.reserve(17 + 4 * points.data().len());
            buf.push(KIND_FOREST);
            buf.extend_from_slice(&(*k as u32).to_le_bytes());
            buf.extend_from_slice(&(*shards as u32).to_le_bytes());
            push_matrix(&mut buf, points);
        }
    }
    buf
}

fn push_matrix(buf: &mut Vec<u8>, m: &Matrix) {
    buf.extend_from_slice(&(m.rows() as u32).to_le_bytes());
    buf.extend_from_slice(&(m.cols() as u32).to_le_bytes());
    for v in m.data() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn read_matrix(w: &mut Wire<'_>) -> Result<Matrix> {
    let rows = w.u32()? as usize;
    let cols = w.u32()? as usize;
    let data = w.f32_vec(rows.checked_mul(cols).ok_or_else(|| {
        Error::Data("dist frame: matrix shape overflows".into())
    })?)?;
    Matrix::from_vec(data, rows, cols)
}

/// Decode a lease frame into `(unit_id, unit)`.
pub fn decode_unit(payload: &[u8]) -> Result<(u64, WorkUnit)> {
    let mut w = Wire::new(payload);
    let id = w.u64()?;
    let kind = w.u8()?;
    let unit = match kind {
        KIND_REDUCE => {
            let offset = w.u64()?;
            let threshold = w.u64()? as usize;
            let seed_order = seed_order_from_code(w.u8()?)?;
            let knn_shards = w.u32()? as usize;
            let points = read_matrix(&mut w)?;
            WorkUnit::ReduceShard { offset, points, threshold, seed_order, knn_shards }
        }
        KIND_FOREST => {
            let k = w.u32()? as usize;
            let shards = w.u32()? as usize;
            let points = read_matrix(&mut w)?;
            WorkUnit::ForestKnn { points, k, shards }
        }
        other => return Err(Error::Data(format!("dist frame: unknown unit kind {other}"))),
    };
    w.finish("work unit")?;
    Ok((id, unit))
}

fn encode_result_ok(id: u64, res: &UnitResult) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&id.to_le_bytes());
    buf.push(STATUS_OK);
    match res {
        UnitResult::ReduceShard { reduction, moments } => {
            buf.push(KIND_REDUCE);
            push_matrix(&mut buf, &reduction.prototypes);
            for v in &reduction.weights {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            buf.extend_from_slice(&(reduction.assignments.len() as u32).to_le_bytes());
            for v in &reduction.assignments {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            buf.extend_from_slice(&(moments.count as u64).to_le_bytes());
            for v in &moments.sum {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            for v in &moments.cross {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        UnitResult::ForestKnn { lists } => {
            buf.push(KIND_FOREST);
            buf.extend_from_slice(&(lists.len() as u32).to_le_bytes());
            buf.extend_from_slice(&(lists.k as u32).to_le_bytes());
            for v in &lists.indices {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            for v in &lists.dists {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    buf
}

fn encode_result_err(id: u64, msg: &str) -> Vec<u8> {
    let bytes = msg.as_bytes();
    let mut buf = Vec::with_capacity(13 + bytes.len());
    buf.extend_from_slice(&id.to_le_bytes());
    buf.push(STATUS_ERR);
    buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(bytes);
    buf
}

/// Decode a result frame into `(unit_id, Ok(result) | Err(worker
/// message))`. The outer [`Result`] is a malformed frame (protocol
/// violation → the worker is declared dead); the inner one is a clean
/// worker-side execution failure (→ the unit falls back to local
/// execution, which reproduces the same deterministic outcome).
pub fn decode_result(payload: &[u8]) -> Result<(u64, std::result::Result<UnitResult, String>)> {
    let mut w = Wire::new(payload);
    let id = w.u64()?;
    let status = w.u8()?;
    if status == STATUS_ERR {
        let len = w.u32()? as usize;
        let msg = String::from_utf8_lossy(w.take(len)?).into_owned();
        w.finish("error result")?;
        return Ok((id, Err(msg)));
    }
    if status != STATUS_OK {
        return Err(Error::Data(format!("dist frame: unknown result status {status}")));
    }
    let kind = w.u8()?;
    let res = match kind {
        KIND_REDUCE => {
            let prototypes = read_matrix(&mut w)?;
            let weights = w.u32_vec(prototypes.rows())?;
            let rows = w.u32()? as usize;
            let assignments = w.u32_vec(rows)?;
            let d = prototypes.cols();
            let mut moments = Moments::new(d);
            moments.count = w.u64()? as usize;
            moments.sum = w.f64_vec(d)?;
            moments.cross = w.f64_vec(d * d)?;
            UnitResult::ReduceShard {
                reduction: ShardReduction { prototypes, weights, assignments },
                moments,
            }
        }
        KIND_FOREST => {
            let rows = w.u32()? as usize;
            let k = w.u32()? as usize;
            let n = rows.checked_mul(k).ok_or_else(|| {
                Error::Data("dist frame: knn shape overflows".into())
            })?;
            let indices = w.u32_vec(n)?;
            let dists = w.f32_vec(n)?;
            UnitResult::ForestKnn { lists: KnnLists { k, indices, dists } }
        }
        other => return Err(Error::Data(format!("dist frame: unknown result kind {other}"))),
    };
    w.finish("result")?;
    Ok((id, Ok(res)))
}

// ---------------------------------------------------------------------
// Unit execution (worker side — and the parity reference for tests)

/// Execute one decoded unit on `exec`. This is the *entire* semantic
/// payload of the protocol: the worker calls exactly the functions the
/// in-process paths call ([`ShardReducer::reduce`] with
/// [`ItisConfig::level0`]; [`crate::knn::knn_auto_sharded_into`]), so
/// the result bytes cannot diverge by construction.
pub fn execute_unit(unit: &WorkUnit, exec: &Arc<Executor>) -> Result<UnitResult> {
    match unit {
        WorkUnit::ReduceShard { points, threshold, seed_order, knn_shards, .. } => {
            let mut moments = Moments::new(points.cols());
            moments.fold(points);
            let mut reducer = ShardReducer::new(
                Arc::clone(exec),
                *knn_shards,
                ItisConfig::level0(*threshold, *seed_order),
            );
            let reduction = reducer.reduce(points)?;
            Ok(UnitResult::ReduceShard { reduction, moments })
        }
        WorkUnit::ForestKnn { points, k, shards } => {
            let mut forest = KdForest::new();
            let mut lists = KnnLists::default();
            crate::knn::knn_auto_sharded_into(points, *k, *shards, exec, &mut forest, &mut lists)?;
            Ok(UnitResult::ForestKnn { lists })
        }
    }
}

// ---------------------------------------------------------------------
// Worker process

/// Deterministic fault injection for the wire, mirroring
/// [`crate::checkpoint::FaultPlan`]: each field names one way a worker
/// can die, indexed by the worker's 0-based lease count. `Default`
/// injects nothing. Used by `rust/tests/dist_parity.rs` to pin the
/// re-lease protocol.
#[derive(Clone, Debug, Default)]
pub struct WireFaultPlan {
    /// Exit without replying after *receiving* this lease — the
    /// coordinator sees `lease_timeout` of silence or EOF mid-lease.
    pub kill_after_lease: Option<usize>,
    /// Reply to this lease with a deliberately torn frame (length
    /// prefix + half the payload), then exit — the coordinator's strict
    /// frame reader must turn it into a dead-worker event, never a
    /// partial result.
    pub torn_result_at_lease: Option<usize>,
    /// Exit cleanly after sending this many results — a connection
    /// dropped *between* frames.
    pub drop_after_results: Option<usize>,
}

impl WireFaultPlan {
    /// A plan that injects nothing (the normal production path).
    pub fn none() -> Self {
        Self::default()
    }
}

/// Run one worker process: connect to the coordinator at `addr`,
/// handshake, then lease units one at a time until the coordinator
/// closes the connection (clean EOF → `Ok`). `workers` sizes the local
/// executor (0 = the machine's available parallelism).
pub fn serve(addr: &str, workers: usize) -> Result<()> {
    serve_with_faults(addr, workers, &WireFaultPlan::none())
}

/// [`serve`] with deterministic fault injection (tests only — the
/// production entry point injects nothing).
pub fn serve_with_faults(addr: &str, workers: usize, faults: &WireFaultPlan) -> Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    let _ = stream.set_nodelay(true);
    let mut hs = [0u8; 12];
    hs[..8].copy_from_slice(&DIST_MAGIC);
    hs[8..].copy_from_slice(&DIST_VERSION.to_le_bytes());
    stream.write_all(&hs)?;
    let mut echo = [0u8; 12];
    stream.read_exact(&mut echo)?;
    if echo != hs {
        return Err(Error::Runtime(
            "dist worker: coordinator handshake mismatch (wrong endpoint or version?)".into(),
        ));
    }
    let exec = Arc::new(Executor::new(workers));
    let mut leases = 0usize;
    let mut results = 0usize;
    loop {
        let payload = match read_frame_from(&mut stream)? {
            Some(p) => p,
            None => return Ok(()), // coordinator closed cleanly
        };
        let idx = leases;
        leases += 1;
        if faults.kill_after_lease == Some(idx) {
            return Ok(()); // vanish mid-lease: unit received, never answered
        }
        let (id, unit) = decode_unit(&payload)?;
        let reply = match execute_unit(&unit, &exec) {
            Ok(res) => encode_result_ok(id, &res),
            Err(e) => encode_result_err(id, &e.to_string()),
        };
        if faults.torn_result_at_lease == Some(idx) {
            stream.write_all(&(reply.len() as u64).to_le_bytes())?;
            stream.write_all(&reply[..reply.len() / 2])?;
            let _ = stream.shutdown(Shutdown::Both);
            return Ok(());
        }
        write_frame_to(&mut stream, &reply)?;
        results += 1;
        if faults.drop_after_results == Some(results) {
            return Ok(()); // drop between frames
        }
    }
}

// ---------------------------------------------------------------------
// Coordinator pool

/// One submitted unit's place in the lease table.
enum UnitSlot {
    /// Awaiting a worker (payload retained for the lease).
    Pending(Arc<Vec<u8>>),
    /// On a worker's wire (payload retained so a dead worker's unit can
    /// be re-queued byte-identically).
    Leased(Arc<Vec<u8>>),
    /// Result frame received.
    Done(Vec<u8>),
    /// No worker will produce this unit — the submitter must run it
    /// in-process.
    Abandoned,
    /// Terminal: the submitter consumed the slot.
    Taken,
}

impl UnitSlot {
    fn terminal(&self) -> bool {
        matches!(self, UnitSlot::Done(_) | UnitSlot::Abandoned | UnitSlot::Taken)
    }
}

#[derive(Default)]
struct PoolState {
    /// Unit ids awaiting a lease, in submission order (re-queued units
    /// go to the front so a died-once unit is retried first).
    pending: VecDeque<u64>,
    /// The lease table, indexed by unit id.
    units: Vec<UnitSlot>,
    /// Connected, handshaken workers.
    live_workers: usize,
    /// Stream clones per live worker, so `shutdown` can unblock their
    /// I/O threads immediately.
    streams: Vec<(usize, TcpStream)>,
    /// True once [`DistPool::shutdown`] ran.
    shutdown: bool,
}

/// The coordinator side of the protocol: a listening socket, the lease
/// table, and one I/O thread per connected worker. See the module docs
/// for the lease/re-lease semantics. Create with [`DistPool::listen`],
/// submit with [`DistPool::submit`], and call [`DistPool::shutdown`]
/// when the run is over (worker connections are closed; workers see a
/// clean EOF and exit).
pub struct DistPool {
    state: Mutex<PoolState>,
    /// Wakes worker I/O threads parked for pending work.
    work_cv: Condvar,
    /// Wakes submitters parked for a unit to turn terminal.
    done_cv: Condvar,
    addr: std::net::SocketAddr,
    lease_timeout: Duration,
}

impl DistPool {
    /// Bind `addr` (port 0 picks a free port — see [`Self::addr`]) and
    /// start accepting workers in the background. `lease_timeout` is
    /// the seconds of socket silence after which a leased worker is
    /// declared dead and its unit re-queued.
    pub fn listen(addr: &str, lease_timeout: Duration) -> Result<Arc<Self>> {
        if lease_timeout.is_zero() {
            return Err(Error::InvalidArgument("dist: lease timeout must be > 0".into()));
        }
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let bound = listener.local_addr()?;
        let pool = Arc::new(Self {
            state: Mutex::new(PoolState::default()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            addr: bound,
            lease_timeout,
        });
        let accept_pool = Arc::clone(&pool);
        // Not executor work: a nonblocking accept poll that parks in
        // sleep, never computes. The conn threads it spawns are likewise
        // pure I/O (their compute happens on the *worker process*).
        // det-lint: allow(stage-spawn)
        let _accept = crate::sync::thread::spawn_named("ihtc-dist-accept".to_string(), move || {
            accept_loop(accept_pool, listener)
        });
        Ok(pool)
    }

    /// The actually-bound listen address (resolves port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Connected, handshaken workers right now.
    pub fn live_workers(&self) -> usize {
        self.state.lock().unwrap().live_workers
    }

    /// Block (bounded by `max_wait`) until at least `n` workers are
    /// connected; returns whether they showed up. A `false` return is
    /// not an error — the run proceeds and units fall back to local
    /// execution, byte-identically.
    pub fn wait_for_workers(&self, n: usize, max_wait: Duration) -> bool {
        let mut waited = Duration::ZERO;
        loop {
            if self.state.lock().unwrap().live_workers >= n {
                return true;
            }
            if waited >= max_wait {
                return false;
            }
            std::thread::sleep(POLL_STEP);
            waited += POLL_STEP;
        }
    }

    /// Submit one unit for remote execution. If no worker is connected
    /// the lease is abandoned immediately (the caller's cue to run the
    /// unit in-process); otherwise it is queued for the next free
    /// worker.
    pub fn submit(self: &Arc<Self>, spec: &WorkSpec<'_>) -> Lease {
        let mut st = self.state.lock().unwrap();
        let id = st.units.len() as u64;
        if st.shutdown || st.live_workers == 0 {
            st.units.push(UnitSlot::Abandoned);
        } else {
            let payload = Arc::new(encode_spec(id, spec));
            st.units.push(UnitSlot::Pending(payload));
            st.pending.push_back(id);
            drop(st);
            self.work_cv.notify_all();
        }
        Lease { pool: Arc::clone(self), id }
    }

    /// Stop accepting, close every worker connection (workers see clean
    /// EOF and exit), and abandon all outstanding units so no submitter
    /// parks forever. Idempotent.
    pub fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        st.pending.clear();
        for slot in st.units.iter_mut() {
            if !slot.terminal() {
                *slot = UnitSlot::Abandoned;
            }
        }
        st.live_workers = 0;
        for (_, s) in st.streams.drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        drop(st);
        self.work_cv.notify_all();
        self.done_cv.notify_all();
    }

    /// A worker's I/O thread hit an error (disconnect, timeout, torn or
    /// mismatched frame): deregister it and either re-queue its leased
    /// unit for the survivors or abandon it — and, with no survivors,
    /// abandon everything pending (the no-hang guarantee).
    fn worker_died(&self, token: usize, leased: Option<u64>) {
        let mut st = self.state.lock().unwrap();
        if let Some(i) = st.streams.iter().position(|(t, _)| *t == token) {
            st.streams.remove(i);
        }
        // Called only by registered workers' I/O threads, exactly once
        // each — decrement unconditionally (registration may have failed
        // to retain a stream clone, but it always counted the worker).
        st.live_workers = st.live_workers.saturating_sub(1);
        if st.shutdown {
            return;
        }
        if let Some(id) = leased {
            if let UnitSlot::Leased(payload) = &st.units[id as usize] {
                if st.live_workers > 0 {
                    let payload = Arc::clone(payload);
                    st.units[id as usize] = UnitSlot::Pending(payload);
                    st.pending.push_front(id);
                } else {
                    st.units[id as usize] = UnitSlot::Abandoned;
                }
            }
        }
        if st.live_workers == 0 {
            while let Some(id) = st.pending.pop_front() {
                st.units[id as usize] = UnitSlot::Abandoned;
            }
        }
        drop(st);
        self.work_cv.notify_all();
        self.done_cv.notify_all();
    }
}

/// One submitted unit's handle: poll with [`Completion::done`], block
/// with [`Completion::wait`], consume with [`Lease::take_result`] —
/// the remote sibling of [`crate::exec::BatchHandle`], behind the same
/// [`Completion`] surface.
pub struct Lease {
    pool: Arc<DistPool>,
    id: u64,
}

impl Lease {
    /// Block until the unit is terminal, then consume it: `Some` is the
    /// remote result, decoded; `None` means the unit was abandoned, the
    /// worker reported an execution error, or the result frame failed
    /// to decode — in every case the caller runs the unit in-process,
    /// which produces the byte-identical outcome (or the same
    /// deterministic error).
    pub fn take_result(&self) -> Option<UnitResult> {
        let mut st = self.pool.state.lock().unwrap();
        while !st.units[self.id as usize].terminal() {
            st = self.pool.done_cv.wait(st).unwrap();
        }
        let bytes = match std::mem::replace(&mut st.units[self.id as usize], UnitSlot::Taken) {
            UnitSlot::Done(b) => b,
            _ => return None,
        };
        drop(st);
        match decode_result(&bytes) {
            Ok((rid, Ok(res))) if rid == self.id => Some(res),
            _ => None,
        }
    }
}

impl Completion for Lease {
    fn done(&self) -> bool {
        self.pool.state.lock().unwrap().units[self.id as usize].terminal()
    }

    fn wait(&self) {
        let mut st = self.pool.state.lock().unwrap();
        while !st.units[self.id as usize].terminal() {
            st = self.pool.done_cv.wait(st).unwrap();
        }
    }
}

fn accept_loop(pool: Arc<DistPool>, listener: TcpListener) {
    let mut next_token = 0usize;
    loop {
        if pool.state.lock().unwrap().shutdown {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let token = next_token;
                next_token += 1;
                let conn_pool = Arc::clone(&pool);
                // Not executor work: blocks on socket I/O for its whole
                // life; the leased unit's compute runs on the worker
                // process, not this thread.
                // det-lint: allow(stage-spawn)
                let _conn = crate::sync::thread::spawn_named(
                    format!("ihtc-dist-conn-{token}"),
                    move || conn_loop(conn_pool, stream, token),
                );
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => std::thread::sleep(POLL_STEP),
            Err(_) => std::thread::sleep(POLL_STEP),
        }
    }
}

/// One worker's coordinator-side I/O loop: handshake, register, then
/// lease → send → await result, until the worker dies or the pool shuts
/// down.
fn conn_loop(pool: Arc<DistPool>, mut stream: TcpStream, token: usize) {
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(pool.lease_timeout)).is_err() {
        return;
    }
    let mut hs = [0u8; 12];
    if stream.read_exact(&mut hs).is_err()
        || hs[..8] != DIST_MAGIC
        || u32::from_le_bytes(hs[8..12].try_into().unwrap()) != DIST_VERSION
        || stream.write_all(&hs).is_err()
    {
        return; // not a compatible worker; never registered
    }
    {
        let mut st = pool.state.lock().unwrap();
        if st.shutdown {
            return;
        }
        st.live_workers += 1;
        if let Ok(clone) = stream.try_clone() {
            st.streams.push((token, clone));
        }
    }
    loop {
        // Acquire the next pending unit (or exit on shutdown).
        let (id, payload) = {
            let mut st = pool.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return; // shutdown already abandoned everything
                }
                if let Some(id) = st.pending.pop_front() {
                    if let UnitSlot::Pending(p) = &st.units[id as usize] {
                        let payload = Arc::clone(p);
                        st.units[id as usize] = UnitSlot::Leased(Arc::clone(&payload));
                        break (id, payload);
                    }
                    continue; // stale queue entry; skip
                }
                st = pool.work_cv.wait(st).unwrap();
            }
        };
        if write_frame_to(&mut stream, &payload).is_err() {
            pool.worker_died(token, Some(id));
            return;
        }
        let reply = match read_frame_from(&mut stream) {
            Ok(Some(r)) => r,
            // EOF, timeout, torn frame, CRC mismatch: the worker is
            // dead mid-lease either way.
            _ => {
                pool.worker_died(token, Some(id));
                return;
            }
        };
        if reply.len() < 8 || u64::from_le_bytes(reply[..8].try_into().unwrap()) != id {
            pool.worker_died(token, Some(id)); // protocol violation
            return;
        }
        let mut st = pool.state.lock().unwrap();
        st.units[id as usize] = UnitSlot::Done(reply);
        drop(st);
        pool.done_cv.notify_all();
    }
}

// ---------------------------------------------------------------------
// Config plumbing

/// Build the coordinator pool a config asks for: `None` when the `dist`
/// block is absent/disabled (`workers: 0`), otherwise a listening pool
/// that has waited up to one lease timeout for the configured worker
/// count to connect (proceeding regardless — absent workers degrade to
/// local execution, byte-identically).
pub fn pool_from_config(config: &crate::config::PipelineConfig) -> Result<Option<Arc<DistPool>>> {
    if config.dist_workers == 0 {
        return Ok(None);
    }
    let listen = config.dist_listen.as_deref().ok_or_else(|| {
        Error::Config("dist.workers > 0 requires dist.listen".into())
    })?;
    let timeout =
        Duration::from_secs_f64(config.dist_lease_timeout.unwrap_or(DEFAULT_LEASE_TIMEOUT_SECS));
    let pool = DistPool::listen(listen, timeout)?;
    pool.wait_for_workers(config.dist_workers, timeout);
    Ok(Some(pool))
}

// ---------------------------------------------------------------------
// k-NN provider for the materialized path

/// [`KnnProvider`] that leases each forest build + query block to a
/// remote worker, falling back to the in-process
/// [`PoolKnnProvider`] when the lease is abandoned. Both sides run
/// [`crate::knn::knn_auto_sharded_into`], whose output is
/// byte-identical for every shards × workers combination — so the
/// provider can switch per call without perturbing a single bit.
pub struct DistKnnProvider<'a> {
    /// The coordinator pool.
    pub pool: &'a Arc<DistPool>,
    /// The in-process fallback (also defines `shards`).
    pub local: PoolKnnProvider<'a>,
}

impl DistKnnProvider<'_> {
    fn remote(&self, points: &Matrix, k: usize) -> Option<KnnLists> {
        let lease = self.pool.submit(&WorkSpec::ForestKnn {
            points,
            k,
            shards: self.local.shards,
        });
        match lease.take_result() {
            Some(UnitResult::ForestKnn { lists }) => Some(lists),
            _ => None,
        }
    }
}

impl KnnProvider for DistKnnProvider<'_> {
    fn knn(&self, points: &Matrix, k: usize) -> Result<KnnLists> {
        let mut out = KnnLists::default();
        self.knn_into(points, k, &mut out)?;
        Ok(out)
    }

    fn knn_into(&self, points: &Matrix, k: usize, out: &mut KnnLists) -> Result<()> {
        match self.remote(points, k) {
            Some(lists) => {
                *out = lists;
                Ok(())
            }
            None => self.local.knn_into(points, k, out),
        }
    }

    fn knn_forest_into(
        &self,
        points: &Matrix,
        k: usize,
        forest: &mut KdForest,
        out: &mut KnnLists,
    ) -> Result<()> {
        match self.remote(points, k) {
            Some(lists) => {
                *out = lists;
                Ok(())
            }
            None => self.local.knn_forest_into(points, k, forest, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;

    fn spawn_worker(addr: std::net::SocketAddr, faults: WireFaultPlan) -> std::thread::JoinHandle<Result<()>> {
        std::thread::spawn(move || serve_with_faults(&addr.to_string(), 2, &faults))
    }

    fn local_reduce(points: &Matrix) -> (ShardReduction, Moments) {
        let exec = Arc::new(Executor::new(2));
        let mut moments = Moments::new(points.cols());
        moments.fold(points);
        let mut reducer = ShardReducer::new(exec, 2, ItisConfig::level0(4, SeedOrder::Natural));
        (reducer.reduce(points).unwrap(), moments)
    }

    fn assert_reduce_matches(res: UnitResult, want: &(ShardReduction, Moments)) {
        let UnitResult::ReduceShard { reduction, moments } = res else {
            panic!("wrong result kind");
        };
        assert_eq!(reduction.prototypes.data(), want.0.prototypes.data());
        assert_eq!(reduction.weights, want.0.weights);
        assert_eq!(reduction.assignments, want.0.assignments);
        assert_eq!(moments.count, want.1.count);
        assert_eq!(moments.sum, want.1.sum);
        assert_eq!(moments.cross, want.1.cross);
    }

    #[test]
    fn miri_unit_codec_roundtrip_and_rejections() {
        let ds = gaussian_mixture_paper(40, 7);
        let spec = WorkSpec::ReduceShard {
            offset: 64,
            points: &ds.points,
            threshold: 4,
            seed_order: SeedOrder::DegreeAscending,
            knn_shards: 3,
        };
        let payload = encode_spec(9, &spec);
        let (id, unit) = decode_unit(&payload).unwrap();
        assert_eq!(id, 9);
        let WorkUnit::ReduceShard { offset, points, threshold, seed_order, knn_shards } = unit
        else {
            panic!("wrong kind");
        };
        assert_eq!(offset, 64);
        assert_eq!(points.data(), ds.points.data());
        assert_eq!(threshold, 4);
        assert_eq!(seed_order, SeedOrder::DegreeAscending);
        assert_eq!(knn_shards, 3);
        // Every truncation is an error, never a panic.
        for cut in 0..payload.len() {
            assert!(decode_unit(&payload[..cut]).is_err(), "cut {cut}");
        }
        let mut padded = payload.clone();
        padded.push(0);
        assert!(decode_unit(&padded).is_err());

        let fspec = WorkSpec::ForestKnn { points: &ds.points, k: 3, shards: 2 };
        let fpayload = encode_spec(11, &fspec);
        let (fid, funit) = decode_unit(&fpayload).unwrap();
        assert_eq!(fid, 11);
        let WorkUnit::ForestKnn { points, k, shards } = funit else { panic!("wrong kind") };
        assert_eq!((k, shards), (3, 2));
        assert_eq!(points.data(), ds.points.data());
    }

    #[test]
    fn miri_result_codec_roundtrip_and_rejections() {
        // Synthetic reduction (no executor: this runs under Miri).
        let prototypes = Matrix::from_vec((0..6).map(|v| v as f32 * 0.25).collect(), 3, 2).unwrap();
        let mut moments = Moments::new(2);
        moments.count = 4;
        moments.sum = vec![1.5, -2.0];
        moments.cross = vec![1.0, 2.0, 3.0, 4.0];
        let want = (
            ShardReduction {
                prototypes,
                weights: vec![2, 1, 1],
                assignments: vec![0, 0, 1, 2],
            },
            moments,
        );
        let res = UnitResult::ReduceShard {
            reduction: want.0.clone(),
            moments: Moments {
                count: want.1.count,
                sum: want.1.sum.clone(),
                cross: want.1.cross.clone(),
            },
        };
        let bytes = encode_result_ok(5, &res);
        let (id, decoded) = decode_result(&bytes).unwrap();
        assert_eq!(id, 5);
        assert_reduce_matches(decoded.unwrap(), &want);
        for cut in 0..bytes.len() {
            assert!(decode_result(&bytes[..cut]).is_err(), "cut {cut}");
        }

        let err = encode_result_err(7, "boom");
        let (id, decoded) = decode_result(&err).unwrap();
        assert_eq!(id, 7);
        assert_eq!(decoded.unwrap_err(), "boom");

        let lists = KnnLists { k: 2, indices: vec![1, 2, 0, 3, 0, 1, 1, 2], dists: vec![0.5; 8] };
        let bytes = encode_result_ok(3, &UnitResult::ForestKnn { lists: lists.clone() });
        let (_, decoded) = decode_result(&bytes).unwrap();
        let UnitResult::ForestKnn { lists: got } = decoded.unwrap() else { panic!("kind") };
        assert_eq!((got.k, got.indices, got.dists), (lists.k, lists.indices, lists.dists));
    }

    #[test]
    fn loopback_lease_produces_local_bytes() {
        let pool = DistPool::listen("127.0.0.1:0", Duration::from_secs(20)).unwrap();
        let worker = spawn_worker(pool.addr(), WireFaultPlan::none());
        assert!(pool.wait_for_workers(1, Duration::from_secs(10)));

        let ds = gaussian_mixture_paper(500, 21);
        let want = local_reduce(&ds.points);
        let lease = pool.submit(&WorkSpec::ReduceShard {
            offset: 0,
            points: &ds.points,
            threshold: 4,
            seed_order: SeedOrder::Natural,
            knn_shards: 2,
        });
        assert_reduce_matches(lease.take_result().expect("remote result"), &want);
        assert!(Completion::done(&lease));

        // ForestKnn parity against the pooled local path — and against
        // the build_query_block convenience, which is the same unit.
        let exec = Executor::new(2);
        let mut local = KnnLists::default();
        let mut forest = KdForest::new();
        crate::knn::knn_auto_sharded_into(&ds.points, 3, 2, &exec, &mut forest, &mut local)
            .unwrap();
        let mut via_block = KnnLists::default();
        KdForest::new()
            .build_query_block(&ds.points, 3, 2, &exec, &mut via_block)
            .unwrap();
        let flease = pool.submit(&WorkSpec::ForestKnn { points: &ds.points, k: 3, shards: 2 });
        let Some(UnitResult::ForestKnn { lists }) = flease.take_result() else {
            panic!("remote knn failed");
        };
        assert_eq!(lists.indices, local.indices);
        assert_eq!(lists.dists, local.dists);
        assert_eq!(via_block.indices, local.indices);
        assert_eq!(via_block.dists, local.dists);

        pool.shutdown();
        worker.join().unwrap().unwrap();
    }

    #[test]
    fn no_workers_means_immediate_abandon() {
        let pool = DistPool::listen("127.0.0.1:0", Duration::from_secs(1)).unwrap();
        let ds = gaussian_mixture_paper(50, 3);
        let lease = pool.submit(&WorkSpec::ForestKnn { points: &ds.points, k: 2, shards: 1 });
        assert!(Completion::done(&lease)); // no waiting, no hanging
        assert!(lease.take_result().is_none());
        pool.shutdown();
    }

    #[test]
    fn killed_worker_abandons_its_lease() {
        let pool = DistPool::listen("127.0.0.1:0", Duration::from_secs(20)).unwrap();
        let worker = spawn_worker(pool.addr(), WireFaultPlan {
            kill_after_lease: Some(0),
            ..WireFaultPlan::none()
        });
        assert!(pool.wait_for_workers(1, Duration::from_secs(10)));
        let ds = gaussian_mixture_paper(100, 5);
        let lease = pool.submit(&WorkSpec::ReduceShard {
            offset: 0,
            points: &ds.points,
            threshold: 4,
            seed_order: SeedOrder::Natural,
            knn_shards: 1,
        });
        // Sole worker vanished mid-lease → abandoned, not hung.
        assert!(lease.take_result().is_none());
        worker.join().unwrap().unwrap();
        pool.shutdown();
    }

    #[test]
    fn torn_result_relerases_to_surviving_worker() {
        let pool = DistPool::listen("127.0.0.1:0", Duration::from_secs(20)).unwrap();
        let bad = spawn_worker(pool.addr(), WireFaultPlan {
            torn_result_at_lease: Some(0),
            ..WireFaultPlan::none()
        });
        assert!(pool.wait_for_workers(1, Duration::from_secs(10)));
        let ds = gaussian_mixture_paper(200, 9);
        let want = local_reduce(&ds.points);
        let lease = pool.submit(&WorkSpec::ReduceShard {
            offset: 0,
            points: &ds.points,
            threshold: 4,
            seed_order: SeedOrder::Natural,
            knn_shards: 2,
        });
        // Give the torn frame time to land, then connect the survivor:
        // the re-queued unit must produce the byte-identical result.
        bad.join().unwrap().unwrap();
        let good = spawn_worker(pool.addr(), WireFaultPlan::none());
        // An abandoned lease (the survivor connected after the bad
        // worker's death drained the pool) is the documented
        // local-fallback path, also byte-identical — so only a *wrong*
        // remote result can fail here.
        if let Some(res) = lease.take_result() {
            assert_reduce_matches(res, &want);
        }
        pool.shutdown();
        good.join().unwrap().unwrap();
    }
}
