//! Bounded-channel streaming pipeline with backpressure and metrics.
//!
//! The ingestion path (`source → preprocess → reduce`) is expressed as a
//! chain of stages connected by `sync_channel`s of configurable capacity.
//! A slow downstream stage fills its input queue and blocks the producer
//! — classic backpressure — and every stage records items processed,
//! busy time, and blocked-on-send time so the launcher can print where
//! the pipeline is actually bottlenecked.

use crate::linalg::Matrix;
use crate::{Error, Result};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A contiguous block of dataset rows flowing through the ingest
/// pipeline in streaming mode: the source emits these without ever
/// materializing the full matrix.
#[derive(Clone, Debug)]
pub struct RowShard {
    /// Index of the shard's first row in the overall stream.
    pub offset: usize,
    /// The shard's rows (`shard_size × d`, except possibly the tail).
    pub points: Matrix,
    /// Ground-truth labels for the shard's rows, when known.
    pub labels: Option<Vec<u32>>,
}

/// A shard after the fused level-0 TC reduction: weighted prototypes
/// plus the row → local-prototype assignment needed to back final
/// labels out onto the original rows.
#[derive(Clone, Debug)]
pub struct ReducedShard {
    /// Index of the source shard's first row in the overall stream.
    pub offset: usize,
    /// Weighted-centroid prototypes, one per TC cluster of the shard.
    pub prototypes: Matrix,
    /// Original units represented by each prototype.
    pub weights: Vec<u32>,
    /// Shard row → local prototype index (length = shard rows).
    pub assignments: Vec<u32>,
    /// Ground-truth labels carried through from the source shard.
    pub labels: Option<Vec<u32>>,
}

/// Metrics recorded by one stage.
#[derive(Clone, Debug, Default)]
pub struct StageMetrics {
    /// Stage name.
    pub name: String,
    /// Items that passed through.
    pub items: usize,
    /// Time spent doing work.
    pub busy: Duration,
    /// Time spent blocked sending downstream (backpressure).
    pub blocked: Duration,
}

impl StageMetrics {
    /// Items per second of busy time.
    pub fn throughput(&self) -> f64 {
        if self.busy.is_zero() {
            0.0
        } else {
            self.items as f64 / self.busy.as_secs_f64()
        }
    }
}

/// Shared collection of per-stage metrics for a run.
pub type MetricsHandle = Arc<Mutex<Vec<StageMetrics>>>;

/// Send with blocked-time accounting: non-blocking first, then a
/// blocking send whose wait is attributed to backpressure.
fn send_counted<T>(tx: &SyncSender<T>, item: T, blocked: &mut Duration) -> Result<()> {
    match tx.try_send(item) {
        Ok(()) => Ok(()),
        Err(TrySendError::Full(back)) => {
            let t0 = Instant::now();
            let r = tx.send(back);
            *blocked += t0.elapsed();
            r.map_err(|_| Error::Coordinator("downstream stage hung up".into()))
        }
        Err(TrySendError::Disconnected(_)) => {
            Err(Error::Coordinator("downstream stage hung up".into()))
        }
    }
}

/// A running pipeline of threads; dropping joins nothing — call
/// [`Pipeline::join`].
pub struct Pipeline<T> {
    /// Receiver of the final stage's output.
    pub output: Receiver<T>,
    handles: Vec<std::thread::JoinHandle<Result<()>>>,
    metrics: MetricsHandle,
}

/// True for the synthetic error a stage reports when its receiver
/// disappeared — a *symptom* of a downstream failure, never the cause.
fn is_hangup(e: &Error) -> bool {
    matches!(e, Error::Coordinator(m) if m.contains("hung up"))
}

impl<T> Pipeline<T> {
    /// Wait for all stages; returns per-stage metrics. Errors from any
    /// stage surface here: all stage results are collected first, and
    /// the first error that is *not* a "downstream stage hung up"
    /// symptom wins — a failing mid-pipeline stage closes its input
    /// channel, which makes every upstream stage report a hang-up, so
    /// returning errors in handle (= stage) order would mask the root
    /// cause behind the source's symptom.
    pub fn join(self) -> Result<Vec<StageMetrics>> {
        let mut hangup: Option<Error> = None;
        let mut root: Option<Error> = None;
        for h in self.handles {
            let r = h
                .join()
                .map_err(|_| Error::Coordinator("stage panicked".into()))
                .and_then(|r| r);
            match r {
                Ok(()) => {}
                Err(e) if is_hangup(&e) => {
                    if hangup.is_none() {
                        hangup = Some(e);
                    }
                }
                Err(e) => {
                    if root.is_none() {
                        root = Some(e);
                    }
                }
            }
        }
        if let Some(e) = root.or(hangup) {
            return Err(e);
        }
        let m = self.metrics.lock().map_err(|_| Error::Coordinator("metrics poisoned".into()))?;
        Ok(m.clone())
    }
}

/// Builder for a linear pipeline `source → map… → output`.
pub struct PipelineBuilder<T: Send + 'static> {
    capacity: usize,
    metrics: MetricsHandle,
    head: Receiver<T>,
    handles: Vec<std::thread::JoinHandle<Result<()>>>,
}

impl<T: Send + 'static> PipelineBuilder<T> {
    /// Start a pipeline from a source closure that pushes items downstream.
    pub fn source(
        name: &str,
        capacity: usize,
        produce: impl FnOnce(&mut dyn FnMut(T) -> Result<()>) -> Result<()> + Send + 'static,
    ) -> Self {
        let metrics: MetricsHandle = Arc::new(Mutex::new(Vec::new()));
        let (tx, rx) = std::sync::mpsc::sync_channel::<T>(capacity.max(1));
        let m = metrics.clone();
        let name = name.to_string();
        let handle = std::thread::spawn(move || {
            let mut stats = StageMetrics { name, ..Default::default() };
            let t0 = Instant::now();
            let mut blocked = Duration::ZERO;
            let mut emit = |item: T| -> Result<()> {
                stats.items += 1;
                send_counted(&tx, item, &mut blocked)
            };
            let out = produce(&mut emit);
            stats.busy = t0.elapsed().saturating_sub(blocked);
            stats.blocked = blocked;
            m.lock().unwrap().push(stats);
            out
        });
        Self { capacity: capacity.max(1), metrics, head: rx, handles: vec![handle] }
    }

    /// Append a transform stage.
    pub fn map<U: Send + 'static>(
        self,
        name: &str,
        mut f: impl FnMut(T) -> Result<U> + Send + 'static,
    ) -> PipelineBuilder<U> {
        self.map_init(name, || (), move |_, item| f(item))
    }

    /// Append a transform stage with thread-local state, built once on
    /// the stage thread and handed to every invocation. This is the
    /// pooled stage variant the fused streaming reduce uses: the state
    /// holds a `WorkerPool` plus reusable workspaces so every shard is
    /// processed through the same buffers with zero steady-state
    /// allocation. The state never crosses threads, so it does not need
    /// to be `Send` — only the initializer does.
    pub fn map_init<S: 'static, U: Send + 'static>(
        self,
        name: &str,
        init: impl FnOnce() -> S + Send + 'static,
        mut f: impl FnMut(&mut S, T) -> Result<U> + Send + 'static,
    ) -> PipelineBuilder<U> {
        let (tx, rx) = std::sync::mpsc::sync_channel::<U>(self.capacity);
        let m = self.metrics.clone();
        let name = name.to_string();
        let upstream = self.head;
        let mut handles = self.handles;
        handles.push(std::thread::spawn(move || {
            let mut stats = StageMetrics { name, ..Default::default() };
            let mut blocked = Duration::ZERO;
            let mut state = init();
            let mut result = Ok(());
            for item in upstream {
                let t0 = Instant::now();
                match f(&mut state, item) {
                    Ok(out) => {
                        stats.busy += t0.elapsed();
                        stats.items += 1;
                        if let Err(e) = send_counted(&tx, out, &mut blocked) {
                            result = Err(e);
                            break;
                        }
                    }
                    Err(e) => {
                        result = Err(e);
                        break;
                    }
                }
            }
            stats.blocked = blocked;
            m.lock().unwrap().push(stats);
            result
        }));
        PipelineBuilder { capacity: self.capacity, metrics: self.metrics, head: rx, handles }
    }

    /// Finish building; the caller consumes `output`.
    pub fn build(self) -> Pipeline<T> {
        Pipeline { output: self.head, handles: self.handles, metrics: self.metrics }
    }
}

/// Convenience: run a source→maps pipeline and fold the outputs.
pub fn collect<T: Send + 'static>(p: Pipeline<T>) -> Result<(Vec<T>, Vec<StageMetrics>)> {
    let mut out = Vec::new();
    for item in &p.output {
        out.push(item);
    }
    let metrics = p.join()?;
    Ok((out, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_pipeline_transforms_in_order() {
        let p = PipelineBuilder::source("gen", 2, |emit| {
            for i in 0..100u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map("double", |x| Ok(x * 2))
        .map("plus1", |x| Ok(x + 1))
        .build();
        let (out, metrics) = collect(p).unwrap();
        assert_eq!(out, (0..100u64).map(|i| i * 2 + 1).collect::<Vec<_>>());
        assert_eq!(metrics.len(), 3);
        assert!(metrics.iter().all(|m| m.items == 100));
    }

    #[test]
    fn backpressure_blocks_producer() {
        // Slow consumer + capacity 1 → the source records blocked time.
        let p = PipelineBuilder::source("fast", 1, |emit| {
            for i in 0..20u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map("slow", |x| {
            std::thread::sleep(Duration::from_millis(3));
            Ok(x)
        })
        .build();
        let (_, metrics) = collect(p).unwrap();
        let source = metrics.iter().find(|m| m.name == "fast").unwrap();
        assert!(
            source.blocked > Duration::from_millis(10),
            "expected backpressure, blocked={:?}",
            source.blocked
        );
    }

    #[test]
    fn stage_error_propagates() {
        let p = PipelineBuilder::source("gen", 2, |emit| {
            for i in 0..10u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map("explode", |x| {
            if x == 5 {
                Err(Error::Coordinator("kaboom".into()))
            } else {
                Ok(x)
            }
        })
        .build();
        // The root cause must surface verbatim — the upstream source's
        // "downstream stage hung up" symptom must never mask it.
        let err = collect(p).unwrap_err();
        assert!(err.to_string().contains("kaboom"), "{err}");
    }

    #[test]
    fn mid_stage_error_is_root_cause() {
        // A failure in the *middle* of a three-stage chain: the source
        // blocks on a full queue and reports a hang-up, the downstream
        // stage drains and finishes cleanly — join must still surface
        // the failing stage's own error.
        let p = PipelineBuilder::source("gen", 1, |emit| {
            for i in 0..100u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map("pre", |x| Ok(x + 1))
        .map("explode", |x| {
            if x == 4 {
                Err(Error::Data("bad shard".into()))
            } else {
                Ok(x)
            }
        })
        .map("post", Ok)
        .build();
        let err = collect(p).unwrap_err();
        assert!(err.to_string().contains("bad shard"), "{err}");
    }

    #[test]
    fn map_init_state_persists_across_items() {
        // The stage state is built once on the stage thread and reused
        // for every item (running sum ⇒ order and persistence).
        let p = PipelineBuilder::source("gen", 2, |emit| {
            for i in 1..=10u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map_init(
            "acc",
            || 0u64,
            |acc, x| {
                *acc += x;
                Ok(*acc)
            },
        )
        .build();
        let (out, metrics) = collect(p).unwrap();
        let want: Vec<u64> = (1..=10u64).scan(0, |s, x| {
            *s += x;
            Some(*s)
        })
        .collect();
        assert_eq!(out, want);
        assert!(metrics.iter().any(|m| m.name == "acc" && m.items == 10));
    }

    #[test]
    fn source_error_propagates() {
        let p = PipelineBuilder::source("bad", 2, |emit| {
            emit(1u64)?;
            Err(Error::Coordinator("source died".into()))
        })
        .map("id", Ok)
        .build();
        assert!(collect(p).is_err());
    }

    #[test]
    fn throughput_metric_sane() {
        let m = StageMetrics {
            name: "x".into(),
            items: 100,
            busy: Duration::from_secs(2),
            blocked: Duration::ZERO,
        };
        assert!((m.throughput() - 50.0).abs() < 1e-9);
    }
}
