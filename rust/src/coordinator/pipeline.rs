//! Executor-native streaming pipeline with backpressure and metrics.
//!
//! The ingestion path is expressed as a short chain of OS threads
//! connected by `sync_channel`s — but the *parallel* work inside it no
//! longer runs on dedicated stage threads. The fused
//! [`PipelineBuilder::source_exec_ordered`] entry runs the source
//! closure on one thread whose emit callback submits each item as a
//! prioritized batch to the run's shared [`Executor`]
//! ([`Executor::submit`] → [`BatchHandle`]), windows the in-flight
//! batches (`reduce_stages` is that window, not a thread count), and
//! feeds completions through an inline [`ReorderBuffer`] so downstream
//! stages still see strict stream order. A slow downstream stage fills
//! its input queue and blocks the producer — classic backpressure — and
//! every stage records items processed, busy time, blocked-on-send
//! time, and (for executor batches) queue-wait vs. run time, so the
//! launcher can print where the pipeline is actually bottlenecked.

use crate::exec::{BatchHandle, Executor, Priority};
use crate::linalg::Matrix;
use crate::sync::{thread, Arc, Mutex};
use crate::{Error, Result};
use std::collections::{BTreeMap, VecDeque};
// Channels stay on std: loom has no mpsc double, and the pipeline is
// only *compiled* under `--cfg loom` (the loom scenarios model the
// executor, which the source thread submits into), never executed
// there. The endpoints live on exactly two kinds of surviving threads —
// the fused source and the map/sink stages — and carry no atomics of
// their own, so nothing here dodges the model checker.
// det-lint: allow(std-mpsc)
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::time::{Duration, Instant};

/// A contiguous block of dataset rows flowing through the ingest
/// pipeline in streaming mode: the source emits these without ever
/// materializing the full matrix.
#[derive(Clone, Debug)]
pub struct RowShard {
    /// Index of the shard's first row in the overall stream.
    pub offset: usize,
    /// The shard's rows (`shard_size × d`, except possibly the tail).
    pub points: Matrix,
    /// Ground-truth labels for the shard's rows, when known.
    pub labels: Option<Vec<u32>>,
}

/// A shard after the fused level-0 TC reduction: weighted prototypes
/// plus the row → local-prototype assignment needed to back final
/// labels out onto the original rows.
#[derive(Clone, Debug)]
pub struct ReducedShard {
    /// Index of the source shard's first row in the overall stream.
    pub offset: usize,
    /// Weighted-centroid prototypes, one per TC cluster of the shard.
    pub prototypes: Matrix,
    /// Original units represented by each prototype.
    pub weights: Vec<u32>,
    /// Shard row → local prototype index (length = shard rows).
    pub assignments: Vec<u32>,
    /// Ground-truth labels carried through from the source shard.
    pub labels: Option<Vec<u32>>,
}

/// Metrics recorded by one stage.
#[derive(Clone, Debug, Default)]
pub struct StageMetrics {
    /// Stage name.
    pub name: String,
    /// Items that passed through.
    pub items: usize,
    /// Time spent doing work.
    pub busy: Duration,
    /// Time spent blocked sending downstream (backpressure).
    pub blocked: Duration,
    /// Time the stage's work sat queued on the shared executor before a
    /// worker first claimed it (executor-native stages only; zero for
    /// plain thread stages). Together with `busy` this splits "the
    /// reduce is slow" into "the team is oversubscribed" vs. "the work
    /// itself is expensive" — attribution the per-stage threads used to
    /// give for free.
    pub queued: Duration,
}

impl StageMetrics {
    /// Items per second of busy time.
    pub fn throughput(&self) -> f64 {
        if self.busy.is_zero() {
            0.0
        } else {
            self.items as f64 / self.busy.as_secs_f64()
        }
    }
}

/// Shared collection of per-stage metrics for a run. Every stage
/// registers its slot at *build* time and writes it by index on exit, so
/// [`Pipeline::join`] always returns metrics in source→…→sink order —
/// pushing on stage *completion* would make the bottleneck report's
/// ordering depend on which thread happened to finish first.
pub type MetricsHandle = Arc<Mutex<Vec<StageMetrics>>>;

/// Reserve the next metrics slot for a stage; returns its index.
fn register_stage(metrics: &MetricsHandle, name: &str) -> usize {
    let mut m = metrics.lock().unwrap();
    m.push(StageMetrics { name: name.to_string(), ..Default::default() });
    m.len() - 1
}

/// Write a stage's final stats into its pre-registered slot.
fn store_stage(metrics: &MetricsHandle, slot: usize, stats: StageMetrics) {
    if let Ok(mut m) = metrics.lock() {
        m[slot] = stats;
    }
}

/// Send with blocked-time accounting: non-blocking first, then a
/// blocking send whose wait is attributed to backpressure.
fn send_counted<T>(tx: &SyncSender<T>, item: T, blocked: &mut Duration) -> Result<()> {
    match tx.try_send(item) {
        Ok(()) => Ok(()),
        Err(TrySendError::Full(back)) => {
            let t0 = Instant::now();
            let r = tx.send(back);
            *blocked += t0.elapsed();
            r.map_err(|_| Error::Coordinator("downstream stage hung up".into()))
        }
        Err(TrySendError::Disconnected(_)) => {
            Err(Error::Coordinator("downstream stage hung up".into()))
        }
    }
}

/// Offset-keyed reorder buffer: accepts items in *any* arrival order and
/// releases them strictly in stream order. Each item covers the
/// half-open offset range `[offset, offset + extent)`; released items
/// must tile the stream exactly — a duplicate, an overlap, or (at
/// [`ReorderBuffer::finish`]) a gap is a hard [`Error::Coordinator`],
/// never a silent mis-concatenation. `bound` caps how many out-of-order
/// items may be parked at once, so a stream whose offsets genuinely do
/// not tile fails fast instead of buffering without limit.
///
/// This is what makes N concurrent reduce stages safe: the fan-in used
/// to *assume* in-order arrival (guarded only by a `debug_assert`, i.e.
/// nothing in release builds); with the buffer the ordering contract is
/// enforced, not assumed.
#[derive(Debug)]
pub struct ReorderBuffer<T> {
    /// Next offset to release (the stream is contiguous below this).
    next: usize,
    /// Max parked items before arrival is declared non-tiling.
    bound: usize,
    /// Parked out-of-order items: offset → (extent, item).
    pending: BTreeMap<usize, (usize, T)>,
}

impl<T> ReorderBuffer<T> {
    /// Empty buffer expecting the stream to start at offset 0.
    pub fn new(bound: usize) -> Self {
        Self::with_start(bound, 0)
    }

    /// Empty buffer for a *resumed* stream: offsets below `start` were
    /// already released in an earlier run (replayed from a checkpoint),
    /// so the first expected arrival is `start` — an arrival below it is
    /// the usual duplicate/overlap hard error.
    pub fn with_start(bound: usize, start: usize) -> Self {
        Self { next: start, bound: bound.max(1), pending: BTreeMap::new() }
    }

    /// Park one arrival. Errors on a duplicate offset, an overlap with a
    /// released or parked range, a zero extent, or buffer overflow.
    pub fn push(&mut self, offset: usize, extent: usize, item: T) -> Result<()> {
        if extent == 0 {
            return Err(Error::Coordinator(format!(
                "reorder buffer: zero-extent item at offset {offset} (offsets must tile the \
                 stream, so every item must cover at least one row)"
            )));
        }
        if offset < self.next {
            return Err(Error::Coordinator(format!(
                "reorder buffer: item at offset {offset} arrived after the stream was already \
                 released through {} (duplicate or overlapping shard)",
                self.next
            )));
        }
        if let Some((&prev_off, prev)) = self.pending.range(..=offset).next_back() {
            if prev_off == offset {
                return Err(Error::Coordinator(format!(
                    "reorder buffer: duplicate shard offset {offset}"
                )));
            }
            if prev_off + prev.0 > offset {
                return Err(Error::Coordinator(format!(
                    "reorder buffer: shard at offset {offset} overlaps the shard covering \
                     [{prev_off}, {})",
                    prev_off + prev.0
                )));
            }
        }
        if let Some((&succ_off, _)) = self.pending.range(offset + 1..).next() {
            if offset + extent > succ_off {
                return Err(Error::Coordinator(format!(
                    "reorder buffer: shard [{offset}, {}) overlaps the shard at offset {succ_off}",
                    offset + extent
                )));
            }
        }
        // The bound caps *out-of-order* items only: the in-order arrival
        // (offset == next) is about to be released by the caller's
        // pop_ready loop and must never be charged against it — a
        // tiling stream sized exactly to the cap would otherwise be
        // spuriously rejected.
        if offset != self.next && self.pending.len() >= self.bound {
            return Err(Error::Coordinator(format!(
                "reorder buffer overflow: {} items parked while waiting for offset {} — shard \
                 offsets do not tile the stream (gap), or the buffer bound is smaller than the \
                 pipeline's in-flight capacity",
                self.pending.len(),
                self.next
            )));
        }
        self.pending.insert(offset, (extent, item));
        Ok(())
    }

    /// Release the next in-order item, if it has arrived.
    pub fn pop_ready(&mut self) -> Option<T> {
        let off = *self.pending.keys().next()?;
        if off != self.next {
            return None;
        }
        let (extent, item) = self.pending.remove(&off).expect("first key just observed");
        self.next += extent;
        Some(item)
    }

    /// Offset the stream has been contiguously released through.
    pub fn released_through(&self) -> usize {
        self.next
    }

    /// Out-of-order items currently parked (waiting for the stream
    /// head). The fused executor stage gates new submissions on this so
    /// a slow head batch cannot let completed successors pile up
    /// without bound.
    pub fn parked(&self) -> usize {
        self.pending.len()
    }

    /// End-of-stream check: any still-parked item means the stream had a
    /// gap (an offset that never arrived).
    pub fn finish(&self) -> Result<()> {
        if let Some((&off, _)) = self.pending.iter().next() {
            return Err(Error::Coordinator(format!(
                "shard stream has a gap: offset {} never arrived ({} shard(s) from offset {off} \
                 onward are stranded in the reorder buffer)",
                self.next,
                self.pending.len()
            )));
        }
        Ok(())
    }
}

/// Knobs for [`PipelineBuilder::source_exec_ordered`], bundled so the
/// call site stays readable next to its four closures.
pub struct ExecStageOpts {
    /// Metrics name for the source half (also the thread name suffix).
    pub source: String,
    /// Metrics name for the executor-batch stage.
    pub stage: String,
    /// Metrics name for the inline reorder accounting.
    pub reorder: String,
    /// Output channel capacity (backpressure toward the sink).
    pub capacity: usize,
    /// Max batches in flight on the executor at once — the
    /// `reduce_stages` knob. Caps pooled per-batch states and parked
    /// memory, not threads: the work itself runs on the shared team.
    pub max_in_flight: usize,
    /// Priority class every batch is submitted at.
    pub priority: Priority,
    /// Max *completed but out-of-order* items parked in the inline
    /// reorder buffer before submission pauses to wait for the stream
    /// head. Size it at least to `max_in_flight`.
    pub parked_bound: usize,
    /// First expected stream offset (resume support; 0 for a fresh run).
    pub start: usize,
}

/// In-flight window + reorder state of one executor-native stage. The
/// fused source thread drives it from inside its emit callback (submit
/// side) and drains it after the producer returns; it owns no thread of
/// its own. Batches are submitted in stream order, so the window front
/// is always the batch producing the offset the reorder head waits for
/// — that is what makes `make_room`'s wait-on-front converge.
struct ExecPump<S, In, T, F, K>
where
    S: Send + 'static,
    In: Send + 'static,
    T: Send + 'static,
    F: Fn((S, In)) -> Result<(S, T)> + Send + Sync + Clone + 'static,
    K: Fn(&T) -> (usize, usize),
{
    exec: std::sync::Arc<Executor>,
    priority: Priority,
    max_in_flight: usize,
    parked_bound: usize,
    /// Prototype task closure, cloned per submission (it captures only
    /// an `Arc` of the caller's work function).
    task_fn: F,
    /// Builds a fresh state when the pool is empty (cold start).
    init: Box<dyn Fn() -> S + Send>,
    /// Handles of in-flight batches, in submission (= stream) order.
    window: VecDeque<BatchHandle<(S, In), (S, T), F>>,
    /// Recycled per-batch states — at most `max_in_flight` ever exist.
    pool: Vec<S>,
    buf: ReorderBuffer<T>,
    tx: SyncSender<T>,
    key: K,
    // Metrics accumulators, split per conceptual stage.
    stage_items: usize,
    queued: Duration,
    run: Duration,
    exec_wait: Duration,
    send_blocked: Duration,
    released: usize,
}

impl<S, In, T, F, K> ExecPump<S, In, T, F, K>
where
    S: Send + 'static,
    In: Send + 'static,
    T: Send + 'static,
    F: Fn((S, In)) -> Result<(S, T)> + Send + Sync + Clone + 'static,
    K: Fn(&T) -> (usize, usize),
{
    /// Collect every completed batch: recycle its state, account its
    /// queue-wait/run split, park its output, and release whatever
    /// became contiguous. Returns whether any batch was collected.
    fn drain_done(&mut self) -> Result<bool> {
        let mut progressed = false;
        let mut i = 0;
        while i < self.window.len() {
            if !self.window[i].done() {
                i += 1;
                continue;
            }
            let h = self.window.remove(i).expect("index checked in bounds");
            let (qw, rt) = h.timings();
            self.queued += qw;
            self.run += rt;
            // collect() errors on any shortfall, so pop() is total here.
            let (state, out) = h
                .collect()?
                .pop()
                .ok_or_else(|| Error::Coordinator("executor lost tasks".into()))?;
            self.pool.push(state);
            self.stage_items += 1;
            let (offset, extent) = (self.key)(&out);
            self.buf.push(offset, extent, out)?;
            while let Some(ready) = self.buf.pop_ready() {
                send_counted(&self.tx, ready, &mut self.send_blocked)?;
                self.released += 1;
            }
            progressed = true;
        }
        Ok(progressed)
    }

    /// Help the executor with (or block on) the oldest in-flight batch.
    fn push_front_along(&mut self) {
        let Some(front) = self.window.front() else { return };
        if !front.help() {
            let t0 = Instant::now();
            front.wait();
            self.exec_wait += t0.elapsed();
        }
    }

    /// Block until there is room for one more submission: a free window
    /// slot AND parked-headroom in the reorder buffer. In-order
    /// submission means the window front is exactly the stream-head
    /// batch, so driving it forward shrinks both gauges.
    fn make_room(&mut self) -> Result<()> {
        loop {
            self.drain_done()?;
            if self.window.len() < self.max_in_flight && self.buf.parked() < self.parked_bound {
                return Ok(());
            }
            if self.window.is_empty() {
                // Window empty ⇒ parked == 0 (everything collected was
                // contiguous), so the gate above must have passed;
                // defensive exit rather than a spin.
                return Ok(());
            }
            self.push_front_along();
        }
    }

    /// Submit one item as a single-task batch on the shared executor.
    fn submit(&mut self, item: In) -> Result<()> {
        self.make_room()?;
        let state = self.pool.pop().unwrap_or_else(|| (self.init)());
        let h = self.exec.submit(vec![(state, item)], self.priority, self.task_fn.clone());
        self.window.push_back(h);
        Ok(())
    }

    /// Drain every in-flight batch, then require the released stream to
    /// have tiled completely (the reorder gap check).
    fn finish(&mut self) -> Result<()> {
        while !self.window.is_empty() {
            if self.drain_done()? {
                continue;
            }
            self.push_front_along();
        }
        self.buf.finish()
    }
}

/// A running pipeline of threads; dropping joins nothing — call
/// [`Pipeline::join`].
pub struct Pipeline<T> {
    /// Receiver of the final stage's output.
    pub output: Receiver<T>,
    handles: Vec<thread::JoinHandle<Result<()>>>,
    metrics: MetricsHandle,
}

/// True for the synthetic error a stage reports when its receiver
/// disappeared — a *symptom* of a downstream failure, never the cause.
fn is_hangup(e: &Error) -> bool {
    matches!(e, Error::Coordinator(m) if m.contains("hung up"))
}

impl<T> Pipeline<T> {
    /// Wait for all stages; returns per-stage metrics. Errors from any
    /// stage surface here: all stage results are collected first, and
    /// the first error that is *not* a "downstream stage hung up"
    /// symptom wins — a failing mid-pipeline stage closes its input
    /// channel, which makes every upstream stage report a hang-up, so
    /// returning errors in handle (= stage) order would mask the root
    /// cause behind the source's symptom.
    pub fn join(self) -> Result<Vec<StageMetrics>> {
        let mut hangup: Option<Error> = None;
        let mut root: Option<Error> = None;
        for h in self.handles {
            let r = h
                .join()
                .map_err(|_| Error::Coordinator("stage panicked".into()))
                .and_then(|r| r);
            match r {
                Ok(()) => {}
                Err(e) if is_hangup(&e) => {
                    if hangup.is_none() {
                        hangup = Some(e);
                    }
                }
                Err(e) => {
                    if root.is_none() {
                        root = Some(e);
                    }
                }
            }
        }
        if let Some(e) = root.or(hangup) {
            return Err(e);
        }
        let m = self.metrics.lock().map_err(|_| Error::Coordinator("metrics poisoned".into()))?;
        Ok(m.clone())
    }
}

/// Builder for a linear pipeline `source → map… → output`.
pub struct PipelineBuilder<T: Send + 'static> {
    capacity: usize,
    metrics: MetricsHandle,
    head: Receiver<T>,
    handles: Vec<thread::JoinHandle<Result<()>>>,
}

impl<T: Send + 'static> PipelineBuilder<T> {
    /// Start a pipeline from a source closure that pushes items downstream.
    pub fn source(
        name: &str,
        capacity: usize,
        produce: impl FnOnce(&mut dyn FnMut(T) -> Result<()>) -> Result<()> + Send + 'static,
    ) -> Self {
        let metrics: MetricsHandle = Arc::new(Mutex::new(Vec::new()));
        let slot = register_stage(&metrics, name);
        let (tx, rx) = sync_channel::<T>(capacity.max(1));
        let m = metrics.clone();
        let name = name.to_string();
        // Surviving source thread: I/O-bound producer, not stage work.
        // det-lint: allow(stage-spawn)
        let handle = thread::spawn_named(format!("ihtc-stage-{name}"), move || {
            let mut stats = StageMetrics { name, ..Default::default() };
            let t0 = Instant::now();
            let mut blocked = Duration::ZERO;
            let mut emit = |item: T| -> Result<()> {
                // Count only items the downstream actually accepted — a
                // failed send must not show up as a processed item.
                send_counted(&tx, item, &mut blocked)?;
                stats.items += 1;
                Ok(())
            };
            let out = produce(&mut emit);
            stats.busy = t0.elapsed().saturating_sub(blocked);
            stats.blocked = blocked;
            store_stage(&m, slot, stats);
            out
        });
        Self { capacity: capacity.max(1), metrics, head: rx, handles: vec![handle] }
    }

    /// Append a transform stage.
    pub fn map<U: Send + 'static>(
        self,
        name: &str,
        mut f: impl FnMut(T) -> Result<U> + Send + 'static,
    ) -> PipelineBuilder<U> {
        self.map_init(name, || (), move |_, item| f(item))
    }

    /// Append a transform stage with thread-local state, built once on
    /// the stage thread and handed to every invocation (e.g. the
    /// streaming checkpoint sink's open file + CRC state). The state
    /// never crosses threads, so it does not need to be `Send` — only
    /// the initializer does. Parallel work does not belong here: that is
    /// [`Self::source_exec_ordered`]'s executor window.
    pub fn map_init<S: 'static, U: Send + 'static>(
        self,
        name: &str,
        init: impl FnOnce() -> S + Send + 'static,
        mut f: impl FnMut(&mut S, T) -> Result<U> + Send + 'static,
    ) -> PipelineBuilder<U> {
        let (tx, rx) = sync_channel::<U>(self.capacity);
        let slot = register_stage(&self.metrics, name);
        let m = self.metrics.clone();
        let name = name.to_string();
        let upstream = self.head;
        let mut handles = self.handles;
        // Surviving sink/serial-map thread (e.g. the checkpoint sink):
        // inherently sequential by contract, not parallel stage work.
        // det-lint: allow(stage-spawn)
        handles.push(thread::spawn_named(format!("ihtc-stage-{name}"), move || {
            let mut stats = StageMetrics { name, ..Default::default() };
            let mut blocked = Duration::ZERO;
            let mut state = init();
            let mut result = Ok(());
            for item in upstream {
                let t0 = Instant::now();
                match f(&mut state, item) {
                    Ok(out) => {
                        stats.busy += t0.elapsed();
                        if let Err(e) = send_counted(&tx, out, &mut blocked) {
                            result = Err(e);
                            break;
                        }
                        // Counted only after the downstream accepted it.
                        stats.items += 1;
                    }
                    Err(e) => {
                        result = Err(e);
                        break;
                    }
                }
            }
            stats.blocked = blocked;
            store_stage(&m, slot, stats);
            result
        }));
        PipelineBuilder { capacity: self.capacity, metrics: self.metrics, head: rx, handles }
    }

    /// Start an executor-native fan-out/fan-in pipeline head: one
    /// thread runs `produce`, and its emit callback submits each item as
    /// a single-task batch ([`Executor::submit`]) at `opts.priority` on
    /// the run's shared team — there is no distributor thread and no
    /// per-stage worker threads. Up to `opts.max_in_flight` batches ride
    /// the executor concurrently (the `reduce_stages` knob), each with a
    /// pooled `init()`-built state that is recycled across batches (so
    /// states cross worker threads and must be `Send`). Completions are
    /// collected back on this same thread, reordered inline through a
    /// [`ReorderBuffer`] keyed by `key` (`(offset, extent)` tiling, resume
    /// supported via `opts.start`), and sent downstream strictly in
    /// stream order.
    ///
    /// Metrics: three slots in topological order — `opts.source`
    /// (produce time minus pump time), `opts.stage` (Σ batch run time as
    /// `busy`, Σ executor queue-wait as `queued`, wait-for-completion as
    /// `blocked`), and `opts.reorder` (released items, send backpressure
    /// as `blocked`).
    ///
    /// Error propagation: a failing batch (including a panicking task,
    /// surfaced as `Error::Coordinator("executor task panicked")`)
    /// aborts the remaining in-flight batches via their handles' drop
    /// and returns the root cause through [`Pipeline::join`]; a
    /// `produce` error does the same. Tiling violations are the usual
    /// hard [`Error::Coordinator`]s from [`ReorderBuffer`].
    pub fn source_exec_ordered<In, S>(
        opts: ExecStageOpts,
        exec: std::sync::Arc<Executor>,
        init: impl Fn() -> S + Send + 'static,
        work: impl Fn(&mut S, In) -> Result<T> + Send + Sync + 'static,
        key: impl Fn(&T) -> (usize, usize) + Send + 'static,
        produce: impl FnOnce(&mut dyn FnMut(In) -> Result<()>) -> Result<()> + Send + 'static,
    ) -> PipelineBuilder<T>
    where
        In: Send + 'static,
        S: Send + 'static,
    {
        let metrics: MetricsHandle = Arc::new(Mutex::new(Vec::new()));
        let src_slot = register_stage(&metrics, &opts.source);
        let stage_slot = register_stage(&metrics, &opts.stage);
        let ro_slot = register_stage(&metrics, &opts.reorder);
        let capacity = opts.capacity.max(1);
        let (tx, rx) = sync_channel::<T>(capacity);
        let m = metrics.clone();
        // The ONE thread of the fused head: source + submit window +
        // reorder fan-in. All parallel work lands on the shared
        // executor team, so peak OS threads stay team + source + sink.
        // det-lint: allow(stage-spawn)
        let handle = thread::spawn_named(format!("ihtc-stage-{}", opts.source), move || {
            let work = std::sync::Arc::new(work);
            let task_fn = {
                let work = std::sync::Arc::clone(&work);
                move |(mut state, item): (S, In)| {
                    let out = (work)(&mut state, item)?;
                    Ok((state, out))
                }
            };
            let max_in_flight = opts.max_in_flight.max(1);
            let parked_bound = opts.parked_bound.max(max_in_flight);
            let mut pump = ExecPump {
                exec,
                priority: opts.priority,
                max_in_flight,
                parked_bound,
                task_fn,
                init: Box::new(init),
                window: VecDeque::new(),
                pool: Vec::new(),
                // One drain pass can park up to a full window on top of
                // the gate's parked headroom; sized so a tiling stream
                // can never spuriously overflow.
                buf: ReorderBuffer::with_start(parked_bound + max_in_flight, opts.start),
                tx,
                key,
                stage_items: 0,
                queued: Duration::ZERO,
                run: Duration::ZERO,
                exec_wait: Duration::ZERO,
                send_blocked: Duration::ZERO,
                released: 0,
            };
            let mut src_items = 0usize;
            let mut pump_time = Duration::ZERO;
            let t0 = Instant::now();
            let mut emit = |item: In| -> Result<()> {
                let e0 = Instant::now();
                let r = pump.submit(item);
                pump_time += e0.elapsed();
                if r.is_ok() {
                    src_items += 1;
                }
                r
            };
            let mut result = produce(&mut emit);
            let produce_total = t0.elapsed();
            drop(emit);
            if result.is_ok() {
                result = pump.finish();
            }
            store_stage(
                &m,
                src_slot,
                StageMetrics {
                    name: opts.source,
                    items: src_items,
                    busy: produce_total.saturating_sub(pump_time),
                    ..Default::default()
                },
            );
            store_stage(
                &m,
                stage_slot,
                StageMetrics {
                    name: opts.stage,
                    items: pump.stage_items,
                    busy: pump.run,
                    blocked: pump.exec_wait,
                    queued: pump.queued,
                },
            );
            store_stage(
                &m,
                ro_slot,
                StageMetrics {
                    name: opts.reorder,
                    items: pump.released,
                    blocked: pump.send_blocked,
                    ..Default::default()
                },
            );
            // On error, dropping `pump` cancels the in-flight batches
            // (each handle's drop aborts its unclaimed tasks) and closes
            // `tx` so the sink drains out cleanly.
            result
        });
        PipelineBuilder { capacity, metrics, head: rx, handles: vec![handle] }
    }

    /// Append a reorder stage: items arriving in any order are parked in
    /// a [`ReorderBuffer`] and released strictly in stream order. `key`
    /// extracts `(offset, extent)` from each item; offsets must tile the
    /// stream from 0 — a gap, duplicate, or overlap is a hard
    /// [`Error::Coordinator`] surfaced through [`Pipeline::join`].
    /// `bound` caps parked items (see [`ReorderBuffer::new`]); size it to
    /// the pipeline's maximum in-flight item count.
    pub fn reorder(
        self,
        name: &str,
        bound: usize,
        key: impl Fn(&T) -> (usize, usize) + Send + 'static,
    ) -> PipelineBuilder<T> {
        self.reorder_from(name, bound, 0, key)
    }

    /// [`Self::reorder`] for a *resumed* stream: the buffer expects the
    /// first arrival at offset `start` (everything below it was released
    /// in an earlier run and replayed from a checkpoint). With
    /// `start = 0` this is exactly `reorder`.
    pub fn reorder_from(
        self,
        name: &str,
        bound: usize,
        start: usize,
        key: impl Fn(&T) -> (usize, usize) + Send + 'static,
    ) -> PipelineBuilder<T> {
        let (tx, rx) = sync_channel::<T>(self.capacity);
        let slot = register_stage(&self.metrics, name);
        let m = self.metrics.clone();
        let name = name.to_string();
        let upstream = self.head;
        let mut handles = self.handles;
        // Standalone reorder stage for channel-fed pipelines; the fused
        // executor head reorders inline and does not use this thread.
        // det-lint: allow(stage-spawn)
        handles.push(thread::spawn_named(format!("ihtc-stage-{name}"), move || {
            let mut stats = StageMetrics { name, ..Default::default() };
            let mut busy = Duration::ZERO;
            let mut blocked = Duration::ZERO;
            let mut buf = ReorderBuffer::with_start(bound, start);
            let mut result = Ok(());
            'recv: for item in upstream {
                let t0 = Instant::now();
                let (offset, extent) = key(&item);
                if let Err(e) = buf.push(offset, extent, item) {
                    result = Err(e);
                    break;
                }
                while let Some(ready) = buf.pop_ready() {
                    if let Err(e) = send_counted(&tx, ready, &mut blocked) {
                        result = Err(e);
                        break 'recv;
                    }
                    stats.items += 1;
                }
                busy += t0.elapsed();
            }
            if result.is_ok() {
                result = buf.finish();
            }
            stats.busy = busy.saturating_sub(blocked);
            stats.blocked = blocked;
            store_stage(&m, slot, stats);
            result
        }));
        PipelineBuilder { capacity: self.capacity, metrics: self.metrics, head: rx, handles }
    }

    /// Finish building; the caller consumes `output`.
    pub fn build(self) -> Pipeline<T> {
        Pipeline { output: self.head, handles: self.handles, metrics: self.metrics }
    }
}

/// Convenience: run a source→maps pipeline and fold the outputs.
pub fn collect<T: Send + 'static>(p: Pipeline<T>) -> Result<(Vec<T>, Vec<StageMetrics>)> {
    let mut out = Vec::new();
    for item in &p.output {
        out.push(item);
    }
    let metrics = p.join()?;
    Ok((out, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_pipeline_transforms_in_order() {
        let p = PipelineBuilder::source("gen", 2, |emit| {
            for i in 0..100u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map("double", |x| Ok(x * 2))
        .map("plus1", |x| Ok(x + 1))
        .build();
        let (out, metrics) = collect(p).unwrap();
        assert_eq!(out, (0..100u64).map(|i| i * 2 + 1).collect::<Vec<_>>());
        // Metrics come back in source→…→sink order regardless of which
        // stage thread finished first (slots are pre-registered at build
        // time, not pushed on completion).
        let names: Vec<&str> = metrics.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, ["gen", "double", "plus1"]);
        assert!(metrics.iter().all(|m| m.items == 100));
    }

    #[test]
    fn source_counts_only_successful_sends() {
        // Downstream vanishes immediately: not a single emit can land,
        // so the source must report zero items processed — not one per
        // attempted send.
        let p = PipelineBuilder::source("gen", 1, |emit| {
            for i in 0..10u64 {
                emit(i)?;
            }
            Ok(())
        })
        .build();
        let Pipeline { output, handles, metrics } = p;
        drop(output);
        for h in handles {
            assert!(h.join().unwrap().is_err(), "source must see the hang-up");
        }
        let m = metrics.lock().unwrap();
        let gen = m.iter().find(|s| s.name == "gen").unwrap();
        assert_eq!(gen.items, 0, "no send succeeded, so no item was processed");
    }

    #[test]
    fn map_init_counts_only_successful_sends() {
        // The map stage transforms one item fine but its downstream is
        // gone — the item must not count as processed.
        let p = PipelineBuilder::source("gen", 1, |emit| {
            emit(1u64)?;
            Ok(())
        })
        .map_init("id", || (), |_, x: u64| Ok(x))
        .build();
        let Pipeline { output, handles, metrics } = p;
        drop(output);
        for h in handles {
            let _ = h.join().unwrap();
        }
        let m = metrics.lock().unwrap();
        let id = m.iter().find(|s| s.name == "id").unwrap();
        assert_eq!(id.items, 0, "send failed, so the item was not processed");
    }

    /// Shorthand opts for the executor-stage tests.
    fn opts(in_flight: usize, priority: Priority, start: usize) -> ExecStageOpts {
        ExecStageOpts {
            source: "gen".into(),
            stage: "par".into(),
            reorder: "reorder".into(),
            capacity: 2,
            max_in_flight: in_flight,
            priority,
            parked_bound: in_flight.max(4),
            start,
        }
    }

    #[test]
    fn exec_stage_processes_everything_in_order() {
        // The fused head submits every item as a batch on the shared
        // executor and reorders inline: all inputs come out *in stream
        // order* (no trailing reorder stage needed), the three metric
        // slots land in topological order, and the per-batch state is
        // pooled rather than rebuilt.
        let exec = std::sync::Arc::new(Executor::new(3));
        let p = PipelineBuilder::source_exec_ordered(
            opts(3, Priority::Normal, 0),
            exec,
            || 0u64,
            |seen, x: u64| {
                *seen += 1;
                Ok((x, x * 2))
            },
            |t: &(u64, u64)| (t.0 as usize, 1),
            |emit| {
                for i in 0..99u64 {
                    emit(i)?;
                }
                Ok(())
            },
        )
        .build();
        let (out, metrics) = collect(p).unwrap();
        assert_eq!(out, (0..99u64).map(|i| (i, i * 2)).collect::<Vec<_>>());
        let names: Vec<&str> = metrics.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, ["gen", "par", "reorder"]);
        assert!(metrics.iter().all(|m| m.items == 99), "{metrics:?}");
    }

    #[test]
    fn exec_stage_restores_stream_order_inline() {
        // Batch run time is value-dependent so completion order on the
        // team is scrambled; the inline buffer must still release items
        // strictly in stream order, and the sleep must be attributed to
        // batch *run* time (stage busy), not queue wait — the
        // queue-wait/run split is what replaced per-thread busy clocks.
        let exec = std::sync::Arc::new(Executor::new(4));
        let p = PipelineBuilder::source_exec_ordered(
            opts(4, Priority::High, 0),
            exec,
            || (),
            |_, x: u64| {
                std::thread::sleep(Duration::from_millis((x * 7) % 5));
                Ok(x)
            },
            |x: &u64| (*x as usize, 1),
            |emit| {
                for i in 0..40u64 {
                    emit(i)?;
                }
                Ok(())
            },
        )
        .build();
        let (out, metrics) = collect(p).unwrap();
        assert_eq!(out, (0..40u64).collect::<Vec<_>>());
        let par = metrics.iter().find(|m| m.name == "par").unwrap();
        assert_eq!(par.items, 40);
        // Σ sleeps ≈ 80ms; all of it is run time inside the batches.
        assert!(
            par.busy >= Duration::from_millis(40),
            "sleeps must land in stage busy (run) time, got {:?}",
            par.busy
        );
        let ro = metrics.iter().find(|m| m.name == "reorder").unwrap();
        assert_eq!(ro.items, 40);
    }

    #[test]
    fn exec_stage_error_is_root_cause() {
        // One batch fails mid-stream: the pump aborts the remaining
        // in-flight batches and the failing task's own error surfaces
        // through join — never a hang-up symptom.
        let exec = std::sync::Arc::new(Executor::new(3));
        let p = PipelineBuilder::source_exec_ordered(
            opts(3, Priority::Normal, 0),
            exec,
            || (),
            |_, x: u64| {
                if x == 7 {
                    Err(Error::Data("poison shard".into()))
                } else {
                    Ok(x)
                }
            },
            |x: &u64| (*x as usize, 1),
            |emit| {
                for i in 0..50u64 {
                    emit(i)?;
                }
                Ok(())
            },
        )
        .build();
        let err = collect(p).unwrap_err();
        assert!(err.to_string().contains("poison shard"), "{err}");
    }

    #[test]
    fn source_error_with_exec_stage_is_root_cause() {
        // The producer dies mid-stream while batches are still in
        // flight: the pump's drop cancels them, and join must surface
        // the source's own error — for every in-flight width, including
        // widths above the worker budget.
        for in_flight in [2usize, 4] {
            let exec = std::sync::Arc::new(Executor::new(2));
            let p = PipelineBuilder::source_exec_ordered(
                opts(in_flight, Priority::Bulk, 0),
                exec,
                || (),
                |_, x: u64| Ok(x),
                |x: &u64| (*x as usize, 1),
                |emit| {
                    for i in 0..20u64 {
                        emit(i)?;
                    }
                    Err(Error::Data("source torn mid-stream".into()))
                },
            )
            .build();
            let err = collect(p).unwrap_err();
            assert!(matches!(err, Error::Data(_)), "in_flight={in_flight}: {err}");
            assert!(
                err.to_string().contains("source torn mid-stream"),
                "in_flight={in_flight}: {err}"
            );
        }
    }

    #[test]
    fn exec_stage_serial_executor_matches_wide() {
        // Budget-1 executor: submit() runs each batch inline and the
        // handle is born complete — output must be identical to a wide
        // team, and max_in_flight above the worker budget is explicitly
        // fine (it is an in-flight cap, not a thread budget).
        let run = |workers: usize, in_flight: usize| {
            let exec = std::sync::Arc::new(Executor::new(workers));
            let p = PipelineBuilder::source_exec_ordered(
                opts(in_flight, Priority::Normal, 0),
                exec,
                || 0u64,
                |acc, x: u64| {
                    *acc = acc.wrapping_add(x);
                    Ok(x * 3)
                },
                |x: &u64| ((*x / 3) as usize, 1),
                |emit| {
                    for i in 0..60u64 {
                        emit(i)?;
                    }
                    Ok(())
                },
            )
            .build();
            collect(p).unwrap().0
        };
        let want = run(1, 1);
        for (workers, in_flight) in [(1, 4), (2, 2), (4, 3)] {
            assert_eq!(run(workers, in_flight), want, "workers={workers} in_flight={in_flight}");
        }
    }

    #[test]
    fn exec_stage_resumes_mid_stream() {
        // A resumed stream starts at the checkpoint row, not 0:
        // submission is in stream order from offset 30, completions
        // scramble on the team, and the inline buffer releases [30, 70)
        // in order. An arrival below the start offset stays the usual
        // duplicate/overlap hard error (raw buffer checks below).
        let exec = std::sync::Arc::new(Executor::new(3));
        let p = PipelineBuilder::source_exec_ordered(
            opts(3, Priority::Normal, 30),
            exec,
            || (),
            |_, x: u64| {
                std::thread::sleep(Duration::from_millis((x * 3) % 4));
                Ok(x)
            },
            |x: &u64| (*x as usize, 1),
            |emit| {
                for i in 30..70u64 {
                    emit(i)?;
                }
                Ok(())
            },
        )
        .build();
        let (out, _) = collect(p).unwrap();
        assert_eq!(out, (30..70u64).collect::<Vec<_>>());

        let mut buf = ReorderBuffer::with_start(8, 30);
        assert!(buf.push(10, 5, ()).is_err(), "pre-start arrival must be rejected");
        buf.push(30, 5, ()).unwrap();
        assert_eq!(buf.parked(), 1);
        assert!(buf.pop_ready().is_some());
        assert_eq!(buf.parked(), 0);
        assert_eq!(buf.released_through(), 35);
        buf.finish().unwrap();
    }

    #[test]
    fn reorder_gap_is_hard_error_through_join() {
        // Offset 5 never arrives: the stream ends with a parked shard and
        // the reorder stage must fail join() with the gap as root cause —
        // in a release build just as in debug (no debug_assert guards).
        let p = PipelineBuilder::source("gen", 2, |emit| {
            emit((0usize, 5usize))?;
            emit((10usize, 5usize))?;
            Ok(())
        })
        .reorder("reorder", 16, |x: &(usize, usize)| (x.0, x.1))
        .build();
        let err = collect(p).unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)), "{err}");
        assert!(err.to_string().contains("gap"), "{err}");
    }

    #[test]
    fn reorder_duplicate_offset_is_hard_error_through_join() {
        let p = PipelineBuilder::source("gen", 2, |emit| {
            emit((0usize, 5usize))?;
            emit((5usize, 5usize))?;
            emit((5usize, 5usize))?;
            Ok(())
        })
        .reorder("reorder", 16, |x: &(usize, usize)| (x.0, x.1))
        .build();
        let err = collect(p).unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)), "{err}");
        assert!(
            err.to_string().contains("duplicate") || err.to_string().contains("overlap"),
            "{err}"
        );
    }

    #[test]
    fn reorder_overlap_is_hard_error() {
        let p = PipelineBuilder::source("gen", 2, |emit| {
            emit((0usize, 8usize))?;
            emit((4usize, 8usize))?;
            Ok(())
        })
        .reorder("reorder", 16, |x: &(usize, usize)| (x.0, x.1))
        .build();
        let err = collect(p).unwrap_err();
        assert!(err.to_string().contains("overlap"), "{err}");
    }

    #[test]
    fn reorder_buffer_property_shuffled_arrivals() {
        // Property: for any seeded shuffle of a tiling shard stream, the
        // buffer releases exactly the in-order sequence.
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(0xBEEF);
        for trial in 0..50u64 {
            // Random tiling: offsets 0..total in random-size steps.
            let mut shards = Vec::new();
            let mut off = 0usize;
            while off < 500 {
                let extent = 1 + (rng.next_below(9) as usize);
                shards.push((off, extent.min(500 - off)));
                off += extent.min(500 - off);
            }
            let mut shuffled = shards.clone();
            rng.shuffle(&mut shuffled);
            let mut buf = ReorderBuffer::new(shards.len());
            let mut released = Vec::new();
            for &(o, e) in &shuffled {
                buf.push(o, e, (o, e)).unwrap_or_else(|err| {
                    panic!("trial {trial}: push({o},{e}) failed: {err}")
                });
                while let Some(item) = buf.pop_ready() {
                    released.push(item);
                }
            }
            buf.finish().unwrap();
            assert_eq!(released, shards, "trial {trial}");
            assert_eq!(buf.released_through(), 500);
        }
    }

    #[test]
    fn reorder_buffer_rejects_bad_streams() {
        // Duplicate.
        let mut buf = ReorderBuffer::new(8);
        buf.push(0, 4, ()).unwrap();
        assert!(buf.push(0, 4, ()).is_err());
        // Overlap with a parked shard.
        let mut buf = ReorderBuffer::new(8);
        buf.push(8, 4, ()).unwrap();
        assert!(buf.push(6, 4, ()).is_err());
        assert!(buf.push(10, 4, ()).is_err());
        // Arrival below the released watermark.
        let mut buf = ReorderBuffer::new(8);
        buf.push(0, 4, ()).unwrap();
        assert!(buf.pop_ready().is_some());
        assert!(buf.push(2, 2, ()).is_err());
        // Zero extent.
        let mut buf = ReorderBuffer::<()>::new(8);
        assert!(buf.push(0, 0, ()).is_err());
        // Overflow: bound 2, three parked out-of-order items.
        let mut buf = ReorderBuffer::new(2);
        buf.push(10, 1, ()).unwrap();
        buf.push(20, 1, ()).unwrap();
        let err = buf.push(30, 1, ()).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
        // …but the in-order arrival is never charged against the bound:
        // a tiling stream sized exactly to the cap must drain cleanly.
        let mut buf = ReorderBuffer::new(1);
        buf.push(1, 1, ()).unwrap(); // the one allowed parked item
        buf.push(0, 1, ()).unwrap(); // in-order: releases 0 then 1
        assert!(buf.pop_ready().is_some());
        assert!(buf.pop_ready().is_some());
        buf.finish().unwrap();
        // Gap at end of stream.
        let mut buf = ReorderBuffer::new(8);
        buf.push(4, 4, ()).unwrap();
        assert!(buf.pop_ready().is_none());
        let err = buf.finish().unwrap_err();
        assert!(err.to_string().contains("gap"), "{err}");
    }

    #[test]
    fn backpressure_blocks_producer() {
        // Slow consumer + capacity 1 → the source records blocked time.
        let p = PipelineBuilder::source("fast", 1, |emit| {
            for i in 0..20u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map("slow", |x| {
            std::thread::sleep(Duration::from_millis(3));
            Ok(x)
        })
        .build();
        let (_, metrics) = collect(p).unwrap();
        let source = metrics.iter().find(|m| m.name == "fast").unwrap();
        assert!(
            source.blocked > Duration::from_millis(10),
            "expected backpressure, blocked={:?}",
            source.blocked
        );
    }

    #[test]
    fn stage_error_propagates() {
        let p = PipelineBuilder::source("gen", 2, |emit| {
            for i in 0..10u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map("explode", |x| {
            if x == 5 {
                Err(Error::Coordinator("kaboom".into()))
            } else {
                Ok(x)
            }
        })
        .build();
        // The root cause must surface verbatim — the upstream source's
        // "downstream stage hung up" symptom must never mask it.
        let err = collect(p).unwrap_err();
        assert!(err.to_string().contains("kaboom"), "{err}");
    }

    #[test]
    fn mid_stage_error_is_root_cause() {
        // A failure in the *middle* of a three-stage chain: the source
        // blocks on a full queue and reports a hang-up, the downstream
        // stage drains and finishes cleanly — join must still surface
        // the failing stage's own error.
        let p = PipelineBuilder::source("gen", 1, |emit| {
            for i in 0..100u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map("pre", |x| Ok(x + 1))
        .map("explode", |x| {
            if x == 4 {
                Err(Error::Data("bad shard".into()))
            } else {
                Ok(x)
            }
        })
        .map("post", Ok)
        .build();
        let err = collect(p).unwrap_err();
        assert!(err.to_string().contains("bad shard"), "{err}");
    }

    #[test]
    fn map_init_state_persists_across_items() {
        // The stage state is built once on the stage thread and reused
        // for every item (running sum ⇒ order and persistence).
        let p = PipelineBuilder::source("gen", 2, |emit| {
            for i in 1..=10u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map_init(
            "acc",
            || 0u64,
            |acc, x| {
                *acc += x;
                Ok(*acc)
            },
        )
        .build();
        let (out, metrics) = collect(p).unwrap();
        let want: Vec<u64> = (1..=10u64).scan(0, |s, x| {
            *s += x;
            Some(*s)
        })
        .collect();
        assert_eq!(out, want);
        assert!(metrics.iter().any(|m| m.name == "acc" && m.items == 10));
    }

    #[test]
    fn source_error_propagates() {
        let p = PipelineBuilder::source("bad", 2, |emit| {
            emit(1u64)?;
            Err(Error::Coordinator("source died".into()))
        })
        .map("id", Ok)
        .build();
        assert!(collect(p).is_err());
    }

    #[test]
    fn throughput_metric_sane() {
        let m = StageMetrics {
            name: "x".into(),
            items: 100,
            busy: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.throughput() - 50.0).abs() < 1e-9);
    }
}
