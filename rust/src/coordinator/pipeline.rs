//! Bounded-channel streaming pipeline with backpressure and metrics.
//!
//! The ingestion path (`source → preprocess → reduce`) is expressed as a
//! chain of stages connected by `sync_channel`s of configurable capacity.
//! A slow downstream stage fills its input queue and blocks the producer
//! — classic backpressure — and every stage records items processed,
//! busy time, and blocked-on-send time so the launcher can print where
//! the pipeline is actually bottlenecked.

use crate::linalg::Matrix;
use crate::sync::{thread, Arc, Mutex};
use crate::{Error, Result};
use std::collections::BTreeMap;
// Channels stay on std: loom has no mpsc double, and the pipeline is
// only *compiled* under `--cfg loom` (the loom scenarios model the
// executor, which the stages submit into), never executed there.
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::time::{Duration, Instant};

/// A contiguous block of dataset rows flowing through the ingest
/// pipeline in streaming mode: the source emits these without ever
/// materializing the full matrix.
#[derive(Clone, Debug)]
pub struct RowShard {
    /// Index of the shard's first row in the overall stream.
    pub offset: usize,
    /// The shard's rows (`shard_size × d`, except possibly the tail).
    pub points: Matrix,
    /// Ground-truth labels for the shard's rows, when known.
    pub labels: Option<Vec<u32>>,
}

/// A shard after the fused level-0 TC reduction: weighted prototypes
/// plus the row → local-prototype assignment needed to back final
/// labels out onto the original rows.
#[derive(Clone, Debug)]
pub struct ReducedShard {
    /// Index of the source shard's first row in the overall stream.
    pub offset: usize,
    /// Weighted-centroid prototypes, one per TC cluster of the shard.
    pub prototypes: Matrix,
    /// Original units represented by each prototype.
    pub weights: Vec<u32>,
    /// Shard row → local prototype index (length = shard rows).
    pub assignments: Vec<u32>,
    /// Ground-truth labels carried through from the source shard.
    pub labels: Option<Vec<u32>>,
}

/// Metrics recorded by one stage.
#[derive(Clone, Debug, Default)]
pub struct StageMetrics {
    /// Stage name.
    pub name: String,
    /// Items that passed through.
    pub items: usize,
    /// Time spent doing work.
    pub busy: Duration,
    /// Time spent blocked sending downstream (backpressure).
    pub blocked: Duration,
}

impl StageMetrics {
    /// Items per second of busy time.
    pub fn throughput(&self) -> f64 {
        if self.busy.is_zero() {
            0.0
        } else {
            self.items as f64 / self.busy.as_secs_f64()
        }
    }
}

/// Shared collection of per-stage metrics for a run. Every stage
/// registers its slot at *build* time and writes it by index on exit, so
/// [`Pipeline::join`] always returns metrics in source→…→sink order —
/// pushing on stage *completion* would make the bottleneck report's
/// ordering depend on which thread happened to finish first.
pub type MetricsHandle = Arc<Mutex<Vec<StageMetrics>>>;

/// Reserve the next metrics slot for a stage; returns its index.
fn register_stage(metrics: &MetricsHandle, name: &str) -> usize {
    let mut m = metrics.lock().unwrap();
    m.push(StageMetrics { name: name.to_string(), ..Default::default() });
    m.len() - 1
}

/// Write a stage's final stats into its pre-registered slot.
fn store_stage(metrics: &MetricsHandle, slot: usize, stats: StageMetrics) {
    if let Ok(mut m) = metrics.lock() {
        m[slot] = stats;
    }
}

/// Send with blocked-time accounting: non-blocking first, then a
/// blocking send whose wait is attributed to backpressure.
fn send_counted<T>(tx: &SyncSender<T>, item: T, blocked: &mut Duration) -> Result<()> {
    match tx.try_send(item) {
        Ok(()) => Ok(()),
        Err(TrySendError::Full(back)) => {
            let t0 = Instant::now();
            let r = tx.send(back);
            *blocked += t0.elapsed();
            r.map_err(|_| Error::Coordinator("downstream stage hung up".into()))
        }
        Err(TrySendError::Disconnected(_)) => {
            Err(Error::Coordinator("downstream stage hung up".into()))
        }
    }
}

/// Offset-keyed reorder buffer: accepts items in *any* arrival order and
/// releases them strictly in stream order. Each item covers the
/// half-open offset range `[offset, offset + extent)`; released items
/// must tile the stream exactly — a duplicate, an overlap, or (at
/// [`ReorderBuffer::finish`]) a gap is a hard [`Error::Coordinator`],
/// never a silent mis-concatenation. `bound` caps how many out-of-order
/// items may be parked at once, so a stream whose offsets genuinely do
/// not tile fails fast instead of buffering without limit.
///
/// This is what makes N concurrent reduce stages safe: the fan-in used
/// to *assume* in-order arrival (guarded only by a `debug_assert`, i.e.
/// nothing in release builds); with the buffer the ordering contract is
/// enforced, not assumed.
#[derive(Debug)]
pub struct ReorderBuffer<T> {
    /// Next offset to release (the stream is contiguous below this).
    next: usize,
    /// Max parked items before arrival is declared non-tiling.
    bound: usize,
    /// Parked out-of-order items: offset → (extent, item).
    pending: BTreeMap<usize, (usize, T)>,
}

impl<T> ReorderBuffer<T> {
    /// Empty buffer expecting the stream to start at offset 0.
    pub fn new(bound: usize) -> Self {
        Self::with_start(bound, 0)
    }

    /// Empty buffer for a *resumed* stream: offsets below `start` were
    /// already released in an earlier run (replayed from a checkpoint),
    /// so the first expected arrival is `start` — an arrival below it is
    /// the usual duplicate/overlap hard error.
    pub fn with_start(bound: usize, start: usize) -> Self {
        Self { next: start, bound: bound.max(1), pending: BTreeMap::new() }
    }

    /// Park one arrival. Errors on a duplicate offset, an overlap with a
    /// released or parked range, a zero extent, or buffer overflow.
    pub fn push(&mut self, offset: usize, extent: usize, item: T) -> Result<()> {
        if extent == 0 {
            return Err(Error::Coordinator(format!(
                "reorder buffer: zero-extent item at offset {offset} (offsets must tile the \
                 stream, so every item must cover at least one row)"
            )));
        }
        if offset < self.next {
            return Err(Error::Coordinator(format!(
                "reorder buffer: item at offset {offset} arrived after the stream was already \
                 released through {} (duplicate or overlapping shard)",
                self.next
            )));
        }
        if let Some((&prev_off, prev)) = self.pending.range(..=offset).next_back() {
            if prev_off == offset {
                return Err(Error::Coordinator(format!(
                    "reorder buffer: duplicate shard offset {offset}"
                )));
            }
            if prev_off + prev.0 > offset {
                return Err(Error::Coordinator(format!(
                    "reorder buffer: shard at offset {offset} overlaps the shard covering \
                     [{prev_off}, {})",
                    prev_off + prev.0
                )));
            }
        }
        if let Some((&succ_off, _)) = self.pending.range(offset + 1..).next() {
            if offset + extent > succ_off {
                return Err(Error::Coordinator(format!(
                    "reorder buffer: shard [{offset}, {}) overlaps the shard at offset {succ_off}",
                    offset + extent
                )));
            }
        }
        // The bound caps *out-of-order* items only: the in-order arrival
        // (offset == next) is about to be released by the caller's
        // pop_ready loop and must never be charged against it — a
        // tiling stream sized exactly to the cap would otherwise be
        // spuriously rejected.
        if offset != self.next && self.pending.len() >= self.bound {
            return Err(Error::Coordinator(format!(
                "reorder buffer overflow: {} items parked while waiting for offset {} — shard \
                 offsets do not tile the stream (gap), or the buffer bound is smaller than the \
                 pipeline's in-flight capacity",
                self.pending.len(),
                self.next
            )));
        }
        self.pending.insert(offset, (extent, item));
        Ok(())
    }

    /// Release the next in-order item, if it has arrived.
    pub fn pop_ready(&mut self) -> Option<T> {
        let off = *self.pending.keys().next()?;
        if off != self.next {
            return None;
        }
        let (extent, item) = self.pending.remove(&off).expect("first key just observed");
        self.next += extent;
        Some(item)
    }

    /// Offset the stream has been contiguously released through.
    pub fn released_through(&self) -> usize {
        self.next
    }

    /// End-of-stream check: any still-parked item means the stream had a
    /// gap (an offset that never arrived).
    pub fn finish(&self) -> Result<()> {
        if let Some((&off, _)) = self.pending.iter().next() {
            return Err(Error::Coordinator(format!(
                "shard stream has a gap: offset {} never arrived ({} shard(s) from offset {off} \
                 onward are stranded in the reorder buffer)",
                self.next,
                self.pending.len()
            )));
        }
        Ok(())
    }
}

/// A running pipeline of threads; dropping joins nothing — call
/// [`Pipeline::join`].
pub struct Pipeline<T> {
    /// Receiver of the final stage's output.
    pub output: Receiver<T>,
    handles: Vec<thread::JoinHandle<Result<()>>>,
    metrics: MetricsHandle,
}

/// True for the synthetic error a stage reports when its receiver
/// disappeared — a *symptom* of a downstream failure, never the cause.
fn is_hangup(e: &Error) -> bool {
    matches!(e, Error::Coordinator(m) if m.contains("hung up"))
}

impl<T> Pipeline<T> {
    /// Wait for all stages; returns per-stage metrics. Errors from any
    /// stage surface here: all stage results are collected first, and
    /// the first error that is *not* a "downstream stage hung up"
    /// symptom wins — a failing mid-pipeline stage closes its input
    /// channel, which makes every upstream stage report a hang-up, so
    /// returning errors in handle (= stage) order would mask the root
    /// cause behind the source's symptom.
    pub fn join(self) -> Result<Vec<StageMetrics>> {
        let mut hangup: Option<Error> = None;
        let mut root: Option<Error> = None;
        for h in self.handles {
            let r = h
                .join()
                .map_err(|_| Error::Coordinator("stage panicked".into()))
                .and_then(|r| r);
            match r {
                Ok(()) => {}
                Err(e) if is_hangup(&e) => {
                    if hangup.is_none() {
                        hangup = Some(e);
                    }
                }
                Err(e) => {
                    if root.is_none() {
                        root = Some(e);
                    }
                }
            }
        }
        if let Some(e) = root.or(hangup) {
            return Err(e);
        }
        let m = self.metrics.lock().map_err(|_| Error::Coordinator("metrics poisoned".into()))?;
        Ok(m.clone())
    }
}

/// Builder for a linear pipeline `source → map… → output`.
pub struct PipelineBuilder<T: Send + 'static> {
    capacity: usize,
    metrics: MetricsHandle,
    head: Receiver<T>,
    handles: Vec<thread::JoinHandle<Result<()>>>,
}

impl<T: Send + 'static> PipelineBuilder<T> {
    /// Start a pipeline from a source closure that pushes items downstream.
    pub fn source(
        name: &str,
        capacity: usize,
        produce: impl FnOnce(&mut dyn FnMut(T) -> Result<()>) -> Result<()> + Send + 'static,
    ) -> Self {
        let metrics: MetricsHandle = Arc::new(Mutex::new(Vec::new()));
        let slot = register_stage(&metrics, name);
        let (tx, rx) = std::sync::mpsc::sync_channel::<T>(capacity.max(1));
        let m = metrics.clone();
        let name = name.to_string();
        let handle = thread::spawn_named(format!("ihtc-stage-{name}"), move || {
            let mut stats = StageMetrics { name, ..Default::default() };
            let t0 = Instant::now();
            let mut blocked = Duration::ZERO;
            let mut emit = |item: T| -> Result<()> {
                // Count only items the downstream actually accepted — a
                // failed send must not show up as a processed item.
                send_counted(&tx, item, &mut blocked)?;
                stats.items += 1;
                Ok(())
            };
            let out = produce(&mut emit);
            stats.busy = t0.elapsed().saturating_sub(blocked);
            stats.blocked = blocked;
            store_stage(&m, slot, stats);
            out
        });
        Self { capacity: capacity.max(1), metrics, head: rx, handles: vec![handle] }
    }

    /// Append a transform stage.
    pub fn map<U: Send + 'static>(
        self,
        name: &str,
        mut f: impl FnMut(T) -> Result<U> + Send + 'static,
    ) -> PipelineBuilder<U> {
        self.map_init(name, || (), move |_, item| f(item))
    }

    /// Append a transform stage with thread-local state, built once on
    /// the stage thread and handed to every invocation. This is the
    /// pooled stage variant the fused streaming reduce uses: the state
    /// holds reusable workspaces (plus an `Arc` handle to the run's
    /// shared executor) so every shard is processed through the same
    /// buffers with zero steady-state allocation. The state never crosses threads, so it does not need
    /// to be `Send` — only the initializer does.
    pub fn map_init<S: 'static, U: Send + 'static>(
        self,
        name: &str,
        init: impl FnOnce() -> S + Send + 'static,
        mut f: impl FnMut(&mut S, T) -> Result<U> + Send + 'static,
    ) -> PipelineBuilder<U> {
        let (tx, rx) = std::sync::mpsc::sync_channel::<U>(self.capacity);
        let slot = register_stage(&self.metrics, name);
        let m = self.metrics.clone();
        let name = name.to_string();
        let upstream = self.head;
        let mut handles = self.handles;
        handles.push(thread::spawn_named(format!("ihtc-stage-{name}"), move || {
            let mut stats = StageMetrics { name, ..Default::default() };
            let mut blocked = Duration::ZERO;
            let mut state = init();
            let mut result = Ok(());
            for item in upstream {
                let t0 = Instant::now();
                match f(&mut state, item) {
                    Ok(out) => {
                        stats.busy += t0.elapsed();
                        if let Err(e) = send_counted(&tx, out, &mut blocked) {
                            result = Err(e);
                            break;
                        }
                        // Counted only after the downstream accepted it.
                        stats.items += 1;
                    }
                    Err(e) => {
                        result = Err(e);
                        break;
                    }
                }
            }
            stats.blocked = blocked;
            store_stage(&m, slot, stats);
            result
        }));
        PipelineBuilder { capacity: self.capacity, metrics: self.metrics, head: rx, handles }
    }

    /// Append a fan-out/fan-in transform: `stages` concurrent stage
    /// threads, each with its own `init()`-built state (the `map_init`
    /// pattern — e.g. one `ItisWorkspace` per stage, every stage
    /// submitting its task batches into the run's one shared executor),
    /// fed round-robin by a distributor thread and funneled into one
    /// output channel. Item completion order is **not** stream order: a slow
    /// item on one stage lets later items overtake it, so a downstream
    /// consumer that needs stream order must follow with [`Self::reorder`].
    ///
    /// Metrics: one slot per stage thread (`{name}/0` … `{name}/N-1`)
    /// plus the distributor (`{name}/rr`), all pre-registered in
    /// topological order. Errors from any failing sibling propagate
    /// through [`Pipeline::join`], which keeps the first *root-cause*
    /// error even when the siblings' hang-up symptoms race it.
    ///
    /// `init` and `f` run once per stage thread and are shared, so they
    /// must be `Fn + Send + Sync` (per-item mutability lives in `S`).
    pub fn map_init_parallel<S: 'static, U: Send + 'static>(
        self,
        name: &str,
        stages: usize,
        init: impl Fn() -> S + Send + Sync + 'static,
        f: impl Fn(&mut S, T) -> Result<U> + Send + Sync + 'static,
    ) -> PipelineBuilder<U> {
        let stages = stages.max(1);
        let (out_tx, out_rx) = std::sync::mpsc::sync_channel::<U>(self.capacity);
        let mut handles = self.handles;
        let metrics = self.metrics;
        let init = Arc::new(init);
        let f = Arc::new(f);
        // Register the distributor before the workers so join() reports
        // source → fan-out → workers in topological order.
        let dist_slot = register_stage(&metrics, &format!("{name}/rr"));
        let mut worker_txs = Vec::with_capacity(stages);
        for i in 0..stages {
            let (tx, rx) = std::sync::mpsc::sync_channel::<T>(self.capacity);
            worker_txs.push(tx);
            let worker_name = format!("{name}/{i}");
            let slot = register_stage(&metrics, &worker_name);
            let m = metrics.clone();
            let out_tx = out_tx.clone();
            let init = init.clone();
            let f = f.clone();
            handles.push(thread::spawn_named(format!("ihtc-stage-{worker_name}"), move || {
                let mut stats = StageMetrics { name: worker_name, ..Default::default() };
                let mut blocked = Duration::ZERO;
                let mut state = (*init)();
                let mut result = Ok(());
                for item in rx {
                    let t0 = Instant::now();
                    match (*f)(&mut state, item) {
                        Ok(out) => {
                            stats.busy += t0.elapsed();
                            if let Err(e) = send_counted(&out_tx, out, &mut blocked) {
                                result = Err(e);
                                break;
                            }
                            stats.items += 1;
                        }
                        Err(e) => {
                            result = Err(e);
                            break;
                        }
                    }
                }
                stats.blocked = blocked;
                store_stage(&m, slot, stats);
                result
            }));
        }
        // Workers hold the only output senders: the channel closes when
        // the last worker exits, not when the distributor does.
        drop(out_tx);
        let upstream = self.head;
        let m = metrics.clone();
        let dist_name = format!("{name}/rr");
        handles.push(thread::spawn_named(format!("ihtc-stage-{dist_name}"), move || {
            let mut stats = StageMetrics { name: dist_name, ..Default::default() };
            let mut busy = Duration::ZERO;
            let mut blocked = Duration::ZERO;
            let mut result = Ok(());
            let mut next = 0usize;
            for item in upstream {
                // Busy covers only the hand-off itself (minus blocked
                // backpressure) — idle recv waits on the upstream must
                // not make the distributor look like the bottleneck.
                let t0 = Instant::now();
                if let Err(e) = send_counted(&worker_txs[next], item, &mut blocked) {
                    result = Err(e);
                    break;
                }
                busy += t0.elapsed();
                stats.items += 1;
                next = (next + 1) % worker_txs.len();
            }
            stats.busy = busy.saturating_sub(blocked);
            stats.blocked = blocked;
            store_stage(&m, dist_slot, stats);
            result
        }));
        PipelineBuilder { capacity: self.capacity, metrics, head: out_rx, handles }
    }

    /// Append a reorder stage: items arriving in any order are parked in
    /// a [`ReorderBuffer`] and released strictly in stream order. `key`
    /// extracts `(offset, extent)` from each item; offsets must tile the
    /// stream from 0 — a gap, duplicate, or overlap is a hard
    /// [`Error::Coordinator`] surfaced through [`Pipeline::join`].
    /// `bound` caps parked items (see [`ReorderBuffer::new`]); size it to
    /// the pipeline's maximum in-flight item count.
    pub fn reorder(
        self,
        name: &str,
        bound: usize,
        key: impl Fn(&T) -> (usize, usize) + Send + 'static,
    ) -> PipelineBuilder<T> {
        self.reorder_from(name, bound, 0, key)
    }

    /// [`Self::reorder`] for a *resumed* stream: the buffer expects the
    /// first arrival at offset `start` (everything below it was released
    /// in an earlier run and replayed from a checkpoint). With
    /// `start = 0` this is exactly `reorder`.
    pub fn reorder_from(
        self,
        name: &str,
        bound: usize,
        start: usize,
        key: impl Fn(&T) -> (usize, usize) + Send + 'static,
    ) -> PipelineBuilder<T> {
        let (tx, rx) = std::sync::mpsc::sync_channel::<T>(self.capacity);
        let slot = register_stage(&self.metrics, name);
        let m = self.metrics.clone();
        let name = name.to_string();
        let upstream = self.head;
        let mut handles = self.handles;
        handles.push(thread::spawn_named(format!("ihtc-stage-{name}"), move || {
            let mut stats = StageMetrics { name, ..Default::default() };
            let mut busy = Duration::ZERO;
            let mut blocked = Duration::ZERO;
            let mut buf = ReorderBuffer::with_start(bound, start);
            let mut result = Ok(());
            'recv: for item in upstream {
                let t0 = Instant::now();
                let (offset, extent) = key(&item);
                if let Err(e) = buf.push(offset, extent, item) {
                    result = Err(e);
                    break;
                }
                while let Some(ready) = buf.pop_ready() {
                    if let Err(e) = send_counted(&tx, ready, &mut blocked) {
                        result = Err(e);
                        break 'recv;
                    }
                    stats.items += 1;
                }
                busy += t0.elapsed();
            }
            if result.is_ok() {
                result = buf.finish();
            }
            stats.busy = busy.saturating_sub(blocked);
            stats.blocked = blocked;
            store_stage(&m, slot, stats);
            result
        }));
        PipelineBuilder { capacity: self.capacity, metrics: self.metrics, head: rx, handles }
    }

    /// Finish building; the caller consumes `output`.
    pub fn build(self) -> Pipeline<T> {
        Pipeline { output: self.head, handles: self.handles, metrics: self.metrics }
    }
}

/// Convenience: run a source→maps pipeline and fold the outputs.
pub fn collect<T: Send + 'static>(p: Pipeline<T>) -> Result<(Vec<T>, Vec<StageMetrics>)> {
    let mut out = Vec::new();
    for item in &p.output {
        out.push(item);
    }
    let metrics = p.join()?;
    Ok((out, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_pipeline_transforms_in_order() {
        let p = PipelineBuilder::source("gen", 2, |emit| {
            for i in 0..100u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map("double", |x| Ok(x * 2))
        .map("plus1", |x| Ok(x + 1))
        .build();
        let (out, metrics) = collect(p).unwrap();
        assert_eq!(out, (0..100u64).map(|i| i * 2 + 1).collect::<Vec<_>>());
        // Metrics come back in source→…→sink order regardless of which
        // stage thread finished first (slots are pre-registered at build
        // time, not pushed on completion).
        let names: Vec<&str> = metrics.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, ["gen", "double", "plus1"]);
        assert!(metrics.iter().all(|m| m.items == 100));
    }

    #[test]
    fn source_counts_only_successful_sends() {
        // Downstream vanishes immediately: not a single emit can land,
        // so the source must report zero items processed — not one per
        // attempted send.
        let p = PipelineBuilder::source("gen", 1, |emit| {
            for i in 0..10u64 {
                emit(i)?;
            }
            Ok(())
        })
        .build();
        let Pipeline { output, handles, metrics } = p;
        drop(output);
        for h in handles {
            assert!(h.join().unwrap().is_err(), "source must see the hang-up");
        }
        let m = metrics.lock().unwrap();
        let gen = m.iter().find(|s| s.name == "gen").unwrap();
        assert_eq!(gen.items, 0, "no send succeeded, so no item was processed");
    }

    #[test]
    fn map_init_counts_only_successful_sends() {
        // The map stage transforms one item fine but its downstream is
        // gone — the item must not count as processed.
        let p = PipelineBuilder::source("gen", 1, |emit| {
            emit(1u64)?;
            Ok(())
        })
        .map_init("id", || (), |_, x: u64| Ok(x))
        .build();
        let Pipeline { output, handles, metrics } = p;
        drop(output);
        for h in handles {
            let _ = h.join().unwrap();
        }
        let m = metrics.lock().unwrap();
        let id = m.iter().find(|s| s.name == "id").unwrap();
        assert_eq!(id.items, 0, "send failed, so the item was not processed");
    }

    #[test]
    fn map_init_parallel_processes_everything() {
        // 3 concurrent stage threads, per-stage state counting its own
        // items: all inputs come out (order not guaranteed), per-stage
        // metrics are pre-registered in topological order, and the
        // distributor's round-robin spreads items across every stage.
        let p = PipelineBuilder::source("gen", 2, |emit| {
            for i in 0..99u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map_init_parallel("par", 3, || 0u64, |seen, x| {
            *seen += 1;
            Ok(x * 2)
        })
        .build();
        let (mut out, metrics) = collect(p).unwrap();
        out.sort_unstable();
        assert_eq!(out, (0..99u64).map(|i| i * 2).collect::<Vec<_>>());
        let names: Vec<&str> = metrics.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, ["gen", "par/rr", "par/0", "par/1", "par/2"]);
        let rr = metrics.iter().find(|m| m.name == "par/rr").unwrap();
        assert_eq!(rr.items, 99);
        let worker_total: usize =
            metrics.iter().filter(|m| m.name.starts_with("par/") && m.name != "par/rr")
                .map(|m| m.items)
                .sum();
        assert_eq!(worker_total, 99);
        // Round-robin distribution: every stage saw exactly a third.
        assert!(metrics
            .iter()
            .filter(|m| m.name.starts_with("par/") && m.name != "par/rr")
            .all(|m| m.items == 33));
    }

    #[test]
    fn map_init_parallel_reorder_restores_stream_order() {
        // Workers sleep a value-dependent amount so completion order is
        // scrambled; the reorder stage must still release items strictly
        // in stream order (offset = item index, extent 1).
        let p = PipelineBuilder::source("gen", 2, |emit| {
            for i in 0..40u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map_init_parallel("par", 4, || (), |_, x: u64| {
            std::thread::sleep(Duration::from_millis((x * 7) % 5));
            Ok(x)
        })
        .reorder("reorder", 64, |x: &u64| (*x as usize, 1))
        .build();
        let (out, metrics) = collect(p).unwrap();
        assert_eq!(out, (0..40u64).collect::<Vec<_>>());
        let ro = metrics.iter().find(|m| m.name == "reorder").unwrap();
        assert_eq!(ro.items, 40);
    }

    #[test]
    fn parallel_stage_error_is_root_cause() {
        // One of several siblings fails; the distributor and source
        // report hang-up symptoms, the surviving siblings drain cleanly —
        // join must surface the failing sibling's own error.
        let p = PipelineBuilder::source("gen", 1, |emit| {
            for i in 0..50u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map_init_parallel("par", 3, || (), |_, x: u64| {
            if x == 7 {
                Err(Error::Data("poison shard".into()))
            } else {
                Ok(x)
            }
        })
        .build();
        let err = collect(p).unwrap_err();
        assert!(err.to_string().contains("poison shard"), "{err}");
    }

    #[test]
    fn source_error_with_parallel_stages_is_root_cause() {
        // The source dies mid-stream while several reduce stages are
        // still draining: the stage threads and distributor see their
        // channels close and report hang-up symptoms — join must surface
        // the source's own error, for every fan-out width.
        for stages in [2usize, 4] {
            let p = PipelineBuilder::source("gen", 1, |emit| {
                for i in 0..20u64 {
                    emit(i)?;
                }
                Err(Error::Data("source torn mid-stream".into()))
            })
            .map_init_parallel("par", stages, || (), |_, x: u64| Ok(x))
            .reorder("reorder", 64, |x: &u64| (*x as usize, 1))
            .build();
            let err = collect(p).unwrap_err();
            assert!(matches!(err, Error::Data(_)), "stages={stages}: {err}");
            assert!(err.to_string().contains("source torn mid-stream"), "stages={stages}: {err}");
        }
    }

    #[test]
    fn reorder_from_resumes_mid_stream() {
        // A resumed stream starts at the checkpoint row, not 0: the
        // buffer releases [30, 70) in order, and an arrival below the
        // start offset is the usual duplicate/overlap hard error.
        let p = PipelineBuilder::source("gen", 2, |emit| {
            for i in (30..70u64).rev() {
                emit(i)?;
            }
            Ok(())
        })
        .map_init_parallel("par", 3, || (), |_, x: u64| Ok(x))
        .reorder_from("reorder", 64, 30, |x: &u64| (*x as usize, 1))
        .build();
        let (out, _) = collect(p).unwrap();
        assert_eq!(out, (30..70u64).collect::<Vec<_>>());

        let mut buf = ReorderBuffer::with_start(8, 30);
        assert!(buf.push(10, 5, ()).is_err(), "pre-start arrival must be rejected");
        buf.push(30, 5, ()).unwrap();
        assert!(buf.pop_ready().is_some());
        assert_eq!(buf.released_through(), 35);
        buf.finish().unwrap();
    }

    #[test]
    fn reorder_gap_is_hard_error_through_join() {
        // Offset 5 never arrives: the stream ends with a parked shard and
        // the reorder stage must fail join() with the gap as root cause —
        // in a release build just as in debug (no debug_assert guards).
        let p = PipelineBuilder::source("gen", 2, |emit| {
            emit((0usize, 5usize))?;
            emit((10usize, 5usize))?;
            Ok(())
        })
        .reorder("reorder", 16, |x: &(usize, usize)| (x.0, x.1))
        .build();
        let err = collect(p).unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)), "{err}");
        assert!(err.to_string().contains("gap"), "{err}");
    }

    #[test]
    fn reorder_duplicate_offset_is_hard_error_through_join() {
        let p = PipelineBuilder::source("gen", 2, |emit| {
            emit((0usize, 5usize))?;
            emit((5usize, 5usize))?;
            emit((5usize, 5usize))?;
            Ok(())
        })
        .reorder("reorder", 16, |x: &(usize, usize)| (x.0, x.1))
        .build();
        let err = collect(p).unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)), "{err}");
        assert!(
            err.to_string().contains("duplicate") || err.to_string().contains("overlap"),
            "{err}"
        );
    }

    #[test]
    fn reorder_overlap_is_hard_error() {
        let p = PipelineBuilder::source("gen", 2, |emit| {
            emit((0usize, 8usize))?;
            emit((4usize, 8usize))?;
            Ok(())
        })
        .reorder("reorder", 16, |x: &(usize, usize)| (x.0, x.1))
        .build();
        let err = collect(p).unwrap_err();
        assert!(err.to_string().contains("overlap"), "{err}");
    }

    #[test]
    fn reorder_buffer_property_shuffled_arrivals() {
        // Property: for any seeded shuffle of a tiling shard stream, the
        // buffer releases exactly the in-order sequence.
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(0xBEEF);
        for trial in 0..50u64 {
            // Random tiling: offsets 0..total in random-size steps.
            let mut shards = Vec::new();
            let mut off = 0usize;
            while off < 500 {
                let extent = 1 + (rng.next_below(9) as usize);
                shards.push((off, extent.min(500 - off)));
                off += extent.min(500 - off);
            }
            let mut shuffled = shards.clone();
            rng.shuffle(&mut shuffled);
            let mut buf = ReorderBuffer::new(shards.len());
            let mut released = Vec::new();
            for &(o, e) in &shuffled {
                buf.push(o, e, (o, e)).unwrap_or_else(|err| {
                    panic!("trial {trial}: push({o},{e}) failed: {err}")
                });
                while let Some(item) = buf.pop_ready() {
                    released.push(item);
                }
            }
            buf.finish().unwrap();
            assert_eq!(released, shards, "trial {trial}");
            assert_eq!(buf.released_through(), 500);
        }
    }

    #[test]
    fn reorder_buffer_rejects_bad_streams() {
        // Duplicate.
        let mut buf = ReorderBuffer::new(8);
        buf.push(0, 4, ()).unwrap();
        assert!(buf.push(0, 4, ()).is_err());
        // Overlap with a parked shard.
        let mut buf = ReorderBuffer::new(8);
        buf.push(8, 4, ()).unwrap();
        assert!(buf.push(6, 4, ()).is_err());
        assert!(buf.push(10, 4, ()).is_err());
        // Arrival below the released watermark.
        let mut buf = ReorderBuffer::new(8);
        buf.push(0, 4, ()).unwrap();
        assert!(buf.pop_ready().is_some());
        assert!(buf.push(2, 2, ()).is_err());
        // Zero extent.
        let mut buf = ReorderBuffer::<()>::new(8);
        assert!(buf.push(0, 0, ()).is_err());
        // Overflow: bound 2, three parked out-of-order items.
        let mut buf = ReorderBuffer::new(2);
        buf.push(10, 1, ()).unwrap();
        buf.push(20, 1, ()).unwrap();
        let err = buf.push(30, 1, ()).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
        // …but the in-order arrival is never charged against the bound:
        // a tiling stream sized exactly to the cap must drain cleanly.
        let mut buf = ReorderBuffer::new(1);
        buf.push(1, 1, ()).unwrap(); // the one allowed parked item
        buf.push(0, 1, ()).unwrap(); // in-order: releases 0 then 1
        assert!(buf.pop_ready().is_some());
        assert!(buf.pop_ready().is_some());
        buf.finish().unwrap();
        // Gap at end of stream.
        let mut buf = ReorderBuffer::new(8);
        buf.push(4, 4, ()).unwrap();
        assert!(buf.pop_ready().is_none());
        let err = buf.finish().unwrap_err();
        assert!(err.to_string().contains("gap"), "{err}");
    }

    #[test]
    fn backpressure_blocks_producer() {
        // Slow consumer + capacity 1 → the source records blocked time.
        let p = PipelineBuilder::source("fast", 1, |emit| {
            for i in 0..20u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map("slow", |x| {
            std::thread::sleep(Duration::from_millis(3));
            Ok(x)
        })
        .build();
        let (_, metrics) = collect(p).unwrap();
        let source = metrics.iter().find(|m| m.name == "fast").unwrap();
        assert!(
            source.blocked > Duration::from_millis(10),
            "expected backpressure, blocked={:?}",
            source.blocked
        );
    }

    #[test]
    fn stage_error_propagates() {
        let p = PipelineBuilder::source("gen", 2, |emit| {
            for i in 0..10u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map("explode", |x| {
            if x == 5 {
                Err(Error::Coordinator("kaboom".into()))
            } else {
                Ok(x)
            }
        })
        .build();
        // The root cause must surface verbatim — the upstream source's
        // "downstream stage hung up" symptom must never mask it.
        let err = collect(p).unwrap_err();
        assert!(err.to_string().contains("kaboom"), "{err}");
    }

    #[test]
    fn mid_stage_error_is_root_cause() {
        // A failure in the *middle* of a three-stage chain: the source
        // blocks on a full queue and reports a hang-up, the downstream
        // stage drains and finishes cleanly — join must still surface
        // the failing stage's own error.
        let p = PipelineBuilder::source("gen", 1, |emit| {
            for i in 0..100u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map("pre", |x| Ok(x + 1))
        .map("explode", |x| {
            if x == 4 {
                Err(Error::Data("bad shard".into()))
            } else {
                Ok(x)
            }
        })
        .map("post", Ok)
        .build();
        let err = collect(p).unwrap_err();
        assert!(err.to_string().contains("bad shard"), "{err}");
    }

    #[test]
    fn map_init_state_persists_across_items() {
        // The stage state is built once on the stage thread and reused
        // for every item (running sum ⇒ order and persistence).
        let p = PipelineBuilder::source("gen", 2, |emit| {
            for i in 1..=10u64 {
                emit(i)?;
            }
            Ok(())
        })
        .map_init(
            "acc",
            || 0u64,
            |acc, x| {
                *acc += x;
                Ok(*acc)
            },
        )
        .build();
        let (out, metrics) = collect(p).unwrap();
        let want: Vec<u64> = (1..=10u64).scan(0, |s, x| {
            *s += x;
            Some(*s)
        })
        .collect();
        assert_eq!(out, want);
        assert!(metrics.iter().any(|m| m.name == "acc" && m.items == 10));
    }

    #[test]
    fn source_error_propagates() {
        let p = PipelineBuilder::source("bad", 2, |emit| {
            emit(1u64)?;
            Err(Error::Coordinator("source died".into()))
        })
        .map("id", Ok)
        .build();
        assert!(collect(p).is_err());
    }

    #[test]
    fn throughput_metric_sane() {
        let m = StageMetrics {
            name: "x".into(),
            items: 100,
            busy: Duration::from_secs(2),
            blocked: Duration::ZERO,
        };
        assert!((m.throughput() - 50.0).abs() < 1e-9);
    }
}
