//! L3 streaming coordinator.
//!
//! The paper's §3.1 closes by noting that "the computation required of
//! ITIS may be drastically improved through the discovery of methods for
//! parallelization of threshold clustering". This module is that system:
//! a data-pipeline orchestrator that
//!
//! * streams the dataset through bounded-channel **stages** with real
//!   backpressure ([`pipeline`]),
//! * shards the k-NN graph construction — the computational bottleneck of
//!   ITIS — across a **work-stealing worker pool** ([`WorkerPool`],
//!   [`parallel_knn`]) with exact (not approximate) results,
//! * runs the whole IHTC flow end-to-end from a config ([`driver`]),
//!   collecting per-stage metrics.
//!
//! Threading is std-only (no tokio offline): scoped threads, `sync_channel`
//! for bounded queues, an atomic cursor for stealing. The PJRT engine is
//! kept on the coordinator thread (the xla handles are not `Sync`);
//! native workers absorb the parallel sections.

pub mod driver;
pub mod pipeline;

use crate::itis::KnnProvider;
use crate::knn::{forest::KdForest, kdtree::KdTree, KnnLists};
use crate::linalg::Matrix;
use crate::{Error, Result};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

/// Resolve a worker-count setting (0 = available parallelism − 1, min 1).
pub fn resolve_workers(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    std::thread::available_parallelism()
        .map(|p| p.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

/// A work-stealing parallel-for over chunked index ranges.
///
/// Workers repeatedly claim the next chunk via an atomic cursor — cheap,
/// contention-free rebalancing that keeps stragglers from stalling the
/// pipeline (dense regions of the kd-tree cost more per query than
/// sparse ones).
pub struct WorkerPool {
    workers: usize,
}

impl Default for WorkerPool {
    /// Pool sized to the machine (available parallelism − 1, min 1) —
    /// what `knn_auto`, `Ihtc::run`, and `itis` use when the caller does
    /// not pass a pool explicitly.
    fn default() -> Self {
        Self::new(0)
    }
}

impl WorkerPool {
    /// Create a pool descriptor (threads are scoped per call).
    pub fn new(workers: usize) -> Self {
        Self { workers: resolve_workers(workers) }
    }

    /// Number of worker threads used.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Work-stealing execution of pre-built tasks (each typically owning
    /// disjoint `&mut` windows of a shared output buffer, so workers
    /// write results in place — no stitch copies). Results come back in
    /// task order; the first task error aborts the run and is returned.
    pub fn run_tasks<T: Send, R: Send>(
        &self,
        tasks: Vec<T>,
        f: impl Fn(T) -> Result<R> + Sync,
    ) -> Result<Vec<R>> {
        let n = tasks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let slots: Vec<Mutex<Option<T>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let results: Vec<Mutex<Option<Result<R>>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let cursor = AtomicUsize::new(0);
        let failed = AtomicBool::new(false);
        std::thread::scope(|scope| {
            for _ in 0..self.workers.min(n).max(1) {
                let cursor = &cursor;
                let failed = &failed;
                let slots = &slots;
                let results = &results;
                let f = &f;
                scope.spawn(move || loop {
                    if failed.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let task = slots[i].lock().unwrap().take();
                    let Some(task) = task else { continue };
                    let out = f(task);
                    if out.is_err() {
                        failed.store(true, Ordering::Relaxed);
                    }
                    *results[i].lock().unwrap() = Some(out);
                });
            }
        });
        let mut out = Vec::with_capacity(n);
        let mut first_err = None;
        for slot in results {
            match slot.into_inner().unwrap() {
                Some(Ok(v)) => out.push(v),
                Some(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                None => {}
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        if out.len() != n {
            return Err(Error::Coordinator("worker pool lost tasks".into()));
        }
        Ok(out)
    }

    /// Process `0..n` in chunks of `chunk`; `f(start, end)` produces a
    /// partial result collected into the output vector (in arbitrary
    /// order). Errors from any worker abort the call.
    pub fn run_chunks<T: Send>(
        &self,
        n: usize,
        chunk: usize,
        f: impl Fn(usize, usize) -> Result<T> + Sync,
    ) -> Result<Vec<T>> {
        let chunk = chunk.max(1);
        let cursor = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<Result<T>>();
        std::thread::scope(|scope| {
            for _ in 0..self.workers {
                let tx = tx.clone();
                let cursor = &cursor;
                let f = &f;
                scope.spawn(move || loop {
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    let out = f(start, end);
                    let failed = out.is_err();
                    if tx.send(out).is_err() || failed {
                        break;
                    }
                });
            }
            drop(tx);
            let mut results = Vec::new();
            for item in rx {
                results.push(item?);
            }
            Ok(results)
        })
    }
}

/// Exact k-NN lists computed by sharding queries across the pool against
/// a shared kd-tree (itself built in parallel over the pool). Output is
/// byte-identical to [`crate::knn::knn_brute`] for any worker count, but
/// wall-clock scales with workers; this is the coordinator's answer to
/// the paper's "parallelize TC" future work (step 1 dominates).
pub fn parallel_knn(points: &Matrix, k: usize, pool: &WorkerPool) -> Result<KnnLists> {
    let mut out = KnnLists::default();
    parallel_knn_into(points, k, pool, &mut out)?;
    Ok(out)
}

/// [`parallel_knn`] writing into a reusable output buffer: workers fill
/// disjoint row windows of `out` directly (no per-shard buffers, no
/// stitch copy), which is what the ITIS loop reuses across iterations.
pub fn parallel_knn_into(
    points: &Matrix,
    k: usize,
    pool: &WorkerPool,
    out: &mut KnnLists,
) -> Result<()> {
    let n = points.rows();
    crate::knn::validate_k(n, k)?;
    let tree = KdTree::build_parallel(points, pool);
    tree.knn_all_pool_into(points, k, pool, out)
}

/// [`KnnProvider`] backed by the worker pool — the injection point that
/// routes the entire ITIS/IHTC reduction through pool-sharded k-NN.
/// With `shards > 1` the kd-tree regime runs on a sharded
/// [`KdForest`] (per-shard parallel construction, merged queries);
/// `shards: 1` is the single-tree path, byte for byte.
pub struct PoolKnnProvider<'a> {
    /// The pool to shard over.
    pub pool: &'a WorkerPool,
    /// kd-forest shard count for the k-NN index (1 = single tree; the
    /// config knob `knn_shards`).
    pub shards: usize,
}

impl KnnProvider for PoolKnnProvider<'_> {
    fn knn(&self, points: &Matrix, k: usize) -> Result<KnnLists> {
        let mut out = KnnLists::default();
        self.knn_into(points, k, &mut out)?;
        Ok(out)
    }

    fn knn_into(&self, points: &Matrix, k: usize, out: &mut KnnLists) -> Result<()> {
        // Workspace-less path (`&self`, nowhere to keep the shard trees):
        // the forest is built for this call and dropped. Construction is
        // still shard-parallel, but arena reuse needs the caller-held
        // forest of `knn_forest_into` — which is what the ITIS loop uses;
        // this path serves one-shot callers and the PJRT fallback.
        let mut forest = KdForest::new();
        crate::knn::knn_auto_sharded_into(points, k, self.shards, self.pool, &mut forest, out)
    }

    fn knn_forest_into(
        &self,
        points: &Matrix,
        k: usize,
        forest: &mut KdForest,
        out: &mut KnnLists,
    ) -> Result<()> {
        crate::knn::knn_auto_sharded_into(points, k, self.shards, self.pool, forest, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::knn::knn_brute;

    #[test]
    fn resolve_workers_bounds() {
        assert_eq!(resolve_workers(3), 3);
        assert!(resolve_workers(0) >= 1);
    }

    #[test]
    fn run_chunks_covers_all_indices() {
        let pool = WorkerPool::new(4);
        let parts = pool
            .run_chunks(1003, 100, |s, e| Ok((s, e)))
            .unwrap();
        let mut covered = vec![false; 1003];
        for (s, e) in parts {
            for slot in covered.iter_mut().take(e).skip(s) {
                assert!(!*slot, "overlap at {s}..{e}");
                *slot = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn run_tasks_preserves_order_and_runs_all() {
        let pool = WorkerPool::new(4);
        let tasks: Vec<usize> = (0..37).collect();
        let out = pool.run_tasks(tasks, |t| Ok(t * 2)).unwrap();
        assert_eq!(out, (0..37).map(|t| t * 2).collect::<Vec<_>>());
        // Empty task lists are a no-op.
        let empty: Vec<usize> = Vec::new();
        assert!(pool.run_tasks(empty, |t| Ok(t)).unwrap().is_empty());
    }

    #[test]
    fn run_tasks_writes_through_mut_slices() {
        let pool = WorkerPool::new(3);
        let mut buf = vec![0u32; 100];
        let tasks: Vec<(usize, &mut [u32])> =
            buf.chunks_mut(7).enumerate().map(|(i, c)| (i * 7, c)).collect();
        pool.run_tasks(tasks, |(start, chunk)| {
            for (o, slot) in chunk.iter_mut().enumerate() {
                *slot = (start + o) as u32;
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(buf, (0..100u32).collect::<Vec<_>>());
    }

    #[test]
    fn run_tasks_propagates_errors() {
        let pool = WorkerPool::new(2);
        let res = pool.run_tasks((0..50usize).collect(), |t| {
            if t == 13 {
                Err(Error::Coordinator("boom".into()))
            } else {
                Ok(t)
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn run_chunks_propagates_errors() {
        let pool = WorkerPool::new(2);
        let res: Result<Vec<()>> = pool.run_chunks(100, 10, |s, _| {
            if s >= 50 {
                Err(Error::Coordinator("boom".into()))
            } else {
                Ok(())
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn parallel_knn_matches_serial() {
        let ds = gaussian_mixture_paper(1500, 201);
        let serial = knn_brute(&ds.points, 4).unwrap();
        let pool = WorkerPool::new(4);
        let par = parallel_knn(&ds.points, 4, &pool).unwrap();
        for i in 0..1500 {
            let a = serial.distances(i);
            let b = par.distances(i);
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() <= 1e-4 * (1.0 + x.abs()), "row {i}");
            }
        }
    }

    #[test]
    fn parallel_knn_single_worker_ok() {
        let ds = gaussian_mixture_paper(300, 202);
        let pool = WorkerPool::new(1);
        let r = parallel_knn(&ds.points, 2, &pool).unwrap();
        assert_eq!(r.len(), 300);
    }
}
