//! L3 streaming coordinator.
//!
//! The paper's §3.1 closes by noting that "the computation required of
//! ITIS may be drastically improved through the discovery of methods for
//! parallelization of threshold clustering". This module is that system:
//! a data-pipeline orchestrator that
//!
//! * streams the dataset through bounded-channel **stages** with real
//!   backpressure ([`pipeline`]),
//! * shards the k-NN graph construction — the computational bottleneck of
//!   ITIS — across the run's **shared work-stealing executor**
//!   ([`crate::exec::Executor`], [`parallel_knn`]) with exact (not
//!   approximate) results,
//! * runs the whole IHTC flow end-to-end from a config ([`driver`]),
//!   collecting per-stage metrics.
//!
//! Threading is std-only (no tokio offline): one persistent executor per
//! run, `sync_channel` for bounded queues, an atomic cursor for
//! stealing. The PJRT engine is kept on the coordinator thread (the xla
//! handles are not `Sync`); executor workers absorb the parallel
//! sections.

pub mod driver;
pub mod pipeline;

use crate::exec::Executor;
use crate::itis::KnnProvider;
use crate::knn::{forest::KdForest, kdtree::KdTree, KnnLists};
use crate::linalg::Matrix;
use crate::Result;

pub use crate::exec::resolve_workers;

/// Deprecated shim over [`crate::exec::Executor`].
///
/// Until the shared-executor refactor, every parallel call site spawned
/// its own scoped thread team through this type. The executor subsumes
/// it: one persistent work-stealing team per run, shared by every layer.
/// The shim keeps out-of-tree `run_tasks`/`run_chunks` callers
/// compiling for one more release — it owns a private `Executor` and
/// forwards. Two caveats for such callers: (1) the cost model changed —
/// the old type was a plain descriptor that spawned scoped threads per
/// call, while constructing this shim now spawns `workers − 1`
/// persistent threads and joins them on drop, so build one and reuse it
/// rather than constructing per call; (2) every in-tree API that used
/// to accept `&WorkerPool` (`parallel_knn`, `itis_with_workspace`,
/// `kmeans_pool`, `Ihtc::run_with`, …) now takes `&Executor`, so
/// callers of those must migrate regardless. New code should construct
/// an [`Executor::new`] / [`Executor::with_config`] directly.
#[deprecated(
    note = "use crate::exec::Executor — one shared work-stealing executor per run; \
            WorkerPool is a forwarding shim and will be removed"
)]
pub struct WorkerPool {
    exec: Executor,
}

#[allow(deprecated)]
impl Default for WorkerPool {
    /// Pool sized to the machine (available parallelism − 1, min 1).
    fn default() -> Self {
        Self::new(0)
    }
}

#[allow(deprecated)]
impl WorkerPool {
    /// Create a pool (now: a private [`Executor`]) with `workers`
    /// threads (0 = machine default).
    pub fn new(workers: usize) -> Self {
        Self { exec: Executor::new(workers) }
    }

    /// Number of worker threads used.
    pub fn workers(&self) -> usize {
        self.exec.workers()
    }

    /// Borrow the backing executor (migration hook for callers moving
    /// off the shim).
    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    /// Forwarded to [`Executor::run_tasks`].
    pub fn run_tasks<T: Send, R: Send>(
        &self,
        tasks: Vec<T>,
        f: impl Fn(T) -> Result<R> + Sync,
    ) -> Result<Vec<R>> {
        self.exec.run_tasks(tasks, f)
    }

    /// Forwarded to [`Executor::run_chunks`].
    pub fn run_chunks<T: Send>(
        &self,
        n: usize,
        chunk: usize,
        f: impl Fn(usize, usize) -> Result<T> + Sync,
    ) -> Result<Vec<T>> {
        self.exec.run_chunks(n, chunk, f)
    }
}

/// Exact k-NN lists computed by sharding queries across the executor
/// against a shared kd-tree (itself built in parallel on the executor).
/// Output is byte-identical to [`crate::knn::knn_brute`] for any worker
/// count, but wall-clock scales with workers; this is the coordinator's
/// answer to the paper's "parallelize TC" future work (step 1 dominates).
pub fn parallel_knn(points: &Matrix, k: usize, exec: &Executor) -> Result<KnnLists> {
    let mut out = KnnLists::default();
    parallel_knn_into(points, k, exec, &mut out)?;
    Ok(out)
}

/// [`parallel_knn`] writing into a reusable output buffer: workers fill
/// disjoint row windows of `out` directly (no per-shard buffers, no
/// stitch copy), which is what the ITIS loop reuses across iterations.
pub fn parallel_knn_into(
    points: &Matrix,
    k: usize,
    exec: &Executor,
    out: &mut KnnLists,
) -> Result<()> {
    let n = points.rows();
    crate::knn::validate_k(n, k)?;
    let tree = KdTree::build_parallel(points, exec);
    tree.knn_all_pool_into(points, k, exec, out)
}

/// [`KnnProvider`] backed by the shared executor — the injection point
/// that routes the entire ITIS/IHTC reduction through executor-sharded
/// k-NN. With `shards > 1` the kd-tree regime runs on a sharded
/// [`KdForest`] (per-shard parallel construction, merged queries);
/// `shards: 1` is the single-tree path, byte for byte.
pub struct PoolKnnProvider<'a> {
    /// The run's shared executor.
    pub exec: &'a Executor,
    /// kd-forest shard count for the k-NN index (1 = single tree; the
    /// config knob `knn_shards`).
    pub shards: usize,
}

impl KnnProvider for PoolKnnProvider<'_> {
    fn knn(&self, points: &Matrix, k: usize) -> Result<KnnLists> {
        let mut out = KnnLists::default();
        self.knn_into(points, k, &mut out)?;
        Ok(out)
    }

    fn knn_into(&self, points: &Matrix, k: usize, out: &mut KnnLists) -> Result<()> {
        // Workspace-less path (`&self`, nowhere to keep the shard trees):
        // the forest is built for this call and dropped. Construction is
        // still shard-parallel, but arena reuse needs the caller-held
        // forest of `knn_forest_into` — which is what the ITIS loop uses;
        // this path serves one-shot callers and the PJRT fallback.
        let mut forest = KdForest::new();
        crate::knn::knn_auto_sharded_into(points, k, self.shards, self.exec, &mut forest, out)
    }

    fn knn_forest_into(
        &self,
        points: &Matrix,
        k: usize,
        forest: &mut KdForest,
        out: &mut KnnLists,
    ) -> Result<()> {
        crate::knn::knn_auto_sharded_into(points, k, self.shards, self.exec, forest, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::knn::knn_brute;

    #[test]
    fn parallel_knn_matches_serial() {
        let ds = gaussian_mixture_paper(1500, 201);
        let serial = knn_brute(&ds.points, 4).unwrap();
        let exec = Executor::new(4);
        let par = parallel_knn(&ds.points, 4, &exec).unwrap();
        for i in 0..1500 {
            let a = serial.distances(i);
            let b = par.distances(i);
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() <= 1e-4 * (1.0 + x.abs()), "row {i}");
            }
        }
    }

    #[test]
    fn parallel_knn_single_worker_ok() {
        let ds = gaussian_mixture_paper(300, 202);
        let exec = Executor::new(1);
        let r = parallel_knn(&ds.points, 2, &exec).unwrap();
        assert_eq!(r.len(), 300);
    }

    #[test]
    #[allow(deprecated)]
    fn worker_pool_shim_forwards_to_the_executor() {
        // The deprecated shim must stay a pure forwarding layer: same
        // results, same ordering contract, same error propagation.
        let pool = WorkerPool::new(3);
        assert_eq!(pool.workers(), 3);
        assert_eq!(pool.executor().workers(), 3);
        let out = pool.run_tasks((0..37usize).collect(), |t| Ok(t * 2)).unwrap();
        assert_eq!(out, (0..37).map(|t| t * 2).collect::<Vec<_>>());
        let parts = pool.run_chunks(100, 7, |s, e| Ok(e - s)).unwrap();
        assert_eq!(parts.iter().sum::<usize>(), 100);
        assert!(pool
            .run_tasks((0..5usize).collect(), |t| {
                if t == 3 {
                    Err(crate::Error::Coordinator("boom".into()))
                } else {
                    Ok(t)
                }
            })
            .is_err());
    }
}
