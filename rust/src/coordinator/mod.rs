//! L3 streaming coordinator.
//!
//! The paper's §3.1 closes by noting that "the computation required of
//! ITIS may be drastically improved through the discovery of methods for
//! parallelization of threshold clustering". This module is that system:
//! a data-pipeline orchestrator that
//!
//! * streams the dataset through bounded-channel **stages** with real
//!   backpressure ([`pipeline`]),
//! * shards the k-NN graph construction — the computational bottleneck of
//!   ITIS — across a **work-stealing worker pool** ([`WorkerPool`],
//!   [`parallel_knn`]) with exact (not approximate) results,
//! * runs the whole IHTC flow end-to-end from a config ([`driver`]),
//!   collecting per-stage metrics.
//!
//! Threading is std-only (no tokio offline): scoped threads, `sync_channel`
//! for bounded queues, an atomic cursor for stealing. The PJRT engine is
//! kept on the coordinator thread (the xla handles are not `Sync`);
//! native workers absorb the parallel sections.

pub mod driver;
pub mod pipeline;

use crate::knn::{kdtree::KdTree, KnnLists};
use crate::linalg::Matrix;
use crate::{Error, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Resolve a worker-count setting (0 = available parallelism − 1, min 1).
pub fn resolve_workers(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    std::thread::available_parallelism()
        .map(|p| p.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

/// A work-stealing parallel-for over chunked index ranges.
///
/// Workers repeatedly claim the next chunk via an atomic cursor — cheap,
/// contention-free rebalancing that keeps stragglers from stalling the
/// pipeline (dense regions of the kd-tree cost more per query than
/// sparse ones).
pub struct WorkerPool {
    workers: usize,
}

impl WorkerPool {
    /// Create a pool descriptor (threads are scoped per call).
    pub fn new(workers: usize) -> Self {
        Self { workers: resolve_workers(workers) }
    }

    /// Number of worker threads used.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Process `0..n` in chunks of `chunk`; `f(start, end)` produces a
    /// partial result collected into the output vector (in arbitrary
    /// order). Errors from any worker abort the call.
    pub fn run_chunks<T: Send>(
        &self,
        n: usize,
        chunk: usize,
        f: impl Fn(usize, usize) -> Result<T> + Sync,
    ) -> Result<Vec<T>> {
        let chunk = chunk.max(1);
        let cursor = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<Result<T>>();
        std::thread::scope(|scope| {
            for _ in 0..self.workers {
                let tx = tx.clone();
                let cursor = &cursor;
                let f = &f;
                scope.spawn(move || loop {
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    let out = f(start, end);
                    let failed = out.is_err();
                    if tx.send(out).is_err() || failed {
                        break;
                    }
                });
            }
            drop(tx);
            let mut results = Vec::new();
            for item in rx {
                results.push(item?);
            }
            Ok(results)
        })
    }
}

/// Exact k-NN lists computed by sharding queries across the pool against
/// a shared kd-tree. Identical output to [`crate::knn::knn_auto`], but
/// wall-clock scales with workers; this is the coordinator's answer to
/// the paper's "parallelize TC" future work (step 1 dominates).
pub fn parallel_knn(points: &Matrix, k: usize, pool: &WorkerPool) -> Result<KnnLists> {
    let n = points.rows();
    if k == 0 || k >= n {
        return Err(Error::InvalidArgument(format!("need 0 < k < n (k={k}, n={n})")));
    }
    let tree = KdTree::build(points);
    let chunk = 512usize;
    let parts = pool.run_chunks(n, chunk, |start, end| {
        let lists = tree.knn_range(points, k, start, end)?;
        Ok((start, lists.indices, lists.dists))
    })?;
    let mut indices = vec![0u32; n * k];
    let mut dists = vec![0f32; n * k];
    for (start, idx, dst) in parts {
        indices[start * k..start * k + idx.len()].copy_from_slice(&idx);
        dists[start * k..start * k + dst.len()].copy_from_slice(&dst);
    }
    Ok(KnnLists { k, indices, dists })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::knn::knn_brute;

    #[test]
    fn resolve_workers_bounds() {
        assert_eq!(resolve_workers(3), 3);
        assert!(resolve_workers(0) >= 1);
    }

    #[test]
    fn run_chunks_covers_all_indices() {
        let pool = WorkerPool::new(4);
        let parts = pool
            .run_chunks(1003, 100, |s, e| Ok((s, e)))
            .unwrap();
        let mut covered = vec![false; 1003];
        for (s, e) in parts {
            for slot in covered.iter_mut().take(e).skip(s) {
                assert!(!*slot, "overlap at {s}..{e}");
                *slot = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn run_chunks_propagates_errors() {
        let pool = WorkerPool::new(2);
        let res: Result<Vec<()>> = pool.run_chunks(100, 10, |s, _| {
            if s >= 50 {
                Err(Error::Coordinator("boom".into()))
            } else {
                Ok(())
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn parallel_knn_matches_serial() {
        let ds = gaussian_mixture_paper(1500, 201);
        let serial = knn_brute(&ds.points, 4).unwrap();
        let pool = WorkerPool::new(4);
        let par = parallel_knn(&ds.points, 4, &pool).unwrap();
        for i in 0..1500 {
            let a = serial.distances(i);
            let b = par.distances(i);
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() <= 1e-4 * (1.0 + x.abs()), "row {i}");
            }
        }
    }

    #[test]
    fn parallel_knn_single_worker_ok() {
        let ds = gaussian_mixture_paper(300, 202);
        let pool = WorkerPool::new(1);
        let r = parallel_knn(&ds.points, 2, &pool).unwrap();
        assert_eq!(r.len(), 300);
    }
}
