//! L3 streaming coordinator.
//!
//! The paper's §3.1 closes by noting that "the computation required of
//! ITIS may be drastically improved through the discovery of methods for
//! parallelization of threshold clustering". This module is that system:
//! a data-pipeline orchestrator that
//!
//! * streams the dataset through bounded-channel **stages** with real
//!   backpressure ([`pipeline`]),
//! * shards the k-NN graph construction — the computational bottleneck of
//!   ITIS — across the run's **shared work-stealing executor**
//!   ([`crate::exec::Executor`], [`parallel_knn`]) with exact (not
//!   approximate) results,
//! * runs the whole IHTC flow end-to-end from a config ([`driver`]),
//!   collecting per-stage metrics.
//!
//! Threading is std-only (no tokio offline): one persistent executor per
//! run, `sync_channel` for bounded queues, an atomic cursor for
//! stealing. The PJRT engine is kept on the coordinator thread (the xla
//! handles are not `Sync`); executor workers absorb the parallel
//! sections.

pub mod driver;
pub mod pipeline;

use crate::exec::Executor;
use crate::itis::KnnProvider;
use crate::knn::{forest::KdForest, kdtree::KdTree, KnnLists};
use crate::linalg::Matrix;
use crate::Result;

pub use crate::exec::resolve_workers;

/// Exact k-NN lists computed by sharding queries across the executor
/// against a shared kd-tree (itself built in parallel on the executor).
/// Output is byte-identical to [`crate::knn::knn_brute`] for any worker
/// count, but wall-clock scales with workers; this is the coordinator's
/// answer to the paper's "parallelize TC" future work (step 1 dominates).
pub fn parallel_knn(points: &Matrix, k: usize, exec: &Executor) -> Result<KnnLists> {
    let mut out = KnnLists::default();
    parallel_knn_into(points, k, exec, &mut out)?;
    Ok(out)
}

/// [`parallel_knn`] writing into a reusable output buffer: workers fill
/// disjoint row windows of `out` directly (no per-shard buffers, no
/// stitch copy), which is what the ITIS loop reuses across iterations.
pub fn parallel_knn_into(
    points: &Matrix,
    k: usize,
    exec: &Executor,
    out: &mut KnnLists,
) -> Result<()> {
    let n = points.rows();
    crate::knn::validate_k(n, k)?;
    let tree = KdTree::build_parallel(points, exec);
    tree.knn_all_pool_into(points, k, exec, out)
}

/// [`KnnProvider`] backed by the shared executor — the injection point
/// that routes the entire ITIS/IHTC reduction through executor-sharded
/// k-NN. With `shards > 1` the kd-tree regime runs on a sharded
/// [`KdForest`] (per-shard parallel construction, merged queries);
/// `shards: 1` is the single-tree path, byte for byte.
pub struct PoolKnnProvider<'a> {
    /// The run's shared executor.
    pub exec: &'a Executor,
    /// kd-forest shard count for the k-NN index (1 = single tree; the
    /// config knob `knn_shards`).
    pub shards: usize,
}

impl KnnProvider for PoolKnnProvider<'_> {
    fn knn(&self, points: &Matrix, k: usize) -> Result<KnnLists> {
        let mut out = KnnLists::default();
        self.knn_into(points, k, &mut out)?;
        Ok(out)
    }

    fn knn_into(&self, points: &Matrix, k: usize, out: &mut KnnLists) -> Result<()> {
        // Workspace-less path (`&self`, nowhere to keep the shard trees):
        // the forest is built for this call and dropped. Construction is
        // still shard-parallel, but arena reuse needs the caller-held
        // forest of `knn_forest_into` — which is what the ITIS loop uses;
        // this path serves one-shot callers and the PJRT fallback.
        let mut forest = KdForest::new();
        crate::knn::knn_auto_sharded_into(points, k, self.shards, self.exec, &mut forest, out)
    }

    fn knn_forest_into(
        &self,
        points: &Matrix,
        k: usize,
        forest: &mut KdForest,
        out: &mut KnnLists,
    ) -> Result<()> {
        crate::knn::knn_auto_sharded_into(points, k, self.shards, self.exec, forest, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::knn::knn_brute;

    #[test]
    fn parallel_knn_matches_serial() {
        let ds = gaussian_mixture_paper(1500, 201);
        let serial = knn_brute(&ds.points, 4).unwrap();
        let exec = Executor::new(4);
        let par = parallel_knn(&ds.points, 4, &exec).unwrap();
        for i in 0..1500 {
            let a = serial.distances(i);
            let b = par.distances(i);
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() <= 1e-4 * (1.0 + x.abs()), "row {i}");
            }
        }
    }

    #[test]
    fn parallel_knn_single_worker_ok() {
        let ds = gaussian_mixture_paper(300, 202);
        let exec = Executor::new(1);
        let r = parallel_knn(&ds.points, 2, &exec).unwrap();
        assert_eq!(r.len(), 300);
    }
}
