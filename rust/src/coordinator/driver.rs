//! End-to-end pipeline driver: config in, clustered dataset + report out.
//!
//! Phases (each timed and memory-bracketed):
//!
//! 1. **ingest** — the dataset streams shard-by-shard through the bounded
//!    pipeline while first/second moments are folded for standardization
//!    and PCA (single pass; no second scan of the source).
//! 2. **preprocess** — standardize + PCA transform, sharded across the
//!    worker pool.
//! 3. **reduce** — ITIS with the coordinator's k-NN backend (work-stealing
//!    kd-tree shards, or the PJRT AOT artifact when `backend = "pjrt"`).
//! 4. **cluster** — the configured final clusterer on the prototypes.
//! 5. **backout** — label propagation to all `n` units, metrics, output.

use super::pipeline::{collect, PipelineBuilder, StageMetrics};
use super::{PoolKnnProvider, WorkerPool};
use crate::cluster::kmeans::{self, NativeAssign};
use crate::cluster::{dbscan, hac};
use crate::config::{Backend, DataSource, PipelineConfig};
use crate::data::synth::{find_spec, gaussian_mixture_paper, realistic};
use crate::data::{csv, Dataset};
use crate::hybrid::{FinalClusterer, IhtcWorkspace};
use crate::itis::{itis_with_workspace, ItisConfig, ItisResult, KnnProvider, StopRule};
use crate::knn::KnnLists;
use crate::linalg::{pca::Pca, Matrix};
use crate::runtime::{Engine, PjrtAssign, PjrtChunks};
use crate::{memtrack, Error, Result};
use std::time::Instant;

/// Timing + memory for one pipeline phase.
#[derive(Clone, Debug)]
pub struct PhaseStat {
    /// Phase name.
    pub name: &'static str,
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Peak allocation above baseline during the phase (bytes; 0 unless
    /// the binary installs [`crate::memtrack::CountingAllocator`]).
    pub peak_bytes: usize,
}

/// Everything a run produces besides the labels themselves.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Config name.
    pub name: String,
    /// Units processed.
    pub n: usize,
    /// Input dimensionality.
    pub dim_in: usize,
    /// Dimensionality after preprocessing.
    pub dim_used: usize,
    /// ITIS iterations actually run.
    pub iterations: usize,
    /// Prototypes handed to the final clusterer.
    pub prototypes: usize,
    /// Final number of clusters.
    pub clusters: usize,
    /// Prediction accuracy vs ground-truth labels (when known).
    pub accuracy: Option<f64>,
    /// BSS/TSS of the final clustering on the preprocessed data.
    pub bss_tss: f64,
    /// Per-phase timing/memory.
    pub phases: Vec<PhaseStat>,
    /// Streaming-stage metrics from the ingest pipeline.
    pub stages: Vec<StageMetrics>,
    /// End-to-end seconds.
    pub total_seconds: f64,
}

impl RunReport {
    /// Render a human-readable multi-line summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "run '{}': n={} d={}→{} m={} prototypes={} clusters={}\n",
            self.name, self.n, self.dim_in, self.dim_used, self.iterations, self.prototypes,
            self.clusters
        ));
        if let Some(acc) = self.accuracy {
            out.push_str(&format!("  accuracy       {acc:.4}\n"));
        }
        out.push_str(&format!("  BSS/TSS        {:.4}\n", self.bss_tss));
        for p in &self.phases {
            out.push_str(&format!(
                "  phase {:<10} {:>9.3}s  peak {:>10} B\n",
                p.name, p.seconds, p.peak_bytes
            ));
        }
        for s in &self.stages {
            out.push_str(&format!(
                "  stage {:<10} items={:<6} busy={:?} blocked={:?}\n",
                s.name, s.items, s.busy, s.blocked
            ));
        }
        out.push_str(&format!("  total          {:>9.3}s\n", self.total_seconds));
        out
    }
}

/// k-NN provider driving the PJRT knn_chunk artifact, falling back to the
/// pool when `k` exceeds the artifact's neighbor slots.
struct PjrtKnn<'a> {
    engine: &'a Engine,
    fallback: PoolKnnProvider<'a>,
}

impl KnnProvider for PjrtKnn<'_> {
    fn knn(&self, points: &Matrix, k: usize) -> Result<KnnLists> {
        let t = &self.engine.tile;
        if k > t.knn_k || points.cols() > t.dim {
            eprintln!(
                "warning: PJRT knn artifact cannot serve k={k}/d={}; falling back to native pool",
                points.cols()
            );
            return self.fallback.knn(points, k);
        }
        crate::knn::knn_chunked(points, k, t.knn_q, t.knn_r, &PjrtChunks { engine: self.engine })
    }
}

/// Load or synthesize the configured dataset, streaming shards through
/// the bounded pipeline while folding first/second moments.
fn ingest(config: &PipelineConfig) -> Result<(Dataset, Moments, Vec<StageMetrics>)> {
    // Materialize the source dataset (generation is itself sharded so the
    // pipeline really streams; CSV reads are shard-sliced after load).
    let ds = match &config.source {
        DataSource::Csv { path, label_column } => {
            let opts = csv::CsvOptions { label_column: *label_column, ..Default::default() };
            csv::read_csv(path, &opts)?
        }
        DataSource::PaperMixture { n } => gaussian_mixture_paper(*n, config.seed),
        DataSource::Analogue { name, scale_div } => {
            let spec = find_spec(name).ok_or_else(|| {
                Error::Config(format!("unknown analogue dataset '{name}' (see Table 3)"))
            })?;
            realistic(spec, *scale_div, config.seed)
        }
    };
    let n = ds.len();
    let d = ds.dim();
    let shard = config.shard_size.max(1);
    let points = ds.points.clone();
    let capacity = config.queue_capacity;
    // Stream shards through the pipeline: source emits row ranges, the
    // moments stage folds Σx and Σx² per column (enough for standardize)
    // plus the full cross-moment matrix (enough for PCA covariance).
    let pipe = PipelineBuilder::source("source", capacity, move |emit| {
        let mut start = 0usize;
        while start < n {
            let end = (start + shard).min(n);
            emit(points.slice_rows(start, end))?;
            start = end;
        }
        Ok(())
    })
    .map("moments", move |m: Matrix| {
        let mut mo = Moments::new(d);
        mo.fold(&m);
        Ok(mo)
    })
    .build();
    let (parts, stages) = collect(pipe)?;
    let mut total = Moments::new(d);
    for p in parts {
        total.merge(&p);
    }
    Ok((ds, total, stages))
}

/// Streaming first/second moments for standardization + PCA covariance.
#[derive(Clone, Debug)]
pub struct Moments {
    /// Rows folded.
    pub count: usize,
    /// Per-column sums.
    pub sum: Vec<f64>,
    /// Upper-triangular cross-products Σ xᵢxⱼ (row-major d×d).
    pub cross: Vec<f64>,
}

impl Moments {
    /// Empty accumulator for `d` columns.
    pub fn new(d: usize) -> Self {
        Self { count: 0, sum: vec![0.0; d], cross: vec![0.0; d * d] }
    }

    /// Fold a shard.
    pub fn fold(&mut self, m: &Matrix) {
        let d = self.sum.len();
        debug_assert_eq!(m.cols(), d);
        self.count += m.rows();
        for i in 0..m.rows() {
            let row = m.row(i);
            for a in 0..d {
                self.sum[a] += row[a] as f64;
                for b in a..d {
                    self.cross[a * d + b] += row[a] as f64 * row[b] as f64;
                }
            }
        }
    }

    /// Merge another accumulator.
    pub fn merge(&mut self, other: &Moments) {
        self.count += other.count;
        for (a, b) in self.sum.iter_mut().zip(&other.sum) {
            *a += b;
        }
        for (a, b) in self.cross.iter_mut().zip(&other.cross) {
            *a += b;
        }
    }

    /// Column means.
    pub fn means(&self) -> Vec<f64> {
        let n = self.count.max(1) as f64;
        self.sum.iter().map(|s| s / n).collect()
    }

    /// Column standard deviations (population).
    pub fn stds(&self) -> Vec<f64> {
        let d = self.sum.len();
        let n = self.count.max(1) as f64;
        let means = self.means();
        (0..d)
            .map(|a| (self.cross[a * d + a] / n - means[a] * means[a]).max(0.0).sqrt())
            .collect()
    }
}

/// Standardize in place using streaming moments (so no second stats pass).
fn standardize_with(m: &mut Matrix, moments: &Moments, pool: &WorkerPool) -> Result<()> {
    let means = moments.means();
    let stds = moments.stds();
    let d = m.cols();
    let n = m.rows();
    // Sharded in-place transform: compute each shard into a fresh buffer.
    let parts = pool.run_chunks(n, 16_384, |start, end| {
        let mut buf = vec![0.0f32; (end - start) * d];
        for i in start..end {
            let row = m.row(i);
            for j in 0..d {
                let c = row[j] as f64 - means[j];
                buf[(i - start) * d + j] =
                    if stds[j] > 1e-12 { (c / stds[j]) as f32 } else { c as f32 };
            }
        }
        Ok((start, buf))
    })?;
    for (start, buf) in parts {
        let rows = buf.len() / d;
        m.data_mut()[start * d..(start + rows) * d].copy_from_slice(&buf);
    }
    Ok(())
}

/// Run the full pipeline: returns `(assignments, report)`.
pub fn run(config: &PipelineConfig) -> Result<(Vec<u32>, RunReport)> {
    config.validate()?;
    let t_all = Instant::now();
    let pool = WorkerPool::new(config.workers);
    let mut phases = Vec::new();

    // Phase 1: ingest (+ streaming moments).
    let t0 = Instant::now();
    let (ingested, peak) = memtrack::measure(|| ingest(config));
    let (mut ds, moments, stages) = ingested?;
    phases.push(PhaseStat {
        name: "ingest",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });
    let dim_in = ds.dim();

    // Phase 2: preprocess (standardize from streaming moments, then PCA).
    let t0 = Instant::now();
    let (prep, peak) = memtrack::measure(|| -> Result<Matrix> {
        let mut points = ds.points.clone();
        if config.standardize {
            standardize_with(&mut points, &moments, &pool)?;
        }
        if let Some(frac) = config.pca_variance {
            let pca = Pca::fit(&points)?;
            let k = pca.components_for_variance(frac);
            points = pca.transform(&points, k)?;
        }
        Ok(points)
    });
    ds.points = prep?;
    phases.push(PhaseStat {
        name: "preprocess",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });
    let dim_used = ds.dim();

    // Backend setup (PJRT engine lives on this thread only).
    let engine = match config.backend {
        Backend::Pjrt => Some(Engine::load(Engine::default_dir())?),
        Backend::Native => None,
    };
    let pool_knn = PoolKnnProvider { pool: &pool };
    let pjrt_knn = engine
        .as_ref()
        .map(|e| PjrtKnn { engine: e, fallback: PoolKnnProvider { pool: &pool } });
    let knn_provider: &dyn KnnProvider = match &pjrt_knn {
        Some(p) => p,
        None => &pool_knn,
    };
    let mut ws = IhtcWorkspace::new();

    // Phase 3: reduce (ITIS).
    let t0 = Instant::now();
    let ws_itis = &mut ws.itis;
    let (reduced, peak) = memtrack::measure(|| -> Result<ItisResult> {
        if config.iterations == 0 {
            return Ok(ItisResult {
                levels: vec![],
                prototypes: ds.points.clone(),
                weights: vec![1; ds.len()],
                n_original: ds.len(),
            });
        }
        let itis_cfg = ItisConfig {
            threshold: config.threshold,
            stop: StopRule::Iterations(config.iterations),
            prototype: config.prototype,
            seed_order: config.seed_order,
            min_prototypes: match &config.clusterer {
                FinalClusterer::KMeans { k, .. }
                | FinalClusterer::Hac { k, .. }
                | FinalClusterer::Gmm { k, .. } => *k,
                FinalClusterer::Dbscan { .. } => 2,
            },
        };
        itis_with_workspace(&ds.points, &itis_cfg, knn_provider, &pool, ws_itis)
    });
    let reduction = reduced?;
    phases.push(PhaseStat {
        name: "reduce",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });

    // Phase 4: final clusterer on the prototypes.
    let t0 = Instant::now();
    let ws_kmeans = &mut ws.kmeans;
    let (labels, peak) = memtrack::measure(|| -> Result<Vec<u32>> {
        let protos = &reduction.prototypes;
        match &config.clusterer {
            FinalClusterer::KMeans { k, restarts } => {
                let cfg = kmeans::KMeansConfig {
                    restarts: (*restarts).max(1),
                    seed: config.seed,
                    ..kmeans::KMeansConfig::new((*k).min(protos.rows()))
                };
                let result = match &engine {
                    // The PJRT assign backend is not Sync (xla handles stay
                    // on the coordinator thread), so it runs serially.
                    Some(e) if protos.cols() <= e.tile.dim && cfg.k <= e.tile.km_k => {
                        kmeans::kmeans_with_backend(protos, None, &cfg, &PjrtAssign { engine: e })?
                    }
                    _ => kmeans::kmeans_pool(protos, None, &cfg, &NativeAssign, &pool, ws_kmeans)?,
                };
                Ok(result.assignments)
            }
            FinalClusterer::Hac { k, linkage } => {
                let cfg = hac::HacConfig { linkage: *linkage, ..Default::default() };
                hac::hac_cut(protos, (*k).min(protos.rows()), &cfg)
            }
            FinalClusterer::Dbscan { eps, min_pts } => {
                dbscan::dbscan(protos, &dbscan::DbscanConfig { eps: *eps, min_pts: *min_pts })
            }
            FinalClusterer::Gmm { k, weighted } => {
                let cfg = crate::cluster::gmm::GmmConfig {
                    seed: config.seed,
                    ..crate::cluster::gmm::GmmConfig::new((*k).min(protos.rows()))
                };
                let masses: Vec<f32>;
                let w = if *weighted {
                    masses = reduction.weights.iter().map(|&x| x as f32).collect();
                    Some(masses.as_slice())
                } else {
                    None
                };
                Ok(crate::cluster::gmm::gmm(protos, w, &cfg)?.assignments)
            }
        }
    });
    let prototype_labels = labels?;
    phases.push(PhaseStat {
        name: "cluster",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });

    // Phase 5: back-out + metrics + optional output.
    let t0 = Instant::now();
    let (backout, peak) = memtrack::measure(|| -> Result<(Vec<u32>, Option<f64>, f64)> {
        let assignments = reduction.back_out(&prototype_labels)?;
        let accuracy = match &ds.labels {
            Some(truth) => Some(crate::metrics::prediction_accuracy(truth, &assignments)?),
            None => None,
        };
        let ratio = crate::metrics::bss_tss(&ds.points, &assignments)?;
        if let Some(path) = &config.output {
            write_assignments(path, &assignments)?;
        }
        Ok((assignments, accuracy, ratio))
    });
    let (assignments, accuracy, ratio) = backout?;
    phases.push(PhaseStat {
        name: "backout",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });

    let report = RunReport {
        name: config.name.clone(),
        n: ds.len(),
        dim_in,
        dim_used,
        iterations: reduction.iterations(),
        prototypes: reduction.prototypes.rows(),
        clusters: crate::metrics::num_clusters(&assignments),
        accuracy,
        bss_tss: ratio,
        phases,
        stages,
        total_seconds: t_all.elapsed().as_secs_f64(),
    };
    Ok((assignments, report))
}

/// Write `unit_index,cluster` rows.
fn write_assignments(path: &str, assignments: &[u32]) -> Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "unit,cluster")?;
    for (i, &c) in assignments.iter().enumerate() {
        writeln!(w, "{i},{c}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_config(n: usize) -> PipelineConfig {
        PipelineConfig {
            source: DataSource::PaperMixture { n },
            workers: 2,
            shard_size: 1024,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_native_kmeans() {
        let cfg = base_config(4000);
        let (assign, report) = run(&cfg).unwrap();
        assert_eq!(assign.len(), 4000);
        assert_eq!(report.n, 4000);
        assert_eq!(report.iterations, 2);
        assert!(report.prototypes <= 1000);
        assert!(report.accuracy.unwrap() > 0.85, "{report:?}");
        assert!(report.bss_tss > 0.5);
        assert_eq!(report.phases.len(), 5);
        assert!(report.stages.iter().any(|s| s.name == "source"));
    }

    #[test]
    fn end_to_end_hac() {
        let mut cfg = base_config(3000);
        cfg.iterations = 4;
        cfg.clusterer = FinalClusterer::Hac { k: 3, linkage: crate::cluster::hac::Linkage::Ward };
        let (assign, report) = run(&cfg).unwrap();
        assert_eq!(assign.len(), 3000);
        assert!(report.prototypes <= 3000 / 16);
        assert!(report.accuracy.unwrap() > 0.80, "{report:?}");
    }

    #[test]
    fn end_to_end_with_preprocess() {
        let mut cfg = base_config(2000);
        cfg.standardize = true;
        cfg.pca_variance = Some(0.9999);
        let (_, report) = run(&cfg).unwrap();
        assert!(report.dim_used <= report.dim_in);
        assert!(report.accuracy.unwrap() > 0.80);
    }

    #[test]
    fn analogue_source_runs() {
        let mut cfg = base_config(0);
        cfg.source = DataSource::Analogue { name: "pm 2.5".into(), scale_div: 50 };
        cfg.clusterer = FinalClusterer::KMeans { k: 4, restarts: 2 };
        cfg.standardize = true;
        let (_, report) = run(&cfg).unwrap();
        assert!(report.n >= 200);
        assert!(report.bss_tss > 0.0);
    }

    #[test]
    fn output_written() {
        let mut cfg = base_config(500);
        let path = std::env::temp_dir().join("ihtc_driver_out.csv");
        cfg.output = Some(path.to_string_lossy().into_owned());
        run(&cfg).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("unit,cluster"));
        assert_eq!(text.lines().count(), 501);
    }

    #[test]
    fn m0_skips_reduction() {
        let mut cfg = base_config(800);
        cfg.iterations = 0;
        let (_, report) = run(&cfg).unwrap();
        assert_eq!(report.prototypes, 800);
        assert_eq!(report.iterations, 0);
    }

    #[test]
    fn unknown_analogue_rejected() {
        let mut cfg = base_config(0);
        cfg.source = DataSource::Analogue { name: "nope".into(), scale_div: 1 };
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn moments_match_direct_stats() {
        let ds = gaussian_mixture_paper(3000, 7);
        let mut mo = Moments::new(2);
        mo.fold(&ds.points);
        let means = mo.means();
        let direct = ds.points.col_means();
        for (a, b) in means.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-9);
        }
        let stds = mo.stds();
        let dstds = ds.points.col_stds();
        for (a, b) in stds.iter().zip(&dstds) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn moments_merge_equals_single_fold() {
        let ds = gaussian_mixture_paper(1000, 8);
        let mut whole = Moments::new(2);
        whole.fold(&ds.points);
        let mut a = Moments::new(2);
        a.fold(&ds.points.slice_rows(0, 400));
        let mut b = Moments::new(2);
        b.fold(&ds.points.slice_rows(400, 1000));
        a.merge(&b);
        assert_eq!(a.count, whole.count);
        for (x, y) in a.cross.iter().zip(&whole.cross) {
            assert!((x - y).abs() < 1e-6 * (1.0 + y.abs()));
        }
    }
}
