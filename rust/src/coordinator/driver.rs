//! End-to-end pipeline driver: config in, clustered dataset + report out.
//!
//! Phases (each timed and memory-bracketed):
//!
//! 1. **ingest** — the dataset streams shard-by-shard through the bounded
//!    pipeline while first/second moments are folded for standardization
//!    and PCA (single pass; no second scan of the source).
//! 2. **preprocess** — standardize + PCA transform, sharded across the
//!    run's shared executor.
//! 3. **reduce** — ITIS with the coordinator's k-NN backend (work-stealing
//!    kd-tree shards, or the PJRT AOT artifact when `backend = "pjrt"`).
//! 4. **cluster** — the configured final clusterer on the prototypes.
//! 5. **backout** — label propagation to all `n` units, metrics, output.
//!
//! With `streaming: true` the first phase is **fused**: every incoming
//! shard is threshold-clustered into weighted prototypes as a
//! prioritized batch on the run's shared executor (one
//! [`crate::itis::reduce_shard`] call per shard, reusing a pooled
//! [`ItisWorkspace`] recycled across batches), and only the
//! concatenated prototype stream — roughly `n / t*` rows — is ever
//! resident: the per-row level-0 assignment map is spilled to disk by a
//! checkpoint sink stage ([`crate::checkpoint`]) and read back once,
//! sequentially, during back-out. With `checkpoint_path` set the spill
//! file doubles as a durable, CRC-framed checkpoint, and `resume: true`
//! replays it after a crash and continues the stream from the first
//! missing row — byte-identical to an uninterrupted run. Standardization
//! moments fold in the same single pass; the remaining `m − 1` ITIS
//! iterations then resume on the prototypes ([`crate::itis::itis_resume`]).
//! The default materialized path is untouched and remains byte-identical.

use super::pipeline::{collect, ExecStageOpts, PipelineBuilder, ReducedShard, RowShard, StageMetrics};
use super::PoolKnnProvider;
use crate::checkpoint::{self, CheckpointWriter, FaultPlan, Level0Map};
use crate::cluster::kmeans::{self, NativeAssign};
use crate::cluster::{dbscan, hac};
use crate::config::{Backend, DataSource, PipelineConfig};
use crate::data::synth::{
    find_spec, gaussian_mixture_paper, paper_mixture_spec, realistic, realistic_spec,
    MixtureSampler, MixtureSpec,
};
use crate::data::{csv, Dataset};
use crate::dist::{DistKnnProvider, DistPool, UnitResult, WorkSpec};
use crate::exec::Executor;
use crate::hybrid::{FinalClusterer, IhtcWorkspace};
use crate::itis::{
    itis_resume, itis_with_workspace, ItisConfig, ItisResult, KnnProvider, PrototypeKind, StopRule,
};
use crate::knn::KnnLists;
use crate::linalg::{pca::Pca, Matrix};
use crate::runtime::{Engine, PjrtAssign, PjrtChunks};
use crate::{memtrack, Error, Result};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Timing + memory for one pipeline phase.
#[derive(Clone, Debug)]
pub struct PhaseStat {
    /// Phase name.
    pub name: &'static str,
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Peak allocation above baseline during the phase (bytes; 0 unless
    /// the binary installs [`crate::memtrack::CountingAllocator`]).
    pub peak_bytes: usize,
}

/// Everything a run produces besides the labels themselves.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Config name.
    pub name: String,
    /// Units processed.
    pub n: usize,
    /// Input dimensionality.
    pub dim_in: usize,
    /// Dimensionality after preprocessing.
    pub dim_used: usize,
    /// ITIS iterations actually run.
    pub iterations: usize,
    /// Prototypes handed to the final clusterer.
    pub prototypes: usize,
    /// Final number of clusters.
    pub clusters: usize,
    /// Prediction accuracy vs ground-truth labels (when known).
    pub accuracy: Option<f64>,
    /// BSS/TSS of the final clustering on the preprocessed data.
    pub bss_tss: f64,
    /// Per-phase timing/memory.
    pub phases: Vec<PhaseStat>,
    /// Streaming-stage metrics from the ingest pipeline.
    pub stages: Vec<StageMetrics>,
    /// End-to-end seconds.
    pub total_seconds: f64,
}

impl RunReport {
    /// Render a human-readable multi-line summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "run '{}': n={} d={}→{} m={} prototypes={} clusters={}\n",
            self.name, self.n, self.dim_in, self.dim_used, self.iterations, self.prototypes,
            self.clusters
        ));
        if let Some(acc) = self.accuracy {
            out.push_str(&format!("  accuracy       {acc:.4}\n"));
        }
        out.push_str(&format!("  BSS/TSS        {:.4}\n", self.bss_tss));
        for p in &self.phases {
            out.push_str(&format!(
                "  phase {:<10} {:>9.3}s  peak {:>10} B\n",
                p.name, p.seconds, p.peak_bytes
            ));
        }
        for s in &self.stages {
            out.push_str(&format!(
                "  stage {:<10} items={:<6} busy={:?} queued={:?} blocked={:?}\n",
                s.name, s.items, s.busy, s.queued, s.blocked
            ));
        }
        out.push_str(&format!("  total          {:>9.3}s\n", self.total_seconds));
        out
    }
}

/// k-NN provider driving the PJRT knn_chunk artifact, falling back to the
/// pool when `k` exceeds the artifact's neighbor slots.
struct PjrtKnn<'a> {
    engine: &'a Engine,
    fallback: PoolKnnProvider<'a>,
}

impl PjrtKnn<'_> {
    /// True when the AOT artifact's tile geometry can serve this
    /// workload; warns (once per call) when it cannot.
    fn artifact_serves(&self, points: &Matrix, k: usize) -> bool {
        let t = &self.engine.tile;
        let ok = k <= t.knn_k && points.cols() <= t.dim;
        if !ok {
            eprintln!(
                "warning: PJRT knn artifact cannot serve k={k}/d={}; falling back to native pool",
                points.cols()
            );
        }
        ok
    }
}

impl KnnProvider for PjrtKnn<'_> {
    fn knn(&self, points: &Matrix, k: usize) -> Result<KnnLists> {
        if !self.artifact_serves(points, k) {
            return self.fallback.knn(points, k);
        }
        let t = &self.engine.tile;
        crate::knn::knn_chunked(points, k, t.knn_q, t.knn_r, &PjrtChunks { engine: self.engine })
    }

    // Forward the workspace hook so the native fallback keeps its
    // per-level forest/buffer reuse even under backend = pjrt (the
    // trait default would allocate a fresh KnnLists and a throwaway
    // forest every ITIS level). Output bytes are unchanged either way.
    fn knn_forest_into(
        &self,
        points: &Matrix,
        k: usize,
        forest: &mut crate::knn::forest::KdForest,
        out: &mut KnnLists,
    ) -> Result<()> {
        if !self.artifact_serves(points, k) {
            return self.fallback.knn_forest_into(points, k, forest, out);
        }
        let t = &self.engine.tile;
        crate::knn::knn_chunked_into(
            points,
            k,
            t.knn_q,
            t.knn_r,
            &PjrtChunks { engine: self.engine },
            out,
        )
    }
}

/// Load or synthesize the configured dataset, streaming shards through
/// the bounded pipeline while folding first/second moments.
fn ingest(config: &PipelineConfig) -> Result<(Dataset, Moments, Vec<StageMetrics>)> {
    // Materialize the source dataset (generation is itself sharded so the
    // pipeline really streams; CSV reads are shard-sliced after load).
    let ds = match &config.source {
        DataSource::Csv { path, label_column } => {
            let opts = csv::CsvOptions { label_column: *label_column, ..Default::default() };
            csv::read_csv(path, &opts)?
        }
        DataSource::PaperMixture { n } => gaussian_mixture_paper(*n, config.seed),
        DataSource::Analogue { name, scale_div } => {
            let spec = find_spec(name).ok_or_else(|| {
                Error::Config(format!("unknown analogue dataset '{name}' (see Table 3)"))
            })?;
            realistic(spec, *scale_div, config.seed)
        }
    };
    let n = ds.len();
    let d = ds.dim();
    let shard = config.shard_size.max(1);
    let points = ds.points.clone();
    let capacity = config.queue_capacity;
    // Stream shards through the pipeline: source emits row ranges, the
    // moments stage folds Σx and Σx² per column (enough for standardize)
    // plus the full cross-moment matrix (enough for PCA covariance).
    let pipe = PipelineBuilder::source("source", capacity, move |emit| {
        let mut start = 0usize;
        while start < n {
            let end = (start + shard).min(n);
            emit(points.slice_rows(start, end))?;
            start = end;
        }
        Ok(())
    })
    .map("moments", move |m: Matrix| {
        let mut mo = Moments::new(d);
        mo.fold(&m);
        Ok(mo)
    })
    .build();
    let (parts, stages) = collect(pipe)?;
    let mut total = Moments::new(d);
    for p in parts {
        total.merge(&p);
    }
    Ok((ds, total, stages))
}

/// Streaming first/second moments for standardization + PCA covariance.
#[derive(Clone, Debug)]
pub struct Moments {
    /// Rows folded.
    pub count: usize,
    /// Per-column sums.
    pub sum: Vec<f64>,
    /// Upper-triangular cross-products Σ xᵢxⱼ (row-major d×d).
    pub cross: Vec<f64>,
}

impl Moments {
    /// Empty accumulator for `d` columns.
    pub fn new(d: usize) -> Self {
        Self { count: 0, sum: vec![0.0; d], cross: vec![0.0; d * d] }
    }

    /// Fold a shard.
    pub fn fold(&mut self, m: &Matrix) {
        let d = self.sum.len();
        debug_assert_eq!(m.cols(), d);
        self.count += m.rows();
        for i in 0..m.rows() {
            let row = m.row(i);
            for a in 0..d {
                self.sum[a] += row[a] as f64;
                for b in a..d {
                    self.cross[a * d + b] += row[a] as f64 * row[b] as f64;
                }
            }
        }
    }

    /// Merge another accumulator.
    pub fn merge(&mut self, other: &Moments) {
        self.count += other.count;
        for (a, b) in self.sum.iter_mut().zip(&other.sum) {
            *a += b;
        }
        for (a, b) in self.cross.iter_mut().zip(&other.cross) {
            *a += b;
        }
    }

    /// Column means.
    pub fn means(&self) -> Vec<f64> {
        let n = self.count.max(1) as f64;
        self.sum.iter().map(|s| s / n).collect()
    }

    /// Column standard deviations (population).
    pub fn stds(&self) -> Vec<f64> {
        let d = self.sum.len();
        let n = self.count.max(1) as f64;
        let means = self.means();
        (0..d)
            .map(|a| (self.cross[a * d + a] / n - means[a] * means[a]).max(0.0).sqrt())
            .collect()
    }

    /// Sample covariance (`d × d`, row-major) of the folded rows,
    /// derived exactly from the cross-moments:
    /// `cov[a][b] = (Σ xₐx_b − n·μₐμ_b) / (n − 1)`. This is the same
    /// matrix [`Pca::fit`] accumulates from the materialized rows — so
    /// the streaming path gets the *full-data* PCA basis from its single
    /// ingest pass, without a second scan and without the old
    /// prototype-stream approximation. Requires `count ≥ 2`.
    pub fn covariance(&self) -> Result<Vec<f64>> {
        let d = self.sum.len();
        if self.count < 2 {
            return Err(Error::Data(format!(
                "covariance needs ≥ 2 folded rows, have {}",
                self.count
            )));
        }
        let n = self.count as f64;
        let means = self.means();
        let mut cov = vec![0.0f64; d * d];
        for a in 0..d {
            for b in a..d {
                let c = (self.cross[a * d + b] - n * means[a] * means[b]) / (n - 1.0);
                cov[a * d + b] = c;
                cov[b * d + a] = c;
            }
        }
        Ok(cov)
    }
}

/// The exact full-data PCA basis from streamed [`Moments`].
///
/// When `standardized` is set the prototypes being transformed have
/// already been standardized with these same moments, so the basis must
/// be fit in standardized coordinates: `cov'[a][b] = cov[a][b]/(sₐ·s_b)`
/// (columns with ~zero spread stay unscaled, sharing
/// [`STD_EPSILON`] with `standardize_with`), and the standardized
/// means are exactly 0.
fn pca_from_moments(moments: &Moments, standardized: bool) -> Result<Pca> {
    let d = moments.sum.len();
    let mut cov = moments.covariance()?;
    if standardized {
        let scale: Vec<f64> =
            moments.stds().into_iter().map(|s| if s > STD_EPSILON { s } else { 1.0 }).collect();
        for a in 0..d {
            for b in 0..d {
                cov[a * d + b] /= scale[a] * scale[b];
            }
        }
        return Pca::from_covariance(vec![0.0; d], &cov);
    }
    Pca::from_covariance(moments.means(), &cov)
}

/// Columns whose population std is at or below this are treated as
/// zero-spread and left unscaled — shared by [`standardize_with`] and
/// [`pca_from_moments`], which MUST agree: the streaming PCA basis is
/// fit in exactly the coordinates the standardized prototypes live in.
const STD_EPSILON: f64 = 1e-12;

/// Standardize in place using streaming moments (so no second stats pass).
fn standardize_with(m: &mut Matrix, moments: &Moments, exec: &Executor) -> Result<()> {
    let means = moments.means();
    let stds = moments.stds();
    let d = m.cols();
    let n = m.rows();
    // Sharded in-place transform: compute each shard into a fresh buffer.
    let parts = exec.run_chunks(n, 16_384, |start, end| {
        let mut buf = vec![0.0f32; (end - start) * d];
        for i in start..end {
            let row = m.row(i);
            for j in 0..d {
                let c = row[j] as f64 - means[j];
                buf[(i - start) * d + j] =
                    if stds[j] > STD_EPSILON { (c / stds[j]) as f32 } else { c as f32 };
            }
        }
        Ok((start, buf))
    })?;
    for (start, buf) in parts {
        let rows = buf.len() / d;
        m.data_mut()[start * d..(start + rows) * d].copy_from_slice(&buf);
    }
    Ok(())
}

/// The fused streaming ingest's output: the concatenated level-0
/// prototype stream (roughly `n / t*` rows) plus everything needed to
/// resume ITIS and back labels out. After [`ingest_streaming`] returns,
/// the prototype stream is the *only* dataset-sized state resident —
/// the raw `n × d` matrix was never materialized, and the per-row
/// level-0 assignment map lives on disk ([`Level0Map`]), read back
/// once, sequentially, during back-out.
#[derive(Debug)]
pub struct StreamedReduction {
    /// Concatenated weighted level-0 prototypes.
    pub prototypes: Matrix,
    /// Original units represented by each prototype.
    pub weights: Vec<u32>,
    /// Disk-spilled map: original row → level-0 prototype id (covers
    /// every streamed row, in stream order).
    pub level0: Level0Map,
    /// Ground-truth labels for all streamed rows, when known.
    pub labels: Option<Vec<u32>>,
    /// Streaming first/second moments of the raw rows (for exact
    /// standardization without a second pass).
    pub moments: Moments,
    /// Rows streamed.
    pub n: usize,
    /// Per-stage pipeline metrics.
    pub stages: Vec<StageMetrics>,
}

/// The boxed producer a streaming source hands to the pipeline.
type ShardProducer = Box<dyn FnOnce(&mut dyn FnMut(RowShard) -> Result<()>) -> Result<()> + Send>;

/// Shard-by-shard synthetic source: one sampler, one RNG stream, so the
/// emitted shards concatenate to exactly what the materialized path's
/// one-shot `sample(n, seed)` produces. A non-zero `start` seeks the
/// sampler past the rows a checkpoint already covers, so a resumed
/// stream emits exactly the missing suffix.
fn mixture_source(
    mix: MixtureSpec,
    n: usize,
    seed: u64,
    shard: usize,
    start: usize,
) -> ShardProducer {
    Box::new(move |emit| {
        let mut sampler = MixtureSampler::new(&mix, seed);
        let mut offset = start.min(n);
        sampler.seek(offset);
        while offset < n {
            let rows = shard.min(n - offset);
            let (points, labels) = sampler.next_shard(rows);
            emit(RowShard { offset, points, labels: Some(labels) })?;
            offset += rows;
        }
        Ok(())
    })
}

/// Build the shard source for the configured input without materializing
/// it: CSV files are read incrementally, synthetic sources are sampled
/// shard-by-shard from the same RNG stream the materialized path uses.
/// `start_row` is the first row to emit (0 for a fresh run; the replayed
/// checkpoint's row count on resume) — always a multiple of the shard
/// size, so the resumed stream's shard tiling matches the original's.
fn shard_source(config: &PipelineConfig, start_row: usize) -> Result<ShardProducer> {
    let shard = config.shard_size.max(1);
    Ok(match &config.source {
        DataSource::Csv { path, label_column } => {
            let opts = csv::CsvOptions { label_column: *label_column, ..Default::default() };
            let path = path.clone();
            Box::new(move |emit| {
                let mut offset = start_row;
                for item in csv::read_csv_chunks_from(&path, &opts, shard, start_row)? {
                    let (points, labels) = item?;
                    let rows = points.rows();
                    emit(RowShard { offset, points, labels })?;
                    offset += rows;
                }
                Ok(())
            })
        }
        DataSource::PaperMixture { n } => {
            mixture_source(paper_mixture_spec(), *n, config.seed, shard, start_row)
        }
        DataSource::Analogue { name, scale_div } => {
            let spec = find_spec(name).ok_or_else(|| {
                Error::Config(format!("unknown analogue dataset '{name}' (see Table 3)"))
            })?;
            let (mix, n) = realistic_spec(spec, *scale_div, config.seed);
            mixture_source(mix, n, config.seed, shard, start_row)
        }
    })
}

/// Fused out-of-core ingest: stream shards through the bounded pipeline,
/// threshold-clustering each one into weighted prototypes (level-0 TC)
/// while folding standardization moments — a single pass over the source
/// with only the in-flight shards plus the growing prototype stream
/// resident.
///
/// The reduce is **executor-native**: the fused source thread submits
/// each shard as a single-task batch on the run's one shared
/// work-stealing executor at `config.reduce_priority`, with
/// `config.reduce_stages` batches in flight at once — an in-flight cap,
/// not a thread budget (it may exceed `workers`; no reduce-stage or
/// distributor OS threads exist). Per-batch [`crate::itis::ShardReducer`]
/// states (each a reusable `ItisWorkspace`) are pooled and recycled
/// across batches, so at most `reduce_stages` ever exist; they cross
/// worker threads between batches, never during one. Completions are
/// reordered inline on the source thread (keyed on `RowShard::offset`)
/// before the checkpoint sink, so frames still hit the file strictly in
/// stream order. The ordering contract is enforced, not assumed:
/// offsets must tile the stream — a gap, duplicate, or overlap is a
/// hard [`Error::Coordinator`] in release builds. Because release order
/// equals stream order and each shard's reduction is worker-count and
/// priority invariant, any `reduce_stages` × `workers` × priority
/// combination yields a byte-identical [`StreamedReduction`].
pub fn ingest_streaming(config: &PipelineConfig) -> Result<StreamedReduction> {
    ingest_streaming_with_faults(config, &FaultPlan::none())
}

/// [`ingest_streaming`] with a deterministic fault plan threaded through
/// the pipeline — the crash/recovery harness's entry point. The plan
/// injects failures (source abort at an exact row, reduce-stage kill at
/// an exact shard offset, checkpoint-sink write error at an exact frame)
/// at reproducible points, so the resume contract is exercised in-tree
/// rather than hoped for. `FaultPlan::none()` makes this identical to
/// [`ingest_streaming`].
pub fn ingest_streaming_with_faults(
    config: &PipelineConfig,
    faults: &FaultPlan,
) -> Result<StreamedReduction> {
    ingest_streaming_with_pool(config, None, faults)
}

/// [`ingest_streaming_with_faults`] against an optional distributed
/// worker pool ([`crate::dist`]): with `Some(pool)` each shard's level-0
/// reduce is offered to a leased remote worker first, falling back to
/// the in-process reduce whenever the lease is abandoned (no connected
/// workers, worker death mid-lease, torn reply). Remote and local
/// execution run the identical functions on the identical bytes, so the
/// [`StreamedReduction`] is byte-identical either way — which is what
/// `rust/tests/dist_parity.rs` pins.
pub fn ingest_streaming_with_pool(
    config: &PipelineConfig,
    pool: Option<Arc<DistPool>>,
    faults: &FaultPlan,
) -> Result<StreamedReduction> {
    ingest_streaming_on(config, &Arc::new(Executor::with_config(config.executor())), pool, faults)
}

/// Reclaim the sink's writer from its shared slot. A poisoned lock maps
/// to `None`: poisoning means a stage thread panicked mid-append, and
/// every caller is already on an error path where the tmp file's frames
/// up to the last fsync remain valid for resume.
fn take_writer(slot: &Mutex<Option<CheckpointWriter>>) -> Option<CheckpointWriter> {
    slot.lock().ok().and_then(|mut s| s.take())
}

/// [`ingest_streaming`] on the caller's shared executor (what
/// [`run`] uses, so the whole streaming run is one thread team).
fn ingest_streaming_on(
    config: &PipelineConfig,
    exec: &Arc<Executor>,
    pool: Option<Arc<DistPool>>,
    faults: &FaultPlan,
) -> Result<StreamedReduction> {
    let capacity = config.queue_capacity.max(1);
    let stages_n = config.reduce_stages.max(1);
    // Resume: replay the durable checkpoint's valid frames (physically
    // truncating a torn tail to the last CRC-clean frame) and start the
    // source at the first row the file does not cover. No checkpoint on
    // disk yet means a fresh start — `prepare_resume` returns None.
    let ckpt_dest = config.checkpoint_path.as_ref().map(PathBuf::from);
    let replayed = match &ckpt_dest {
        Some(dest) if config.resume => checkpoint::prepare_resume(dest)?,
        _ => None,
    };
    let start_row = replayed.as_ref().map_or(0, |r| r.rows);
    let produce = shard_source(config, start_row)?;
    // The one level-0 shape, shared with the remote-worker path
    // (`crate::dist::execute_unit`) so both sides provably run the same
    // reduction.
    let itis_cfg = ItisConfig::level0(config.threshold, config.seed_order);
    let knn_shards = config.knn_shards.max(1);
    let dist_threshold = config.threshold;
    let dist_seed_order = config.seed_order;
    // The pooled reducer states are built lazily on the fused source
    // thread and submit their own nested k-NN batches, so they take
    // owning `Arc` handles to the one team.
    let stage_exec = Arc::clone(exec);
    // Shared slot for the checkpoint writer. The sink stage owns the
    // writer while the pipeline runs; the collector reclaims it after
    // `join` to finish (fsync + rename into place) or abort. On resume
    // the slot is pre-seeded with a writer positioned after the last
    // replayed frame, so the sink appends where the dead run stopped.
    let writer_slot: Arc<Mutex<Option<CheckpointWriter>>> = Arc::new(Mutex::new(None));
    if let Some(rep) = &replayed {
        let dest = ckpt_dest.as_ref().expect("resume implies checkpoint_path");
        let resumed = CheckpointWriter::resume(dest, rep, config.checkpoint_every_rows)?;
        *writer_slot.lock().expect("no other thread holds the fresh slot") = Some(resumed);
    }
    let fail_source = faults.fail_source_at_row;
    let kill_reduce = faults.kill_reduce_at_offset;
    let fail_sink = faults.fail_sink_at_frame;
    let sink_slot = Arc::clone(&writer_slot);
    let sink_dest = ckpt_dest.clone();
    let sync_every = config.checkpoint_every_rows;
    let pipe = PipelineBuilder::source_exec_ordered(
        ExecStageOpts {
            source: "source".into(),
            stage: "reduce".into(),
            reorder: "reorder".into(),
            capacity,
            // An in-flight cap on executor batches, not a thread count —
            // values above `workers` are fine (batches queue on the team).
            max_in_flight: stages_n,
            priority: config.reduce_priority,
            // Out-of-order completions that may park while the stream
            // head is still reducing: the window itself plus channel
            // slack. A correct (tiling) stream can never park more.
            parked_bound: stages_n + capacity + 2,
            start: start_row,
        },
        Arc::clone(exec),
        move || {
            crate::itis::ShardReducer::new(Arc::clone(&stage_exec), knn_shards, itis_cfg.clone())
        },
        move |reducer, shard: RowShard| {
            if kill_reduce == Some(shard.offset) {
                panic!("fault injection: reduce stage killed at offset {}", shard.offset);
            }
            // Offer the shard to a leased remote worker first. An
            // abandoned lease (no connected workers, worker death
            // mid-lease, torn reply) falls through to the in-process
            // reduce below — same functions on the same bytes, so the
            // output is byte-identical either way.
            if let Some(pool) = &pool {
                let lease = pool.submit(&WorkSpec::ReduceShard {
                    offset: shard.offset as u64,
                    points: &shard.points,
                    threshold: dist_threshold,
                    seed_order: dist_seed_order,
                    knn_shards,
                });
                if let Some(UnitResult::ReduceShard { reduction: red, moments }) =
                    lease.take_result()
                {
                    return Ok((
                        ReducedShard {
                            offset: shard.offset,
                            prototypes: red.prototypes,
                            weights: red.weights,
                            assignments: red.assignments,
                            labels: shard.labels,
                        },
                        moments,
                    ));
                }
            }
            let mut moments = Moments::new(shard.points.cols());
            moments.fold(&shard.points);
            let red = reducer.reduce(&shard.points)?;
            Ok((
                ReducedShard {
                    offset: shard.offset,
                    prototypes: red.prototypes,
                    weights: red.weights,
                    assignments: red.assignments,
                    labels: shard.labels,
                },
                moments,
            ))
        },
        |(shard, _): &(ReducedShard, Moments)| (shard.offset, shard.assignments.len()),
        move |emit: &mut dyn FnMut(RowShard) -> Result<()>| {
            let mut guarded = |shard: RowShard| {
                if let Some(k) = fail_source {
                    if shard.offset + shard.points.rows() > k {
                        return Err(Error::Data(format!(
                            "fault injection: source failed at row {k}"
                        )));
                    }
                }
                emit(shard)
            };
            produce(&mut guarded)
        },
    )
        // Checkpoint sink, strictly behind the reorder stage: frames hit
        // the file in stream order, so the file always holds an
        // offset-tiled prefix of the stream — exactly the resume
        // contract. Without `checkpoint_path` the writer is an anonymous
        // spill (no fsync, deleted on drop) that only serves back-out.
        .map("checkpoint", move |(shard, mo): (ReducedShard, Moments)| {
            let mut slot = sink_slot
                .lock()
                .map_err(|_| Error::Coordinator("checkpoint sink: writer lock poisoned".into()))?;
            if slot.is_none() {
                let d = shard.prototypes.cols();
                *slot = Some(match &sink_dest {
                    Some(dest) => CheckpointWriter::create(dest, d, sync_every)?,
                    None => CheckpointWriter::create_spill(&checkpoint::spill_path(), d)?,
                });
            }
            let writer = slot.as_mut().expect("just initialized");
            if fail_sink == Some(writer.frames()) {
                return Err(Error::Coordinator(format!(
                    "fault injection: checkpoint sink write failed at frame {}",
                    writer.frames()
                )));
            }
            writer.append(&shard, &mo).map_err(|e| match e {
                Error::Coordinator(m) => Error::Coordinator(m),
                e => Error::Coordinator(format!("checkpoint sink: {e}")),
            })?;
            Ok((shard, mo))
        })
        .build();

    // Concatenate the prototype stream. The fused head's inline reorder
    // guarantees stream order; the hard check below replaces the old
    // debug_assert-only guard (which vanished in release builds and let
    // an out-of-order shard silently corrupt every downstream weight and
    // back-out label). The per-row assignments are NOT accumulated here
    // — the checkpoint sink already spilled them to disk, so the last
    // resident O(n) buffer is gone.
    let mut data: Vec<f32> = Vec::new();
    let mut weights: Vec<u32> = Vec::new();
    let mut labels: Vec<u32> = Vec::new();
    let mut have_labels = true;
    let mut moments: Option<Moments> = None;
    let mut rows_total = 0usize;
    let mut d = 0usize;
    if let Some(rep) = replayed {
        // Seed the concatenation with the replayed prefix: frames were
        // appended in stream order, so this is exactly the state the
        // collector had reached when the interrupted run last fsynced
        // (including the moments fold order — resumed output stays
        // f64-bit-identical).
        d = rep.d;
        data = rep.prototypes;
        weights = rep.weights;
        labels = rep.labels;
        have_labels = rep.have_labels;
        moments = rep.moments;
        rows_total = rep.rows;
    }
    let mut order_err: Option<Error> = None;
    for (shard, mo) in &pipe.output {
        if order_err.is_some() {
            continue; // drain so the stages can finish; error after join
        }
        if shard.offset != rows_total {
            order_err = Some(Error::Coordinator(format!(
                "streaming collector: shard at offset {} arrived but the stream is only \
                 concatenated through {rows_total} — ordering contract violated",
                shard.offset,
            )));
            continue;
        }
        rows_total += shard.assignments.len();
        d = shard.prototypes.cols();
        data.extend_from_slice(shard.prototypes.data());
        weights.extend_from_slice(&shard.weights);
        match shard.labels {
            Some(l) => labels.extend(l),
            None => have_labels = false,
        }
        match &mut moments {
            Some(total) => total.merge(&mo),
            None => moments = Some(mo),
        }
    }
    // Every error path below must reclaim and abort the writer: abort
    // keeps a durable tmp file's fsynced frames on disk for resume and
    // deletes an anonymous spill.
    let stages = match pipe.join() {
        Ok(stages) => stages,
        Err(e) => {
            if let Some(w) = take_writer(&writer_slot) {
                w.abort();
            }
            return Err(e);
        }
    };
    if let Some(e) = order_err {
        if let Some(w) = take_writer(&writer_slot) {
            w.abort();
        }
        return Err(e);
    }
    let n = rows_total;
    if n == 0 {
        if let Some(w) = take_writer(&writer_slot) {
            w.abort();
        }
        return Err(Error::Data("streaming source produced no rows".into()));
    }
    let writer = match take_writer(&writer_slot) {
        Some(w) => w,
        None => {
            return Err(Error::Coordinator(
                "checkpoint sink produced no writer despite streamed rows".into(),
            ))
        }
    };
    let wrote = writer.rows();
    if wrote != n {
        writer.abort();
        return Err(Error::Coordinator(format!(
            "checkpoint covers {wrote} rows but the stream delivered {n}"
        )));
    }
    let level0 = writer.finish()?;
    let prototypes = Matrix::from_vec(data, weights.len(), d)?;
    Ok(StreamedReduction {
        prototypes,
        weights,
        level0,
        labels: if have_labels { Some(labels) } else { None },
        moments: moments.unwrap_or_else(|| Moments::new(d)),
        n,
        stages,
    })
}

/// Run the configured final clusterer on the reduction's prototypes
/// (shared by the materialized and streaming paths).
fn cluster_prototypes(
    config: &PipelineConfig,
    engine: Option<&Engine>,
    exec: &Executor,
    reduction: &ItisResult,
    ws: &mut kmeans::KMeansWorkspace,
) -> Result<Vec<u32>> {
    let protos = &reduction.prototypes;
    match &config.clusterer {
        FinalClusterer::KMeans { k, restarts } => {
            let cfg = kmeans::KMeansConfig {
                restarts: (*restarts).max(1),
                seed: config.seed,
                bounds: config.kmeans_bounds,
                ..kmeans::KMeansConfig::new((*k).min(protos.rows()))
            };
            let result = match engine {
                // The PJRT assign backend is not Sync (xla handles stay
                // on the coordinator thread), so it runs serially.
                Some(e) if protos.cols() <= e.tile.dim && cfg.k <= e.tile.km_k => {
                    kmeans::kmeans_with_backend(protos, None, &cfg, &PjrtAssign { engine: e })?
                }
                _ => kmeans::kmeans_pool(protos, None, &cfg, &NativeAssign, exec, ws)?,
            };
            Ok(result.assignments)
        }
        FinalClusterer::Hac { k, linkage } => {
            let cfg = hac::HacConfig { linkage: *linkage, ..Default::default() };
            hac::hac_cut(protos, (*k).min(protos.rows()), &cfg)
        }
        FinalClusterer::Dbscan { eps, min_pts } => {
            dbscan::dbscan(protos, &dbscan::DbscanConfig { eps: *eps, min_pts: *min_pts })
        }
        FinalClusterer::Gmm { k, weighted } => {
            let cfg = crate::cluster::gmm::GmmConfig {
                seed: config.seed,
                ..crate::cluster::gmm::GmmConfig::new((*k).min(protos.rows()))
            };
            let masses: Vec<f32>;
            let w = if *weighted {
                masses = reduction.weights.iter().map(|&x| x as f32).collect();
                Some(masses.as_slice())
            } else {
                None
            };
            Ok(crate::cluster::gmm::gmm(protos, w, &cfg)?.assignments)
        }
    }
}

/// Run the full pipeline: returns `(assignments, report)`.
///
/// With a `dist` block in the config this opens the coordinator pool
/// ([`crate::dist::pool_from_config`]), waits up to one lease timeout
/// for the configured workers to connect, runs with remote leases
/// enabled, and shuts the pool down before returning (workers see a
/// clean EOF and exit). Output bytes are identical with or without
/// workers — see the [`crate::dist`] determinism contract.
pub fn run(config: &PipelineConfig) -> Result<(Vec<u32>, RunReport)> {
    config.validate()?;
    let pool = crate::dist::pool_from_config(config)?;
    let result = run_with_pool(config, pool.as_ref());
    if let Some(p) = &pool {
        p.shutdown();
    }
    result
}

/// [`run`] against a caller-owned distributed pool (or none). The
/// caller keeps the pool's lifecycle: this function never shuts it
/// down, so tests and benches can reuse one pool across runs.
pub fn run_with_pool(
    config: &PipelineConfig,
    pool: Option<&Arc<DistPool>>,
) -> Result<(Vec<u32>, RunReport)> {
    config.validate()?;
    if config.streaming {
        return run_streaming(config, pool);
    }
    let t_all = Instant::now();
    // The run's one thread team: every parallel site below — kd-tree
    // and kd-forest builds, pooled k-NN queries, the ITIS prototype
    // reduction, k-means assignment parts, standardization chunks —
    // submits task batches into this executor.
    let exec = Executor::with_config(config.executor());
    let mut phases = Vec::new();

    // Phase 1: ingest (+ streaming moments).
    let t0 = Instant::now();
    let (ingested, peak) = memtrack::measure(|| ingest(config));
    let (mut ds, moments, stages) = ingested?;
    phases.push(PhaseStat {
        name: "ingest",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });
    let dim_in = ds.dim();

    // Phase 2: preprocess (standardize from streaming moments, then PCA).
    let t0 = Instant::now();
    let (prep, peak) = memtrack::measure(|| -> Result<Matrix> {
        let mut points = ds.points.clone();
        if config.standardize {
            standardize_with(&mut points, &moments, &exec)?;
        }
        if let Some(frac) = config.pca_variance {
            let pca = Pca::fit(&points)?;
            let k = pca.components_for_variance(frac);
            points = pca.transform(&points, k)?;
        }
        Ok(points)
    });
    ds.points = prep?;
    phases.push(PhaseStat {
        name: "preprocess",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });
    let dim_used = ds.dim();

    // Backend setup (PJRT engine lives on this thread only).
    let engine = match config.backend {
        Backend::Pjrt => Some(Engine::load(Engine::default_dir())?),
        Backend::Native => None,
    };
    let pool_knn = PoolKnnProvider { exec: &exec, shards: config.knn_shards };
    // Provider priority: PJRT > distributed leases > local pool. The
    // dist provider leases each forest build + query block and falls
    // back to `pool_knn`'s exact computation when abandoned, so the
    // choice never changes the bytes.
    let dist_knn = pool.map(|p| DistKnnProvider {
        pool: p,
        local: PoolKnnProvider { exec: &exec, shards: config.knn_shards },
    });
    let pjrt_knn = engine.as_ref().map(|e| PjrtKnn {
        engine: e,
        fallback: PoolKnnProvider { exec: &exec, shards: config.knn_shards },
    });
    let knn_provider: &dyn KnnProvider = match (&pjrt_knn, &dist_knn) {
        (Some(p), _) => p,
        (None, Some(d)) => d,
        (None, None) => &pool_knn,
    };
    let mut ws = IhtcWorkspace::new();

    // Phase 3: reduce (ITIS).
    let t0 = Instant::now();
    let ws_itis = &mut ws.itis;
    let (reduced, peak) = memtrack::measure(|| -> Result<ItisResult> {
        if config.iterations == 0 {
            return Ok(ItisResult {
                levels: vec![],
                prototypes: ds.points.clone(),
                weights: vec![1; ds.len()],
                n_original: ds.len(),
            });
        }
        let itis_cfg = ItisConfig {
            threshold: config.threshold,
            stop: StopRule::Iterations(config.iterations),
            prototype: config.prototype,
            seed_order: config.seed_order,
            min_prototypes: config.clusterer.min_prototypes(),
        };
        itis_with_workspace(&ds.points, &itis_cfg, knn_provider, &exec, ws_itis)
    });
    let reduction = reduced?;
    phases.push(PhaseStat {
        name: "reduce",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });

    // Phase 4: final clusterer on the prototypes.
    let t0 = Instant::now();
    let ws_kmeans = &mut ws.kmeans;
    let (labels, peak) = memtrack::measure(|| {
        cluster_prototypes(config, engine.as_ref(), &exec, &reduction, ws_kmeans)
    });
    let prototype_labels = labels?;
    phases.push(PhaseStat {
        name: "cluster",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });

    // Phase 5: back-out + metrics + optional output.
    let t0 = Instant::now();
    let (backout, peak) = memtrack::measure(|| -> Result<(Vec<u32>, Option<f64>, f64)> {
        let assignments = reduction.back_out(&prototype_labels)?;
        let accuracy = match &ds.labels {
            Some(truth) => Some(crate::metrics::prediction_accuracy(truth, &assignments)?),
            None => None,
        };
        let ratio = crate::metrics::bss_tss(&ds.points, &assignments)?;
        if let Some(path) = &config.output {
            write_assignments(path, &assignments)?;
        }
        Ok((assignments, accuracy, ratio))
    });
    let (assignments, accuracy, ratio) = backout?;
    phases.push(PhaseStat {
        name: "backout",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });

    let report = RunReport {
        name: config.name.clone(),
        n: ds.len(),
        dim_in,
        dim_used,
        iterations: reduction.iterations(),
        prototypes: reduction.prototypes.rows(),
        clusters: crate::metrics::num_clusters(&assignments),
        accuracy,
        bss_tss: ratio,
        phases,
        stages,
        total_seconds: t_all.elapsed().as_secs_f64(),
    };
    Ok((assignments, report))
}

/// Out-of-core execution: fused ingest + level-0 reduction, then the
/// remaining ITIS iterations, final clusterer, and back-out — with only
/// the prototype stream (plus per-row maps) ever resident. Phase names
/// match the materialized path so reports stay comparable;
/// [`RunReport::bss_tss`] is computed on the prototype stream (the full
/// matrix no longer exists by phase 5).
fn run_streaming(
    config: &PipelineConfig,
    pool: Option<&Arc<DistPool>>,
) -> Result<(Vec<u32>, RunReport)> {
    let t_all = Instant::now();
    // One executor for the whole run: the fused ingest submits its
    // per-shard reduce batches (and their nested k-NN batches) into it
    // through an `Arc`, and phases 2–5 use it directly by reference.
    let exec = Arc::new(Executor::with_config(config.executor()));
    let mut phases = Vec::new();

    // Phase 1: fused ingest + shard-wise level-0 TC (+ streaming moments).
    let t0 = Instant::now();
    let (ingested, peak) =
        memtrack::measure(|| ingest_streaming_on(config, &exec, pool.cloned(), &FaultPlan::none()));
    let StreamedReduction { prototypes, weights, level0, labels: truth, moments, n, stages } =
        ingested?;
    phases.push(PhaseStat {
        name: "ingest",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });
    let dim_in = prototypes.cols();
    let num_level0 = prototypes.rows();
    // The materialized path discards an ITIS level that undershoots the
    // final clusterer's floor — but the fused level 0 cannot be
    // discarded (the raw rows are gone), so undershoot must be an
    // explicit error rather than a silently clamped cluster count.
    let floor = config.clusterer.min_prototypes();
    if num_level0 < floor {
        return Err(Error::Coordinator(format!(
            "fused level-0 reduction left {num_level0} prototypes, below the final \
             clusterer's floor of {floor}; lower k or t*, or use the materialized path"
        )));
    }

    // Phase 2: preprocess the prototype stream. The level-0 partition
    // was formed on *raw* coordinates (the materialized path clusters
    // after standardize/PCA, so its partition can differ); what stays
    // exact is the prototypes themselves — standardizing the weighted
    // centroids with the streamed full-data moments equals the weighted
    // means of the standardized rows, because the per-column affine map
    // commutes with weighted means. PCA (when requested) is likewise
    // derived from the streamed cross-moments, so the basis is the
    // *exact* full-data fit (the old prototype-stream fit was a
    // documented approximation); component count is chosen from the
    // full-data eigenvalues and the basis is applied to the prototypes.
    let t0 = Instant::now();
    let (prep, peak) = memtrack::measure(|| -> Result<Matrix> {
        let mut points = prototypes;
        if config.standardize {
            standardize_with(&mut points, &moments, &exec)?;
        }
        if let Some(frac) = config.pca_variance {
            let pca = pca_from_moments(&moments, config.standardize)?;
            let k = pca.components_for_variance(frac);
            points = pca.transform(&points, k)?;
        }
        Ok(points)
    });
    let protos0 = prep?;
    phases.push(PhaseStat {
        name: "preprocess",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });
    let dim_used = protos0.cols();

    // Backend setup (PJRT engine lives on this thread only).
    let engine = match config.backend {
        Backend::Pjrt => Some(Engine::load(Engine::default_dir())?),
        Backend::Native => None,
    };
    let pool_knn = PoolKnnProvider { exec: &exec, shards: config.knn_shards };
    // Provider priority: PJRT > distributed leases > local pool. The
    // dist provider leases each forest build + query block and falls
    // back to `pool_knn`'s exact computation when abandoned, so the
    // choice never changes the bytes.
    let dist_knn = pool.map(|p| DistKnnProvider {
        pool: p,
        local: PoolKnnProvider { exec: &exec, shards: config.knn_shards },
    });
    let pjrt_knn = engine.as_ref().map(|e| PjrtKnn {
        engine: e,
        fallback: PoolKnnProvider { exec: &exec, shards: config.knn_shards },
    });
    let knn_provider: &dyn KnnProvider = match (&pjrt_knn, &dist_knn) {
        (Some(p), _) => p,
        (None, Some(d)) => d,
        (None, None) => &pool_knn,
    };
    let mut ws = IhtcWorkspace::new();

    // Phase 3: the remaining m − 1 ITIS iterations on the prototypes.
    let t0 = Instant::now();
    let ws_itis = &mut ws.itis;
    let (reduced, peak) = memtrack::measure(|| -> Result<ItisResult> {
        let itis_cfg = ItisConfig {
            threshold: config.threshold,
            stop: StopRule::Iterations(config.iterations - 1),
            prototype: config.prototype,
            seed_order: config.seed_order,
            min_prototypes: config.clusterer.min_prototypes(),
        };
        itis_resume(protos0, weights, n, &itis_cfg, knn_provider, &exec, ws_itis)
    });
    let reduction = reduced?;
    phases.push(PhaseStat {
        name: "reduce",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });

    // Phase 4: final clusterer on the prototypes.
    let t0 = Instant::now();
    let ws_kmeans = &mut ws.kmeans;
    let (labels, peak) = memtrack::measure(|| {
        cluster_prototypes(config, engine.as_ref(), &exec, &reduction, ws_kmeans)
    });
    let prototype_labels = labels?;
    phases.push(PhaseStat {
        name: "cluster",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });

    // Phase 5: back-out + metrics + optional output. The fused level-0
    // map lives on disk, so the composition runs in two steps: fold the
    // in-RAM levels (each ≤ num_level0 entries) plus the final labels
    // into one level-0-prototype-id → cluster-label lookup, then stream
    // the spilled per-row map through it once, sequentially — the O(n)
    // assignment vector below is the run's *output*, the only
    // dataset-sized allocation of the whole streaming path.
    let t0 = Instant::now();
    let (backout, peak) = memtrack::measure(|| -> Result<(Vec<u32>, Option<f64>, f64)> {
        if prototype_labels.len() != reduction.prototypes.rows() {
            return Err(Error::Shape(format!(
                "{} prototype labels for {} prototypes",
                prototype_labels.len(),
                reduction.prototypes.rows()
            )));
        }
        let mut lookup: Vec<u32> = (0..num_level0 as u32).collect();
        for level in &reduction.levels {
            for slot in lookup.iter_mut() {
                *slot = level.assignments[*slot as usize];
            }
        }
        for slot in lookup.iter_mut() {
            *slot = prototype_labels[*slot as usize];
        }
        let assignments = level0.back_out(&lookup)?;
        let accuracy = match &truth {
            Some(t) => Some(crate::metrics::prediction_accuracy(t, &assignments)?),
            None => None,
        };
        let ratio = crate::metrics::bss_tss(&reduction.prototypes, &prototype_labels)?;
        if let Some(path) = &config.output {
            write_assignments(path, &assignments)?;
        }
        Ok((assignments, accuracy, ratio))
    });
    let (assignments, accuracy, ratio) = backout?;
    phases.push(PhaseStat {
        name: "backout",
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: peak,
    });

    let report = RunReport {
        name: config.name.clone(),
        n,
        dim_in,
        dim_used,
        // The fused level-0 pass is an iteration too, but it is no
        // longer prepended to `levels` (its map lives on disk).
        iterations: reduction.iterations() + 1,
        prototypes: reduction.prototypes.rows(),
        clusters: crate::metrics::num_clusters(&assignments),
        accuracy,
        bss_tss: ratio,
        phases,
        stages,
        total_seconds: t_all.elapsed().as_secs_f64(),
    };
    Ok((assignments, report))
}

/// Write `unit_index,cluster` rows.
fn write_assignments(path: &str, assignments: &[u32]) -> Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "unit,cluster")?;
    for (i, &c) in assignments.iter().enumerate() {
        writeln!(w, "{i},{c}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::itis::{reduce_shard, ItisWorkspace};

    fn base_config(n: usize) -> PipelineConfig {
        PipelineConfig {
            source: DataSource::PaperMixture { n },
            workers: 2,
            shard_size: 1024,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_native_kmeans() {
        let cfg = base_config(4000);
        let (assign, report) = run(&cfg).unwrap();
        assert_eq!(assign.len(), 4000);
        assert_eq!(report.n, 4000);
        assert_eq!(report.iterations, 2);
        assert!(report.prototypes <= 1000);
        assert!(report.accuracy.unwrap() > 0.85, "{report:?}");
        assert!(report.bss_tss > 0.5);
        assert_eq!(report.phases.len(), 5);
        assert!(report.stages.iter().any(|s| s.name == "source"));
    }

    #[test]
    fn end_to_end_hac() {
        let mut cfg = base_config(3000);
        cfg.iterations = 4;
        cfg.clusterer = FinalClusterer::Hac { k: 3, linkage: crate::cluster::hac::Linkage::Ward };
        let (assign, report) = run(&cfg).unwrap();
        assert_eq!(assign.len(), 3000);
        assert!(report.prototypes <= 3000 / 16);
        assert!(report.accuracy.unwrap() > 0.80, "{report:?}");
    }

    #[test]
    fn end_to_end_with_preprocess() {
        let mut cfg = base_config(2000);
        cfg.standardize = true;
        cfg.pca_variance = Some(0.9999);
        let (_, report) = run(&cfg).unwrap();
        assert!(report.dim_used <= report.dim_in);
        assert!(report.accuracy.unwrap() > 0.80);
    }

    #[test]
    fn analogue_source_runs() {
        let mut cfg = base_config(0);
        cfg.source = DataSource::Analogue { name: "pm 2.5".into(), scale_div: 50 };
        cfg.clusterer = FinalClusterer::KMeans { k: 4, restarts: 2 };
        cfg.standardize = true;
        let (_, report) = run(&cfg).unwrap();
        assert!(report.n >= 200);
        assert!(report.bss_tss > 0.0);
    }

    #[test]
    fn output_written() {
        let mut cfg = base_config(500);
        let path = std::env::temp_dir().join("ihtc_driver_out.csv");
        cfg.output = Some(path.to_string_lossy().into_owned());
        run(&cfg).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("unit,cluster"));
        assert_eq!(text.lines().count(), 501);
    }

    #[test]
    fn m0_skips_reduction() {
        let mut cfg = base_config(800);
        cfg.iterations = 0;
        let (_, report) = run(&cfg).unwrap();
        assert_eq!(report.prototypes, 800);
        assert_eq!(report.iterations, 0);
    }

    #[test]
    fn unknown_analogue_rejected() {
        let mut cfg = base_config(0);
        cfg.source = DataSource::Analogue { name: "nope".into(), scale_div: 1 };
        assert!(run(&cfg).is_err());
    }

    fn streaming_config(n: usize) -> PipelineConfig {
        PipelineConfig {
            source: DataSource::PaperMixture { n },
            streaming: true,
            prototype: PrototypeKind::WeightedCentroid,
            // reduce_stages is an in-flight batch cap, not a thread
            // budget — sweeps may exceed this worker count freely.
            workers: 4,
            shard_size: 512,
            ..Default::default()
        }
    }

    #[test]
    fn streaming_end_to_end() {
        let cfg = streaming_config(4000);
        let (assign, report) = run(&cfg).unwrap();
        assert_eq!(assign.len(), 4000);
        assert_eq!(report.n, 4000);
        // Fused level 0 + one resumed iteration.
        assert_eq!(report.iterations, 2);
        assert!(report.prototypes <= 4000 / 4 + 8, "{}", report.prototypes);
        assert!(report.accuracy.unwrap() > 0.85, "{report:?}");
        assert_eq!(report.phases.len(), 5);
        // Executor-native topology: the fused head reports source,
        // reduce (batch queue/run split), and inline reorder slots, then
        // the checkpoint sink — in source→…→sink order, with no
        // per-stage or distributor slots left.
        let names: Vec<&str> = report.stages.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["source", "reduce", "reorder", "checkpoint"]);
        let reduce = report.stages.iter().find(|s| s.name == "reduce").unwrap();
        assert_eq!(reduce.items, 4000 / 512 + 1, "one batch per shard");
    }

    #[test]
    fn streaming_single_iteration_is_pure_fusion() {
        // m = 1: the fused level-0 pass is the whole reduction.
        let mut cfg = streaming_config(2000);
        cfg.iterations = 1;
        let (assign, report) = run(&cfg).unwrap();
        assert_eq!(assign.len(), 2000);
        assert_eq!(report.iterations, 1);
        assert!(report.prototypes <= 1000 + 4);
        assert!(report.accuracy.unwrap() > 0.85, "{report:?}");
    }

    #[test]
    fn streaming_with_preprocess_runs() {
        let mut cfg = streaming_config(3000);
        cfg.standardize = true;
        cfg.pca_variance = Some(0.9999);
        let (_, report) = run(&cfg).unwrap();
        assert!(report.dim_used <= report.dim_in);
        assert!(report.accuracy.unwrap() > 0.80, "{report:?}");
    }

    #[test]
    fn streaming_rejects_bad_configs() {
        let mut cfg = streaming_config(100);
        cfg.prototype = crate::itis::PrototypeKind::Centroid;
        assert!(run(&cfg).is_err());
        let mut cfg = streaming_config(100);
        cfg.iterations = 0;
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn streaming_empty_source_is_hard_error() {
        // An empty stream used to fall through to a degenerate 0×0
        // prototype matrix and Moments::new(0); it must be an explicit
        // dataset error instead.
        let cfg = streaming_config(0);
        let err = ingest_streaming(&cfg).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("no rows"), "{err}");
        // And the full run surfaces the same root cause.
        let err = run(&streaming_config(0)).unwrap_err();
        assert!(err.to_string().contains("no rows"), "{err}");
    }

    #[test]
    fn reduce_stages_all_byte_identical() {
        // The acceptance contract for the parallel fan-out: any number
        // of concurrent reduce stages produces a byte-identical
        // StreamedReduction — prototypes, weights, assignments, labels,
        // and (f64-exact) moments — because the reorder buffer restores
        // stream order before concatenation and each shard's reduction
        // is worker-count invariant.
        let mut base_cfg = streaming_config(3000);
        base_cfg.reduce_stages = 1;
        let base = ingest_streaming(&base_cfg).unwrap();
        for r in [2usize, 4] {
            let mut cfg = streaming_config(3000);
            cfg.reduce_stages = r;
            let got = ingest_streaming(&cfg).unwrap();
            assert_eq!(got.n, base.n, "r={r}");
            assert_eq!(got.prototypes.data(), base.prototypes.data(), "r={r}");
            assert_eq!(got.weights, base.weights, "r={r}");
            assert_eq!(
                got.level0.read_assignments().unwrap(),
                base.level0.read_assignments().unwrap(),
                "r={r}"
            );
            assert_eq!(got.labels, base.labels, "r={r}");
            assert_eq!(got.moments.count, base.moments.count, "r={r}");
            assert_eq!(got.moments.sum, base.moments.sum, "r={r}");
            assert_eq!(got.moments.cross, base.moments.cross, "r={r}");
        }
    }

    #[test]
    fn reduce_stages_end_to_end_labels_identical() {
        // Same seed, different fan-out: the final per-unit labels of the
        // whole streaming run must be identical.
        let mut cfg = streaming_config(2500);
        cfg.reduce_stages = 1;
        let (base, _) = run(&cfg).unwrap();
        cfg.reduce_stages = 4;
        let (par, report) = run(&cfg).unwrap();
        assert_eq!(base, par);
        assert!(report.stages.iter().any(|s| s.name == "reduce"));
    }

    #[test]
    fn streaming_errors_when_floor_unreachable() {
        // The fused level 0 cannot be discarded (raw rows are gone), so
        // a reduction below the clusterer's floor must be an explicit
        // error — never a silently clamped cluster count.
        let mut cfg = streaming_config(100);
        cfg.clusterer = FinalClusterer::KMeans { k: 80, restarts: 1 };
        let err = run(&cfg).unwrap_err();
        assert!(err.to_string().contains("floor"), "{err}");
    }

    #[test]
    fn fused_ingest_matches_two_pass_shard_reduction() {
        // The tentpole's parity contract: the fused single-pass ingest
        // must produce byte-identical WeightedCentroid prototypes (and
        // weights, level-0 assignments, moments) to a separate two-pass
        // run over the same shards — pass 1 materializing each shard and
        // reducing it, pass 2 folding moments.
        let cfg = streaming_config(3000);
        let stream = ingest_streaming(&cfg).unwrap();
        assert_eq!(stream.n, 3000);

        let ds = gaussian_mixture_paper(3000, cfg.seed);
        let exec = Executor::new(cfg.workers);
        let provider = PoolKnnProvider { exec: &exec, shards: 1 };
        let mut ws = ItisWorkspace::new();
        let itis_cfg = ItisConfig {
            threshold: cfg.threshold,
            stop: StopRule::Iterations(1),
            prototype: PrototypeKind::WeightedCentroid,
            seed_order: cfg.seed_order,
            min_prototypes: 1,
        };
        let mut data: Vec<f32> = Vec::new();
        let mut weights: Vec<u32> = Vec::new();
        let mut assignments: Vec<u32> = Vec::new();
        // Per-shard fold + merge, mirroring the fused stage's structure
        // (f64 addition is not associative, and the parity is bitwise).
        let mut moments = Moments::new(2);
        let mut start = 0usize;
        while start < 3000 {
            let end = (start + cfg.shard_size).min(3000);
            let shard = ds.points.slice_rows(start, end);
            let mut mo = Moments::new(2);
            mo.fold(&shard);
            moments.merge(&mo);
            let red = reduce_shard(
                &shard,
                &vec![1; end - start],
                &itis_cfg,
                &provider,
                &exec,
                &mut ws,
            )
            .unwrap();
            let base = weights.len() as u32;
            assignments.extend(red.assignments.iter().map(|&a| base + a));
            data.extend_from_slice(red.prototypes.data());
            weights.extend_from_slice(&red.weights);
            start = end;
        }
        assert_eq!(stream.prototypes.data(), &data[..]);
        assert_eq!(stream.weights, weights);
        assert_eq!(stream.level0.read_assignments().unwrap(), assignments);
        assert_eq!(stream.labels, ds.labels);
        assert_eq!(stream.moments.count, moments.count);
        assert_eq!(stream.moments.sum, moments.sum);
        assert_eq!(stream.moments.cross, moments.cross);
        let total: u64 = stream.weights.iter().map(|&w| w as u64).sum();
        assert_eq!(total, 3000);
        // The parallel fan-out must hit the same materialized two-pass
        // bytes, not merely agree with the single-stage fused run.
        let mut par_cfg = streaming_config(3000);
        par_cfg.reduce_stages = 4;
        let par = ingest_streaming(&par_cfg).unwrap();
        assert_eq!(par.prototypes.data(), &data[..]);
        assert_eq!(par.weights, weights);
        assert_eq!(par.level0.read_assignments().unwrap(), assignments);
        assert_eq!(par.moments.cross, moments.cross);
    }

    #[test]
    fn streaming_pca_basis_is_exact_full_data_fit() {
        // The streamed cross-moments must reproduce the materialized
        // two-pass basis: standardize the full matrix with the same
        // moments, fit PCA on it directly, and compare eigenvalues and
        // components (up to sign) against pca_from_moments.
        let ds = gaussian_mixture_paper(4000, 91);
        let mut mo = Moments::new(2);
        mo.fold(&ds.points);
        let exec = Executor::new(2);
        for standardize in [false, true] {
            let mut mat = ds.points.clone();
            if standardize {
                standardize_with(&mut mat, &mo, &exec).unwrap();
            }
            let direct = Pca::fit(&mat).unwrap();
            let streamed = pca_from_moments(&mo, standardize).unwrap();
            for (a, b) in direct.eigenvalues.iter().zip(&streamed.eigenvalues) {
                assert!(
                    (a - b).abs() < 1e-4 * (1.0 + a.abs()),
                    "standardize={standardize}: eigenvalue {a} vs {b}"
                );
            }
            for (ca, cb) in direct.components.iter().zip(&streamed.components) {
                let dot: f64 = ca.iter().zip(cb).map(|(x, y)| x * y).sum();
                assert!(
                    (dot.abs() - 1.0).abs() < 1e-4,
                    "standardize={standardize}: |dot|={}",
                    dot.abs()
                );
            }
            // Component selection agrees too.
            assert_eq!(
                direct.components_for_variance(0.95),
                streamed.components_for_variance(0.95),
                "standardize={standardize}"
            );
        }
        // Degenerate moment streams are explicit errors.
        assert!(Moments::new(2).covariance().is_err());
    }

    #[test]
    fn moments_match_direct_stats() {
        let ds = gaussian_mixture_paper(3000, 7);
        let mut mo = Moments::new(2);
        mo.fold(&ds.points);
        let means = mo.means();
        let direct = ds.points.col_means();
        for (a, b) in means.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-9);
        }
        let stds = mo.stds();
        let dstds = ds.points.col_stds();
        for (a, b) in stds.iter().zip(&dstds) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn moments_merge_equals_single_fold() {
        let ds = gaussian_mixture_paper(1000, 8);
        let mut whole = Moments::new(2);
        whole.fold(&ds.points);
        let mut a = Moments::new(2);
        a.fold(&ds.points.slice_rows(0, 400));
        let mut b = Moments::new(2);
        b.fold(&ds.points.slice_rows(400, 1000));
        a.merge(&b);
        assert_eq!(a.count, whole.count);
        for (x, y) in a.cross.iter().zip(&whole.cross) {
            assert!((x - y).abs() < 1e-6 * (1.0 + y.abs()));
        }
    }
}
