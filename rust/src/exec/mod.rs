//! The shared work-stealing executor — one thread team per run.
//!
//! Before this module, every parallel layer spawned its own thread team:
//! each `ShardReducer`, kd-forest build, k-means assignment pass, and
//! ITIS prototype reduction went through a per-call `WorkerPool` (scoped
//! threads spawned and joined per invocation). [`Executor`] replaced all
//! of that with a single persistent team, and the streaming pipeline is
//! now executor-native too: per-shard reduce work arrives as submitted
//! batches ([`Executor::submit`] → [`BatchHandle`]) instead of running
//! on dedicated stage threads, so `reduce_stages` caps *in-flight
//! batches*, not OS threads.
//!
//! * **One team per run.** The driver (and `Ihtc::run_with` for the
//!   materialized path) creates one `Executor`; every parallel site —
//!   kd-tree builds, `KdForest` shard builds, pooled k-NN queries, the
//!   ITIS prototype reduction, k-means assignment parts, and the
//!   streaming per-shard reduce batches — submits task batches into it
//!   by reference (or via a shared [`std::sync::Arc`] from the
//!   pipeline's source thread).
//! * **Submitters are workers.** `Executor::new(w)` spawns `w − 1`
//!   background threads; the thread calling [`Executor::run_tasks`]
//!   participates in its own batch, so one active submitter runs on
//!   exactly `w` threads (the old pool's contract), and a batch can
//!   always make progress even if every background worker is busy
//!   elsewhere — no deadlock, whatever the fan-out. `S` concurrent
//!   submitters *share* the one background team instead of multiplying
//!   it: peak compute threads are `w − 1 + S` (each submitter occupies
//!   its own thread while active), bounded and transient, where the
//!   per-call-pool scheme would have run `S · w`. A [`BatchHandle`]
//!   holder can likewise pitch in via [`BatchHandle::help`]/`wait`.
//! * **Work-stealing across batches, priorities across classes.**
//!   Batches queue in per-[`Priority`] injectors; idle workers always
//!   serve the highest non-empty class, then claim tasks through an
//!   atomic cursor (the stealing granularity), so when one streaming
//!   reduce batch is hard, the whole team converges on it while lighter
//!   batches' submitters finish solo. [`StealPolicy`] picks which
//!   queued batch idle workers serve first *within* a class;
//!   `fair_stages` caps how many tasks a worker takes from one batch
//!   before re-selecting, so a giant batch cannot starve its siblings —
//!   and the re-selection re-reads the class scan, so newly arrived
//!   high-priority work overtakes within one fairness grain.
//! * **Determinism.** Results are keyed by submission index and
//!   returned in task order, and every in-tree task partitioning is
//!   index-deterministic — so output bytes never depend on the worker
//!   count, the steal policy, the priority class, or scheduling (the
//!   byte-parity suites in `rust/tests/` pin this down).
//!
//! No in-tree code spawns ad-hoc threads anymore: the driver
//! paths create one `Executor` per run and share it, while the
//! workspace-less convenience entry points (`knn_auto`, `itis`,
//! `Ihtc::run`, `DefaultKnn`) construct a short-lived machine-default
//! `Executor` per call. Background workers spawn lazily on the first
//! submitted batch, so those throwaway executors cost nothing on
//! serial-fallback workloads and one team spawn (the retired scoped
//! pools' cost) when a parallel section engages; pass an executor
//! explicitly to amortize the team across calls.

use crate::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::sync::thread::JoinHandle;
use crate::sync::{thread, Arc, Condvar, Mutex};
use crate::{Error, Result};
use std::collections::VecDeque;
use std::time::Duration;
#[cfg(not(loom))]
use std::time::Instant;

#[cfg(all(loom, test))]
mod loom_tests;

/// Resolve a worker-count setting (0 = available parallelism − 1, min 1).
pub fn resolve_workers(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    thread::available_parallelism().saturating_sub(1).max(1)
}

/// Which queued batch an idle worker serves first when several runs'
/// batches are waiting. The policy can only change scheduling order —
/// results are keyed by submission index, so output bytes are identical
/// under every policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StealPolicy {
    /// Oldest batch first (default): finishes earlier submissions sooner,
    /// which keeps the streaming reorder buffer shallow.
    Fifo,
    /// Newest batch first: favors cache-warm work just submitted.
    Lifo,
}

/// Priority class of a submitted batch. Workers always serve the
/// highest non-empty class; [`StealPolicy`] and the fairness rotation
/// order batches *within* a class. Priorities are scheduling-only:
/// results stay keyed by submission index, so output bytes are
/// identical whatever class work runs in — pinned by the priority sweep
/// in `rust/tests/exec_determinism.rs`, like steal/fairness already are.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    /// Overtakes everything else queued — the class for latency-critical
    /// work (e.g. assignment-serving query batches that must not sit
    /// behind a bulk re-index).
    High,
    /// The default class; [`Executor::run_tasks`] submits here.
    #[default]
    Normal,
    /// Yields to everything else queued — background maintenance work.
    Bulk,
}

impl Priority {
    /// Number of classes (the per-priority queue array size).
    const COUNT: usize = 3;

    /// Every class, highest first — for byte-parity test sweeps.
    pub const ALL: [Priority; Priority::COUNT] = [Priority::High, Priority::Normal, Priority::Bulk];

    /// Queue index: highest priority scans first.
    fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Bulk => 2,
        }
    }

    /// Parse a config-file value (`"high" | "normal" | "bulk"`).
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "bulk" => Some(Priority::Bulk),
            _ => None,
        }
    }
}

/// Executor construction knobs (the config file's `executor` block).
#[derive(Clone, Copy, Debug)]
pub struct ExecutorConfig {
    /// Total thread budget (0 = available parallelism − 1, min 1). The
    /// team is `workers − 1` background threads plus the submitting
    /// thread itself. Taken literally — the config layer enforces a
    /// sanity ceiling; direct API callers own their budget.
    pub workers: usize,
    /// Which queued batch idle workers serve first (within a class).
    pub steal: StealPolicy,
    /// When several batches are queued (e.g. concurrent in-flight reduce
    /// batches), cap how many tasks a worker takes from one batch before
    /// re-selecting, and rotate the served batch to the back of its
    /// class queue — so no batch starves its same-priority siblings.
    /// Off, a worker drains its chosen batch completely. Higher-priority
    /// classes always preempt the rotation at re-selection time.
    pub fair_stages: bool,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        Self { workers: 0, steal: StealPolicy::Fifo, fair_stages: true }
    }
}

/// Tasks a worker takes from one batch before re-selecting under
/// `fair_stages` (tasks are coarse — hundreds of rows — so the
/// re-selection lock touch is noise).
const FAIR_GRAIN: usize = 8;

/// Wall-clock stamps for one batch — metrics only, never read by any
/// scheduling decision (the wallclock lint allowlists this module for
/// exactly this struct). Not compiled under loom: `Instant` would
/// explode the model's state space for no modeled behavior.
#[cfg(not(loom))]
struct BatchTiming {
    submitted: Instant,
    /// Stamped by whichever thread claims index 0 — the first claim in
    /// the cursor's modification order — ending the queue-wait span.
    first_claim: Mutex<Option<Instant>>,
    /// Stamped when `remaining` reaches 0 (under the `done` lock).
    finished: Mutex<Option<Instant>>,
}

#[cfg(not(loom))]
impl BatchTiming {
    fn start() -> Self {
        Self { submitted: Instant::now(), first_claim: Mutex::new(None), finished: Mutex::new(None) }
    }

    /// `(queue_wait, run_time)` once the batch is done; zeros before.
    fn queue_and_run(&self) -> (Duration, Duration) {
        let first = *self.first_claim.lock().unwrap();
        let fin = *self.finished.lock().unwrap();
        match (first, fin) {
            (Some(fc), Some(fi)) => (
                fc.saturating_duration_since(self.submitted),
                fi.saturating_duration_since(fc),
            ),
            // Aborted before any claim: the whole span was queue wait.
            (None, Some(fi)) => (fi.saturating_duration_since(self.submitted), Duration::ZERO),
            _ => (Duration::ZERO, Duration::ZERO),
        }
    }
}

/// One submitted batch: `n` type-erased tasks claimed through an atomic
/// cursor. The `ctx` pointer targets either a stack frame inside the
/// submitting `run_tasks` call or the heap-pinned `OwnedCtx` of a
/// [`BatchHandle`]; see the safety arguments on [`Executor::run_tasks`]
/// and [`Executor::submit`].
struct Batch {
    n: usize,
    /// Next unclaimed task index; claims beyond `n` mean "exhausted".
    cursor: AtomicUsize,
    /// Tasks not yet finished executing; 0 releases the submitter.
    remaining: AtomicUsize,
    /// Monomorphized trampoline executing task `i` against `ctx`;
    /// returns true when the task failed and the batch should abort.
    // SAFETY contract of the fn pointer: callers must pass this batch's
    // own `ctx` and an index claimed from `cursor` — see `run_erased`.
    run: unsafe fn(*const (), usize) -> bool,
    /// Borrowed batch state (slots, results, closure). Only dereferenced
    /// for successfully claimed indices.
    ctx: *const (),
    done: Mutex<()>,
    done_cv: Condvar,
    #[cfg(not(loom))]
    timing: BatchTiming,
}

// SAFETY: `ctx` is only dereferenced through `run` for claimed task
// indices, and the submitter (or handle) blocks until `remaining == 0`,
// which happens strictly after the last such dereference — so the
// pointee outlives every access. All other fields are Sync primitives.
unsafe impl Send for Batch {}
unsafe impl Sync for Batch {}

impl Batch {
    /// Claim the next unexecuted task index, if any.
    ///
    /// Ordering audit (loom: `claim_is_exclusive_and_complete`,
    /// `abort_rest_accounts_every_index_once`): both cursor operations
    /// are deliberately `Relaxed`. Index *uniqueness* needs no ordering
    /// at all — `fetch_add` is a read-modify-write, and RMWs on one
    /// atomic always observe the latest value in its modification
    /// order, so two claimers can never receive the same index. Task
    /// *data* visibility is not the cursor's job either: workers reach
    /// the batch through the queue mutex (which synchronizes the
    /// submitter's writes), and result publication rides the
    /// `remaining` Release/Acquire pair plus the slot mutexes. The
    /// pre-check is a pure optimization — a stale read only costs one
    /// extra `fetch_add` past `n`, which the `i < n` guard absorbs.
    fn claim(&self) -> Option<usize> {
        // Pre-check keeps the cursor from racing far past `n` while a
        // batch lingers in the queue.
        if self.cursor.load(Ordering::Relaxed) >= self.n {
            return None;
        }
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        if i < self.n {
            #[cfg(not(loom))]
            if i == 0 {
                // Index 0 is the first claim in the cursor's modification
                // order: stamp the end of the batch's queue wait.
                *self.timing.first_claim.lock().unwrap() = Some(Instant::now());
            }
            Some(i)
        } else {
            None
        }
    }

    /// True once every task index has been claimed (not necessarily
    /// finished) — the queue prunes exhausted batches.
    fn exhausted(&self) -> bool {
        self.cursor.load(Ordering::Relaxed) >= self.n
    }

    /// Execute claimed task `i` and publish its completion.
    ///
    /// # Safety
    /// `i` must come from [`Self::claim`] on this batch (each index
    /// executes at most once, and the submitter is still alive).
    unsafe fn execute(&self, i: usize) {
        // SAFETY: forwarded from the caller's contract.
        let abort = unsafe { (self.run)(self.ctx, i) };
        if abort {
            // First failure: claim every not-yet-claimed index in one
            // shot so the error returns without the submitter and
            // workers paying a claim + slot-lock round-trip per
            // remaining task (the retired pool's short-circuit `break`,
            // adapted to the remaining-counter completion protocol).
            self.abort_rest();
        }
        // Release pairs with the Acquire load in `wait`: everything this
        // task wrote (its result slot, its `&mut` output window)
        // happens-before the submitter observing `remaining == 0` — the
        // submitter may deallocate the `ctx` frame right after.
        if self.remaining.fetch_sub(1, Ordering::Release) == 1 {
            // Take the lock so a submitter between its predicate check
            // and `wait` cannot miss this wakeup.
            let _guard = self.done.lock().unwrap();
            #[cfg(not(loom))]
            {
                *self.timing.finished.lock().unwrap() = Some(Instant::now());
            }
            self.done_cv.notify_all();
        }
    }

    /// Bulk-claim all unclaimed indices and account for them in
    /// `remaining`. Indices already claimed by racing workers are NOT
    /// covered here — their claimers decrement for them — so every
    /// index is counted exactly once whichever way the race goes.
    ///
    /// Ordering audit (loom: `abort_rest_accounts_every_index_once`,
    /// `submit_drop_aborts_unclaimed`): the `swap` is `Relaxed` for the
    /// same reason `claim`'s `fetch_add` is — it is an RMW on the
    /// cursor's modification order, so it partitions indices exactly:
    /// everything below `prev` was (or will be) claimed by racing
    /// `fetch_add`s, everything in `prev..n` is accounted here and can
    /// never be claimed afterwards. The `fetch_sub` on `remaining` is
    /// `Release` so that a bulk decrement that happens to be the *last*
    /// one still orders this thread's prior task writes before the
    /// submitter's Acquire observation.
    fn abort_rest(&self) {
        let prev = self.cursor.swap(self.n, Ordering::Relaxed);
        let skipped = self.n.saturating_sub(prev);
        if skipped > 0 && self.remaining.fetch_sub(skipped, Ordering::Release) == skipped {
            let _guard = self.done.lock().unwrap();
            #[cfg(not(loom))]
            {
                *self.timing.finished.lock().unwrap() = Some(Instant::now());
            }
            self.done_cv.notify_all();
        }
    }

    /// Block until every task has finished executing.
    ///
    /// No lost wakeup (loom: `wait_notify_no_lost_wakeup`,
    /// `submit_handle_wait_no_lost_wakeup`): the predicate is checked
    /// while holding `done`, and notifiers take `done` *before*
    /// `notify_all` — so a notifier can never fire in the window between
    /// this thread's predicate check and its `wait` (which releases the
    /// lock atomically). The `Acquire` load pairs with the `Release`
    /// `fetch_sub`s in `execute`/`abort_rest`; see the comment there for
    /// why that edge is load-bearing.
    fn wait(&self) {
        let mut guard = self.done.lock().unwrap();
        while self.remaining.load(Ordering::Acquire) > 0 {
            guard = self.done_cv.wait(guard).unwrap();
        }
    }
}

/// State shared between the executor handle and its background workers.
struct Shared {
    /// One injector per [`Priority`] class, indexed by
    /// `Priority::index` (highest first). Workers always serve the
    /// highest non-empty class.
    queues: Mutex<[VecDeque<Arc<Batch>>; Priority::COUNT]>,
    available: Condvar,
    shutdown: AtomicBool,
    steal: StealPolicy,
    fair: bool,
}

/// Background worker: serve queued batches until shutdown.
fn worker_loop(shared: &Shared) {
    loop {
        let batch = {
            let mut qs = shared.queues.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                for q in qs.iter_mut() {
                    q.retain(|b| !b.exhausted());
                }
                // Serve the highest-priority class with queued work; the
                // steal policy and the fairness rotation apply *within*
                // that class. Because every re-selection re-runs this
                // scan, freshly queued higher-priority batches overtake
                // within one fairness grain.
                if let Some(q) = qs.iter_mut().find(|q| !q.is_empty()) {
                    let b = match shared.steal {
                        StealPolicy::Fifo => q.pop_front(),
                        StealPolicy::Lifo => q.pop_back(),
                    }
                    .expect("class queue checked non-empty");
                    // Keep the batch visible to the other workers; under
                    // fairness it goes to the far end so the next idle
                    // worker serves a *different* batch first.
                    if shared.fair {
                        match shared.steal {
                            StealPolicy::Fifo => q.push_back(b.clone()),
                            StealPolicy::Lifo => q.push_front(b.clone()),
                        }
                    } else {
                        match shared.steal {
                            StealPolicy::Fifo => q.push_front(b.clone()),
                            StealPolicy::Lifo => q.push_back(b.clone()),
                        }
                    }
                    break b;
                }
                qs = shared.available.wait(qs).unwrap();
            }
        };
        let grain = if shared.fair { FAIR_GRAIN } else { usize::MAX };
        let mut taken = 0usize;
        while let Some(i) = batch.claim() {
            // SAFETY: `i` was just claimed from `batch`.
            unsafe { batch.execute(i) };
            taken += 1;
            if taken >= grain {
                break;
            }
        }
    }
}

/// Execute task `i` against the given slot/result/flag/closure state —
/// the shared body of the borrowed (`run_erased`) and owned
/// (`run_owned`) trampolines, and of the inline `submit` path. Returns
/// true when the task failed (the batch should abort).
fn run_slot<T, R, F: Fn(T) -> Result<R>>(
    slots: &[Mutex<Option<T>>],
    results: &[Mutex<Option<Result<R>>>],
    failed: &AtomicBool,
    f: &F,
    i: usize,
) -> bool {
    let task = slots[i].lock().unwrap().take();
    let Some(task) = task else { return false };
    // Ordering audit (loom: `run_tasks_publishes_results`): `failed` is
    // Relaxed on both sides because it is advisory-only — a stale
    // `false` merely executes one more task whose result is then
    // discarded by the collector's first-error scan, and a stale `true`
    // cannot occur before some task actually failed (the store is
    // program-ordered after the failing result is recorded under its
    // slot mutex). No correctness property reads through this flag.
    if failed.load(Ordering::Relaxed) {
        // A sibling already failed: drop the task unexecuted (its result
        // stays `None`; the collector reports the recorded error).
        return false;
    }
    // A panicking task must still decrement `remaining` (the caller's
    // `execute` does) or the submitter would deadlock — convert it into
    // an error instead of unwinding through the worker loop.
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(task)))
        .unwrap_or_else(|_| Err(Error::Coordinator("executor task panicked".into())));
    let is_err = out.is_err();
    if is_err {
        failed.store(true, Ordering::Relaxed);
    }
    *results[i].lock().unwrap() = Some(out);
    is_err
}

/// Drain `results` in submission order; first recorded error wins, and
/// a shortfall without an error is the "lost tasks" invariant breach.
fn collect_results<R>(results: &[Mutex<Option<Result<R>>>]) -> Result<Vec<R>> {
    // Slots are drained through `lock()` rather than `into_inner()` —
    // the facade's loom double does not expose consuming accessors, and
    // after the wait every lock is uncontended anyway.
    let mut out = Vec::with_capacity(results.len());
    let mut first_err = None;
    for slot in results {
        match slot.lock().unwrap().take() {
            Some(Ok(v)) => out.push(v),
            Some(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
            None => {}
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    if out.len() != results.len() {
        return Err(Error::Coordinator("executor lost tasks".into()));
    }
    Ok(out)
}

/// Borrowed state of one `run_tasks` batch, erased behind `Batch::ctx`.
struct BatchCtx<'a, T, R, F> {
    slots: &'a [Mutex<Option<T>>],
    results: &'a [Mutex<Option<Result<R>>>],
    failed: &'a AtomicBool,
    f: &'a F,
}

/// Monomorphized trampoline: run task `i` of the batch behind `p`.
/// Returns true when this task failed (the batch should abort).
///
/// # Safety
/// `p` must point to a live `BatchCtx<'_, T, R, F>` and `i` must be a
/// claimed, not-yet-executed index into its slots.
unsafe fn run_erased<T: Send, R: Send, F: Fn(T) -> Result<R> + Sync>(
    p: *const (),
    i: usize,
) -> bool {
    // SAFETY: forwarded from the caller's contract.
    let ctx = unsafe { &*(p as *const BatchCtx<'_, T, R, F>) };
    run_slot(ctx.slots, ctx.results, ctx.failed, ctx.f, i)
}

/// Owned state of one `submit` batch, heap-pinned inside its
/// [`BatchHandle`] and erased behind `Batch::ctx`.
struct OwnedCtx<T, R, F> {
    slots: Vec<Mutex<Option<T>>>,
    results: Vec<Mutex<Option<Result<R>>>>,
    failed: AtomicBool,
    f: F,
}

/// Monomorphized trampoline for owned-context batches.
///
/// # Safety
/// `p` must point to a live `OwnedCtx<T, R, F>` and `i` must be a
/// claimed, not-yet-executed index into its slots. Liveness is the
/// handle's obligation: both `collect` and `Drop` wait for
/// `remaining == 0` before the `Box<OwnedCtx>` can free.
unsafe fn run_owned<T: Send, R: Send, F: Fn(T) -> Result<R> + Sync>(
    p: *const (),
    i: usize,
) -> bool {
    // SAFETY: forwarded from the caller's contract.
    let ctx = unsafe { &*(p as *const OwnedCtx<T, R, F>) };
    run_slot(&ctx.slots, &ctx.results, &ctx.failed, &ctx.f, i)
}

/// A non-blocking batch submitted via [`Executor::submit`]: poll with
/// [`done`](Self::done), contribute cycles with [`help`](Self::help),
/// block with [`wait`](Self::wait), and take the results (submission
/// order, first error wins) with [`collect`](Self::collect).
///
/// Dropping the handle **aborts** the batch: every unclaimed task is
/// cancelled, and the drop blocks only for tasks already running on
/// workers (their claims were made before the abort). That wait is what
/// keeps the erased context pointer sound — the `Box<OwnedCtx>` inside
/// the handle must outlive the last worker dereference, exactly the
/// frame-lifetime argument `run_tasks` makes for its stack context,
/// with the heap allocation as the "frame" (loom:
/// `submit_drop_aborts_unclaimed`).
pub struct BatchHandle<T, R, F> {
    /// `None` on the inline path (budget-1 executor, or an empty task
    /// list): the batch completed during `submit` itself.
    batch: Option<Arc<Batch>>,
    /// Heap-pinned so `Batch::ctx`'s raw pointer stays valid while the
    /// handle value moves around (queues of handles, returns).
    ctx: Box<OwnedCtx<T, R, F>>,
    /// Run time of the inline path (its queue wait is zero by
    /// construction).
    #[cfg(not(loom))]
    inline_run: Duration,
}

impl<T: Send, R: Send, F: Fn(T) -> Result<R> + Sync> BatchHandle<T, R, F> {
    /// True once every task has finished (or been aborted).
    ///
    /// The `Acquire` load pairs with the `Release` `fetch_sub`s in
    /// `Batch::execute`/`abort_rest`: observing 0 here makes every
    /// task's result write visible to a subsequent `collect`.
    pub fn done(&self) -> bool {
        match &self.batch {
            None => true,
            Some(b) => b.remaining.load(Ordering::Acquire) == 0,
        }
    }

    /// Claim and execute one of this batch's own tasks on the calling
    /// thread. Returns false when every task is already claimed — the
    /// holder's cue that only waiting remains.
    pub fn help(&self) -> bool {
        let Some(b) = &self.batch else { return false };
        match b.claim() {
            Some(i) => {
                // SAFETY: `i` was just claimed from this handle's own
                // batch, whose `OwnedCtx` is alive for as long as the
                // handle (self) is borrowed here.
                unsafe { b.execute(i) };
                true
            }
            None => false,
        }
    }

    /// Drive remaining unclaimed tasks on this thread, then block until
    /// tasks claimed by workers finish too.
    pub fn wait(&self) {
        let Some(b) = &self.batch else { return };
        while self.help() {}
        b.wait();
    }

    /// Wait for completion and take the results in submission order;
    /// the first task error (or panic, surfaced as
    /// `Error::Coordinator("executor task panicked")`) wins.
    pub fn collect(self) -> Result<Vec<R>> {
        self.wait();
        collect_results(&self.ctx.results)
        // Drop runs after this: abort_rest on an exhausted batch is a
        // no-op and the wait sees remaining == 0 immediately.
    }

    /// `(queue_wait, run_time)` for the batch — meaningful once
    /// [`done`](Self::done) is true (zeros before, and always zero
    /// queue wait on the inline path). Metrics only; under loom this
    /// returns zeros.
    #[cfg(not(loom))]
    pub fn timings(&self) -> (Duration, Duration) {
        match &self.batch {
            Some(b) => b.timing.queue_and_run(),
            None => (Duration::ZERO, self.inline_run),
        }
    }

    /// Loom double of [`Self::timings`]: stamps are not modeled.
    #[cfg(loom)]
    pub fn timings(&self) -> (Duration, Duration) {
        (Duration::ZERO, Duration::ZERO)
    }
}

impl<T, R, F> Drop for BatchHandle<T, R, F> {
    fn drop(&mut self) {
        if let Some(b) = &self.batch {
            // Cancel every unclaimed task, then wait out the claimed
            // in-flight ones: the `OwnedCtx` box must stay allocated
            // until the last worker dereference completes (loom:
            // `submit_drop_aborts_unclaimed`).
            b.abort_rest();
            b.wait();
        }
    }
}

/// The poll/block surface a unit of in-flight work exposes, abstracted
/// from where it runs: [`BatchHandle`] implements it for batches on the
/// local thread team, and `crate::dist`'s remote lease implements it
/// for batches leased to a worker process — so driver code can hold
/// either behind one bound without caring which side of the socket the
/// work landed on.
pub trait Completion {
    /// True once the unit of work has finished (or been abandoned).
    fn done(&self) -> bool;
    /// Block until [`done`](Self::done) is true, contributing cycles
    /// where the implementation can (a local batch self-helps; a remote
    /// lease just parks).
    fn wait(&self);
}

impl<T: Send, R: Send, F: Fn(T) -> Result<R> + Sync> Completion for BatchHandle<T, R, F> {
    fn done(&self) -> bool {
        BatchHandle::done(self)
    }

    fn wait(&self) {
        BatchHandle::wait(self)
    }
}

/// The shared work-stealing thread team (see the module docs).
///
/// Create one per run and hand it down by reference; it is `Sync`, so
/// the pipeline's source thread can share it through an `Arc` and
/// submit concurrently with in-task `run_tasks` calls. Dropping the
/// executor joins its background threads.
pub struct Executor {
    budget: usize,
    shared: Option<Arc<Shared>>,
    /// Background workers, spawned lazily by the first parallel batch
    /// (`spawned` flips once). Serial-fallback workloads — and the
    /// convenience entry points that build a throwaway executor but
    /// never submit a multi-task batch — therefore pay no thread
    /// spawn/join at all, matching the retired descriptor-style pool.
    handles: Mutex<Vec<JoinHandle<()>>>,
    spawned: AtomicBool,
}

impl Default for Executor {
    /// Team sized to the machine (available parallelism − 1, min 1) —
    /// what `knn_auto`, `Ihtc::run`, and `itis` use when the caller does
    /// not pass an executor explicitly.
    fn default() -> Self {
        Self::new(0)
    }
}

impl Executor {
    /// Executor with `workers` total threads (0 = machine default) and
    /// default steal policy/fairness.
    pub fn new(workers: usize) -> Self {
        Self::with_config(ExecutorConfig { workers, ..Default::default() })
    }

    /// Executor with explicit knobs. A budget of 1 never spawns
    /// background threads: every batch runs inline on the submitting
    /// thread, which is the exact serial path. Larger budgets spawn
    /// their `budget − 1` background workers lazily, on the first
    /// multi-task batch — construction itself is allocation-cheap.
    pub fn with_config(config: ExecutorConfig) -> Self {
        let budget = resolve_workers(config.workers);
        let shared = (budget > 1).then(|| {
            Arc::new(Shared {
                queues: Mutex::new(Default::default()),
                available: Condvar::new(),
                shutdown: AtomicBool::new(false),
                steal: config.steal,
                fair: config.fair_stages,
            })
        });
        Self { budget, shared, handles: Mutex::new(Vec::new()), spawned: AtomicBool::new(false) }
    }

    /// Spawn the background workers if no batch has needed them yet.
    fn ensure_spawned(&self) {
        let Some(shared) = &self.shared else { return };
        if self.spawned.load(Ordering::Acquire) {
            return;
        }
        let mut handles = self.handles.lock().unwrap();
        if self.spawned.load(Ordering::Relaxed) {
            return; // lost the race; workers already up
        }
        for i in 0..self.budget - 1 {
            let s = Arc::clone(shared);
            handles.push(thread::spawn_named(format!("ihtc-exec-{i}"), move || worker_loop(&s)));
        }
        // Release/Acquire on `spawned` (loom: `lazy_spawn_races_once`):
        // a fast-path reader that sees `true` skips the handles lock, so
        // the flag itself must publish "the team is up"; the double
        // check under the lock needs only Relaxed — the lock already
        // synchronizes with the spawning critical section.
        self.spawned.store(true, Ordering::Release);
    }

    /// Total thread budget (background workers + the submitting thread).
    pub fn workers(&self) -> usize {
        self.budget
    }

    /// Queue a batch and notify the team.
    fn enqueue(&self, batch: &Arc<Batch>, priority: Priority) {
        self.ensure_spawned();
        let shared = self.shared.as_ref().expect("enqueue requires a background team");
        {
            let mut qs = shared.queues.lock().unwrap();
            qs[priority.index()].push_back(Arc::clone(batch));
        }
        shared.available.notify_all();
    }

    /// Work-stealing execution of pre-built tasks (each typically owning
    /// disjoint `&mut` windows of a shared output buffer, so workers
    /// write results in place — no stitch copies). Results come back in
    /// task (submission-index) order regardless of which thread ran
    /// what; the first task error aborts the batch and is returned. The
    /// submitting thread participates in its own batch, so the call
    /// completes even when every background worker is busy with other
    /// submitters' batches. Submits at [`Priority::Normal`].
    pub fn run_tasks<T: Send, R: Send, F: Fn(T) -> Result<R> + Sync>(
        &self,
        tasks: Vec<T>,
        f: F,
    ) -> Result<Vec<R>> {
        let n = tasks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        if self.shared.is_none() || n == 1 {
            // Serial fast path: no queue round-trip, no erasure. Panics
            // convert to the same error as on the parallel path, so
            // error behavior never depends on the worker count.
            let mut out = Vec::with_capacity(n);
            for t in tasks {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(t)))
                    .unwrap_or_else(|_| {
                        Err(Error::Coordinator("executor task panicked".into()))
                    });
                out.push(r?);
            }
            return Ok(out);
        }
        let slots: Vec<Mutex<Option<T>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let results: Vec<Mutex<Option<Result<R>>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let failed = AtomicBool::new(false);
        let ctx = BatchCtx { slots: &slots, results: &results, failed: &failed, f: &f };
        // SAFETY of the erasure below: `batch.ctx` points at `ctx` on
        // this stack frame. Workers dereference it only for indices
        // obtained from `Batch::claim`, every claimed index decrements
        // `remaining` exactly once *after* its dereferences complete,
        // and this frame does not return before `batch.wait()` observes
        // `remaining == 0` — so no dereference can outlive `ctx`. Late
        // workers holding the `Arc<Batch>` after that point see the
        // cursor exhausted and never touch `ctx` again.
        let batch = Arc::new(Batch {
            n,
            cursor: AtomicUsize::new(0),
            remaining: AtomicUsize::new(n),
            run: run_erased::<T, R, F>,
            ctx: (&ctx as *const BatchCtx<'_, T, R, F>).cast(),
            done: Mutex::new(()),
            done_cv: Condvar::new(),
            #[cfg(not(loom))]
            timing: BatchTiming::start(),
        });
        self.enqueue(&batch, Priority::Normal);
        // Participate: the submitter is the batch's guaranteed worker.
        while let Some(i) = batch.claim() {
            // SAFETY: `i` was just claimed from `batch`.
            unsafe { batch.execute(i) };
        }
        batch.wait();
        drop(batch);
        // Collect in submission order; first error wins (matching the
        // retired `WorkerPool::run_tasks` contract).
        collect_results(&results)
    }

    /// Non-blocking batch submission: queue `tasks` at `priority` and
    /// return a [`BatchHandle`] to poll, help, or collect. Unlike
    /// [`run_tasks`](Self::run_tasks), the calling thread does NOT
    /// automatically participate — workers pick the batch up, and the
    /// holder can contribute via the handle. On a budget-1 executor
    /// there is no background team, so the batch runs inline right here
    /// (the exact serial path) and the handle is born complete.
    pub fn submit<T: Send, R: Send, F: Fn(T) -> Result<R> + Sync>(
        &self,
        tasks: Vec<T>,
        priority: Priority,
        f: F,
    ) -> BatchHandle<T, R, F> {
        let n = tasks.len();
        let ctx = Box::new(OwnedCtx {
            slots: tasks.into_iter().map(|t| Mutex::new(Some(t))).collect(),
            results: (0..n).map(|_| Mutex::new(None)).collect(),
            failed: AtomicBool::new(false),
            f,
        });
        if self.shared.is_none() || n == 0 {
            #[cfg(not(loom))]
            let t0 = Instant::now();
            for i in 0..n {
                run_slot(&ctx.slots, &ctx.results, &ctx.failed, &ctx.f, i);
            }
            return BatchHandle {
                batch: None,
                ctx,
                #[cfg(not(loom))]
                inline_run: t0.elapsed(),
            };
        }
        // SAFETY of the erasure below: `batch.ctx` points at the
        // heap-pinned `OwnedCtx` owned by the returned handle. Workers
        // dereference it only for claimed indices, each claimed index
        // decrements `remaining` exactly once after its dereferences
        // complete, and the handle (`collect` or Drop) waits for
        // `remaining == 0` before the box can free — so no dereference
        // outlives the pointee, wherever the handle value moves.
        let batch = Arc::new(Batch {
            n,
            cursor: AtomicUsize::new(0),
            remaining: AtomicUsize::new(n),
            run: run_owned::<T, R, F>,
            ctx: (&*ctx as *const OwnedCtx<T, R, F>).cast(),
            done: Mutex::new(()),
            done_cv: Condvar::new(),
            #[cfg(not(loom))]
            timing: BatchTiming::start(),
        });
        self.enqueue(&batch, priority);
        BatchHandle {
            batch: Some(batch),
            ctx,
            #[cfg(not(loom))]
            inline_run: Duration::ZERO,
        }
    }

    /// Process `0..n` in chunks of `chunk`; `f(start, end)` produces a
    /// partial result. Results come back in chunk order (ascending
    /// `start`). Errors from any worker abort the call.
    pub fn run_chunks<T: Send>(
        &self,
        n: usize,
        chunk: usize,
        f: impl Fn(usize, usize) -> Result<T> + Sync,
    ) -> Result<Vec<T>> {
        let chunk = chunk.max(1);
        let mut tasks = Vec::with_capacity(n.div_ceil(chunk.max(1)).max(1));
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            tasks.push((start, end));
            start = end;
        }
        self.run_tasks(tasks, |(s, e)| f(s, e))
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            {
                // Flip the flag under the queue lock so a worker between
                // its shutdown check and `wait` cannot miss the wakeup
                // (loom: `shutdown_wakeup_not_lost`). Relaxed suffices:
                // both the store and every worker's load happen inside
                // the queue-lock critical section, which synchronizes.
                let _guard = shared.queues.lock().unwrap();
                shared.shutdown.store(true, Ordering::Relaxed);
            }
            shared.available.notify_all();
        }
        // Drain under the lock, join outside it (`get_mut` is absent
        // from the facade's loom double; nothing else can hold this
        // lock during drop anyway).
        let handles: Vec<JoinHandle<()>> =
            self.handles.lock().unwrap().drain(..).collect();
        for handle in handles {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_workers_bounds() {
        assert_eq!(resolve_workers(3), 3);
        assert!(resolve_workers(0) >= 1);
    }

    #[test]
    fn run_tasks_preserves_order_and_runs_all() {
        for workers in [1usize, 2, 4] {
            let exec = Executor::new(workers);
            let tasks: Vec<usize> = (0..137).collect();
            let out = exec.run_tasks(tasks, |t| Ok(t * 2)).unwrap();
            assert_eq!(out, (0..137).map(|t| t * 2).collect::<Vec<_>>(), "workers={workers}");
            let empty: Vec<usize> = Vec::new();
            assert!(exec.run_tasks(empty, |t| Ok(t)).unwrap().is_empty());
        }
    }

    #[test]
    fn run_tasks_writes_through_mut_slices() {
        let exec = Executor::new(3);
        let mut buf = vec![0u32; 100];
        let tasks: Vec<(usize, &mut [u32])> =
            buf.chunks_mut(7).enumerate().map(|(i, c)| (i * 7, c)).collect();
        exec.run_tasks(tasks, |(start, chunk)| {
            for (o, slot) in chunk.iter_mut().enumerate() {
                *slot = (start + o) as u32;
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(buf, (0..100u32).collect::<Vec<_>>());
    }

    #[test]
    fn run_tasks_propagates_errors() {
        for workers in [1usize, 2] {
            let exec = Executor::new(workers);
            let res = exec.run_tasks((0..50usize).collect(), |t| {
                if t == 13 {
                    Err(Error::Coordinator("boom".into()))
                } else {
                    Ok(t)
                }
            });
            let err = res.unwrap_err();
            assert!(err.to_string().contains("boom"), "workers={workers}: {err}");
        }
    }

    #[test]
    fn run_tasks_converts_panics_to_errors() {
        // A panicking task must not deadlock the submitter (remaining
        // must still reach 0) and must surface as a Coordinator error —
        // on the serial fast path (workers = 1) exactly like on the
        // parallel path, so error behavior is worker-count independent.
        for workers in [1usize, 2] {
            let exec = Executor::new(workers);
            let res = exec.run_tasks((0..20usize).collect(), |t| {
                if t == 7 {
                    panic!("task exploded");
                }
                Ok(t)
            });
            let err = res.unwrap_err();
            assert!(err.to_string().contains("panicked"), "workers={workers}: {err}");
            // The executor survives for the next batch.
            let out = exec.run_tasks((0..5usize).collect(), Ok).unwrap();
            assert_eq!(out, vec![0, 1, 2, 3, 4], "workers={workers}");
        }
    }

    #[test]
    fn run_chunks_covers_all_indices_in_order() {
        let exec = Executor::new(4);
        let parts = exec.run_chunks(1003, 100, |s, e| Ok((s, e))).unwrap();
        let mut covered = vec![false; 1003];
        let mut last_start = None;
        for (s, e) in parts {
            if let Some(p) = last_start {
                assert!(s > p, "chunks out of order");
            }
            last_start = Some(s);
            for slot in covered.iter_mut().take(e).skip(s) {
                assert!(!*slot, "overlap at {s}..{e}");
                *slot = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn run_chunks_propagates_errors() {
        let exec = Executor::new(2);
        let res: Result<Vec<()>> = exec.run_chunks(100, 10, |s, _| {
            if s >= 50 {
                Err(Error::Coordinator("boom".into()))
            } else {
                Ok(())
            }
        });
        assert!(res.is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 4 submitters × 20 rounds — minutes under Miri; loom models the same shape
    fn concurrent_submitters_share_one_team() {
        // Four submitter threads, one 3-thread executor: every batch
        // completes with results in submission order, whatever the
        // interleaving. This is the concurrent-callers usage shape.
        let exec = Arc::new(Executor::new(3));
        let mut joins = Vec::new();
        for s in 0..4u64 {
            let exec = Arc::clone(&exec);
            joins.push(std::thread::spawn(move || {
                for round in 0..20u64 {
                    let tasks: Vec<u64> = (0..31).map(|i| s * 10_000 + round * 100 + i).collect();
                    let want: Vec<u64> = tasks.iter().map(|t| t * 3 + 1).collect();
                    let out = exec.run_tasks(tasks, |t| Ok(t * 3 + 1)).unwrap();
                    assert_eq!(out, want, "submitter {s} round {round}");
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 64 × 200k-iteration tasks — far too slow under Miri
    fn skewed_batches_self_balance() {
        // Steal-heavy smoke: one submitter's batch is 100× more
        // expensive per task; both finish correctly while sharing the
        // team (no static split to strand threads on the light batch).
        let exec = Arc::new(Executor::new(4));
        let heavy = {
            let exec = Arc::clone(&exec);
            std::thread::spawn(move || {
                exec.run_tasks((0..64usize).collect(), |t| {
                    let mut acc = 0u64;
                    for i in 0..200_000u64 {
                        acc = acc.wrapping_mul(31).wrapping_add(i ^ t as u64);
                    }
                    Ok(acc)
                })
                .unwrap()
            })
        };
        let light = exec.run_tasks((0..64usize).collect(), |t| Ok(t + 1)).unwrap();
        assert_eq!(light, (1..=64usize).collect::<Vec<_>>());
        assert_eq!(heavy.join().unwrap().len(), 64);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 4 configs × 200 tasks × 3 threads — slow under Miri, covered natively
    fn policies_do_not_change_results() {
        // Steal policy and fairness are scheduling-only: results are
        // keyed by submission index, so every combination is identical.
        let base: Vec<usize> = (0..200).map(|t| t * 7).collect();
        for steal in [StealPolicy::Fifo, StealPolicy::Lifo] {
            for fair in [false, true] {
                let exec = Executor::with_config(ExecutorConfig {
                    workers: 3,
                    steal,
                    fair_stages: fair,
                });
                let out = exec.run_tasks((0..200usize).collect(), |t| Ok(t * 7)).unwrap();
                assert_eq!(out, base, "steal={steal:?} fair={fair}");
            }
        }
    }

    #[test]
    fn single_worker_runs_inline() {
        let exec = Executor::new(1);
        assert_eq!(exec.workers(), 1);
        let out = exec.run_tasks(vec![1, 2, 3], |t| Ok(t * 10)).unwrap();
        assert_eq!(out, vec![10, 20, 30]);
    }

    #[test]
    fn drop_joins_cleanly_with_pending_nothing() {
        // Construct + drop without submitting: lazily-spawned workers
        // never come up, and drop is a no-op join.
        for _ in 0..8 {
            let exec = Executor::new(4);
            assert!(!exec.spawned.load(Ordering::Relaxed), "no batch → no threads");
            drop(exec);
        }
        // …and after a real batch, drop still joins cleanly.
        let exec = Executor::new(4);
        exec.run_tasks((0..8usize).collect(), Ok).unwrap();
        assert!(exec.spawned.load(Ordering::Relaxed));
        drop(exec);
    }

    #[test]
    fn submit_collect_matches_run_tasks_every_priority() {
        // The handle path returns the same ordered results as the
        // blocking path, for every budget and priority class — the
        // priority byte-invariance contract at the unit level.
        let want: Vec<usize> = (0..97).map(|t| t * 3 + 1).collect();
        for workers in [1usize, 2, 4] {
            for priority in Priority::ALL {
                let exec = Executor::new(workers);
                let h = exec.submit((0..97usize).collect(), priority, |t| Ok(t * 3 + 1));
                let out = h.collect().unwrap();
                assert_eq!(out, want, "workers={workers} priority={priority:?}");
            }
        }
    }

    #[test]
    fn submit_handle_polls_and_helps_to_completion() {
        // Even if the background team never touches the batch, the
        // holder can finish it alone through help(): done() must flip
        // and collect() must return everything in order.
        let exec = Executor::new(2);
        let h = exec.submit((0..40usize).collect(), Priority::Bulk, |t| Ok(t + 7));
        while h.help() {}
        h.wait();
        assert!(h.done());
        let (queue_wait, _run) = h.timings();
        let _ = queue_wait; // stamps exist once done; values are timing-dependent
        assert_eq!(h.collect().unwrap(), (7..47usize).collect::<Vec<_>>());
    }

    #[test]
    fn submit_surfaces_errors_and_panics() {
        for workers in [1usize, 2] {
            let exec = Executor::new(workers);
            let h = exec.submit((0..30usize).collect(), Priority::Normal, |t| {
                if t == 11 {
                    Err(Error::Coordinator("boom".into()))
                } else {
                    Ok(t)
                }
            });
            let err = h.collect().unwrap_err();
            assert!(err.to_string().contains("boom"), "workers={workers}: {err}");
            let h = exec.submit((0..30usize).collect(), Priority::Normal, |t| {
                if t == 3 {
                    panic!("task exploded");
                }
                Ok(t)
            });
            let err = h.collect().unwrap_err();
            assert!(err.to_string().contains("panicked"), "workers={workers}: {err}");
            // The executor survives for the next batch.
            let out = exec.submit(vec![1usize, 2], Priority::Normal, Ok).collect().unwrap();
            assert_eq!(out, vec![1, 2], "workers={workers}");
        }
    }

    #[test]
    fn dropping_handle_aborts_without_hanging() {
        use std::sync::atomic::AtomicUsize as StdAtomicUsize;
        for _ in 0..16 {
            let exec = Executor::new(3);
            let ran = StdAtomicUsize::new(0);
            let h = exec.submit((0..64usize).collect(), Priority::Normal, |t| {
                ran.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Ok(t)
            });
            // Drop without collecting: unclaimed tasks are cancelled,
            // in-flight ones finish; neither drop nor executor drop may
            // hang, and the team stays healthy for the next batch.
            drop(h);
            assert!(ran.load(std::sync::atomic::Ordering::Relaxed) <= 64);
            let out = exec.run_tasks(vec![5usize], Ok).unwrap();
            assert_eq!(out, vec![5]);
        }
    }

    #[test]
    fn inline_submit_reports_zero_queue_wait() {
        // Budget 1 ⇒ the batch runs during submit; the handle is born
        // complete with a zero queue-wait stamp (deterministic, unlike
        // the threaded stamps).
        let exec = Executor::new(1);
        let h = exec.submit(vec![1usize, 2, 3], Priority::High, |t| Ok(t * 2));
        assert!(h.done());
        let (queue_wait, _run) = h.timings();
        assert_eq!(queue_wait, Duration::ZERO);
        assert_eq!(h.collect().unwrap(), vec![2, 4, 6]);
    }

    #[test]
    fn concurrent_priority_submitters_keep_order() {
        // A High and a Bulk submitter share the team; each handle still
        // collects its own results in submission order.
        let exec = Arc::new(Executor::new(3));
        let bulk = {
            let exec = Arc::clone(&exec);
            std::thread::spawn(move || {
                exec.submit((0..50usize).collect(), Priority::Bulk, |t| Ok(t + 1000)).collect()
            })
        };
        let high =
            exec.submit((0..50usize).collect(), Priority::High, |t| Ok(t + 2000)).collect().unwrap();
        assert_eq!(high, (2000..2050usize).collect::<Vec<_>>());
        assert_eq!(bulk.join().unwrap().unwrap(), (1000..1050usize).collect::<Vec<_>>());
    }
}
