//! Loom model checking of the executor's unsafe concurrency core.
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` (run with
//! `cargo test --release --lib loom_`). Offline, the `loom` name
//! resolves to the std-backed shim in `rust/loom-shim` and every
//! scenario body executes once on real threads — a smoke pass. In CI's
//! `loom` job the real model checker is swapped in and each
//! `loom::model` call exhaustively explores thread interleavings up to
//! the `LOOM_MAX_PREEMPTIONS` bound, including every Relaxed-atomic
//! weak-memory outcome — this is what licenses the `Ordering::Relaxed`
//! arguments written on `Batch::claim`, `Batch::abort_rest`, and the
//! `failed` flag in `run_erased`.
//!
//! Scenario map (each name is referenced from the ordering-audit
//! comments in `exec/mod.rs`):
//!
//! * `loom_claim_is_exclusive_and_complete` — the claim/execute race:
//!   two claimers, every index executed exactly once.
//! * `loom_abort_rest_accounts_every_index_once` — a failing task's
//!   bulk-claim racing a live claimer: `remaining` reaches 0 exactly
//!   (no deadlock, no double-count), nothing executes twice.
//! * `loom_wait_notify_no_lost_wakeup` — the submitter's
//!   wait/notify_all handshake.
//! * `loom_run_tasks_publishes_results` — the full `run_tasks` path:
//!   lazy spawn, queue hand-off, result publication, drop/shutdown.
//! * `loom_concurrent_submitters_one_team` — two submitters sharing
//!   one background worker.
//! * `loom_lazy_spawn_races_once` — racing `ensure_spawned` calls
//!   bring up exactly one team.
//! * `loom_shutdown_wakeup_not_lost` — drop racing a worker that may
//!   sit anywhere between its shutdown check and its condvar wait.
//! * `loom_submit_handle_wait_no_lost_wakeup` — the non-blocking
//!   `submit` → `BatchHandle::collect` path: queue hand-off to a
//!   background worker, the handle's help/wait handshake, result
//!   publication through the owned context.
//! * `loom_submit_drop_aborts_unclaimed` — dropping an uncollected
//!   handle races the worker's claims: `abort_rest` + the drop-side
//!   wait must neither hang nor double-count, and no task may run
//!   after its cancellation.

use super::*;

/// Shared context for the raw-`Batch` scenarios: per-index execution
/// counters plus an optional index whose execution reports failure
/// (driving `abort_rest`).
struct CountCtx {
    executed: Vec<AtomicUsize>,
    abort_at: Option<usize>,
}

/// Counting trampoline with the same shape as `run_erased`.
///
/// # Safety
/// `p` must point to a live `CountCtx` whose `executed` has at least
/// `i + 1` slots, and `i` must come from `Batch::claim`.
unsafe fn run_counting(p: *const (), i: usize) -> bool {
    // SAFETY: forwarded from the caller's contract; the scenario keeps
    // the `CountCtx` alive on the submitting thread's stack until
    // `Batch::wait` has observed `remaining == 0`.
    let ctx = unsafe { &*(p as *const CountCtx) };
    ctx.executed[i].fetch_add(1, Ordering::Relaxed);
    ctx.abort_at == Some(i)
}

/// Build a raw batch over `ctx` with `n` tasks — the exact layout
/// `run_tasks` erects on its stack frame.
fn counting_batch(ctx: &CountCtx, n: usize) -> Arc<Batch> {
    Arc::new(Batch {
        n,
        cursor: AtomicUsize::new(0),
        remaining: AtomicUsize::new(n),
        run: run_counting,
        ctx: (ctx as *const CountCtx).cast(),
        done: Mutex::new(()),
        done_cv: Condvar::new(),
    })
}

/// Claim-and-execute until the batch is exhausted (a worker's inner
/// loop without the queue around it).
fn drain(batch: &Batch) {
    while let Some(i) = batch.claim() {
        // SAFETY: `i` was just claimed from `batch`, and the batch's
        // `CountCtx` outlives the submitter's `wait()` below.
        unsafe { batch.execute(i) };
    }
}

#[test]
fn loom_claim_is_exclusive_and_complete() {
    loom::model(|| {
        let ctx = CountCtx {
            executed: (0..2).map(|_| AtomicUsize::new(0)).collect(),
            abort_at: None,
        };
        let batch = counting_batch(&ctx, 2);
        let worker = {
            let batch = Arc::clone(&batch);
            thread::spawn_named("model-worker".into(), move || drain(&batch))
        };
        drain(&batch);
        batch.wait();
        worker.join().unwrap();
        for (i, slot) in ctx.executed.iter().enumerate() {
            assert_eq!(slot.load(Ordering::Relaxed), 1, "index {i} must run exactly once");
        }
        assert_eq!(batch.remaining.load(Ordering::Relaxed), 0);
    });
}

#[test]
fn loom_abort_rest_accounts_every_index_once() {
    loom::model(|| {
        let ctx = CountCtx {
            executed: (0..3).map(|_| AtomicUsize::new(0)).collect(),
            abort_at: Some(0),
        };
        let batch = counting_batch(&ctx, 3);
        let worker = {
            let batch = Arc::clone(&batch);
            thread::spawn_named("model-worker".into(), move || drain(&batch))
        };
        drain(&batch);
        // The exactly-once accounting property IS `wait` returning: a
        // missed decrement deadlocks here, a double decrement underflows
        // `remaining` (usize wrap keeps it nonzero) and also deadlocks.
        batch.wait();
        worker.join().unwrap();
        assert_eq!(batch.remaining.load(Ordering::Relaxed), 0);
        assert!(batch.cursor.load(Ordering::Relaxed) >= 3, "abort must exhaust the cursor");
        for (i, slot) in ctx.executed.iter().enumerate() {
            assert!(slot.load(Ordering::Relaxed) <= 1, "index {i} ran twice");
        }
        // Whoever claimed index 0 executed it (both drains run to
        // exhaustion), so the aborting task itself always runs.
        assert_eq!(ctx.executed[0].load(Ordering::Relaxed), 1);
    });
}

#[test]
fn loom_wait_notify_no_lost_wakeup() {
    loom::model(|| {
        let ctx = CountCtx {
            executed: vec![AtomicUsize::new(0)],
            abort_at: None,
        };
        let batch = counting_batch(&ctx, 1);
        let worker = {
            let batch = Arc::clone(&batch);
            thread::spawn_named("model-worker".into(), move || drain(&batch))
        };
        // The worker may decrement-and-notify before, during, or after
        // this wait's predicate check; the lock-before-notify protocol
        // must never strand the submitter.
        batch.wait();
        worker.join().unwrap();
        assert_eq!(ctx.executed[0].load(Ordering::Relaxed), 1);
    });
}

#[test]
fn loom_run_tasks_publishes_results() {
    loom::model(|| {
        let exec = Executor::new(2);
        let out = exec.run_tasks(vec![10usize, 20], |t| Ok(t * 2)).unwrap();
        assert_eq!(out, vec![20, 40]);
        // Drop is part of the model: the lazily-spawned worker must
        // observe shutdown and join from wherever the scheduler left it.
        drop(exec);
    });
}

#[test]
fn loom_concurrent_submitters_one_team() {
    loom::model(|| {
        let exec = Arc::new(Executor::new(2));
        let other = {
            let exec = Arc::clone(&exec);
            thread::spawn_named("model-submitter".into(), move || {
                exec.run_tasks(vec![1usize, 2], |t| Ok(t + 100)).unwrap()
            })
        };
        let mine = exec.run_tasks(vec![3usize, 4], |t| Ok(t + 200)).unwrap();
        assert_eq!(mine, vec![203, 204]);
        assert_eq!(other.join().unwrap(), vec![101, 102]);
    });
}

#[test]
fn loom_lazy_spawn_races_once() {
    loom::model(|| {
        let exec = Arc::new(Executor::new(2));
        let racer = {
            let exec = Arc::clone(&exec);
            thread::spawn_named("model-racer".into(), move || exec.ensure_spawned())
        };
        exec.ensure_spawned();
        racer.join().unwrap();
        assert_eq!(
            exec.handles.lock().unwrap().len(),
            1,
            "budget 2 ⇒ exactly one background worker, however the race lands"
        );
        assert!(exec.spawned.load(Ordering::Relaxed));
    });
}

#[test]
fn loom_shutdown_wakeup_not_lost() {
    loom::model(|| {
        let exec = Executor::new(2);
        exec.ensure_spawned();
        // Drop races the worker through every point of its loop —
        // including the window between its shutdown check and its
        // condvar wait. Model completion == no stranded worker.
        drop(exec);
    });
}

#[test]
fn loom_submit_handle_wait_no_lost_wakeup() {
    loom::model(|| {
        // Budget 2 ⇒ one background worker racing the handle holder
        // through submit → claim/execute → done-notify → collect. The
        // worker may finish before, during, or after the handle's
        // help/wait — collect must never strand (lost wakeup) and must
        // see every result (the remaining Release/Acquire edge).
        let exec = Executor::new(2);
        let h = exec.submit(vec![1usize, 2], Priority::High, |t| Ok(t + 10));
        assert_eq!(h.collect().unwrap(), vec![11, 12]);
        drop(exec);
    });
}

#[test]
fn loom_submit_drop_aborts_unclaimed() {
    loom::model(|| {
        let exec = Executor::new(2);
        let ran = AtomicUsize::new(0);
        let h = exec.submit(vec![(), (), ()], Priority::Bulk, |()| {
            ran.fetch_add(1, Ordering::Relaxed);
            Ok(())
        });
        // Drop without collecting: abort_rest bulk-claims whatever the
        // worker has not claimed yet, then waits out in-flight tasks so
        // the owned context cannot free under a live dereference. Model
        // completion == no hang; the counter bounds prove cancelled
        // tasks never ran.
        drop(h);
        assert!(ran.load(Ordering::Relaxed) <= 3);
        drop(exec);
    });
}
