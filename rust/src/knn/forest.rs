//! Sharded kd-forest: one kd-tree per contiguous data shard, queried
//! together with merged candidates.
//!
//! The single [`KdTree`] stops scaling at one NUMA node: construction is
//! one recursive partition over one permutation (parallelizable only
//! near the top of the tree), and the finished arena is a single cache
//! footprint every worker walks. The forest splits the point set into
//! `s` contiguous row shards, builds one independent tree per shard —
//! embarrassingly parallel on the shared [`Executor`], no serial planning
//! phase, no arena splice — and answers a query by probing every shard
//! tree into one shared [`TopK`] collector. It is also the unit of
//! distribution the ROADMAP's TeraHAC-style graph phase will scatter
//! across nodes: a shard tree plus its row range is self-contained.
//!
//! Exactness and determinism: each shard tree is exact over its shard,
//! the shards tile the rows, and every candidate flows through the same
//! `(distance, index)` total order all backends share — so the merged
//! lists are **byte-identical to [`super::knn_brute`]** for every shard
//! count and worker count (`rust/tests/knn_forest_parity.rs` pins this
//! down). Shard boundaries depend only on `(n, s)`, never on the pool.
//!
//! The struct doubles as its own workspace: [`KdForest::rebuild`] reuses
//! the per-tree node/box/permutation arenas across calls, so the ITIS
//! loop (whose level sizes shrink geometrically) re-indexes every level
//! without reallocating — [`crate::itis::ItisWorkspace`] holds one
//! forest for exactly this reason.

use super::kdtree::KdTree;
use super::{KnnLists, TopK};
use crate::exec::Executor;
use crate::linalg::Matrix;
use crate::Result;

/// Leaf size for shard trees (the same §Perf sweep minimum as the
/// single-tree default).
const LEAF_SIZE: usize = 12;

/// Query rows per pooled query task (matches the single-tree pooled
/// query path).
const QUERY_CHUNK: usize = 512;

/// A forest of per-shard kd-trees over the rows of a [`Matrix`].
#[derive(Debug, Default)]
pub struct KdForest {
    /// One tree per shard; arenas recycled across rebuilds.
    trees: Vec<KdTree>,
    /// Shard boundaries: shard `i` owns rows `bounds[i]..bounds[i + 1]`.
    bounds: Vec<usize>,
}

impl KdForest {
    /// Empty forest; [`Self::rebuild`] populates it and later calls
    /// recycle its arenas.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of shard trees currently built.
    pub fn shards(&self) -> usize {
        self.trees.len()
    }

    /// (Re)build the forest over `points` with `shards` contiguous row
    /// shards (clamped to at least one row per shard), one kd-tree per
    /// shard, built concurrently on `exec`. Shard boundaries are the
    /// deterministic `n/s` split (first `n % s` shards one row longer),
    /// and each shard tree is built by the serial single-tree recursion,
    /// so the forest is identical for every worker count. Tree arenas
    /// from a previous rebuild are reused (level sizes in the ITIS loop
    /// only shrink, so steady state allocates nothing).
    pub fn rebuild(&mut self, points: &Matrix, shards: usize, exec: &Executor) {
        let n = points.rows();
        let s = shards.max(1).min(n.max(1));
        let base = n / s;
        let rem = n % s;
        self.bounds.clear();
        self.bounds.push(0);
        let mut off = 0usize;
        for i in 0..s {
            off += base + usize::from(i < rem);
            self.bounds.push(off);
        }
        debug_assert_eq!(off, n);
        self.trees.resize_with(s, KdTree::default);
        let bounds = &self.bounds;
        let tasks: Vec<(usize, usize, &mut KdTree)> = self
            .trees
            .iter_mut()
            .enumerate()
            .map(|(i, tree)| (bounds[i], bounds[i + 1], tree))
            .collect();
        if exec.workers() > 1 && s > 1 {
            exec.run_tasks(tasks, |(s0, s1, tree)| {
                tree.rebuild_range(points, s0, s1, LEAF_SIZE);
                Ok(())
            })
            .expect("kd-forest build tasks are infallible");
        } else {
            for (s0, s1, tree) in tasks {
                tree.rebuild_range(points, s0, s1, LEAF_SIZE);
            }
        }
    }

    /// The self-contained "forest shard build + query block" work unit:
    /// rebuild this forest over `points` with `shards` shard trees, then
    /// answer the all-rows k-NN query into `out` via the pooled path.
    /// This is the unit a distributed worker (`crate::dist`) leases —
    /// the forest parity contract (byte-identical to `knn_brute` for any
    /// shards × workers) is what makes its output location-independent.
    pub fn build_query_block(
        &mut self,
        points: &Matrix,
        k: usize,
        shards: usize,
        exec: &Executor,
        out: &mut KnnLists,
    ) -> Result<()> {
        self.rebuild(points, shards, exec);
        self.knn_all_pool_into(points, k, exec, out)
    }

    /// k-NN lists for every indexed row (self excluded), writing into a
    /// reusable output buffer. Byte-identical to [`super::knn_brute`].
    pub fn knn_all_into(&self, points: &Matrix, k: usize, out: &mut KnnLists) -> Result<()> {
        let n = points.rows();
        super::validate_k(n, k)?;
        out.reset(n, k);
        self.knn_range_into(points, k, 0, n, &mut out.indices, &mut out.dists)
    }

    /// [`Self::knn_all_into`] sharded across the executor: disjoint
    /// query ranges are stolen chunk-by-chunk and written straight into
    /// `out`. Byte-identical to the serial path for any worker count
    /// (each query row's merged candidate set is independent of which
    /// worker computes it).
    pub fn knn_all_pool_into(
        &self,
        points: &Matrix,
        k: usize,
        exec: &Executor,
        out: &mut KnnLists,
    ) -> Result<()> {
        let n = points.rows();
        super::validate_k(n, k)?;
        out.reset(n, k);
        let KnnLists { indices, dists, .. } = out;
        let tasks: Vec<(usize, &mut [u32], &mut [f32])> = indices
            .chunks_mut(QUERY_CHUNK * k)
            .zip(dists.chunks_mut(QUERY_CHUNK * k))
            .enumerate()
            .map(|(ci, (is, ds))| (ci * QUERY_CHUNK, is, ds))
            .collect();
        exec.run_tasks(tasks, |(start, is, ds)| {
            let end = start + is.len() / k;
            self.knn_range_into(points, k, start, end, is, ds)
        })?;
        Ok(())
    }

    /// k-NN lists restricted to query rows `[start, end)`, written into
    /// caller-owned slices of length `(end - start) * k` each — the task
    /// unit the pooled query path distributes.
    ///
    /// Per-shard pruning: each query first ranks the shard trees by the
    /// minimum distance from the query to their *root* bounding box and
    /// probes them in that order, so the nearest shards tighten the
    /// [`TopK`] bound before farther shards are tested; a shard whose
    /// root box lies **strictly** beyond the current bound is skipped
    /// without descending it at all. This is the same strict-inequality
    /// rule the in-tree descent uses (boxes *at* the bound may still
    /// hold an index-tie winner), and the kept set is defined by the
    /// shared `(distance, index)` total order — independent of probe
    /// order — so pruning changes wall-clock only, never output bytes.
    pub fn knn_range_into(
        &self,
        points: &Matrix,
        k: usize,
        start: usize,
        end: usize,
        indices: &mut [u32],
        dists: &mut [f32],
    ) -> Result<()> {
        let n = points.rows();
        super::validate_k(n, k)?;
        assert!(start <= end && end <= n);
        assert!(!self.trees.is_empty(), "rebuild the forest before querying");
        debug_assert_eq!(*self.bounds.last().unwrap(), n, "forest built over a different matrix");
        let m = end - start;
        assert_eq!(indices.len(), m * k);
        assert_eq!(dists.len(), m * k);
        let mut top = TopK::new(k);
        let mut scratch: Vec<(f32, u32)> = Vec::with_capacity(k);
        let mut order: Vec<(f32, u32)> = Vec::with_capacity(self.trees.len());
        for i in start..end {
            top.reset();
            let q = points.row(i);
            order.clear();
            order.extend(
                self.trees
                    .iter()
                    .enumerate()
                    .map(|(t, tree)| (tree.root_bbox_min_dist(q), t as u32)),
            );
            // Deterministic near-to-far order (root distances are never
            // NaN: finite data, or +inf for an empty tree's box).
            order.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            for &(dmin, t) in &order {
                if dmin > top.bound() {
                    // Sorted ascending: every remaining shard is at
                    // least this far, and candidates strictly beyond
                    // the bound can never enter the kept set.
                    break;
                }
                self.trees[t as usize].knn_accumulate(points, q, i as u32, &mut top);
            }
            top.drain_sorted_into(&mut scratch);
            debug_assert_eq!(scratch.len(), k);
            let o = i - start;
            for (slot, &(d, j)) in scratch.iter().enumerate() {
                indices[o * k + slot] = j;
                dists[o * k + slot] = d;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::knn::knn_brute;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn forest_byte_identical_to_brute() {
        let ds = gaussian_mixture_paper(900, 91);
        let oracle = knn_brute(&ds.points, 5).unwrap();
        let exec = Executor::new(2);
        for shards in [1usize, 2, 3, 7] {
            let mut forest = KdForest::new();
            forest.rebuild(&ds.points, shards, &exec);
            assert_eq!(forest.shards(), shards);
            let mut out = KnnLists::default();
            forest.knn_all_into(&ds.points, 5, &mut out).unwrap();
            assert_eq!(out.indices, oracle.indices, "shards={shards}");
            assert_eq!(bits(&out.dists), bits(&oracle.dists), "shards={shards}");
        }
    }

    #[test]
    fn pooled_queries_match_serial_for_any_worker_count() {
        let ds = gaussian_mixture_paper(3000, 92);
        let build_exec = Executor::new(2);
        let mut forest = KdForest::new();
        forest.rebuild(&ds.points, 4, &build_exec);
        let mut serial = KnnLists::default();
        forest.knn_all_into(&ds.points, 4, &mut serial).unwrap();
        for workers in [1usize, 3] {
            let pool = Executor::new(workers);
            let mut pooled = KnnLists::default();
            forest.knn_all_pool_into(&ds.points, 4, &pool, &mut pooled).unwrap();
            assert_eq!(serial.indices, pooled.indices, "workers={workers}");
            assert_eq!(bits(&serial.dists), bits(&pooled.dists), "workers={workers}");
        }
    }

    #[test]
    fn rebuild_reuse_never_leaks_stale_state() {
        // Alternate between two datasets of different sizes on one
        // forest: every rebuild must give oracle-identical answers.
        let big = gaussian_mixture_paper(2000, 93);
        let small = gaussian_mixture_paper(700, 94);
        let exec = Executor::new(2);
        let mut forest = KdForest::new();
        let mut out = KnnLists::default();
        for ds in [&big, &small, &big] {
            forest.rebuild(&ds.points, 3, &exec);
            forest.knn_all_into(&ds.points, 4, &mut out).unwrap();
            let oracle = knn_brute(&ds.points, 4).unwrap();
            assert_eq!(out.indices, oracle.indices);
            assert_eq!(bits(&out.dists), bits(&oracle.dists));
        }
    }

    #[test]
    fn more_shards_than_rows_clamps() {
        let ds = gaussian_mixture_paper(40, 95);
        let exec = Executor::new(2);
        let mut forest = KdForest::new();
        forest.rebuild(&ds.points, 64, &exec);
        assert_eq!(forest.shards(), 40);
        let mut out = KnnLists::default();
        forest.knn_all_into(&ds.points, 3, &mut out).unwrap();
        let oracle = knn_brute(&ds.points, 3).unwrap();
        assert_eq!(out.indices, oracle.indices);
    }

    #[test]
    fn shard_pruning_keeps_byte_parity_on_separated_shards() {
        // Contiguous row blocks form far-apart blobs, so each shard's
        // root box is distant from most queries and the per-shard
        // pruning actually skips trees; output must still be
        // byte-identical to the oracle for every shard count.
        let n = 600usize;
        let mut data = Vec::with_capacity(n * 2);
        for i in 0..n {
            let blob = (i / 150) as f32; // 4 well-separated blobs
            data.push(blob * 1e4 + (i % 150) as f32 * 0.01);
            data.push(blob * -1e4 + ((i % 7) as f32).sin());
        }
        let m = Matrix::from_vec(data, n, 2).unwrap();
        let oracle = knn_brute(&m, 5).unwrap();
        let exec = Executor::new(2);
        let mut forest = KdForest::new();
        let mut out = KnnLists::default();
        for shards in [2usize, 4, 8] {
            forest.rebuild(&m, shards, &exec);
            forest.knn_all_into(&m, 5, &mut out).unwrap();
            assert_eq!(out.indices, oracle.indices, "shards={shards}");
            assert_eq!(bits(&out.dists), bits(&oracle.dists), "shards={shards}");
        }
    }

    #[test]
    fn rejects_degenerate_k() {
        let ds = gaussian_mixture_paper(10, 96);
        let exec = Executor::new(1);
        let mut forest = KdForest::new();
        forest.rebuild(&ds.points, 2, &exec);
        let mut out = KnnLists::default();
        assert!(forest.knn_all_into(&ds.points, 0, &mut out).is_err());
        assert!(forest.knn_all_into(&ds.points, 10, &mut out).is_err());
        assert!(forest.knn_all_into(&ds.points, 11, &mut out).is_err());
    }
}
