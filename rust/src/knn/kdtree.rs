//! Exact k-d tree for low-dimensional k-NN queries.
//!
//! The paper's complexity claim for TC rests on the `(t*−1)`-NN graph
//! being constructible in `O(k·n·log n)` when the covariate space is
//! low-dimensional (Friedman et al. 1976; Vaidya 1989). After the §5 PCA
//! step d is 2–7, squarely in k-d tree territory.
//!
//! Implementation notes (§Perf): nodes live in a flat arena with their
//! bounding boxes in a parallel flat `f32` arena (no per-node heap
//! indirection — the box pruning test is the hottest branch of the
//! query). Splits choose the axis of maximum spread at the median (via
//! `select_nth_unstable`); leaves hold up to `leaf_size` points and are
//! scanned linearly, which is both cache-friendly and what the Pallas
//! tile kernel mirrors at L1. Batch queries reuse one [`TopK`] and one
//! scratch buffer so the hot loop does not allocate.
//!
//! Parallel construction ([`KdTree::build_parallel`]) splits the top of
//! the tree serially into `~8×workers` disjoint permutation windows, has
//! the shared executor build one sub-arena per window, and splices the
//! sub-arenas back into a single flat arena. Because the planning phase
//! uses the same median/comparator as the serial recursion, the merged
//! arena (nodes, boxes, permutation) is **byte-identical** to the serial
//! build for every worker count.

use super::{KnnLists, TopK};
use crate::exec::Executor;
use crate::linalg::{sq_dist, Matrix};
use crate::Result;

/// Arena node: either an internal split or a leaf range into `perm`.
/// The node's bounding box lives at `bboxes[node_id * 2d ..]`.
#[derive(Clone, Debug)]
enum Node {
    Split { axis: u16, left: u32, right: u32 },
    Leaf { start: u32, end: u32 },
}

/// Append a node and its (possibly dim-padded) bounding box to an arena.
fn push_arena_node(
    nodes: &mut Vec<Node>,
    bboxes: &mut Vec<f32>,
    dim: usize,
    node: Node,
    lo: &[f32],
    hi: &[f32],
) -> u32 {
    let id = nodes.len() as u32;
    nodes.push(node);
    // Degenerate (empty-tree) boxes are padded to `dim`.
    for j in 0..dim.max(1) {
        bboxes.push(lo.get(j).copied().unwrap_or(f32::INFINITY));
    }
    for j in 0..dim.max(1) {
        bboxes.push(hi.get(j).copied().unwrap_or(f32::NEG_INFINITY));
    }
    id
}

/// Bounding box of the rows indexed by `perm`.
fn bbox_of(points: &Matrix, perm: &[u32]) -> (Vec<f32>, Vec<f32>) {
    let d = points.cols();
    let mut lo = vec![f32::INFINITY; d];
    let mut hi = vec![f32::NEG_INFINITY; d];
    for &i in perm {
        let row = points.row(i as usize);
        for j in 0..d {
            lo[j] = lo[j].min(row[j]);
            hi[j] = hi[j].max(row[j]);
        }
    }
    (lo, hi)
}

/// Axis of maximum spread and that spread (`-1.0` when no axis exists).
fn widest_axis(lo: &[f32], hi: &[f32]) -> (usize, f32) {
    let mut axis = 0usize;
    let mut best = -1.0f32;
    for j in 0..lo.len() {
        let spread = hi[j] - lo[j];
        if spread > best {
            best = spread;
            axis = j;
        }
    }
    (axis, best)
}

/// Median partition of `perm` on `axis` — the single comparator shared by
/// the serial recursion and the parallel planning phase, so both produce
/// the same permutation layout.
fn partition_median(points: &Matrix, perm: &mut [u32], axis: usize) -> usize {
    let mid = perm.len() / 2;
    perm.select_nth_unstable_by(mid, |&a, &b| {
        points
            .get(a as usize, axis)
            .partial_cmp(&points.get(b as usize, axis))
            .unwrap()
            .then(a.cmp(&b))
    });
    mid
}

/// Recursive arena construction over one permutation window. `offset` is
/// the window's global position within the full permutation (leaves store
/// global ranges). Returns the subtree root's arena id.
fn build_arena(
    points: &Matrix,
    perm: &mut [u32],
    offset: usize,
    leaf_size: usize,
    nodes: &mut Vec<Node>,
    bboxes: &mut Vec<f32>,
) -> u32 {
    let d = points.cols();
    let len = perm.len();
    let (lo, hi) = bbox_of(points, perm);
    let leaf = Node::Leaf { start: offset as u32, end: (offset + len) as u32 };
    if len <= leaf_size {
        return push_arena_node(nodes, bboxes, d, leaf, &lo, &hi);
    }
    let (axis, spread) = widest_axis(&lo, &hi);
    if spread <= 0.0 {
        // All points identical: force a leaf to avoid infinite recursion.
        return push_arena_node(nodes, bboxes, d, leaf, &lo, &hi);
    }
    let mid = partition_median(points, perm, axis);
    let (left_perm, right_perm) = perm.split_at_mut(mid);
    let left = build_arena(points, left_perm, offset, leaf_size, nodes, bboxes);
    let right = build_arena(points, right_perm, offset + mid, leaf_size, nodes, bboxes);
    push_arena_node(nodes, bboxes, d, Node::Split { axis: axis as u16, left, right }, &lo, &hi)
}

/// Top-of-tree plan produced by the serial partitioning phase of the
/// parallel build: internal splits plus leaf *tasks* (permutation
/// windows) the executor builds concurrently.
enum Plan {
    Task { offset: usize, len: usize },
    Split { axis: u16, lo: Vec<f32>, hi: Vec<f32>, left: Box<Plan>, right: Box<Plan> },
}

/// Serially partition `perm` until every remaining window is at most
/// `task_len` rows (or degenerate), recording the split skeleton.
fn make_plan(points: &Matrix, perm: &mut [u32], offset: usize, task_len: usize) -> Plan {
    let len = perm.len();
    if len <= task_len {
        return Plan::Task { offset, len };
    }
    let (lo, hi) = bbox_of(points, perm);
    let (axis, spread) = widest_axis(&lo, &hi);
    if spread <= 0.0 {
        return Plan::Task { offset, len };
    }
    let mid = partition_median(points, perm, axis);
    let (left_perm, right_perm) = perm.split_at_mut(mid);
    let left = Box::new(make_plan(points, left_perm, offset, task_len));
    let right = Box::new(make_plan(points, right_perm, offset + mid, task_len));
    Plan::Split { axis: axis as u16, lo, hi, left, right }
}

/// In-order task windows of a plan (ascending, disjoint, covering 0..n).
fn plan_tasks(plan: &Plan, out: &mut Vec<(usize, usize)>) {
    match plan {
        Plan::Task { offset, len } => out.push((*offset, *len)),
        Plan::Split { left, right, .. } => {
            plan_tasks(left, out);
            plan_tasks(right, out);
        }
    }
}

/// Splice the per-task sub-arenas into the final arena following the
/// plan's post-order, rebasing child ids; returns the root id. The
/// resulting arena layout equals the serial build's exactly.
fn merge_plan(
    plan: &Plan,
    arenas: &mut [Option<(Vec<Node>, Vec<f32>, u32)>],
    next: &mut usize,
    nodes: &mut Vec<Node>,
    bboxes: &mut Vec<f32>,
    dim: usize,
) -> u32 {
    match plan {
        Plan::Task { .. } => {
            let (task_nodes, task_bboxes, task_root) =
                arenas[*next].take().expect("each task arena spliced once");
            *next += 1;
            let base = nodes.len() as u32;
            for node in task_nodes {
                nodes.push(match node {
                    Node::Leaf { start, end } => Node::Leaf { start, end },
                    Node::Split { axis, left, right } => {
                        Node::Split { axis, left: left + base, right: right + base }
                    }
                });
            }
            bboxes.extend_from_slice(&task_bboxes);
            base + task_root
        }
        Plan::Split { axis, lo, hi, left, right } => {
            let l = merge_plan(left, arenas, next, nodes, bboxes, dim);
            let r = merge_plan(right, arenas, next, nodes, bboxes, dim);
            push_arena_node(
                nodes,
                bboxes,
                dim,
                Node::Split { axis: *axis, left: l, right: r },
                lo,
                hi,
            )
        }
    }
}

/// An immutable k-d tree over the rows of a [`Matrix`].
#[derive(Debug)]
pub struct KdTree {
    nodes: Vec<Node>,
    /// `lo[d] ++ hi[d]` per node, indexed by node id.
    bboxes: Vec<f32>,
    /// Permutation of row indices; leaves own contiguous ranges.
    perm: Vec<u32>,
    root: u32,
    dim: usize,
    leaf_size: usize,
}

impl Default for KdTree {
    /// Empty placeholder over zero rows — the state a
    /// [`super::forest::KdForest`] slot holds before its first
    /// [`Self::rebuild_range`]. Queries on it find nothing.
    fn default() -> Self {
        Self::build(&Matrix::zeros(0, 0))
    }
}

impl KdTree {
    /// Build with the default leaf size (tuned in the §Perf pass: the
    /// flat-arena + nearest-child-first query favors small leaves; 12
    /// was the sweep minimum at n = 10⁵, d = 2).
    pub fn build(points: &Matrix) -> Self {
        Self::build_with_leaf_size(points, 12)
    }

    /// Build with an explicit leaf size.
    pub fn build_with_leaf_size(points: &Matrix, leaf_size: usize) -> Self {
        let n = points.rows();
        let d = points.cols();
        let leaf_size = leaf_size.max(1);
        let mut perm: Vec<u32> = (0..n as u32).collect();
        let cap = 2 * (n / leaf_size + 1);
        let mut nodes = Vec::with_capacity(cap);
        let mut bboxes = Vec::with_capacity(cap * 2 * d.max(1));
        let root = if n == 0 {
            push_arena_node(
                &mut nodes,
                &mut bboxes,
                d,
                Node::Leaf { start: 0, end: 0 },
                &[f32::INFINITY],
                &[f32::NEG_INFINITY],
            )
        } else {
            build_arena(points, &mut perm, 0, leaf_size, &mut nodes, &mut bboxes)
        };
        KdTree { nodes, bboxes, perm, root, dim: d, leaf_size }
    }

    /// Build with node partitioning parallelized over the shared
    /// executor (default leaf size). Output is byte-identical to
    /// [`Self::build`].
    pub fn build_parallel(points: &Matrix, exec: &Executor) -> Self {
        Self::build_parallel_with_leaf_size(points, 12, exec)
    }

    /// [`Self::build_parallel`] with an explicit leaf size. Small inputs
    /// and single-worker executors fall back to the serial build.
    pub fn build_parallel_with_leaf_size(
        points: &Matrix,
        leaf_size: usize,
        exec: &Executor,
    ) -> Self {
        let n = points.rows();
        let workers = exec.workers();
        if workers <= 1 || n < 4096 {
            return Self::build_with_leaf_size(points, leaf_size);
        }
        let d = points.cols();
        let leaf_size = leaf_size.max(1);
        let mut perm: Vec<u32> = (0..n as u32).collect();
        // ~8 tasks per worker so stealing evens out density skew, but
        // never smaller than a few leaves per task.
        let task_len = (n / (workers * 8)).max(leaf_size.max(256));
        let plan = make_plan(points, &mut perm, 0, task_len);
        let mut ranges = Vec::new();
        plan_tasks(&plan, &mut ranges);
        // Hand each task its disjoint mutable window of the permutation.
        let mut tasks: Vec<(usize, &mut [u32])> = Vec::with_capacity(ranges.len());
        let mut rest: &mut [u32] = &mut perm;
        let mut consumed = 0usize;
        for &(off, len) in &ranges {
            debug_assert_eq!(off, consumed);
            let window = std::mem::take(&mut rest);
            let (head, tail) = window.split_at_mut(len);
            tasks.push((off, head));
            rest = tail;
            consumed += len;
        }
        debug_assert_eq!(consumed, n);
        let arenas = exec
            .run_tasks(tasks, |(off, window)| {
                let mut nodes = Vec::new();
                let mut bboxes = Vec::new();
                let root = build_arena(points, window, off, leaf_size, &mut nodes, &mut bboxes);
                Ok((nodes, bboxes, root))
            })
            .expect("kd-tree build tasks are infallible");
        let mut arenas: Vec<Option<(Vec<Node>, Vec<f32>, u32)>> =
            arenas.into_iter().map(Some).collect();
        let cap = 2 * (n / leaf_size + 1);
        let mut nodes = Vec::with_capacity(cap);
        let mut bboxes = Vec::with_capacity(cap * 2 * d.max(1));
        let mut next = 0usize;
        let root = merge_plan(&plan, &mut arenas, &mut next, &mut nodes, &mut bboxes, d);
        KdTree { nodes, bboxes, perm, root, dim: d, leaf_size }
    }

    /// Configured leaf size.
    pub fn leaf_size(&self) -> usize {
        self.leaf_size
    }

    /// Number of rows this tree indexes.
    pub fn len(&self) -> usize {
        self.perm.len()
    }

    /// True when the tree indexes no rows.
    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// Rebuild this tree in place over the **global** row range
    /// `[start, end)` of `points`, reusing the node/box/permutation
    /// arenas from the previous build (capacities only grow). This is the
    /// construction unit [`super::forest::KdForest`] parallelizes: the
    /// permutation holds global row ids, so query results need no index
    /// translation, and the recursion is the exact serial
    /// [`Self::build_with_leaf_size`] algorithm — the tree over
    /// `[start, end)` is identical however many sibling shards build
    /// concurrently.
    pub fn rebuild_range(&mut self, points: &Matrix, start: usize, end: usize, leaf_size: usize) {
        debug_assert!(start <= end && end <= points.rows());
        let d = points.cols();
        let leaf_size = leaf_size.max(1);
        self.nodes.clear();
        self.bboxes.clear();
        self.perm.clear();
        self.perm.extend(start as u32..end as u32);
        self.dim = d;
        self.leaf_size = leaf_size;
        self.root = if start == end {
            push_arena_node(
                &mut self.nodes,
                &mut self.bboxes,
                d,
                Node::Leaf { start: 0, end: 0 },
                &[f32::INFINITY],
                &[f32::NEG_INFINITY],
            )
        } else {
            build_arena(points, &mut self.perm, 0, leaf_size, &mut self.nodes, &mut self.bboxes)
        };
    }

    /// Push this tree's candidates for query `q` into an existing
    /// [`TopK`] collector (self-exclusion via `exclude`; `u32::MAX`
    /// keeps all). [`super::forest::KdForest`] merges per-shard
    /// candidates through one collector this way: the shared
    /// `(distance, index)` total order makes the merged result identical
    /// to a single tree over the union of the shards, and an already
    /// part-filled collector tightens the pruning bound for later
    /// shards.
    pub fn knn_accumulate(&self, points: &Matrix, q: &[f32], exclude: u32, top: &mut TopK) {
        debug_assert_eq!(q.len(), self.dim);
        self.search(points, q, exclude, self.root, top);
    }

    /// Minimum squared distance from `q` to this tree's *root* bounding
    /// box — the whole shard's box. [`super::forest::KdForest`] orders
    /// shard trees by this and skips trees strictly beyond the current
    /// [`TopK`] bound (the same strict-inequality pruning rule the
    /// in-tree descent uses), so far shards are never descended at all.
    /// An empty tree reports `+inf` (its degenerate box contains nothing).
    #[inline]
    pub fn root_bbox_min_dist(&self, q: &[f32]) -> f32 {
        self.bbox_min_dist(self.root, q)
    }

    /// Minimum squared distance from `q` to a node's bounding box.
    #[inline]
    fn bbox_min_dist(&self, node: u32, q: &[f32]) -> f32 {
        let d = self.dim.max(1);
        let base = node as usize * 2 * d;
        let lo = &self.bboxes[base..base + d];
        let hi = &self.bboxes[base + d..base + 2 * d];
        let mut acc = 0.0f32;
        for j in 0..q.len().min(d) {
            let v = q[j];
            let e = if v < lo[j] {
                lo[j] - v
            } else if v > hi[j] {
                v - hi[j]
            } else {
                0.0
            };
            acc += e * e;
        }
        acc
    }

    fn search(&self, points: &Matrix, q: &[f32], exclude: u32, node: u32, top: &mut TopK) {
        match self.nodes[node as usize] {
            Node::Leaf { start, end } => {
                for &idx in &self.perm[start as usize..end as usize] {
                    if idx == exclude {
                        continue;
                    }
                    top.push(sq_dist(q, points.row(idx as usize)), idx);
                }
            }
            Node::Split { axis, left, right } => {
                // Descend into the child whose box is closer first. Boxes
                // *at* the bound may still hold an index-tie winner, so
                // only prune strictly beyond it (see `TopK::bound`).
                let dl = self.bbox_min_dist(left, q);
                let dr = self.bbox_min_dist(right, q);
                let _ = axis;
                let (near, near_d, far, far_d) =
                    if dl <= dr { (left, dl, right, dr) } else { (right, dr, left, dl) };
                if near_d <= top.bound() {
                    self.search(points, q, exclude, near, top);
                }
                if far_d <= top.bound() {
                    self.search(points, q, exclude, far, top);
                }
            }
        }
    }

    /// k nearest neighbors of the query vector `q` among the indexed
    /// points, excluding index `exclude` (pass `u32::MAX` to keep all).
    pub fn knn_query(&self, points: &Matrix, q: &[f32], k: usize, exclude: u32) -> Vec<(f32, u32)> {
        assert_eq!(q.len(), self.dim);
        let mut top = TopK::new(k);
        self.search(points, q, exclude, self.root, &mut top);
        top.into_sorted()
    }

    /// k-NN lists for every indexed point (self excluded): the TC step-1
    /// workhorse. Allocation-free per query (one reused [`TopK`] and
    /// scratch buffer), and queries are issued in tree (leaf) order so
    /// consecutive queries share search paths and cache lines (§Perf).
    pub fn knn_all(&self, points: &Matrix, k: usize) -> Result<KnnLists> {
        let mut out = KnnLists::default();
        self.knn_all_into(points, k, &mut out)?;
        Ok(out)
    }

    /// [`Self::knn_all`] writing into a reusable output buffer.
    pub fn knn_all_into(&self, points: &Matrix, k: usize, out: &mut KnnLists) -> Result<()> {
        let n = points.rows();
        super::validate_k(n, k)?;
        out.reset(n, k);
        let mut top = TopK::new(k);
        let mut scratch: Vec<(f32, u32)> = Vec::with_capacity(k);
        for &pi in &self.perm {
            let i = pi as usize;
            top.reset();
            self.search(points, points.row(i), pi, self.root, &mut top);
            top.drain_sorted_into(&mut scratch);
            debug_assert_eq!(scratch.len(), k);
            for (slot, &(d, j)) in scratch.iter().enumerate() {
                out.indices[i * k + slot] = j;
                out.dists[i * k + slot] = d;
            }
        }
        Ok(())
    }

    /// [`Self::knn_all`] sharded across the executor: disjoint query
    /// ranges are stolen chunk-by-chunk and written straight into `out`
    /// (no per-shard buffers, no stitch copy). Byte-identical to the
    /// serial path for any worker count.
    pub fn knn_all_pool_into(
        &self,
        points: &Matrix,
        k: usize,
        exec: &Executor,
        out: &mut KnnLists,
    ) -> Result<()> {
        let n = points.rows();
        super::validate_k(n, k)?;
        out.reset(n, k);
        const CHUNK: usize = 512;
        let KnnLists { indices, dists, .. } = out;
        let tasks: Vec<(usize, &mut [u32], &mut [f32])> = indices
            .chunks_mut(CHUNK * k)
            .zip(dists.chunks_mut(CHUNK * k))
            .enumerate()
            .map(|(ci, (is, ds))| (ci * CHUNK, is, ds))
            .collect();
        exec.run_tasks(tasks, |(start, is, ds)| {
            let end = start + is.len() / k;
            self.knn_range_into(points, k, start, end, is, ds)
        })?;
        Ok(())
    }

    /// [`Self::knn_all`] restricted to query rows `[start, end)` — the
    /// shard unit the coordinator's executor distributes.
    pub fn knn_range(
        &self,
        points: &Matrix,
        k: usize,
        start: usize,
        end: usize,
    ) -> Result<KnnLists> {
        let n = points.rows();
        super::validate_k(n, k)?;
        assert!(start <= end && end <= n);
        let m = end - start;
        let mut out = KnnLists { k, indices: vec![0u32; m * k], dists: vec![0f32; m * k] };
        {
            let KnnLists { indices, dists, .. } = &mut out;
            self.knn_range_into(points, k, start, end, indices, dists)?;
        }
        Ok(out)
    }

    /// [`Self::knn_range`] writing into caller-owned slices of length
    /// `(end - start) * k` each.
    pub fn knn_range_into(
        &self,
        points: &Matrix,
        k: usize,
        start: usize,
        end: usize,
        indices: &mut [u32],
        dists: &mut [f32],
    ) -> Result<()> {
        let n = points.rows();
        super::validate_k(n, k)?;
        assert!(start <= end && end <= n);
        let m = end - start;
        assert_eq!(indices.len(), m * k);
        assert_eq!(dists.len(), m * k);
        let mut top = TopK::new(k);
        let mut scratch: Vec<(f32, u32)> = Vec::with_capacity(k);
        for i in start..end {
            top.reset();
            self.search(points, points.row(i), i as u32, self.root, &mut top);
            top.drain_sorted_into(&mut scratch);
            debug_assert_eq!(scratch.len(), k);
            let o = i - start;
            for (slot, &(d, j)) in scratch.iter().enumerate() {
                indices[o * k + slot] = j;
                dists[o * k + slot] = d;
            }
        }
        Ok(())
    }

    /// All indexed points within squared radius `r2` of `q` (used by
    /// DBSCAN's region queries), excluding `exclude`.
    pub fn radius_query(&self, points: &Matrix, q: &[f32], r2: f32, exclude: u32) -> Vec<u32> {
        let mut out = Vec::new();
        self.radius_rec(points, q, r2, exclude, self.root, &mut out);
        out
    }

    fn radius_rec(
        &self,
        points: &Matrix,
        q: &[f32],
        r2: f32,
        exclude: u32,
        node: u32,
        out: &mut Vec<u32>,
    ) {
        if self.bbox_min_dist(node, q) > r2 {
            return;
        }
        match self.nodes[node as usize] {
            Node::Leaf { start, end } => {
                for &idx in &self.perm[start as usize..end as usize] {
                    if idx == exclude {
                        continue;
                    }
                    if sq_dist(q, points.row(idx as usize)) <= r2 {
                        out.push(idx);
                    }
                }
            }
            Node::Split { left, right, .. } => {
                self.radius_rec(points, q, r2, exclude, left, out);
                self.radius_rec(points, q, r2, exclude, right, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::knn::knn_brute;

    #[test]
    fn matches_brute_force_distances() {
        let ds = gaussian_mixture_paper(800, 31);
        let tree = KdTree::build(&ds.points);
        let brute = knn_brute(&ds.points, 6).unwrap();
        let fast = tree.knn_all(&ds.points, 6).unwrap();
        // Deterministic (distance, index) candidate order makes the two
        // backends agree exactly.
        assert_eq!(brute.indices, fast.indices);
        for i in 0..800 {
            let a = brute.distances(i);
            let b = fast.distances(i);
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() <= 1e-4 * (1.0 + x.abs()), "row {i}");
            }
        }
    }

    #[test]
    fn duplicate_points_handled() {
        // 100 copies of the same point + 10 distinct ones.
        let mut data = vec![1.0f32; 200];
        for i in 0..10 {
            data.push(i as f32 * 3.0);
            data.push(-(i as f32));
        }
        let m = Matrix::from_vec(data, 110, 2).unwrap();
        let tree = KdTree::build_with_leaf_size(&m, 4);
        let knn = tree.knn_all(&m, 3).unwrap();
        // A duplicated point's neighbors are other duplicates at distance 0.
        assert_eq!(knn.distances(0), &[0.0, 0.0, 0.0]);
        // Ties resolve to the smallest indices (self excluded).
        assert_eq!(knn.neighbors(0), &[1, 2, 3]);
        assert_eq!(knn.neighbors(5), &[0, 1, 2]);
    }

    #[test]
    fn parallel_build_byte_identical_to_serial() {
        let ds = gaussian_mixture_paper(6000, 36);
        let serial = KdTree::build(&ds.points);
        let base = serial.knn_all(&ds.points, 4).unwrap();
        for workers in [1usize, 2, 4] {
            let exec = Executor::new(workers);
            let tree = KdTree::build_parallel(&ds.points, &exec);
            assert_eq!(tree.perm, serial.perm, "workers={workers}");
            let got = tree.knn_all(&ds.points, 4).unwrap();
            assert_eq!(base.indices, got.indices, "workers={workers}");
            let bb: Vec<u32> = base.dists.iter().map(|d| d.to_bits()).collect();
            let gb: Vec<u32> = got.dists.iter().map(|d| d.to_bits()).collect();
            assert_eq!(bb, gb, "workers={workers}");
        }
    }

    #[test]
    fn pool_queries_match_serial() {
        let ds = gaussian_mixture_paper(3000, 37);
        let tree = KdTree::build(&ds.points);
        let serial = tree.knn_all(&ds.points, 5).unwrap();
        for workers in [1usize, 3] {
            let exec = Executor::new(workers);
            let mut pooled = KnnLists::default();
            tree.knn_all_pool_into(&ds.points, 5, &exec, &mut pooled).unwrap();
            assert_eq!(serial.indices, pooled.indices, "workers={workers}");
            assert_eq!(serial.dists, pooled.dists, "workers={workers}");
        }
    }

    #[test]
    fn radius_query_exact() {
        let ds = gaussian_mixture_paper(500, 32);
        let tree = KdTree::build(&ds.points);
        let q = ds.points.row(17).to_vec();
        let r2 = 0.5f32;
        let mut expect: Vec<u32> = (0..500u32)
            .filter(|&j| j != 17 && sq_dist(&q, ds.points.row(j as usize)) <= r2)
            .collect();
        let mut got = tree.radius_query(&ds.points, &q, r2, 17);
        expect.sort_unstable();
        got.sort_unstable();
        assert_eq!(expect, got);
    }

    #[test]
    fn small_leaf_sizes_consistent() {
        let ds = gaussian_mixture_paper(300, 33);
        let t1 = KdTree::build_with_leaf_size(&ds.points, 1);
        let t64 = KdTree::build_with_leaf_size(&ds.points, 64);
        let a = t1.knn_all(&ds.points, 4).unwrap();
        let b = t64.knn_all(&ds.points, 4).unwrap();
        for i in 0..300 {
            for (x, y) in a.distances(i).iter().zip(b.distances(i)) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn query_excludes_requested_index() {
        let ds = gaussian_mixture_paper(100, 34);
        let tree = KdTree::build(&ds.points);
        let res = tree.knn_query(&ds.points, ds.points.row(5), 10, 5);
        assert!(res.iter().all(|&(_, j)| j != 5));
        let res_all = tree.knn_query(&ds.points, ds.points.row(5), 10, u32::MAX);
        assert!(res_all.iter().any(|&(d, j)| j == 5 && d == 0.0));
    }

    #[test]
    fn knn_range_matches_knn_all() {
        let ds = gaussian_mixture_paper(400, 35);
        let tree = KdTree::build(&ds.points);
        let all = tree.knn_all(&ds.points, 4).unwrap();
        let mid = tree.knn_range(&ds.points, 4, 100, 250).unwrap();
        for i in 0..150 {
            assert_eq!(all.neighbors(100 + i), mid.neighbors(i));
        }
    }

    #[test]
    fn rebuild_range_matches_fresh_build() {
        let ds = gaussian_mixture_paper(1200, 38);
        let mut tree = KdTree::default();
        tree.rebuild_range(&ds.points, 0, 1200, 12);
        let fresh = KdTree::build(&ds.points);
        assert_eq!(tree.perm, fresh.perm);
        let a = tree.knn_all(&ds.points, 4).unwrap();
        let b = fresh.knn_all(&ds.points, 4).unwrap();
        assert_eq!(a.indices, b.indices);
        // Arena reuse on a smaller, offset range must not leak stale
        // state, and leaves must keep global row ids.
        tree.rebuild_range(&ds.points, 100, 500, 12);
        assert_eq!(tree.len(), 400);
        let res = tree.knn_query(&ds.points, ds.points.row(0), 3, u32::MAX);
        assert_eq!(res.len(), 3);
        assert!(res.iter().all(|&(_, j)| (100u32..500).contains(&j)));
    }

    #[test]
    fn empty_and_tiny_trees() {
        let empty = Matrix::zeros(0, 2);
        let _ = KdTree::build(&empty); // must not panic
        let one = Matrix::from_vec(vec![1.0, 2.0], 1, 2).unwrap();
        let t = KdTree::build(&one);
        let res = t.knn_query(&one, &[0.0, 0.0], 1, u32::MAX);
        assert_eq!(res.len(), 1);
    }

    #[test]
    fn miri_arena_reuse_smoke() {
        // The kd-tree slice of the CI Miri lane (the name matches the
        // job's test filter): a deliberately tiny input — Miri runs at
        // ~100× native cost — driving the arena paths the forest leans
        // on: fresh build, in-place rebuild over an offset range with
        // reused (and stale-capacity) arenas, and a query through the
        // spliced node/bbox layout. Executor parallelism is covered by
        // the exec tests; below the parallel-build cutoff this stays on
        // the serial arena code by design.
        let data: Vec<f32> = (0..40u32)
            .flat_map(|i| [(i % 7) as f32, (i / 7) as f32 * 1.5])
            .collect();
        let m = Matrix::from_vec(data, 40, 2).unwrap();
        let fresh = KdTree::build_with_leaf_size(&m, 3);
        let mut reused = KdTree::default();
        reused.rebuild_range(&m, 0, 40, 3);
        assert_eq!(reused.perm, fresh.perm);
        assert_eq!(
            fresh.knn_all(&m, 3).unwrap().indices,
            reused.knn_all(&m, 3).unwrap().indices
        );
        // Rebuild over a sub-range: capacities only grow, leaves keep
        // global row ids, no stale nodes leak into queries.
        reused.rebuild_range(&m, 10, 30, 3);
        assert_eq!(reused.len(), 20);
        let res = reused.knn_query(&m, m.row(0), 4, u32::MAX);
        assert_eq!(res.len(), 4);
        assert!(res.iter().all(|&(_, j)| (10u32..30).contains(&j)));
    }
}
