//! Exact k-d tree for low-dimensional k-NN queries.
//!
//! The paper's complexity claim for TC rests on the `(t*−1)`-NN graph
//! being constructible in `O(k·n·log n)` when the covariate space is
//! low-dimensional (Friedman et al. 1976; Vaidya 1989). After the §5 PCA
//! step d is 2–7, squarely in k-d tree territory.
//!
//! Implementation notes (§Perf): nodes live in a flat arena with their
//! bounding boxes in a parallel flat `f32` arena (no per-node heap
//! indirection — the box pruning test is the hottest branch of the
//! query). Splits choose the axis of maximum spread at the median (via
//! `select_nth_unstable`); leaves hold up to `leaf_size` points and are
//! scanned linearly, which is both cache-friendly and what the Pallas
//! tile kernel mirrors at L1. Batch queries reuse one [`TopK`] and one
//! scratch buffer (`knn_range`) so the hot loop does not allocate.

use super::{KnnLists, TopK};
use crate::linalg::{sq_dist, Matrix};
use crate::{Error, Result};

/// Arena node: either an internal split or a leaf range into `perm`.
/// The node's bounding box lives at `bboxes[node_id * 2d ..]`.
#[derive(Clone, Debug)]
enum Node {
    Split { axis: u16, left: u32, right: u32 },
    Leaf { start: u32, end: u32 },
}

/// An immutable k-d tree over the rows of a [`Matrix`].
pub struct KdTree {
    nodes: Vec<Node>,
    /// `lo[d] ++ hi[d]` per node, indexed by node id.
    bboxes: Vec<f32>,
    /// Permutation of row indices; leaves own contiguous ranges.
    perm: Vec<u32>,
    root: u32,
    dim: usize,
    leaf_size: usize,
}

impl KdTree {
    /// Build with the default leaf size (tuned in the §Perf pass: the
    /// flat-arena + nearest-child-first query favors small leaves; 12
    /// was the sweep minimum at n = 10⁵, d = 2).
    pub fn build(points: &Matrix) -> Self {
        Self::build_with_leaf_size(points, 12)
    }

    /// Build with an explicit leaf size.
    pub fn build_with_leaf_size(points: &Matrix, leaf_size: usize) -> Self {
        let n = points.rows();
        let d = points.cols();
        let leaf_size = leaf_size.max(1);
        let mut perm: Vec<u32> = (0..n as u32).collect();
        let cap = 2 * (n / leaf_size + 1);
        let mut tree = KdTree {
            nodes: Vec::with_capacity(cap),
            bboxes: Vec::with_capacity(cap * 2 * d),
            perm: Vec::new(),
            root: 0,
            dim: d,
            leaf_size,
        };
        let root = if n == 0 {
            tree.push_node(Node::Leaf { start: 0, end: 0 }, &[f32::INFINITY], &[f32::NEG_INFINITY])
        } else {
            tree.build_rec(points, &mut perm, 0, n)
        };
        tree.root = root;
        tree.perm = perm;
        tree
    }

    fn push_node(&mut self, node: Node, lo: &[f32], hi: &[f32]) -> u32 {
        let id = self.nodes.len() as u32;
        self.nodes.push(node);
        // Degenerate (empty-tree) boxes are padded to `dim`.
        for j in 0..self.dim.max(1) {
            self.bboxes.push(lo.get(j).copied().unwrap_or(f32::INFINITY));
        }
        for j in 0..self.dim.max(1) {
            self.bboxes.push(hi.get(j).copied().unwrap_or(f32::NEG_INFINITY));
        }
        id
    }

    fn build_rec(&mut self, points: &Matrix, perm: &mut [u32], offset: usize, len: usize) -> u32 {
        let d = points.cols();
        let slice = &mut perm[offset..offset + len];
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for &i in slice.iter() {
            let row = points.row(i as usize);
            for j in 0..d {
                lo[j] = lo[j].min(row[j]);
                hi[j] = hi[j].max(row[j]);
            }
        }
        if len <= self.leaf_size {
            return self.push_node(
                Node::Leaf { start: offset as u32, end: (offset + len) as u32 },
                &lo,
                &hi,
            );
        }
        // Axis of maximum spread.
        let mut axis = 0usize;
        let mut best = -1.0f32;
        for j in 0..d {
            let spread = hi[j] - lo[j];
            if spread > best {
                best = spread;
                axis = j;
            }
        }
        if best <= 0.0 {
            // All points identical: force a leaf to avoid infinite recursion.
            return self.push_node(
                Node::Leaf { start: offset as u32, end: (offset + len) as u32 },
                &lo,
                &hi,
            );
        }
        let mid = len / 2;
        slice.select_nth_unstable_by(mid, |&a, &b| {
            points
                .get(a as usize, axis)
                .partial_cmp(&points.get(b as usize, axis))
                .unwrap()
                .then(a.cmp(&b))
        });
        let left = self.build_rec(points, perm, offset, mid);
        let right = self.build_rec(points, perm, offset + mid, len - mid);
        self.push_node(Node::Split { axis: axis as u16, left, right }, &lo, &hi)
    }

    /// Configured leaf size.
    pub fn leaf_size(&self) -> usize {
        self.leaf_size
    }

    /// Minimum squared distance from `q` to a node's bounding box.
    #[inline]
    fn bbox_min_dist(&self, node: u32, q: &[f32]) -> f32 {
        let d = self.dim.max(1);
        let base = node as usize * 2 * d;
        let lo = &self.bboxes[base..base + d];
        let hi = &self.bboxes[base + d..base + 2 * d];
        let mut acc = 0.0f32;
        for j in 0..q.len().min(d) {
            let v = q[j];
            let e = if v < lo[j] {
                lo[j] - v
            } else if v > hi[j] {
                v - hi[j]
            } else {
                0.0
            };
            acc += e * e;
        }
        acc
    }

    fn search(&self, points: &Matrix, q: &[f32], exclude: u32, node: u32, top: &mut TopK) {
        match self.nodes[node as usize] {
            Node::Leaf { start, end } => {
                for &idx in &self.perm[start as usize..end as usize] {
                    if idx == exclude {
                        continue;
                    }
                    let d = sq_dist(q, points.row(idx as usize));
                    if d < top.bound() {
                        top.push(d, idx);
                    }
                }
            }
            Node::Split { axis, left, right } => {
                // Descend into the child whose box is closer first.
                let dl = self.bbox_min_dist(left, q);
                let dr = self.bbox_min_dist(right, q);
                let _ = axis;
                let (near, near_d, far, far_d) =
                    if dl <= dr { (left, dl, right, dr) } else { (right, dr, left, dl) };
                if near_d < top.bound() {
                    self.search(points, q, exclude, near, top);
                }
                if far_d < top.bound() {
                    self.search(points, q, exclude, far, top);
                }
            }
        }
    }

    /// k nearest neighbors of the query vector `q` among the indexed
    /// points, excluding index `exclude` (pass `u32::MAX` to keep all).
    pub fn knn_query(&self, points: &Matrix, q: &[f32], k: usize, exclude: u32) -> Vec<(f32, u32)> {
        assert_eq!(q.len(), self.dim);
        let mut top = TopK::new(k);
        self.search(points, q, exclude, self.root, &mut top);
        top.into_sorted()
    }

    /// k-NN lists for every indexed point (self excluded): the TC step-1
    /// workhorse. Allocation-free per query (one reused [`TopK`] and
    /// scratch buffer), and queries are issued in tree (leaf) order so
    /// consecutive queries share search paths and cache lines (§Perf).
    pub fn knn_all(&self, points: &Matrix, k: usize) -> Result<KnnLists> {
        let n = points.rows();
        if k == 0 || k >= n {
            return Err(Error::InvalidArgument(format!("need 0 < k < n (k={k}, n={n})")));
        }
        let mut indices = vec![0u32; n * k];
        let mut dists = vec![0f32; n * k];
        let mut top = TopK::new(k);
        let mut scratch: Vec<(f32, u32)> = Vec::with_capacity(k);
        for &pi in &self.perm {
            let i = pi as usize;
            top.reset();
            self.search(points, points.row(i), pi, self.root, &mut top);
            top.drain_sorted_into(&mut scratch);
            debug_assert_eq!(scratch.len(), k);
            for (slot, &(d, j)) in scratch.iter().enumerate() {
                indices[i * k + slot] = j;
                dists[i * k + slot] = d;
            }
        }
        Ok(KnnLists { k, indices, dists })
    }

    /// [`Self::knn_all`] restricted to query rows `[start, end)` — the
    /// shard unit the coordinator's worker pool distributes.
    pub fn knn_range(
        &self,
        points: &Matrix,
        k: usize,
        start: usize,
        end: usize,
    ) -> Result<KnnLists> {
        let n = points.rows();
        if k == 0 || k >= n {
            return Err(Error::InvalidArgument(format!("need 0 < k < n (k={k}, n={n})")));
        }
        assert!(start <= end && end <= n);
        let m = end - start;
        let mut indices = vec![0u32; m * k];
        let mut dists = vec![0f32; m * k];
        let mut top = TopK::new(k);
        let mut scratch: Vec<(f32, u32)> = Vec::with_capacity(k);
        for i in start..end {
            top.reset();
            self.search(points, points.row(i), i as u32, self.root, &mut top);
            top.drain_sorted_into(&mut scratch);
            debug_assert_eq!(scratch.len(), k);
            let o = i - start;
            for (slot, &(d, j)) in scratch.iter().enumerate() {
                indices[o * k + slot] = j;
                dists[o * k + slot] = d;
            }
        }
        Ok(KnnLists { k, indices, dists })
    }

    /// All indexed points within squared radius `r2` of `q` (used by
    /// DBSCAN's region queries), excluding `exclude`.
    pub fn radius_query(&self, points: &Matrix, q: &[f32], r2: f32, exclude: u32) -> Vec<u32> {
        let mut out = Vec::new();
        self.radius_rec(points, q, r2, exclude, self.root, &mut out);
        out
    }

    fn radius_rec(
        &self,
        points: &Matrix,
        q: &[f32],
        r2: f32,
        exclude: u32,
        node: u32,
        out: &mut Vec<u32>,
    ) {
        if self.bbox_min_dist(node, q) > r2 {
            return;
        }
        match self.nodes[node as usize] {
            Node::Leaf { start, end } => {
                for &idx in &self.perm[start as usize..end as usize] {
                    if idx == exclude {
                        continue;
                    }
                    if sq_dist(q, points.row(idx as usize)) <= r2 {
                        out.push(idx);
                    }
                }
            }
            Node::Split { left, right, .. } => {
                self.radius_rec(points, q, r2, exclude, left, out);
                self.radius_rec(points, q, r2, exclude, right, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::knn::knn_brute;

    #[test]
    fn matches_brute_force_distances() {
        let ds = gaussian_mixture_paper(800, 31);
        let tree = KdTree::build(&ds.points);
        let brute = knn_brute(&ds.points, 6).unwrap();
        let fast = tree.knn_all(&ds.points, 6).unwrap();
        for i in 0..800 {
            let a = brute.distances(i);
            let b = fast.distances(i);
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() <= 1e-4 * (1.0 + x.abs()), "row {i}");
            }
        }
    }

    #[test]
    fn duplicate_points_handled() {
        // 100 copies of the same point + 10 distinct ones.
        let mut data = vec![1.0f32; 200];
        for i in 0..10 {
            data.push(i as f32 * 3.0);
            data.push(-(i as f32));
        }
        let m = Matrix::from_vec(data, 110, 2).unwrap();
        let tree = KdTree::build_with_leaf_size(&m, 4);
        let knn = tree.knn_all(&m, 3).unwrap();
        // A duplicated point's neighbors are other duplicates at distance 0.
        assert_eq!(knn.distances(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn radius_query_exact() {
        let ds = gaussian_mixture_paper(500, 32);
        let tree = KdTree::build(&ds.points);
        let q = ds.points.row(17).to_vec();
        let r2 = 0.5f32;
        let mut expect: Vec<u32> = (0..500u32)
            .filter(|&j| j != 17 && sq_dist(&q, ds.points.row(j as usize)) <= r2)
            .collect();
        let mut got = tree.radius_query(&ds.points, &q, r2, 17);
        expect.sort_unstable();
        got.sort_unstable();
        assert_eq!(expect, got);
    }

    #[test]
    fn small_leaf_sizes_consistent() {
        let ds = gaussian_mixture_paper(300, 33);
        let t1 = KdTree::build_with_leaf_size(&ds.points, 1);
        let t64 = KdTree::build_with_leaf_size(&ds.points, 64);
        let a = t1.knn_all(&ds.points, 4).unwrap();
        let b = t64.knn_all(&ds.points, 4).unwrap();
        for i in 0..300 {
            for (x, y) in a.distances(i).iter().zip(b.distances(i)) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn query_excludes_requested_index() {
        let ds = gaussian_mixture_paper(100, 34);
        let tree = KdTree::build(&ds.points);
        let res = tree.knn_query(&ds.points, ds.points.row(5), 10, 5);
        assert!(res.iter().all(|&(_, j)| j != 5));
        let res_all = tree.knn_query(&ds.points, ds.points.row(5), 10, u32::MAX);
        assert!(res_all.iter().any(|&(d, j)| j == 5 && d == 0.0));
    }

    #[test]
    fn knn_range_matches_knn_all() {
        let ds = gaussian_mixture_paper(400, 35);
        let tree = KdTree::build(&ds.points);
        let all = tree.knn_all(&ds.points, 4).unwrap();
        let mid = tree.knn_range(&ds.points, 4, 100, 250).unwrap();
        for i in 0..150 {
            assert_eq!(all.neighbors(100 + i), mid.neighbors(i));
        }
    }

    #[test]
    fn empty_and_tiny_trees() {
        let empty = Matrix::zeros(0, 2);
        let _ = KdTree::build(&empty); // must not panic
        let one = Matrix::from_vec(vec![1.0, 2.0], 1, 2).unwrap();
        let t = KdTree::build(&one);
        let res = t.knn_query(&one, &[0.0, 0.0], 1, u32::MAX);
        assert_eq!(res.len(), 1);
    }
}
