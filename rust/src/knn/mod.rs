//! k-nearest-neighbor graph construction.
//!
//! TC's step 1 (§2.3) builds the `(t*−1)`-nearest-neighbors subgraph. This
//! module provides three interchangeable backends:
//!
//! * [`knn_brute`] — exact `O(n²·d)`, the baseline and oracle.
//! * [`kdtree::KdTree`] — exact `O(k·n·log n)` for the low-dimensional
//!   covariate spaces the paper targets (d ≤ 8 after PCA).
//! * [`knn_chunked`] — exact, block-tiled queries×references evaluation
//!   driven through an arbitrary chunk evaluator; this is the entry point
//!   the PJRT runtime plugs its AOT pairwise-distance executable into, and
//!   the shape the coordinator shards across workers.
//!
//! All backends produce a [`KnnLists`], which [`graph::NeighborGraph`]
//! symmetrizes into the CSR adjacency TC consumes (Definition 6: the edge
//! `ij` exists iff `j` is one of `i`'s k nearest **or** `i` one of `j`'s).

pub mod graph;
pub mod kdtree;

use crate::linalg::{sq_dist, Matrix};
use crate::{Error, Result};

/// Directed k-NN lists: for each of `n` query points, its `k` nearest
/// neighbors (by squared Euclidean distance), self excluded, ascending.
#[derive(Clone, Debug)]
pub struct KnnLists {
    /// Neighbors per point.
    pub k: usize,
    /// `n × k` neighbor indices, row-major.
    pub indices: Vec<u32>,
    /// `n × k` squared distances, row-major, ascending per row.
    pub dists: Vec<f32>,
}

impl KnnLists {
    /// Number of query points.
    pub fn len(&self) -> usize {
        if self.k == 0 { 0 } else { self.indices.len() / self.k }
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Neighbor indices of point `i`.
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.indices[i * self.k..(i + 1) * self.k]
    }

    /// Squared distances of point `i`'s neighbor list.
    pub fn distances(&self, i: usize) -> &[f32] {
        &self.dists[i * self.k..(i + 1) * self.k]
    }
}

/// A bounded max-heap used to keep the k smallest distances seen so far.
/// Stored as a binary heap over (dist, idx) with the *largest* at the root
/// so it can be evicted in O(log k).
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    heap: Vec<(f32, u32)>,
}

impl TopK {
    /// New collector for the `k` smallest entries.
    pub fn new(k: usize) -> Self {
        Self { k, heap: Vec::with_capacity(k) }
    }

    /// The `k` this collector was built for.
    pub fn capacity(&self) -> usize {
        self.k
    }

    /// Clear for reuse (keeps the allocation) — the kd-tree batch query
    /// path calls this once per point instead of reallocating.
    pub fn reset(&mut self) {
        self.heap.clear();
    }

    /// Drain into `out` sorted ascending (ties by index), reusing both
    /// buffers. Leaves `self` empty.
    pub fn drain_sorted_into(&mut self, out: &mut Vec<(f32, u32)>) {
        out.clear();
        out.extend_from_slice(&self.heap);
        self.heap.clear();
        out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    }

    /// Current worst (largest) kept distance, or +inf while under-full.
    #[inline]
    pub fn bound(&self) -> f32 {
        if self.heap.len() < self.k { f32::INFINITY } else { self.heap[0].0 }
    }

    /// Offer a candidate.
    #[inline]
    pub fn push(&mut self, d: f32, idx: u32) {
        if self.heap.len() < self.k {
            self.heap.push((d, idx));
            let mut i = self.heap.len() - 1;
            while i > 0 {
                let parent = (i - 1) / 2;
                if self.heap[parent].0 < self.heap[i].0 {
                    self.heap.swap(parent, i);
                    i = parent;
                } else {
                    break;
                }
            }
        } else if d < self.heap[0].0 {
            self.heap[0] = (d, idx);
            // Sift down.
            let mut i = 0;
            loop {
                let (l, r) = (2 * i + 1, 2 * i + 2);
                let mut largest = i;
                if l < self.heap.len() && self.heap[l].0 > self.heap[largest].0 {
                    largest = l;
                }
                if r < self.heap.len() && self.heap[r].0 > self.heap[largest].0 {
                    largest = r;
                }
                if largest == i {
                    break;
                }
                self.heap.swap(i, largest);
                i = largest;
            }
        }
    }

    /// Drain into `(dist, idx)` pairs sorted ascending by distance
    /// (ties broken by index for determinism).
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.heap
            .sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        self.heap
    }
}

/// Exact brute-force k-NN: the `O(n²)` oracle used for tests and as the
/// baseline in the complexity benches.
pub fn knn_brute(points: &Matrix, k: usize) -> Result<KnnLists> {
    let n = points.rows();
    if k == 0 || k >= n {
        return Err(Error::InvalidArgument(format!("need 0 < k < n (k={k}, n={n})")));
    }
    let mut indices = vec![0u32; n * k];
    let mut dists = vec![0f32; n * k];
    for i in 0..n {
        let mut top = TopK::new(k);
        let qi = points.row(i);
        for j in 0..n {
            if j == i {
                continue;
            }
            let d = sq_dist(qi, points.row(j));
            if d < top.bound() {
                top.push(d, j as u32);
            }
        }
        for (slot, (d, j)) in top.into_sorted().into_iter().enumerate() {
            indices[i * k + slot] = j;
            dists[i * k + slot] = d;
        }
    }
    Ok(KnnLists { k, indices, dists })
}

/// A chunk evaluator: given a block of query rows (global offset `q0`) and
/// the full point set, fill per-query [`TopK`] collectors. The PJRT
/// runtime implements this with the AOT pairwise+top-k executable; the
/// native implementation tiles `pairwise_sq_dists`.
pub trait ChunkEvaluator {
    /// Evaluate queries `[q0, q0+nq)` against references `[r0, r0+nr)`,
    /// updating `tops[q]` for each local query index `q`.
    fn eval_block(
        &self,
        points: &Matrix,
        q0: usize,
        nq: usize,
        r0: usize,
        nr: usize,
        tops: &mut [TopK],
    ) -> Result<()>;
}

/// Native (pure-Rust) chunk evaluator mirroring the L1 Pallas kernel.
pub struct NativeChunks {
    /// Reference-block edge length.
    pub block: usize,
}

impl Default for NativeChunks {
    fn default() -> Self {
        Self { block: 1024 }
    }
}

impl ChunkEvaluator for NativeChunks {
    fn eval_block(
        &self,
        points: &Matrix,
        q0: usize,
        nq: usize,
        r0: usize,
        nr: usize,
        tops: &mut [TopK],
    ) -> Result<()> {
        for qi in 0..nq {
            let q = points.row(q0 + qi);
            let top = &mut tops[qi];
            for rj in r0..r0 + nr {
                if rj == q0 + qi {
                    continue;
                }
                let d = sq_dist(q, points.row(rj));
                if d < top.bound() {
                    top.push(d, rj as u32);
                }
            }
        }
        Ok(())
    }
}

/// Exact k-NN through a [`ChunkEvaluator`]: queries are processed in
/// blocks of `q_block`, references streamed in blocks of `r_block`. This
/// is the tiling the AOT artifacts are compiled for and the unit of work
/// the coordinator distributes.
pub fn knn_chunked(
    points: &Matrix,
    k: usize,
    q_block: usize,
    r_block: usize,
    eval: &dyn ChunkEvaluator,
) -> Result<KnnLists> {
    let n = points.rows();
    if k == 0 || k >= n {
        return Err(Error::InvalidArgument(format!("need 0 < k < n (k={k}, n={n})")));
    }
    let mut indices = vec![0u32; n * k];
    let mut dists = vec![0f32; n * k];
    let mut q0 = 0;
    while q0 < n {
        let nq = q_block.min(n - q0);
        let mut tops: Vec<TopK> = (0..nq).map(|_| TopK::new(k)).collect();
        let mut r0 = 0;
        while r0 < n {
            let nr = r_block.min(n - r0);
            eval.eval_block(points, q0, nq, r0, nr, &mut tops)?;
            r0 += nr;
        }
        for (qi, top) in tops.into_iter().enumerate() {
            let i = q0 + qi;
            for (slot, (d, j)) in top.into_sorted().into_iter().enumerate() {
                indices[i * k + slot] = j;
                dists[i * k + slot] = d;
            }
        }
        q0 += nq;
    }
    Ok(KnnLists { k, indices, dists })
}

/// Pick the best exact backend for the given workload: kd-tree for low
/// dimension, chunked brute force otherwise.
pub fn knn_auto(points: &Matrix, k: usize) -> Result<KnnLists> {
    if points.cols() <= 12 && points.rows() > 256 {
        kdtree::KdTree::build(points).knn_all(points, k)
    } else {
        knn_chunked(points, k, 256, 1024, &NativeChunks::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;

    #[test]
    fn topk_keeps_smallest() {
        let mut t = TopK::new(3);
        for (d, i) in [(5.0, 0), (1.0, 1), (4.0, 2), (0.5, 3), (9.0, 4), (2.0, 5)] {
            t.push(d, i);
        }
        let out = t.into_sorted();
        assert_eq!(out.iter().map(|x| x.1).collect::<Vec<_>>(), vec![3, 1, 5]);
        assert!(out.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn topk_underfull() {
        let mut t = TopK::new(5);
        t.push(2.0, 7);
        t.push(1.0, 3);
        let out = t.into_sorted();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1, 3);
    }

    #[test]
    fn brute_small_known() {
        // Points on a line: 0, 1, 3, 7.
        let m = Matrix::from_vec(vec![0.0, 1.0, 3.0, 7.0], 4, 1).unwrap();
        let knn = knn_brute(&m, 2).unwrap();
        assert_eq!(knn.neighbors(0), &[1, 2]); // d²=1, 9
        assert_eq!(knn.neighbors(1), &[0, 2]); // d²=1, 4
        assert_eq!(knn.neighbors(2), &[1, 0]); // d²=4, 9 (point 3 is d²=16)
        assert_eq!(knn.neighbors(3), &[2, 1]); // d²=16, 36
    }

    #[test]
    fn brute_rejects_bad_k() {
        let m = Matrix::zeros(4, 2);
        assert!(knn_brute(&m, 0).is_err());
        assert!(knn_brute(&m, 4).is_err());
    }

    #[test]
    fn chunked_matches_brute() {
        let ds = gaussian_mixture_paper(300, 21);
        let a = knn_brute(&ds.points, 5).unwrap();
        let b = knn_chunked(&ds.points, 5, 64, 128, &NativeChunks::default()).unwrap();
        assert_eq!(a.indices, b.indices);
        for (x, y) in a.dists.iter().zip(&b.dists) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn auto_matches_brute() {
        let ds = gaussian_mixture_paper(500, 22);
        let a = knn_brute(&ds.points, 3).unwrap();
        let b = knn_auto(&ds.points, 3).unwrap();
        // kd-tree may order equal distances differently; compare dists.
        for i in 0..ds.len() {
            let da = a.distances(i);
            let db = b.distances(i);
            for (x, y) in da.iter().zip(db) {
                assert!((x - y).abs() < 1e-4, "row {i}: {da:?} vs {db:?}");
            }
        }
    }

    #[test]
    fn rows_sorted_ascending() {
        let ds = gaussian_mixture_paper(200, 23);
        let knn = knn_auto(&ds.points, 4).unwrap();
        for i in 0..200 {
            let d = knn.distances(i);
            assert!(d.windows(2).all(|w| w[0] <= w[1]), "row {i}: {d:?}");
            assert!(!knn.neighbors(i).contains(&(i as u32)), "self in row {i}");
        }
    }
}
