//! k-nearest-neighbor graph construction.
//!
//! TC's step 1 (§2.3) builds the `(t*−1)`-nearest-neighbors subgraph. This
//! module provides three interchangeable backends:
//!
//! * [`knn_brute`] — exact `O(n²·d)`, the baseline and oracle.
//! * [`kdtree::KdTree`] — exact `O(k·n·log n)` for the low-dimensional
//!   covariate spaces the paper targets (d ≤ 8 after PCA).
//! * [`forest::KdForest`] — the kd-tree regime sharded: one tree per
//!   contiguous row shard, built in parallel, queried with merged
//!   candidates ([`knn_auto_sharded_into`], config knob `knn_shards`).
//! * [`knn_chunked`] — exact, block-tiled queries×references evaluation
//!   driven through an arbitrary chunk evaluator; this is the entry point
//!   the PJRT runtime plugs its AOT pairwise-distance executable into, and
//!   the shape the coordinator shards across workers.
//!
//! [`knn_auto`] routes every caller — `threshold_cluster`, ITIS, the
//! benches — through a shared work-stealing executor by default: the
//! kd-tree is built with parallel node partitioning and queried in
//! pool-sharded ranges, and the chunked path shards query blocks. All
//! backends share a total candidate order (distance, then index; see
//! [`TopK`]), so every path is deterministic and worker-count invariant;
//! the kd-tree paths (what `knn_auto` picks for the paper's post-PCA
//! dimensionalities) are additionally **byte-identical** to
//! [`knn_brute`] — the parity property tests pin this down. The
//! norm-trick chunked kernel is exact up to standard float
//! reassociation, matching the Pallas/PJRT kernel's arithmetic instead.
//!
//! Allocation discipline: every backend has a `*_into` variant that
//! writes into a caller-owned [`KnnLists`], so the ITIS reduction loop
//! reuses its `n×k` buffers across iterations instead of reallocating.
//!
//! All backends produce a [`KnnLists`], which [`graph::NeighborGraph`]
//! symmetrizes into the CSR adjacency TC consumes (Definition 6: the edge
//! `ij` exists iff `j` is one of `i`'s k nearest **or** `i` one of `j`'s).

pub mod forest;
pub mod graph;
pub mod kdtree;

use crate::exec::Executor;
// The dimensionality-regime constants (norm-trick and kd-tree
// boundaries) live in `linalg` next to the kernels they route between,
// so this dispatcher, the SIMD dispatcher, and the kernel docs share
// one source of truth.
use crate::linalg::{simd, sq_norm, Matrix, KDTREE_MAX_DIM, KDTREE_MIN_ROWS, NORM_TRICK_MIN_DIM};
use crate::{Error, Result};

/// Below this row count the pooled paths fall back to serial execution
/// (thread spawn overhead dominates).
const PARALLEL_QUERY_MIN: usize = 2048;
/// Below this row count the kd-tree is built serially.
const PARALLEL_BUILD_MIN: usize = 8192;

/// Directed k-NN lists: for each of `n` query points, its `k` nearest
/// neighbors (by squared Euclidean distance), self excluded, ascending.
#[derive(Clone, Debug, Default)]
pub struct KnnLists {
    /// Neighbors per point.
    pub k: usize,
    /// `n × k` neighbor indices, row-major.
    pub indices: Vec<u32>,
    /// `n × k` squared distances, row-major, ascending per row.
    pub dists: Vec<f32>,
}

impl KnnLists {
    /// Number of query points.
    pub fn len(&self) -> usize {
        if self.k == 0 { 0 } else { self.indices.len() / self.k }
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Neighbor indices of point `i`.
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.indices[i * self.k..(i + 1) * self.k]
    }

    /// Squared distances of point `i`'s neighbor list.
    pub fn distances(&self, i: usize) -> &[f32] {
        &self.dists[i * self.k..(i + 1) * self.k]
    }

    /// Resize for `n` queries × `k` neighbors, keeping existing capacity —
    /// the workspace-reuse hook the ITIS loop leans on (level sizes only
    /// shrink, so after the first iteration this never allocates).
    pub fn reset(&mut self, n: usize, k: usize) {
        self.k = k;
        self.indices.clear();
        self.indices.resize(n * k, 0);
        self.dists.clear();
        self.dists.resize(n * k, 0.0);
    }
}

/// Shared argument check for every k-NN entry point: `0 < k < n`. One
/// helper, one error message — the backends (brute, kd-tree, forest,
/// chunked, pooled) must reject degenerate workloads identically.
#[inline]
pub(crate) fn validate_k(n: usize, k: usize) -> Result<()> {
    if k == 0 || k >= n {
        return Err(Error::InvalidArgument(format!("need 0 < k < n (k={k}, n={n})")));
    }
    Ok(())
}

/// Total order on k-NN candidates: `a` is *worse* than `b` when it is
/// farther, ties broken toward the larger index. Ordering by
/// `(distance, index)` makes the kept set independent of visit order, so
/// every backend (brute, kd-tree, chunked, pooled) returns identical
/// lists — the cross-backend parity guarantees rest on this.
#[inline]
fn worse(a: (f32, u32), b: (f32, u32)) -> bool {
    a.0 > b.0 || (a.0 == b.0 && a.1 > b.1)
}

/// A bounded max-heap used to keep the k smallest `(dist, idx)` pairs
/// seen so far, under the total order of [`worse`]. Stored as a binary
/// heap with the *worst* kept pair at the root so it can be evicted in
/// O(log k).
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    heap: Vec<(f32, u32)>,
}

impl TopK {
    /// New collector for the `k` smallest entries.
    pub fn new(k: usize) -> Self {
        Self { k, heap: Vec::with_capacity(k) }
    }

    /// The `k` this collector was built for.
    pub fn capacity(&self) -> usize {
        self.k
    }

    /// Clear for reuse (keeps the allocation) — the batch query paths
    /// call this once per point instead of reallocating.
    pub fn reset(&mut self) {
        self.heap.clear();
    }

    /// Drain into `out` sorted ascending (ties by index), reusing both
    /// buffers. Leaves `self` empty.
    pub fn drain_sorted_into(&mut self, out: &mut Vec<(f32, u32)>) {
        out.clear();
        out.extend_from_slice(&self.heap);
        self.heap.clear();
        out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    }

    /// Current worst (largest) kept distance, or +inf while under-full.
    /// Candidates strictly beyond this bound can never enter; candidates
    /// *at* the bound still can (smaller index wins ties), so pruning
    /// must only skip regions strictly beyond it.
    #[inline]
    pub fn bound(&self) -> f32 {
        if self.heap.len() < self.k { f32::INFINITY } else { self.heap[0].0 }
    }

    /// Offer a candidate; keeps the k smallest under the `(dist, idx)`
    /// total order. Rejection is handled internally — callers need no
    /// bound pre-check.
    #[inline]
    pub fn push(&mut self, d: f32, idx: u32) {
        if self.heap.len() < self.k {
            self.heap.push((d, idx));
            let mut i = self.heap.len() - 1;
            while i > 0 {
                let parent = (i - 1) / 2;
                if worse(self.heap[i], self.heap[parent]) {
                    self.heap.swap(parent, i);
                    i = parent;
                } else {
                    break;
                }
            }
        } else if worse(self.heap[0], (d, idx)) {
            self.heap[0] = (d, idx);
            // Sift down.
            let mut i = 0;
            loop {
                let (l, r) = (2 * i + 1, 2 * i + 2);
                let mut worst = i;
                if l < self.heap.len() && worse(self.heap[l], self.heap[worst]) {
                    worst = l;
                }
                if r < self.heap.len() && worse(self.heap[r], self.heap[worst]) {
                    worst = r;
                }
                if worst == i {
                    break;
                }
                self.heap.swap(i, worst);
                i = worst;
            }
        }
    }

    /// Drain into `(dist, idx)` pairs sorted ascending by distance
    /// (ties broken by index for determinism).
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.heap
            .sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        self.heap
    }
}

/// Exact brute-force k-NN: the `O(n²)` oracle used for tests and as the
/// baseline in the complexity benches.
pub fn knn_brute(points: &Matrix, k: usize) -> Result<KnnLists> {
    let n = points.rows();
    validate_k(n, k)?;
    let mut indices = vec![0u32; n * k];
    let mut dists = vec![0f32; n * k];
    // One kernel dispatch for the whole O(n²) sweep.
    let sq = simd::sq_dist_kernel();
    for i in 0..n {
        let mut top = TopK::new(k);
        let qi = points.row(i);
        for j in 0..n {
            if j == i {
                continue;
            }
            top.push(sq(qi, points.row(j)), j as u32);
        }
        for (slot, (d, j)) in top.into_sorted().into_iter().enumerate() {
            indices[i * k + slot] = j;
            dists[i * k + slot] = d;
        }
    }
    Ok(KnnLists { k, indices, dists })
}

/// Reusable per-thread scratch for chunk evaluation: one reference-block
/// row of distances plus the reference norms of the norm-trick kernel.
/// Thread one through [`knn_chunked_into`] (done automatically) so the
/// hot loop stays allocation-free across blocks. A scratch belongs to a
/// single `knn_chunked*` call (one point set): the norm cache is keyed
/// only by row count.
#[derive(Debug, Default)]
pub struct ChunkScratch {
    /// `nr` distances of the current query row against the block.
    dist_row: Vec<f32>,
    /// `‖r‖²` for every reference row, filled lazily on the first block
    /// and reused by all subsequent blocks of the call.
    rnorms: Vec<f32>,
}

impl ChunkScratch {
    /// Empty scratch; buffers grow on first use and are then reused.
    pub fn new() -> Self {
        Self::default()
    }
}

/// A chunk evaluator: given a block of query rows (global offset `q0`) and
/// the full point set, fill per-query [`TopK`] collectors. The PJRT
/// runtime implements this with the AOT pairwise+top-k executable; the
/// native implementation tiles the norm-trick blocked kernel.
pub trait ChunkEvaluator {
    /// Evaluate queries `[q0, q0+nq)` against references `[r0, r0+nr)`,
    /// updating `tops[q]` for each local query index `q`.
    fn eval_block(
        &self,
        points: &Matrix,
        q0: usize,
        nq: usize,
        r0: usize,
        nr: usize,
        tops: &mut [TopK],
    ) -> Result<()>;

    /// Workspace-aware variant: implementations that need per-block
    /// buffers (the native norm-trick kernel) take them from `scratch`
    /// instead of allocating. The default ignores the scratch and
    /// delegates to [`Self::eval_block`].
    fn eval_block_ws(
        &self,
        points: &Matrix,
        q0: usize,
        nq: usize,
        r0: usize,
        nr: usize,
        tops: &mut [TopK],
        scratch: &mut ChunkScratch,
    ) -> Result<()> {
        let _ = scratch;
        self.eval_block(points, q0, nq, r0, nr, tops)
    }
}

/// Native (pure-Rust) chunk evaluator mirroring the L1 Pallas kernel.
///
/// For d ≥ [`NORM_TRICK_MIN_DIM`] the workspace path uses the same
/// `‖q‖² + ‖r‖² − 2 q·r` decomposition as the kernel (reference norms
/// precomputed once per block, dot-product inner loop); below that the
/// direct difference kernel wins and stays bit-identical to
/// [`crate::linalg::sq_dist`]. Both inner loops hoist their kernel
/// function pointer from [`simd`] once per block.
pub struct NativeChunks {
    /// Reference-block edge length.
    pub block: usize,
}

impl Default for NativeChunks {
    fn default() -> Self {
        Self { block: 1024 }
    }
}

impl ChunkEvaluator for NativeChunks {
    fn eval_block(
        &self,
        points: &Matrix,
        q0: usize,
        nq: usize,
        r0: usize,
        nr: usize,
        tops: &mut [TopK],
    ) -> Result<()> {
        // Hoisted dispatch: the block loop carries a bare fn-pointer
        // call, never a per-pair kernel lookup.
        let sq = simd::sq_dist_kernel();
        for qi in 0..nq {
            let q = points.row(q0 + qi);
            let top = &mut tops[qi];
            for rj in r0..r0 + nr {
                if rj == q0 + qi {
                    continue;
                }
                top.push(sq(q, points.row(rj)), rj as u32);
            }
        }
        Ok(())
    }

    fn eval_block_ws(
        &self,
        points: &Matrix,
        q0: usize,
        nq: usize,
        r0: usize,
        nr: usize,
        tops: &mut [TopK],
        scratch: &mut ChunkScratch,
    ) -> Result<()> {
        let d = points.cols();
        if d < NORM_TRICK_MIN_DIM {
            return self.eval_block(points, q0, nq, r0, nr, tops);
        }
        // Fill the norm cache once per call, not once per block — every
        // reference row is revisited n/q_block times otherwise.
        if scratch.rnorms.len() != points.rows() {
            scratch.rnorms.clear();
            scratch.rnorms.extend((0..points.rows()).map(|j| sq_norm(points.row(j))));
        }
        scratch.dist_row.clear();
        scratch.dist_row.resize(nr, 0.0);
        // Hoisted dispatch: the norm-trick inner loop is a bare
        // fn-pointer call (scalar = the historical inline loop,
        // bit-for-bit; AVX2 when the `simd` dispatcher installed it).
        let dot = simd::dot_kernel();
        for qi in 0..nq {
            let q = points.row(q0 + qi);
            let qn = sq_norm(q);
            for (jj, slot) in scratch.dist_row.iter_mut().enumerate() {
                // Clamp: catastrophic cancellation can go slightly negative.
                *slot = (qn + scratch.rnorms[r0 + jj] - 2.0 * dot(q, points.row(r0 + jj)))
                    .max(0.0);
            }
            let top = &mut tops[qi];
            for (jj, &dd) in scratch.dist_row.iter().enumerate() {
                let rj = r0 + jj;
                if rj == q0 + qi {
                    continue;
                }
                top.push(dd, rj as u32);
            }
        }
        Ok(())
    }
}

/// Exact k-NN through a [`ChunkEvaluator`]: queries are processed in
/// blocks of `q_block`, references streamed in blocks of `r_block`. This
/// is the tiling the AOT artifacts are compiled for and the unit of work
/// the coordinator distributes.
pub fn knn_chunked(
    points: &Matrix,
    k: usize,
    q_block: usize,
    r_block: usize,
    eval: &dyn ChunkEvaluator,
) -> Result<KnnLists> {
    let mut out = KnnLists::default();
    knn_chunked_into(points, k, q_block, r_block, eval, &mut out)?;
    Ok(out)
}

/// [`knn_chunked`] writing into a reusable output buffer. The per-query
/// [`TopK`] collectors and the evaluator scratch are allocated once and
/// reused across every query block (§Perf: the seed allocated a fresh
/// `Vec<TopK>` per block).
pub fn knn_chunked_into(
    points: &Matrix,
    k: usize,
    q_block: usize,
    r_block: usize,
    eval: &dyn ChunkEvaluator,
    out: &mut KnnLists,
) -> Result<()> {
    let n = points.rows();
    validate_k(n, k)?;
    let q_block = q_block.max(1);
    let r_block = r_block.max(1);
    out.reset(n, k);
    let mut tops: Vec<TopK> = (0..q_block.min(n)).map(|_| TopK::new(k)).collect();
    let mut scratch = ChunkScratch::new();
    let mut sort_buf: Vec<(f32, u32)> = Vec::with_capacity(k);
    let mut q0 = 0;
    while q0 < n {
        let nq = q_block.min(n - q0);
        for t in tops[..nq].iter_mut() {
            t.reset();
        }
        let mut r0 = 0;
        while r0 < n {
            let nr = r_block.min(n - r0);
            eval.eval_block_ws(points, q0, nq, r0, nr, &mut tops[..nq], &mut scratch)?;
            r0 += nr;
        }
        for (qi, top) in tops[..nq].iter_mut().enumerate() {
            let i = q0 + qi;
            top.drain_sorted_into(&mut sort_buf);
            for (slot, &(d, j)) in sort_buf.iter().enumerate() {
                out.indices[i * k + slot] = j;
                out.dists[i * k + slot] = d;
            }
        }
        q0 += nq;
    }
    Ok(())
}

/// Pool-sharded [`knn_chunked`]: contiguous runs of query blocks are
/// distributed across the executor (~4 tasks per worker, so the
/// [`TopK`] set, evaluator scratch, and norm cache amortize over many
/// blocks instead of being rebuilt per 256-row block). Tasks are always
/// whole multiples of `q_block`, so the (query block, reference block)
/// decomposition — and therefore the output — is byte-identical to the
/// serial path for any worker count.
pub fn knn_chunked_pool(
    points: &Matrix,
    k: usize,
    q_block: usize,
    r_block: usize,
    eval: &(dyn ChunkEvaluator + Sync),
    exec: &Executor,
) -> Result<KnnLists> {
    let mut out = KnnLists::default();
    knn_chunked_pool_into(points, k, q_block, r_block, eval, exec, &mut out)?;
    Ok(out)
}

/// [`knn_chunked_pool`] writing into a reusable output buffer. Workers
/// write directly into disjoint row ranges of `out` — no per-shard
/// result buffers, no stitch copy.
pub fn knn_chunked_pool_into(
    points: &Matrix,
    k: usize,
    q_block: usize,
    r_block: usize,
    eval: &(dyn ChunkEvaluator + Sync),
    exec: &Executor,
    out: &mut KnnLists,
) -> Result<()> {
    let n = points.rows();
    validate_k(n, k)?;
    let q_block = q_block.max(1);
    let r_block = r_block.max(1);
    out.reset(n, k);
    // Task size: a whole number of q_blocks, ~4 tasks per worker.
    let total_blocks = n.div_ceil(q_block);
    let target_tasks = exec.workers() * 4;
    let blocks_per_task = total_blocks.div_ceil(target_tasks).max(1);
    let task_rows = blocks_per_task * q_block;
    let KnnLists { indices, dists, .. } = out;
    let tasks: Vec<(usize, &mut [u32], &mut [f32])> = indices
        .chunks_mut(task_rows * k)
        .zip(dists.chunks_mut(task_rows * k))
        .enumerate()
        .map(|(ti, (is, ds))| (ti * task_rows, is, ds))
        .collect();
    exec.run_tasks(tasks, |(t0, is, ds)| {
        let rows = is.len() / k;
        // Per-task reusable state, amortized over every block the task
        // owns (mirrors the serial loop's hoisting).
        let mut tops: Vec<TopK> = (0..q_block.min(rows)).map(|_| TopK::new(k)).collect();
        let mut scratch = ChunkScratch::new();
        let mut sort_buf: Vec<(f32, u32)> = Vec::with_capacity(k);
        let mut off = 0;
        while off < rows {
            let nq = q_block.min(rows - off);
            let q0 = t0 + off;
            for t in tops[..nq].iter_mut() {
                t.reset();
            }
            let mut r0 = 0;
            while r0 < n {
                let nr = r_block.min(n - r0);
                eval.eval_block_ws(points, q0, nq, r0, nr, &mut tops[..nq], &mut scratch)?;
                r0 += nr;
            }
            for (qi, top) in tops[..nq].iter_mut().enumerate() {
                let local = off + qi;
                top.drain_sorted_into(&mut sort_buf);
                for (slot, &(d, j)) in sort_buf.iter().enumerate() {
                    is[local * k + slot] = j;
                    ds[local * k + slot] = d;
                }
            }
            off += nq;
        }
        Ok(())
    })?;
    Ok(())
}

/// Pick the best exact backend for the given workload — kd-tree for low
/// dimension, chunked norm-trick kernel otherwise — running on the
/// default executor. Every caller (TC, ITIS, benches) gets parallel
/// k-NN without opting in; use [`knn_auto_with`] to control the executor.
pub fn knn_auto(points: &Matrix, k: usize) -> Result<KnnLists> {
    knn_auto_with(points, k, &Executor::default())
}

/// [`knn_auto`] on an explicit executor.
pub fn knn_auto_with(points: &Matrix, k: usize, exec: &Executor) -> Result<KnnLists> {
    let mut out = KnnLists::default();
    knn_auto_into(points, k, exec, &mut out)?;
    Ok(out)
}

/// [`knn_auto_with`] writing into a reusable output buffer (the ITIS
/// loop's allocation-reuse hook). Small workloads run serially — the
/// executor only engages once the task fan-out amortizes.
pub fn knn_auto_into(
    points: &Matrix,
    k: usize,
    exec: &Executor,
    out: &mut KnnLists,
) -> Result<()> {
    let n = points.rows();
    validate_k(n, k)?;
    let parallel = n >= PARALLEL_QUERY_MIN && exec.workers() > 1;
    if kdtree_regime(points) {
        let tree = if n >= PARALLEL_BUILD_MIN && exec.workers() > 1 {
            kdtree::KdTree::build_parallel(points, exec)
        } else {
            kdtree::KdTree::build(points)
        };
        if parallel {
            tree.knn_all_pool_into(points, k, exec, out)
        } else {
            tree.knn_all_into(points, k, out)
        }
    } else if parallel {
        knn_chunked_pool_into(points, k, 256, 1024, &NativeChunks::default(), exec, out)
    } else {
        knn_chunked_into(points, k, 256, 1024, &NativeChunks::default(), out)
    }
}

/// The backend-routing predicate shared by [`knn_auto_into`] and
/// [`knn_auto_sharded_into`]: kd-trees win for the paper's
/// low-dimensional post-PCA spaces on non-tiny inputs; otherwise the
/// blocked norm-trick chunked kernel takes over. One predicate, two
/// dispatchers — so retuning the thresholds can never make the sharded
/// and single-tree paths route the same workload differently.
#[inline]
fn kdtree_regime(points: &Matrix) -> bool {
    points.cols() <= KDTREE_MAX_DIM && points.rows() > KDTREE_MIN_ROWS
}

/// [`knn_auto_into`] with a sharded kd-forest backend. When `shards > 1`
/// and the workload is in the kd-tree regime (the same [`kdtree_regime`]
/// routing as [`knn_auto_into`]), `forest` is rebuilt over `shards`
/// contiguous row shards — construction parallel across shards, tree
/// arenas reused across calls — and queried with merged per-shard
/// candidates, which is byte-identical to both the single-tree path and
/// [`knn_brute`]. With `shards <= 1`, or outside the kd-tree regime,
/// this is exactly [`knn_auto_into`] and `forest` is left untouched —
/// so `knn_shards: 1` cannot perturb existing output bytes.
pub fn knn_auto_sharded_into(
    points: &Matrix,
    k: usize,
    shards: usize,
    exec: &Executor,
    forest: &mut forest::KdForest,
    out: &mut KnnLists,
) -> Result<()> {
    let n = points.rows();
    validate_k(n, k)?;
    if shards <= 1 || !kdtree_regime(points) {
        return knn_auto_into(points, k, exec, out);
    }
    forest.rebuild(points, shards, exec);
    if n >= PARALLEL_QUERY_MIN && exec.workers() > 1 {
        forest.knn_all_pool_into(points, k, exec, out)
    } else {
        forest.knn_all_into(points, k, out)
    }
}

/// Allocating convenience over [`knn_auto_sharded_into`] for one-shot
/// callers and tests (throwaway forest and output buffers).
pub fn knn_auto_sharded(
    points: &Matrix,
    k: usize,
    shards: usize,
    exec: &Executor,
) -> Result<KnnLists> {
    let mut forest = forest::KdForest::new();
    let mut out = KnnLists::default();
    knn_auto_sharded_into(points, k, shards, exec, &mut forest, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::rng::Xoshiro256;

    #[test]
    fn topk_keeps_smallest() {
        let mut t = TopK::new(3);
        for (d, i) in [(5.0, 0), (1.0, 1), (4.0, 2), (0.5, 3), (9.0, 4), (2.0, 5)] {
            t.push(d, i);
        }
        let out = t.into_sorted();
        assert_eq!(out.iter().map(|x| x.1).collect::<Vec<_>>(), vec![3, 1, 5]);
        assert!(out.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn topk_underfull() {
        let mut t = TopK::new(5);
        t.push(2.0, 7);
        t.push(1.0, 3);
        let out = t.into_sorted();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1, 3);
    }

    #[test]
    fn topk_tie_break_by_index() {
        // Equal distances: the smaller index must win, regardless of
        // insertion order — the cross-backend determinism guarantee.
        for order in [[9u32, 2, 5], [5, 9, 2], [2, 5, 9]] {
            let mut t = TopK::new(2);
            for idx in order {
                t.push(1.0, idx);
            }
            let out = t.into_sorted();
            assert_eq!(out.iter().map(|x| x.1).collect::<Vec<_>>(), vec![2, 5], "{order:?}");
        }
    }

    #[test]
    fn brute_small_known() {
        // Points on a line: 0, 1, 3, 7.
        let m = Matrix::from_vec(vec![0.0, 1.0, 3.0, 7.0], 4, 1).unwrap();
        let knn = knn_brute(&m, 2).unwrap();
        assert_eq!(knn.neighbors(0), &[1, 2]); // d²=1, 9
        assert_eq!(knn.neighbors(1), &[0, 2]); // d²=1, 4
        assert_eq!(knn.neighbors(2), &[1, 0]); // d²=4, 9 (point 3 is d²=16)
        assert_eq!(knn.neighbors(3), &[2, 1]); // d²=16, 36
    }

    #[test]
    fn brute_rejects_bad_k() {
        let m = Matrix::zeros(4, 2);
        assert!(knn_brute(&m, 0).is_err());
        assert!(knn_brute(&m, 4).is_err());
    }

    #[test]
    fn chunked_matches_brute() {
        let ds = gaussian_mixture_paper(300, 21);
        let a = knn_brute(&ds.points, 5).unwrap();
        let b = knn_chunked(&ds.points, 5, 64, 128, &NativeChunks::default()).unwrap();
        assert_eq!(a.indices, b.indices);
        for (x, y) in a.dists.iter().zip(&b.dists) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    /// Random matrix in `dim` dimensions (exercises the norm-trick path,
    /// which engages at d ≥ 4).
    fn random_points(n: usize, dim: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian() as f32 * 2.0).collect();
        Matrix::from_vec(data, n, dim).unwrap()
    }

    #[test]
    fn norm_trick_matches_brute_distances() {
        let m = random_points(400, 8, 24);
        let a = knn_brute(&m, 6).unwrap();
        let b = knn_chunked(&m, 6, 64, 128, &NativeChunks::default()).unwrap();
        for i in 0..400 {
            for (x, y) in a.distances(i).iter().zip(b.distances(i)) {
                assert!((x - y).abs() <= 1e-4 * (1.0 + x.abs()), "row {i}");
            }
            let d = b.distances(i);
            assert!(d.windows(2).all(|w| w[0] <= w[1]), "row {i} unsorted");
        }
    }

    #[test]
    fn chunked_pool_byte_identical_to_serial() {
        let m = random_points(700, 8, 25);
        let serial = knn_chunked(&m, 4, 64, 256, &NativeChunks::default()).unwrap();
        for workers in [1usize, 2, 4] {
            let exec = Executor::new(workers);
            let par =
                knn_chunked_pool(&m, 4, 64, 256, &NativeChunks::default(), &exec).unwrap();
            assert_eq!(serial.indices, par.indices, "workers={workers}");
            let sb: Vec<u32> = serial.dists.iter().map(|d| d.to_bits()).collect();
            let pb: Vec<u32> = par.dists.iter().map(|d| d.to_bits()).collect();
            assert_eq!(sb, pb, "workers={workers}");
        }
    }

    #[test]
    fn auto_matches_brute() {
        let ds = gaussian_mixture_paper(500, 22);
        let a = knn_brute(&ds.points, 3).unwrap();
        let b = knn_auto(&ds.points, 3).unwrap();
        // The shared (distance, index) candidate order makes the two
        // backends agree exactly, not just up to distance ties.
        assert_eq!(a.indices, b.indices);
        for (x, y) in a.dists.iter().zip(&b.dists) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn auto_into_reuses_buffers() {
        let ds = gaussian_mixture_paper(600, 26);
        let exec = Executor::new(2);
        let mut out = KnnLists::default();
        knn_auto_into(&ds.points, 5, &exec, &mut out).unwrap();
        assert_eq!(out.len(), 600);
        let cap_i = out.indices.capacity();
        // A smaller follow-up query must fit in the existing allocation.
        let half = ds.points.slice_rows(0, 300);
        knn_auto_into(&half, 5, &exec, &mut out).unwrap();
        assert_eq!(out.len(), 300);
        assert_eq!(out.indices.capacity(), cap_i);
    }

    #[test]
    fn rows_sorted_ascending() {
        let ds = gaussian_mixture_paper(200, 23);
        let knn = knn_auto(&ds.points, 4).unwrap();
        for i in 0..200 {
            let d = knn.distances(i);
            assert!(d.windows(2).all(|w| w[0] <= w[1]), "row {i}: {d:?}");
            assert!(!knn.neighbors(i).contains(&(i as u32)), "self in row {i}");
        }
    }
}
