//! Symmetrized nearest-neighbor graph in CSR form.
//!
//! Definition 6 of the paper: the k-NN *subgraph* `NG_k` has the edge `ij`
//! iff `j` is one of the `k` closest vertices to `i` **or** vice versa.
//! TC (§2.3) then needs exactly two queries on this graph: adjacency
//! (walks of length 1) and two-walks (length ≤ 2). CSR gives both with
//! zero per-query allocation.

use super::KnnLists;

/// Reusable scratch for [`NeighborGraph::rebuild_from_knn`]: the
/// directed edge list and the cursor/row-sort buffers the CSR build
/// needs. The one-shot [`NeighborGraph::from_knn`] allocated these per
/// call — which in the ITIS loop meant per level;
/// [`crate::itis::ItisWorkspace`] now holds one scratch (plus the graph
/// itself) so graph construction stops allocating once warm.
#[derive(Clone, Debug, Default)]
pub struct GraphScratch {
    /// Canonicalized (`i < j`) directed edges, pre-dedup.
    edges: Vec<(u32, u32, f32)>,
    /// Per-vertex write cursor while scattering CSR rows.
    cursor: Vec<u32>,
    /// Single-row sort buffer.
    row: Vec<(u32, f32)>,
}

/// Undirected graph in compressed-sparse-row form.
#[derive(Clone, Debug)]
pub struct NeighborGraph {
    /// Row offsets, length `n + 1`.
    offsets: Vec<u32>,
    /// Column indices, sorted within each row.
    targets: Vec<u32>,
    /// Edge weights (squared distances), parallel to `targets`.
    weights: Vec<f32>,
}

impl Default for NeighborGraph {
    /// Empty graph (zero vertices) — the state a workspace slot holds
    /// before its first [`Self::rebuild_from_knn`].
    fn default() -> Self {
        Self { offsets: vec![0], targets: Vec::new(), weights: Vec::new() }
    }
}

impl NeighborGraph {
    /// Symmetrize directed k-NN lists into `NG_k` (one-shot; allocates).
    pub fn from_knn(knn: &KnnLists) -> Self {
        let mut g = Self::default();
        g.rebuild_from_knn(knn, &mut GraphScratch::default());
        g
    }

    /// Rebuild this graph in place from directed k-NN lists, reusing
    /// both the graph's CSR buffers and `scratch` across calls. The
    /// result is identical to [`Self::from_knn`]; only the allocation
    /// behavior differs.
    pub fn rebuild_from_knn(&mut self, knn: &KnnLists, scratch: &mut GraphScratch) {
        let n = knn.len();
        let k = knn.k;
        // Collect both directions, dedup (i<j canonical), then build CSR.
        let edges = &mut scratch.edges;
        edges.clear();
        edges.reserve(n * k);
        for i in 0..n {
            let nbrs = knn.neighbors(i);
            let ds = knn.distances(i);
            for (&j, &d) in nbrs.iter().zip(ds) {
                let (a, b) = if (i as u32) < j { (i as u32, j) } else { (j, i as u32) };
                edges.push((a, b, d));
            }
        }
        edges.sort_unstable_by(|x, y| x.0.cmp(&y.0).then(x.1.cmp(&y.1)));
        edges.dedup_by(|a, b| a.0 == b.0 && a.1 == b.1);

        // Count degrees straight into the (shifted) offsets, prefix-sum.
        self.offsets.clear();
        self.offsets.resize(n + 1, 0);
        for &(a, b, _) in edges.iter() {
            self.offsets[a as usize + 1] += 1;
            self.offsets[b as usize + 1] += 1;
        }
        for i in 0..n {
            self.offsets[i + 1] += self.offsets[i];
        }
        let m = self.offsets[n] as usize;
        self.targets.clear();
        self.targets.resize(m, 0);
        self.weights.clear();
        self.weights.resize(m, 0.0);
        scratch.cursor.clear();
        scratch.cursor.extend_from_slice(&self.offsets[..n]);
        for &(a, b, d) in edges.iter() {
            let ca = scratch.cursor[a as usize] as usize;
            self.targets[ca] = b;
            self.weights[ca] = d;
            scratch.cursor[a as usize] += 1;
            let cb = scratch.cursor[b as usize] as usize;
            self.targets[cb] = a;
            self.weights[cb] = d;
            scratch.cursor[b as usize] += 1;
        }
        // Rows come out sorted because edges were sorted by (a, b) and
        // reverse edges are appended in increasing a — but not guaranteed
        // for the reverse direction; sort each row for determinism.
        for i in 0..n {
            let (s, e) = (self.offsets[i] as usize, self.offsets[i + 1] as usize);
            scratch.row.clear();
            scratch
                .row
                .extend(self.targets[s..e].iter().copied().zip(self.weights[s..e].iter().copied()));
            scratch.row.sort_unstable_by_key(|&(t, _)| t);
            for (slot, &(t, w)) in scratch.row.iter().enumerate() {
                self.targets[s + slot] = t;
                self.weights[s + slot] = w;
            }
        }
    }

    /// Number of vertices.
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    /// True when the graph has no vertices.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of undirected edges.
    pub fn edge_count(&self) -> usize {
        self.targets.len() / 2
    }

    /// Neighbors of `i` (sorted ascending).
    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.targets[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// Edge weights parallel to [`Self::neighbors`].
    #[inline]
    pub fn weights(&self, i: usize) -> &[f32] {
        &self.weights[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// Degree of vertex `i`.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        (self.offsets[i + 1] - self.offsets[i]) as usize
    }

    /// Visit every vertex within a walk of length ≤ 2 of `i` (excluding
    /// `i` itself); `f(j, hops)` with hops ∈ {1, 2}. A vertex reachable at
    /// both 1 and 2 hops is reported at 1 hop only.
    pub fn for_two_walk(&self, i: usize, mut f: impl FnMut(u32, u8)) {
        // Mark direct neighbors to suppress duplicate 2-hop reports.
        let direct = self.neighbors(i);
        for &j in direct {
            f(j, 1);
        }
        for &j in direct {
            for &l in self.neighbors(j as usize) {
                if l as usize != i && direct.binary_search(&l).is_err() {
                    f(l, 2);
                }
            }
        }
    }

    /// Maximum edge weight in the graph (the bottleneck of `NG_k`).
    pub fn max_weight(&self) -> f32 {
        self.weights.iter().copied().fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture_paper;
    use crate::knn::knn_brute;

    fn line_graph() -> NeighborGraph {
        // Points 0,1,3,7 on a line, k=1: directed lists 0→1, 1→0, 2→1, 3→2.
        let m = crate::linalg::Matrix::from_vec(vec![0.0, 1.0, 3.0, 7.0], 4, 1).unwrap();
        let knn = knn_brute(&m, 1).unwrap();
        NeighborGraph::from_knn(&knn)
    }

    #[test]
    fn symmetrization_or_semantics() {
        let g = line_graph();
        // Edge 2-1 exists because 1 is 2's nearest, even though 2 is not 1's.
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.neighbors(2), &[1, 3]);
        assert_eq!(g.neighbors(3), &[2]);
        assert_eq!(g.edge_count(), 3);
    }

    #[test]
    fn weights_match_distances() {
        let g = line_graph();
        let i = g.neighbors(2).iter().position(|&t| t == 3).unwrap();
        assert_eq!(g.weights(2)[i], 16.0);
    }

    #[test]
    fn two_walk_visits_correct_set() {
        let g = line_graph();
        // From 0: 1-hop {1}, 2-hop {2} (via 1).
        let mut one = vec![];
        let mut two = vec![];
        g.for_two_walk(0, |j, h| if h == 1 { one.push(j) } else { two.push(j) });
        assert_eq!(one, vec![1]);
        assert_eq!(two, vec![2]);
    }

    #[test]
    fn two_walk_no_self_no_dup_direct() {
        let ds = gaussian_mixture_paper(200, 41);
        let knn = knn_brute(&ds.points, 3).unwrap();
        let g = NeighborGraph::from_knn(&knn);
        for i in 0..200 {
            let mut seen_direct = std::collections::HashSet::new();
            g.for_two_walk(i, |j, h| {
                assert_ne!(j as usize, i, "self reported from {i}");
                if h == 1 {
                    seen_direct.insert(j);
                } else {
                    assert!(!seen_direct.contains(&j), "dup 2-hop {j} from {i}");
                }
            });
        }
    }

    #[test]
    fn degrees_at_least_k() {
        // Each vertex has ≥ k incident edges after symmetrization.
        let ds = gaussian_mixture_paper(300, 42);
        let k = 4;
        let knn = knn_brute(&ds.points, k).unwrap();
        let g = NeighborGraph::from_knn(&knn);
        for i in 0..300 {
            assert!(g.degree(i) >= k, "degree({i}) = {}", g.degree(i));
        }
    }

    #[test]
    fn rebuild_reuse_matches_from_knn() {
        // One graph + scratch recycled across differently-sized inputs
        // must equal a fresh from_knn every time (stale CSR/edge-list
        // contents must never leak into the next build).
        let mut g = NeighborGraph::default();
        let mut scratch = GraphScratch::default();
        assert_eq!(g.len(), 0);
        for (n, k, seed) in [(250usize, 4usize, 44u64), (120, 2, 45), (250, 4, 44)] {
            let ds = gaussian_mixture_paper(n, seed);
            let knn = knn_brute(&ds.points, k).unwrap();
            g.rebuild_from_knn(&knn, &mut scratch);
            let fresh = NeighborGraph::from_knn(&knn);
            assert_eq!(g.len(), fresh.len());
            for i in 0..n {
                assert_eq!(g.neighbors(i), fresh.neighbors(i), "row {i}");
                assert_eq!(g.weights(i), fresh.weights(i), "row {i}");
            }
        }
    }

    #[test]
    fn rows_sorted() {
        let ds = gaussian_mixture_paper(150, 43);
        let knn = knn_brute(&ds.points, 5).unwrap();
        let g = NeighborGraph::from_knn(&knn);
        for i in 0..150 {
            let n = g.neighbors(i);
            assert!(n.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
