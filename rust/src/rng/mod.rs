//! Deterministic pseudo-random number generation.
//!
//! The simulation study in §4 of the paper samples from a bivariate
//! Gaussian mixture; this module provides the PRNG substrate (no external
//! `rand` crate is used anywhere in the repository so every experiment is
//! reproducible from a single `u64` seed).
//!
//! * [`SplitMix64`] — seeding / stream-splitting generator.
//! * [`Xoshiro256`] — xoshiro256++, the workhorse uniform generator.
//! * Gaussian variates via the polar Box–Muller transform.

/// SplitMix64: tiny, fast generator used to expand one `u64` seed into the
/// state of larger generators (and to derive independent streams).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality uniform generator.
///
/// Reference: Blackman & Vigna, "Scrambled linear pseudorandom number
/// generators" (2019).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
    /// Cached second Gaussian variate from the polar Box–Muller pair.
    gauss_spare: Option<f64>,
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive the `i`-th independent stream of a base seed. Used by the
    /// coordinator to give each shard worker its own generator.
    pub fn stream(seed: u64, i: u64) -> Self {
        // Mix the stream index through SplitMix64 so streams are decorrelated.
        let mut sm = SplitMix64::new(seed ^ (0xA076_1D64_78BD_642F_u64.wrapping_mul(i + 1)));
        Self::seed_from_u64(sm.next_u64())
    }

    /// Next raw 64-bit output (xoshiro256++ scrambler).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` using Lemire's method.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal variate via the polar Box–Muller method.
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // Membership probes only, never iterated: the output order comes
        // from Floyd's loop over j, so hash order cannot leak into it. A
        // bool table over 0..n would defeat the point of sampling k ≪ n.
        // det-lint: allow(hash-iter)
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_below(j as u64 + 1) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (reference vector from the SplitMix64
        // reference implementation).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256::seed_from_u64(7);
        let mut b = Xoshiro256::seed_from_u64(7);
        let mut c = Xoshiro256::seed_from_u64(8);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Xoshiro256::seed_from_u64(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Xoshiro256::seed_from_u64(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256::seed_from_u64(5);
        for _ in 0..100 {
            let idx = r.sample_indices(50, 10);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(idx.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn streams_are_decorrelated() {
        let mut s0 = Xoshiro256::stream(42, 0);
        let mut s1 = Xoshiro256::stream(42, 1);
        let a: Vec<u64> = (0..4).map(|_| s0.next_u64()).collect();
        let b: Vec<u64> = (0..4).map(|_| s1.next_u64()).collect();
        assert_ne!(a, b);
    }
}
