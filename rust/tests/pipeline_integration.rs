//! Cross-module integration tests: IHTC invariants at realistic scale,
//! config-file driven runs, CSV round trips, and failure injection.

use ihtc::cluster::hac::Linkage;
use ihtc::config::PipelineConfig;
use ihtc::coordinator::driver;
use ihtc::data::synth::{gaussian_mixture_paper, realistic, TABLE3};
use ihtc::data::{csv, Preprocess};
use ihtc::hybrid::{FinalClusterer, Ihtc};
use ihtc::metrics;
use ihtc::rng::Xoshiro256;

#[test]
fn ihtc_kmeans_accuracy_matches_paper_band() {
    // Paper Table 1: accuracy ≈ 0.9239 at n = 10⁴, roughly flat in m.
    let ds = gaussian_mixture_paper(10_000, 1001);
    let truth = ds.labels.as_ref().unwrap();
    let mut accs = Vec::new();
    for m in 0..=4 {
        let r = Ihtc::new(2, m, FinalClusterer::KMeans { k: 3, restarts: 6 })
            .run(&ds.points)
            .unwrap();
        accs.push(metrics::prediction_accuracy(truth, &r.assignments).unwrap());
    }
    // The m = 0 baseline should land in the paper's band and decay by at
    // most a couple of points over the first four iterations.
    assert!(accs[0] > 0.90, "baseline {accs:?}");
    for (m, &a) in accs.iter().enumerate() {
        assert!(a > accs[0] - 0.03, "m={m}: {accs:?}");
    }
}

#[test]
fn ihtc_cluster_size_guarantee_large() {
    let ds = gaussian_mixture_paper(20_000, 1002);
    let r = Ihtc::new(2, 5, FinalClusterer::KMeans { k: 3, restarts: 2 })
        .run(&ds.points)
        .unwrap();
    assert!(metrics::min_cluster_size(&r.assignments) >= 32); // 2⁵
}

#[test]
fn itis_bottleneck_growth_is_bounded() {
    // ITIS prototypes drift from their units, but the composed clusters'
    // bottleneck should stay within a small factor of the one-level bound.
    let ds = gaussian_mixture_paper(4_000, 1003);
    let r = ihtc::itis::itis(&ds.points, &ihtc::itis::ItisConfig::iterations(2, 1)).unwrap();
    let map = r.unit_to_prototype();
    let bn = metrics::bottleneck(&ds.points, &map, 200).unwrap();
    // t* = 2, m = 1: direct TC bound is 4λ where λ ≤ max 1-NN distance.
    let knn = ihtc::knn::knn_auto(&ds.points, 1).unwrap();
    let max_nn = (0..4000)
        .map(|i| knn.distances(i)[0])
        .fold(0.0f32, f32::max)
        .sqrt() as f64;
    assert!(bn <= 4.0 * max_nn + 1e-6, "bottleneck {bn} vs 4λ̂ {}", 4.0 * max_nn);
}

#[test]
fn hac_hybrid_on_analogue_beats_cap() {
    let spec = &TABLE3[0]; // PM 2.5
    let ds = realistic(spec, 10, 1004);
    let prep = Preprocess { standardize: true, pca_variance: Some(0.99), max_components: None }
        .apply(&ds)
        .unwrap();
    let r = Ihtc::new(2, 3, FinalClusterer::Hac { k: spec.classes, linkage: Linkage::Ward })
        .run(&prep.points)
        .unwrap();
    assert!(r.num_prototypes() < prep.len() / 4);
    let ratio = metrics::bss_tss(&prep.points, &r.assignments).unwrap();
    assert!(ratio > 0.3, "BSS/TSS {ratio}");
}

#[test]
fn config_file_driven_run() {
    let dir = std::env::temp_dir().join("ihtc_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("run.json");
    let out_path = dir.join("assign.csv");
    std::fs::write(
        &cfg_path,
        format!(
            r#"{{
              "name": "itest",
              "source": {{"kind": "paper_mixture", "n": 2500}},
              "threshold": 2,
              "iterations": 2,
              "workers": 2,
              "clusterer": {{"kind": "kmeans", "k": 3, "restarts": 2}},
              "output": "{}"
            }}"#,
            out_path.display()
        ),
    )
    .unwrap();
    let cfg = PipelineConfig::from_file(cfg_path.to_str().unwrap()).unwrap();
    let (assign, report) = driver::run(&cfg).unwrap();
    assert_eq!(assign.len(), 2500);
    assert_eq!(report.name, "itest");
    let text = std::fs::read_to_string(&out_path).unwrap();
    assert_eq!(text.lines().count(), 2501);
}

#[test]
fn csv_source_round_trip_through_pipeline() {
    let dir = std::env::temp_dir().join("ihtc_itest_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let data_path = dir.join("data.csv");
    let ds = gaussian_mixture_paper(1200, 1005);
    csv::write_csv(&ds, &data_path).unwrap();
    let cfg = PipelineConfig {
        source: ihtc::config::DataSource::Csv {
            path: data_path.to_string_lossy().into_owned(),
            label_column: Some(2),
        },
        workers: 2,
        ..Default::default()
    };
    let (_, report) = driver::run(&cfg).unwrap();
    assert_eq!(report.n, 1200);
    // Labels survived the CSV hop → accuracy computable and sane.
    assert!(report.accuracy.unwrap() > 0.8, "{:?}", report.accuracy);
}

#[test]
fn pipeline_error_paths() {
    // Missing CSV file.
    let cfg = PipelineConfig {
        source: ihtc::config::DataSource::Csv {
            path: "/no/such/file.csv".into(),
            label_column: None,
        },
        ..Default::default()
    };
    assert!(driver::run(&cfg).is_err());
    // Invalid config json.
    assert!(PipelineConfig::from_json("{not json").is_err());
}

#[test]
fn duplicate_heavy_dataset_survives_full_stack() {
    // Pathological input: 60% of points identical. TC, ITIS, k-means and
    // the metrics must all cope (zero distances, degenerate clusters).
    let mut rng = Xoshiro256::seed_from_u64(1006);
    let n = 2000;
    let mut data = Vec::with_capacity(n * 2);
    for i in 0..n {
        if i < 1200 {
            data.push(5.0f32);
            data.push(5.0f32);
        } else {
            data.push(rng.next_gaussian() as f32 * 3.0);
            data.push(rng.next_gaussian() as f32 * 3.0);
        }
    }
    let m = ihtc::linalg::Matrix::from_vec(data, n, 2).unwrap();
    let r = Ihtc::new(2, 2, FinalClusterer::KMeans { k: 3, restarts: 2 }).run(&m).unwrap();
    assert_eq!(r.assignments.len(), n);
    // All duplicates must land in the same final cluster.
    let first = r.assignments[0];
    assert!(r.assignments[..1200].iter().all(|&a| a == first));
}

#[test]
fn seeded_runs_are_reproducible_end_to_end() {
    let cfg = PipelineConfig {
        source: ihtc::config::DataSource::PaperMixture { n: 3000 },
        workers: 3,
        ..Default::default()
    };
    let (a1, _) = driver::run(&cfg).unwrap();
    let (a2, _) = driver::run(&cfg).unwrap();
    assert_eq!(a1, a2, "same seed + config must give identical clusterings");
}
