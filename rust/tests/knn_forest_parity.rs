//! Sharded kd-forest parity property suite: for every tested
//! `knn_shards ∈ {1, 2, 4} × workers ∈ {1, 2, 4}` combination, the
//! forest must produce **byte-identical** `KnnLists` to the `knn_brute`
//! oracle, and `knn_shards: 1` must be byte-identical to the single-tree
//! path. This pins down the tentpole contract: shard boundaries depend
//! only on `(n, s)`, per-shard trees are exact, and candidates merge
//! through the shared `(distance, index)` total order — so sharding and
//! pooling can only change wall-clock, never output bytes. The final
//! test drives the streaming coordinator end-to-end across shard counts.

use ihtc::config::{DataSource, PipelineConfig};
use ihtc::coordinator::driver;
use ihtc::exec::Executor;
use ihtc::data::synth::gaussian_mixture_paper;
use ihtc::itis::PrototypeKind;
use ihtc::knn::forest::KdForest;
use ihtc::knn::{knn_auto_sharded, knn_auto_sharded_into, knn_auto_with, knn_brute, KnnLists};

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_identical(got: &KnnLists, oracle: &KnnLists, what: &str) {
    assert_eq!(got.k, oracle.k, "{what}: k");
    assert_eq!(got.indices, oracle.indices, "{what}: neighbor indices");
    assert_eq!(bits(&got.dists), bits(&oracle.dists), "{what}: distance bits");
}

#[test]
fn forest_byte_identical_to_brute_across_shards_and_workers() {
    // n spans the serial/parallel query routing threshold (2048); k
    // spans t*−1 for small and large thresholds.
    for &(n, k) in &[(700usize, 3usize), (2600, 2), (2600, 7)] {
        let ds = gaussian_mixture_paper(n, 0xF0E5 + (n + k) as u64);
        let oracle = knn_brute(&ds.points, k).unwrap();
        for shards in [1usize, 2, 4] {
            for workers in [1usize, 2, 4] {
                let pool = Executor::new(workers);
                let got = knn_auto_sharded(&ds.points, k, shards, &pool).unwrap();
                assert_identical(
                    &got,
                    &oracle,
                    &format!("n={n} k={k} shards={shards} workers={workers}"),
                );
            }
        }
    }
}

#[test]
fn shards_one_byte_identical_to_single_tree_path() {
    let ds = gaussian_mixture_paper(3000, 0xA11CE);
    for workers in [1usize, 2, 4] {
        let pool = Executor::new(workers);
        let single = knn_auto_with(&ds.points, 4, &pool).unwrap();
        let sharded = knn_auto_sharded(&ds.points, 4, 1, &pool).unwrap();
        assert_identical(&sharded, &single, &format!("workers={workers}"));
    }
}

#[test]
fn forest_handles_duplicate_ties_identically() {
    // Heavy exact-tie workload: 60% duplicated points, with duplicates
    // straddling shard boundaries. Ties are where nondeterminism would
    // hide; the shared candidate order must keep every shard count
    // identical to the oracle.
    let n = 1500;
    let mut data = Vec::with_capacity(n * 2);
    for i in 0..n {
        if i % 5 < 3 {
            data.push(1.25f32);
            data.push(-0.5f32);
        } else {
            data.push((i % 97) as f32 * 0.1);
            data.push((i % 89) as f32 * 0.2);
        }
    }
    let m = ihtc::linalg::Matrix::from_vec(data, n, 2).unwrap();
    let oracle = knn_brute(&m, 4).unwrap();
    for shards in [1usize, 2, 4] {
        for workers in [1usize, 2, 4] {
            let pool = Executor::new(workers);
            let got = knn_auto_sharded(&m, 4, shards, &pool).unwrap();
            assert_identical(&got, &oracle, &format!("dups shards={shards} workers={workers}"));
        }
    }
}

#[test]
fn shard_pruning_handles_boundary_ties_identically() {
    // The per-shard pruning case: far-apart blobs aligned with shard
    // boundaries (so whole shard trees sit strictly beyond the TopK
    // bound and are skipped) *plus* duplicated points whose distance
    // ties sit exactly AT the bound across a shard boundary — the
    // strict-inequality skip rule must keep tie candidates from pruned-
    // looking shards eligible, exactly like the in-tree descent. Byte
    // parity with the oracle pins it for every shard × worker count.
    let n = 1200usize;
    let mut data = Vec::with_capacity(n * 2);
    for i in 0..n {
        let blob = (i / 300) as f32; // 4 far-apart blobs, 300 rows each
        if i % 3 == 0 {
            // Duplicates at the blob center: exact zero-distance ties,
            // including across the 300-row shard boundary when the
            // forest uses 2 or 4 shards (rows 299/300 both duplicates).
            data.push(blob * 5e3);
            data.push(blob * -5e3);
        } else {
            data.push(blob * 5e3 + (i % 13) as f32 * 0.25);
            data.push(blob * -5e3 + (i % 11) as f32 * 0.5);
        }
    }
    let m = ihtc::linalg::Matrix::from_vec(data, n, 2).unwrap();
    let oracle = knn_brute(&m, 6).unwrap();
    for shards in [1usize, 2, 4, 8] {
        for workers in [1usize, 2, 4] {
            let pool = Executor::new(workers);
            let got = knn_auto_sharded(&m, 6, shards, &pool).unwrap();
            assert_identical(
                &got,
                &oracle,
                &format!("pruning ties shards={shards} workers={workers}"),
            );
        }
    }
}

#[test]
fn degenerate_k_rejected_and_shards_clamped() {
    // n ≤ k and k = 0 are errors on every backend, forest included.
    let tiny = gaussian_mixture_paper(5, 0xD0D0);
    let pool = Executor::new(2);
    let mut forest = KdForest::new();
    let mut out = KnnLists::default();
    for k in [0usize, 5, 7] {
        assert!(
            knn_auto_sharded_into(&tiny.points, k, 4, &pool, &mut forest, &mut out).is_err(),
            "k={k} must be rejected"
        );
    }
    // More shards than rows clamps to one row per shard and stays exact.
    let ds = gaussian_mixture_paper(40, 0xD0D1);
    forest.rebuild(&ds.points, 64, &pool);
    assert_eq!(forest.shards(), 40);
    forest.knn_all_into(&ds.points, 3, &mut out).unwrap();
    let oracle = knn_brute(&ds.points, 3).unwrap();
    assert_identical(&out, &oracle, "clamped shards");
}

#[test]
fn forest_workspace_reuse_across_levels_is_clean() {
    // Mimic the ITIS loop: one forest + output buffer reused across
    // shrinking levels must stay oracle-identical at every level.
    let pool = Executor::new(2);
    let mut forest = KdForest::new();
    let mut out = KnnLists::default();
    for (n, seed) in [(2600usize, 7u64), (1100, 8), (400, 9)] {
        let ds = gaussian_mixture_paper(n, seed);
        knn_auto_sharded_into(&ds.points, 3, 4, &pool, &mut forest, &mut out).unwrap();
        let oracle = knn_brute(&ds.points, 3).unwrap();
        assert_identical(&out, &oracle, &format!("level n={n}"));
    }
}

fn driver_config(n: usize, streaming: bool, knn_shards: usize) -> PipelineConfig {
    let prototype =
        if streaming { PrototypeKind::WeightedCentroid } else { PrototypeKind::Centroid };
    PipelineConfig {
        source: DataSource::PaperMixture { n },
        streaming,
        prototype,
        workers: 2,
        shard_size: 512,
        knn_shards,
        ..Default::default()
    }
}

#[test]
fn materialized_driver_labels_identical_across_knn_shards() {
    let (base, _) = driver::run(&driver_config(3000, false, 1)).unwrap();
    for shards in [2usize, 4] {
        let (got, report) = driver::run(&driver_config(3000, false, shards)).unwrap();
        assert_eq!(base, got, "knn_shards={shards}");
        assert_eq!(report.n, 3000);
    }
}

#[test]
fn streaming_driver_labels_identical_across_knn_shards() {
    // End-to-end through the fused streaming ingest: every per-shard
    // ShardReducer runs its level-0 k-NN on a kd-forest, and the resumed
    // ITIS levels run on the coordinator's forest — final labels must be
    // identical for every knn_shards value.
    let (base, _) = driver::run(&driver_config(2500, true, 1)).unwrap();
    for shards in [2usize, 4] {
        let (got, report) = driver::run(&driver_config(2500, true, shards)).unwrap();
        assert_eq!(base, got, "knn_shards={shards}");
        assert_eq!(report.n, 2500);
    }
}
