//! Integration tests over the PJRT runtime: AOT artifacts loaded through
//! the xla crate must agree with the native Rust implementations.
//!
//! The whole file is quarantined behind the `pjrt` feature, so CI's
//! feature-matrix job (`--features pjrt`) compiles it against the
//! runtime stub — keeping this surface building — while the tests
//! themselves only execute against the real engine (`pjrt-runtime` +
//! the `xla` crate). They also skip (with a message) when
//! `artifacts/manifest.json` is missing so `cargo test` works before
//! `make artifacts`.
#![cfg(feature = "pjrt")]

use ihtc::cluster::kmeans::{kmeans_with_backend, KMeansConfig, NativeAssign};
use ihtc::data::synth::gaussian_mixture_paper;
use ihtc::knn::{knn_auto, knn_chunked};
use ihtc::runtime::{Engine, PjrtAssign, PjrtChunks};

fn engine() -> Option<Engine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    if cfg!(not(feature = "pjrt-runtime")) {
        eprintln!("skipping: runtime stub compiled in (build with `pjrt-runtime` + xla)");
        return None;
    }
    Some(Engine::load(dir).expect("engine load"))
}

#[test]
fn knn_pjrt_matches_native_distances() {
    let Some(engine) = engine() else { return };
    let ds = gaussian_mixture_paper(3000, 71);
    let native = knn_auto(&ds.points, 5).unwrap();
    let pjrt = knn_chunked(
        &ds.points,
        5,
        engine.tile.knn_q,
        engine.tile.knn_r,
        &PjrtChunks { engine: &engine },
    )
    .unwrap();
    for i in 0..3000 {
        let a = native.distances(i);
        let b = pjrt.distances(i);
        for (x, y) in a.iter().zip(b) {
            assert!(
                (x - y).abs() <= 1e-3 * (1.0 + x.abs()),
                "row {i}: {a:?} vs {b:?}"
            );
        }
    }
}

#[test]
fn knn_pjrt_handles_ragged_tail() {
    // n not a multiple of the tile sizes exercises the padding path.
    let Some(engine) = engine() else { return };
    let ds = gaussian_mixture_paper(1371, 72);
    let native = knn_auto(&ds.points, 3).unwrap();
    let pjrt = knn_chunked(
        &ds.points,
        3,
        engine.tile.knn_q,
        engine.tile.knn_r,
        &PjrtChunks { engine: &engine },
    )
    .unwrap();
    for i in 0..1371 {
        for (x, y) in native.distances(i).iter().zip(pjrt.distances(i)) {
            assert!((x - y).abs() <= 1e-3 * (1.0 + x.abs()), "row {i}");
        }
    }
}

#[test]
fn kmeans_pjrt_matches_native_objective() {
    let Some(engine) = engine() else { return };
    let ds = gaussian_mixture_paper(5000, 73);
    let cfg = KMeansConfig { restarts: 2, ..KMeansConfig::new(3) };
    let native = kmeans_with_backend(&ds.points, None, &cfg, &NativeAssign).unwrap();
    let pjrt =
        kmeans_with_backend(&ds.points, None, &cfg, &PjrtAssign { engine: &engine }).unwrap();
    // Same seeds + same argmin semantics → identical assignments.
    assert_eq!(native.assignments, pjrt.assignments);
    assert!(
        (native.wcss - pjrt.wcss).abs() < 1e-2 * (1.0 + native.wcss),
        "{} vs {}",
        native.wcss,
        pjrt.wcss
    );
}

#[test]
fn kmeans_pjrt_rejects_weights() {
    let Some(engine) = engine() else { return };
    let ds = gaussian_mixture_paper(100, 74);
    let w = vec![1.0f32; 100];
    let cfg = KMeansConfig::new(3);
    let res = kmeans_with_backend(&ds.points, Some(&w), &cfg, &PjrtAssign { engine: &engine });
    assert!(res.is_err());
}

#[test]
fn pjrt_pipeline_end_to_end() {
    let Some(_engine) = engine() else { return };
    // Full driver run with backend = pjrt.
    let cfg = ihtc::config::PipelineConfig {
        source: ihtc::config::DataSource::PaperMixture { n: 3000 },
        backend: ihtc::config::Backend::Pjrt,
        workers: 2,
        ..Default::default()
    };
    // Point the engine loader at the manifest-relative dir.
    std::env::set_var(
        "IHTC_ARTIFACTS",
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    );
    let (assign, report) = ihtc::coordinator::driver::run(&cfg).unwrap();
    std::env::remove_var("IHTC_ARTIFACTS");
    assert_eq!(assign.len(), 3000);
    assert!(report.accuracy.unwrap() > 0.85, "{report:?}");
}
